//! Quickstart: protect a GEMM with V-ABFT, inject a soft error, watch it
//! get detected, localized and corrected online.
//!
//! ```text
//! cargo run --release --example quickstart
//! ```

use vabft::prelude::*;

fn main() -> vabft::error::Result<()> {
    // 1. Operands: a BF16 activation × weight multiply (the mixed-precision
    //    deep-learning setting the paper targets).
    let mut rng = Xoshiro256pp::seed_from_u64(2026);
    let dist = Distribution::normal_1_1();
    let a = Matrix::sample_in(32, 256, &dist, Precision::Bf16, &mut rng);
    let b = Matrix::sample_in(256, 64, &dist, Precision::Bf16, &mut rng);

    // 2. A fault-tolerant GEMM executor: BF16 inputs, FP32 accumulation
    //    (the GPU/NPU "wide" model), V-ABFT thresholds, online (fused-
    //    kernel) verification with correction enabled.
    //    `EngineConfig::auto()` picks worker threads and the SIMD level
    //    for this host and folds in the `vabft autotune` manifest when
    //    one exists — all pure scheduling, so outputs are bitwise the
    //    same as `GemmEngine::new` (the serial scalar default).
    let engine =
        GemmEngine::with_config(AccumModel::wide(Precision::Bf16), EngineConfig::auto());
    let ft = FtGemm::new(engine, Box::new(VabftThreshold::default()), VerifyPolicy::default());

    // 3. Clean multiply: verifies clean.
    let clean = ft.multiply(&a, &b)?;
    println!("clean multiply:    verdict {:?}", clean.report.verdict);
    assert_eq!(clean.report.verdict, Verdict::Clean);

    // 4. Inject a single-event upset: flip an exponent bit of one FP32
    //    accumulator element (bit 26 scales the value by 2^16).
    let site = InjectionSite { row: 5, col: 17 };
    let faulty = ft.multiply_with_injection(&a, &b, |out| {
        let flip = BitFlip::new(26, Precision::F32);
        let (old, new, dir) = (
            out.acc.get(site.row, site.col),
            flip.apply(out.acc.get(site.row, site.col)).0,
            flip.apply(out.acc.get(site.row, site.col)).1,
        );
        out.acc.set(site.row, site.col, new);
        out.c.set(site.row, site.col, Precision::Bf16.quantize(new));
        println!("injected SEU:      {old:+.4} -> {new:+.4e} ({dir:?} at bit 26, site {site:?})");
    })?;

    // 5. The verification pipeline caught and repaired it.
    println!("faulty multiply:   verdict {:?}", faulty.report.verdict);
    for d in &faulty.report.detections {
        println!(
            "  detection: row {} col {:?}  D1 {:+.3e}  threshold {:.3e}  corrected={}",
            d.row, d.col, d.d1, d.threshold, d.corrected
        );
    }
    let diff = faulty.c.max_abs_diff(&clean.c);
    println!("max |corrected - clean| = {diff:.3e}");
    assert!(diff < 1e-2, "correction must restore the clean product");

    // 6. The same V-ABFT threshold maths, one level down: per-row
    //    thresholds are O(K) from single-pass max/min/mean statistics.
    let stats = a.row_stats(5);
    println!(
        "row 5 stats: mean {:+.3}  max {:+.3}  min {:+.3}  extrema-var bound {:.3} (true var {:.3})",
        stats.mean,
        stats.max,
        stats.min,
        stats.extrema_var_bound(),
        stats.variance,
    );
    println!("\nquickstart OK");
    Ok(())
}
