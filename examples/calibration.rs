//! e_max calibration walkthrough (paper §3.6): run the one-time
//! calibration protocol against each simulated platform, fit the scaling
//! law, and compare with the paper's Table 7 recommendations — including
//! the offline-vs-online (fused kernel) granularity gap.
//!
//! ```text
//! cargo run --release --example calibration -- [--trials N]
//! ```

use vabft::calibrate::{CalibrationProtocol, EmaxTable, Platform};
use vabft::cli::Args;
use vabft::fp::Precision;
use vabft::report::{sci, Table};

fn main() {
    let args = Args::parse();
    let trials = args.opt_or("trials", 6usize);

    println!("== one-time e_max calibration (protocol of §3.6) ==\n");
    let mut t = Table::new(
        "Calibrated e_max laws vs paper Table 7",
        &["Platform", "Precision", "fitted law", "CV", "R2(sqrtN)", "paper"],
    );
    for (platform, p) in [
        (Platform::Cpu, Precision::F64),
        (Platform::Cpu, Precision::F32),
        (Platform::Gpu, Precision::F32),
        (Platform::Gpu, Precision::Bf16),
        (Platform::Npu, Precision::F32),
        (Platform::Npu, Precision::Bf16),
    ] {
        let proto = CalibrationProtocol {
            sizes: vec![128, 512, 2048],
            trials_per_size: trials,
            ..Default::default()
        };
        let res = proto.run(platform.model_for(p), false);
        t.row(vec![
            platform.name().to_string(),
            p.name().to_string(),
            res.fitted.label(),
            format!("{:.0}%", res.cv * 100.0),
            format!("{:.2}", res.r2_sqrt_n),
            EmaxTable::recommended(platform, p).label(),
        ]);
    }
    t.print();

    // The fused-kernel granularity headline: same BF16 GEMM, verified
    // before vs after output quantization.
    println!("== offline vs online (fused-kernel) verification, BF16 GEMM ==\n");
    let model = Platform::Gpu.model_for(Precision::Bf16);
    let proto = CalibrationProtocol {
        sizes: vec![256, 1024],
        trials_per_size: trials,
        ..Default::default()
    };
    let offline = proto.run(model, false);
    let online = proto.run(model, true);
    let mut t2 = Table::new(
        "e_max: offline (stored BF16) vs online (FP32 accumulator)",
        &["N", "offline e_max", "online e_max", "granularity gain"],
    );
    for (o, n) in offline.points.iter().zip(&online.points) {
        t2.row(vec![
            o.n.to_string(),
            sci(o.emax),
            sci(n.emax),
            format!("{:.0}x", o.emax / n.emax),
        ]);
    }
    t2.print();
    println!("Paper §3.6: ~1000x finer detection granularity for fused-kernel ABFT");
    println!("(e_max ~1e-3 offline vs ~1e-6 online for low-precision GEMM).");
}
