//! Serving demo: the L3 coordinator as a fault-tolerant GEMM service —
//! register transformer-layer weights once (checksum encodings + V-ABFT
//! statistics cached in the coordinator's LRU, the weight-stationary fast
//! path), stream activation batches through the worker pool with a
//! configurable soft-error rate, and report throughput / latency /
//! detection counters. Also demos the handle-based request path
//! (`register_weights` → `submit_prepared`), which skips the id lookup
//! entirely. Optionally routes the GEMMs through the AOT-compiled L1
//! Pallas kernel via PJRT (`--pjrt`).
//!
//! ```text
//! cargo run --release --example serving -- [--requests N] [--workers W]
//!     [--fault-rate 0.05] [--offline] [--block-k B] [--pjrt]
//!     [--threads T] [--mc M --kc K --nc N] [--mr R --nr C]
//!     [--split S] [--simd L] [--manifest FILE]   # per-worker engine config
//! ```

use std::sync::Arc;
use std::time::Instant;

use vabft::cli::Args;
use vabft::coordinator::{
    Coordinator, CoordinatorConfig, GemmRequest, InjectSpec, PreparedGemmRequest,
};
use vabft::prelude::*;

fn main() -> vabft::error::Result<()> {
    let args = Args::parse();
    let requests = args.opt_or("requests", 200usize);
    let workers = args.opt_or("workers", 2usize);
    let fault_rate = args.opt_or("fault-rate", 0.05f64);
    let online = !args.flag("offline");

    if args.flag("pjrt") {
        return serve_pjrt(requests, fault_rate);
    }

    let (k, n) = (256usize, 128usize);
    let block_k = args.opt_or("block-k", 0usize); // 0 = monolithic
    let cfg = CoordinatorConfig {
        workers,
        queue_depth: 32,
        model: AccumModel::wide(Precision::Bf16),
        policy: if online { VerifyPolicy::default() } else { VerifyPolicy::offline() },
        threshold: Arc::new(|| Box::new(VabftThreshold::default())),
        engine: Some(EngineConfig::from_args(&args)),
        weight_capacity: 64,
        block_k: if block_k == 0 { None } else { Some(block_k) },
        ..Default::default()
    };
    let coord = Coordinator::start(cfg);

    // Register a few "layers" of weights: checksum encoding + V-ABFT
    // statistics computed once per layer, cached in the coordinator's LRU
    // — every request after this is pure weight-stationary warm path.
    let mut rng = Xoshiro256pp::seed_from_u64(1);
    let mut handles = Vec::new();
    for wid in 0..4u32 {
        let b = Matrix::sample_in(k, n, &Distribution::normal_1_1(), Precision::Bf16, &mut rng);
        handles.push(coord.register_weights(wid, &b));
    }
    println!("registered 4 weight matrices ({k}x{n}), {workers} workers, online={online}");

    let t0 = Instant::now();
    let mut injected = 0usize;
    let receivers: Vec<_> = (0..requests)
        .map(|i| {
            let a = Matrix::sample_in(
                16,
                k,
                &Distribution::near_zero_normal(),
                Precision::Bf16,
                &mut rng,
            );
            let inject = if rng.next_f64() < fault_rate {
                injected += 1;
                Some(InjectSpec::output(
                    rng.uniform_u64(16) as usize,
                    rng.uniform_u64(n as u64) as usize,
                    23 + rng.uniform_u64(6) as u32, // f32 exponent bits
                ))
            } else {
                None
            };
            coord.submit(GemmRequest { a, weight: (i % 4) as u32, inject })
        })
        .collect();

    let mut verdicts = [0usize; 4];
    for r in receivers {
        let resp = r.recv().unwrap();
        match resp.result.unwrap().report.verdict {
            Verdict::Clean => verdicts[0] += 1,
            Verdict::Corrected => verdicts[1] += 1,
            Verdict::Recomputed => verdicts[2] += 1,
            Verdict::Flagged => verdicts[3] += 1,
        }
    }
    let wall = t0.elapsed();
    println!("\n{requests} requests in {wall:?} ({:.0} req/s)", requests as f64 / wall.as_secs_f64());
    println!("verdicts: clean {} corrected {} recomputed {} flagged {}", verdicts[0], verdicts[1], verdicts[2], verdicts[3]);
    println!("injected faults: {injected}; detected+repaired: {}", verdicts[1] + verdicts[2]);
    println!("metrics: {}", coord.metrics().summary());
    assert_eq!(verdicts[1] + verdicts[2], injected, "every injected fault must be caught");
    assert_eq!(verdicts[3], 0);

    // Handle-based fast path: the caller holds the PreparedWeights handle,
    // so the request skips the id → cache lookup and stays valid across
    // evictions/re-registrations (useful for pinned hot layers).
    let t1 = Instant::now();
    let warm = requests.min(64);
    let pending: Vec<_> = (0..warm)
        .map(|i| {
            let a = Matrix::sample_in(
                16,
                k,
                &Distribution::near_zero_normal(),
                Precision::Bf16,
                &mut rng,
            );
            coord.submit_prepared(PreparedGemmRequest {
                a,
                weights: Arc::clone(&handles[i % handles.len()]),
                inject: None,
            })
        })
        .collect();
    for r in pending {
        let out = r.recv().unwrap().result.unwrap();
        assert_eq!(out.report.verdict, Verdict::Clean);
    }
    let wall1 = t1.elapsed();
    println!(
        "handle path: {warm} requests in {wall1:?} ({:.0} req/s)",
        warm as f64 / wall1.as_secs_f64()
    );
    coord.shutdown();
    println!("serving demo OK");
    Ok(())
}

/// Same serving story, but the GEMM + verification runs inside the
/// AOT-compiled Pallas fused kernel, executed through PJRT.
fn serve_pjrt(requests: usize, fault_rate: f64) -> vabft::error::Result<()> {
    use vabft::runtime::{artifacts_dir, PjrtRuntime};

    let rt = PjrtRuntime::from_artifacts(&artifacts_dir())?;
    let e = rt
        .manifest()
        .get("ftgemm_f32_correct")
        .ok_or_else(|| vabft::anyhow!("ftgemm_f32_correct not in manifest"))?
        .clone();
    let (m, k, n) = (
        e.meta_parse::<usize>("m").unwrap(),
        e.meta_parse::<usize>("k").unwrap(),
        e.meta_parse::<usize>("n").unwrap(),
    );
    println!("PJRT path: fused kernel artifact {m}x{k}x{n} on {}", rt.platform());

    let mut rng = Xoshiro256pp::seed_from_u64(3);
    let b: Vec<f32> = (0..k * n).map(|_| rng.standard_normal() as f32).collect();
    let t0 = Instant::now();
    let (mut clean, mut caught, mut injected) = (0usize, 0usize, 0usize);
    for _ in 0..requests {
        let a: Vec<f32> = (0..m * k).map(|_| rng.standard_normal() as f32).collect();
        let fault = if rng.next_f64() < fault_rate {
            injected += 1;
            [
                rng.uniform_u64(m as u64) as f32,
                rng.uniform_u64(n as u64) as f32,
                50.0,
                1.0,
            ]
        } else {
            [-1.0, -1.0, 0.0, 0.0]
        };
        let outs = rt.execute_f32(
            "ftgemm_f32_correct",
            &[
                (&a, &[m as i64, k as i64]),
                (&b, &[k as i64, n as i64]),
                (&fault, &[4]),
            ],
        )?;
        let max_ratio = outs[1].iter().cloned().fold(0.0f32, f32::max);
        if max_ratio > 1.0 {
            caught += 1; // detected (and corrected in-kernel)
        } else {
            clean += 1;
        }
    }
    let wall = t0.elapsed();
    println!(
        "{requests} PJRT requests in {wall:?} ({:.0} req/s): clean {clean}, detected+corrected {caught}",
        requests as f64 / wall.as_secs_f64()
    );
    assert_eq!(caught, injected);
    println!("serving (PJRT) demo OK");
    Ok(())
}
