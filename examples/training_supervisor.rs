//! End-to-end validation driver: train the L2 transformer through the AOT
//! train-step artifact under the Rust training supervisor, with
//! fused-kernel (online) ABFT verification on every protected GEMM.
//!
//! Three runs on the same data stream:
//!   1. clean        — no faults;
//!   2. protected    — periodic compute-SEU injection, V-ABFT detection +
//!                     step rollback/re-execution (the paper's system);
//!   3. unprotected  — same faults, verification ignored (what SDCs do to
//!                     a training run).
//!
//! The protected loss curve tracks the clean one; the unprotected one
//! spikes/diverges. Results are appended to EXPERIMENTS.md by hand (see
//! §End-to-end there).
//!
//! ```text
//! cargo run --release --example training_supervisor -- [--steps 200]
//!     [--fault-every 10] [--fault-mag 1000] [--log-every 10]
//! ```

use vabft::cli::Args;
use vabft::runtime::{artifacts_dir, PjrtRuntime};
use vabft::train::{StepFault, SyntheticCorpus, Trainer, TrainerConfig};

fn main() -> vabft::error::Result<()> {
    let args = Args::parse();
    let steps = args.opt_or("steps", 200usize);
    let fault_every = args.opt_or("fault-every", 10usize);
    // Default: an overflow-class SDC (an exponent-bit flip driving the
    // accumulator to Inf) — §2.1's catastrophic case. NaN poisons the
    // gradients of an unprotected run permanently; the supervisor's
    // rollback absorbs it. Finite magnitudes (--fault-mag 1e4) are
    // self-limiting through RMSNorm and mostly show as loss spikes.
    let fault_mag = args.opt_or("fault-mag", f32::INFINITY);
    let log_every = args.opt_or("log-every", 10usize);

    let rt = PjrtRuntime::from_artifacts(&artifacts_dir())?;
    println!("loaded artifacts on {}; training {steps} steps per run\n", rt.platform());

    let run = |label: &str, inject: bool, rollback: bool| -> vabft::error::Result<Vec<f32>> {
        let cfg = TrainerConfig { rollback_on_detection: rollback, ..Default::default() };
        let mut trainer = Trainer::new(&rt, cfg)?;
        let (b, s) = trainer.batch_dims();
        let mut corpus = SyntheticCorpus::new(256, 1234);
        let mut losses = Vec::with_capacity(steps);
        let t0 = std::time::Instant::now();
        for step in 0..steps {
            let toks = corpus.batch(b, s + 1);
            let fault = if inject && step > 0 && step % fault_every == 0 {
                Some(StepFault {
                    gemm_index: step % 8, // rotate across the protected GEMMs
                    row: (step * 13) % 512,
                    col: (step * 7) % 128,
                    delta: fault_mag,
                })
            } else {
                None
            };
            let out = trainer.step(&toks, fault)?;
            losses.push(out.loss);
            if step % log_every == 0 {
                println!(
                    "[{label:<12}] step {step:>4}  loss {:.4}  ratio {:>9.3}  {}{}",
                    out.loss,
                    out.ratio,
                    if out.retried { "DETECTED→ROLLBACK+RETRY " } else { "" },
                    if fault.is_some() && !out.retried { "FAULT APPLIED SILENTLY" } else { "" },
                );
            }
        }
        println!(
            "[{label:<12}] done in {:?}; detections {}; final loss {:.4}\n",
            t0.elapsed(),
            trainer.detections,
            losses.last().unwrap()
        );
        Ok(losses)
    };

    let clean = run("clean", false, true)?;
    let protected = run("protected", true, true)?;
    let unprotected = run("unprotected", true, false)?;

    // Summary: protected tracks clean; unprotected deviates.
    let tail = steps.saturating_sub(steps / 5).max(1);
    let avg = |v: &[f32]| v[tail..].iter().sum::<f32>() / (v.len() - tail) as f32;
    let (ac, ap, au) = (avg(&clean), avg(&protected), avg(&unprotected));
    println!("== loss curve summary (mean over final 20% of steps) ==");
    println!("clean        {ac:.4}");
    println!("protected    {ap:.4}   (gap to clean {:+.4})", ap - ac);
    println!("unprotected  {au:.4}   (gap to clean {:+.4})", au - ac);
    let spike = |v: &[f32]| v.windows(2).map(|w| w[1] - w[0]).fold(0.0f32, f32::max);
    println!(
        "largest single-step loss spike: clean {:+.3}, protected {:+.3}, unprotected {:+.3}",
        spike(&clean),
        spike(&protected),
        spike(&unprotected)
    );
    assert!(
        (ap - ac).abs() < 0.15,
        "protected run must track clean (gap {})",
        ap - ac
    );
    assert!(
        au.is_nan()
            || au > ac + 0.05
            || spike(&unprotected) > spike(&clean).max(0.05) * 5.0,
        "unprotected run should be visibly worse (tail {au} vs clean {ac}, spike {})",
        spike(&unprotected)
    );
    println!("\ntraining supervisor e2e OK — record these numbers in EXPERIMENTS.md");
    Ok(())
}
