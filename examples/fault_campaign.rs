//! Fault-injection campaign (paper Table 8 workload): sweep exponent-bit
//! positions of BF16 outputs across the paper's four distributions and
//! report detection / localization rates plus the clean-data FPR.
//!
//! ```text
//! cargo run --release --example fault_campaign -- [--trials N] [--online] [--shape M,K,N]
//! ```

use vabft::cli::Args;
use vabft::inject::{Campaign, CampaignConfig};
use vabft::report::{pct, Table};
use vabft::rng::Distribution;
use vabft::threshold::{AabftThreshold, VabftThreshold, Threshold};

fn main() {
    let args = Args::parse();
    let trials = args.opt_or("trials", 256usize);
    let online = args.flag("online");
    let shape = match args.opt("shape") {
        None => (64, 512, 128),
        Some(s) => {
            let d: Vec<usize> = s.split(',').map(|x| x.parse().unwrap()).collect();
            (d[0], d[1], d[2])
        }
    };
    println!("campaign: shape {shape:?}, {trials} injections/bit, online={online}\n");

    let algorithms: Vec<(&str, Box<dyn Threshold>)> = vec![
        ("V-ABFT", Box::new(VabftThreshold::default())),
        ("A-ABFT (computed y)", Box::new(AabftThreshold::computed_y())),
    ];
    for (name, algo) in &algorithms {
        let mut t = Table::new(
            &format!("Detection rate (%) by exponent bit — {name}"),
            &["Bit", "N(1e-6,1)", "N(1,1)", "U(-1,1)", "TruncN"],
        );
        let mut fp = 0;
        let mut rows = 0;
        let mut per_dist = Vec::new();
        for (_, d) in Distribution::paper_suite() {
            let mut cfg = CampaignConfig::table8(d, trials);
            cfg.shape = shape;
            cfg.online = online;
            let res = Campaign::new(cfg).run(algo.as_ref());
            fp += res.false_positives;
            rows += res.clean_rows_checked;
            per_dist.push(res);
        }
        let bits: Vec<u32> = per_dist[0].bits.iter().map(|b| b.bit).collect();
        for (i, bit) in bits.iter().enumerate() {
            t.row(vec![
                bit.to_string(),
                pct(per_dist[0].bits[i].detection_rate()),
                pct(per_dist[1].bits[i].detection_rate()),
                pct(per_dist[2].bits[i].detection_rate()),
                pct(per_dist[3].bits[i].detection_rate()),
            ]);
        }
        t.print();
        println!("{name}: {rows} clean rows, {fp} false positives\n");
    }
}
