"""AOT pipeline tests: lowering produces parseable HLO text with the right
entry signatures, and the manifest matches the model contract."""

import os

import jax
import jax.numpy as jnp
import pytest

jax.config.update("jax_platforms", "cpu")

from compile import aot, model


def test_ftgemm_entry_lowers_to_hlo_text():
    text = aot.to_hlo_text(aot.ftgemm_entry(correct=False))
    assert text.startswith("HloModule")
    assert "ENTRY" in text
    # f32[64,128] input signature appears in the module
    assert f"f32[{aot.FTGEMM_M},{aot.FTGEMM_K}]" in text
    # interpret-mode pallas must lower to plain HLO: no custom-calls that
    # the CPU PJRT client cannot execute
    assert "custom_call_target=\"Mosaic\"" not in text


def test_manifest_contract_matches_model():
    shapes = model.param_shapes()
    meta_batch = f"{model.BATCH},{model.SEQ + 1}"
    # mirror of what aot.main() writes; the real file is covered by the
    # rust integration tests
    assert len(shapes) == 2 + 4 * model.N_LAYERS
    assert meta_batch.count(",") == 1


@pytest.mark.skipif(
    not os.path.exists(
        os.path.join(os.path.dirname(__file__), "../../artifacts/manifest.tsv")
    ),
    reason="artifacts not built",
)
def test_written_manifest_lists_all_artifacts():
    path = os.path.join(os.path.dirname(__file__), "../../artifacts/manifest.tsv")
    with open(path) as f:
        text = f.read()
    for name in ["ftgemm_f32", "ftgemm_f32_correct", "train_step", "model_fwd"]:
        assert name in text, f"{name} missing from manifest"
    # param shape metadata round-trips
    for i, s in enumerate(model.param_shapes()):
        assert f"param{i}=" + ",".join(str(d) for d in s) in text


@pytest.mark.skipif(
    not os.path.exists(
        os.path.join(os.path.dirname(__file__), "../../artifacts/train_step.hlo.txt")
    ),
    reason="artifacts not built",
)
def test_written_hlo_is_parseable_text():
    path = os.path.join(
        os.path.dirname(__file__), "../../artifacts/train_step.hlo.txt"
    )
    with open(path) as f:
        head = f.read(4096)
    assert head.startswith("HloModule")
    # int32 token input present
    assert f"s32[{model.BATCH},{model.SEQ + 1}]" in head or "s32[" in head
