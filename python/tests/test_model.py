"""L2 model tests: shapes, training dynamics, verification signal routing,
and custom-vjp gradient correctness."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

jax.config.update("jax_platforms", "cpu")

from compile import model

NOFAULT = jnp.array([-1.0, 0.0, 0.0, 0.0], jnp.float32)


@pytest.fixture(scope="module")
def params():
    return model.init_params(jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def tokens():
    return jax.random.randint(
        jax.random.PRNGKey(1), (model.BATCH, model.SEQ + 1), 0, model.VOCAB
    )


def test_param_shapes_match_manifest_contract(params):
    shapes = model.param_shapes()
    assert len(params) == len(shapes) == 2 + 4 * model.N_LAYERS
    for p, s in zip(params, shapes):
        assert p.shape == s


def test_forward_shapes_and_clean_ratio(params, tokens):
    logits, ratio = model.forward(params, tokens[:, :-1], NOFAULT)
    assert logits.shape == (model.BATCH, model.SEQ, model.VOCAB)
    assert float(ratio) < 1.0


def test_loss_near_uniform_at_init(params, tokens):
    loss, ratio = model.loss_fn(params, tokens, NOFAULT)
    assert abs(float(loss) - np.log(model.VOCAB)) < 0.5
    assert float(ratio) < 1.0


def test_training_reduces_loss_on_learnable_data(params):
    # deterministic affine-recurrence sequences (same family as the Rust
    # SyntheticCorpus) — learnable in a handful of steps
    def batch(seed):
        key = jax.random.PRNGKey(seed)
        x0 = jax.random.randint(key, (model.BATCH, 1), 0, model.VOCAB)
        seqs = [x0]
        for _ in range(model.SEQ):
            seqs.append((seqs[-1] * 5 + 17) % model.VOCAB)
        return jnp.concatenate(seqs, axis=1)

    ps = list(params)
    losses = []
    for step in range(40):
        out = model.train_step(ps, batch(step), jnp.float32(0.15), NOFAULT)
        ps = list(out[:-2])
        losses.append(float(out[-2]))
    assert losses[-1] < losses[0] - 0.4, (losses[0], losses[-1])


def test_fault_routes_to_single_gemm(params, tokens):
    # a fault on gemm 0 must raise the ratio; disabled id must not
    f_on = jnp.array([0.0, 3.0, 5.0, 1e4], jnp.float32)
    _, r_on = model.loss_fn(params, tokens, f_on)
    assert float(r_on) > 1.0
    f_off = jnp.array([float(model.N_PROTECTED + 3), 3.0, 5.0, 1e4], jnp.float32)
    _, r_off = model.loss_fn(params, tokens, f_off)
    assert float(r_off) < 1.0


@pytest.mark.parametrize("gemm_id", [0, 1, model.N_PROTECTED - 1])
def test_every_protected_gemm_is_wired(params, tokens, gemm_id):
    fault = jnp.array([float(gemm_id), 1.0, 1.0, 1e5], jnp.float32)
    _, ratio = model.loss_fn(params, tokens, fault)
    assert float(ratio) > 1.0, f"gemm {gemm_id} not reached by fault input"


def test_custom_vjp_matches_plain_matmul_grads():
    # the protected matmul's backward pass must equal d/dx, d/dw of x@w
    from compile.kernels.vabft_gemm import protected_matmul_factory

    f = protected_matmul_factory(0, bm=8, bk=8)
    x = jax.random.normal(jax.random.PRNGKey(2), (8, 8), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(3), (8, 8), jnp.float32)

    def loss_protected(x, w):
        y, _ = f(x, w, NOFAULT)
        return jnp.sum(jnp.sin(y))

    def loss_plain(x, w):
        return jnp.sum(jnp.sin(x @ w))

    gx1, gw1 = jax.grad(loss_protected, argnums=(0, 1))(x, w)
    gx2, gw2 = jax.grad(loss_plain, argnums=(0, 1))(x, w)
    np.testing.assert_allclose(gx1, gx2, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(gw1, gw2, rtol=1e-4, atol=1e-5)


def test_train_step_detected_fault_is_visible_in_outputs(params, tokens):
    fault = jnp.array([2.0, 7.0, 3.0, 1e3], jnp.float32)
    out = model.train_step(params, tokens, jnp.float32(0.03), fault)
    ratio = float(out[-1])
    assert ratio > 1.0
    # outputs are still well-formed (supervisor decides whether to apply)
    for p, s in zip(out[:-2], model.param_shapes()):
        assert p.shape == s
