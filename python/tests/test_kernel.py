"""L1 kernel correctness: Pallas fused ABFT-GEMM vs the pure-jnp oracle.

The core correctness signal for the compile path: the kernel must (a)
compute the same product, checksums, thresholds and verdicts as ref.py,
(b) never flag clean data, (c) detect/localize/correct injected faults.
Hypothesis sweeps shapes and dtypes.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

jax.config.update("jax_platforms", "cpu")

from compile.kernels.ref import ref_vabft_matmul
from compile.kernels.vabft_gemm import (
    b_row_checksums,
    b_summary_stats,
    default_emax_f32,
    vabft_matmul,
)


def rand(key, shape, dtype=jnp.float32, mean=0.0, scale=1.0):
    return (jax.random.normal(jax.random.PRNGKey(key), shape) * scale + mean).astype(
        dtype
    )


# ---------------------------------------------------------------------------
# kernel vs oracle
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("m,k,n,bm,bk", [
    (32, 64, 48, 32, 64),     # single tile
    (64, 128, 96, 32, 64),    # multi-tile both dims
    (128, 256, 64, 64, 64),   # deeper K loop
    (8, 8, 8, 8, 8),          # minimal
])
def test_kernel_matches_ref(m, k, n, bm, bk):
    a = rand(0, (m, k))
    b = rand(1, (k, n))
    out = vabft_matmul(a, b, bm=bm, bk=bk)
    ref = ref_vabft_matmul(a, b)
    np.testing.assert_allclose(out["acc"], ref["acc"], rtol=1e-5, atol=1e-4)
    np.testing.assert_allclose(out["c"], ref["c"], rtol=1e-5, atol=1e-4)
    # D1 is a difference of near-equal sums: compare against the threshold
    # scale rather than elementwise (reduction orders differ slightly).
    thr = np.asarray(ref["threshold"])
    assert np.all(np.abs(np.asarray(out["d1"])) < thr)
    assert float(jnp.max(out["ratio"])) < 1.0


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_kernel_dtypes(dtype):
    a = rand(2, (32, 64), dtype)
    b = rand(3, (64, 32), dtype)
    out = vabft_matmul(a, b, bm=32, bk=64)
    assert out["c"].dtype == dtype
    assert out["acc"].dtype == jnp.float32
    # product sanity vs fp32 matmul
    ref = jnp.matmul(
        a.astype(jnp.float32), b.astype(jnp.float32)
    )
    np.testing.assert_allclose(out["acc"], ref, rtol=2e-2, atol=2e-1)
    assert float(jnp.max(out["ratio"])) < 1.0


@settings(max_examples=25, deadline=None)
@given(
    mt=st.integers(1, 4),
    kt=st.integers(1, 4),
    n=st.sampled_from([8, 24, 56, 96]),
    seed=st.integers(0, 2**31 - 1),
    mean=st.sampled_from([0.0, 1.0, -0.5]),
    bf16=st.booleans(),
)
def test_kernel_vs_ref_hypothesis(mt, kt, n, seed, mean, bf16):
    bm, bk = 16, 32
    m, k = mt * bm, kt * bk
    dtype = jnp.bfloat16 if bf16 else jnp.float32
    key = jax.random.PRNGKey(seed)
    k1, k2 = jax.random.split(key)
    a = (jax.random.normal(k1, (m, k)) + mean).astype(dtype)
    b = (jax.random.normal(k2, (k, n)) + mean).astype(dtype)
    out = vabft_matmul(a, b, bm=bm, bk=bk)
    ref = ref_vabft_matmul(a, b)
    np.testing.assert_allclose(out["acc"], ref["acc"], rtol=1e-4, atol=1e-2)
    # clean data must never flag — the zero-FPR invariant
    assert float(jnp.max(out["ratio"])) < 1.0, "false positive on clean data"


# ---------------------------------------------------------------------------
# detection / localization / correction
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("frow,fcol,fdelta", [
    (0, 0, 10.0),
    (31, 47, -25.0),
    (17, 3, 3.0),
])
def test_fault_detected_and_localized(frow, fcol, fdelta):
    a = rand(4, (32, 64), mean=0.5)
    b = rand(5, (64, 48), mean=0.5)
    fault = jnp.array([frow, fcol, fdelta, 1.0], jnp.float32)
    out = vabft_matmul(a, b, fault, bm=32, bk=64)
    assert float(out["ratio"][frow]) > 1.0
    assert int(out["loc"][frow]) == fcol
    assert abs(float(out["d1"][frow]) - fdelta) < 0.05 * abs(fdelta) + 1e-2
    # unaffected rows stay clean
    mask = np.arange(32) != frow
    assert float(np.max(np.asarray(out["ratio"])[mask])) < 1.0


def test_in_kernel_correction_restores_clean_product():
    a = rand(6, (64, 128))
    b = rand(7, (128, 64))
    clean = vabft_matmul(a, b, bm=32, bk=64)
    fault = jnp.array([9.0, 13.0, 50.0, 1.0], jnp.float32)
    fixed = vabft_matmul(a, b, fault, bm=32, bk=64, correct=True)
    diff = float(jnp.max(jnp.abs(fixed["acc"] - clean["acc"])))
    # residual = D1's rounding noise, far below the fault magnitude
    assert diff < 1e-3, diff
    assert float(fixed["ratio"][9]) > 1.0  # it was seen


def test_kernel_fault_matches_ref_fault():
    a = rand(8, (32, 32))
    b = rand(9, (32, 32))
    fault = jnp.array([5.0, 6.0, 7.0, 1.0], jnp.float32)
    out = vabft_matmul(a, b, fault, bm=16, bk=16)
    ref = ref_vabft_matmul(a, b, fault)
    np.testing.assert_allclose(out["acc"], ref["acc"], rtol=1e-5, atol=1e-4)
    assert int(out["loc"][5]) == int(ref["loc"][5]) == 6


@settings(max_examples=20, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    frow=st.integers(0, 31),
    fcol=st.integers(0, 31),
    logmag=st.floats(0.5, 4.0),
    sign=st.booleans(),
)
def test_detect_correct_roundtrip_hypothesis(seed, frow, fcol, logmag, sign):
    key = jax.random.PRNGKey(seed)
    k1, k2 = jax.random.split(key)
    a = jax.random.normal(k1, (32, 64), jnp.float32)
    b = jax.random.normal(k2, (64, 32), jnp.float32)
    delta = (10.0 ** logmag) * (1.0 if sign else -1.0)
    fault = jnp.array([frow, fcol, delta, 1.0], jnp.float32)
    out = vabft_matmul(a, b, fault, bm=32, bk=64, correct=True)
    clean = vabft_matmul(a, b, bm=32, bk=64)
    assert float(out["ratio"][frow]) > 1.0
    assert int(out["loc"][frow]) == fcol
    assert float(jnp.max(jnp.abs(out["acc"] - clean["acc"]))) < 1e-2


# ---------------------------------------------------------------------------
# threshold building blocks
# ---------------------------------------------------------------------------


def test_b_row_checksums_formulas():
    b = jnp.array([[1.0, 2.0, 3.0], [4.0, 5.0, 6.0]], jnp.float32)
    cs = b_row_checksums(b)
    np.testing.assert_allclose(cs[:, 0], [6.0, 15.0])
    np.testing.assert_allclose(cs[:, 1], [14.0, 32.0])  # 1·1+2·2+3·3 …


def test_b_summary_stats_extrema_bound():
    b = jnp.array([[1.0, -1.0, 1.0, -1.0]], jnp.float32)
    s = b_summary_stats(b)
    # mu=0, sigma² bound = (1-0)(0+1) = 1
    np.testing.assert_allclose(s, [0.0, 0.0, 1.0], atol=1e-7)


def test_default_emax_grows_with_depth():
    assert default_emax_f32(4096) > default_emax_f32(64)
    assert default_emax_f32(1024) < 1e-4  # stays FP32-scale


def test_zero_matrices_do_not_flag():
    a = jnp.zeros((16, 32), jnp.float32)
    b = jnp.zeros((32, 16), jnp.float32)
    out = vabft_matmul(a, b, bm=16, bk=32)
    assert float(jnp.max(out["ratio"])) < 1.0
    assert float(jnp.max(jnp.abs(out["c"]))) == 0.0
