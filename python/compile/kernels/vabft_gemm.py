"""L1: fused ABFT-GEMM Pallas kernel with in-kernel V-ABFT verification.

This is the paper's "online / fused-kernel ABFT" (§3.6) re-thought for the
TPU programming model (DESIGN.md §Hardware-Adaptation):

* the GEMM is tiled over a (M/bm, K/bk) grid; the FP32 accumulator tile
  lives across the K grid dimension (the CUDA version kept it in
  registers/shared memory per threadblock; here BlockSpec + the revisiting
  output ref express the same HBM<->VMEM schedule);
* operand tiles feed the MXU via ``preferred_element_type=float32``
  (tensor-core WMMA -> MXU systolic array);
* on the last K step -- while the result is still in the FP32 accumulator,
  i.e. *before* output quantization -- the kernel computes the row-checksum
  difference D1, the position-weighted difference D2, the V-ABFT threshold
  (Algorithm 1) from single-pass A-row statistics, and optionally corrects
  a localized single-event upset in place (Eq. 10).

Verifying pre-quantization is what gives low-precision GEMM FP32-level
thresholds (e_max ~ 1e-6) -- the ~1000x detection-granularity headline.

The kernel MUST run with ``interpret=True``: real-TPU lowering emits a
Mosaic custom-call the CPU PJRT plugin cannot execute. Interpret mode
lowers to plain HLO, which both pytest and the Rust runtime consume.

A fault-injection input emulates a compute SEU: ``fault = [row, col,
delta, enable]`` adds ``delta`` to accumulator element (row, col) after
accumulation but before verification -- exactly where a real upset would
corrupt the output path.
"""

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Confidence multiplier c_sigma (paper: 2.5 ~ 99% Gaussian coverage).
C_SIGMA = 2.5

# Tiny floor so clean-but-zero thresholds never divide by zero.
_T_FLOOR = 1e-30

# Finite sentinel for "row is poisoned by Inf/NaN" — large enough to flag,
# finite so XLA max-reductions cannot drop it.
_RATIO_SENTINEL = 1e30


def default_emax_f32(depth: int, margin: float = 4.0) -> float:
    """e_max law for FP32 accumulation with per-step rounding.

    Mirrors the Rust ``EmaxTable::for_model`` sequential law
    (1.2*sqrt(n) + 2)*u_f32 with a safety margin for XLA's (unspecified,
    possibly vectorized-sequential) reduction order.
    """
    u = 2.0 ** -24
    return margin * ((1.2 * depth ** 0.5 + 2.0) * u)


def b_row_checksums(b):
    """[B*r1 | B*r2] per row of B, computed in FP32 (fused/online ABFT
    keeps encodings in the datapath -- they are never quantized to the
    operand dtype)."""
    bf = b.astype(jnp.float32)
    n = b.shape[1]
    w = jnp.arange(1, n + 1, dtype=jnp.float32)
    r1 = jnp.sum(bf, axis=1)
    r2 = jnp.sum(bf * w[None, :], axis=1)
    return jnp.stack([r1, r2], axis=1)  # [K, 2]


def b_summary_stats(b):
    """V-ABFT B-side aggregates (Algorithm 1 lines 3-6):
    [sum_k |mu_Bk|, sum_k mu_Bk^2, sum_k sigma_Bk^2] with the
    extrema-variance bound sigma^2 <= (max-mu)(mu-min)."""
    bf = b.astype(jnp.float32)
    mu = jnp.mean(bf, axis=1)
    mx = jnp.max(bf, axis=1)
    mn = jnp.min(bf, axis=1)
    sig2 = jnp.maximum((mx - mu) * (mu - mn), 0.0)
    return jnp.stack(
        [jnp.sum(jnp.abs(mu)), jnp.sum(mu * mu), jnp.sum(sig2)]
    )  # [3]


def _kernel(
    a_ref,
    b_ref,
    bsum_ref,
    bstats_ref,
    fault_ref,
    c_ref,
    acc_ref,
    ck_ref,
    astats_ref,
    ratio_ref,
    d1_ref,
    loc_ref,
    *,
    k_steps: int,
    k_total: int,
    n: int,
    bm: int,
    emax: float,
    c_sigma: float,
    correct: bool,
    loc_tol: float,
):
    i = pl.program_id(0)
    kk = pl.program_id(1)

    @pl.when(kk == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        ck_ref[...] = jnp.zeros_like(ck_ref)
        astats_ref[...] = jnp.concatenate(
            [
                jnp.zeros((bm, 1), jnp.float32),
                jnp.full((bm, 1), -jnp.inf, jnp.float32),
                jnp.full((bm, 1), jnp.inf, jnp.float32),
                jnp.zeros((bm, 1), jnp.float32),
            ],
            axis=1,
        )

    a = a_ref[...]
    af = a.astype(jnp.float32)
    # MXU matmul with FP32 accumulation (tensor-core / Cube analogue).
    acc_ref[...] += jax.lax.dot_general(
        a,
        b_ref[...],
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    # Checksum path: A-tile x [Br1 | Br2], same datapath, FP32 throughout.
    ck_ref[...] += jax.lax.dot_general(
        af,
        bsum_ref[...],
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    # Single-pass A-row statistics (Algorithm 1 lines 1-2), fused into the
    # K loop: running sum / max / min.
    st = astats_ref[...]
    astats_ref[...] = jnp.stack(
        [
            st[:, 0] + jnp.sum(af, axis=1),
            jnp.maximum(st[:, 1], jnp.max(af, axis=1)),
            jnp.minimum(st[:, 2], jnp.min(af, axis=1)),
            st[:, 3],
        ],
        axis=1,
    )

    @pl.when(kk == k_steps - 1)
    def _epilogue():
        # --- fault injection: a compute SEU lands in the accumulator ----
        frow, fcol, fdelta, fen = (
            fault_ref[0],
            fault_ref[1],
            fault_ref[2],
            fault_ref[3],
        )
        local = frow - (i * bm).astype(jnp.float32)
        rows = jax.lax.broadcasted_iota(jnp.float32, (bm, n), 0)
        cols = jax.lax.broadcasted_iota(jnp.float32, (bm, n), 1)
        hit = (rows == local) & (cols == fcol)
        acc_ref[...] += jnp.where(hit, fdelta * fen, 0.0)

        acc = acc_ref[...]
        # --- verification difference, pre-quantization (online ABFT) ----
        wvec = jax.lax.broadcasted_iota(jnp.float32, (1, n), 1) + 1.0
        row_sums = jnp.sum(acc, axis=1)
        w_sums = jnp.sum(acc * wvec, axis=1)
        ck = ck_ref[...]
        d1 = row_sums - ck[:, 0]
        d2 = w_sums - ck[:, 1]

        # --- V-ABFT threshold (Algorithm 1) -----------------------------
        st2 = astats_ref[...]
        mu_a = st2[:, 0] / float(k_total)
        sig2_a = jnp.maximum((st2[:, 1] - mu_a) * (mu_a - st2[:, 2]), 0.0)
        sig_a = jnp.sqrt(sig2_a)
        s_absmu = bstats_ref[0]
        s_mu2 = bstats_ref[1]
        s_sig2 = bstats_ref[2]
        nf = float(n)
        t_det = nf * jnp.abs(mu_a) * s_absmu
        t_var23 = c_sigma * jnp.sqrt(
            nf * mu_a * mu_a * s_sig2 + nf * nf * sig2_a * s_mu2
        )
        t_var4 = c_sigma * jnp.sqrt(nf) * sig_a * jnp.sqrt(s_sig2)
        thr = emax * (t_det + t_var23 + t_var4) + _T_FLOOR

        # Detection ratio, sanitized to a finite sentinel: XLA's max
        # reduction may drop NaN (and a NaN threshold would launder an
        # Inf fault into NaN), so Inf/NaN anywhere in the row — the
        # catastrophic overflow class of §2.1 — must surface as a large
        # *finite* ratio that survives every downstream max().
        raw = jnp.abs(d1) / thr
        row_finite = jnp.all(jnp.isfinite(acc), axis=1)
        ratio = jnp.where(
            row_finite & jnp.isfinite(raw), raw, _RATIO_SENTINEL
        )
        flagged = ratio > 1.0

        # --- localization + online correction (Eq. 9-10) ----------------
        wj = d2 / jnp.where(d1 == 0.0, 1.0, d1)  # ~ j+1
        wr = jnp.round(wj)
        consistent = (
            flagged
            & (jnp.abs(wj - wr) <= loc_tol)
            & (wr >= 1.0)
            & (wr <= nf)
            & jnp.isfinite(wj)
        )
        loc = jnp.where(consistent, wr - 1.0, -1.0)
        if correct:
            colmask = cols == loc[:, None]
            fix = jnp.where(
                colmask & consistent[:, None], d1[:, None], 0.0
            )
            acc = acc - fix
            acc_ref[...] = acc

        ratio_ref[...] = ratio[:, None]
        d1_ref[...] = d1[:, None]
        loc_ref[...] = loc[:, None]
        # --- output quantization happens only now ------------------------
        c_ref[...] = acc.astype(c_ref.dtype)


def vabft_matmul(
    a,
    b,
    fault=None,
    *,
    out_dtype=None,
    bm=None,
    bk=None,
    emax=None,
    c_sigma=C_SIGMA,
    correct=False,
    loc_tol=0.45,
    interpret=True,
):
    """Fused ABFT-protected matmul: ``C = A @ B`` with in-kernel V-ABFT.

    Returns a dict with:
      c      -- [M, N] product in ``out_dtype`` (default: A's dtype)
      acc    -- [M, N] FP32 accumulator (pre-quantization values)
      ratio  -- [M] verification ratio |D1| / T  (>1 -> fault detected)
      d1     -- [M] raw verification difference
      loc    -- [M] localized fault column (or -1)

    ``fault`` is ``[row, col, delta, enable]`` (f32): adds ``delta`` to
    accumulator element (row, col) pre-verification when ``enable > 0``.
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, f"shape mismatch {a.shape} @ {b.shape}"
    out_dtype = out_dtype or a.dtype
    bm = bm or min(m, 128)
    bk = bk or min(k, 512)
    assert m % bm == 0, f"M={m} not divisible by bm={bm}"
    assert k % bk == 0, f"K={k} not divisible by bk={bk}"
    k_steps = k // bk
    if emax is None:
        emax = default_emax_f32(max(n, k))
    if fault is None:
        fault = jnp.array([-1.0, -1.0, 0.0, 0.0], jnp.float32)

    bsum = b_row_checksums(b)  # [K, 2] f32
    bstats = b_summary_stats(b)  # [3]   f32

    kernel = partial(
        _kernel,
        k_steps=k_steps,
        k_total=k,
        n=n,
        bm=bm,
        emax=float(emax),
        c_sigma=float(c_sigma),
        correct=correct,
        loc_tol=float(loc_tol),
    )
    grid = (m // bm, k_steps)
    outs = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, kk: (i, kk)),
            pl.BlockSpec((bk, n), lambda i, kk: (kk, 0)),
            pl.BlockSpec((bk, 2), lambda i, kk: (kk, 0)),
            pl.BlockSpec((3,), lambda i, kk: (0,)),
            pl.BlockSpec((4,), lambda i, kk: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((bm, n), lambda i, kk: (i, 0)),
            pl.BlockSpec((bm, n), lambda i, kk: (i, 0)),
            pl.BlockSpec((bm, 2), lambda i, kk: (i, 0)),
            pl.BlockSpec((bm, 4), lambda i, kk: (i, 0)),
            pl.BlockSpec((bm, 1), lambda i, kk: (i, 0)),
            pl.BlockSpec((bm, 1), lambda i, kk: (i, 0)),
            pl.BlockSpec((bm, 1), lambda i, kk: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, n), out_dtype),
            jax.ShapeDtypeStruct((m, n), jnp.float32),
            jax.ShapeDtypeStruct((m, 2), jnp.float32),
            jax.ShapeDtypeStruct((m, 4), jnp.float32),
            jax.ShapeDtypeStruct((m, 1), jnp.float32),
            jax.ShapeDtypeStruct((m, 1), jnp.float32),
            jax.ShapeDtypeStruct((m, 1), jnp.float32),
        ],
        interpret=interpret,
    )(a, b, bsum, bstats, fault)
    c, acc, _ck, _astats, ratio, d1, loc = outs
    return {
        "c": c,
        "acc": acc,
        "ratio": ratio[:, 0],
        "d1": d1[:, 0],
        "loc": loc[:, 0],
    }


def protected_matmul_factory(gemm_id: int, **kw):
    """A differentiable protected matmul bound to a static GEMM id.

    Returns ``f(x, w, fault) -> (y_f32, max_ratio)`` where ``fault`` is the
    model-wide ``[gemm_id, row, col, delta]`` vector; the fault applies
    only when its id matches. The backward pass uses plain matmuls (ABFT
    protects the forward path; see DESIGN.md).
    """

    @jax.custom_vjp
    def f(x, w, fault):
        y, r = _fwd_compute(x, w, fault)
        return y, r

    def _fwd_compute(x, w, fault):
        enable = jnp.where(fault[0] == float(gemm_id), 1.0, 0.0)
        local_fault = jnp.array(
            [0.0, 0.0, 0.0, 0.0], jnp.float32
        ).at[0].set(fault[1]).at[1].set(fault[2]).at[2].set(fault[3]).at[3].set(enable)
        out = vabft_matmul(x, w, local_fault, **kw)
        return out["acc"], jnp.max(out["ratio"])

    def f_fwd(x, w, fault):
        y, r = _fwd_compute(x, w, fault)
        return (y, r), (x, w)

    def f_bwd(res, cot):
        x, w = res
        gy, _gr = cot
        gx = gy @ w.T.astype(gy.dtype)
        gw = x.T.astype(gy.dtype) @ gy
        return gx, gw, jnp.zeros(4, jnp.float32)

    f.defvjp(f_fwd, f_bwd)
    return f
