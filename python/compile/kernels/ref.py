"""Pure-jnp oracle for the fused ABFT-GEMM kernel.

Implements the same computation as ``vabft_gemm.vabft_matmul`` with plain
jnp ops — the correctness reference pytest checks the Pallas kernel
against. Every formula mirrors the paper:

* checksum encoding (Eq. 1–4) in FP32,
* verification difference D1/D2 (Eq. 7–8),
* V-ABFT threshold (Algorithm 1) with the extrema-variance bound
  (Theorem 1),
* localization j = D2/D1 − 1 (Eq. 9) and correction C −= D1 (Eq. 10).
"""

import jax.numpy as jnp

from .vabft_gemm import C_SIGMA, b_row_checksums, b_summary_stats, default_emax_f32

_T_FLOOR = 1e-30


def ref_vabft_matmul(
    a,
    b,
    fault=None,
    *,
    out_dtype=None,
    emax=None,
    c_sigma=C_SIGMA,
    correct=False,
    loc_tol=0.45,
):
    """Reference implementation; same outputs as ``vabft_matmul``."""
    m, k = a.shape
    _, n = b.shape
    out_dtype = out_dtype or a.dtype
    if emax is None:
        emax = default_emax_f32(max(n, k))
    if fault is None:
        fault = jnp.array([-1.0, -1.0, 0.0, 0.0], jnp.float32)

    bsum = b_row_checksums(b)
    bstats = b_summary_stats(b)

    acc = jnp.matmul(a, b, preferred_element_type=jnp.float32)
    ck = jnp.matmul(
        a.astype(jnp.float32), bsum, preferred_element_type=jnp.float32
    )

    # fault injection on the accumulator
    rows = jnp.arange(m, dtype=jnp.float32)[:, None]
    cols = jnp.arange(n, dtype=jnp.float32)[None, :]
    hit = (rows == fault[0]) & (cols == fault[1])
    acc = acc + jnp.where(hit, fault[2] * fault[3], 0.0)

    wvec = jnp.arange(1, n + 1, dtype=jnp.float32)
    row_sums = jnp.sum(acc, axis=1)
    w_sums = jnp.sum(acc * wvec[None, :], axis=1)
    d1 = row_sums - ck[:, 0]
    d2 = w_sums - ck[:, 1]

    af = a.astype(jnp.float32)
    mu_a = jnp.mean(af, axis=1)
    sig2_a = jnp.maximum(
        (jnp.max(af, axis=1) - mu_a) * (mu_a - jnp.min(af, axis=1)), 0.0
    )
    sig_a = jnp.sqrt(sig2_a)
    nf = float(n)
    t_det = nf * jnp.abs(mu_a) * bstats[0]
    t_var23 = c_sigma * jnp.sqrt(
        nf * mu_a * mu_a * bstats[2] + nf * nf * sig2_a * bstats[1]
    )
    t_var4 = c_sigma * jnp.sqrt(nf) * sig_a * jnp.sqrt(bstats[2])
    thr = emax * (t_det + t_var23 + t_var4) + _T_FLOOR

    # Same Inf/NaN sanitization as the kernel (see vabft_gemm._kernel).
    raw = jnp.abs(d1) / thr
    row_finite = jnp.all(jnp.isfinite(acc), axis=1)
    ratio = jnp.where(row_finite & jnp.isfinite(raw), raw, 1e30)
    flagged = ratio > 1.0
    wj = d2 / jnp.where(d1 == 0.0, 1.0, d1)
    wr = jnp.round(wj)
    consistent = (
        flagged
        & (jnp.abs(wj - wr) <= loc_tol)
        & (wr >= 1.0)
        & (wr <= nf)
        & jnp.isfinite(wj)
    )
    loc = jnp.where(consistent, wr - 1.0, -1.0)
    if correct:
        colmask = cols == loc[:, None]
        acc = acc - jnp.where(colmask & consistent[:, None], d1[:, None], 0.0)

    return {
        "c": acc.astype(out_dtype),
        "acc": acc,
        "ratio": ratio,
        "d1": d1,
        "loc": loc,
        "threshold": thr,
    }
