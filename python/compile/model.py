"""L2: GPT-style transformer with ABFT-protected matmuls.

Every projection in the model (QKV, attention output, both FFN layers)
routes through the L1 fused ABFT-GEMM Pallas kernel; the forward pass
aggregates the maximum verification ratio max|E|/T across all protected
GEMMs, which the Rust training supervisor monitors (ratio > 1 ⇒ a fault
tripped a V-ABFT threshold ⇒ discard the step and re-execute).

A model-wide ``fault = [gemm_id, row, col, delta]`` input routes an
injected accumulator corruption to exactly one protected GEMM — the
experiment hook for the end-to-end driver.

Architecture (sized so a few hundred CPU training steps are minutes, not
hours; scales by constants only):
    vocab 256 (byte-level), seq 64, d_model 128, 2 layers, 4 heads,
    FFN 4×d. Tied unembedding. Parameter-free RMSNorm.

Parameter order (the Rust supervisor relies on it; aot.py writes it into
the manifest):
    0: embed   [V, D]
    1: pos     [S, D]
    per layer l (2 + 4l …): wqkv [D, 3D], wo [D, D], w1 [D, F], w2 [F, D]
"""

from functools import partial

import jax
import jax.numpy as jnp

from compile.kernels.vabft_gemm import protected_matmul_factory

# ---- configuration ---------------------------------------------------------

VOCAB = 256
SEQ = 64
D_MODEL = 128
N_LAYERS = 2
N_HEADS = 4
D_FF = 4 * D_MODEL
BATCH = 8

# Protected GEMM ids, in call order: layer l contributes ids
# 4l+0 (qkv), 4l+1 (wo), 4l+2 (w1), 4l+3 (w2).
N_PROTECTED = 4 * N_LAYERS


def param_shapes():
    shapes = [(VOCAB, D_MODEL), (SEQ, D_MODEL)]
    for _ in range(N_LAYERS):
        shapes += [
            (D_MODEL, 3 * D_MODEL),
            (D_MODEL, D_MODEL),
            (D_MODEL, D_FF),
            (D_FF, D_MODEL),
        ]
    return shapes


def init_params(key):
    shapes = param_shapes()
    keys = jax.random.split(key, len(shapes))
    out = []
    for k, s in zip(keys, shapes):
        std = 0.02 if len(s) < 2 or s == (VOCAB, D_MODEL) or s == (SEQ, D_MODEL) else s[0] ** -0.5
        out.append(jax.random.normal(k, s, jnp.float32) * std)
    return out


# Pre-built protected matmul closures, one per GEMM id. bm sized to the
# flattened token dimension (BATCH*SEQ = 512 → bm 128 tiles).
_PROTECTED = [
    protected_matmul_factory(gid, bm=128, bk=128) for gid in range(N_PROTECTED)
]


def _rmsnorm(x):
    return x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + 1e-6)


def _attention(x, wqkv, wo, fault, gid0):
    """Causal multi-head attention; QKV and output projections protected."""
    bs, d = x.shape  # [B*S, D]
    qkv, r1 = _PROTECTED[gid0](x, wqkv, fault)  # [B*S, 3D]
    q, k, v = jnp.split(qkv, 3, axis=1)

    def heads(t):
        return t.reshape(-1, SEQ, N_HEADS, d // N_HEADS).transpose(0, 2, 1, 3)

    q, k, v = heads(q), heads(k), heads(v)  # [B, H, S, Dh]
    scale = (d // N_HEADS) ** -0.5
    att = jnp.einsum("bhsd,bhtd->bhst", q, k) * scale
    mask = jnp.tril(jnp.ones((SEQ, SEQ), bool))
    att = jnp.where(mask[None, None], att, -1e30)
    att = jax.nn.softmax(att, axis=-1)
    y = jnp.einsum("bhst,bhtd->bhsd", att, v)
    y = y.transpose(0, 2, 1, 3).reshape(bs, d)
    y, r2 = _PROTECTED[gid0 + 1](y, wo, fault)
    return y, jnp.maximum(r1, r2)


def _ffn(x, w1, w2, fault, gid0):
    h, r1 = _PROTECTED[gid0](x, w1, fault)
    h = jax.nn.gelu(h)
    y, r2 = _PROTECTED[gid0 + 1](h, w2, fault)
    return y, jnp.maximum(r1, r2)


def forward(params, tokens, fault):
    """Logits + max verification ratio.

    tokens: i32[B, S]; fault: f32[4] = [gemm_id, row, col, delta]
    (gemm_id < 0 disables injection).
    """
    embed, pos = params[0], params[1]
    x = embed[tokens] + pos[None, :, :]  # [B, S, D]
    x = x.reshape(-1, D_MODEL)  # [B*S, D]
    ratio = jnp.float32(0.0)
    for l in range(N_LAYERS):
        wqkv, wo, w1, w2 = params[2 + 4 * l : 6 + 4 * l]
        h, r = _attention(_rmsnorm(x), wqkv, wo, fault, 4 * l)
        x = x + h
        ratio = jnp.maximum(ratio, r)
        h, r = _ffn(_rmsnorm(x), w1, w2, fault, 4 * l + 2)
        x = x + h
        ratio = jnp.maximum(ratio, r)
    x = _rmsnorm(x)
    logits = x @ embed.T  # tied unembedding (unprotected epilogue)
    return logits.reshape(-1, SEQ, VOCAB), ratio


def loss_fn(params, tokens_with_targets, fault):
    """Next-token cross entropy. tokens_with_targets: i32[B, S+1]."""
    inp = tokens_with_targets[:, :-1]
    tgt = tokens_with_targets[:, 1:]
    logits, ratio = forward(params, inp, fault)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, tgt[..., None], axis=-1)[..., 0]
    return jnp.mean(nll), ratio


@partial(jax.jit, static_argnums=())
def train_step(params, tokens, lr, fault):
    """One SGD step. Returns (new_params…, loss, ratio) as a flat tuple.

    When the returned ratio exceeds 1 the supervisor must discard
    new_params (they were computed from a corrupted forward pass).
    """
    (loss, ratio), grads = jax.value_and_grad(loss_fn, has_aux=True)(
        params, tokens, fault
    )
    new_params = [p - lr * g for p, g in zip(params, grads)]
    return tuple(new_params) + (loss, ratio)


def fwd_eval(params, tokens, fault):
    """Inference entry point: logits + ratio (serving artifact)."""
    logits, ratio = forward(params, tokens, fault)
    return logits, ratio
