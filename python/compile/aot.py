"""AOT lowering: JAX (L2) + Pallas (L1) → HLO text artifacts for the Rust
runtime (L3).

Run once via ``make artifacts``. Python never executes on the request
path; the Rust binary loads the HLO text with
``HloModuleProto::from_text_file`` and runs it on the PJRT CPU client.

HLO *text* is the interchange format (NOT ``lowered.compile()`` or proto
``.serialize()``): jax ≥ 0.5 emits HloModuleProtos with 64-bit instruction
ids that xla_extension 0.5.1 rejects; the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Artifacts:
  ftgemm_f32      — standalone fused ABFT-GEMM (serving building block)
  ftgemm_f32_correct — same with in-kernel localization + correction
  train_step      — transformer SGD step with fused verification
  model_fwd       — transformer inference with fused verification
plus manifest.tsv (machine-readable, parsed by rust/src/runtime/manifest.rs)
and manifest.json (human-readable).
"""

import argparse
import json
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model
from compile.kernels.vabft_gemm import vabft_matmul

# Standalone fused-GEMM artifact shape (serving example): activations
# [M, K] × weights [K, N].
FTGEMM_M, FTGEMM_K, FTGEMM_N = 64, 128, 64


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def ftgemm_entry(correct: bool):
    def fn(a, b, fault):
        out = vabft_matmul(
            a, b, fault, bm=FTGEMM_M, bk=FTGEMM_K, correct=correct
        )
        return out["c"], out["ratio"], out["d1"], out["loc"]

    spec_a = jax.ShapeDtypeStruct((FTGEMM_M, FTGEMM_K), jnp.float32)
    spec_b = jax.ShapeDtypeStruct((FTGEMM_K, FTGEMM_N), jnp.float32)
    spec_f = jax.ShapeDtypeStruct((4,), jnp.float32)
    return jax.jit(fn).lower(spec_a, spec_b, spec_f)


def train_step_entry():
    param_specs = [
        jax.ShapeDtypeStruct(s, jnp.float32) for s in model.param_shapes()
    ]
    tok_spec = jax.ShapeDtypeStruct((model.BATCH, model.SEQ + 1), jnp.int32)
    lr_spec = jax.ShapeDtypeStruct((), jnp.float32)
    fault_spec = jax.ShapeDtypeStruct((4,), jnp.float32)

    def fn(*args):
        params = list(args[: len(param_specs)])
        tokens, lr, fault = args[len(param_specs) :]
        return model.train_step(params, tokens, lr, fault)

    return jax.jit(fn).lower(*param_specs, tok_spec, lr_spec, fault_spec)


def model_fwd_entry():
    param_specs = [
        jax.ShapeDtypeStruct(s, jnp.float32) for s in model.param_shapes()
    ]
    tok_spec = jax.ShapeDtypeStruct((model.BATCH, model.SEQ), jnp.int32)
    fault_spec = jax.ShapeDtypeStruct((4,), jnp.float32)

    def fn(*args):
        params = list(args[: len(param_specs)])
        tokens, fault = args[len(param_specs) :]
        return model.fwd_eval(params, tokens, fault)

    return jax.jit(fn).lower(*param_specs, tok_spec, fault_spec)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--only",
        default=None,
        help="comma-separated artifact names to (re)build",
    )
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)
    only = set(args.only.split(",")) if args.only else None

    param_meta = {
        "n_params": str(len(model.param_shapes())),
        "batch": f"{model.BATCH},{model.SEQ + 1}",
        "lr_input": "1",
        "fault_input": "1",
        "n_protected_gemms": str(model.N_PROTECTED),
        "d_model": str(model.D_MODEL),
        "vocab": str(model.VOCAB),
    }
    for i, s in enumerate(model.param_shapes()):
        param_meta[f"param{i}"] = ",".join(str(d) for d in s)

    artifacts = [
        (
            "ftgemm_f32",
            lambda: ftgemm_entry(correct=False),
            {
                "m": str(FTGEMM_M),
                "k": str(FTGEMM_K),
                "n": str(FTGEMM_N),
                "dtype": "f32",
                "outputs": "c,ratio,d1,loc",
            },
        ),
        (
            "ftgemm_f32_correct",
            lambda: ftgemm_entry(correct=True),
            {
                "m": str(FTGEMM_M),
                "k": str(FTGEMM_K),
                "n": str(FTGEMM_N),
                "dtype": "f32",
                "outputs": "c,ratio,d1,loc",
                "correct": "1",
            },
        ),
        ("train_step", train_step_entry, dict(param_meta)),
        (
            "model_fwd",
            model_fwd_entry,
            {**param_meta, "batch": f"{model.BATCH},{model.SEQ}"},
        ),
    ]

    manifest_lines = []
    manifest_json = []
    for name, build, meta in artifacts:
        fname = f"{name}.hlo.txt"
        path = os.path.join(args.out_dir, fname)
        if only is not None and name not in only:
            if os.path.exists(path):
                manifest_lines.append(_tsv_line(name, fname, meta))
                manifest_json.append({"name": name, "file": fname, **meta})
                continue
        print(f"lowering {name}…", flush=True)
        text = to_hlo_text(build())
        with open(path, "w") as f:
            f.write(text)
        print(f"  wrote {len(text)} chars to {path}", flush=True)
        manifest_lines.append(_tsv_line(name, fname, meta))
        manifest_json.append({"name": name, "file": fname, **meta})

    with open(os.path.join(args.out_dir, "manifest.tsv"), "w") as f:
        f.write("# name\tfile\tkey=value…  (parsed by rust/src/runtime/manifest.rs)\n")
        f.write("\n".join(manifest_lines) + "\n")
    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest_json, f, indent=2)
    print("manifest written.")


def _tsv_line(name, fname, meta):
    kvs = "\t".join(f"{k}={v}" for k, v in sorted(meta.items()))
    return f"{name}\t{fname}\t{kvs}"


if __name__ == "__main__":
    sys.exit(main())
