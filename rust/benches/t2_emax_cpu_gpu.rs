//! Paper Table 2: measured e_max scaling on CPU (Xeon) and GPU (H100)
//! accumulation models — constant for CPU (tree reduction) and GPU low
//! precision (FP32 accumulate + output round), ∝ √N for GPU FP32/FP64.

use vabft::bench_harness::BenchMode;
use vabft::calibrate::{CalibrationProtocol, Platform};
use vabft::fp::Precision;
use vabft::report::Table;

fn main() {
    let mode = BenchMode::from_env();
    mode.banner("t2_emax_cpu_gpu");
    let sizes = mode.pick(vec![128, 256, 512, 1024], vec![128, 256, 512, 1024, 2048, 4096]);
    let trials = mode.pick(4, 25);

    let cases = [
        (Platform::Cpu, Precision::F64),
        (Platform::Cpu, Precision::F32),
        (Platform::Gpu, Precision::F64),
        (Platform::Gpu, Precision::F32),
        (Platform::Gpu, Precision::Bf16),
        (Platform::Gpu, Precision::F16),
        (Platform::Gpu, Precision::F8E4M3),
    ];
    let mut table = Table::new(
        "Table 2 — measured e_max scaling on CPU and GPU models",
        &["Platform", "Precision", "e_max/u range", "CV", "R2(sqrtN)", "Scaling"],
    );
    for (platform, p) in cases {
        let model = platform.model_for(p);
        let proto = CalibrationProtocol {
            sizes: sizes.clone(),
            trials_per_size: trials,
            ..Default::default()
        };
        let res = proto.run(model, false);
        // u convention follows the paper: FP8 rows are reported relative
        // to u_FP16 (the output precision governs, §3.6).
        let u = model.out.unit_roundoff();
        let lo = res.points.iter().map(|x| x.emax / u).fold(f64::INFINITY, f64::min);
        let hi = res.points.iter().map(|x| x.emax / u).fold(0.0f64, f64::max);
        let scaling = if res.cv < 0.2 {
            "~ constant"
        } else if res.r2_sqrt_n > 0.7 {
            "prop sqrtN"
        } else {
            "mixed"
        };
        table.row(vec![
            platform.name().to_string(),
            p.name().to_string(),
            format!("{lo:.1}-{hi:.1}"),
            format!("{:.1}%", res.cv * 100.0),
            format!("{:.2}", res.r2_sqrt_n),
            scaling.to_string(),
        ]);
    }
    table.print();
    println!("Paper Table 2: CPU FP64 3.6-4.8 (const), CPU FP32 5.0-6.1 (const),");
    println!("  GPU FP64 2.7-7.1 (sqrtN), GPU FP32 2.6-6.0 (sqrtN), GPU BF16/FP16/FP8 ~2.0 (const).");
    println!("  (FP8 relative to u_FP16 — output precision governs, §3.6.)");
}
