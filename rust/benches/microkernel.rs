//! Microkernel-level bench + the CI **bitwise smoke gate**.
//!
//! Four sections:
//!
//! 1. **Equality gate** — packed and unpacked engines vs the naive
//!    reference kernels at small ragged sizes, all three strategies,
//!    f32/f64 and the generic BF16 path, several thread counts and
//!    micro shapes. Every output is asserted bitwise-equal; **timing is
//!    reported but never asserted**, so this is safe (and mandatory) on
//!    every CI push — see `.github/workflows/ci.yml`.
//! 2. **MR/NR sweep** — GFLOP/s of the packed FP32 FMA path per
//!    microkernel shape, the measured input to the tuning recipe in
//!    `docs/PERFORMANCE.md`.
//! 3. **SIMD dispatch sweep** — GFLOP/s of every `SimdLevel` this host
//!    can execute (engine label `simd-<level>`), each asserted bitwise
//!    against the scalar path first. The dispatched level's row is the
//!    acceptance evidence that runtime dispatch beats the
//!    autovectorized scalar build.
//! 4. **quantize_slice micro-bench** — batched vs per-element
//!    `Precision::quantize` on the BF16/FP16 paths (the satellite fix:
//!    powi-free `FloatSpec` constants + one dispatch per slice).
//!
//! Emits `BENCH_gemm_micro.json` next to `BENCH_gemm.json`.
//!
//! ```text
//! cargo bench --bench microkernel [-- --full]
//! ```

use std::time::{Duration, Instant};

use vabft::bench_harness::{time_once, BenchMode, BenchRecord, BenchRecords};
use vabft::fp::Precision;
use vabft::gemm::{
    cpu_features, generic_gemm, kernels, tiled, MicroConfig, ParallelismConfig, ReduceStrategy,
    SimdLevel, TileConfig,
};
use vabft::report::Table;
use vabft::rng::{Rng, Xoshiro256pp};

fn rand_f64(n: usize, seed: u64) -> Vec<f64> {
    let mut rng = Xoshiro256pp::seed_from_u64(seed);
    (0..n).map(|_| rng.next_f64() * 2.0 - 1.0).collect()
}

fn best_of(reps: usize, mut f: impl FnMut() -> Duration) -> Duration {
    (0..reps.max(1)).map(|_| f()).min().unwrap()
}

fn gflops(m: usize, k: usize, n: usize, t: Duration) -> f64 {
    2.0 * (m * k * n) as f64 / t.as_secs_f64() / 1e9
}

/// Section 1: the bitwise gate over ragged shapes.
fn equality_gate(records: &mut BenchRecords, mode: BenchMode) {
    let shapes: Vec<(usize, usize, usize)> = mode.pick(
        vec![(96, 160, 112), (33, 257, 65), (7, 1, 129)],
        vec![(96, 160, 112), (33, 257, 65), (7, 1, 129), (384, 384, 384)],
    );
    let micros = [MicroConfig::DEFAULT, MicroConfig::new(4, 8), MicroConfig::new(3, 5)];
    for &(m, k, n) in &shapes {
        let case = format!("{m}x{k}x{n}");
        let a64 = rand_f64(m * k, 11);
        let b64 = rand_f64(k * n, 12);
        let a32: Vec<f32> = a64.iter().map(|&x| x as f32).collect();
        let b32: Vec<f32> = b64.iter().map(|&x| x as f32).collect();
        for strategy in
            [ReduceStrategy::Sequential, ReduceStrategy::Fma, ReduceStrategy::Pairwise]
        {
            let ref32 = kernels::reference_gemm_f32(&a32, &b32, m, k, n, strategy);
            let ref64 = kernels::reference_gemm_f64(&a64, &b64, m, k, n, strategy);
            for threads in [1usize, 3] {
                for micro in micros {
                    let par = ParallelismConfig::with_threads(threads).micro(micro);
                    let p32 = tiled::gemm_f32(&a32, &b32, m, k, n, strategy, &par);
                    assert!(
                        p32 == ref32,
                        "f32 packed diverged: {case} {strategy:?} x{threads} {micro:?}"
                    );
                    let p64 = tiled::gemm_f64(&a64, &b64, m, k, n, strategy, &par);
                    assert!(
                        p64 == ref64,
                        "f64 packed diverged: {case} {strategy:?} x{threads} {micro:?}"
                    );
                    let u32out = tiled::gemm_unpacked_f32(&a32, &b32, m, k, n, strategy, &par);
                    assert!(u32out == ref32, "f32 unpacked diverged: {case} {strategy:?}");
                }
            }
        }
        // Generic BF16 path against its naive reference.
        let p = Precision::Bf16;
        let mut aq = a64.clone();
        let mut bq = b64.clone();
        p.quantize_slice(&mut aq);
        p.quantize_slice(&mut bq);
        for strategy in
            [ReduceStrategy::Sequential, ReduceStrategy::Fma, ReduceStrategy::Pairwise]
        {
            let want = generic_gemm(&aq, &bq, m, k, n, p, strategy);
            for tiles in [TileConfig::DEFAULT, TileConfig::new(4, 5, 7)] {
                let par = ParallelismConfig::with_threads(2).tiles(tiles);
                let got = tiled::gemm_generic(&aq, &bq, m, k, n, p, strategy, &par);
                assert!(got == want, "generic diverged: {case} {strategy:?} {tiles:?}");
            }
        }
        records.push(BenchRecord {
            case,
            precision: "all".into(),
            strategy: "all".into(),
            engine: "equality-gate".into(),
            threads: 0,
            unit: "GFLOP/s".into(),
            value: 0.0,
            speedup_vs_baseline: 1.0,
            bitwise_equal: true,
        });
    }
    println!("equality gate: all engines bitwise-equal to the reference kernels\n");
}

/// Section 2: MR/NR sweep of the packed FP32 FMA path.
fn mr_nr_sweep(records: &mut BenchRecords, mode: BenchMode) {
    let s = mode.pick(256, 512);
    let (m, k, n) = (s, s, s);
    let reps = mode.pick(2, 3);
    let case = format!("{m}x{k}x{n}");
    let a64 = rand_f64(m * k, 21);
    let b64 = rand_f64(k * n, 22);
    let a: Vec<f32> = a64.iter().map(|&x| x as f32).collect();
    let b: Vec<f32> = b64.iter().map(|&x| x as f32).collect();
    let strategy = ReduceStrategy::Fma;
    let reference = kernels::reference_gemm_f32(&a, &b, m, k, n, strategy);
    let mut table = Table::new(
        &format!("packed fp32 {case} [fma], 1 thread, per micro shape"),
        &["mr x nr", "best", "GFLOP/s", "bitwise"],
    );
    let mut baseline = 0.0f64;
    for (mr, nr) in [(2, 8), (4, 4), (4, 8), (8, 4), (8, 8), (4, 16), (8, 16), (16, 4), (6, 6)] {
        let par = ParallelismConfig::serial().micro(MicroConfig::new(mr, nr));
        let mut out = Vec::new();
        let t = best_of(reps, || {
            time_once(|| out = tiled::gemm_f32(&a, &b, m, k, n, strategy, &par))
        });
        assert!(out == reference, "mr{mr}nr{nr} diverged");
        let g = gflops(m, k, n, t);
        if (mr, nr) == (8, 8) {
            baseline = g;
        }
        table.row(vec![
            format!("{mr} x {nr}"),
            format!("{t:?}"),
            format!("{g:.2}"),
            "OK".into(),
        ]);
        records.push(BenchRecord {
            case: case.clone(),
            precision: "fp32".into(),
            strategy: strategy.name().into(),
            engine: format!("mr{mr}nr{nr}"),
            threads: 1,
            unit: "GFLOP/s".into(),
            value: g,
            speedup_vs_baseline: 1.0,
            bitwise_equal: true,
        });
    }
    table.print();
    println!("(default 8x8 = {baseline:.2} GFLOP/s; see docs/PERFORMANCE.md for the recipe)\n");
}

/// Section 3: every SIMD level this host can run, bitwise-checked
/// against the scalar path, then timed. `speedup_vs_baseline` is the
/// level's throughput over the scalar row.
fn simd_sweep(records: &mut BenchRecords, mode: BenchMode) {
    let s = mode.pick(256, 512);
    let (m, k, n) = (s, s, s);
    let reps = mode.pick(2, 3);
    let case = format!("{m}x{k}x{n}");
    let a64 = rand_f64(m * k, 41);
    let b64 = rand_f64(k * n, 42);
    let a: Vec<f32> = a64.iter().map(|&x| x as f32).collect();
    let b: Vec<f32> = b64.iter().map(|&x| x as f32).collect();
    let strategy = ReduceStrategy::Fma;
    println!("cpu features: {}", cpu_features());
    let mut table = Table::new(
        &format!("SIMD dispatch fp32 {case} [fma], 1 thread, per level"),
        &["level", "best", "GFLOP/s", "vs scalar", "bitwise"],
    );
    let reference = tiled::gemm_f32(
        &a,
        &b,
        m,
        k,
        n,
        strategy,
        &ParallelismConfig::serial().simd(SimdLevel::Scalar),
    );
    let mut scalar_g = 0.0f64;
    for level in SimdLevel::available_levels() {
        let par = ParallelismConfig::serial().simd(level);
        let mut out = Vec::new();
        let t = best_of(reps, || {
            time_once(|| out = tiled::gemm_f32(&a, &b, m, k, n, strategy, &par))
        });
        assert!(out == reference, "simd-{} diverged from scalar", level.name());
        let g = gflops(m, k, n, t);
        if level == SimdLevel::Scalar {
            scalar_g = g;
        }
        let sp = if scalar_g > 0.0 { g / scalar_g } else { 1.0 };
        table.row(vec![
            level.name().into(),
            format!("{t:?}"),
            format!("{g:.2}"),
            format!("{sp:.2}x"),
            "OK".into(),
        ]);
        records.push(BenchRecord {
            case: case.clone(),
            precision: "fp32".into(),
            strategy: strategy.name().into(),
            engine: format!("simd-{}", level.name()),
            threads: 1,
            unit: "GFLOP/s".into(),
            value: g,
            speedup_vs_baseline: sp,
            bitwise_equal: true,
        });
    }
    table.print();
    println!("(dispatched level on this host = {})\n", SimdLevel::detect().name());
}

/// Section 4: batched vs per-element quantization.
fn quantize_bench(records: &mut BenchRecords, mode: BenchMode) {
    let len = 1usize << mode.pick(15, 18);
    let reps = mode.pick(20, 50);
    // Mix of normal-range and subnormal-range values: the subnormal-flush
    // branch is where the old powi-derived constants sat.
    let xs: Vec<f64> = rand_f64(len, 31)
        .iter()
        .enumerate()
        .map(|(i, &x)| if i % 4 == 0 { x * 1e-41 } else { x * 4.0 })
        .collect();
    let mut table = Table::new(
        &format!("quantize: per-element vs quantize_slice ({len} values)"),
        &["precision", "per-call", "slice", "Melem/s slice", "speedup"],
    );
    for p in [Precision::Bf16, Precision::F16, Precision::F8E4M3] {
        let mut per_call_out = Vec::new();
        let t_call = best_of(reps, || {
            time_once(|| per_call_out = xs.iter().map(|&x| p.quantize(x)).collect::<Vec<f64>>())
        });
        let mut slice_out = Vec::new();
        let t_slice = best_of(reps, || {
            let mut v = xs.clone();
            let t0 = Instant::now();
            p.quantize_slice(&mut v);
            let dt = t0.elapsed();
            slice_out = v;
            dt
        });
        for (a, b) in per_call_out.iter().zip(&slice_out) {
            assert_eq!(a.to_bits(), b.to_bits(), "quantize_slice diverged for {p:?}");
        }
        let speedup = t_call.as_secs_f64() / t_slice.as_secs_f64();
        let melems = len as f64 / t_slice.as_secs_f64() / 1e6;
        table.row(vec![
            p.name().into(),
            format!("{t_call:?}"),
            format!("{t_slice:?}"),
            format!("{melems:.1}"),
            format!("{speedup:.2}x"),
        ]);
        for (engine, t, sp) in
            [("quantize", t_call, 1.0), ("quantize_slice", t_slice, speedup)]
        {
            records.push(BenchRecord {
                case: format!("quantize {len}"),
                precision: p.name().into(),
                strategy: "-".into(),
                engine: engine.into(),
                threads: 1,
                unit: "Melem/s".into(),
                value: len as f64 / t.as_secs_f64() / 1e6,
                speedup_vs_baseline: sp,
                bitwise_equal: true,
            });
        }
    }
    table.print();
}

fn main() {
    let mode = BenchMode::from_env();
    mode.banner("microkernel");
    let mut records = BenchRecords::new("microkernel");
    equality_gate(&mut records, mode);
    mr_nr_sweep(&mut records, mode);
    simd_sweep(&mut records, mode);
    quantize_bench(&mut records, mode);
    match records.write("BENCH_gemm_micro.json") {
        Ok(path) => println!("\ntrajectory written to {}", path.display()),
        Err(e) => eprintln!("\nwarning: could not write BENCH_gemm_micro.json: {e}"),
    }
    println!("microkernel: bitwise gate passed");
}
