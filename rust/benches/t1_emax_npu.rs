//! Paper Table 1: measured e_max scaling behaviour on the (simulated)
//! Ascend 910B NPU — BF16/FP16 wide accumulation vs FP32 per-step.
//!
//! Quick: sizes 128–1024, few trials. `--full`: 128–8192.

use vabft::bench_harness::BenchMode;
use vabft::calibrate::{CalibrationProtocol, EmaxTable, Platform};
use vabft::fp::Precision;
use vabft::report::{sci, Table};

fn main() {
    let mode = BenchMode::from_env();
    mode.banner("t1_emax_npu");
    let sizes = mode.pick(vec![128, 256, 512, 1024], vec![128, 256, 512, 1024, 2048, 4096, 8192]);
    let trials = mode.pick(4, 30);

    let mut table = Table::new(
        "Table 1 — measured e_max scaling on NPU (910B accumulation models)",
        &["Precision", "u", "e_max (measured max)", "e_max/u", "Scales with N?", "paper"],
    );
    for p in [Precision::Bf16, Precision::F16, Precision::F32] {
        let model = Platform::Npu.model_for(p);
        let proto = CalibrationProtocol {
            sizes: sizes.clone(),
            trials_per_size: trials,
            ..Default::default()
        };
        let res = proto.run(model, false);
        let max_e = res.points.iter().fold(0.0f64, |m, pt| m.max(pt.emax));
        let u = model.out.unit_roundoff();
        let scaling = if res.cv < 0.2 { "No" } else { "Yes (prop sqrtN)" };
        let paper = EmaxTable::recommended(Platform::Npu, p);
        table.row(vec![
            p.name().to_string(),
            sci(u),
            sci(max_e),
            format!("{:.1}", max_e / u),
            scaling.to_string(),
            paper.label(),
        ]);
        let detail: Vec<String> =
            res.points.iter().map(|pt| format!("{}:{}", pt.n, sci(pt.emax))).collect();
        println!("  {} per-size: {}", p.name(), detail.join("  "));
        println!(
            "  {} fitted: {}  CV {:.1}%  R2(sqrtN) {:.2}",
            p.name(),
            res.fitted.label(),
            res.cv * 100.0,
            res.r2_sqrt_n
        );
    }
    println!();
    table.print();
    println!("Paper Table 1: BF16 8e-3 (~2u, no scaling); FP16 1e-3 (~2u, no); FP32 2e-6*sqrt(N/1024).");
}
