//! Paper Table 4: threshold tightness, FP64, U(−1,1), high-precision
//! baseline (double-double substitutes mpmath), A-ABFT y=21 vs V-ABFT.

use vabft::bench_harness::BenchMode;
use vabft::calibrate::{EmaxTable, Platform};
use vabft::experiments::{run_tightness, validate_dd_baseline, TightnessConfig};
use vabft::fp::Precision;
use vabft::gemm::AccumModel;
use vabft::report::{ratio, sci, Table};
use vabft::rng::Distribution;
use vabft::threshold::AabftThreshold;

fn main() {
    let mode = BenchMode::from_env();
    mode.banner("t4_tightness_fp64");

    // Measurement-methodology check: the double-double baseline (mpmath
    // substitute) agrees exactly with the direct path difference.
    let disc = validate_dd_baseline(256, 4);
    println!("dd-baseline validation @256: discrepancy {} (must be ~0)\n", sci(disc));
    assert!(disc < 1e-15);

    let cfg = TightnessConfig {
        label: "FP64, U(-1,1), dd baseline".into(),
        model: AccumModel::cpu(Precision::F64),
        dist: Distribution::uniform_pm1(),
        sizes: mode.pick(vec![128, 256, 512], vec![128, 256, 512, 1024, 2048]),
        trials: mode.pick(3, 20),
        rows: Some(mode.pick(32, 256)),
        aabft: AabftThreshold::paper_repro(),
        vabft_emax: EmaxTable::recommended(Platform::Cpu, Precision::F64),
        wide_checksums: false,
        seed: 0x7401,
    };
    let rows = run_tightness(&cfg);
    let mut t = Table::new(
        "Table 4 — Threshold Tightness (FP64, U(-1,1), dd baseline)",
        &["Size", "Actual Diff", "A-ABFT", "V-ABFT", "A-Tight", "V-Tight", "FP(A)", "FP(V)"],
    );
    for r in &rows {
        t.row(vec![
            format!("{}x{}", r.n, r.n),
            sci(r.actual),
            sci(r.aabft_threshold),
            sci(r.vabft_threshold),
            ratio(r.a_tight()),
            ratio(r.v_tight()),
            r.fp_aabft.to_string(),
            r.fp_vabft.to_string(),
        ]);
    }
    t.print();
    println!("Paper Table 4: A-Tight 159-164x flat; V-Tight 15x->7x decreasing with size;");
    println!("  A-ABFT @512 = 1.66e-11 (reproduction target), zero FP for both.");
}
