//! Paper §6.4: false positive rate — zero across all distributions and
//! precisions (BF16/FP16/FP32), offline and online verification.

use vabft::abft::{FtGemm, Verdict, VerifyPolicy};
use vabft::bench_harness::BenchMode;
use vabft::fp::Precision;
use vabft::gemm::{AccumModel, GemmEngine};
use vabft::matrix::Matrix;
use vabft::report::Table;
use vabft::rng::{Distribution, Xoshiro256pp};
use vabft::threshold::VabftThreshold;

fn main() {
    let mode = BenchMode::from_env();
    mode.banner("fpr");
    // paper: 100k trials/config; quick: 200 multiplies × 32 rows ≈ 6.4k
    // row-verifications per config; full: 3000 × 32 ≈ 100k.
    let multiplies = mode.pick(200, 3000);

    let precisions = [Precision::Bf16, Precision::F16, Precision::F32];
    let dists = Distribution::paper_suite();

    let mut t = Table::new(
        "§6.4 — False positives over clean row verifications (must all be 0)",
        &["Precision", "Distribution", "mode", "rows checked", "false positives"],
    );
    let mut total_fp = 0usize;
    for p in precisions {
        let model = if p == Precision::F32 {
            AccumModel::gpu_highprec(p)
        } else {
            AccumModel::wide(p)
        };
        for (name, d) in &dists {
            for online in [false, true] {
                let ft = FtGemm::new(
                    GemmEngine::new(model),
                    Box::new(VabftThreshold::default()),
                    VerifyPolicy::detect_only(online),
                );
                let mut rows = 0usize;
                let mut fp = 0usize;
                let mut rng = Xoshiro256pp::from_stream(0xF9, p.bits() as u64);
                for i in 0..multiplies {
                    let (m, k, n) = (32, 96 + (i % 3) * 32, 64);
                    let a = Matrix::sample_in(m, k, d, model.input, &mut rng);
                    let b = Matrix::sample_in(k, n, d, model.input, &mut rng);
                    let out = ft.multiply(&a, &b).unwrap();
                    rows += out.report.rows_checked;
                    if out.report.verdict != Verdict::Clean {
                        fp += out.report.detections.len();
                    }
                }
                total_fp += fp;
                t.row(vec![
                    p.name().to_string(),
                    name.to_string(),
                    if online { "online" } else { "offline" }.to_string(),
                    rows.to_string(),
                    fp.to_string(),
                ]);
            }
        }
    }
    t.print();
    println!("TOTAL false positives: {total_fp}   (paper §6.4: 0 across all configs)");
    assert_eq!(total_fp, 0, "FPR must be zero");
}
