//! Paper §6.7: real model data — 0% FPR on LLaMA-7B / GPT-2 / ViT-B/32
//! weight matrices.
//!
//! Checkpoints are not available in this sandbox; per DESIGN.md §6 we use
//! synthetic weight tensors with the published shapes and layer-statistic
//! profiles (V-ABFT consumes only row-wise max/min/mean), plus — when AOT
//! artifacts exist — the actual weights of our own trained L2 transformer.

use vabft::bench_harness::BenchMode;
use vabft::experiments::run_real_model;
use vabft::report::Table;

fn main() {
    let mode = BenchMode::from_env();
    mode.banner("real_model");
    // scale divides the published dims; layers per family; GEMMs per matrix
    let (scale, layers, gemms) = mode.pick((16, 2, 3), (4, 8, 6));

    let mut t = Table::new(
        "§6.7 — Real-model-profile weights: V-ABFT false positives",
        &["Model family", "weight matrices", "row verifications", "false positives"],
    );
    let mut total_fp = 0;
    for family in ["llama-7b", "gpt2", "vit-b32"] {
        let row = run_real_model(family, scale, layers, gemms, true, 0x6E7);
        total_fp += row.false_positives;
        t.row(vec![
            row.family,
            row.matrices.to_string(),
            row.verifications.to_string(),
            row.false_positives.to_string(),
        ]);
    }
    t.print();
    println!("(shapes scaled 1/{scale}; {layers} layers per family)");

    // Our own trained transformer's weights, via the AOT training path.
    trained_weights_check(&mode);

    println!("\nPaper §6.7: LLaMA-7B 111 matrices 0% FPR; GPT-2 5379 verifications 0% FPR;");
    println!("  ViT-B/32 5937 sampled verifications 0% FPR.");
    assert_eq!(total_fp, 0);
}

/// Train the L2 transformer for a few steps through the PJRT artifact and
/// verify its *trained* weight tensors with V-ABFT (skips without
/// artifacts).
fn trained_weights_check(mode: &BenchMode) {
    use vabft::abft::{FtGemm, Verdict, VerifyPolicy};
    use vabft::fp::Precision;
    use vabft::gemm::{AccumModel, GemmEngine};
    use vabft::matrix::Matrix;
    use vabft::rng::{Distribution, Xoshiro256pp};
    use vabft::runtime::{artifacts_dir, PjrtRuntime};
    use vabft::threshold::VabftThreshold;
    use vabft::train::{SyntheticCorpus, Trainer, TrainerConfig};

    let dir = artifacts_dir();
    if !dir.join("manifest.tsv").exists() {
        println!("\n[trained-weights check skipped: run `make artifacts`]");
        return;
    }
    let rt = PjrtRuntime::from_artifacts(&dir).expect("artifacts");
    let mut trainer = Trainer::new(&rt, TrainerConfig::default()).expect("trainer");
    let (b, s) = trainer.batch_dims();
    let mut corpus = SyntheticCorpus::new(256, 3);
    let steps = mode.pick(5, 40);
    for _ in 0..steps {
        let toks = corpus.batch(b, s + 1);
        trainer.step(&toks, None).expect("step");
    }

    let model = AccumModel::wide(Precision::Bf16);
    let ft = FtGemm::new(
        GemmEngine::new(model),
        Box::new(VabftThreshold::default()),
        VerifyPolicy::detect_only(true),
    );
    let mut rng = Xoshiro256pp::seed_from_u64(77);
    let mut checked = 0;
    let mut fp = 0;
    for (p, shape) in trainer.params().iter().zip(trainer.param_shapes()) {
        if shape.len() != 2 || shape[0] < 16 {
            continue;
        }
        let (k, n) = (shape[0] as usize, shape[1] as usize);
        let w = Matrix::from_vec(k, n, p.iter().map(|&x| x as f64).collect());
        let a = Matrix::sample_in(
            16,
            k,
            &Distribution::Normal { mean: 0.0, std: 1.0 },
            model.input,
            &mut rng,
        );
        let out = ft.multiply(&a, &w.quantized(Precision::Bf16)).unwrap();
        checked += out.report.rows_checked;
        if out.report.verdict != Verdict::Clean {
            fp += out.report.detections.len();
        }
    }
    println!(
        "\ntrained L2 transformer weights ({} steps): {} verifications, {} false positives",
        trainer.steps_run, checked, fp
    );
    assert_eq!(fp, 0);
}
