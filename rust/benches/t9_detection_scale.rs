//! Paper Table 9: V-ABFT detection rate at larger scales (BF16) —
//! (128, 4096, 256) and (4096, 4096, 4096), bits 9–11, two distributions.
//!
//! Quick mode shrinks the shapes by 4× in each dimension (documented in
//! the output); `--full` runs the paper's exact shapes (the 4096³ GEMM
//! takes minutes on one core).

use vabft::bench_harness::BenchMode;
use vabft::inject::{Campaign, CampaignConfig};
use vabft::report::{pct, Table};
use vabft::rng::Distribution;
use vabft::threshold::VabftThreshold;

fn main() {
    let mode = BenchMode::from_env();
    mode.banner("t9_detection_scale");
    let trials = mode.pick(96, 1024);
    let shapes = mode.pick(
        vec![(32usize, 1024usize, 64usize), (1024, 1024, 1024)],
        vec![(128, 4096, 256), (4096, 4096, 4096)],
    );
    let dists = [
        ("N(1e-6,1)", Distribution::near_zero_normal()),
        ("TruncN", Distribution::truncated_normal()),
    ];

    for shape in shapes {
        let mut t = Table::new(
            &format!("Table 9 — V-ABFT Detection Rate (%) at scale {shape:?} (BF16)"),
            &["Bit", "N(1e-6,1)", "(0->1)", "TruncN", "(0->1)"],
        );
        let mut per_dist = Vec::new();
        for (_, d) in &dists {
            let mut cfg = CampaignConfig::table8(d.clone(), trials);
            cfg.shape = shape;
            cfg.bits = vec![9, 10, 11];
            cfg.trials_per_matrix = trials; // one GEMM per distribution
            let res = Campaign::new(cfg).run(&VabftThreshold::default());
            assert_eq!(res.false_positives, 0, "FPR must stay zero at scale");
            per_dist.push(res);
        }
        let dr01 = |b: &vabft::inject::BitResult| {
            if b.trials_0to1 > 0 {
                pct(100.0 * b.detected_0to1 as f64 / b.trials_0to1 as f64)
            } else {
                "-".to_string()
            }
        };
        for (i, bit) in [9u32, 10, 11].iter().enumerate() {
            t.row(vec![
                bit.to_string(),
                pct(per_dist[0].bits[i].detection_rate()),
                dr01(&per_dist[0].bits[i]),
                pct(per_dist[1].bits[i].detection_rate()),
                dr01(&per_dist[1].bits[i]),
            ]);
        }
        t.print();
    }
    println!("Paper Table 9: (128,4096,256): bit9 39.9/97.5, bit10 99.98/99.99, bit11 100/100;");
    println!("  (4096,4096,4096): bit9 0.0/67.5, bit10 96.4/100, bit11 100/100.");
    println!("Shape: DR degrades for low bits as K grows (rounding noise), 100% kept at bit 11.");
}
