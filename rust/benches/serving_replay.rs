//! Sharded serving-replay throughput bench, closed and open loop.
//!
//! Replays the LLaMA-7B layer trace (published shapes, scaled) through
//! the coordinator at a ladder of shard configurations, runs the
//! planned-vs-uniform protection A/B on the mixed three-family trace,
//! then drives the open-loop traffic engine (one rung per seeded arrival
//! process, plus an always-recompute vs severity-aware recovery pair on
//! a fault-injected mixed-family trace) and records the whole trajectory
//! to `BENCH_serving.json` (`vabft-serving/v3`: per-row protection-plan
//! labels alongside tail latencies, shed rates and throughput).
//!
//! Gates:
//!
//! * **always** — the closed-loop output fingerprint must be identical
//!   across every rung (sharding / partitioning / stealing are pure
//!   scheduling); the planner-driven arm must reproduce the uniform
//!   arm's fingerprint bit-for-bit (invariant #9: neutral plan selection
//!   is pure scheduling too); open-loop reruns must reproduce their
//!   fingerprints; and severity-aware recovery must preserve every
//!   detection and every output bit of the always-recompute run. All
//!   deterministic, so even the quick run enforces them — never a
//!   timing assertion;
//! * **full only** — shards=4 must reach ≥ 1.5× the shards=1 request
//!   throughput on the LLaMA-7B trace at concurrency ≥ 8, the planned
//!   arm must not lose request throughput to uniform ABFT on the mixed
//!   trace (per-layer scheme choice has to pay for itself), and
//!   severity-aware recovery must not lose to always-recompute on p99
//!   (≤ 1.10× slack for scheduler noise; it skips recompute work, so
//!   its tail should be no worse).

use std::time::Duration;

use vabft::abft::VerifyPolicy;
use vabft::bench_harness::{validate_schema, BenchMode, SERVING_SCHEMA};
use vabft::coordinator::{CoordinatorConfig, PartitionPolicy};
use vabft::gemm::{AccumModel, ParallelismConfig};
use vabft::planner::{CostModel, Planner, PlannerConfig, ProtectionPlan, ProtectionScheme};
use vabft::prelude::Precision;
use vabft::report::Table;
use vabft::workload::{
    build_trace, run_open_loop, run_replay, run_replay_planned, replay_doc, ArrivalModel,
    OpenLoopConfig, ReplayConfig, ReplayReport, ReplayRow,
};

struct Rung {
    shards: usize,
    partition: PartitionPolicy,
    steal: bool,
}

fn main() {
    let mode = BenchMode::from_env();
    mode.banner("serving_replay");

    let seed = 0x5E12u64;
    let cfg = ReplayConfig {
        family: "llama-7b".to_string(),
        scale: mode.pick(16, 4),
        layers: mode.pick(1, 2),
        batch: mode.pick(8, 16),
        passes: mode.pick(2, 4),
        concurrency: 8,
        seed,
    };
    let rungs = if mode.is_full() {
        vec![
            Rung { shards: 1, partition: PartitionPolicy::Contiguous, steal: false },
            Rung { shards: 2, partition: PartitionPolicy::Contiguous, steal: true },
            Rung { shards: 2, partition: PartitionPolicy::Interleaved, steal: true },
            Rung { shards: 4, partition: PartitionPolicy::Contiguous, steal: true },
        ]
    } else {
        vec![
            Rung { shards: 1, partition: PartitionPolicy::Contiguous, steal: false },
            Rung { shards: 2, partition: PartitionPolicy::Contiguous, steal: true },
            Rung { shards: 2, partition: PartitionPolicy::Interleaved, steal: false },
        ]
    };
    let workers = 1usize; // per shard: the ladder scales worker count via shards
    let reps = mode.pick(1, 2);

    println!(
        "replaying {} (scale 1/{}, {} layers, batch {}, {} passes, concurrency {})\n",
        cfg.family, cfg.scale, cfg.layers, cfg.batch, cfg.passes, cfg.concurrency
    );

    let mut rows: Vec<ReplayRow> = Vec::new();
    let mut t = Table::new(
        "Serving replay — LLaMA-7B trace",
        &["shards", "partition", "steal", "req/s", "GFLOP/s", "stolen", "speedup", "fp=="],
    );
    for r in &rungs {
        let run_once = || {
            run_replay(
                &cfg,
                CoordinatorConfig {
                    workers,
                    queue_depth: (2 * cfg.concurrency).max(16),
                    model: AccumModel::wide(Precision::Bf16),
                    parallelism: ParallelismConfig::serial(),
                    shards: r.shards,
                    partition: r.partition,
                    steal: r.steal,
                    ..Default::default()
                },
            )
        };
        // Best-of-reps on throughput; the fingerprint must not vary
        // between repetitions at all.
        let mut best: Option<ReplayReport> = None;
        for _ in 0..reps {
            let rep = run_once();
            if let Some(b) = &best {
                assert_eq!(b.fingerprint, rep.fingerprint, "replay not reproducible");
            }
            if best.as_ref().map(|b| rep.rps() > b.rps()).unwrap_or(true) {
                best = Some(rep);
            }
        }
        let report = best.unwrap();
        assert_eq!(report.faulty, 0, "clean replay produced non-clean verdicts");
        let row = ReplayRow::ladder(
            report,
            rows.first(),
            r.partition.name(),
            r.steal,
            workers,
            cfg.concurrency,
        );
        t.row(vec![
            r.shards.to_string(),
            r.partition.name().to_string(),
            r.steal.to_string(),
            format!("{:.1}", row.report.rps()),
            format!("{:.2}", row.report.gflops()),
            row.report.stolen.to_string(),
            format!("{:.2}x", row.speedup_vs_baseline),
            if row.fingerprint_equal { "yes".into() } else { "DIVERGED".into() },
        ]);
        rows.push(row);
    }
    t.print();

    assert!(
        rows.iter().all(|r| r.fingerprint_equal),
        "output fingerprint diverged across shard configurations"
    );
    println!(
        "\nfingerprint identical across {} configurations — sharding is pure scheduling",
        rows.len()
    );

    if mode.is_full() {
        let base = rows[0].report.rps();
        let four = rows
            .iter()
            .find(|r| r.report.shards == 4)
            .expect("full ladder includes shards=4")
            .report
            .rps();
        assert!(
            four >= 1.5 * base,
            "shards=4 must reach ≥1.5x shards=1 throughput: {four:.1} vs {base:.1} req/s"
        );
        println!("scaling gate OK: shards=4 at {:.2}x shards=1", four / base);
    }

    // ---- planned vs uniform protection on the mixed trace ----
    // The planner calibrates every neutral scheme on the trace's own
    // shapes and assigns a scheme per layer; invariant #9 makes the
    // planned arm's fingerprint a bitwise gate in every mode, and the
    // full run additionally requires the plan to pay for itself.
    let mixed_cfg = ReplayConfig {
        family: "mixed".to_string(),
        scale: mode.pick(32, 8),
        layers: 1,
        batch: mode.pick(4, 8),
        passes: mode.pick(1, 2),
        concurrency: 8,
        seed,
    };
    let trace = build_trace(&mixed_cfg);
    let pcfg = PlannerConfig::default();
    let schemes: Vec<ProtectionScheme> = ProtectionScheme::vocabulary(pcfg.block_k)
        .into_iter()
        .filter(|s| s.is_schedule_neutral())
        .collect();
    let mut cost = CostModel::new();
    let mut shapes: Vec<(usize, usize, usize)> = Vec::new();
    for e in &ProtectionPlan::uniform_for(&trace).entries {
        if !shapes.contains(&(e.m, e.k, e.n)) {
            shapes.push((e.m, e.k, e.n));
        }
    }
    for &(m, k, n) in &shapes {
        cost.calibrate_shape(
            AccumModel::wide(Precision::Bf16),
            m,
            k,
            n,
            &schemes,
            pcfg.calibration_reps,
        );
    }
    let plan = Planner::new(pcfg, cost).plan_trace(&trace);
    println!("\nprotection plan over the mixed trace: {}", plan.summary());
    let plan_ccfg = || CoordinatorConfig {
        workers: 1,
        queue_depth: (2 * mixed_cfg.concurrency).max(16),
        model: AccumModel::wide(Precision::Bf16),
        parallelism: ParallelismConfig::serial(),
        shards: 2,
        ..Default::default()
    };
    let best_of = |plan: Option<&ProtectionPlan>| {
        let mut best: Option<ReplayReport> = None;
        for _ in 0..reps {
            let rep = run_replay_planned(&mixed_cfg, plan_ccfg(), plan);
            if let Some(b) = &best {
                assert_eq!(b.fingerprint, rep.fingerprint, "planned replay not reproducible");
            }
            if best.as_ref().map(|b| rep.rps() > b.rps()).unwrap_or(true) {
                best = Some(rep);
            }
        }
        best.unwrap()
    };
    let uniform = best_of(None);
    let planned = best_of(Some(&plan));
    assert_eq!(uniform.faulty, 0, "clean uniform replay produced non-clean verdicts");
    assert_eq!(planned.faulty, 0, "clean planned replay produced non-clean verdicts");
    assert_eq!(
        planned.fingerprint, uniform.fingerprint,
        "planned replay must reproduce the uniform fingerprint bit-for-bit (invariant #9)"
    );
    println!(
        "planned vs uniform on mixed trace: {:.1} vs {:.1} req/s (fingerprints identical)",
        planned.rps(),
        uniform.rps()
    );
    if mode.is_full() {
        assert!(
            planned.rps() >= uniform.rps(),
            "planned protection must not lose to uniform ABFT on the mixed trace: \
             {:.1} vs {:.1} req/s",
            planned.rps(),
            uniform.rps()
        );
        println!("plan gate OK: planned throughput >= uniform on the mixed trace");
    }
    let urow = ReplayRow::ladder(uniform, None, "contiguous", false, 1, mixed_cfg.concurrency);
    let prow = ReplayRow::ladder(planned, Some(&urow), "contiguous", false, 1, mixed_cfg.concurrency)
        .with_plan(plan.mode.label());
    assert!(prow.fingerprint_equal, "planned row must match the uniform baseline");
    rows.push(urow);
    rows.push(prow);

    // ---- open loop: one rung per arrival process on the mixed trace ----
    // Queues run deeper than the offered count so nothing sheds and the
    // fingerprints are exact; tail latencies still include queue wait.
    let mut ol_cfg = OpenLoopConfig::smoke(seed);
    ol_cfg.scale = mode.pick(32, 8);
    ol_cfg.batch = mode.pick(4, 8);
    ol_cfg.requests = mode.pick(48, 240);
    ol_cfg.rate = mode.pick(300.0, 600.0);
    let ol_requests = ol_cfg.requests;
    let ol_ccfg = move |policy: VerifyPolicy| CoordinatorConfig {
        workers: 1,
        queue_depth: ol_requests,
        model: AccumModel::wide(Precision::Bf16),
        parallelism: ParallelismConfig::serial(),
        shards: 2,
        policy,
        ..Default::default()
    };
    let ms = |d: Duration| d.as_secs_f64() * 1e3;
    let mut ot = Table::new(
        "Open-loop serving — mixed llama-7b+gpt2+vit-b32 trace",
        &["arrival", "offered", "admitted", "p50 ms", "p99 ms", "p999 ms", "SLO %", "req/s"],
    );
    for arrival in ArrivalModel::all() {
        ol_cfg.arrival = arrival;
        let r = run_open_loop(&ol_cfg, ol_ccfg(VerifyPolicy::default()));
        if mode.is_full() {
            let again = run_open_loop(&ol_cfg, ol_ccfg(VerifyPolicy::default()));
            assert_eq!(r.trace_fingerprint, again.trace_fingerprint, "schedule not reproducible");
            assert_eq!(
                r.output_fingerprint, again.output_fingerprint,
                "open-loop outputs not reproducible"
            );
        }
        assert_eq!(r.replay.shed, 0, "deep queues must not shed");
        assert_eq!(r.replay.faulty, 0, "clean open-loop trace produced non-clean verdicts");
        ot.row(vec![
            arrival.name().to_string(),
            r.offered.to_string(),
            r.replay.requests.to_string(),
            format!("{:.2}", ms(r.replay.p50)),
            format!("{:.2}", ms(r.replay.p99)),
            format!("{:.2}", ms(r.replay.p999)),
            format!("{:.1}", 100.0 * r.slo_attainment()),
            format!("{:.1}", r.replay.rps()),
        ]);
        rows.push(ReplayRow::ladder(r.replay, None, "contiguous", false, 1, ol_cfg.requests));
    }
    ot.print();

    // ---- severity-aware vs always-recompute on a faulted trace ----
    // Identical seeded schedule, faults on every 3rd request (exponent
    // upsets alternating with sub-noise checksum perturbations). The
    // bitwise gates are deterministic and always enforced; the p99
    // comparison is timing and gates only the full run.
    let mut fault_cfg = ol_cfg.clone();
    fault_cfg.arrival = ArrivalModel::Poisson;
    fault_cfg.fault_every = 3;
    let strict = run_open_loop(&fault_cfg, ol_ccfg(VerifyPolicy::default()));
    let lenient = run_open_loop(&fault_cfg, ol_ccfg(VerifyPolicy::default().with_severity()));
    assert!(strict.faults_detected > 0, "faulted trace produced no detections");
    assert_eq!(
        lenient.faults_detected, strict.faults_detected,
        "severity-aware recovery must not downgrade detection"
    );
    assert_eq!(
        lenient.output_fingerprint, strict.output_fingerprint,
        "severity classification must never alter any computed output's bits"
    );
    assert_eq!(
        lenient.faults_waived + lenient.rows_recomputed,
        strict.rows_recomputed,
        "every strict recompute must become a waiver or stay a recompute"
    );
    println!(
        "severity on faulted trace: {} detections; always-recompute p99 {:.2} ms \
         ({} rows recomputed) vs severity-aware p99 {:.2} ms ({} waived, {} recomputed)",
        strict.faults_detected,
        ms(strict.replay.p99),
        strict.rows_recomputed,
        ms(lenient.replay.p99),
        lenient.faults_waived,
        lenient.rows_recomputed,
    );
    if mode.is_full() {
        assert!(
            lenient.replay.p99 <= strict.replay.p99.mul_f64(1.10),
            "severity-aware p99 must not lose to always-recompute: {:?} vs {:?}",
            lenient.replay.p99,
            strict.replay.p99
        );
        println!("severity tail gate OK: waiving does not inflate p99");
    }
    let rename = |mut rep: ReplayReport, label: &str| {
        rep.family = format!("{} [{label}]", rep.family);
        rep
    };
    rows.push(ReplayRow::ladder(
        rename(strict.replay, "always-recompute"),
        None,
        "contiguous",
        false,
        1,
        fault_cfg.requests,
    ));
    rows.push(ReplayRow::ladder(
        rename(lenient.replay, "severity-aware"),
        None,
        "contiguous",
        false,
        1,
        fault_cfg.requests,
    ));

    let doc = replay_doc(&rows, if mode.is_full() { "full" } else { "quick" });
    let json = doc.to_json();
    validate_schema(&json, SERVING_SCHEMA).expect("serving schema must validate");
    match doc.write("BENCH_serving.json", "VABFT_SERVING_JSON") {
        Ok(p) => println!("wrote {}", p.display()),
        Err(e) => {
            eprintln!("failed to write BENCH_serving.json: {e}");
            std::process::exit(1);
        }
    }
}
