//! Sharded serving-replay throughput bench.
//!
//! Replays the LLaMA-7B layer trace (published shapes, scaled) through
//! the coordinator at a ladder of shard configurations and records the
//! trajectory to `BENCH_serving.json` (`vabft-serving/v1`).
//!
//! Two gates, one per mode:
//!
//! * **always** — the output fingerprint must be identical across every
//!   rung (sharding / partitioning / stealing are pure scheduling); the
//!   bench exits non-zero on divergence, so even the quick run is a
//!   correctness gate, never a timing assertion;
//! * **full only** — shards=4 must reach ≥ 1.5× the shards=1 request
//!   throughput on the LLaMA-7B trace at concurrency ≥ 8 (the scaling
//!   claim of the serving tier; skipped on loaded quick runs).

use vabft::bench_harness::{validate_schema, BenchMode, SERVING_SCHEMA};
use vabft::coordinator::{CoordinatorConfig, PartitionPolicy};
use vabft::gemm::{AccumModel, ParallelismConfig};
use vabft::prelude::Precision;
use vabft::report::Table;
use vabft::workload::{run_replay, replay_doc, ReplayConfig, ReplayReport, ReplayRow};

struct Rung {
    shards: usize,
    partition: PartitionPolicy,
    steal: bool,
}

fn main() {
    let mode = BenchMode::from_env();
    mode.banner("serving_replay");

    let seed = 0x5E12u64;
    let cfg = ReplayConfig {
        family: "llama-7b".to_string(),
        scale: mode.pick(16, 4),
        layers: mode.pick(1, 2),
        batch: mode.pick(8, 16),
        passes: mode.pick(2, 4),
        concurrency: 8,
        seed,
    };
    let rungs = if mode.is_full() {
        vec![
            Rung { shards: 1, partition: PartitionPolicy::Contiguous, steal: false },
            Rung { shards: 2, partition: PartitionPolicy::Contiguous, steal: true },
            Rung { shards: 2, partition: PartitionPolicy::Interleaved, steal: true },
            Rung { shards: 4, partition: PartitionPolicy::Contiguous, steal: true },
        ]
    } else {
        vec![
            Rung { shards: 1, partition: PartitionPolicy::Contiguous, steal: false },
            Rung { shards: 2, partition: PartitionPolicy::Contiguous, steal: true },
            Rung { shards: 2, partition: PartitionPolicy::Interleaved, steal: false },
        ]
    };
    let workers = 1usize; // per shard: the ladder scales worker count via shards
    let reps = mode.pick(1, 2);

    println!(
        "replaying {} (scale 1/{}, {} layers, batch {}, {} passes, concurrency {})\n",
        cfg.family, cfg.scale, cfg.layers, cfg.batch, cfg.passes, cfg.concurrency
    );

    let mut rows: Vec<ReplayRow> = Vec::new();
    let mut t = Table::new(
        "Serving replay — LLaMA-7B trace",
        &["shards", "partition", "steal", "req/s", "GFLOP/s", "stolen", "speedup", "fp=="],
    );
    for r in &rungs {
        let run_once = || {
            run_replay(
                &cfg,
                CoordinatorConfig {
                    workers,
                    queue_depth: (2 * cfg.concurrency).max(16),
                    model: AccumModel::wide(Precision::Bf16),
                    parallelism: ParallelismConfig::serial(),
                    shards: r.shards,
                    partition: r.partition,
                    steal: r.steal,
                    ..Default::default()
                },
            )
        };
        // Best-of-reps on throughput; the fingerprint must not vary
        // between repetitions at all.
        let mut best: Option<ReplayReport> = None;
        for _ in 0..reps {
            let rep = run_once();
            if let Some(b) = &best {
                assert_eq!(b.fingerprint, rep.fingerprint, "replay not reproducible");
            }
            if best.as_ref().map(|b| rep.rps() > b.rps()).unwrap_or(true) {
                best = Some(rep);
            }
        }
        let report = best.unwrap();
        assert_eq!(report.faulty, 0, "clean replay produced non-clean verdicts");
        let row = ReplayRow::ladder(
            report,
            rows.first(),
            r.partition.name(),
            r.steal,
            workers,
            cfg.concurrency,
        );
        t.row(vec![
            r.shards.to_string(),
            r.partition.name().to_string(),
            r.steal.to_string(),
            format!("{:.1}", row.report.rps()),
            format!("{:.2}", row.report.gflops()),
            row.report.stolen.to_string(),
            format!("{:.2}x", row.speedup_vs_baseline),
            if row.fingerprint_equal { "yes".into() } else { "DIVERGED".into() },
        ]);
        rows.push(row);
    }
    t.print();

    let doc = replay_doc(&rows, if mode.is_full() { "full" } else { "quick" });
    let json = doc.to_json();
    validate_schema(&json, SERVING_SCHEMA).expect("serving schema must validate");
    match doc.write("BENCH_serving.json", "VABFT_SERVING_JSON") {
        Ok(p) => println!("wrote {}", p.display()),
        Err(e) => {
            eprintln!("failed to write BENCH_serving.json: {e}");
            std::process::exit(1);
        }
    }

    assert!(
        rows.iter().all(|r| r.fingerprint_equal),
        "output fingerprint diverged across shard configurations"
    );
    println!(
        "\nfingerprint identical across {} configurations — sharding is pure scheduling",
        rows.len()
    );

    if mode.is_full() {
        let base = rows[0].report.rps();
        let four = rows
            .iter()
            .find(|r| r.report.shards == 4)
            .expect("full ladder includes shards=4")
            .report
            .rps();
        assert!(
            four >= 1.5 * base,
            "shards=4 must reach ≥1.5x shards=1 throughput: {four:.1} vs {base:.1} req/s"
        );
        println!("scaling gate OK: shards=4 at {:.2}x shards=1", four / base);
    }
}
