//! Paper Table 6: threshold tightness, BF16, U(0,1), GPU H100 model,
//! A-ABFT with computed y = max|A|·max|Σ_j B_kj|.
//!
//! BF16 GEMM uses the wide (FP32) accumulation model; checksum columns
//! stay in the FP32 datapath (fused-style encoding) while C is stored in
//! BF16 — matching the measured "Actual Diff" magnitudes in the paper.

use vabft::bench_harness::BenchMode;
use vabft::calibrate::{EmaxTable, Platform};
use vabft::experiments::{run_tightness, TightnessConfig};
use vabft::fp::Precision;
use vabft::gemm::AccumModel;
use vabft::report::{ratio, sci, Table};
use vabft::rng::Distribution;
use vabft::threshold::AabftThreshold;

fn main() {
    let mode = BenchMode::from_env();
    mode.banner("t6_tightness_bf16");
    let cfg = TightnessConfig {
        label: "BF16, U(0,1), GPU model".into(),
        model: AccumModel::wide(Precision::Bf16),
        dist: Distribution::uniform_01(),
        sizes: mode.pick(vec![128, 256, 512], vec![128, 256, 512, 1024, 2048]),
        trials: mode.pick(5, 100),
        rows: Some(mode.pick(32, 256)),
        aabft: AabftThreshold::computed_y(),
        vabft_emax: EmaxTable::recommended(Platform::Gpu, Precision::Bf16),
        wide_checksums: true,
        seed: 0x7603,
    };
    let rows = run_tightness(&cfg);
    let mut t = Table::new(
        "Table 6 — Threshold Tightness (BF16, U(0,1), GPU model)",
        &["Size", "Actual Diff", "A-ABFT", "V-ABFT", "A-Tight", "V-Tight", "FP(A)", "FP(V)"],
    );
    for r in &rows {
        t.row(vec![
            format!("{}x{}", r.n, r.n),
            sci(r.actual),
            sci(r.aabft_threshold),
            sci(r.vabft_threshold),
            ratio(r.a_tight()),
            ratio(r.v_tight()),
            r.fp_aabft.to_string(),
            r.fp_vabft.to_string(),
        ]);
    }
    t.print();
    println!("Paper Table 6: A-ABFT 300x@128 -> 4233x@2048 (degrades, O(n^1.5));");
    println!("  V-ABFT 48x@128 -> 158x@2048; zero FP for both.");
}
