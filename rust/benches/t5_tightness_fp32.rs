//! Paper Table 5: threshold tightness, FP32, U(−1,1), FP64 baseline.

use vabft::bench_harness::BenchMode;
use vabft::calibrate::{EmaxTable, Platform};
use vabft::experiments::{run_tightness, TightnessConfig};
use vabft::fp::Precision;
use vabft::gemm::AccumModel;
use vabft::report::{ratio, sci, Table};
use vabft::rng::Distribution;
use vabft::threshold::AabftThreshold;

fn main() {
    let mode = BenchMode::from_env();
    mode.banner("t5_tightness_fp32");
    let cfg = TightnessConfig {
        label: "FP32, U(-1,1), FP64 baseline".into(),
        model: AccumModel::cpu(Precision::F32),
        dist: Distribution::uniform_pm1(),
        sizes: mode.pick(vec![128, 256, 512], vec![128, 256, 512, 1024, 2048]),
        trials: mode.pick(5, 100),
        rows: Some(mode.pick(32, 256)),
        aabft: AabftThreshold::paper_repro(),
        vabft_emax: EmaxTable::recommended(Platform::Cpu, Precision::F32),
        wide_checksums: false,
        seed: 0x7502,
    };
    let rows = run_tightness(&cfg);
    let mut t = Table::new(
        "Table 5 — Threshold Tightness (FP32, U(-1,1), FP64 baseline)",
        &["Size", "Actual Diff", "A-ABFT", "V-ABFT", "A-Tight", "V-Tight", "FP(A)", "FP(V)"],
    );
    for r in &rows {
        t.row(vec![
            format!("{}x{}", r.n, r.n),
            sci(r.actual),
            sci(r.aabft_threshold),
            sci(r.vabft_threshold),
            ratio(r.a_tight()),
            ratio(r.v_tight()),
            r.fp_aabft.to_string(),
            r.fp_vabft.to_string(),
        ]);
    }
    t.print();
    println!("Paper Table 5: A-ABFT 2.23e-3@128 … 1.42e-1@2048 (321-633x);");
    println!("  V-ABFT 9.19e-5@128 … 2.94e-3@2048 (7-20x); zero FP for both.");
}
