//! Tiled parallel engine vs the naive reference kernels: wall-clock at
//! serving-relevant sizes, with the bitwise schedule-equality check run on
//! every measured output (speed is worthless here if the schedule moved).
//!
//! Quick mode: 512³ FP32 (the acceptance shape — the 4-thread engine must
//! beat the naive kernel). Full mode adds 1024³ and the FP64 path.
//!
//! ```text
//! cargo bench --bench parallel_engine [-- --full]
//! ```

use std::time::Duration;

use vabft::bench_harness::{time_once, BenchMode};
use vabft::gemm::{kernels, tiled, ParallelismConfig, ReduceStrategy};
use vabft::report::Table;
use vabft::rng::{Rng, Xoshiro256pp};

fn rand_f32(n: usize, seed: u64) -> Vec<f32> {
    let mut rng = Xoshiro256pp::seed_from_u64(seed);
    (0..n).map(|_| (rng.next_f64() * 2.0 - 1.0) as f32).collect()
}

fn best_of(reps: usize, mut f: impl FnMut() -> Duration) -> Duration {
    (0..reps.max(1)).map(|_| f()).min().unwrap()
}

fn main() {
    let mode = BenchMode::from_env();
    mode.banner("parallel_engine");
    let reps = mode.pick(2, 4);
    let sizes: Vec<usize> = mode.pick(vec![512], vec![512, 1024]);
    let par_from_cli = ParallelismConfig::from_args(&vabft::cli::Args::parse());
    let thread_counts: Vec<usize> = if par_from_cli.threads > 1 {
        vec![par_from_cli.threads]
    } else {
        vec![1, 2, 4]
    };

    for &s in &sizes {
        let (m, k, n) = (s, s, s);
        let a = rand_f32(m * k, 1);
        let b = rand_f32(k * n, 2);
        for strategy in
            [ReduceStrategy::Sequential, ReduceStrategy::Fma, ReduceStrategy::Pairwise]
        {
            let mut reference = Vec::new();
            let t_naive = best_of(reps, || {
                time_once(|| reference = kernels::reference_gemm_f32(&a, &b, m, k, n, strategy))
            });
            let flops = 2.0 * (m * k * n) as f64;

            let mut table = Table::new(
                &format!("fp32 {m}x{k}x{n} [{}]", strategy.name()),
                &["engine", "best", "GFLOP/s", "speedup", "bitwise"],
            );
            table.row(vec![
                "naive ikj".into(),
                format!("{t_naive:?}"),
                format!("{:.2}", flops / t_naive.as_secs_f64() / 1e9),
                "1.00x".into(),
                "ref".into(),
            ]);
            for &threads in &thread_counts {
                let par = ParallelismConfig::with_threads(threads).tiles(par_from_cli.tiles);
                let mut out = Vec::new();
                let t_tiled = best_of(reps, || {
                    time_once(|| out = tiled::gemm_f32(&a, &b, m, k, n, strategy, &par))
                });
                let equal = out == reference;
                assert!(equal, "schedule invariant violated at {threads} threads");
                let speedup = t_naive.as_secs_f64() / t_tiled.as_secs_f64();
                table.row(vec![
                    format!("tiled x{threads}"),
                    format!("{t_tiled:?}"),
                    format!("{:.2}", flops / t_tiled.as_secs_f64() / 1e9),
                    format!("{speedup:.2}x"),
                    "OK".into(),
                ]);
                // The acceptance bar: at 512³ FP32 and 4 threads the
                // parallel engine must beat the naive kernel wall-clock.
                if s >= 512 && threads >= 4 {
                    assert!(
                        speedup > 1.0,
                        "parallel engine slower than naive at {s}³ x{threads} ({speedup:.2}x)"
                    );
                }
            }
            table.print();
        }
    }
    println!("parallel_engine: all outputs bitwise-equal to the naive reference");
}
