//! Engine trajectory bench: **naive → unpacked (PR 1) → packed (PR 3)**
//! at serving-relevant sizes, with the bitwise schedule-equality check
//! run on every measured output (speed is worthless here if the schedule
//! moved), plus the generic-precision BF16 path. Emits the
//! machine-readable trajectory to `BENCH_gemm.json` at the repo root
//! (override with `VABFT_BENCH_JSON`).
//!
//! Quick mode: 512³ FP32 + a small generic-BF16 shape. Full mode adds
//! 1024³, the FP64 path, and asserts the acceptance bars:
//! packed ≥ 1.5× unpacked at 1024³ FP32 FMA, and the blocked generic
//! path beating the naive generic reference.
//!
//! ```text
//! cargo bench --bench parallel_engine [-- --full]
//! ```

use std::time::Duration;

use vabft::bench_harness::{time_once, BenchMode, BenchRecord, BenchRecords};
use vabft::fp::Precision;
use vabft::gemm::{generic_gemm, kernels, tiled, EngineConfig, ParallelismConfig, ReduceStrategy};
use vabft::report::Table;
use vabft::rng::{Rng, Xoshiro256pp};

fn rand_f32(n: usize, seed: u64) -> Vec<f32> {
    let mut rng = Xoshiro256pp::seed_from_u64(seed);
    (0..n).map(|_| (rng.next_f64() * 2.0 - 1.0) as f32).collect()
}

fn rand_f64(n: usize, seed: u64) -> Vec<f64> {
    let mut rng = Xoshiro256pp::seed_from_u64(seed);
    (0..n).map(|_| rng.next_f64() * 2.0 - 1.0).collect()
}

fn best_of(reps: usize, mut f: impl FnMut() -> Duration) -> Duration {
    (0..reps.max(1)).map(|_| f()).min().unwrap()
}

fn gflops(m: usize, k: usize, n: usize, t: Duration) -> f64 {
    2.0 * (m * k * n) as f64 / t.as_secs_f64() / 1e9
}

#[allow(clippy::too_many_arguments)]
fn record(
    records: &mut BenchRecords,
    case: &str,
    precision: &str,
    strategy: &str,
    engine: &str,
    threads: usize,
    value: f64,
    speedup: f64,
) {
    records.push(BenchRecord {
        case: case.into(),
        precision: precision.into(),
        strategy: strategy.into(),
        engine: engine.into(),
        threads,
        unit: "GFLOP/s".into(),
        value,
        speedup_vs_baseline: speedup,
        bitwise_equal: true, // asserted before recording
    });
}

/// One (size, element type) section: naive reference, then unpacked and
/// packed engines per thread count. Returns (best unpacked, best packed)
/// FMA-strategy times for the acceptance bar.
macro_rules! engine_section {
    ($records:expr, $reps:expr, $thread_counts:expr, $base_par:expr, $m:expr, $k:expr, $n:expr,
     $prec_name:expr, $a:expr, $b:expr, $naive:expr, $unpacked:expr, $packed:expr) => {{
        let (m, k, n) = ($m, $k, $n);
        let case = format!("{m}x{k}x{n}");
        let mut best_unpacked_fma = Duration::MAX;
        let mut best_packed_fma = Duration::MAX;
        for strategy in
            [ReduceStrategy::Sequential, ReduceStrategy::Fma, ReduceStrategy::Pairwise]
        {
            let mut reference = Vec::new();
            let t_naive =
                best_of($reps, || time_once(|| reference = $naive(&$a, &$b, strategy)));
            let mut table = Table::new(
                &format!("{} {case} [{}]", $prec_name, strategy.name()),
                &["engine", "best", "GFLOP/s", "speedup", "bitwise"],
            );
            table.row(vec![
                "naive ikj".into(),
                format!("{t_naive:?}"),
                format!("{:.2}", gflops(m, k, n, t_naive)),
                "1.00x".into(),
                "ref".into(),
            ]);
            record(
                $records, &case, $prec_name, strategy.name(), "naive", 1,
                gflops(m, k, n, t_naive), 1.0,
            );
            for &threads in $thread_counts {
                // Honor the CLI tile/micro flags (--mc/--kc/--nc/--mr/--nr).
                let par = ParallelismConfig::with_threads(threads)
                    .tiles($base_par.tiles)
                    .micro($base_par.micro);
                for (engine_name, is_packed) in [("unpacked", false), ("packed", true)] {
                    let mut out = Vec::new();
                    let t = best_of($reps, || {
                        time_once(|| {
                            out = if is_packed {
                                $packed(&$a, &$b, m, k, n, strategy, &par)
                            } else {
                                $unpacked(&$a, &$b, m, k, n, strategy, &par)
                            }
                        })
                    });
                    assert!(
                        out == reference,
                        "schedule invariant violated: {engine_name} x{threads} {strategy:?}"
                    );
                    let speedup = t_naive.as_secs_f64() / t.as_secs_f64();
                    table.row(vec![
                        format!("{engine_name} x{threads}"),
                        format!("{t:?}"),
                        format!("{:.2}", gflops(m, k, n, t)),
                        format!("{speedup:.2}x"),
                        "OK".into(),
                    ]);
                    record(
                        $records, &case, $prec_name, strategy.name(), engine_name, threads,
                        gflops(m, k, n, t), speedup,
                    );
                    if strategy == ReduceStrategy::Fma {
                        if is_packed {
                            best_packed_fma = best_packed_fma.min(t);
                        } else {
                            best_unpacked_fma = best_unpacked_fma.min(t);
                        }
                    }
                    // The PR-1 acceptance bar, now also demanded of the
                    // packed engine: beat the naive kernel at 512³ ×4.
                    if m >= 512 && threads >= 4 {
                        assert!(
                            speedup > 1.0,
                            "{engine_name} slower than naive at {case} x{threads} ({speedup:.2}x)"
                        );
                    }
                }
            }
            table.print();
        }
        (best_unpacked_fma, best_packed_fma)
    }};
}

fn main() {
    let mode = BenchMode::from_env();
    mode.banner("parallel_engine");
    let reps = mode.pick(2, 4);
    let sizes: Vec<usize> = mode.pick(vec![512], vec![512, 1024]);
    let par_from_cli = EngineConfig::from_args(&vabft::cli::Args::parse()).resolve();
    let thread_counts: Vec<usize> = if par_from_cli.threads > 1 {
        vec![par_from_cli.threads]
    } else {
        vec![1, 2, 4]
    };
    let mut records = BenchRecords::new("parallel_engine");

    for &s in &sizes {
        let (m, k, n) = (s, s, s);
        let a32 = rand_f32(m * k, 1);
        let b32 = rand_f32(k * n, 2);
        let naive32 =
            |a: &[f32], b: &[f32], st: ReduceStrategy| kernels::reference_gemm_f32(a, b, m, k, n, st);
        let (best_unpacked, best_packed) = engine_section!(
            &mut records, reps, &thread_counts, par_from_cli, m, k, n, "fp32", a32, b32,
            naive32, tiled::gemm_unpacked_f32, tiled::gemm_f32
        );
        // Acceptance bar (full mode, 1024³): the packed engine must be
        // ≥ 1.5× the PR-1 unpacked engine on the FP32 FMA path.
        if mode.is_full() && s >= 1024 {
            let ratio = best_unpacked.as_secs_f64() / best_packed.as_secs_f64();
            println!("acceptance: packed vs unpacked fp32 fma at {s}³ = {ratio:.2}x");
            assert!(
                ratio >= 1.5,
                "packed engine below the 1.5x acceptance bar vs unpacked at {s}³ ({ratio:.2}x)"
            );
        }
        if mode.is_full() && s <= 512 {
            let a64 = rand_f64(m * k, 3);
            let b64 = rand_f64(k * n, 4);
            let naive64 = |a: &[f64], b: &[f64], st: ReduceStrategy| {
                kernels::reference_gemm_f64(a, b, m, k, n, st)
            };
            let _ = engine_section!(
                &mut records, reps, &thread_counts, par_from_cli, m, k, n, "fp64", a64, b64,
                naive64, tiled::gemm_unpacked_f64, tiled::gemm_f64
            );
        }
    }

    // The generic (software-precision) BF16 path: the naive reference is
    // crate::gemm::generic_gemm (tile-blind, strided B); the blocked path
    // is tiled::gemm_generic, which now honors TileConfig.
    {
        let s = mode.pick(160, 256);
        let (m, k, n) = (s, s, s);
        let p = Precision::Bf16;
        let mut a = rand_f64(m * k, 5);
        let mut b = rand_f64(k * n, 6);
        p.quantize_slice(&mut a);
        p.quantize_slice(&mut b);
        let case = format!("{m}x{k}x{n}");
        let mut table = Table::new(
            &format!("bf16(generic) {case} [sequential]"),
            &["engine", "best", "GFLOP/s", "speedup", "bitwise"],
        );
        let st = ReduceStrategy::Sequential;
        let mut reference = Vec::new();
        let t_naive =
            best_of(reps, || time_once(|| reference = generic_gemm(&a, &b, m, k, n, p, st)));
        table.row(vec![
            "naive".into(),
            format!("{t_naive:?}"),
            format!("{:.2}", gflops(m, k, n, t_naive)),
            "1.00x".into(),
            "ref".into(),
        ]);
        record(&mut records, &case, "bf16(generic)", st.name(), "naive", 1,
            gflops(m, k, n, t_naive), 1.0);
        for &threads in &thread_counts {
            let par = ParallelismConfig::with_threads(threads).tiles(par_from_cli.tiles);
            let mut out = Vec::new();
            let t = best_of(reps, || {
                time_once(|| out = tiled::gemm_generic(&a, &b, m, k, n, p, st, &par))
            });
            assert!(out == reference, "generic schedule invariant violated x{threads}");
            let speedup = t_naive.as_secs_f64() / t.as_secs_f64();
            table.row(vec![
                format!("blocked x{threads}"),
                format!("{t:?}"),
                format!("{:.2}", gflops(m, k, n, t)),
                format!("{speedup:.2}x"),
                "OK".into(),
            ]);
            record(&mut records, &case, "bf16(generic)", st.name(), "blocked", threads,
                gflops(m, k, n, t), speedup);
            // Acceptance: a measurable win for the blocked generic path
            // (full mode; single-thread keeps it an apples-to-apples
            // blocking win, not a threading win).
            if mode.is_full() && threads == 1 {
                assert!(
                    speedup > 1.0,
                    "blocked generic path not faster than naive ({speedup:.2}x)"
                );
            }
        }
        table.print();
    }

    match records.write("BENCH_gemm.json") {
        Ok(path) => println!("\ntrajectory written to {}", path.display()),
        Err(e) => eprintln!("\nwarning: could not write BENCH_gemm.json: {e}"),
    }
    println!("parallel_engine: all outputs bitwise-equal to the naive reference");
}
