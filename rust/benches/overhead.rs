//! Paper §6.8: performance overhead — FT-GEMM vs plain GEMM vs DMR, and
//! the threshold-computation share.
//!
//! The paper reports 11.98% average FT-GEMM overhead on Ascend 910B with
//! <2% from threshold computation, vs >200% for DMR. Absolute numbers
//! here are CPU-simulation numbers; the shape that must reproduce is
//! threshold ≪ FT-GEMM ≪ DMR.

use vabft::bench_harness::BenchMode;
use vabft::experiments::{run_overhead, OverheadConfig};
use vabft::fp::Precision;
use vabft::gemm::AccumModel;
use vabft::report::Table;
use vabft::rng::Distribution;

fn main() {
    let mode = BenchMode::from_env();
    mode.banner("overhead");
    let reps = mode.pick(5, 15);
    let shapes = mode.pick(
        vec![(128usize, 1024usize, 256usize)],
        vec![(128, 1024, 256), (512, 512, 512), (1024, 1024, 1024)],
    );

    for shape in shapes {
        for model in [AccumModel::wide(Precision::Bf16), AccumModel::gpu_highprec(Precision::F32)]
        {
            let cfg = OverheadConfig {
                model,
                shape,
                dist: Distribution::normal_1_1(),
                reps,
                seed: 0x0E0,
            };
            let rows = run_overhead(&cfg);
            let mut t = Table::new(
                &format!("§6.8 — Overhead, shape {:?}, model {}", shape, model.label()),
                &["Configuration", "median time", "overhead vs plain"],
            );
            for r in &rows {
                t.row(vec![
                    r.label.clone(),
                    format!("{:?}", r.median),
                    format!("{:+.2}%", r.overhead_pct),
                ]);
            }
            t.print();
        }
    }
    println!("Paper §6.8: FT-GEMM total 11.98% avg overhead; threshold <2%; DMR >200%.");
}
