//! Paper §6.8: performance overhead — FT-GEMM vs plain GEMM vs DMR, and
//! the threshold-computation share.
//!
//! The paper reports 11.98% average FT-GEMM overhead on Ascend 910B with
//! <2% from threshold computation, vs >200% for DMR. Absolute numbers
//! here are CPU-simulation numbers; the shape that must reproduce is
//! threshold ≪ FT-GEMM ≪ DMR. The ladder includes the fused verify
//! point (detection inside the packed GEMM epilogue) and appends every
//! row to the shared `BENCH_gemm.json` trajectory — the same file the
//! parallel_engine bench writes — so one committed document carries the
//! full perf record. Full mode asserts the fused acceptance bar:
//! < 10% overhead vs plain GEMM at 1024³.

use vabft::bench_harness::{BenchMode, BenchRecord, BenchRecords};
use vabft::experiments::{run_overhead, OverheadConfig};
use vabft::fp::Precision;
use vabft::gemm::AccumModel;
use vabft::report::Table;
use vabft::rng::Distribution;

/// Short machine-readable slug for a ladder row label.
fn engine_slug(label: &str) -> &str {
    match label {
        "plain GEMM" => "plain",
        "FT-GEMM (encode per call)" => "ftgemm-cold",
        "FT-GEMM (prepared weights)" => "ftgemm-prepared",
        "FT-GEMM (fused epilogue, prepared)" => "ftgemm-fused",
        "DMR (2x GEMM + compare)" => "dmr",
        "threshold only (full)" => "threshold-full",
        "threshold only (prepared)" => "threshold-prepared",
        other => other,
    }
}

fn main() {
    let mode = BenchMode::from_env();
    mode.banner("overhead");
    let reps = mode.pick(5, 15);
    let shapes = mode.pick(
        vec![(128usize, 1024usize, 256usize)],
        vec![(128, 1024, 256), (512, 512, 512), (1024, 1024, 1024)],
    );
    let mut records = BenchRecords::new("overhead");

    for shape in shapes {
        for model in [AccumModel::wide(Precision::Bf16), AccumModel::gpu_highprec(Precision::F32)]
        {
            let cfg = OverheadConfig {
                model,
                shape,
                dist: Distribution::normal_1_1(),
                reps,
                seed: 0x0E0,
            };
            let rows = run_overhead(&cfg);
            let base = rows[0].median.as_secs_f64();
            let case = format!("{}x{}x{}", shape.0, shape.1, shape.2);
            let mut t = Table::new(
                &format!("§6.8 — Overhead, shape {:?}, model {}", shape, model.label()),
                &["Configuration", "median time", "overhead vs plain"],
            );
            for r in &rows {
                t.row(vec![
                    r.label.clone(),
                    format!("{:?}", r.median),
                    format!("{:+.2}%", r.overhead_pct),
                ]);
                records.push(BenchRecord {
                    case: case.clone(),
                    precision: model.input.name().to_string(),
                    strategy: model.strategy.name().to_string(),
                    engine: engine_slug(&r.label).to_string(),
                    threads: 1,
                    unit: "ms".into(),
                    value: r.median.as_secs_f64() * 1e3,
                    speedup_vs_baseline: base / r.median.as_secs_f64(),
                    bitwise_equal: true,
                });
            }
            t.print();
            // Acceptance bar (full mode, 1024³): the fused verify point
            // must stay under 10% overhead vs the unprotected GEMM.
            if mode.is_full() && shape == (1024, 1024, 1024) {
                let fused = rows
                    .iter()
                    .find(|r| r.label.contains("fused"))
                    .expect("fused row missing from overhead ladder");
                println!(
                    "acceptance: fused FT-GEMM overhead at 1024³ ({}) = {:+.2}%",
                    model.label(),
                    fused.overhead_pct
                );
                assert!(
                    fused.overhead_pct < 10.0,
                    "fused FT-GEMM above the 10% overhead bar at 1024³: {:+.2}%",
                    fused.overhead_pct
                );
            }
        }
    }

    match records.append("BENCH_gemm.json") {
        Ok(path) => println!("\noverhead ladder appended to {}", path.display()),
        Err(e) => eprintln!("\nwarning: could not update BENCH_gemm.json: {e}"),
    }
    println!("Paper §6.8: FT-GEMM total 11.98% avg overhead; threshold <2%; DMR >200%.");
}
