//! Weight-stationary FT-GEMM: cold (checksum encode + B statistics per
//! call) vs warm (`PreparedWeights` computed once) — the serving-shaped
//! amortization the coordinator's `register_weights` path relies on.
//!
//! Serving shape: a small activation batch (M = 8) against square weights
//! (quick: 512², full: adds 1024²), across all three reduction strategies.
//! Every measured pair is checked for **bitwise-identical outputs and
//! identical verification decisions** — speed from amortization, never
//! from changing the rounding schedule. The acceptance bar: at ≥512²
//! weights the warm path must beat cold encode-per-call.
//!
//! ```text
//! cargo bench --bench prepared_vs_cold [-- --full]
//! ```

use std::time::Duration;

use vabft::abft::{FtGemm, Verdict, VerifyPolicy};
use vabft::bench_harness::{time_once, BenchMode};
use vabft::fp::Precision;
use vabft::gemm::{AccumModel, GemmEngine, ReduceStrategy};
use vabft::matrix::Matrix;
use vabft::report::Table;
use vabft::rng::{Distribution, Xoshiro256pp};
use vabft::threshold::VabftThreshold;

fn best_of(reps: usize, mut f: impl FnMut() -> Duration) -> Duration {
    (0..reps.max(1)).map(|_| f()).min().unwrap()
}

fn main() {
    let mode = BenchMode::from_env();
    mode.banner("prepared_vs_cold");
    let reps = mode.pick(3, 5);
    let sizes: Vec<usize> = mode.pick(vec![512], vec![512, 1024]);
    let m = 8usize; // serving batch: the regime where encode cost dominates

    let mut rng = Xoshiro256pp::seed_from_u64(0xC01D);
    let d = Distribution::normal_1_1();

    for &s in &sizes {
        let (k, n) = (s, s);
        let a = Matrix::sample_in(m, k, &d, Precision::Bf16, &mut rng);
        let b = Matrix::sample_in(k, n, &d, Precision::Bf16, &mut rng);

        let mut table = Table::new(
            &format!("FT-GEMM {m}x{k}x{n} — cold vs PreparedWeights"),
            &["strategy", "cold best", "warm best", "speedup", "bitwise"],
        );
        for strategy in
            [ReduceStrategy::Sequential, ReduceStrategy::Fma, ReduceStrategy::Pairwise]
        {
            let model = AccumModel {
                input: Precision::Bf16,
                work: Precision::F32,
                strategy,
                out: Precision::Bf16,
            };
            let ft = FtGemm::new(
                GemmEngine::new(model),
                Box::new(VabftThreshold::default()),
                VerifyPolicy::default(),
            );
            let prepared = ft.prepare(&b);

            let mut cold = None;
            let t_cold = best_of(reps, || {
                time_once(|| cold = Some(ft.multiply(&a, &b).unwrap()))
            });
            let mut warm = None;
            let t_warm = best_of(reps, || {
                time_once(|| warm = Some(ft.multiply_prepared(&a, &prepared, None).unwrap()))
            });
            let (cold, warm) = (cold.unwrap(), warm.unwrap());

            // Identity gate: outputs bitwise-equal, decisions identical.
            assert_eq!(
                cold.c.data(),
                warm.c.data(),
                "warm output diverged from cold at {s}² [{}]",
                strategy.name()
            );
            assert_eq!(cold.report.verdict, warm.report.verdict);
            assert_eq!(cold.report.verdict, Verdict::Clean, "clean data must verify clean");
            assert_eq!(cold.report.detections.len(), warm.report.detections.len());

            // Decision parity under an injected upset (detect + localize).
            let inject = |o: &mut vabft::gemm::GemmOutput| {
                let v = o.acc.get(3, 7);
                o.acc.set(3, 7, v + 8.0);
                o.c.set(3, 7, Precision::Bf16.quantize(v + 8.0));
            };
            let cold_f = ft.multiply_with_injection(&a, &b, inject).unwrap();
            let inj: &dyn Fn(usize, &mut vabft::gemm::GemmOutput) = &|_, o| inject(o);
            let warm_f = ft.multiply_prepared(&a, &prepared, Some(inj)).unwrap();
            assert_eq!(cold_f.report.verdict, warm_f.report.verdict);
            assert_eq!(cold_f.report.detections.len(), warm_f.report.detections.len());
            assert_eq!(cold_f.report.detections[0].row, warm_f.report.detections[0].row);
            assert_eq!(cold_f.report.detections[0].col, warm_f.report.detections[0].col);
            assert_eq!(cold_f.c.data(), warm_f.c.data());

            let speedup = t_cold.as_secs_f64() / t_warm.as_secs_f64();
            // Acceptance bar: warm must beat cold at ≥512² weights.
            if s >= 512 {
                assert!(
                    speedup > 1.0,
                    "prepared path not faster at {s}² [{}]: {speedup:.2}x",
                    strategy.name()
                );
            }
            table.row(vec![
                strategy.name().into(),
                format!("{t_cold:?}"),
                format!("{t_warm:?}"),
                format!("{speedup:.2}x"),
                "OK".into(),
            ]);
        }
        table.print();
    }
    println!(
        "prepared_vs_cold: warm path bitwise-identical (outputs + decisions) and faster at ≥512²"
    );
}
