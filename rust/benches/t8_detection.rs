//! Paper Table 8: V-ABFT detection rate by exponent-bit position, BF16,
//! matrix size (128, 1024, 256), four distributions.

use vabft::bench_harness::BenchMode;
use vabft::inject::{Campaign, CampaignConfig};
use vabft::report::{pct, Table};
use vabft::rng::Distribution;
use vabft::threshold::VabftThreshold;

fn main() {
    let mode = BenchMode::from_env();
    mode.banner("t8_detection");
    let trials = mode.pick(128, 2048);
    let shape = mode.pick((64, 512, 128), (128, 1024, 256));

    let dists = Distribution::paper_suite();
    let mut results = Vec::new();
    let mut fp_total = 0usize;
    let mut rows_total = 0usize;
    for (name, d) in &dists {
        let mut cfg = CampaignConfig::table8(d.clone(), trials);
        cfg.shape = shape;
        let res = Campaign::new(cfg).run(&VabftThreshold::default());
        fp_total += res.false_positives;
        rows_total += res.clean_rows_checked;
        results.push((*name, res));
    }

    let mut t = Table::new(
        &format!("Table 8 — V-ABFT Detection Rate (%) for BF16, shape {shape:?}"),
        &["Bit", "N(1e-6,1)", "N(1,1)", "U(-1,1)", "TruncN"],
    );
    let bits: Vec<u32> = results[0].1.bits.iter().map(|b| b.bit).collect();
    for (i, bit) in bits.iter().enumerate() {
        let label = if *bit == 7 { "7 (exp LSB)".to_string() } else { bit.to_string() };
        let mut row = vec![label];
        for (_, res) in &results {
            row.push(pct(res.bits[i].detection_rate()));
        }
        t.row(row);
    }
    t.print();

    // Amplifying (0→1) flips only: the catastrophic direction. 1→0 flips
    // on unit-scale operands shrink one contribution toward zero — an
    // error smaller than the GEMM's own rounding envelope for low bits,
    // sub-threshold for ANY zero-FPR method (see EXPERIMENTS.md notes).
    let mut t01 = Table::new(
        "Table 8b — DR (%) for amplifying (0→1) exponent flips only",
        &["Bit", "N(1e-6,1)", "N(1,1)", "U(-1,1)", "TruncN"],
    );
    for (i, bit) in bits.iter().enumerate() {
        let mut row = vec![bit.to_string()];
        for (_, res) in &results {
            let b = &res.bits[i];
            row.push(if b.trials_0to1 > 0 {
                pct(100.0 * b.detected_0to1 as f64 / b.trials_0to1 as f64)
            } else {
                "-".to_string()
            });
        }
        t01.row(row);
    }
    t01.print();
    println!("clean rows checked {rows_total}, false positives {fp_total} (paper: 0)");
    println!("Paper Table 8: bits 11-14 all 100%; bit 10 >99.8%; bit 9 73-100%;");
    println!("  bit 8 36-70%; bit 7 0-20% (small magnitude changes, expected).");

    // localization detail
    let mut t2 = Table::new(
        "Localization rate (%) among detected (not in paper; diagnostic)",
        &["Bit", "N(1e-6,1)", "N(1,1)", "U(-1,1)", "TruncN"],
    );
    for (i, bit) in bits.iter().enumerate() {
        let mut row = vec![bit.to_string()];
        for (_, res) in &results {
            let b = &res.bits[i];
            let loc = if b.detected > 0 {
                100.0 * b.localized as f64 / b.detected as f64
            } else {
                0.0
            };
            row.push(pct(loc));
        }
        t2.row(row);
    }
    t2.print();
}
