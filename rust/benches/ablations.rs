//! Ablation studies of V-ABFT's design choices (DESIGN.md §2 calls these
//! out; they are not paper tables but probe the decisions §3 argues for):
//!
//! 1. extrema-variance bound (Theorem 1) vs. the exact variance — how much
//!    tightness does the O(n) shortcut cost?
//! 2. confidence multiplier c_σ sweep — threshold scale vs FPR margin.
//! 3. block-wise (§5.2) vs monolithic thresholds — detection granularity
//!    gained by per-block verification.
//! 4. reduction-strategy ablation — the same operands under sequential /
//!    fma / pairwise schedules (why e_max must be per-platform).

use vabft::abft::{ChecksumEncoding, FtGemm, VerifyGranularity, VerifyPolicy};
use vabft::bench_harness::BenchMode;
use vabft::fp::Precision;
use vabft::gemm::{AccumModel, GemmEngine, ReduceStrategy};
use vabft::matrix::{Matrix, RowStats};
use vabft::report::{ratio, sci, Table};
use vabft::rng::{Distribution, Xoshiro256pp};
use vabft::threshold::{BSummary, Threshold, ThresholdContext, VabftThreshold};

fn main() {
    let mode = BenchMode::from_env();
    mode.banner("ablations");
    extrema_vs_exact_variance(&mode);
    c_sigma_sweep(&mode);
    blockwise_granularity(&mode);
    strategy_ablation(&mode);
}

/// 1. Extrema bound vs exact variance in the threshold formula.
fn extrema_vs_exact_variance(mode: &BenchMode) {
    let trials = mode.pick(3, 20);
    let mut t = Table::new(
        "Ablation 1 — extrema-variance bound vs exact variance (threshold ratio)",
        &["Distribution", "N", "T(extrema)/T(exact)", "still 0 FP?"],
    );
    let model = AccumModel::gpu_highprec(Precision::F32);
    let engine = GemmEngine::new(model);
    let ctx = ThresholdContext::offline(model);
    for (name, d) in Distribution::paper_suite() {
        for n in [128usize, 512] {
            let mut worst_ratio = 0.0f64;
            let mut fp = 0usize;
            for trial in 0..trials {
                let mut rng = Xoshiro256pp::from_stream(0xAB1, (n + trial) as u64);
                let a = Matrix::sample_in(16, n, &d, model.input, &mut rng);
                let b = Matrix::sample_in(n, n, &d, model.input, &mut rng);
                let vab = VabftThreshold::default();
                let t_extrema = vab.thresholds(&a, &b, &ctx);
                // exact-variance variant: recompute with true σ² via a
                // BSummary substituted from RowStats::of
                let mut bsum = BSummary::of(&b);
                bsum.sum_sigma_sq =
                    (0..n).map(|r| RowStats::of(b.row(r)).variance).sum();
                let emax = vab.effective_emax(&ctx, n);
                let enc = ChecksumEncoding::encode_b(&b, &engine);
                let gout = engine.matmul_mixed(&a, &enc.b_encoded, enc.wide_cols());
                let (c, cr1, _) = enc.split_product(&gout.c);
                for i in 0..16 {
                    let s = RowStats::of(a.row(i));
                    let mut s_exact = s;
                    // exact σ for A's row too
                    s_exact.max = s.mean + s.variance.sqrt();
                    s_exact.min = s.mean - s.variance.sqrt();
                    let t_exact = vab.row_threshold(&s_exact, &bsum, emax);
                    worst_ratio = worst_ratio.max(t_extrema[i] / t_exact);
                    let e = (engine.reduce(c.row(i)) - cr1[i]).abs();
                    if e > t_exact {
                        fp += 1; // exact-variance threshold too tight?
                    }
                }
            }
            t.row(vec![
                name.to_string(),
                n.to_string(),
                format!("{worst_ratio:.1}x"),
                if fp == 0 { "yes".into() } else { format!("NO ({fp} FP)") },
            ]);
        }
    }
    t.print();
    println!("Theorem 1's bound costs a constant factor of threshold tightness but");
    println!("needs only max/min/mean; the exact-variance variant can false-positive");
    println!("on clustered data (the conservatism is load-bearing).\n");
}

/// 2. c_σ sweep: FPR margin vs threshold scale.
fn c_sigma_sweep(mode: &BenchMode) {
    let multiplies = mode.pick(60, 500);
    let mut t = Table::new(
        "Ablation 2 — confidence multiplier c_σ (FP32, 4 distributions pooled)",
        &["c_sigma", "max E/T observed", "false positives", "median threshold"],
    );
    let model = AccumModel::gpu_highprec(Precision::F32);
    let engine = GemmEngine::new(model);
    let ctx = ThresholdContext::offline(model);
    for c_sigma in [1.0, 1.5, 2.0, 2.5, 4.0] {
        let vab = VabftThreshold::with_c_sigma(c_sigma);
        let mut worst = 0.0f64;
        let mut fp = 0usize;
        let mut ths = Vec::new();
        let mut rng = Xoshiro256pp::seed_from_u64(0xC516);
        for i in 0..multiplies {
            let d = &Distribution::paper_suite()[i % 4].1;
            let a = Matrix::sample_in(8, 192, d, model.input, &mut rng);
            let b = Matrix::sample_in(192, 96, d, model.input, &mut rng);
            let th = vab.thresholds(&a, &b, &ctx);
            let enc = ChecksumEncoding::encode_b(&b, &engine);
            let gout = engine.matmul_mixed(&a, &enc.b_encoded, enc.wide_cols());
            let (c, cr1, _) = enc.split_product(&gout.c);
            for r in 0..8 {
                let e = (engine.reduce(c.row(r)) - cr1[r]).abs();
                if th[r] > 0.0 {
                    worst = worst.max(e / th[r]);
                }
                if e > th[r] {
                    fp += 1;
                }
                ths.push(th[r]);
            }
        }
        ths.sort_by(f64::total_cmp);
        t.row(vec![
            format!("{c_sigma}"),
            format!("{worst:.3}"),
            fp.to_string(),
            sci(ths[ths.len() / 2]),
        ]);
    }
    t.print();
    println!("The paper's c_σ = 2.5 leaves ~3-10x margin; c_σ = 1 already flirts with");
    println!("the observed maximum — the knob trades FPR risk for detection floor.\n");
}

/// 3. Block-wise (§5.2) detection granularity.
fn blockwise_granularity(mode: &BenchMode) {
    let (k, n) = (1024usize, 128usize);
    let model = AccumModel::wide(Precision::Bf16);
    let ctx = ThresholdContext::online(model);
    let vab = VabftThreshold::default();
    let mut rng = Xoshiro256pp::seed_from_u64(0xB10C);
    let d = Distribution::normal_1_1();
    let a = Matrix::sample_in(8, k, &d, model.input, &mut rng);
    let b = Matrix::sample_in(k, n, &d, model.input, &mut rng);
    let t_full = vab.thresholds(&a, &b, &ctx)[0];

    let mut t = Table::new(
        "Ablation 3 — block-wise ABFT (§5.2): per-block threshold vs block depth",
        &["block_k", "blocks", "per-block T (row 0)", "vs monolithic", "min detectable δ gain"],
    );
    for bk in [1024usize, 256, 64] {
        let a_blk = Matrix::from_fn(8, bk, |i, j| a.get(i, j));
        let b_blk = Matrix::from_fn(bk, n, |i, j| b.get(i, j));
        let t_blk = vab.thresholds(&a_blk, &b_blk, &ctx)[0];
        t.row(vec![
            bk.to_string(),
            (k / bk).to_string(),
            sci(t_blk),
            ratio(t_blk / t_full),
            ratio(t_full / t_blk),
        ]);
    }
    t.print();

    // functional check: a fault below the monolithic threshold is caught
    // by the 64-deep block pipeline
    let bw = FtGemm::new(
        GemmEngine::new(model),
        Box::new(VabftThreshold::default()),
        VerifyPolicy::default().with_granularity(VerifyGranularity::BlockK(64)),
    );
    let delta = t_full * 0.5;
    let out = bw
        .multiply_with_block_injection(&a, &b, |bi, o| {
            if bi == 3 {
                let v = o.acc.get(2, 7);
                o.acc.set(2, 7, v + delta);
            }
        })
        .unwrap();
    println!(
        "fault of δ = {} (0.5x the monolithic threshold): blockwise verdict {:?} in block {:?}\n",
        sci(delta),
        out.report.verdict,
        out.detection_blocks
    );
    let _ = mode;
}

/// 4. Reduction-strategy ablation on identical operands.
fn strategy_ablation(mode: &BenchMode) {
    let trials = mode.pick(4, 20);
    let mut t = Table::new(
        "Ablation 4 — verification error vs reduction strategy (FP32, K=N)",
        &["strategy", "N=256 max |E|/|cks|", "N=2048 max |E|/|cks|", "growth"],
    );
    for strategy in [ReduceStrategy::Sequential, ReduceStrategy::Fma, ReduceStrategy::Pairwise] {
        let model = AccumModel {
            input: Precision::F32,
            work: Precision::F32,
            strategy,
            out: Precision::F32,
        };
        let engine = GemmEngine::new(model);
        let mut rel = [0.0f64; 2];
        for (si, n) in [256usize, 2048].into_iter().enumerate() {
            for trial in 0..trials {
                let mut rng = Xoshiro256pp::from_stream(0x57A7, (n + trial) as u64);
                let d = Distribution::calibration();
                let a = Matrix::sample_in(8, n, &d, model.input, &mut rng);
                let b = Matrix::sample_in(n, n, &d, model.input, &mut rng);
                let enc = ChecksumEncoding::encode_b(&b, &engine);
                let gout = engine.matmul_mixed(&a, &enc.b_encoded, enc.wide_cols());
                let (c, cr1, _) = enc.split_product(&gout.c);
                for i in 0..8 {
                    let e = (engine.reduce(c.row(i)) - cr1[i]).abs();
                    rel[si] = rel[si].max(e / cr1[i].abs().max(1e-300));
                }
            }
        }
        t.row(vec![
            strategy.name().to_string(),
            sci(rel[0]),
            sci(rel[1]),
            format!("{:.1}x", rel[1] / rel[0]),
        ]);
    }
    t.print();
    println!("Per-step schedules grow ~sqrt(8x)=2.8x over an 8x size range; pairwise");
    println!("stays ~flat — the platform-dependence that e_max (§3.6) must encode.");
}
