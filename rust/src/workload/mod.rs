//! Serving workloads: deterministic request streams driven through the
//! [`crate::coordinator`].
//!
//! Real NN inference is a *mix* of differently-shaped layer GEMMs, not
//! one square multiply — the arithmetic-intensity spread across a
//! model's layers is exactly what makes scheduling interesting (skewed
//! per-request cost, weight reuse, bursty concurrency). This module
//! turns the published layer-shape profiles of
//! [`crate::experiments::real_model`] into replayable traces
//! ([`replay`]) so the sharded serving tier can be load-tested and
//! differential-tested against a workload with production structure,
//! while staying fully seeded and machine-independent.
//!
//! Two load regimes share the trace machinery: the closed-loop replay
//! ([`run_replay`]; fixed in-flight window, measures capacity) and the
//! open-loop traffic engine ([`arrivals`]; seeded arrival processes,
//! bounded-queue admission control, tail-latency SLOs).

pub mod arrivals;
pub mod replay;

pub use arrivals::{
    arrival_times, build_mixed_trace, build_schedule, run_open_loop, ArrivalModel,
    OpenLoopConfig, OpenLoopReport, ScheduledRequest,
};
pub use replay::{
    build_trace, replay_doc, run_replay, run_replay_planned, LayerTrace, ReplayConfig,
    ReplayReport, ReplayRow, TraceEntry,
};
