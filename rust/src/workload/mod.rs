//! Serving workloads: deterministic request streams driven through the
//! [`crate::coordinator`].
//!
//! Real NN inference is a *mix* of differently-shaped layer GEMMs, not
//! one square multiply — the arithmetic-intensity spread across a
//! model's layers is exactly what makes scheduling interesting (skewed
//! per-request cost, weight reuse, bursty concurrency). This module
//! turns the published layer-shape profiles of
//! [`crate::experiments::real_model`] into replayable traces
//! ([`replay`]) so the sharded serving tier can be load-tested and
//! differential-tested against a workload with production structure,
//! while staying fully seeded and machine-independent.

pub mod replay;

pub use replay::{
    build_trace, replay_doc, run_replay, LayerTrace, ReplayConfig, ReplayReport, ReplayRow,
    TraceEntry,
};
