//! Open-loop traffic engine: seeded arrival processes over a
//! mixed-family transformer trace.
//!
//! The closed-loop replay ([`super::run_replay`]) measures capacity: it
//! keeps a fixed number of requests in flight, so offered load always
//! equals service rate and queueing never builds. Serving SLOs live in
//! the opposite regime — requests arrive on *their own* clock, queues
//! grow when the service falls behind, and the interesting numbers are
//! the latency tail and the shed rate. [`run_open_loop`] drives exactly
//! that: a seeded [`ArrivalModel`] (Poisson, bursty, diurnal) schedules
//! request times against the wall clock, each arrival picks a GEMM from
//! a trace mixing several model families, and admission goes through the
//! non-blocking [`Coordinator::try_submit_prepared`] — a full shard
//! queue yields an explicit load-shed verdict, never a stalled arrival
//! loop.
//!
//! Everything except the clock is deterministic per seed: the arrival
//! schedule, the request mix and the fault plan are pure functions of
//! `(config, seed)` (pinned by [`build_schedule`]'s trace fingerprint),
//! and admitted requests produce bitwise-identical outputs at any shard
//! count, partition policy or steal setting. Timing enters only through
//! *which* requests get shed — so the determinism gates in
//! `tests/shard_equivalence.rs` run with queues deep enough that nothing
//! sheds, making the output fingerprint exact.

use std::sync::mpsc::Receiver;
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::abft::Verdict;
use crate::coordinator::{
    Admission, Coordinator, CoordinatorConfig, GemmResponse, InjectSpec, PreparedGemmRequest,
    WeightHandle,
};
use crate::matrix::Matrix;
use crate::rng::{fnv1a, Distribution, Rng, Xoshiro256pp, FNV1A_OFFSET};

use super::replay::{build_trace, fold_response, LayerTrace, ReplayConfig, ReplayReport, TraceEntry};

/// Stream tags separating the open-loop RNG streams (arrival clock,
/// request mix, fault plan, weights, activations) from each other and
/// from every other subsystem's streams.
const ARRIVAL_TAG: u64 = 0x0A12_71AF;
const MIX_TAG: u64 = 0x0A12_82B0;
const FAULT_TAG: u64 = 0x0A12_93C1;
const OL_WEIGHT_TAG: u64 = 0x0A12_A4D2;
const OL_ACT_TAG: u64 = 0x0A12_B5E3;

/// Seeded arrival process shaping the open-loop request clock.
///
/// All three are parameter-free beyond the offered `rate`: burst and
/// diurnal shape constants are fixed so that a schedule is a pure
/// function of `(model, rate, n, seed)`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ArrivalModel {
    /// Memoryless arrivals: i.i.d. exponential inter-arrival times at
    /// the offered rate.
    Poisson,
    /// MMPP-style two-state modulation: arrivals alternate between a
    /// calm state (rate/2) and a burst state (4×rate), toggling with
    /// probability 1/16 after each arrival (expected dwell ≈ 16
    /// arrivals). Offered load averages near the configured rate while
    /// producing the queue-filling bursts admission control exists for.
    Bursty,
    /// Diurnally modulated Poisson via thinning: the instantaneous rate
    /// follows `rate · (1 + 0.5·sin(2πt/T))` with three full cycles over
    /// the nominal schedule span — a compressed day/night load curve.
    Diurnal,
}

impl ArrivalModel {
    /// Stable lowercase label (CLI flag value and JSON `arrival` column).
    pub fn name(self) -> &'static str {
        match self {
            ArrivalModel::Poisson => "poisson",
            ArrivalModel::Bursty => "bursty",
            ArrivalModel::Diurnal => "diurnal",
        }
    }

    /// Parse a [`Self::name`] label.
    pub fn parse(s: &str) -> Option<ArrivalModel> {
        match s {
            "poisson" => Some(ArrivalModel::Poisson),
            "bursty" => Some(ArrivalModel::Bursty),
            "diurnal" => Some(ArrivalModel::Diurnal),
            _ => None,
        }
    }

    /// Every model, in a fixed order (bench/campaign sweeps).
    pub fn all() -> [ArrivalModel; 3] {
        [ArrivalModel::Poisson, ArrivalModel::Bursty, ArrivalModel::Diurnal]
    }
}

/// Exponential inter-arrival sample at `rate` (finite: `1-u` ∈ (0, 1]).
fn exp_sample(rng: &mut Xoshiro256pp, rate: f64) -> f64 {
    -(1.0 - rng.next_f64()).ln() / rate
}

/// Generate `n` arrival offsets (from the schedule start, nondecreasing)
/// for `model` at offered `rate` requests/second. Deterministic per
/// `(model, rate, n, seed)`; the RNG stream is disjoint from the
/// weight/activation/mix/fault streams.
pub fn arrival_times(model: ArrivalModel, rate: f64, n: usize, seed: u64) -> Vec<Duration> {
    assert!(rate > 0.0 && rate.is_finite(), "offered rate must be positive");
    let mut rng = Xoshiro256pp::from_stream(seed ^ ARRIVAL_TAG, model as u64);
    let mut t = 0.0f64;
    let mut out = Vec::with_capacity(n);
    match model {
        ArrivalModel::Poisson => {
            for _ in 0..n {
                t += exp_sample(&mut rng, rate);
                out.push(Duration::from_secs_f64(t));
            }
        }
        ArrivalModel::Bursty => {
            let mut burst = false;
            for _ in 0..n {
                let r = if burst { 4.0 * rate } else { 0.5 * rate };
                t += exp_sample(&mut rng, r);
                out.push(Duration::from_secs_f64(t));
                if rng.uniform_u64(16) == 0 {
                    burst = !burst;
                }
            }
        }
        ArrivalModel::Diurnal => {
            // Thinning against the peak rate 1.5·rate; three cycles over
            // the nominal span n/rate.
            let peak = 1.5 * rate;
            let period = (n as f64 / rate / 3.0).max(1e-3);
            while out.len() < n {
                t += exp_sample(&mut rng, peak);
                let inst = rate * (1.0 + 0.5 * (std::f64::consts::TAU * t / period).sin());
                if rng.next_f64() * peak < inst {
                    out.push(Duration::from_secs_f64(t));
                }
            }
        }
    }
    out
}

/// Open-loop workload configuration.
#[derive(Debug, Clone)]
pub struct OpenLoopConfig {
    /// Model families mixed into one trace (each expanded exactly like
    /// the closed-loop replay's [`build_trace`]).
    pub families: Vec<String>,
    /// Shape divisor shared by every family (see [`ReplayConfig::scale`]).
    pub scale: usize,
    /// Transformer layers per family trace.
    pub layers: usize,
    /// Activation rows per request.
    pub batch: usize,
    /// Requests offered (arrivals generated; admitted ≤ offered).
    pub requests: usize,
    /// Offered arrival rate, requests/second.
    pub rate: f64,
    /// Arrival process shaping the request clock.
    pub arrival: ArrivalModel,
    /// Master seed for the arrival/mix/fault/weight/activation streams.
    pub seed: u64,
    /// Inject a fault into every `fault_every`-th request (0 = clean
    /// trace). The plan alternates exponent-class output upsets
    /// (corrected in place) with small checksum perturbations — the
    /// unlocalizable, sub-quantization-noise class the severity policy
    /// waives instead of recomputing.
    pub fault_every: usize,
    /// Latency SLO: admitted responses at or under this budget count as
    /// hits ([`OpenLoopReport::slo_attainment`]). `None` disables.
    pub slo: Option<Duration>,
}

impl OpenLoopConfig {
    /// Tiny deterministic mixed-family configuration for CI smoke runs.
    pub fn smoke(seed: u64) -> OpenLoopConfig {
        OpenLoopConfig {
            families: vec!["llama-7b".to_string(), "gpt2".to_string(), "vit-b32".to_string()],
            scale: 32,
            layers: 1,
            batch: 4,
            requests: 60,
            rate: 300.0,
            arrival: ArrivalModel::Poisson,
            seed,
            fault_every: 0,
            slo: Some(Duration::from_millis(250)),
        }
    }
}

/// Concatenate one trace per family into a single mixed trace, re-basing
/// weight indices so every family keeps its own distinct tensors. The
/// combined label joins the family names with `+`.
pub fn build_mixed_trace(cfg: &OpenLoopConfig) -> LayerTrace {
    assert!(!cfg.families.is_empty(), "open loop needs at least one model family");
    let mut entries: Vec<TraceEntry> = Vec::new();
    let mut weights = Vec::new();
    for fam in &cfg.families {
        let sub = build_trace(&ReplayConfig {
            family: fam.clone(),
            scale: cfg.scale,
            layers: cfg.layers,
            batch: cfg.batch,
            passes: 1,
            concurrency: 1,
            seed: cfg.seed,
        });
        let base = weights.len();
        weights.extend(sub.weights.iter().cloned());
        entries.extend(
            sub.entries.iter().map(|e| TraceEntry { weight: e.weight + base, ..e.clone() }),
        );
    }
    LayerTrace { family: cfg.families.join("+"), entries, weights }
}

/// One scheduled open-loop request: when it arrives, which trace entry
/// it executes, and its planned fault (if any).
#[derive(Debug, Clone)]
pub struct ScheduledRequest {
    /// Arrival offset from the schedule start.
    pub at: Duration,
    /// Index into the mixed trace's entries.
    pub entry: usize,
    /// Planned injection for this request.
    pub inject: Option<InjectSpec>,
}

/// Expand the config into the full request schedule plus its
/// **trace fingerprint**: an order-sensitive FNV-1a hash over every
/// arrival time, entry choice and fault parameter. Two processes that
/// agree on `(config, seed)` agree on this fingerprint *before* running
/// anything — the pre-execution half of the open-loop determinism
/// contract (the post-execution half is the output fingerprint).
pub fn build_schedule(cfg: &OpenLoopConfig, trace: &LayerTrace) -> (Vec<ScheduledRequest>, u64) {
    let times = arrival_times(cfg.arrival, cfg.rate, cfg.requests, cfg.seed);
    let mut mix = Xoshiro256pp::from_stream(cfg.seed ^ MIX_TAG, 0);
    let mut fault = Xoshiro256pp::from_stream(cfg.seed ^ FAULT_TAG, 0);
    let mut fp = FNV1A_OFFSET;
    let mut schedule = Vec::with_capacity(times.len());
    for (i, at) in times.into_iter().enumerate() {
        let entry = mix.uniform_u64(trace.entries.len() as u64) as usize;
        let e = &trace.entries[entry];
        let mut fault_words = [0u64; 4];
        let inject = if cfg.fault_every > 0 && (i + 1) % cfg.fault_every == 0 {
            let row = fault.uniform_u64(e.m as u64) as usize;
            if fault.next_u64() & 1 == 0 {
                // FP32 exponent bit 1 on a data element: an
                // unmistakable upset, localized and corrected in place.
                let col = fault.uniform_u64(e.n as u64) as usize;
                fault_words = [1, row as u64, col as u64, 24];
                Some(InjectSpec::output(row, col, 24))
            } else {
                // Mid-mantissa flip on the row checksum: detected on the
                // verify grid, unlocalizable, and (usually) below
                // output-quantization noise — the waive-vs-recompute
                // decision point. Never touches output data bits.
                fault_words = [2, row as u64, 0, 16];
                Some(InjectSpec::checksum(row, 16))
            }
        } else {
            None
        };
        fp = fnv1a(fp, (i as u64).to_le_bytes());
        fp = fnv1a(fp, (at.as_nanos() as u64).to_le_bytes());
        fp = fnv1a(fp, (entry as u64).to_le_bytes());
        for w in fault_words {
            fp = fnv1a(fp, w.to_le_bytes());
        }
        schedule.push(ScheduledRequest { at, entry, inject });
    }
    (schedule, fp)
}

/// Outcome of one open-loop run.
#[derive(Debug, Clone)]
pub struct OpenLoopReport {
    /// The shared serving report (requests = admitted; `arrival` carries
    /// the model name, `shed` the refusals, `p50/p99/p999` the tail).
    pub replay: ReplayReport,
    /// Requests offered (admitted + shed).
    pub offered: usize,
    /// Configured offered rate, requests/second.
    pub rate: f64,
    /// Arrival process used.
    pub arrival: ArrivalModel,
    /// Pre-execution schedule fingerprint (see [`build_schedule`]).
    pub trace_fingerprint: u64,
    /// Order-sensitive hash over admitted responses' output bits only
    /// (no verdict tags): invariant between recovery policies that
    /// differ solely in *how* they repair — e.g. severity-aware vs
    /// always-recompute — as well as across scheduling knobs.
    pub output_fingerprint: u64,
    /// Detections across the run (coordinator counter).
    pub faults_detected: u64,
    /// In-place corrections across the run.
    pub faults_corrected: u64,
    /// Detections waived by the severity policy.
    pub faults_waived: u64,
    /// Rows recomputed across the run.
    pub rows_recomputed: u64,
    /// Latency SLO in force, if any.
    pub slo: Option<Duration>,
    /// Admitted responses with latency ≤ the SLO.
    pub slo_hits: usize,
}

impl OpenLoopReport {
    /// Fraction of admitted responses meeting the SLO (1.0 when no SLO
    /// was set or nothing was admitted).
    pub fn slo_attainment(&self) -> f64 {
        match self.slo {
            None => 1.0,
            Some(_) if self.replay.requests == 0 => 1.0,
            Some(_) => self.slo_hits as f64 / self.replay.requests as f64,
        }
    }
}

/// Drive the open-loop schedule through a coordinator started from
/// `ccfg`. Weights and activations are sampled and registered exactly
/// like the closed-loop replay (disjoint streams); then each scheduled
/// request is released at its arrival offset — sleeping against absolute
/// deadlines, so pacing never drifts — and admitted via the non-blocking
/// path. Shed requests are counted and dropped; admitted responses are
/// drained in submission order into the fingerprints, the verdict
/// counts and the SLO tally. Tail latencies come from the coordinator's
/// histogram, so they include queue wait.
pub fn run_open_loop(cfg: &OpenLoopConfig, ccfg: CoordinatorConfig) -> OpenLoopReport {
    let trace = build_mixed_trace(cfg);
    let model = ccfg.model;
    let coord = Coordinator::start(ccfg);

    let handles: Vec<WeightHandle> = trace
        .weights
        .iter()
        .enumerate()
        .map(|(i, (k, n, dist))| {
            let mut rng = Xoshiro256pp::from_stream(cfg.seed ^ OL_WEIGHT_TAG, i as u64);
            let b = Matrix::sample_in(*k, *n, dist, model.input, &mut rng);
            coord.register_weights(i as u32, &b)
        })
        .collect();
    let acts: Vec<Matrix> = trace
        .entries
        .iter()
        .enumerate()
        .map(|(i, e)| {
            let mut rng = Xoshiro256pp::from_stream(cfg.seed ^ OL_ACT_TAG, i as u64);
            let unit = Distribution::Normal { mean: 0.0, std: 1.0 };
            Matrix::sample_in(e.m, e.k, &unit, model.input, &mut rng)
        })
        .collect();

    let (schedule, trace_fingerprint) = build_schedule(cfg, &trace);

    let t0 = Instant::now();
    let mut admitted: Vec<(u64, usize, Receiver<GemmResponse>)> =
        Vec::with_capacity(schedule.len());
    for req in &schedule {
        if let Some(wait) = req.at.checked_sub(t0.elapsed()) {
            if !wait.is_zero() {
                std::thread::sleep(wait);
            }
        }
        let e = &trace.entries[req.entry];
        let prepared = PreparedGemmRequest {
            a: acts[req.entry].clone(),
            weights: Arc::clone(&handles[e.weight]),
            inject: req.inject.clone(),
        };
        match coord.try_submit_prepared(prepared) {
            Admission::Accepted(id, rx) => admitted.push((id, req.entry, rx)),
            Admission::Shed(_) => {} // counted by the coordinator
        }
    }

    let mut clean = 0usize;
    let mut faulty = 0usize;
    let mut flops = 0.0f64;
    let mut fingerprint = FNV1A_OFFSET;
    let mut output_fingerprint = FNV1A_OFFSET;
    let mut slo_hits = 0usize;
    let mut ord = 0u64;
    for (id, entry, rx) in &admitted {
        let resp = rx.recv().expect("open-loop worker died");
        assert_eq!(resp.id, *id, "open-loop response mis-routed");
        match &resp.result {
            Ok(out) if out.report.verdict == Verdict::Clean => clean += 1,
            _ => faulty += 1,
        }
        if let Some(slo) = cfg.slo {
            if resp.latency <= slo {
                slo_hits += 1;
            }
        }
        flops += trace.entries[*entry].flops;
        fingerprint = fold_response(fingerprint, &resp);
        // Output-only fold: admission order + bits, no verdict tag —
        // comparable across recovery policies.
        output_fingerprint = fnv1a(output_fingerprint, ord.to_le_bytes());
        if let Ok(out) = &resp.result {
            for &v in out.c.data() {
                output_fingerprint = fnv1a(output_fingerprint, v.to_bits().to_le_bytes());
            }
        }
        ord += 1;
    }
    let elapsed = t0.elapsed();

    let m = coord.metrics();
    let shed = m.jobs_shed.get();
    let tail = m.tail.snapshot();
    let snap = m.snapshot();
    let shards = coord.shards();
    let stolen = snap.jobs_stolen;
    coord.shutdown();
    assert_eq!(
        admitted.len() as u64 + shed,
        cfg.requests as u64,
        "every offered request must be admitted or shed"
    );

    OpenLoopReport {
        replay: ReplayReport {
            family: trace.family,
            requests: admitted.len(),
            weights: handles.len(),
            flops,
            elapsed,
            clean,
            faulty,
            fingerprint,
            shards,
            stolen,
            arrival: cfg.arrival.name().to_string(),
            shed,
            p50: tail.p50(),
            p99: tail.p99(),
            p999: tail.p999(),
        },
        offered: cfg.requests,
        rate: cfg.rate,
        arrival: cfg.arrival,
        trace_fingerprint,
        output_fingerprint,
        faults_detected: snap.faults_detected,
        faults_corrected: snap.faults_corrected,
        faults_waived: snap.faults_waived,
        rows_recomputed: snap.rows_recomputed,
        slo: cfg.slo,
        slo_hits,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arrival_times_are_seeded_monotone_and_rate_shaped() {
        for model in ArrivalModel::all() {
            let a = arrival_times(model, 1000.0, 512, 42);
            let b = arrival_times(model, 1000.0, 512, 42);
            assert_eq!(a, b, "{}: same seed must give the same clock", model.name());
            let c = arrival_times(model, 1000.0, 512, 43);
            assert_ne!(a, c, "{}: different seeds must differ", model.name());
            assert_eq!(a.len(), 512);
            assert!(a.windows(2).all(|w| w[0] <= w[1]), "{}: non-monotone", model.name());
            // Mean inter-arrival within a loose band of the offered rate
            // (bursty trades ±; diurnal thins against 1.5× peak).
            let mean = a.last().unwrap().as_secs_f64() / 512.0;
            assert!(
                (0.25e-3..4.0e-3).contains(&mean),
                "{}: mean inter-arrival {mean} out of band",
                model.name()
            );
        }
        // Models shape time differently from the same seed.
        let p = arrival_times(ArrivalModel::Poisson, 500.0, 64, 7);
        let m = arrival_times(ArrivalModel::Bursty, 500.0, 64, 7);
        assert_ne!(p, m);
    }

    #[test]
    fn mixed_trace_rebases_weights_per_family() {
        let cfg = OpenLoopConfig::smoke(9);
        let mixed = build_mixed_trace(&cfg);
        assert_eq!(mixed.family, "llama-7b+gpt2+vit-b32");
        let per_family: usize = cfg
            .families
            .iter()
            .map(|f| build_trace(&ReplayConfig::smoke(f, 9)).entries.len())
            .sum();
        assert_eq!(mixed.entries.len(), per_family);
        assert_eq!(mixed.entries.len(), mixed.weights.len());
        for e in &mixed.entries {
            let (k, n, _) = &mixed.weights[e.weight];
            assert_eq!((e.k, e.n), (*k, *n), "weight re-basing broke shape linkage");
        }
        // Every distinct weight is referenced exactly once (one entry
        // per tensor per pass, as in the per-family traces).
        let mut seen = vec![false; mixed.weights.len()];
        for e in &mixed.entries {
            assert!(!seen[e.weight]);
            seen[e.weight] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn schedule_and_trace_fingerprint_are_pure_functions_of_seed() {
        let mut cfg = OpenLoopConfig::smoke(21);
        cfg.fault_every = 5;
        let trace = build_mixed_trace(&cfg);
        let (s1, f1) = build_schedule(&cfg, &trace);
        let (s2, f2) = build_schedule(&cfg, &trace);
        assert_eq!(f1, f2, "schedule fingerprint must be deterministic");
        assert_eq!(s1.len(), cfg.requests);
        assert_eq!(
            s1.iter().map(|r| (r.at, r.entry)).collect::<Vec<_>>(),
            s2.iter().map(|r| (r.at, r.entry)).collect::<Vec<_>>()
        );
        assert_eq!(
            s1.iter().filter(|r| r.inject.is_some()).count(),
            cfg.requests / cfg.fault_every,
            "fault cadence must hit exactly every fault_every-th request"
        );
        let mut other = cfg.clone();
        other.seed = 22;
        let (_, f3) = build_schedule(&other, &build_mixed_trace(&other));
        assert_ne!(f1, f3, "different seeds must not collide");
        // The fault plan is part of the fingerprint.
        let mut clean = cfg.clone();
        clean.fault_every = 0;
        let (_, f4) = build_schedule(&clean, &trace);
        assert_ne!(f1, f4);
    }

    #[test]
    fn open_loop_smoke_is_clean_and_accounts_every_request() {
        let mut cfg = OpenLoopConfig::smoke(33);
        cfg.families = vec!["gpt2".to_string()];
        cfg.requests = 24;
        let r = run_open_loop(
            &cfg,
            CoordinatorConfig {
                workers: 2,
                // Deeper than the offered count: zero shed by construction.
                queue_depth: cfg.requests,
                ..Default::default()
            },
        );
        assert_eq!(r.offered, 24);
        assert_eq!(r.replay.shed, 0, "queue_depth ≥ offered must never shed");
        assert_eq!(r.replay.requests, 24);
        assert_eq!(r.replay.clean, 24);
        assert_eq!(r.replay.faulty, 0);
        assert_eq!(r.faults_detected, 0);
        assert_eq!(r.replay.shed_rate(), 0.0);
        assert!(r.replay.p50 <= r.replay.p99 && r.replay.p99 <= r.replay.p999);
        assert!(r.slo_attainment() >= 0.0 && r.slo_attainment() <= 1.0);
        // Same seed reruns agree on both fingerprints even at different
        // worker counts (scheduling is pure).
        let r2 = run_open_loop(
            &cfg,
            CoordinatorConfig { workers: 1, queue_depth: cfg.requests, ..Default::default() },
        );
        assert_eq!(r.trace_fingerprint, r2.trace_fingerprint);
        assert_eq!(r.replay.fingerprint, r2.replay.fingerprint);
        assert_eq!(r.output_fingerprint, r2.output_fingerprint);
    }

    #[test]
    fn severity_policy_waives_but_never_downgrades_detection() {
        // The serving-level severity gate: identical faulted schedule
        // under always-recompute vs severity-aware recovery. Detection
        // counts and output bits must match exactly; the severity run
        // converts (some) recomputes into waivers, never into misses.
        let mut cfg = OpenLoopConfig::smoke(55);
        cfg.families = vec!["gpt2".to_string()];
        cfg.requests = 30;
        cfg.fault_every = 3;
        let run = |severity: bool| {
            let policy = if severity {
                crate::abft::VerifyPolicy::default().with_severity()
            } else {
                crate::abft::VerifyPolicy::default()
            };
            run_open_loop(
                &cfg,
                CoordinatorConfig {
                    workers: 2,
                    queue_depth: cfg.requests,
                    policy,
                    ..Default::default()
                },
            )
        };
        let strict = run(false);
        let lenient = run(true);
        assert_eq!(strict.replay.shed, 0);
        assert_eq!(lenient.replay.shed, 0);
        assert!(strict.faults_detected > 0, "faulted schedule produced no detections");
        assert_eq!(
            lenient.faults_detected, strict.faults_detected,
            "severity policy must not downgrade detection"
        );
        assert_eq!(lenient.faults_corrected, strict.faults_corrected);
        assert_eq!(strict.faults_waived, 0);
        assert_eq!(
            lenient.faults_waived + lenient.rows_recomputed,
            strict.rows_recomputed,
            "every strict recompute must become a waiver or stay a recompute"
        );
        assert_eq!(
            lenient.output_fingerprint, strict.output_fingerprint,
            "severity classification must never alter any computed output's bits"
        );
        assert_eq!(lenient.trace_fingerprint, strict.trace_fingerprint);
    }

    #[test]
    fn shallow_queues_shed_instead_of_blocking() {
        let mut cfg = OpenLoopConfig::smoke(77);
        cfg.families = vec!["gpt2".to_string()];
        cfg.requests = 40;
        cfg.rate = 50_000.0; // far beyond service capacity
        let r = run_open_loop(
            &cfg,
            CoordinatorConfig { workers: 1, queue_depth: 1, ..Default::default() },
        );
        assert!(r.replay.shed > 0, "overload against depth-1 queues must shed");
        assert_eq!(r.replay.requests as u64 + r.replay.shed, r.offered as u64);
        assert!(r.replay.shed_rate() > 0.0 && r.replay.shed_rate() <= 1.0);
        // Admitted work still verifies clean — shedding never corrupts.
        assert_eq!(r.replay.faulty, 0);
        assert_eq!(r.replay.clean, r.replay.requests);
    }
}
