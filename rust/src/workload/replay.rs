//! Transformer-layer replay: a deterministic serving workload.
//!
//! [`build_trace`] expands a model family's published layer shapes
//! ([`crate::experiments::real_model::model_weight_profiles`]) into a
//! forward-pass-ordered list of GEMM requests over distinct weight
//! tensors; [`run_replay`] registers every weight once (the
//! weight-stationary path) and replays the trace through
//! [`Coordinator::submit_batch_prepared`] at a configurable concurrency,
//! one batch in flight ahead of the drain.
//!
//! Everything is seeded: weights and activations come from fixed RNG
//! streams, and the report carries an order-sensitive FNV-1a
//! **fingerprint** over every response's output bits and verdict. Two
//! runs with the same `(config, seed)` — at any shard count, partition
//! policy, steal setting or worker count — must produce the same
//! fingerprint; `tests/shard_equivalence.rs` and the `serve-replay
//! --smoke` CI step pin exactly that.

use std::sync::mpsc::Receiver;
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::abft::Verdict;
use crate::bench_harness::{JsonDoc, JsonValue, SERVING_SCHEMA};
use crate::coordinator::{
    Coordinator, CoordinatorConfig, GemmResponse, PreparedGemmRequest, WeightHandle,
};
use crate::matrix::Matrix;
use crate::rng::{fnv1a, Distribution, Xoshiro256pp, FNV1A_OFFSET};

/// Stream tags separating the replay's RNG streams (weights vs
/// activations) from each other and from other subsystems' streams.
const WEIGHT_TAG: u64 = 0x5E2F_11AD;
const ACT_TAG: u64 = 0x5E2F_22BE;

/// Replay workload configuration.
#[derive(Debug, Clone)]
pub struct ReplayConfig {
    /// Model family (`"llama-7b"`, `"gpt2"`, `"vit-b32"`).
    pub family: String,
    /// Shape divisor (1 = published sizes; larger = scaled down).
    pub scale: usize,
    /// Transformer layers replayed per pass.
    pub layers: usize,
    /// Activation rows per request (the GEMM's M — the serving batch).
    pub batch: usize,
    /// Forward passes replayed over the trace.
    pub passes: usize,
    /// Requests per in-flight batch (`submit_batch_prepared` size; one
    /// batch is submitted ahead of the drain, so up to 2× this many
    /// requests are outstanding).
    pub concurrency: usize,
    /// Master seed for weights and activations.
    pub seed: u64,
}

impl ReplayConfig {
    /// Tiny deterministic configuration for CI smoke runs (sub-second).
    pub fn smoke(family: &str, seed: u64) -> ReplayConfig {
        ReplayConfig {
            family: family.to_string(),
            scale: 32,
            layers: 1,
            batch: 4,
            passes: 2,
            concurrency: 4,
            seed,
        }
    }

    /// Bench-quick configuration (seconds).
    pub fn quick(family: &str, seed: u64) -> ReplayConfig {
        ReplayConfig {
            family: family.to_string(),
            scale: 16,
            layers: 2,
            batch: 8,
            passes: 4,
            concurrency: 8,
            seed,
        }
    }
}

/// One request of a replay trace.
#[derive(Debug, Clone)]
pub struct TraceEntry {
    /// Layer name from the weight profile (`"wq/wk/wv/wo"`, …).
    pub name: &'static str,
    /// Index into the trace's distinct weights.
    pub weight: usize,
    /// GEMM shape (m, k, n) of the request.
    pub m: usize,
    /// GEMM reduction depth.
    pub k: usize,
    /// GEMM output columns.
    pub n: usize,
    /// FLOPs of this request, per
    /// [`crate::experiments::WeightProfile::gemm_flops`] — the single
    /// source of the FLOP-counting convention.
    pub flops: f64,
}

/// A forward-pass-ordered trace over distinct weight tensors.
#[derive(Debug, Clone)]
pub struct LayerTrace {
    /// Model family the trace was built from.
    pub family: String,
    /// One entry per GEMM of one forward pass, in layer order.
    pub entries: Vec<TraceEntry>,
    /// Distinct weight tensors: `(k, n, element distribution)` — one per
    /// (layer, profile, instance).
    pub weights: Vec<(usize, usize, Distribution)>,
}

impl LayerTrace {
    /// Total FLOPs of one pass over the trace.
    pub fn pass_flops(&self) -> f64 {
        self.entries.iter().map(|e| e.flops).sum()
    }
}

/// Expand `family`'s layer profiles into a replayable trace: every
/// (layer, profile, instance) becomes one distinct weight tensor and one
/// trace entry per forward pass, in layer order.
///
/// The pseudo-family `"mixed"` concatenates the three published
/// families (`llama-7b`, `gpt2`, `vit-b32`) back to back with weight
/// indices re-based — the heterogeneous trace the protection planner is
/// benchmarked on, mixing attention/MLP shapes across very different
/// arithmetic intensities.
pub fn build_trace(cfg: &ReplayConfig) -> LayerTrace {
    if cfg.family == "mixed" {
        let mut entries = Vec::new();
        let mut weights = Vec::new();
        for fam in ["llama-7b", "gpt2", "vit-b32"] {
            let sub = build_trace(&ReplayConfig { family: fam.to_string(), ..cfg.clone() });
            let base = weights.len();
            weights.extend(sub.weights.iter().cloned());
            entries.extend(
                sub.entries.iter().map(|e| TraceEntry { weight: e.weight + base, ..e.clone() }),
            );
        }
        return LayerTrace { family: "mixed".to_string(), entries, weights };
    }
    let profiles = crate::experiments::model_weight_profiles(&cfg.family, cfg.scale.max(1));
    let mut entries = Vec::new();
    let mut weights = Vec::new();
    for _layer in 0..cfg.layers.max(1) {
        for p in &profiles {
            for _instance in 0..p.count {
                let widx = weights.len();
                weights.push((
                    p.rows,
                    p.cols,
                    Distribution::Normal { mean: p.mean, std: p.std },
                ));
                entries.push(TraceEntry {
                    name: p.name,
                    weight: widx,
                    m: cfg.batch.max(1),
                    k: p.rows,
                    n: p.cols,
                    flops: p.gemm_flops(cfg.batch.max(1)),
                });
            }
        }
    }
    LayerTrace { family: cfg.family.clone(), entries, weights }
}

/// Outcome of one replay run.
#[derive(Debug, Clone)]
pub struct ReplayReport {
    /// Model family replayed.
    pub family: String,
    /// Requests completed (entries × passes).
    pub requests: usize,
    /// Distinct weight tensors registered.
    pub weights: usize,
    /// Total FLOPs executed.
    pub flops: f64,
    /// Wall-clock time of the replay (excluding weight registration).
    pub elapsed: Duration,
    /// Requests completed that verified clean.
    pub clean: usize,
    /// Requests with any non-clean verdict (should be zero on a clean
    /// replay).
    pub faulty: usize,
    /// Order-sensitive FNV-1a fingerprint over every response's output
    /// bits and verdict, in submission order — the differential-test
    /// contract: invariant across shards × partition × steal × workers.
    pub fingerprint: u64,
    /// Shards the coordinator ran.
    pub shards: usize,
    /// Jobs executed by a non-home shard (work stealing).
    pub stolen: u64,
    /// Arrival process label: `"closed-loop"` for [`run_replay`], the
    /// [`crate::workload::ArrivalModel`] name for open-loop runs.
    pub arrival: String,
    /// Requests refused by admission control (always 0 for the
    /// closed-loop replay, whose bounded queues block instead of
    /// shedding).
    pub shed: u64,
    /// Median end-to-end request latency (submit → response), from the
    /// coordinator's [`crate::metrics::TailHistogram`].
    pub p50: Duration,
    /// 99th-percentile end-to-end request latency.
    pub p99: Duration,
    /// 99.9th-percentile end-to-end request latency.
    pub p999: Duration,
}

impl ReplayReport {
    /// Requests per second.
    pub fn rps(&self) -> f64 {
        self.requests as f64 / self.elapsed.as_secs_f64().max(1e-9)
    }

    /// Aggregate GFLOP/s across the replay.
    pub fn gflops(&self) -> f64 {
        self.flops / 1e9 / self.elapsed.as_secs_f64().max(1e-9)
    }

    /// Fraction of offered requests refused by admission control
    /// (`shed / (completed + shed)`; 0.0 when nothing was offered).
    pub fn shed_rate(&self) -> f64 {
        let offered = self.requests as u64 + self.shed;
        if offered == 0 {
            0.0
        } else {
            self.shed as f64 / offered as f64
        }
    }
}

/// Fold one response into the fingerprint state (little-endian byte
/// order; the shared [`crate::rng::fnv1a`] hash). Shared with the
/// open-loop engine so closed- and open-loop fingerprints follow one
/// rule.
pub(crate) fn fold_response(h: u64, resp: &GemmResponse) -> u64 {
    let mut h = fnv1a(h, resp.id.to_le_bytes());
    match &resp.result {
        Err(_) => h = fnv1a(h, u64::MAX.to_le_bytes()),
        Ok(out) => {
            let tag: u64 = match out.report.verdict {
                Verdict::Clean => 0,
                Verdict::Corrected => 1,
                Verdict::Recomputed => 2,
                Verdict::Flagged => 3,
                Verdict::Waived => 4,
                Verdict::CorrectedGrid => 5,
            };
            h = fnv1a(h, tag.to_le_bytes());
            for &v in out.c.data() {
                h = fnv1a(h, v.to_bits().to_le_bytes());
            }
        }
    }
    h
}

/// Replay `cfg` through a coordinator started from `ccfg`. Weights are
/// sampled and registered once (streams keyed off `cfg.seed`), then the
/// trace is replayed `cfg.passes` times in `cfg.concurrency`-sized
/// prepared batches, one batch submitted ahead of the drain. Responses
/// are folded into the fingerprint in submission order.
///
/// The coordinator's accumulation model decides the operand grid; the
/// caller owns `ccfg` entirely (shards, partition, steal, workers,
/// engine parallelism) — none of it can change the fingerprint.
pub fn run_replay(cfg: &ReplayConfig, ccfg: CoordinatorConfig) -> ReplayReport {
    run_replay_planned(cfg, ccfg, None)
}

/// [`run_replay`] with an optional per-weight [`ProtectionPlan`]: weights
/// with a plan entry are registered through
/// [`Coordinator::register_weights_planned`] so the planner-chosen
/// scheme rides the handle and drives worker-side verification;
/// unplanned weights (and `plan = None`) take the uniform staged-ABFT
/// path. Invariant #9: a plan built from schedule-neutral schemes must
/// leave the fingerprint bitwise-identical to the uniform run.
pub fn run_replay_planned(
    cfg: &ReplayConfig,
    ccfg: CoordinatorConfig,
    plan: Option<&crate::planner::ProtectionPlan>,
) -> ReplayReport {
    let trace = build_trace(cfg);
    let model = ccfg.model;
    let coord = Coordinator::start(ccfg);

    // Register every distinct weight once; keep the handles (requests go
    // through the id-free prepared path, like a production router).
    let handles: Vec<WeightHandle> = trace
        .weights
        .iter()
        .enumerate()
        .map(|(i, (k, n, dist))| {
            let mut rng = Xoshiro256pp::from_stream(cfg.seed ^ WEIGHT_TAG, i as u64);
            let b = Matrix::sample_in(*k, *n, dist, model.input, &mut rng);
            match plan.and_then(|p| p.entry_for(i)) {
                Some(entry) => coord.register_weights_planned(i as u32, &b, entry),
                None => coord.register_weights(i as u32, &b),
            }
        })
        .collect();

    // Pre-sample one activation per trace entry (unit-normal
    // post-layernorm statistics), reused across passes — sampling cost
    // stays out of the timed replay.
    let acts: Vec<Matrix> = trace
        .entries
        .iter()
        .enumerate()
        .map(|(i, e)| {
            let mut rng = Xoshiro256pp::from_stream(cfg.seed ^ ACT_TAG, i as u64);
            let unit = Distribution::Normal { mean: 0.0, std: 1.0 };
            Matrix::sample_in(e.m, e.k, &unit, model.input, &mut rng)
        })
        .collect();

    let total = trace.entries.len() * cfg.passes.max(1);
    let mut clean = 0usize;
    let mut faulty = 0usize;
    let mut fingerprint = FNV1A_OFFSET;
    let mut drain = |pending: Vec<(u64, Receiver<GemmResponse>)>| {
        for (id, rx) in pending {
            let resp = rx.recv().expect("replay worker died");
            assert_eq!(resp.id, id, "replay response mis-routed");
            match &resp.result {
                Ok(out) if out.report.verdict == Verdict::Clean => clean += 1,
                _ => faulty += 1,
            }
            fingerprint = fold_response(fingerprint, &resp);
        }
    };

    let flops = trace.pass_flops() * cfg.passes.max(1) as f64;
    let t0 = Instant::now();
    let mut inflight: Option<Vec<(u64, Receiver<GemmResponse>)>> = None;
    let mut wave: Vec<PreparedGemmRequest> = Vec::with_capacity(cfg.concurrency.max(1));
    for _pass in 0..cfg.passes.max(1) {
        for (i, e) in trace.entries.iter().enumerate() {
            wave.push(PreparedGemmRequest {
                a: acts[i].clone(),
                weights: Arc::clone(&handles[e.weight]),
                inject: None,
            });
            if wave.len() >= cfg.concurrency.max(1) {
                let pending = coord.submit_batch_prepared(std::mem::take(&mut wave));
                if let Some(prev) = inflight.take() {
                    drain(prev);
                }
                inflight = Some(pending);
            }
        }
    }
    if !wave.is_empty() {
        let pending = coord.submit_batch_prepared(std::mem::take(&mut wave));
        if let Some(prev) = inflight.take() {
            drain(prev);
        }
        inflight = Some(pending);
    }
    if let Some(prev) = inflight.take() {
        drain(prev);
    }
    let elapsed = t0.elapsed();

    let shards = coord.shards();
    let stolen = coord.metrics().jobs_stolen.get();
    let shed = coord.metrics().jobs_shed.get();
    let tail = coord.metrics().tail.snapshot();
    coord.shutdown();
    ReplayReport {
        family: trace.family,
        requests: total,
        weights: handles.len(),
        flops,
        elapsed,
        clean,
        faulty,
        fingerprint,
        shards,
        stolen,
        arrival: "closed-loop".to_string(),
        shed,
        p50: tail.p50(),
        p99: tail.p99(),
        p999: tail.p999(),
    }
}

/// One row of the `BENCH_serving.json` document: a replay run under one
/// coordinator configuration.
#[derive(Debug, Clone)]
pub struct ReplayRow {
    /// The run's report.
    pub report: ReplayReport,
    /// Partition policy label (`"contiguous"` / `"interleaved"`).
    pub partition: String,
    /// Whether work stealing was enabled.
    pub steal: bool,
    /// Workers per shard.
    pub workers: usize,
    /// Batch concurrency of the replay.
    pub concurrency: usize,
    /// Throughput speedup vs the run's baseline row (1.0 for the
    /// baseline itself).
    pub speedup_vs_baseline: f64,
    /// Whether the fingerprint matched the baseline row's (the
    /// differential gate; always true for the baseline).
    pub fingerprint_equal: bool,
    /// Protection-plan label for the run (`"uniform"` for unplanned
    /// replays, `"auto"` for planner-driven ones) — the v3 A/B axis.
    pub plan: String,
}

impl ReplayRow {
    /// Assemble one ladder row: speedup and fingerprint equality are
    /// computed against `baseline` (`None` for the baseline rung
    /// itself). The one place the ladder-comparison rule lives — shared
    /// by the `serve-replay` CLI and `benches/serving_replay.rs`, so the
    /// two gates cannot drift.
    pub fn ladder(
        report: ReplayReport,
        baseline: Option<&ReplayRow>,
        partition: &str,
        steal: bool,
        workers: usize,
        concurrency: usize,
    ) -> ReplayRow {
        let (speedup_vs_baseline, fingerprint_equal) = match baseline {
            None => (1.0, true),
            Some(b) => (
                report.rps() / b.report.rps().max(1e-9),
                report.fingerprint == b.report.fingerprint,
            ),
        };
        ReplayRow {
            report,
            partition: partition.to_string(),
            steal,
            workers,
            concurrency,
            speedup_vs_baseline,
            fingerprint_equal,
            plan: "uniform".to_string(),
        }
    }

    /// Re-label the row's protection plan (ladder rows default to
    /// `"uniform"`).
    pub fn with_plan(mut self, plan: &str) -> ReplayRow {
        self.plan = plan.to_string();
        self
    }
}

/// Assemble the schema-versioned `vabft-serving/v3` document from replay
/// rows (shared by `benches/serving_replay.rs` and `vabft serve-replay
/// --json`). `mode` labels how the rows were produced (`"quick"` /
/// `"full"` for the bench per [`crate::bench_harness::BenchMode`],
/// `"smoke"` / `"custom"` for CLI runs) — the caller knows; this
/// function does not guess from the environment.
///
/// v2 added the open-loop columns over v1: `arrival` (arrival-process
/// label), tail latencies `p50_ms` / `p99_ms` / `p999_ms`, and
/// `shed_rate` (admission-control refusals / offered). Closed-loop rows
/// carry `arrival = "closed-loop"` and `shed_rate = 0`. v3 adds the
/// `plan` column (`"uniform"` / `"auto"`) for the planned-vs-uniform
/// A/B pair.
pub fn replay_doc(rows: &[ReplayRow], mode: &str) -> JsonDoc {
    let ms = |d: Duration| d.as_secs_f64() * 1e3;
    let mut doc = JsonDoc::new(SERVING_SCHEMA);
    doc.meta("bench", JsonValue::Str("serving_replay".to_string()));
    doc.meta("mode", JsonValue::Str(mode.to_string()));
    for r in rows {
        doc.entry(vec![
            ("family".to_string(), JsonValue::Str(r.report.family.clone())),
            ("plan".to_string(), JsonValue::Str(r.plan.clone())),
            ("arrival".to_string(), JsonValue::Str(r.report.arrival.clone())),
            ("shards".to_string(), JsonValue::Int(r.report.shards as i64)),
            ("partition".to_string(), JsonValue::Str(r.partition.clone())),
            ("steal".to_string(), JsonValue::Bool(r.steal)),
            ("workers".to_string(), JsonValue::Int(r.workers as i64)),
            ("concurrency".to_string(), JsonValue::Int(r.concurrency as i64)),
            ("requests".to_string(), JsonValue::Int(r.report.requests as i64)),
            ("rps".to_string(), JsonValue::Num(r.report.rps())),
            ("gflops".to_string(), JsonValue::Num(r.report.gflops())),
            ("p50_ms".to_string(), JsonValue::Num(ms(r.report.p50))),
            ("p99_ms".to_string(), JsonValue::Num(ms(r.report.p99))),
            ("p999_ms".to_string(), JsonValue::Num(ms(r.report.p999))),
            ("shed_rate".to_string(), JsonValue::Num(r.report.shed_rate())),
            ("speedup_vs_baseline".to_string(), JsonValue::Num(r.speedup_vs_baseline)),
            ("fingerprint_equal".to_string(), JsonValue::Bool(r.fingerprint_equal)),
        ]);
    }
    doc
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trace_shapes_follow_profiles() {
        let cfg = ReplayConfig::smoke("gpt2", 7);
        let t = build_trace(&cfg);
        assert_eq!(t.entries.len(), t.weights.len(), "one entry per weight per pass");
        assert!(!t.entries.is_empty());
        for e in &t.entries {
            let (k, n, _) = &t.weights[e.weight];
            assert_eq!((e.k, e.n), (*k, *n));
            assert_eq!(e.m, cfg.batch);
            assert_eq!(e.flops, 2.0 * e.m as f64 * e.k as f64 * e.n as f64);
        }
        assert!(t.pass_flops() > 0.0);
        // layers multiply the trace
        let two = build_trace(&ReplayConfig { layers: 2, ..cfg });
        assert_eq!(two.entries.len(), 2 * t.entries.len());
    }

    #[test]
    fn replay_is_clean_and_fingerprint_is_reproducible() {
        let cfg = ReplayConfig::smoke("gpt2", 11);
        let run = |workers: usize| {
            run_replay(
                &cfg,
                CoordinatorConfig { workers, queue_depth: 32, ..Default::default() },
            )
        };
        let a = run(1);
        assert_eq!(a.faulty, 0, "clean replay must verify clean everywhere");
        assert_eq!(a.requests, a.clean);
        assert_eq!(a.shed, 0, "closed-loop replay blocks; it never sheds");
        assert!(a.p50 <= a.p99 && a.p99 <= a.p999, "tail quantiles must be ordered");
        assert_eq!(a.weights, build_trace(&cfg).weights.len());
        let b = run(3);
        assert_eq!(a.fingerprint, b.fingerprint, "fingerprint depends on worker count");
        assert_eq!(a.requests, b.requests);
    }

    #[test]
    fn replay_doc_is_schema_valid() {
        let cfg = ReplayConfig::smoke("vit-b32", 3);
        let report =
            run_replay(&cfg, CoordinatorConfig { workers: 2, ..Default::default() });
        let base = ReplayRow::ladder(report, None, "contiguous", false, 2, cfg.concurrency);
        assert_eq!(base.speedup_vs_baseline, 1.0);
        assert!(base.fingerprint_equal);
        let rows = vec![base];
        let json = replay_doc(&rows, "quick").to_json();
        crate::bench_harness::validate_schema(&json, SERVING_SCHEMA).expect("schema");
        assert!(json.contains("\"family\": \"vit-b32\""));
        assert!(json.contains("\"mode\": \"quick\""));
        assert!(json.contains("\"fingerprint_equal\": true"));
        // v2 open-loop columns are present on closed-loop rows too.
        assert!(json.contains("\"arrival\": \"closed-loop\""));
        assert!(json.contains("\"p99_ms\""));
        assert!(json.contains("\"shed_rate\": 0"));
        // v3: every row carries its protection-plan label.
        assert!(json.contains("vabft-serving/v3"));
        assert!(json.contains("\"plan\": \"uniform\""));
    }

    #[test]
    fn mixed_trace_concatenates_families_with_rebased_weights() {
        let cfg = ReplayConfig::smoke("mixed", 5);
        let mixed = build_trace(&cfg);
        assert_eq!(mixed.family, "mixed");
        let mut entries = 0;
        let mut weights = 0;
        for fam in ["llama-7b", "gpt2", "vit-b32"] {
            let sub = build_trace(&ReplayConfig { family: fam.to_string(), ..cfg.clone() });
            entries += sub.entries.len();
            weights += sub.weights.len();
        }
        assert_eq!(mixed.entries.len(), entries);
        assert_eq!(mixed.weights.len(), weights);
        // Re-based weight indices stay consistent with the weight table.
        for e in &mixed.entries {
            let (k, n, _) = &mixed.weights[e.weight];
            assert_eq!((e.k, e.n), (*k, *n));
        }
        // The heterogeneous trace spans more than one reduction depth —
        // the property the planner's intensity split depends on.
        let mut ks: Vec<usize> = mixed.entries.iter().map(|e| e.k).collect();
        ks.sort_unstable();
        ks.dedup();
        assert!(ks.len() > 1, "mixed trace must mix shapes");
    }

    #[test]
    fn neutral_plan_replay_matches_uniform_bitwise() {
        use crate::planner::{PlanEntry, PlanMode, ProtectionPlan, ProtectionScheme};
        let cfg = ReplayConfig::smoke("gpt2", 13);
        let trace = build_trace(&cfg);
        // Cycle the schedule-neutral schemes across the trace's weights.
        let neutral =
            [ProtectionScheme::Full, ProtectionScheme::Fused, ProtectionScheme::Replicate];
        let entries: Vec<PlanEntry> = trace
            .weights
            .iter()
            .enumerate()
            .map(|(i, (k, n, _))| PlanEntry {
                weight: i,
                name: format!("w{i}"),
                m: cfg.batch,
                k: *k,
                n: *n,
                intensity: crate::planner::arithmetic_intensity(cfg.batch, *k, *n),
                scheme: neutral[i % neutral.len()],
                predicted_ns: 0.0,
            })
            .collect();
        let plan = ProtectionPlan { mode: PlanMode::Auto, entries };
        let ccfg = || CoordinatorConfig { workers: 2, ..Default::default() };
        let uniform = run_replay(&cfg, ccfg());
        let planned = run_replay_planned(&cfg, ccfg(), Some(&plan));
        assert_eq!(planned.faulty, 0, "planned clean replay must verify clean");
        assert_eq!(
            planned.fingerprint, uniform.fingerprint,
            "invariant #9: schedule-neutral plans cannot change output bits"
        );
    }
}
