//! Artifact manifest: the contract between `python/compile/aot.py` and the
//! Rust runtime.
//!
//! Format: `manifest.tsv`, one artifact per line,
//! `name \t file \t key=value \t key=value …` — trivially parseable without
//! a JSON dependency (serde is not in the offline registry); aot.py also
//! writes a human-oriented manifest.json with the same content.

use std::collections::HashMap;
use std::path::Path;

use crate::anyhow;
use crate::error::{Context, Result};

/// One artifact: a lowered HLO-text module plus its metadata
/// (shapes, dtypes, parameter layouts — whatever the producer recorded).
#[derive(Debug, Clone, Default)]
pub struct ArtifactEntry {
    /// Artifact name (the key [`Manifest::get`] resolves).
    pub name: String,
    /// HLO-text file, relative to the manifest's directory.
    pub file: String,
    /// Producer-recorded metadata (shapes, dtypes, parameter layout).
    pub meta: HashMap<String, String>,
}

impl ArtifactEntry {
    /// Typed metadata accessor.
    pub fn meta_parse<T: std::str::FromStr>(&self, key: &str) -> Option<T> {
        self.meta.get(key).and_then(|v| v.parse().ok())
    }

    /// Comma-separated usize list, e.g. `shape=128,256`.
    pub fn meta_dims(&self, key: &str) -> Option<Vec<usize>> {
        let v = self.meta.get(key)?;
        v.split(',').map(|s| s.trim().parse().ok()).collect()
    }
}

/// The parsed manifest.
#[derive(Debug, Clone, Default)]
pub struct Manifest {
    /// Every artifact, in file order.
    pub entries: Vec<ArtifactEntry>,
}

impl Manifest {
    /// Read and parse a `manifest.tsv`.
    pub fn load(path: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::parse(&text)
    }

    /// Parse manifest text (see the module docs for the format).
    pub fn parse(text: &str) -> Result<Manifest> {
        let mut entries = Vec::new();
        for (lineno, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let mut fields = line.split('\t');
            let name = fields
                .next()
                .ok_or_else(|| anyhow!("line {}: missing name", lineno + 1))?
                .to_string();
            let file = fields
                .next()
                .ok_or_else(|| anyhow!("line {}: missing file", lineno + 1))?
                .to_string();
            let mut meta = HashMap::new();
            for kv in fields {
                if let Some((k, v)) = kv.split_once('=') {
                    meta.insert(k.trim().to_string(), v.trim().to_string());
                }
            }
            entries.push(ArtifactEntry { name, file, meta });
        }
        Ok(Manifest { entries })
    }

    /// Look up an artifact by name.
    pub fn get(&self, name: &str) -> Option<&ArtifactEntry> {
        self.entries.iter().find(|e| e.name == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_full_manifest() {
        let text = "# comment\n\
                    ftgemm_bf16\tftgemm_bf16.hlo.txt\tm=8\tk=64\tn=32\tdtype=bf16\n\
                    \n\
                    train_step\ttrain_step.hlo.txt\tparams=5\tloss_index=5\n";
        let m = Manifest::parse(text).unwrap();
        assert_eq!(m.entries.len(), 2);
        let e = m.get("ftgemm_bf16").unwrap();
        assert_eq!(e.file, "ftgemm_bf16.hlo.txt");
        assert_eq!(e.meta_parse::<usize>("k"), Some(64));
        assert_eq!(e.meta.get("dtype").map(|s| s.as_str()), Some("bf16"));
        assert!(m.get("missing").is_none());
    }

    #[test]
    fn dims_helper() {
        let m = Manifest::parse("x\tx.hlo\tshape=128,256,8\n").unwrap();
        assert_eq!(m.entries[0].meta_dims("shape"), Some(vec![128, 256, 8]));
    }

    #[test]
    fn malformed_line_errors() {
        assert!(Manifest::parse("onlyname\n").is_err());
    }
}
