//! Artifact manifest: the contract between `python/compile/aot.py` and the
//! Rust runtime.
//!
//! Format: `manifest.tsv`, one artifact per line,
//! `name \t file \t key=value \t key=value …` — trivially parseable without
//! a JSON dependency (serde is not in the offline registry); aot.py also
//! writes a human-oriented manifest.json with the same content.
//!
//! This module also owns the **tuning manifest** ([`TuningManifest`]): the
//! schema-versioned TSV written by `vabft autotune` that records, per
//! shape class, the fastest measured execution configuration (tiles ×
//! microkernel × threads × row-split × SIMD level). Consumers
//! ([`crate::gemm::EngineConfig`], the coordinator shards, `serve-replay`)
//! load it at startup; every recorded choice is pure *scheduling*, so a
//! stale or missing manifest can cost wall-clock time but can never change
//! a result bit.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use crate::anyhow;
use crate::error::{Context, Result};
use crate::gemm::{MicroConfig, RowSplit, SimdLevel, TileConfig};

/// One artifact: a lowered HLO-text module plus its metadata
/// (shapes, dtypes, parameter layouts — whatever the producer recorded).
#[derive(Debug, Clone, Default)]
pub struct ArtifactEntry {
    /// Artifact name (the key [`Manifest::get`] resolves).
    pub name: String,
    /// HLO-text file, relative to the manifest's directory.
    pub file: String,
    /// Producer-recorded metadata (shapes, dtypes, parameter layout).
    pub meta: HashMap<String, String>,
}

impl ArtifactEntry {
    /// Typed metadata accessor.
    pub fn meta_parse<T: std::str::FromStr>(&self, key: &str) -> Option<T> {
        self.meta.get(key).and_then(|v| v.parse().ok())
    }

    /// Comma-separated usize list, e.g. `shape=128,256`.
    pub fn meta_dims(&self, key: &str) -> Option<Vec<usize>> {
        let v = self.meta.get(key)?;
        v.split(',').map(|s| s.trim().parse().ok()).collect()
    }
}

/// The parsed manifest.
#[derive(Debug, Clone, Default)]
pub struct Manifest {
    /// Every artifact, in file order.
    pub entries: Vec<ArtifactEntry>,
}

impl Manifest {
    /// Read and parse a `manifest.tsv`.
    pub fn load(path: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::parse(&text)
    }

    /// Parse manifest text (see the module docs for the format).
    pub fn parse(text: &str) -> Result<Manifest> {
        let mut entries = Vec::new();
        for (lineno, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let mut fields = line.split('\t');
            let name = fields
                .next()
                .ok_or_else(|| anyhow!("line {}: missing name", lineno + 1))?
                .to_string();
            let file = fields
                .next()
                .ok_or_else(|| anyhow!("line {}: missing file", lineno + 1))?
                .to_string();
            let mut meta = HashMap::new();
            for kv in fields {
                if let Some((k, v)) = kv.split_once('=') {
                    meta.insert(k.trim().to_string(), v.trim().to_string());
                }
            }
            entries.push(ArtifactEntry { name, file, meta });
        }
        Ok(Manifest { entries })
    }

    /// Look up an artifact by name.
    pub fn get(&self, name: &str) -> Option<&ArtifactEntry> {
        self.entries.iter().find(|e| e.name == name)
    }
}

/// Schema tag a tuning manifest must declare on its first non-comment
/// line (`schema\t<tag>`). Bumped whenever the record format changes, so
/// stale manifests are rejected instead of silently misread.
pub const TUNING_SCHEMA: &str = "vabft-tuning/v1";

/// One autotuned winner: the fastest measured execution configuration for
/// a shape class, plus the measurements that picked it.
#[derive(Debug, Clone, PartialEq)]
pub struct TunedShape {
    /// Human-readable shape-class label (e.g. `llama-7b/attn.qkv`).
    pub label: String,
    /// Output rows of the shape class.
    pub m: usize,
    /// Reduction depth of the shape class.
    pub k: usize,
    /// Output columns of the shape class.
    pub n: usize,
    /// Winning cache-blocking tile sizes.
    pub tiles: TileConfig,
    /// Winning microkernel (register-block) shape.
    pub micro: MicroConfig,
    /// Winning worker-thread count.
    pub threads: usize,
    /// Winning row-split policy.
    pub split: RowSplit,
    /// Winning SIMD dispatch level.
    pub simd: SimdLevel,
    /// Measured throughput of the winner (GFLOP/s).
    pub gflops: f64,
    /// Measured throughput of the default configuration (GFLOP/s).
    pub baseline_gflops: f64,
}

/// The autotuner's persisted output: per-shape-class winners plus the CPU
/// feature string they were measured on.
///
/// Format (TSV, `#` comments allowed anywhere):
///
/// ```text
/// schema\tvabft-tuning/v1
/// cpu\tavx2+fma
/// shape\tlabel=…\tm=…\tk=…\tn=…\tmc=…\tkc=…\tnc=…\tmr=…\tnr=…\t…
/// ```
///
/// The `schema` line must come first; a missing or mismatched tag is a
/// hard parse error (the stale-manifest guard).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct TuningManifest {
    /// CPU feature string ([`crate::gemm::cpu_features`]) of the machine
    /// the winners were measured on.
    pub cpu: String,
    /// Per-shape-class winners, in file order.
    pub entries: Vec<TunedShape>,
}

impl TuningManifest {
    /// Empty manifest tagged with a CPU feature string.
    pub fn new(cpu: impl Into<String>) -> TuningManifest {
        TuningManifest { cpu: cpu.into(), entries: Vec::new() }
    }

    /// Append a tuned shape class.
    pub fn push(&mut self, entry: TunedShape) {
        self.entries.push(entry);
    }

    /// Read and parse a tuning manifest file.
    pub fn load(path: &Path) -> Result<TuningManifest> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::parse(&text).with_context(|| format!("parsing {}", path.display()))
    }

    /// Parse tuning-manifest text (see the type docs for the format).
    pub fn parse(text: &str) -> Result<TuningManifest> {
        let mut man = TuningManifest::default();
        let mut saw_schema = false;
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let mut fields = line.split('\t');
            let tag = fields.next().unwrap_or_default();
            if !saw_schema {
                let got = fields.next().unwrap_or_default();
                crate::ensure!(
                    tag == "schema" && got == TUNING_SCHEMA,
                    "line {}: tuning manifest must open with 'schema\\t{}', got {:?}",
                    lineno + 1,
                    TUNING_SCHEMA,
                    line
                );
                saw_schema = true;
                continue;
            }
            match tag {
                "cpu" => man.cpu = fields.next().unwrap_or_default().to_string(),
                "shape" => {
                    let mut kv: HashMap<&str, &str> = HashMap::new();
                    for f in fields {
                        if let Some((k, v)) = f.split_once('=') {
                            kv.insert(k.trim(), v.trim());
                        }
                    }
                    man.entries.push(Self::entry_from(&kv, lineno + 1)?);
                }
                other => {
                    return Err(anyhow!("line {}: unknown record {:?}", lineno + 1, other));
                }
            }
        }
        crate::ensure!(saw_schema, "tuning manifest has no schema line");
        Ok(man)
    }

    fn entry_from(kv: &HashMap<&str, &str>, lineno: usize) -> Result<TunedShape> {
        fn field<T: std::str::FromStr>(
            kv: &HashMap<&str, &str>,
            key: &str,
            lineno: usize,
        ) -> Result<T> {
            kv.get(key)
                .and_then(|v| v.parse().ok())
                .ok_or_else(|| anyhow!("line {lineno}: missing or invalid {key}"))
        }
        let split_s: String = field(kv, "split", lineno)?;
        let split = RowSplit::parse(&split_s)
            .ok_or_else(|| anyhow!("line {lineno}: invalid split {split_s:?}"))?;
        let simd_s: String = field(kv, "simd", lineno)?;
        let simd = SimdLevel::parse(&simd_s)
            .ok_or_else(|| anyhow!("line {lineno}: invalid simd {simd_s:?}"))?;
        let (mc, kc, nc) = (field(kv, "mc", lineno)?, field(kv, "kc", lineno)?,
            field(kv, "nc", lineno)?);
        crate::ensure!(mc > 0 && kc > 0 && nc > 0, "line {lineno}: tile sizes must be positive");
        let (mr, nr): (usize, usize) = (field(kv, "mr", lineno)?, field(kv, "nr", lineno)?);
        let micro_ok = (1..=crate::gemm::micro::MAX_MICRO).contains(&mr)
            && (1..=crate::gemm::micro::MAX_MICRO).contains(&nr);
        crate::ensure!(micro_ok, "line {lineno}: micro-tile sizes out of range");
        Ok(TunedShape {
            label: kv.get("label").unwrap_or(&"").to_string(),
            m: field(kv, "m", lineno)?,
            k: field(kv, "k", lineno)?,
            n: field(kv, "n", lineno)?,
            tiles: TileConfig { mc, kc, nc },
            micro: MicroConfig { mr, nr },
            threads: field(kv, "threads", lineno)?,
            split,
            simd,
            gflops: field(kv, "gflops", lineno)?,
            baseline_gflops: field(kv, "baseline_gflops", lineno)?,
        })
    }

    /// Serialize to the TSV format [`TuningManifest::parse`] reads.
    /// Floats use `Display` (shortest round-trip form), so
    /// save → load → save is byte-stable.
    pub fn to_text(&self) -> String {
        let mut out = String::new();
        out.push_str("# vabft tuning manifest — regenerate with `vabft autotune`\n");
        out.push_str(&format!("schema\t{TUNING_SCHEMA}\n"));
        if !self.cpu.is_empty() {
            out.push_str(&format!("cpu\t{}\n", self.cpu));
        }
        for e in &self.entries {
            out.push_str(&format!(
                "shape\tlabel={}\tm={}\tk={}\tn={}\tmc={}\tkc={}\tnc={}\tmr={}\tnr={}\t\
                 threads={}\tsplit={}\tsimd={}\tgflops={}\tbaseline_gflops={}\n",
                e.label,
                e.m,
                e.k,
                e.n,
                e.tiles.mc,
                e.tiles.kc,
                e.tiles.nc,
                e.micro.mr,
                e.micro.nr,
                e.threads,
                e.split.name(),
                e.simd.name(),
                e.gflops,
                e.baseline_gflops,
            ));
        }
        out
    }

    /// Write the manifest to `path` (see [`TuningManifest::to_text`]).
    pub fn save(&self, path: &Path) -> Result<()> {
        std::fs::write(path, self.to_text())
            .with_context(|| format!("writing {}", path.display()))
    }

    /// Find the tuned entry closest to an (m, k, n) shape: an exact match
    /// when one exists, else the entry minimizing the symmetric
    /// log-ratio distance `|ln(m'/m)| + |ln(k'/k)| + |ln(n'/n)|`, capped
    /// so wildly different shapes fall back to defaults instead of
    /// inheriting someone else's blocking.
    ///
    /// Equidistant entries tie-break on *content* — the smaller
    /// `(m, k, n, label)` tuple wins — never on file position, so two
    /// manifests holding the same entries in different orders resolve
    /// every query identically. (Earliest-entry tie-breaking looked
    /// deterministic but made resolution a function of write order: two
    /// autotune runs that persisted the same winners in different orders
    /// could hand the same GEMM different tile configs.)
    pub fn lookup(&self, m: usize, k: usize, n: usize) -> Option<&TunedShape> {
        const MAX_DIST: f64 = 3.0;
        let d = |a: usize, b: usize| ((a as f64 + 1.0) / (b as f64 + 1.0)).ln().abs();
        let key = |e: &TunedShape| (e.m, e.k, e.n, e.label.clone());
        let mut best: Option<(&TunedShape, f64)> = None;
        for e in &self.entries {
            let dist = d(e.m, m) + d(e.k, k) + d(e.n, n);
            let better = match &best {
                Some((be, bd)) => {
                    dist < *bd || (dist == *bd && key(e) < key(be))
                }
                None => true,
            };
            if better {
                best = Some((e, dist));
            }
        }
        best.filter(|&(_, dist)| dist <= MAX_DIST).map(|(e, _)| e)
    }

    /// Default manifest location: `$VABFT_TUNING_MANIFEST` verbatim when
    /// set and non-empty, else `vabft-tuning.tsv` at the workspace root.
    pub fn default_path() -> PathBuf {
        match std::env::var("VABFT_TUNING_MANIFEST") {
            Ok(p) if !p.is_empty() => PathBuf::from(p),
            _ => {
                // CARGO_MANIFEST_DIR is rust/; the tuning manifest lives
                // at the workspace root next to README.md.
                let manifest = PathBuf::from(env!("CARGO_MANIFEST_DIR"));
                let root = manifest.parent().map(|p| p.to_path_buf()).unwrap_or(manifest);
                root.join("vabft-tuning.tsv")
            }
        }
    }

    /// Load from [`TuningManifest::default_path`]. An absent file is
    /// `Ok(None)` (no tuning is a valid state — the engine uses built-in
    /// defaults); a present but corrupt or stale-schema file is an error.
    pub fn load_default() -> Result<Option<TuningManifest>> {
        let path = Self::default_path();
        if !path.exists() {
            return Ok(None);
        }
        Self::load(&path).map(Some)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_full_manifest() {
        let text = "# comment\n\
                    ftgemm_bf16\tftgemm_bf16.hlo.txt\tm=8\tk=64\tn=32\tdtype=bf16\n\
                    \n\
                    train_step\ttrain_step.hlo.txt\tparams=5\tloss_index=5\n";
        let m = Manifest::parse(text).unwrap();
        assert_eq!(m.entries.len(), 2);
        let e = m.get("ftgemm_bf16").unwrap();
        assert_eq!(e.file, "ftgemm_bf16.hlo.txt");
        assert_eq!(e.meta_parse::<usize>("k"), Some(64));
        assert_eq!(e.meta.get("dtype").map(|s| s.as_str()), Some("bf16"));
        assert!(m.get("missing").is_none());
    }

    #[test]
    fn dims_helper() {
        let m = Manifest::parse("x\tx.hlo\tshape=128,256,8\n").unwrap();
        assert_eq!(m.entries[0].meta_dims("shape"), Some(vec![128, 256, 8]));
    }

    #[test]
    fn malformed_line_errors() {
        assert!(Manifest::parse("onlyname\n").is_err());
    }

    fn tuned(label: &str, m: usize, k: usize, n: usize) -> TunedShape {
        TunedShape {
            label: label.to_string(),
            m,
            k,
            n,
            tiles: TileConfig { mc: 32, kc: 128, nc: 64 },
            micro: MicroConfig { mr: 4, nr: 16 },
            threads: 4,
            split: RowSplit::Interleaved,
            simd: SimdLevel::Scalar,
            gflops: 12.375,
            baseline_gflops: 10.0625,
        }
    }

    #[test]
    fn tuning_manifest_round_trips() {
        let mut man = TuningManifest::new("avx2+fma");
        man.push(tuned("llama-7b/qkv", 256, 4096, 12288));
        man.push(tuned("grid/64", 64, 64, 66));
        let text = man.to_text();
        let back = TuningManifest::parse(&text).unwrap();
        assert_eq!(back, man);
        // Byte-stability: save → load → save is the identity.
        assert_eq!(back.to_text(), text);
    }

    #[test]
    fn tuning_manifest_rejects_stale_or_corrupt() {
        // Wrong schema tag (a v0 file, or a future v2) must be rejected.
        let stale = "schema\tvabft-tuning/v0\ncpu\tneon\n";
        assert!(TuningManifest::parse(stale).is_err());
        // Missing schema line entirely.
        assert!(TuningManifest::parse("cpu\tneon\n").is_err());
        assert!(TuningManifest::parse("").is_err());
        // Corrupt shape records: missing field, bad number, bad enum,
        // out-of-range micro tile.
        let head = format!("schema\t{TUNING_SCHEMA}\n");
        for bad in [
            "shape\tlabel=x\tm=8\tk=8\n",
            "shape\tlabel=x\tm=eight\tk=8\tn=8\tmc=1\tkc=1\tnc=1\tmr=1\tnr=1\t\
             threads=1\tsplit=contiguous\tsimd=scalar\tgflops=1\tbaseline_gflops=1\n",
            "shape\tlabel=x\tm=8\tk=8\tn=8\tmc=1\tkc=1\tnc=1\tmr=1\tnr=1\t\
             threads=1\tsplit=diagonal\tsimd=scalar\tgflops=1\tbaseline_gflops=1\n",
            "shape\tlabel=x\tm=8\tk=8\tn=8\tmc=1\tkc=1\tnc=1\tmr=1\tnr=99\t\
             threads=1\tsplit=contiguous\tsimd=scalar\tgflops=1\tbaseline_gflops=1\n",
            "shape\tlabel=x\tm=8\tk=8\tn=8\tmc=0\tkc=1\tnc=1\tmr=1\tnr=1\t\
             threads=1\tsplit=contiguous\tsimd=scalar\tgflops=1\tbaseline_gflops=1\n",
        ] {
            let text = format!("{head}{bad}");
            assert!(TuningManifest::parse(&text).is_err(), "accepted: {bad}");
        }
        // Unknown record kinds are errors, not silently skipped.
        assert!(TuningManifest::parse(&format!("{head}mystery\t1\n")).is_err());
    }

    #[test]
    fn tuning_lookup_prefers_exact_then_nearest_with_cap() {
        let mut man = TuningManifest::new("scalar");
        man.push(tuned("small", 64, 64, 64));
        man.push(tuned("large", 4096, 4096, 4096));
        // Exact hit.
        assert_eq!(man.lookup(64, 64, 64).unwrap().label, "small");
        // Near miss maps to the closest class.
        assert_eq!(man.lookup(96, 64, 48).unwrap().label, "small");
        assert_eq!(man.lookup(2048, 4096, 8192).unwrap().label, "large");
        // A shape unlike anything tuned falls back to defaults (None).
        assert!(man.lookup(1, 1_000_000, 1).is_none());
        // Empty manifest never matches.
        assert!(TuningManifest::new("x").lookup(8, 8, 8).is_none());
    }

    #[test]
    fn tuning_lookup_tie_break_is_independent_of_entry_order() {
        // Two entries equidistant (in summed log-ratio) from the query
        // (127, 127, 127): the smoothed distance uses (x + 1), so pick
        // m values with (127+1)^2 = (63+1)*(255+1) — both sit exactly
        // ln 2 away on the m axis. Whichever file order they were
        // persisted in, the same entry must win — the content tie-break
        // prefers the smaller (m, k, n, label) tuple.
        let lo = tuned("lo", 63, 127, 127);
        let hi = tuned("hi", 255, 127, 127);
        let d = |a: usize, b: usize| ((a as f64 + 1.0) / (b as f64 + 1.0)).ln().abs();
        let dist = |e: &TunedShape| d(e.m, 127) + d(e.k, 127) + d(e.n, 127);
        assert!(
            (dist(&lo) - dist(&hi)).abs() < 1e-12,
            "test fixture must be equidistant: {} vs {}",
            dist(&lo),
            dist(&hi)
        );

        let mut fwd = TuningManifest::new("scalar");
        fwd.push(lo.clone());
        fwd.push(hi.clone());
        let mut rev = TuningManifest::new("scalar");
        rev.push(hi);
        rev.push(lo);

        let a = fwd.lookup(127, 127, 127).expect("within cap");
        let b = rev.lookup(127, 127, 127).expect("within cap");
        assert_eq!(a.label, b.label, "tie resolution depends on entry order");
        // And specifically the smaller (m, k, n, label) tuple wins.
        assert_eq!(a.label, "lo");

        // Identical shapes differing only by label also resolve by
        // content, not position.
        let mut m1 = TuningManifest::new("scalar");
        m1.push(tuned("beta", 64, 64, 64));
        m1.push(tuned("alpha", 64, 64, 64));
        let mut m2 = TuningManifest::new("scalar");
        m2.push(tuned("alpha", 64, 64, 64));
        m2.push(tuned("beta", 64, 64, 64));
        assert_eq!(m1.lookup(64, 64, 64).unwrap().label, "alpha");
        assert_eq!(m2.lookup(64, 64, 64).unwrap().label, "alpha");
    }
}
