//! Offline stand-in for the `xla` (xla_extension / PJRT) crate.
//!
//! The offline registry has no XLA bindings, so this module provides the
//! small API surface [`crate::runtime`] is written against:
//!
//! * [`Literal`] — typed host tensors (f32 / i32 / tuple) with reshape,
//!   flattening and element counts. Fully functional.
//! * [`PjRtClient`] / [`HloModuleProto`] / [`XlaComputation`] — artifact
//!   loading parses and retains the HLO text, but `compile` reports that
//!   no real PJRT backend is linked. The integration tests skip when
//!   artifacts are absent, so `cargo test` stays green; linking a real
//!   `xla_extension` only requires swapping this module back for the
//!   crate of the same shape.

use std::borrow::Borrow;
use std::fmt;

/// Error type mirroring the binding crate's (Debug-printable status).
#[derive(Debug, Clone)]
pub struct XlaError(pub String);

impl fmt::Display for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for XlaError {}

type Result<T> = std::result::Result<T, XlaError>;

/// Element types a [`Literal`] can hold.
#[doc(hidden)]
#[derive(Debug, Clone)]
pub enum LiteralData {
    F32(Vec<f32>),
    I32(Vec<i32>),
    Tuple(Vec<Literal>),
}

/// Native element types storable in a [`Literal`].
pub trait NativeType: Copy {
    #[doc(hidden)]
    fn wrap(data: Vec<Self>) -> LiteralData;
    #[doc(hidden)]
    fn unwrap(data: &LiteralData) -> Option<Vec<Self>>;
}

impl NativeType for f32 {
    fn wrap(data: Vec<f32>) -> LiteralData {
        LiteralData::F32(data)
    }
    fn unwrap(data: &LiteralData) -> Option<Vec<f32>> {
        match data {
            LiteralData::F32(v) => Some(v.clone()),
            _ => None,
        }
    }
}

impl NativeType for i32 {
    fn wrap(data: Vec<i32>) -> LiteralData {
        LiteralData::I32(data)
    }
    fn unwrap(data: &LiteralData) -> Option<Vec<i32>> {
        match data {
            LiteralData::I32(v) => Some(v.clone()),
            _ => None,
        }
    }
}

/// A host tensor: flat data plus dimensions.
#[derive(Debug, Clone)]
pub struct Literal {
    dims: Vec<i64>,
    data: LiteralData,
}

impl Literal {
    /// Rank-1 literal from a slice.
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        Literal { dims: vec![data.len() as i64], data: T::wrap(data.to_vec()) }
    }

    /// Reinterpret with new dimensions (element count must match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let n: i64 = dims.iter().product();
        if n as usize != self.element_count() {
            return Err(XlaError(format!(
                "reshape: {} elements cannot take shape {dims:?}",
                self.element_count()
            )));
        }
        Ok(Literal { dims: dims.to_vec(), data: self.data.clone() })
    }

    /// Flatten to a typed vector.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::unwrap(&self.data)
            .ok_or_else(|| XlaError("literal element type mismatch".to_string()))
    }

    /// Decompose a tuple literal into its elements.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        match self.data {
            LiteralData::Tuple(v) => Ok(v),
            _ => Ok(vec![self]),
        }
    }

    /// Total element count (tuples sum their elements).
    pub fn element_count(&self) -> usize {
        match &self.data {
            LiteralData::F32(v) => v.len(),
            LiteralData::I32(v) => v.len(),
            LiteralData::Tuple(v) => v.iter().map(Literal::element_count).sum(),
        }
    }

    /// The literal's dimensions.
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

/// A parsed HLO-text module (text retained verbatim).
#[derive(Debug, Clone)]
pub struct HloModuleProto {
    /// The module's HLO text.
    pub text: String,
}

impl HloModuleProto {
    /// Read an HLO-text file.
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| XlaError(format!("read {path}: {e}")))?;
        Ok(HloModuleProto { text })
    }
}

/// A computation wrapping a parsed module.
#[derive(Debug, Clone)]
pub struct XlaComputation {
    #[allow(dead_code)]
    module: HloModuleProto,
}

impl XlaComputation {
    /// Wrap a parsed module.
    pub fn from_proto(proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { module: proto.clone() }
    }
}

/// The PJRT client. Construction succeeds (the registry itself is pure
/// Rust); only `compile` requires the real backend.
#[derive(Debug)]
pub struct PjRtClient {
    platform: &'static str,
}

impl PjRtClient {
    /// Construct the (stub) CPU client.
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient { platform: "cpu-stub (no PJRT backend linked)" })
    }

    /// The platform's display name.
    pub fn platform_name(&self) -> String {
        self.platform.to_string()
    }

    /// Compile a computation — always errors in the stub (no backend).
    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(XlaError(
            "no XLA/PJRT backend linked in this offline build; \
             swap runtime::xla_stub for a real xla_extension binding to \
             execute AOT artifacts"
                .to_string(),
        ))
    }
}

/// A compiled executable. Never constructed by the stub (compile errors),
/// but the type must exist so the runtime's execute path typechecks.
#[derive(Debug)]
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    /// Execute with literal inputs — always errors in the stub.
    pub fn execute<B: Borrow<Literal>>(&self, _inputs: &[B]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(XlaError("stub executable cannot run".to_string()))
    }
}

/// A device buffer handle.
#[derive(Debug)]
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    /// Fetch the buffer to host — always errors in the stub.
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(XlaError("stub buffer holds no data".to_string()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_reshape_and_flatten() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        assert_eq!(l.element_count(), 4);
        let r = l.reshape(&[2, 2]).unwrap();
        assert_eq!(r.dims(), &[2, 2]);
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(l.reshape(&[3, 3]).is_err());
        assert!(l.to_vec::<i32>().is_err());
    }

    #[test]
    fn client_exists_but_compile_is_gated() {
        let c = PjRtClient::cpu().unwrap();
        assert!(!c.platform_name().is_empty());
        let m = HloModuleProto { text: "HloModule test".to_string() };
        assert!(c.compile(&XlaComputation::from_proto(&m)).is_err());
    }

    #[test]
    fn non_tuple_literal_untuples_to_itself() {
        let l = Literal::vec1(&[7i32]);
        let parts = l.to_tuple().unwrap();
        assert_eq!(parts.len(), 1);
        assert_eq!(parts[0].to_vec::<i32>().unwrap(), vec![7]);
    }
}
