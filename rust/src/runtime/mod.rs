//! PJRT runtime: load and execute the AOT artifacts from the Rust hot
//! path.
//!
//! Python runs once at build time (`make artifacts`): `python/compile/aot.py`
//! lowers the L2 JAX model (whose matmuls route through the L1 Pallas
//! fused ABFT-GEMM kernel) to **HLO text** and writes a manifest. This
//! module loads those files with `HloModuleProto::from_text_file`, compiles
//! them on the PJRT CPU client and executes them with concrete inputs —
//! Python is never on the request path.
//!
//! HLO *text* (not a serialized `HloModuleProto`) is the interchange
//! format: jax ≥ 0.5 emits protos with 64-bit instruction ids that the
//! crate's xla_extension 0.5.1 rejects; the text parser reassigns ids.

mod manifest;
pub mod xla_stub;
pub use manifest::{ArtifactEntry, Manifest, TunedShape, TuningManifest, TUNING_SCHEMA};

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use crate::anyhow;
use crate::error::{Context, Result};
use xla_stub as xla;

/// Default artifact directory, overridable with VABFT_ARTIFACTS.
pub fn artifacts_dir() -> PathBuf {
    std::env::var("VABFT_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

/// A loaded-and-compiled artifact registry backed by a PJRT CPU client.
pub struct PjrtRuntime {
    client: xla::PjRtClient,
    executables: HashMap<String, xla::PjRtLoadedExecutable>,
    manifest: Manifest,
}

impl PjrtRuntime {
    /// Create a CPU PJRT client with an empty registry.
    pub fn new() -> Result<PjrtRuntime> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        Ok(PjrtRuntime { client, executables: HashMap::new(), manifest: Manifest::default() })
    }

    /// Create a runtime and load every artifact listed in
    /// `<dir>/manifest.tsv`.
    pub fn from_artifacts(dir: &Path) -> Result<PjrtRuntime> {
        let mut rt = Self::new()?;
        let manifest = Manifest::load(&dir.join("manifest.tsv"))
            .with_context(|| format!("loading manifest from {}", dir.display()))?;
        for entry in manifest.entries.clone() {
            rt.load(&entry.name, &dir.join(&entry.file))?;
        }
        rt.manifest = manifest;
        Ok(rt)
    }

    /// PJRT platform name (e.g. "cpu").
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// The loaded artifact manifest.
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Load one HLO-text artifact and compile it under `name`.
    pub fn load(&mut self, name: &str, path: &Path) -> Result<()> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .map_err(|e| anyhow!("parse {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compile {}: {e:?}", path.display()))?;
        self.executables.insert(name.to_string(), exe);
        Ok(())
    }

    /// Whether an artifact is loaded and compiled under `name`.
    pub fn has(&self, name: &str) -> bool {
        self.executables.contains_key(name)
    }

    /// Names of every compiled artifact.
    pub fn names(&self) -> Vec<&str> {
        self.executables.keys().map(|s| s.as_str()).collect()
    }

    /// Execute an artifact with literal inputs. The jax side lowers with
    /// `return_tuple=True`, so the single output is a tuple that we
    /// decompose into its elements.
    pub fn execute(&self, name: &str, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let exe = self
            .executables
            .get(name)
            .ok_or_else(|| anyhow!("artifact '{name}' not loaded"))?;
        let result = exe
            .execute::<xla::Literal>(inputs)
            .map_err(|e| anyhow!("execute {name}: {e:?}"))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch result of {name}: {e:?}"))?;
        lit.to_tuple().map_err(|e| anyhow!("untuple result of {name}: {e:?}"))
    }

    /// Execute with f32 tensor inputs given as (data, dims) pairs, and
    /// return every output as a flat f32 vector.
    pub fn execute_f32(&self, name: &str, inputs: &[(&[f32], &[i64])]) -> Result<Vec<Vec<f32>>> {
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|(data, dims)| literal_f32(data, dims))
            .collect::<Result<_>>()?;
        let outs = self.execute(name, &literals)?;
        outs.iter()
            .map(|l| l.to_vec::<f32>().map_err(|e| anyhow!("to_vec: {e:?}")))
            .collect()
    }
}

/// Build an f32 literal with the given dimensions.
pub fn literal_f32(data: &[f32], dims: &[i64]) -> Result<xla::Literal> {
    let n: i64 = dims.iter().product();
    crate::ensure!(n as usize == data.len(), "shape/data mismatch: {dims:?} vs {}", data.len());
    xla::Literal::vec1(data)
        .reshape(dims)
        .map_err(|e| anyhow!("reshape to {dims:?}: {e:?}"))
}

/// Build an i32 literal with the given dimensions.
pub fn literal_i32(data: &[i32], dims: &[i64]) -> Result<xla::Literal> {
    let n: i64 = dims.iter().product();
    crate::ensure!(n as usize == data.len(), "shape/data mismatch");
    xla::Literal::vec1(data)
        .reshape(dims)
        .map_err(|e| anyhow!("reshape to {dims:?}: {e:?}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Integration tests that need real artifacts live in
    /// `rust/tests/runtime_integration.rs` and skip gracefully when
    /// `make artifacts` has not run. Here we only test the pieces that
    /// don't require artifacts.

    #[test]
    fn literal_roundtrip() {
        let l = literal_f32(&[1.0, 2.0, 3.0, 4.0, 5.0, 6.0], &[2, 3]).unwrap();
        assert_eq!(l.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert_eq!(l.element_count(), 6);
    }

    #[test]
    fn literal_shape_mismatch_rejected() {
        assert!(literal_f32(&[1.0, 2.0], &[3, 3]).is_err());
    }

    #[test]
    fn runtime_construction_and_missing_artifact() {
        let rt = PjrtRuntime::new().expect("cpu client");
        assert!(!rt.has("nope"));
        assert!(rt.execute("nope", &[]).is_err());
        assert!(!rt.platform().is_empty());
    }
}
