//! Threshold-tightness experiment (paper Tables 4, 5, 6).
//!
//! For each size n: sample A (m×n), B (n×n) from the configured
//! distribution on the model's input grid, run the encoded GEMM, measure
//! the *actual* verification difference `max_i |Σ_j C_ij − C^{r1}_i|`, and
//! compare against the A-ABFT and V-ABFT thresholds. Tightness =
//! threshold / actual (lower is better; 1 is perfect).

use crate::abft::encode::ChecksumEncoding;
use crate::calibrate::EmaxModel;
use crate::fp::dd::Dd;
use crate::gemm::{exact, GemmEngine, AccumModel};
use crate::matrix::Matrix;
use crate::rng::{Distribution, Xoshiro256pp};
use crate::threshold::{AabftThreshold, Threshold, ThresholdContext, VabftThreshold};

/// Configuration of one tightness table.
#[derive(Debug, Clone)]
pub struct TightnessConfig {
    /// Display label ("FP64, U(-1,1), dd baseline").
    pub label: String,
    /// Accumulation model under test.
    pub model: AccumModel,
    /// Operand distribution.
    pub dist: Distribution,
    /// Matrix sizes n (B is n×n).
    pub sizes: Vec<usize>,
    /// Trials per size.
    pub trials: usize,
    /// Rows of A per trial (paper uses m = n; quick mode samples fewer
    /// rows — the max statistic converges quickly).
    pub rows: Option<usize>,
    /// A-ABFT baseline configuration.
    pub aabft: AabftThreshold,
    /// V-ABFT e_max law (the platform's Table 7 value).
    pub vabft_emax: EmaxModel,
    /// Keep checksum columns in work precision (fused-style encoding —
    /// Table 6's BF16 setup).
    pub wide_checksums: bool,
    /// Base RNG seed; trials use deterministic substreams.
    pub seed: u64,
}

/// One row of the resulting table.
#[derive(Debug, Clone, Copy)]
pub struct TightnessRow {
    /// Matrix size (B is n×n).
    pub n: usize,
    /// max observed |E| across trials and rows.
    pub actual: f64,
    /// Largest A-ABFT threshold issued.
    pub aabft_threshold: f64,
    /// Largest V-ABFT threshold issued.
    pub vabft_threshold: f64,
    /// Observed clean-data false positives (must be 0 for both).
    pub fp_aabft: usize,
    /// V-ABFT clean-data false positives.
    pub fp_vabft: usize,
    /// Clean rows verified.
    pub rows_checked: usize,
}

impl TightnessRow {
    /// A-ABFT tightness (threshold / actual; lower is better).
    pub fn a_tight(&self) -> f64 {
        self.aabft_threshold / self.actual
    }

    /// V-ABFT tightness (threshold / actual; lower is better).
    pub fn v_tight(&self) -> f64 {
        self.vabft_threshold / self.actual
    }
}

/// Run the experiment.
pub fn run_tightness(cfg: &TightnessConfig) -> Vec<TightnessRow> {
    let engine = GemmEngine::new(cfg.model);
    let ctx = ThresholdContext::offline(cfg.model);
    let vab = VabftThreshold::with_emax(cfg.vabft_emax);
    let mut out = Vec::new();
    for &n in &cfg.sizes {
        let m = cfg.rows.unwrap_or(n).min(n);
        let mut actual = 0.0f64;
        let mut a_thr_max = 0.0f64;
        let mut v_thr_max = 0.0f64;
        let mut fp_a = 0usize;
        let mut fp_v = 0usize;
        let mut rows_checked = 0usize;
        for trial in 0..cfg.trials {
            let mut rng = Xoshiro256pp::from_stream(cfg.seed ^ (n as u64) << 20, trial as u64);
            let a = Matrix::sample_in(m, n, &cfg.dist, cfg.model.input, &mut rng);
            let b = Matrix::sample_in(n, n, &cfg.dist, cfg.model.input, &mut rng);
            let enc = if cfg.wide_checksums {
                ChecksumEncoding::encode_b_wide(&b, &engine)
            } else {
                ChecksumEncoding::encode_b(&b, &engine)
            };
            let gout = engine.matmul_mixed(&a, &enc.b_encoded, enc.wide_cols());
            // Data rows come from the stored (output-precision) C; with
            // fused-style wide encoding the checksum entries stay in the
            // FP32 datapath, so read them from the accumulator.
            let (c, cr1, _) = if cfg.wide_checksums {
                let (c, _, _) = enc.split_product(&gout.c);
                let (_, cr1, cr2) = enc.split_product(&gout.acc);
                (c, cr1, cr2)
            } else {
                enc.split_product(&gout.c)
            };
            let a_thr = cfg.aabft.thresholds(&a, &b, &ctx);
            let v_thr = vab.thresholds(&a, &b, &ctx);
            for i in 0..m {
                let e = (engine.reduce(c.row(i)) - cr1[i]).abs();
                actual = actual.max(e);
                a_thr_max = a_thr_max.max(a_thr[i]);
                v_thr_max = v_thr_max.max(v_thr[i]);
                if e > a_thr[i] {
                    fp_a += 1;
                }
                if e > v_thr[i] {
                    fp_v += 1;
                }
                rows_checked += 1;
            }
        }
        out.push(TightnessRow {
            n,
            actual,
            aabft_threshold: a_thr_max,
            vabft_threshold: v_thr_max,
            fp_aabft: fp_a,
            fp_vabft: fp_v,
            rows_checked,
        });
    }
    out
}

/// Project campaign clean-run statistics onto a Tables 4–6-shaped row.
///
/// The campaign engine measures, per grid cell, the same two quantities
/// this experiment measures — the realized verification difference on
/// clean data (`actual`, from the pipeline's |D1| telemetry) and the
/// largest issued A-ABFT / V-ABFT thresholds — so tightness tables are
/// campaign cells re-shaped, not a separate measurement pass.
///
/// The campaign verifies with V-ABFT thresholds only; `fp_aabft` is
/// therefore a lower bound, recorded as 1 only when even the loosest
/// A-ABFT threshold sat below the worst clean difference.
pub fn tightness_row_from_campaign(
    n: usize,
    actual: f64,
    aabft_threshold: f64,
    vabft_threshold: f64,
    rows_checked: usize,
    fp_vabft: usize,
) -> TightnessRow {
    TightnessRow {
        n,
        actual,
        aabft_threshold,
        vabft_threshold,
        fp_aabft: usize::from(aabft_threshold < actual),
        fp_vabft,
        rows_checked,
    }
}

/// Validate that the measured FP64 verification difference equals the
/// difference of the two paths' true errors against the double-double
/// baseline (the mpmath substitute) — Table 4's measurement methodology.
///
/// Returns the max |(path1 − exact) − (path2 − exact) − E| discrepancy,
/// which must be ≈ 0 (the f64 subtraction is exact at these magnitudes).
pub fn validate_dd_baseline(n: usize, seed: u64) -> f64 {
    let model = AccumModel::cpu(crate::fp::Precision::F64);
    let engine = GemmEngine::new(model);
    let dist = Distribution::uniform_pm1();
    let mut rng = Xoshiro256pp::seed_from_u64(seed);
    let m = 8.min(n);
    let a = Matrix::sample(m, n, &dist, &mut rng);
    let b = Matrix::sample(n, n, &dist, &mut rng);
    let enc = ChecksumEncoding::encode_b(&b, &engine);
    let gout = engine.matmul_mixed(&a, &enc.b_encoded, enc.wide_cols());
    let (c, cr1, _) = enc.split_product(&gout.c);
    let exact_cks = exact::exact_row_checksums(&a, &b);
    let mut worst = 0.0f64;
    for i in 0..m {
        let path1 = engine.reduce(c.row(i)); // row sum of computed C
        let path2 = cr1[i]; // checksum path
        let e_direct = path1 - path2;
        let err1 = Dd::from_f64(path1).sub(exact_cks[i]).to_f64();
        let err2 = Dd::from_f64(path2).sub(exact_cks[i]).to_f64();
        let e_via_dd = err1 - err2;
        worst = worst.max((e_direct - e_via_dd).abs());
        // sanity: per-path true errors are small multiples of u·|checksum|
        let scale = exact_cks[i].to_f64().abs().max(1.0);
        debug_assert!(err1.abs() < 1e-11 * scale);
    }
    worst
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fp::Precision;

    fn quick_cfg(model: AccumModel, dist: Distribution, emax: EmaxModel) -> TightnessConfig {
        TightnessConfig {
            label: "test".into(),
            model,
            dist,
            sizes: vec![64, 128],
            trials: 2,
            rows: Some(16),
            aabft: AabftThreshold::paper_repro(),
            vabft_emax: emax,
            wide_checksums: false,
            seed: 1,
        }
    }

    #[test]
    fn fp64_table_shape() {
        let cfg = quick_cfg(
            AccumModel::cpu(Precision::F64),
            Distribution::uniform_pm1(),
            EmaxModel::Constant(6e-16),
        );
        let rows = run_tightness(&cfg);
        assert_eq!(rows.len(), 2);
        for r in &rows {
            assert_eq!(r.fp_vabft, 0, "V-ABFT FP at n={}", r.n);
            assert_eq!(r.fp_aabft, 0, "A-ABFT FP at n={}", r.n);
            // paper Table 4 ordering: V much tighter than A, both > 1
            assert!(r.v_tight() > 1.0, "V threshold must bound actual");
            assert!(r.a_tight() > r.v_tight() * 3.0, "A should be ≫ V");
        }
        // A-ABFT degrades with n (O(n^1.5) vs actual ~ n·u growth)
        assert!(rows[1].a_tight() > rows[0].a_tight() * 0.8);
    }

    #[test]
    fn bf16_wide_checksum_table_shape() {
        let mut cfg = quick_cfg(
            AccumModel::wide(Precision::Bf16),
            Distribution::uniform_01(),
            EmaxModel::Constant(8e-3),
        );
        cfg.wide_checksums = true;
        cfg.aabft = AabftThreshold::computed_y();
        let rows = run_tightness(&cfg);
        for r in &rows {
            assert_eq!(r.fp_vabft, 0);
            assert!(r.v_tight() > 1.0 && r.v_tight() < 2000.0);
            assert!(r.a_tight() > r.v_tight());
        }
    }

    #[test]
    fn dd_baseline_validation_is_exact() {
        let disc = validate_dd_baseline(96, 7);
        assert!(disc < 1e-18, "dd-baseline discrepancy {disc}");
    }
}
