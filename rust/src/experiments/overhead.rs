//! Performance-overhead experiment (paper §6.8).
//!
//! Compares, on identical operands:
//! * plain GEMM (no protection),
//! * FT-GEMM (encode + encoded GEMM + V-ABFT threshold + verify + correct),
//! * FT-GEMM with pre-encoded weights (the serving hot path),
//! * FT-GEMM with the fused verify point (pre-encoded weights +
//!   detection inside the packed GEMM epilogue, [`VerifyPolicy::fused`]),
//! * DMR (double modular redundancy: run the GEMM twice and compare) —
//!   the paper's >200%-overhead strawman.
//!
//! Also isolates the threshold-computation share (paper: <2%).

use std::time::{Duration, Instant};

use crate::abft::{FtGemm, VerifyPolicy};
use crate::gemm::{AccumModel, GemmEngine};
use crate::matrix::Matrix;
use crate::rng::{Distribution, Xoshiro256pp};
use crate::threshold::{Threshold, ThresholdContext, VabftThreshold};

/// Configuration of the overhead comparison.
#[derive(Debug, Clone)]
pub struct OverheadConfig {
    /// Accumulation model under test.
    pub model: AccumModel,
    /// GEMM shape (M, K, N).
    pub shape: (usize, usize, usize),
    /// Operand distribution.
    pub dist: Distribution,
    /// Timed repetitions (median reported).
    pub reps: usize,
    /// RNG seed for the operands.
    pub seed: u64,
}

/// One row of the overhead table.
#[derive(Debug, Clone)]
pub struct OverheadRow {
    /// What was measured.
    pub label: String,
    /// Median wall-clock over the repetitions.
    pub median: Duration,
    /// Overhead vs the plain GEMM baseline, percent.
    pub overhead_pct: f64,
}

fn median_time(reps: usize, mut f: impl FnMut()) -> Duration {
    let mut samples = Vec::with_capacity(reps);
    f(); // warmup
    for _ in 0..reps {
        let t = Instant::now();
        f();
        samples.push(t.elapsed());
    }
    samples.sort();
    samples[samples.len() / 2]
}

/// Run the overhead comparison; first row is the plain-GEMM baseline.
pub fn run_overhead(cfg: &OverheadConfig) -> Vec<OverheadRow> {
    let (m, k, n) = cfg.shape;
    let mut rng = Xoshiro256pp::seed_from_u64(cfg.seed);
    let a = Matrix::sample_in(m, k, &cfg.dist, cfg.model.input, &mut rng);
    let b = Matrix::sample_in(k, n, &cfg.dist, cfg.model.input, &mut rng);
    let engine = GemmEngine::new(cfg.model);
    let ft = FtGemm::new(
        GemmEngine::new(cfg.model),
        Box::new(VabftThreshold::default()),
        VerifyPolicy::default(),
    );
    let prepared = ft.prepare(&b);
    let ft_fused = FtGemm::new(
        GemmEngine::new(cfg.model),
        Box::new(VabftThreshold::default()),
        VerifyPolicy::fused(),
    );
    let prepared_fused = ft_fused.prepare(&b);

    let base = median_time(cfg.reps, || {
        std::hint::black_box(engine.matmul(&a, &b));
    });
    let ft_full = median_time(cfg.reps, || {
        std::hint::black_box(ft.multiply(&a, &b).unwrap());
    });
    let ft_prep = median_time(cfg.reps, || {
        std::hint::black_box(ft.multiply_prepared(&a, &prepared, None).unwrap());
    });
    let ft_fused_t = median_time(cfg.reps, || {
        std::hint::black_box(ft_fused.multiply_prepared(&a, &prepared_fused, None).unwrap());
    });
    let dmr = median_time(cfg.reps, || {
        let c1 = engine.matmul(&a, &b);
        let c2 = engine.matmul(&a, &b);
        std::hint::black_box(c1.c.max_abs_diff(&c2.c));
    });
    // threshold computation alone
    let vab = VabftThreshold::default();
    let ctx = ThresholdContext::online(cfg.model);
    let thr_only = median_time(cfg.reps, || {
        std::hint::black_box(vab.thresholds(&a, &b, &ctx));
    });
    let thr_prep = median_time(cfg.reps, || {
        std::hint::black_box(vab.thresholds_prepared(&a, &prepared.blocks()[0].stats, &ctx));
    });

    let pct = |d: Duration| {
        100.0 * (d.as_secs_f64() - base.as_secs_f64()) / base.as_secs_f64()
    };
    vec![
        OverheadRow { label: "plain GEMM".into(), median: base, overhead_pct: 0.0 },
        OverheadRow {
            label: "FT-GEMM (encode per call)".into(),
            median: ft_full,
            overhead_pct: pct(ft_full),
        },
        OverheadRow {
            label: "FT-GEMM (prepared weights)".into(),
            median: ft_prep,
            overhead_pct: pct(ft_prep),
        },
        OverheadRow {
            label: "FT-GEMM (fused epilogue, prepared)".into(),
            median: ft_fused_t,
            overhead_pct: pct(ft_fused_t),
        },
        OverheadRow { label: "DMR (2x GEMM + compare)".into(), median: dmr, overhead_pct: pct(dmr) },
        OverheadRow {
            label: "threshold only (full)".into(),
            median: thr_only,
            overhead_pct: 100.0 * thr_only.as_secs_f64() / base.as_secs_f64(),
        },
        OverheadRow {
            label: "threshold only (prepared)".into(),
            median: thr_prep,
            overhead_pct: 100.0 * thr_prep.as_secs_f64() / base.as_secs_f64(),
        },
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fp::Precision;

    #[test]
    fn dmr_costs_about_double_and_ft_much_less() {
        let cfg = OverheadConfig {
            model: AccumModel::wide(Precision::Bf16),
            shape: (64, 256, 128),
            dist: Distribution::normal_1_1(),
            reps: 3,
            seed: 5,
        };
        let rows = run_overhead(&cfg);
        let base = rows[0].median.as_secs_f64();
        let ft_prep = rows[2].median.as_secs_f64();
        let ft_fused = rows[3].median.as_secs_f64();
        let dmr = rows[4].median.as_secs_f64();
        assert!(dmr > base * 1.5, "DMR should ≈ double: {rows:?}");
        assert!(
            ft_prep < dmr,
            "prepared FT-GEMM must beat DMR: {ft_prep} vs {dmr}"
        );
        assert!(
            ft_fused < dmr,
            "fused-epilogue FT-GEMM must beat DMR: {ft_fused} vs {dmr}"
        );
    }
}
