//! Reusable experiment drivers behind the paper-table benches.
//!
//! Each bench in `rust/benches/` is a thin main over one of these drivers,
//! so the measurement logic itself is unit-tested library code.

pub mod overhead;
pub mod real_model;
pub mod tightness;

pub use overhead::{run_overhead, OverheadConfig, OverheadRow};
pub use real_model::{model_weight_profiles, run_real_model, RealModelRow, WeightProfile};
pub use tightness::{
    run_tightness, tightness_row_from_campaign, validate_dd_baseline, TightnessConfig,
    TightnessRow,
};
