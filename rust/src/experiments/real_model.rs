//! Real-model-weights experiment (paper §6.7).
//!
//! The paper validates 0% FPR on LLaMA-7B (111 weight matrices), GPT-2
//! (5,379 GEMM verifications) and ViT-B/32 fine-tuning. Those checkpoints
//! are not downloadable in this sandbox, so per the substitution rule we
//! build synthetic weight tensors with the *published shapes and
//! layer-statistic profiles* of each model family (V-ABFT consumes only
//! row-wise max/min/mean, so matched low-order statistics exercise the
//! same threshold regime), plus — when AOT artifacts are present — the
//! actual weights of our own trained L2 transformer.

use crate::abft::{FtGemm, Verdict, VerifyPolicy};
use crate::fp::Precision;
use crate::gemm::AccumModel;
use crate::gemm::GemmEngine;
use crate::matrix::Matrix;
use crate::rng::{Distribution, Rng, Xoshiro256pp};
use crate::threshold::VabftThreshold;

/// A weight-matrix profile: shape plus element statistics.
#[derive(Debug, Clone)]
pub struct WeightProfile {
    /// Layer name ("wq/wk/wv/wo", …).
    pub name: &'static str,
    /// Weight rows (the GEMM's K).
    pub rows: usize,
    /// Weight columns (the GEMM's N).
    pub cols: usize,
    /// Element standard deviation of the published checkpoint family.
    pub std: f64,
    /// Element mean.
    pub mean: f64,
    /// How many distinct tensors of this profile the model has.
    pub count: usize,
}

impl WeightProfile {
    /// Floating-point operations of one activation GEMM against this
    /// weight: `2·m·K·N` for an `m × rows · rows × cols` multiply (the
    /// unit the replay workload's GFLOP/s throughput is counted in).
    pub fn gemm_flops(&self, m: usize) -> f64 {
        2.0 * m as f64 * self.rows as f64 * self.cols as f64
    }
}

/// Published-architecture weight profiles, scaled by `scale` (1 = full
/// size; quick mode uses 1/8).
pub fn model_weight_profiles(family: &str, scale: usize) -> Vec<WeightProfile> {
    let s = |d: usize| (d / scale).max(8);
    match family {
        // LLaMA-7B: d=4096, ffn=11008, 32 layers; init/trained std ≈ 0.02
        "llama-7b" => vec![
            WeightProfile { name: "wq/wk/wv/wo", rows: s(4096), cols: s(4096), std: 0.02, mean: 0.0, count: 4 },
            WeightProfile { name: "w_gate/w_up", rows: s(4096), cols: s(11008), std: 0.015, mean: 0.0, count: 2 },
            WeightProfile { name: "w_down", rows: s(11008), cols: s(4096), std: 0.015, mean: 0.0, count: 1 },
        ],
        // GPT-2 (124M): d=768, ffn=3072, 12 layers
        "gpt2" => vec![
            WeightProfile { name: "c_attn", rows: s(768), cols: s(2304), std: 0.02, mean: 0.0, count: 1 },
            WeightProfile { name: "c_proj", rows: s(768), cols: s(768), std: 0.02, mean: 0.0, count: 1 },
            WeightProfile { name: "mlp_fc", rows: s(768), cols: s(3072), std: 0.02, mean: 0.0, count: 1 },
            WeightProfile { name: "mlp_proj", rows: s(3072), cols: s(768), std: 0.02, mean: 0.0, count: 1 },
        ],
        // ViT-B/32: d=768, ffn=3072, patch embed 3072→768
        "vit-b32" => vec![
            WeightProfile { name: "patch_embed", rows: s(3072), cols: s(768), std: 0.02, mean: 0.0, count: 1 },
            WeightProfile { name: "qkv", rows: s(768), cols: s(2304), std: 0.02, mean: 0.0, count: 1 },
            WeightProfile { name: "mlp_fc", rows: s(768), cols: s(3072), std: 0.02, mean: 0.0, count: 1 },
        ],
        other => panic!("unknown model family '{other}'"),
    }
}

/// Result per model family.
#[derive(Debug, Clone)]
pub struct RealModelRow {
    /// Model family ("llama-7b", "gpt2", "vit-b32").
    pub family: String,
    /// Distinct weight matrices prepared.
    pub matrices: usize,
    /// Row verifications performed.
    pub verifications: usize,
    /// Clean rows that flagged (paper result: exactly zero).
    pub false_positives: usize,
}

/// Verify `gemms_per_matrix` activation GEMMs against each profile's
/// weights; count false positives (paper result: exactly zero).
pub fn run_real_model(
    family: &str,
    scale: usize,
    layers: usize,
    gemms_per_matrix: usize,
    online: bool,
    seed: u64,
) -> RealModelRow {
    let model = AccumModel::wide(Precision::Bf16);
    let policy = if online {
        VerifyPolicy::detect_only(true)
    } else {
        VerifyPolicy::detect_only(false)
    };
    let ft = FtGemm::new(GemmEngine::new(model), Box::new(VabftThreshold::default()), policy);
    let mut rng = Xoshiro256pp::seed_from_u64(seed);
    let mut matrices = 0;
    let mut verifications = 0;
    let mut fp = 0;
    for layer in 0..layers {
        for profile in model_weight_profiles(family, scale) {
            for c in 0..profile.count {
                let dist = Distribution::Normal { mean: profile.mean, std: profile.std };
                let b = Matrix::sample_in(
                    profile.rows,
                    profile.cols,
                    &dist,
                    model.input,
                    &mut rng,
                );
                let prepared = ft.prepare(&b);
                matrices += 1;
                for g in 0..gemms_per_matrix {
                    // activations: unit-normal post-layernorm statistics
                    let m_rows = 16;
                    let a = Matrix::sample_in(
                        m_rows,
                        profile.rows,
                        &Distribution::Normal { mean: 0.0, std: 1.0 },
                        model.input,
                        &mut rng,
                    );
                    let out = ft.multiply_prepared(&a, &prepared, None).unwrap();
                    verifications += out.report.rows_checked;
                    if out.report.verdict != Verdict::Clean {
                        fp += out.report.detections.len();
                    }
                    let _ = (g, c, layer);
                }
            }
        }
    }
    let _ = rng.next_u64();
    RealModelRow {
        family: family.to_string(),
        matrices,
        verifications,
        false_positives: fp,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gemm_flops_counts_multiply_adds() {
        let p = WeightProfile {
            name: "w_up",
            rows: 4096,
            cols: 11008,
            std: 0.015,
            mean: 0.0,
            count: 1,
        };
        assert_eq!(p.gemm_flops(16), 2.0 * 16.0 * 4096.0 * 11008.0);
    }

    #[test]
    fn profiles_exist_for_all_families() {
        for f in ["llama-7b", "gpt2", "vit-b32"] {
            let p = model_weight_profiles(f, 8);
            assert!(!p.is_empty());
            for w in p {
                assert!(w.rows >= 8 && w.cols >= 8);
            }
        }
    }

    #[test]
    fn zero_false_positives_on_scaled_gpt2() {
        let row = run_real_model("gpt2", 16, 2, 2, true, 7);
        assert_eq!(row.false_positives, 0, "{row:?}");
        assert!(row.verifications > 100);
    }
}
