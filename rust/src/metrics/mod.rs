//! Lightweight metrics: counters and latency histograms for the
//! coordinator's hot path (lock-free, allocation-free on record).

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

/// Monotonic counter.
#[derive(Debug, Default)]
pub struct Counter {
    v: AtomicU64,
}

impl Counter {
    /// Zeroed counter.
    pub const fn new() -> Counter {
        Counter { v: AtomicU64::new(0) }
    }

    /// Increment by one.
    #[inline]
    pub fn inc(&self) {
        self.v.fetch_add(1, Ordering::Relaxed);
    }

    /// Increment by `n`.
    #[inline]
    pub fn add(&self, n: u64) {
        self.v.fetch_add(n, Ordering::Relaxed);
    }

    /// Current value.
    pub fn get(&self) -> u64 {
        self.v.load(Ordering::Relaxed)
    }
}

/// Log₂-bucketed latency histogram: bucket i counts durations in
/// [2^i, 2^(i+1)) microseconds. 48 buckets cover ns..days.
#[derive(Debug)]
pub struct Histogram {
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    sum_us: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    /// Empty histogram.
    pub fn new() -> Histogram {
        Histogram {
            buckets: (0..48).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum_us: AtomicU64::new(0),
        }
    }

    /// Record one duration.
    #[inline]
    pub fn record(&self, d: Duration) {
        let us = d.as_micros() as u64;
        let idx = (64 - us.max(1).leading_zeros() as usize - 1).min(self.buckets.len() - 1);
        self.buckets[idx].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add(us, Ordering::Relaxed);
    }

    /// Number of recorded durations.
    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Mean recorded duration (zero when empty).
    pub fn mean(&self) -> Duration {
        let c = self.count();
        if c == 0 {
            return Duration::ZERO;
        }
        Duration::from_micros(self.sum_us.load(Ordering::Relaxed) / c)
    }

    /// Approximate quantile (upper edge of the bucket containing it).
    ///
    /// `target` is clamped to `1..=total` exactly like
    /// [`TailSnapshot::quantile`]: without the clamp, `q` at (or
    /// rounding up past) 1.0 could demand more samples than exist and
    /// fall off the bucket scan into the sentinel edge — a bogus
    /// ~4.5-year p100 instead of the max occupied bucket.
    pub fn quantile(&self, q: f64) -> Duration {
        let total = self.count();
        if total == 0 {
            return Duration::ZERO;
        }
        let target = ((total as f64 * q).ceil() as u64).clamp(1, total);
        let mut acc = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            acc += b.load(Ordering::Relaxed);
            if acc >= target {
                return Duration::from_micros(1 << (i + 1));
            }
        }
        Duration::from_micros(1 << 47)
    }
}

/// Sub-buckets per octave of [`TailHistogram`] (8 → at most 12.5%
/// relative error on any quantile estimate).
const TAIL_SUB: usize = 8;
/// Octave groups of [`TailHistogram`]; the last bucket saturates.
const TAIL_GROUPS: usize = 32;
/// Fixed bucket count of [`TailHistogram`] — every histogram has exactly
/// this many buckets, which is what makes the merge deterministic.
pub const TAIL_BUCKETS: usize = TAIL_SUB * TAIL_GROUPS;

/// Bucket index for a latency of `us` microseconds: log-linear (HDR
/// style) — values below [`TAIL_SUB`] get exact buckets, above that each
/// octave is split into [`TAIL_SUB`] equal-width sub-buckets.
fn tail_index(us: u64) -> usize {
    if us < TAIL_SUB as u64 {
        return us as usize;
    }
    let msb = 63 - us.leading_zeros() as usize;
    let group = msb - 2;
    let sub = ((us >> (msb - 3)) & (TAIL_SUB as u64 - 1)) as usize;
    (group * TAIL_SUB + sub).min(TAIL_BUCKETS - 1)
}

/// Inclusive upper edge (µs) of tail bucket `i` — the value quantile
/// estimates report, so estimates never understate the true sample.
fn tail_upper_us(i: usize) -> u64 {
    let group = i / TAIL_SUB;
    let sub = (i % TAIL_SUB) as u64;
    if group == 0 {
        return sub;
    }
    ((TAIL_SUB as u64 + sub + 1) << (group - 1)) - 1
}

/// Fixed-bucket log-scale latency histogram for tail quantiles
/// (p50/p99/p999). Unlike [`Histogram`]'s coarse power-of-two buckets,
/// each octave is split into [`TAIL_SUB`] sub-buckets, bounding the
/// relative error of any quantile estimate by `1/TAIL_SUB`. The bucket
/// layout is identical for every instance, so shard-local histograms
/// merge by bucket-wise addition — associative, commutative, and
/// independent of record order ([`TailSnapshot::merge`]).
#[derive(Debug)]
pub struct TailHistogram {
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
}

impl Default for TailHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl TailHistogram {
    /// Empty histogram.
    pub fn new() -> TailHistogram {
        TailHistogram {
            buckets: (0..TAIL_BUCKETS).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
        }
    }

    /// Record one duration.
    #[inline]
    pub fn record(&self, d: Duration) {
        let us = d.as_micros().min(u64::MAX as u128) as u64;
        self.buckets[tail_index(us)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
    }

    /// Number of recorded durations.
    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Point-in-time copy of the bucket counts (a mergeable value type).
    pub fn snapshot(&self) -> TailSnapshot {
        TailSnapshot {
            counts: self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect(),
        }
    }
}

/// A point-in-time copy of a [`TailHistogram`]'s buckets: the unit of
/// deterministic cross-shard merging and quantile reporting.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TailSnapshot {
    counts: Vec<u64>,
}

impl Default for TailSnapshot {
    fn default() -> Self {
        Self::empty()
    }
}

impl TailSnapshot {
    /// All-zero snapshot (the merge identity).
    pub fn empty() -> TailSnapshot {
        TailSnapshot { counts: vec![0; TAIL_BUCKETS] }
    }

    /// Total samples.
    pub fn count(&self) -> u64 {
        self.counts.iter().sum()
    }

    /// Bucket-wise sum with `other` — associative and commutative by
    /// construction (u64 addition on an identical fixed layout), so any
    /// merge tree over shard-local histograms yields the same result.
    pub fn merge(&self, other: &TailSnapshot) -> TailSnapshot {
        TailSnapshot {
            counts: self
                .counts
                .iter()
                .zip(&other.counts)
                .map(|(a, b)| a.wrapping_add(*b))
                .collect(),
        }
    }

    /// Quantile estimate: the inclusive upper edge of the bucket holding
    /// the ⌈q·n⌉-th smallest sample (never understates the true sample;
    /// overstates it by at most `1/TAIL_SUB` relative). Zero when empty.
    pub fn quantile(&self, q: f64) -> Duration {
        let total = self.count();
        if total == 0 {
            return Duration::ZERO;
        }
        let target = ((total as f64 * q).ceil() as u64).clamp(1, total);
        let mut acc = 0u64;
        for (i, c) in self.counts.iter().enumerate() {
            acc += c;
            if acc >= target {
                return Duration::from_micros(tail_upper_us(i));
            }
        }
        Duration::from_micros(tail_upper_us(TAIL_BUCKETS - 1))
    }

    /// Median latency.
    pub fn p50(&self) -> Duration {
        self.quantile(0.50)
    }

    /// 99th-percentile latency.
    pub fn p99(&self) -> Duration {
        self.quantile(0.99)
    }

    /// 99.9th-percentile latency.
    pub fn p999(&self) -> Duration {
        self.quantile(0.999)
    }
}

/// Aggregated coordinator metrics.
#[derive(Debug, Default)]
pub struct ServiceMetrics {
    /// Requests accepted into the queue.
    pub jobs_submitted: Counter,
    /// Requests fully processed (including errored lookups).
    pub jobs_completed: Counter,
    /// Batches accepted via `submit_batch`.
    pub batches_submitted: Counter,
    /// Rows that exceeded their detection threshold.
    pub faults_detected: Counter,
    /// Detections repaired in place via localization.
    pub faults_corrected: Counter,
    /// Rows recomputed via the escalation path.
    pub rows_recomputed: Counter,
    /// Jobs executed by a worker of a shard other than the one they were
    /// routed to (cross-shard work stealing).
    pub jobs_stolen: Counter,
    /// Campaign grid cells fully executed through this coordinator (the
    /// campaign engine's progress signal).
    pub campaign_cells: Counter,
    /// Campaign injection trials executed through this coordinator.
    pub campaign_trials: Counter,
    /// Requests refused at admission because the target shard queue was
    /// full (open-loop load shedding — never blocks, never computes).
    pub jobs_shed: Counter,
    /// Detections whose recovery was waived by the severity policy: the
    /// residual was provably below output-quantization noise, so the
    /// recompute escalation was skipped.
    pub faults_waived: Counter,
    /// Rows repaired via the column/grid checksum direction — multi-fault
    /// patterns corrected without a recompute (two-dimensional encoding
    /// modes only).
    pub faults_corrected_grid: Counter,
    /// Row localizations that came back `Inconsistent` (multi-fault,
    /// checksum-column upset, or sub-noise fault) — previously folded
    /// silently into the recompute path.
    pub inconsistent_localizations: Counter,
    /// Submission-to-completion latency distribution.
    pub latency: Histogram,
    /// Fine-grained tail-latency histogram (p50/p99/p999) over the same
    /// submission-to-completion durations as [`ServiceMetrics::latency`].
    pub tail: TailHistogram,
}

/// A consistent point-in-time copy of every [`ServiceMetrics`] counter —
/// what [`ServiceMetrics::snapshot`] returns.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MetricsSnapshot {
    /// Requests accepted into the queues.
    pub jobs_submitted: u64,
    /// Requests fully processed (including errored lookups).
    pub jobs_completed: u64,
    /// Batches accepted via `submit_batch`.
    pub batches_submitted: u64,
    /// Rows that exceeded their detection threshold.
    pub faults_detected: u64,
    /// Detections repaired in place via localization.
    pub faults_corrected: u64,
    /// Rows recomputed via the escalation path.
    pub rows_recomputed: u64,
    /// Jobs executed by a non-home shard (work stealing).
    pub jobs_stolen: u64,
    /// Campaign cells executed.
    pub campaign_cells: u64,
    /// Campaign trials executed.
    pub campaign_trials: u64,
    /// Requests shed at admission.
    pub jobs_shed: u64,
    /// Detections waived by the severity policy.
    pub faults_waived: u64,
    /// Rows repaired via the column/grid checksum direction.
    pub faults_corrected_grid: u64,
    /// Row localizations that came back `Inconsistent`.
    pub inconsistent_localizations: u64,
    /// Latencies recorded.
    pub latency_count: u64,
    /// Tail-histogram samples recorded.
    pub tail_count: u64,
}

impl ServiceMetrics {
    /// All-zero metrics.
    pub fn new() -> ServiceMetrics {
        Default::default()
    }

    /// One read of every counter, in a fixed order (the building block of
    /// [`ServiceMetrics::snapshot`]).
    fn read_all(&self) -> MetricsSnapshot {
        MetricsSnapshot {
            jobs_submitted: self.jobs_submitted.get(),
            jobs_completed: self.jobs_completed.get(),
            batches_submitted: self.batches_submitted.get(),
            faults_detected: self.faults_detected.get(),
            faults_corrected: self.faults_corrected.get(),
            rows_recomputed: self.rows_recomputed.get(),
            jobs_stolen: self.jobs_stolen.get(),
            campaign_cells: self.campaign_cells.get(),
            campaign_trials: self.campaign_trials.get(),
            jobs_shed: self.jobs_shed.get(),
            faults_waived: self.faults_waived.get(),
            faults_corrected_grid: self.faults_corrected_grid.get(),
            inconsistent_localizations: self.inconsistent_localizations.get(),
            latency_count: self.latency.count(),
            tail_count: self.tail.count(),
        }
    }

    /// A quiesced, mutually-consistent snapshot of every counter.
    ///
    /// Each counter is individually atomic, but reading them one after
    /// another can observe a torn total (e.g. a drain loop seeing
    /// `jobs_completed > jobs_submitted` because a worker incremented
    /// between the two loads). This method re-reads the full counter set
    /// until two consecutive sweeps agree — the returned value is then a
    /// consistent cut: no counter changed while it was being assembled.
    ///
    /// Intended for quiesce points (after a drain, join, or shutdown).
    /// A sweep is ~a dozen relaxed loads, so even under sustained
    /// traffic two clean sweeps fit inside ordinary inter-update gaps;
    /// after a burst of failed attempts the loop yields the CPU between
    /// retries rather than spinning hot.
    pub fn snapshot(&self) -> MetricsSnapshot {
        let mut prev = self.read_all();
        let mut attempts = 0u32;
        loop {
            let cur = self.read_all();
            if cur == prev {
                return cur;
            }
            prev = cur;
            attempts = attempts.saturating_add(1);
            if attempts > 16 {
                std::thread::yield_now();
            } else {
                std::hint::spin_loop();
            }
        }
    }

    /// One-line human-readable summary of every counter.
    pub fn summary(&self) -> String {
        let tail = self.tail.snapshot();
        format!(
            "jobs={}/{} shed={} batches={} detected={} corrected={} waived={} \
             grid_corrected={} inconsistent={} \
             recomputed_rows={} stolen={} campaign_cells={} campaign_trials={} \
             mean={:?} p50={:?} p99={:?} p999={:?}",
            self.jobs_completed.get(),
            self.jobs_submitted.get(),
            self.jobs_shed.get(),
            self.batches_submitted.get(),
            self.faults_detected.get(),
            self.faults_corrected.get(),
            self.faults_waived.get(),
            self.faults_corrected_grid.get(),
            self.inconsistent_localizations.get(),
            self.rows_recomputed.get(),
            self.jobs_stolen.get(),
            self.campaign_cells.get(),
            self.campaign_trials.get(),
            self.latency.mean(),
            tail.p50(),
            tail.p99(),
            tail.p999(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_basics() {
        let c = Counter::new();
        c.inc();
        c.add(4);
        assert_eq!(c.get(), 5);
    }

    #[test]
    fn histogram_quantiles() {
        let h = Histogram::new();
        for us in [10u64, 20, 40, 80, 100, 1000, 10000] {
            h.record(Duration::from_micros(us));
        }
        assert_eq!(h.count(), 7);
        assert!(h.quantile(0.5) >= Duration::from_micros(32));
        assert!(h.quantile(1.0) >= Duration::from_micros(10000));
        assert!(h.mean() >= Duration::from_micros(1000));
    }

    #[test]
    fn empty_histogram() {
        let h = Histogram::new();
        assert_eq!(h.quantile(0.5), Duration::ZERO);
        assert_eq!(h.mean(), Duration::ZERO);
    }

    /// Exact quantile of a sorted sample: the ⌈q·n⌉-th smallest value —
    /// the definition [`TailSnapshot::quantile`] approximates.
    fn exact_quantile(sorted: &[u64], q: f64) -> u64 {
        let n = sorted.len() as u64;
        let target = ((n as f64 * q).ceil() as u64).clamp(1, n);
        sorted[(target - 1) as usize]
    }

    #[test]
    fn tail_bucket_layout_is_monotone_and_self_consistent() {
        // Every value must land in a bucket whose upper edge is >= the
        // value, bucket indices must be monotone in the value, and the
        // upper edge of bucket i must itself index back to bucket i.
        let mut prev = 0usize;
        for v in (0u64..4096).chain([1 << 20, 1 << 30, 1 << 40, u64::MAX]) {
            let i = tail_index(v);
            assert!(i >= prev, "index not monotone at {v}");
            assert!(i < TAIL_BUCKETS);
            if i < TAIL_BUCKETS - 1 {
                assert!(tail_upper_us(i) >= v, "upper edge below value at {v}");
                assert_eq!(tail_index(tail_upper_us(i)), i, "edge escapes bucket at {v}");
            }
            prev = i;
        }
    }

    #[test]
    fn tail_quantiles_track_exact_sorted_sample_quantiles() {
        // Synthetic distributions with very different shapes; the
        // histogram estimate must bracket the exact quantile within the
        // documented 1/TAIL_SUB relative error (upper edge reporting:
        // never below the exact value).
        let mut rng = 0x9E3779B97F4A7C15u64;
        let mut next = move || {
            rng ^= rng << 13;
            rng ^= rng >> 7;
            rng ^= rng << 17;
            rng
        };
        let uniform: Vec<u64> = (0..5000).map(|_| next() % 100_000).collect();
        let heavy_tail: Vec<u64> =
            (0..5000).map(|_| 10 + (1u64 << (next() % 20)) + next() % 7).collect();
        let constant: Vec<u64> = vec![777; 1000];
        for samples in [uniform, heavy_tail, constant] {
            let h = TailHistogram::new();
            for &s in &samples {
                h.record(Duration::from_micros(s));
            }
            let snap = h.snapshot();
            let mut sorted = samples.clone();
            sorted.sort_unstable();
            for q in [0.5, 0.9, 0.99, 0.999] {
                let exact = exact_quantile(&sorted, q);
                let est = snap.quantile(q).as_micros() as u64;
                assert!(est >= exact, "q={q}: estimate {est} below exact {exact}");
                let bound = exact + exact / TAIL_SUB as u64 + 1;
                assert!(est <= bound, "q={q}: estimate {est} above bound {bound}");
            }
        }
    }

    #[test]
    fn quantile_edge_cases_are_pinned() {
        // Empty histograms report zero at every q — an empty shard
        // merged into a BENCH_serving row must contribute Duration::ZERO,
        // not a sentinel edge.
        let empty = TailSnapshot::empty();
        for q in [0.0, 0.5, 1.0, 1.5] {
            assert_eq!(empty.quantile(q), Duration::ZERO, "empty tail at q={q}");
        }
        let h = Histogram::new();
        for q in [0.0, 0.5, 1.0, 1.5] {
            assert_eq!(h.quantile(q), Duration::ZERO, "empty coarse at q={q}");
        }

        // q = 1.0 (and anything that rounds past the sample count) must
        // return the max occupied bucket's edge, never overrun the scan.
        let h = Histogram::new();
        h.record(Duration::from_micros(100));
        h.record(Duration::from_micros(3000));
        let max_edge = h.quantile(1.0);
        assert!(max_edge >= Duration::from_micros(3000));
        assert!(max_edge < Duration::from_micros(1 << 40), "fell off the scan");
        assert_eq!(h.quantile(1.5), max_edge, "q>1 must clamp to the max sample");

        let t = TailHistogram::new();
        t.record(Duration::from_micros(250));
        t.record(Duration::from_micros(9_000));
        let snap = t.snapshot();
        let top = snap.quantile(1.0);
        assert!(top >= Duration::from_micros(9_000));
        assert!(top <= Duration::from_micros(9_000 + 9_000 / TAIL_SUB as u64 + 1));
        assert_eq!(snap.quantile(2.0), top, "q>1 must clamp to the max sample");
        // q = 0.0 still reports the smallest occupied bucket (the
        // ⌈q·n⌉-th sample clamps to the 1st), not zero.
        assert!(snap.quantile(0.0) >= Duration::from_micros(250));
        assert!(snap.quantile(0.0) < Duration::from_micros(9_000));
    }

    #[test]
    fn single_bucket_saturation_collapses_all_quantiles() {
        // Every sample in one bucket: p50 == p99 == p999 == that
        // bucket's upper edge, whether one sample or millions of
        // logical samples (bucket counts near u64 range still scan
        // without overflow because the clamp caps target at total).
        let t = TailHistogram::new();
        for _ in 0..1000 {
            t.record(Duration::from_micros(777));
        }
        let snap = t.snapshot();
        let edge = Duration::from_micros(tail_upper_us(tail_index(777)));
        assert_eq!(snap.p50(), edge);
        assert_eq!(snap.p99(), edge);
        assert_eq!(snap.p999(), edge);
        assert_eq!(snap.quantile(1.0), edge);

        // The saturating last bucket behaves the same: huge latencies
        // all collapse onto the final edge.
        let t = TailHistogram::new();
        t.record(Duration::from_micros(u64::MAX));
        t.record(Duration::from_micros(u64::MAX / 2));
        let snap = t.snapshot();
        let last = Duration::from_micros(tail_upper_us(TAIL_BUCKETS - 1));
        assert_eq!(snap.p50(), last);
        assert_eq!(snap.p999(), last);
    }

    #[test]
    fn tail_merge_with_empty_and_mismatched_histories_is_exact() {
        // Merging an empty shard must not perturb any quantile — the
        // exact failure mode behind a corrupted BENCH_serving merge row.
        let t = TailHistogram::new();
        for us in [10u64, 40, 90, 2_000, 55_000] {
            t.record(Duration::from_micros(us));
        }
        let snap = t.snapshot();
        let merged = snap.merge(&TailSnapshot::empty());
        assert_eq!(merged, snap);
        for q in [0.5, 0.99, 0.999, 1.0] {
            assert_eq!(merged.quantile(q), snap.quantile(q), "q={q} drifted");
        }
        // And the merge of two snapshots equals the histogram that
        // recorded both sample sets directly.
        let a = TailHistogram::new();
        let b = TailHistogram::new();
        let both = TailHistogram::new();
        for us in [10u64, 40, 90] {
            a.record(Duration::from_micros(us));
            both.record(Duration::from_micros(us));
        }
        for us in [2_000u64, 55_000] {
            b.record(Duration::from_micros(us));
            both.record(Duration::from_micros(us));
        }
        assert_eq!(a.snapshot().merge(&b.snapshot()), both.snapshot());
    }

    #[test]
    fn tail_merge_is_associative_and_commutative() {
        // Three "shard-local" histograms with disjoint latency regimes.
        let mk = |base: u64, n: u64| {
            let h = TailHistogram::new();
            for i in 0..n {
                h.record(Duration::from_micros(base + i * 3));
            }
            h.snapshot()
        };
        let (a, b, c) = (mk(10, 400), mk(5_000, 300), mk(900_000, 200));
        assert_eq!(a.merge(&b), b.merge(&a), "merge must be commutative");
        assert_eq!(
            a.merge(&b).merge(&c),
            a.merge(&b.merge(&c)),
            "merge must be associative"
        );
        assert_eq!(a.merge(&TailSnapshot::empty()), a, "empty is the identity");
        let merged = a.merge(&b).merge(&c);
        assert_eq!(merged.count(), 900);
        // Quantiles of the merge reflect the union: the p50 sits in the
        // mid regime, the p999 in the slow one.
        assert!(merged.p50() >= Duration::from_micros(1_000));
        assert!(merged.p50() < Duration::from_micros(900_000));
        assert!(merged.p999() >= Duration::from_micros(900_000));
    }

    #[test]
    fn tail_snapshot_never_tears_under_concurrent_records() {
        // Mirror of `snapshot_is_a_consistent_cut…` for the tail
        // histogram: the writer records exactly one sample per
        // `jobs_completed` increment, completed-then-record order, so at
        // every instant tail_count <= jobs_completed. A torn read of the
        // two would invert that.
        use std::sync::Arc;
        const N: u64 = 20_000;
        let m = Arc::new(ServiceMetrics::new());
        let w = {
            let m = Arc::clone(&m);
            std::thread::spawn(move || {
                for i in 0..N {
                    m.jobs_completed.inc();
                    m.tail.record(Duration::from_micros(i % 512));
                }
            })
        };
        while !w.is_finished() {
            let s = m.snapshot();
            assert!(
                s.jobs_completed >= s.tail_count,
                "torn snapshot: completed {} < tail samples {}",
                s.jobs_completed,
                s.tail_count
            );
        }
        w.join().unwrap();
        let s = m.snapshot();
        assert_eq!((s.jobs_completed, s.tail_count), (N, N));
        assert_eq!(m.tail.snapshot().count(), N);
    }

    #[test]
    fn snapshot_is_a_consistent_cut_under_concurrent_updates() {
        // The writer maintains the invariant `jobs_submitted ≥
        // jobs_completed` at every instant (submitted is always
        // incremented first). Naive field-by-field reads can tear it —
        // read submitted, lose the race, read a newer completed.
        // `snapshot()` must never expose a torn pair, and must converge
        // to the exact totals once the writer quiesces.
        use std::sync::Arc;
        const N: u64 = 20_000;
        let m = Arc::new(ServiceMetrics::new());
        let w = {
            let m = Arc::clone(&m);
            std::thread::spawn(move || {
                for _ in 0..N {
                    m.jobs_submitted.inc();
                    m.jobs_completed.inc();
                }
            })
        };
        while !w.is_finished() {
            let s = m.snapshot();
            assert!(
                s.jobs_submitted >= s.jobs_completed,
                "torn snapshot: submitted {} < completed {}",
                s.jobs_submitted,
                s.jobs_completed
            );
        }
        w.join().unwrap();
        let s = m.snapshot();
        assert_eq!((s.jobs_submitted, s.jobs_completed), (N, N));
        assert_eq!(s, m.snapshot(), "quiesced snapshots must be stable");
    }
}
