//! Lightweight metrics: counters and latency histograms for the
//! coordinator's hot path (lock-free, allocation-free on record).

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

/// Monotonic counter.
#[derive(Debug, Default)]
pub struct Counter {
    v: AtomicU64,
}

impl Counter {
    /// Zeroed counter.
    pub const fn new() -> Counter {
        Counter { v: AtomicU64::new(0) }
    }

    /// Increment by one.
    #[inline]
    pub fn inc(&self) {
        self.v.fetch_add(1, Ordering::Relaxed);
    }

    /// Increment by `n`.
    #[inline]
    pub fn add(&self, n: u64) {
        self.v.fetch_add(n, Ordering::Relaxed);
    }

    /// Current value.
    pub fn get(&self) -> u64 {
        self.v.load(Ordering::Relaxed)
    }
}

/// Log₂-bucketed latency histogram: bucket i counts durations in
/// [2^i, 2^(i+1)) microseconds. 48 buckets cover ns..days.
#[derive(Debug)]
pub struct Histogram {
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    sum_us: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    /// Empty histogram.
    pub fn new() -> Histogram {
        Histogram {
            buckets: (0..48).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum_us: AtomicU64::new(0),
        }
    }

    /// Record one duration.
    #[inline]
    pub fn record(&self, d: Duration) {
        let us = d.as_micros() as u64;
        let idx = (64 - us.max(1).leading_zeros() as usize - 1).min(self.buckets.len() - 1);
        self.buckets[idx].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add(us, Ordering::Relaxed);
    }

    /// Number of recorded durations.
    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Mean recorded duration (zero when empty).
    pub fn mean(&self) -> Duration {
        let c = self.count();
        if c == 0 {
            return Duration::ZERO;
        }
        Duration::from_micros(self.sum_us.load(Ordering::Relaxed) / c)
    }

    /// Approximate quantile (upper edge of the bucket containing it).
    pub fn quantile(&self, q: f64) -> Duration {
        let total = self.count();
        if total == 0 {
            return Duration::ZERO;
        }
        let target = (total as f64 * q).ceil() as u64;
        let mut acc = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            acc += b.load(Ordering::Relaxed);
            if acc >= target {
                return Duration::from_micros(1 << (i + 1));
            }
        }
        Duration::from_micros(1 << 47)
    }
}

/// Aggregated coordinator metrics.
#[derive(Debug, Default)]
pub struct ServiceMetrics {
    /// Requests accepted into the queue.
    pub jobs_submitted: Counter,
    /// Requests fully processed (including errored lookups).
    pub jobs_completed: Counter,
    /// Batches accepted via `submit_batch`.
    pub batches_submitted: Counter,
    /// Rows that exceeded their detection threshold.
    pub faults_detected: Counter,
    /// Detections repaired in place via localization.
    pub faults_corrected: Counter,
    /// Rows recomputed via the escalation path.
    pub rows_recomputed: Counter,
    /// Jobs executed by a worker of a shard other than the one they were
    /// routed to (cross-shard work stealing).
    pub jobs_stolen: Counter,
    /// Campaign grid cells fully executed through this coordinator (the
    /// campaign engine's progress signal).
    pub campaign_cells: Counter,
    /// Campaign injection trials executed through this coordinator.
    pub campaign_trials: Counter,
    /// Submission-to-completion latency distribution.
    pub latency: Histogram,
}

/// A consistent point-in-time copy of every [`ServiceMetrics`] counter —
/// what [`ServiceMetrics::snapshot`] returns.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MetricsSnapshot {
    /// Requests accepted into the queues.
    pub jobs_submitted: u64,
    /// Requests fully processed (including errored lookups).
    pub jobs_completed: u64,
    /// Batches accepted via `submit_batch`.
    pub batches_submitted: u64,
    /// Rows that exceeded their detection threshold.
    pub faults_detected: u64,
    /// Detections repaired in place via localization.
    pub faults_corrected: u64,
    /// Rows recomputed via the escalation path.
    pub rows_recomputed: u64,
    /// Jobs executed by a non-home shard (work stealing).
    pub jobs_stolen: u64,
    /// Campaign cells executed.
    pub campaign_cells: u64,
    /// Campaign trials executed.
    pub campaign_trials: u64,
    /// Latencies recorded.
    pub latency_count: u64,
}

impl ServiceMetrics {
    /// All-zero metrics.
    pub fn new() -> ServiceMetrics {
        Default::default()
    }

    /// One read of every counter, in a fixed order (the building block of
    /// [`ServiceMetrics::snapshot`]).
    fn read_all(&self) -> MetricsSnapshot {
        MetricsSnapshot {
            jobs_submitted: self.jobs_submitted.get(),
            jobs_completed: self.jobs_completed.get(),
            batches_submitted: self.batches_submitted.get(),
            faults_detected: self.faults_detected.get(),
            faults_corrected: self.faults_corrected.get(),
            rows_recomputed: self.rows_recomputed.get(),
            jobs_stolen: self.jobs_stolen.get(),
            campaign_cells: self.campaign_cells.get(),
            campaign_trials: self.campaign_trials.get(),
            latency_count: self.latency.count(),
        }
    }

    /// A quiesced, mutually-consistent snapshot of every counter.
    ///
    /// Each counter is individually atomic, but reading them one after
    /// another can observe a torn total (e.g. a drain loop seeing
    /// `jobs_completed > jobs_submitted` because a worker incremented
    /// between the two loads). This method re-reads the full counter set
    /// until two consecutive sweeps agree — the returned value is then a
    /// consistent cut: no counter changed while it was being assembled.
    ///
    /// Intended for quiesce points (after a drain, join, or shutdown).
    /// A sweep is ~a dozen relaxed loads, so even under sustained
    /// traffic two clean sweeps fit inside ordinary inter-update gaps;
    /// after a burst of failed attempts the loop yields the CPU between
    /// retries rather than spinning hot.
    pub fn snapshot(&self) -> MetricsSnapshot {
        let mut prev = self.read_all();
        let mut attempts = 0u32;
        loop {
            let cur = self.read_all();
            if cur == prev {
                return cur;
            }
            prev = cur;
            attempts = attempts.saturating_add(1);
            if attempts > 16 {
                std::thread::yield_now();
            } else {
                std::hint::spin_loop();
            }
        }
    }

    /// One-line human-readable summary of every counter.
    pub fn summary(&self) -> String {
        format!(
            "jobs={}/{} batches={} detected={} corrected={} recomputed_rows={} stolen={} \
             campaign_cells={} campaign_trials={} mean={:?} p95={:?}",
            self.jobs_completed.get(),
            self.jobs_submitted.get(),
            self.batches_submitted.get(),
            self.faults_detected.get(),
            self.faults_corrected.get(),
            self.rows_recomputed.get(),
            self.jobs_stolen.get(),
            self.campaign_cells.get(),
            self.campaign_trials.get(),
            self.latency.mean(),
            self.latency.quantile(0.95),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_basics() {
        let c = Counter::new();
        c.inc();
        c.add(4);
        assert_eq!(c.get(), 5);
    }

    #[test]
    fn histogram_quantiles() {
        let h = Histogram::new();
        for us in [10u64, 20, 40, 80, 100, 1000, 10000] {
            h.record(Duration::from_micros(us));
        }
        assert_eq!(h.count(), 7);
        assert!(h.quantile(0.5) >= Duration::from_micros(32));
        assert!(h.quantile(1.0) >= Duration::from_micros(10000));
        assert!(h.mean() >= Duration::from_micros(1000));
    }

    #[test]
    fn empty_histogram() {
        let h = Histogram::new();
        assert_eq!(h.quantile(0.5), Duration::ZERO);
        assert_eq!(h.mean(), Duration::ZERO);
    }

    #[test]
    fn snapshot_is_a_consistent_cut_under_concurrent_updates() {
        // The writer maintains the invariant `jobs_submitted ≥
        // jobs_completed` at every instant (submitted is always
        // incremented first). Naive field-by-field reads can tear it —
        // read submitted, lose the race, read a newer completed.
        // `snapshot()` must never expose a torn pair, and must converge
        // to the exact totals once the writer quiesces.
        use std::sync::Arc;
        const N: u64 = 20_000;
        let m = Arc::new(ServiceMetrics::new());
        let w = {
            let m = Arc::clone(&m);
            std::thread::spawn(move || {
                for _ in 0..N {
                    m.jobs_submitted.inc();
                    m.jobs_completed.inc();
                }
            })
        };
        while !w.is_finished() {
            let s = m.snapshot();
            assert!(
                s.jobs_submitted >= s.jobs_completed,
                "torn snapshot: submitted {} < completed {}",
                s.jobs_submitted,
                s.jobs_completed
            );
        }
        w.join().unwrap();
        let s = m.snapshot();
        assert_eq!((s.jobs_submitted, s.jobs_completed), (N, N));
        assert_eq!(s, m.snapshot(), "quiesced snapshots must be stable");
    }
}
