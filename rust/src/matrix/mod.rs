//! Dense row-major matrices with single-pass row statistics.
//!
//! Values are carried as f64 regardless of the *logical* precision: the
//! GEMM engines quantize at exactly the points the accumulation model
//! dictates (see [`crate::gemm`]), which is the behaviour the paper
//! studies. A matrix whose elements all lie on the BF16 grid *is* a BF16
//! matrix for every experiment in the paper; carrying them in f64 adds no
//! information and keeps one code path for all six precisions.

use crate::fp::Precision;
use crate::rng::{Distribution, Rng};

mod stats;
pub use stats::RowStats;

/// Dense row-major matrix of f64 carriers.
#[derive(Debug, Clone, PartialEq)]
pub struct Matrix {
    rows: usize,
    cols: usize,
    data: Vec<f64>,
}

impl Matrix {
    /// All-zeros matrix.
    pub fn zeros(rows: usize, cols: usize) -> Matrix {
        Matrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Build from a row-major vector (length must equal rows × cols).
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Matrix {
        assert_eq!(data.len(), rows * cols, "shape/data mismatch");
        Matrix { rows, cols, data }
    }

    /// Build by evaluating `f(i, j)`.
    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f64) -> Matrix {
        let mut data = Vec::with_capacity(rows * cols);
        for i in 0..rows {
            for j in 0..cols {
                data.push(f(i, j));
            }
        }
        Matrix { rows, cols, data }
    }

    /// Sample each element i.i.d. from `dist`.
    pub fn sample<R: Rng + ?Sized>(
        rows: usize,
        cols: usize,
        dist: &Distribution,
        rng: &mut R,
    ) -> Matrix {
        let mut m = Matrix::zeros(rows, cols);
        dist.sample_into(&mut m.data, rng);
        m
    }

    /// Sample and quantize every element onto `precision`'s grid — the
    /// standard way to create a "BF16 matrix" etc. for the experiments.
    pub fn sample_in(
        rows: usize,
        cols: usize,
        dist: &Distribution,
        precision: Precision,
        rng: &mut impl Rng,
    ) -> Matrix {
        let mut m = Self::sample(rows, cols, dist, rng);
        m.quantize(precision);
        m
    }

    /// Number of rows.
    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Number of columns.
    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Element (i, j).
    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f64 {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i * self.cols + j]
    }

    /// Set element (i, j).
    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f64) {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i * self.cols + j] = v;
    }

    /// Row `i` as a slice.
    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Mutable row `i`.
    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Flat row-major data.
    #[inline]
    pub fn data(&self) -> &[f64] {
        &self.data
    }

    /// Mutable flat row-major data.
    #[inline]
    pub fn data_mut(&mut self) -> &mut [f64] {
        &mut self.data
    }

    /// Quantize every element onto `precision`'s grid in place.
    pub fn quantize(&mut self, precision: Precision) {
        if precision == Precision::F64 {
            return;
        }
        for v in &mut self.data {
            *v = precision.quantize(*v);
        }
    }

    /// A copy quantized to `precision`.
    pub fn quantized(&self, precision: Precision) -> Matrix {
        let mut m = self.clone();
        m.quantize(precision);
        m
    }

    /// Transpose (copying).
    pub fn transpose(&self) -> Matrix {
        let mut t = Matrix::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                t.data[j * self.rows + i] = self.data[i * self.cols + j];
            }
        }
        t
    }

    /// Single-pass (max, min, mean) statistics of row `i` — the only
    /// statistics V-ABFT needs (Algorithm 1), O(n) per row.
    pub fn row_stats(&self, i: usize) -> RowStats {
        RowStats::of(self.row(i))
    }

    /// Single-pass (max, min, mean) without the diagnostic variance —
    /// the production threshold path (see [`RowStats::fast`]).
    #[inline]
    pub fn row_stats_fast(&self, i: usize) -> RowStats {
        RowStats::fast(self.row(i))
    }

    /// Statistics of every row.
    pub fn all_row_stats(&self) -> Vec<RowStats> {
        (0..self.rows).map(|i| self.row_stats(i)).collect()
    }

    /// Column sums: out[j] = Σ_i M[i][j] (plain f64 accumulation).
    pub fn col_sums(&self) -> Vec<f64> {
        let mut out = vec![0.0; self.cols];
        for i in 0..self.rows {
            let row = self.row(i);
            for (o, &v) in out.iter_mut().zip(row) {
                *o += v;
            }
        }
        out
    }

    /// Row sums: out[i] = Σ_j M[i][j].
    pub fn row_sums(&self) -> Vec<f64> {
        (0..self.rows).map(|i| self.row(i).iter().sum()).collect()
    }

    /// Max |element|.
    pub fn max_abs(&self) -> f64 {
        self.data.iter().fold(0.0f64, |m, &v| m.max(v.abs()))
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f64 {
        self.data.iter().map(|v| v * v).sum::<f64>().sqrt()
    }

    /// Element-wise maximum absolute difference against another matrix.
    pub fn max_abs_diff(&self, other: &Matrix) -> f64 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .fold(0.0f64, |m, (&a, &b)| m.max((a - b).abs()))
    }

    /// Take ownership of the data.
    pub fn into_vec(self) -> Vec<f64> {
        self.data
    }

    /// View of the first `r` rows (copying).
    pub fn top_rows(&self, r: usize) -> Matrix {
        assert!(r <= self.rows);
        Matrix { rows: r, cols: self.cols, data: self.data[..r * self.cols].to_vec() }
    }
}

impl std::fmt::Display for Matrix {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        writeln!(f, "Matrix {}x{} [", self.rows, self.cols)?;
        let show_rows = self.rows.min(6);
        for i in 0..show_rows {
            let row = self.row(i);
            let shown: Vec<String> =
                row.iter().take(8).map(|v| format!("{v:>11.4e}")).collect();
            let ell = if self.cols > 8 { ", ..." } else { "" };
            writeln!(f, "  [{}{}]", shown.join(", "), ell)?;
        }
        if self.rows > show_rows {
            writeln!(f, "  ...")?;
        }
        write!(f, "]")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Xoshiro256pp;

    #[test]
    fn construction_and_indexing() {
        let m = Matrix::from_fn(3, 4, |i, j| (i * 10 + j) as f64);
        assert_eq!(m.get(0, 0), 0.0);
        assert_eq!(m.get(2, 3), 23.0);
        assert_eq!(m.row(1), &[10.0, 11.0, 12.0, 13.0]);
    }

    #[test]
    fn transpose_roundtrip() {
        let mut rng = Xoshiro256pp::seed_from_u64(1);
        let m = Matrix::sample(5, 7, &Distribution::uniform_pm1(), &mut rng);
        assert_eq!(m.transpose().transpose(), m);
        assert_eq!(m.transpose().get(3, 2), m.get(2, 3));
    }

    #[test]
    fn sums() {
        let m = Matrix::from_vec(2, 3, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert_eq!(m.row_sums(), vec![6.0, 15.0]);
        assert_eq!(m.col_sums(), vec![5.0, 7.0, 9.0]);
    }

    #[test]
    fn quantize_snaps_to_grid() {
        let mut m = Matrix::from_vec(1, 2, vec![1.0 + 2e-4, -3.14159]);
        m.quantize(Precision::Bf16);
        assert_eq!(m.get(0, 0), 1.0); // 1+2e-4 rounds to 1.0 in bf16
        assert_eq!(m.get(0, 1), -3.140625);
    }

    #[test]
    fn sample_in_lands_on_grid() {
        let mut rng = Xoshiro256pp::seed_from_u64(3);
        let m = Matrix::sample_in(8, 8, &Distribution::normal_1_1(), Precision::F16, &mut rng);
        for &v in m.data() {
            assert_eq!(Precision::F16.quantize(v), v);
        }
    }

    #[test]
    fn norms_and_diffs() {
        let a = Matrix::from_vec(1, 3, vec![3.0, 0.0, 4.0]);
        assert_eq!(a.fro_norm(), 5.0);
        assert_eq!(a.max_abs(), 4.0);
        let b = Matrix::from_vec(1, 3, vec![3.0, 1.0, 4.5]);
        assert_eq!(a.max_abs_diff(&b), 1.0);
    }
}
