//! Single-pass row statistics — the O(n) primitive behind V-ABFT.
//!
//! Algorithm 1 in the paper needs, per row: mean, max, min (for the
//! extrema-variance bound) — nothing else. `RowStats` computes these in
//! one fused pass and also records the exact sum of squares so tests can
//! compare the extrema bound against the true variance (Theorem 1's
//! guarantee is `var ≤ (max-μ)(μ-min)`).

/// Summary statistics of one row, computed in a single pass.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RowStats {
    /// Number of elements.
    pub n: usize,
    /// Arithmetic mean.
    pub mean: f64,
    /// Largest element.
    pub max: f64,
    /// Smallest element.
    pub min: f64,
    /// True population variance (kept for tests/diagnostics; the V-ABFT
    /// production path uses only `extrema_var_bound`).
    pub variance: f64,
}

impl RowStats {
    /// Production single-pass statistics: max/min/mean only — all V-ABFT
    /// needs (Algorithm 1). `variance` is set to NaN; use [`RowStats::of`]
    /// when the true variance is wanted for diagnostics/tests.
    #[inline]
    pub fn fast(xs: &[f64]) -> RowStats {
        assert!(!xs.is_empty(), "row statistics of empty slice");
        let n = xs.len();
        // 4 independent accumulator lanes break the serial max/min/add
        // dependency chains so the loop vectorizes.
        let mut mx = [f64::NEG_INFINITY; 4];
        let mut mn = [f64::INFINITY; 4];
        let mut sm = [0.0f64; 4];
        let chunks = xs.chunks_exact(4);
        let rem = chunks.remainder();
        for c in chunks {
            for l in 0..4 {
                mx[l] = mx[l].max(c[l]);
                mn[l] = mn[l].min(c[l]);
                sm[l] += c[l];
            }
        }
        let mut max = mx[0].max(mx[1]).max(mx[2]).max(mx[3]);
        let mut min = mn[0].min(mn[1]).min(mn[2]).min(mn[3]);
        let mut sum = sm[0] + sm[1] + sm[2] + sm[3];
        for &x in rem {
            max = max.max(x);
            min = min.min(x);
            sum += x;
        }
        RowStats { n, mean: sum / n as f64, max, min, variance: f64::NAN }
    }

    /// Compute statistics of `xs`. Panics on empty input.
    pub fn of(xs: &[f64]) -> RowStats {
        assert!(!xs.is_empty(), "row statistics of empty slice");
        let n = xs.len();
        let mut max = f64::NEG_INFINITY;
        let mut min = f64::INFINITY;
        let mut sum = 0.0;
        for &x in xs {
            max = max.max(x);
            min = min.min(x);
            sum += x;
        }
        let mean = sum / n as f64;
        // Second pass for a numerically stable variance (diagnostics only;
        // the hot path in gemm/fused_stats.rs skips it).
        let mut ss = 0.0;
        for &x in xs {
            let d = x - mean;
            ss += d * d;
        }
        RowStats { n, mean, max, min, variance: ss / n as f64 }
    }

    /// Theorem 1 (extrema-variance bound): σ² ≤ (max − μ)(μ − min).
    ///
    /// Tight when mass clusters at the extremes; a constant-factor
    /// overestimate for well-spread data — conservative, hence safe for
    /// thresholds. Both factors are ≥ 0 by definition of max/min/mean;
    /// we clamp at 0 against roundoff.
    #[inline]
    pub fn extrema_var_bound(&self) -> f64 {
        ((self.max - self.mean) * (self.mean - self.min)).max(0.0)
    }

    /// √ of the extrema variance bound.
    #[inline]
    pub fn extrema_std_bound(&self) -> f64 {
        self.extrema_var_bound().sqrt()
    }

    /// Largest absolute element (max(|max|, |min|)) — used by the A-ABFT
    /// baseline's `y` parameter and by the analytical bounds.
    #[inline]
    pub fn max_abs(&self) -> f64 {
        self.max.abs().max(self.min.abs())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::{Distribution, Xoshiro256pp};

    #[test]
    fn basic_stats() {
        let s = RowStats::of(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.mean, 2.5);
        assert_eq!(s.max, 4.0);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.variance, 1.25);
        assert_eq!(s.max_abs(), 4.0);
    }

    #[test]
    fn extrema_bound_dominates_variance() {
        // Property test over many random rows: Theorem 1 must hold.
        let mut rng = Xoshiro256pp::seed_from_u64(11);
        let dists = [
            Distribution::near_zero_normal(),
            Distribution::normal_1_1(),
            Distribution::uniform_pm1(),
            Distribution::truncated_normal(),
            Distribution::calibration(),
        ];
        for d in &dists {
            for len in [2usize, 3, 17, 256, 1024] {
                let xs: Vec<f64> = (0..len).map(|_| d.sample(&mut rng)).collect();
                let s = RowStats::of(&xs);
                assert!(
                    s.variance <= s.extrema_var_bound() * (1.0 + 1e-12) + 1e-300,
                    "Theorem 1 violated: var={} bound={} dist={} len={}",
                    s.variance,
                    s.extrema_var_bound(),
                    d.label(),
                    len
                );
            }
        }
    }

    #[test]
    fn extrema_bound_tight_at_two_point_mass() {
        // Half the mass at each extreme: bound equals variance exactly.
        let xs = [1.0, -1.0, 1.0, -1.0];
        let s = RowStats::of(&xs);
        assert!((s.variance - s.extrema_var_bound()).abs() < 1e-15);
    }

    #[test]
    fn constant_row_has_zero_bound() {
        let s = RowStats::of(&[5.0; 100]);
        assert_eq!(s.extrema_var_bound(), 0.0);
        assert_eq!(s.variance, 0.0);
    }

    #[test]
    fn single_element_row() {
        let s = RowStats::of(&[42.0]);
        assert_eq!(s.mean, 42.0);
        assert_eq!(s.extrema_var_bound(), 0.0);
    }
}
