//! Threshold determination for ABFT verification.
//!
//! The fundamental tension (paper §2.2): a threshold must exceed every
//! legitimate floating-point rounding difference between the two
//! verification paths (else false positives), yet sit as low as possible
//! (else real faults slip through). This module provides the paper's
//! contribution and its three baselines behind one trait:
//!
//! * [`VabftThreshold`] — **V-ABFT** (§3): direct statistical modelling of
//!   the verification difference using only per-row max/min/mean (O(n)).
//! * [`AabftThreshold`] — **A-ABFT** (Braun, Halder & Wunderlich, DSN'14),
//!   reproduced per §4.1: probabilistic inner-product bound, 3σ threshold.
//! * [`AnalyticalThreshold`] — Higham-style worst-case γ_n bound.
//! * [`SeaThreshold`] — Simplified Error Analysis (Roy-Chowdhury &
//!   Banerjee, FTCS'93) — reconstructed first-order deterministic bound.

mod aabft;
mod analytical;
mod sea;
mod vabft;

pub use aabft::{AabftThreshold, YMode};
pub use analytical::AnalyticalThreshold;
pub use sea::SeaThreshold;
pub use vabft::{BSummary, VabftThreshold};

use crate::calibrate::EmaxModel;
use crate::gemm::AccumModel;
use crate::matrix::{Matrix, RowStats};

/// Everything a threshold algorithm may consult about the verification
/// setting. The decisive field is `online`: fused-kernel verification reads
/// the FP32 accumulator (e_max ≈ 1e-6) while offline verification sees the
/// quantized output (e_max ≈ 2·u_out) — §3.6.
#[derive(Debug, Clone, Copy)]
pub struct ThresholdContext {
    /// Accumulation model of the GEMM being verified.
    pub model: AccumModel,
    /// Verify before (true) or after (false) output quantization.
    pub online: bool,
    /// Override the e_max law (None = derive from `model`/`online` via
    /// [`crate::calibrate::EmaxTable::for_model`]).
    pub emax_override: Option<EmaxModel>,
}

impl ThresholdContext {
    /// Context for offline (post-quantization) verification.
    pub fn offline(model: AccumModel) -> ThresholdContext {
        ThresholdContext { model, online: false, emax_override: None }
    }

    /// Context for online (fused-kernel, pre-quantization) verification.
    pub fn online(model: AccumModel) -> ThresholdContext {
        ThresholdContext { model, online: true, emax_override: None }
    }

    /// Override the e_max law (e.g. with a calibrated value).
    pub fn with_emax(mut self, emax: EmaxModel) -> ThresholdContext {
        self.emax_override = Some(emax);
        self
    }

    /// The e_max value for reduction length `n`.
    pub fn emax(&self, n: usize) -> f64 {
        self.emax_override
            .unwrap_or_else(|| crate::calibrate::EmaxTable::for_model(self.model, self.online))
            .eval(n)
    }
}

/// A threshold algorithm: maps (A, B, context) to one detection threshold
/// per row of C = A·B, bounding |checksum − rowsum| on fault-free data.
///
/// ```
/// use vabft::prelude::*;
/// use vabft::threshold::ThresholdContext;
///
/// let mut rng = Xoshiro256pp::seed_from_u64(3);
/// let d = Distribution::uniform_pm1();
/// let model = AccumModel::gpu_highprec(Precision::F32);
/// let a = Matrix::sample_in(8, 256, &d, model.input, &mut rng);
/// let b = Matrix::sample_in(256, 256, &d, model.input, &mut rng);
///
/// let ctx = ThresholdContext::offline(model);
/// let algo: &dyn Threshold = &VabftThreshold::default();
/// let t = algo.thresholds(&a, &b, &ctx);
/// assert_eq!(t.len(), 8); // one threshold per row of C
/// assert!(t.iter().all(|&x| x.is_finite() && x > 0.0));
/// ```
pub trait Threshold: Send + Sync {
    /// Display name of the algorithm (used by reports and benches).
    fn name(&self) -> &'static str;

    /// Per-row thresholds for verifying C = A·B.
    fn thresholds(&self, a: &Matrix, b: &Matrix, ctx: &ThresholdContext) -> Vec<f64>;

    /// Serving fast path: thresholds against a weight matrix whose summary
    /// was precomputed once (see [`PreparedBStats`]). The default falls
    /// back to the full two-operand path; V-ABFT overrides it to skip the
    /// O(KN) pass over B entirely.
    fn thresholds_prepared(
        &self,
        a: &Matrix,
        prepared: &PreparedBStats,
        ctx: &ThresholdContext,
    ) -> Vec<f64> {
        self.thresholds(a, &prepared.b, ctx)
    }

    /// Per-*column* thresholds for verifying the A-side column-checksum
    /// direction of C = A·B (one threshold per column of C, bounding
    /// |column checksum − column sum| on fault-free data).
    ///
    /// Derived by transpose symmetry: Cᵀ = Bᵀ·Aᵀ, so column j of C is
    /// row j of a GEMM whose "A" is Bᵀ and whose "B" is Aᵀ — the row
    /// machinery applies verbatim with the operands swapped and
    /// transposed. The e_max reduction length becomes max(M, K) (column
    /// sums run over the M data rows).
    fn thresholds_columns(&self, a: &Matrix, b: &Matrix, ctx: &ThresholdContext) -> Vec<f64> {
        self.thresholds(&b.transpose(), &a.transpose(), ctx)
    }

    /// Serving fast path for the column direction, against per-weight
    /// state precomputed once (see [`PreparedColStats`]). The default
    /// reuses the cached Bᵀ; V-ABFT overrides it to use only the cached
    /// per-column statistics.
    fn thresholds_columns_prepared(
        &self,
        a: &Matrix,
        prepared: &PreparedColStats,
        ctx: &ThresholdContext,
    ) -> Vec<f64> {
        self.thresholds(&prepared.bt, &a.transpose(), ctx)
    }

    /// Asymptotic cost per row of A, for the complexity comparison
    /// (§4.4): V-ABFT is O(K) (one max/min/mean pass), A-ABFT O(pK).
    fn complexity(&self) -> &'static str {
        "O(n)"
    }
}

/// Precomputed per-weight-matrix state shared across many requests in the
/// serving coordinator: the matrix itself (baselines need it) plus the
/// one-pass V-ABFT summary. One of these is cached per K-block inside
/// [`crate::abft::PreparedWeights`].
#[derive(Debug, Clone)]
pub struct PreparedBStats {
    /// The (block of the) weight matrix — the fallback operand for
    /// threshold algorithms without a prepared fast path, and the
    /// recomputation-escalation operand.
    pub b: Matrix,
    /// One-pass V-ABFT summary of `b` (Σ|μ|, Σμ², Σσ² per Theorem 1).
    pub bsum: BSummary,
}

impl PreparedBStats {
    /// One pass over B: clone the data and build the V-ABFT summary.
    pub fn of(b: &Matrix) -> PreparedBStats {
        PreparedBStats { b: b.clone(), bsum: BSummary::of(b) }
    }
}

/// Per-weight state for the *column*-checksum direction, the transpose
/// mirror of [`PreparedBStats`]: column-direction thresholds need the
/// per-column statistics of B (the "row of A" role under Cᵀ = Bᵀ·Aᵀ),
/// which depend only on the weight matrix and are cached once per
/// K-block alongside the row-direction state.
#[derive(Debug, Clone)]
pub struct PreparedColStats {
    /// Bᵀ — the fallback operand for algorithms without a prepared
    /// column fast path (mirrors [`PreparedBStats::b`]).
    pub bt: Matrix,
    /// Per-column statistics of B (= row stats of `bt`), in column
    /// order — the O(K) inputs of Algorithm 1 in the column direction.
    pub cols: Vec<RowStats>,
}

impl PreparedColStats {
    /// One transpose + one stats pass over B's columns.
    pub fn of(b: &Matrix) -> PreparedColStats {
        let bt = b.transpose();
        let cols = (0..bt.rows()).map(|j| bt.row_stats_fast(j)).collect();
        PreparedColStats { bt, cols }
    }

    /// Rows of B (the dot-product reduction length, for e_max).
    pub fn k(&self) -> usize {
        self.bt.cols()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fp::Precision;
    use crate::gemm::GemmEngine;
    use crate::rng::{Distribution, Xoshiro256pp};

    /// Shared harness: every algorithm must produce thresholds that are
    /// positive and that bound the actual verification difference on clean
    /// data (zero false positives) for a basket of distributions.
    fn check_no_false_positives(t: &dyn Threshold, model: AccumModel, dist: &Distribution) {
        let engine = GemmEngine::new(model);
        let ctx = ThresholdContext::offline(model);
        for (m, k, n, seed) in [(16usize, 64usize, 32usize, 1u64), (8, 128, 64, 2)] {
            let mut rng = Xoshiro256pp::seed_from_u64(seed);
            let a = Matrix::sample_in(m, k, dist, model.input, &mut rng);
            let b = Matrix::sample_in(k, n, dist, model.input, &mut rng);
            let ths = t.thresholds(&a, &b, &ctx);
            assert_eq!(ths.len(), m);
            // Build verification difference without faults.
            let benc = crate::abft::encode::r1_checksum_of_b(&b, &engine);
            let mut bext = Matrix::zeros(k, n + 1);
            for r in 0..k {
                bext.row_mut(r)[..n].copy_from_slice(b.row(r));
                bext.set(r, n, benc[r]);
            }
            let out = engine.matmul(&a, &bext);
            for i in 0..m {
                let row = out.c.row(i);
                let e = (row[n] - engine.reduce(&row[..n])).abs();
                assert!(
                    ths[i] >= e,
                    "{}: FP at row {i}: threshold {:.3e} < diff {:.3e} ({}, {:?})",
                    t.name(),
                    ths[i],
                    e,
                    dist.label(),
                    model
                );
                assert!(ths[i].is_finite() && ths[i] >= 0.0);
            }
        }
    }

    #[test]
    fn no_false_positives_all_algorithms_fp32() {
        let model = AccumModel::gpu_highprec(Precision::F32);
        let dists = [
            Distribution::near_zero_normal(),
            Distribution::normal_1_1(),
            Distribution::uniform_pm1(),
            Distribution::truncated_normal(),
        ];
        let algos: Vec<Box<dyn Threshold>> = vec![
            Box::new(VabftThreshold::default()),
            Box::new(AabftThreshold::computed_y()),
            Box::new(AnalyticalThreshold::default()),
            Box::new(SeaThreshold::default()),
        ];
        for algo in &algos {
            for d in &dists {
                check_no_false_positives(algo.as_ref(), model, d);
            }
        }
    }

    #[test]
    fn no_false_positives_vabft_bf16() {
        let model = AccumModel::wide(Precision::Bf16);
        for d in [Distribution::uniform_01(), Distribution::normal_1_1()] {
            check_no_false_positives(&VabftThreshold::default(), model, &d);
        }
    }

    #[test]
    fn tightness_ordering_holds() {
        // The paper's Table 3/4/5 shape: V-ABFT < A-ABFT < SEA ≤ Analytical
        // on U(-1,1) data (allow SEA/Analytical to swap nowhere).
        let model = AccumModel::gpu_highprec(Precision::F32);
        let ctx = ThresholdContext::offline(model);
        let mut rng = Xoshiro256pp::seed_from_u64(3);
        let d = Distribution::uniform_pm1();
        let a = Matrix::sample_in(8, 256, &d, model.input, &mut rng);
        let b = Matrix::sample_in(256, 256, &d, model.input, &mut rng);
        let v = VabftThreshold::default().thresholds(&a, &b, &ctx);
        let aa = AabftThreshold::paper_repro().thresholds(&a, &b, &ctx);
        let an = AnalyticalThreshold::default().thresholds(&a, &b, &ctx);
        let se = SeaThreshold::default().thresholds(&a, &b, &ctx);
        for i in 0..8 {
            assert!(v[i] < aa[i], "row {i}: V {:.3e} !< A {:.3e}", v[i], aa[i]);
            assert!(aa[i] < an[i], "row {i}: A {:.3e} !< Higham {:.3e}", aa[i], an[i]);
            assert!(se[i] < an[i], "row {i}: SEA {:.3e} !< Higham {:.3e}", se[i], an[i]);
        }
    }

    #[test]
    fn online_thresholds_are_much_tighter_for_bf16() {
        // §3.6: verifying the FP32 accumulator instead of the BF16 output
        // tightens the threshold by ~1000×.
        let model = AccumModel::wide(Precision::Bf16);
        let mut rng = Xoshiro256pp::seed_from_u64(4);
        let d = Distribution::uniform_01();
        let a = Matrix::sample_in(4, 128, &d, model.input, &mut rng);
        let b = Matrix::sample_in(128, 128, &d, model.input, &mut rng);
        let t = VabftThreshold::default();
        let off = t.thresholds(&a, &b, &ThresholdContext::offline(model));
        let on = t.thresholds(&a, &b, &ThresholdContext::online(model));
        for i in 0..4 {
            let ratio = off[i] / on[i];
            assert!(ratio > 100.0, "row {i}: ratio {ratio}");
        }
    }
}
