//! Worst-case analytical threshold (Higham-style), the classical baseline
//! the paper's introduction cites as 10⁴–10⁵× looser than actual errors.
//!
//! Standard forward-error bound for a length-s accumulation:
//! `|fl(Σx) − Σx| ≤ γ_s · Σ|x|` with `γ_s = s·u / (1 − s·u)`. Applied to
//! the verification difference, both paths accumulate over N and K, so we
//! bound with depth s = N + K against the full absolute mass
//! `Σ_k |A_mk| · Σ_n |B_kn|`.

use super::{Threshold, ThresholdContext};
use crate::matrix::Matrix;

/// Higham worst-case threshold.
#[derive(Debug, Clone, Default)]
pub struct AnalyticalThreshold;

impl AnalyticalThreshold {
    /// γ_s = s·u / (1 − s·u); saturates to infinity when s·u ≥ 1 (the
    /// bound is vacuous there, which the paper notes for low precision).
    pub fn gamma(s: usize, u: f64) -> f64 {
        let su = s as f64 * u;
        if su >= 1.0 {
            f64::INFINITY
        } else {
            su / (1.0 - su)
        }
    }
}

impl Threshold for AnalyticalThreshold {
    fn name(&self) -> &'static str {
        "Analytical (Higham)"
    }

    fn thresholds(&self, a: &Matrix, b: &Matrix, ctx: &ThresholdContext) -> Vec<f64> {
        assert_eq!(a.cols(), b.rows());
        let (k, n) = (b.rows(), b.cols());
        let p = if ctx.online { ctx.model.work } else { ctx.model.out };
        let u = p.unit_roundoff();
        let g = Self::gamma(n + k, u);
        // Row-wise absolute mass of B: Σ_n |B_kn| per row k.
        let b_abs_rs: Vec<f64> =
            (0..k).map(|r| b.row(r).iter().map(|v| v.abs()).sum()).collect();
        (0..a.rows())
            .map(|i| {
                let mass: f64 = a
                    .row(i)
                    .iter()
                    .zip(&b_abs_rs)
                    .map(|(&av, &bs)| av.abs() * bs)
                    .sum();
                // ×2: both verification paths contribute a γ-bounded error.
                2.0 * g * mass
            })
            .collect()
    }

    fn complexity(&self) -> &'static str {
        "O(n) — absolute sums"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fp::Precision;
    use crate::gemm::AccumModel;

    #[test]
    fn gamma_basics() {
        let u = Precision::F64.unit_roundoff();
        assert!((AnalyticalThreshold::gamma(10, u) - 10.0 * u).abs() < 1e-20);
        assert!(AnalyticalThreshold::gamma(1 << 55, u).is_infinite());
    }

    #[test]
    fn bound_is_conservative_by_construction() {
        // For all-ones 64×64: mass per row = 64·64 = 4096,
        // T = 2·γ_128·4096 in FP32.
        let a = Matrix::from_fn(4, 64, |_, _| 1.0);
        let b = Matrix::from_fn(64, 64, |_, _| 1.0);
        let ctx = ThresholdContext::offline(AccumModel::gpu_highprec(Precision::F32));
        let th = AnalyticalThreshold.thresholds(&a, &b, &ctx);
        let u = Precision::F32.unit_roundoff();
        let want = 2.0 * AnalyticalThreshold::gamma(128, u) * 4096.0;
        assert!((th[0] - want).abs() < 1e-9);
        // ~10^4 × the actual error scale (which is ~u·N·val ≈ 2e-4):
        assert!(th[0] > 0.01);
    }
}
