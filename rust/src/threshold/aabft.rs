//! A-ABFT baseline (Braun, Halder & Wunderlich, DSN 2014), reproduced per
//! the paper's §4.1 so the Table 4–6 comparisons can be regenerated.
//!
//! For an inner product of length n the rounding-error standard deviation
//! is bounded by
//!
//! ```text
//! σ(Δs_n) ≤ √( (n(n+1)(n+0.5) + 2n) / 24 ) · 2^(−t) · y
//! ```
//!
//! with t the precision parameter (53 for FP64, 23 for FP32 — the values
//! the paper states and which reproduce the original Table II numbers) and
//! y the magnitude scale. The detection threshold is 3σ.
//!
//! The original work determines y from the p largest |a_k·b_k| products
//! (O(pn)); the paper's reproduction uses the calibrated constant y = 21
//! for U(−1,1) (partitioned encoding, block ≈ 150) and, for the BF16 GPU
//! table, the computed value y = max|A| · max_k|Σ_j B_kj|.

use super::{Threshold, ThresholdContext};
use crate::fp::Precision;
use crate::matrix::Matrix;

/// How A-ABFT's magnitude parameter y is determined.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum YMode {
    /// Fixed calibrated constant (paper reproduction: y = 21 for U(-1,1)).
    Fixed(f64),
    /// Computed per matrix pair: y = max|A| · max_k |Σ_j B_kj| (Table 6).
    Computed,
    /// Original O(pn) procedure: y = mean of the p largest |A_mk| per row
    /// times max_k |Σ_j B_kj| — kept for the complexity comparison.
    PLargest(usize),
}

/// The A-ABFT threshold baseline.
#[derive(Debug, Clone)]
pub struct AabftThreshold {
    /// How the magnitude parameter y is determined.
    pub y_mode: YMode,
    /// σ multiplier (3 in the original: ≈99.7% coverage).
    pub n_sigma: f64,
}

impl AabftThreshold {
    /// The configuration used to reproduce the original paper's Table II
    /// (validated in §6.2: 0.91–0.99× of the published values).
    pub fn paper_repro() -> AabftThreshold {
        AabftThreshold { y_mode: YMode::Fixed(21.0), n_sigma: 3.0 }
    }

    /// Computed-y variant (the configuration Table 6 uses for BF16).
    pub fn computed_y() -> AabftThreshold {
        AabftThreshold { y_mode: YMode::Computed, n_sigma: 3.0 }
    }

    /// A-ABFT's precision parameter t (§4.1: 53 for FP64, 23 for FP32;
    /// extended to the low-precision formats by the same convention the
    /// paper uses in Table 6).
    pub fn t_bits(p: Precision) -> i32 {
        match p {
            Precision::F64 => 53,
            Precision::F32 => 23,
            Precision::F16 => 11,
            Precision::Bf16 => 8,
            Precision::F8E4M3 => 4,
            Precision::F8E5M2 => 3,
        }
    }

    /// σ(Δs_n) for inner-product length n, scale y, precision parameter t.
    pub fn sigma(n: usize, t: i32, y: f64) -> f64 {
        let nf = n as f64;
        ((nf * (nf + 1.0) * (nf + 0.5) + 2.0 * nf) / 24.0).sqrt() * (2.0f64).powi(-t) * y
    }
}

impl Threshold for AabftThreshold {
    fn name(&self) -> &'static str {
        "A-ABFT"
    }

    fn thresholds(&self, a: &Matrix, b: &Matrix, ctx: &ThresholdContext) -> Vec<f64> {
        assert_eq!(a.cols(), b.rows());
        let (k, n) = (b.rows(), b.cols());
        // Verification-relevant precision: what the checked values are
        // stored in (A-ABFT has no online/offline distinction; it predates
        // fused verification).
        let p = if ctx.online { ctx.model.work } else { ctx.model.out };
        let t = Self::t_bits(p);
        // Inner-product length of the longer verification path.
        let len = n.max(k);

        // max_k |Σ_j B_kj| — B's largest row-sum magnitude.
        let max_brs = b
            .row_sums()
            .iter()
            .fold(0.0f64, |m, &v| m.max(v.abs()));

        match self.y_mode {
            YMode::Fixed(y) => {
                let th = self.n_sigma * Self::sigma(len, t, y);
                vec![th; a.rows()]
            }
            YMode::Computed => {
                let y = a.max_abs() * max_brs;
                let th = self.n_sigma * Self::sigma(len, t, y);
                vec![th; a.rows()]
            }
            YMode::PLargest(pp) => (0..a.rows())
                .map(|i| {
                    // O(p·K) selection of the p largest |A_mk| (the cost
                    // §4.4 contrasts with V-ABFT's single pass).
                    let mut top: Vec<f64> = Vec::with_capacity(pp + 1);
                    for &v in a.row(i) {
                        let av = v.abs();
                        let pos = top.partition_point(|&x| x > av);
                        if pos < pp {
                            top.insert(pos, av);
                            top.truncate(pp);
                        }
                    }
                    let y_row = top.iter().copied().sum::<f64>()
                        / top.len().max(1) as f64
                        * max_brs;
                    self.n_sigma * Self::sigma(len, t, y_row)
                })
                .collect(),
        }
    }

    fn complexity(&self) -> &'static str {
        "O(pn) — p-largest selection"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gemm::AccumModel;

    #[test]
    fn reproduces_original_table_ii_fp64_512() {
        // §6.2: at 512×512 FP64 the reproduced A-ABFT threshold is
        // 1.66e-11 (0.99× the original paper's 1.68e-11).
        let th = 3.0 * AabftThreshold::sigma(512, 53, 21.0);
        assert!(
            (th - 1.66e-11).abs() < 0.03e-11,
            "got {th:.3e}, want ≈1.66e-11"
        );
    }

    #[test]
    fn reproduces_table5_fp32_values() {
        // Table 5 A-ABFT column: 512 → 1.78e-2, 2048 → 1.42e-1.
        let t512 = 3.0 * AabftThreshold::sigma(512, 23, 21.0);
        assert!((t512 - 1.78e-2).abs() < 0.05e-2, "{t512:.3e}");
        let t2048 = 3.0 * AabftThreshold::sigma(2048, 23, 21.0);
        assert!((t2048 - 1.42e-1).abs() < 0.05e-1, "{t2048:.3e}");
    }

    #[test]
    fn sigma_grows_as_n_to_three_halves() {
        // §4.2 limitation 2: O(n^1.5) growth.
        let s1 = AabftThreshold::sigma(1000, 53, 1.0);
        let s2 = AabftThreshold::sigma(4000, 53, 1.0);
        let ratio = s2 / s1;
        assert!((ratio - 8.0).abs() < 0.1, "4× n should give ≈8× σ, got {ratio}");
    }

    #[test]
    fn computed_y_uses_matrix_magnitudes() {
        let a = Matrix::from_fn(2, 4, |_, _| 0.5);
        let b = Matrix::from_fn(4, 4, |_, _| 1.0); // row sums = 4
        let ctx = ThresholdContext::offline(AccumModel::gpu_highprec(Precision::F32));
        let th = AabftThreshold::computed_y().thresholds(&a, &b, &ctx);
        let want = 3.0 * AabftThreshold::sigma(4, 23, 0.5 * 4.0);
        assert!((th[0] - want).abs() < 1e-18);
    }

    #[test]
    fn p_largest_is_per_row() {
        let mut a = Matrix::from_fn(2, 8, |_, _| 0.1);
        for j in 0..8 {
            a.set(1, j, 10.0); // row 1 has much larger elements
        }
        let b = Matrix::from_fn(8, 8, |_, _| 1.0);
        let ctx = ThresholdContext::offline(AccumModel::gpu_highprec(Precision::F32));
        let th = AabftThreshold { y_mode: YMode::PLargest(3), n_sigma: 3.0 }
            .thresholds(&a, &b, &ctx);
        assert!(th[1] > th[0] * 50.0);
    }
}
