//! Simplified Error Analysis (SEA) baseline, after Roy-Chowdhury &
//! Banerjee (FTCS 1993).
//!
//! The original SEA derivation simplifies the full forward-error analysis
//! by keeping only the first-order term and replacing per-step partial-sum
//! magnitudes with a single magnitude estimate. The exact constants in the
//! 1993 paper are tied to their checksum scheme; we reconstruct the bound
//! in the form the V-ABFT paper characterizes (10³–10⁴× actual, i.e.
//! roughly one order tighter than the Higham worst case):
//!
//! ```text
//! T_m = u · (N + K) · max_k |A_mk| · max_k Σ_n |B_kn|
//! ```
//!
//! i.e. linear accumulation depth times a worst-single-element magnitude —
//! deterministic like Higham's bound, but without summing the full
//! absolute mass (which is what makes γ-style bounds a further ~K× looser).

use super::{Threshold, ThresholdContext};
use crate::matrix::Matrix;

/// SEA threshold (reconstruction — see module docs).
#[derive(Debug, Clone, Default)]
pub struct SeaThreshold;

impl Threshold for SeaThreshold {
    fn name(&self) -> &'static str {
        "SEA"
    }

    fn thresholds(&self, a: &Matrix, b: &Matrix, ctx: &ThresholdContext) -> Vec<f64> {
        assert_eq!(a.cols(), b.rows());
        let (k, n) = (b.rows(), b.cols());
        let p = if ctx.online { ctx.model.work } else { ctx.model.out };
        let u = p.unit_roundoff();
        let depth = (n + k) as f64;
        let max_abs_brs = (0..k)
            .map(|r| b.row(r).iter().map(|v| v.abs()).sum::<f64>())
            .fold(0.0f64, f64::max);
        (0..a.rows())
            .map(|i| {
                let max_a = a.row(i).iter().fold(0.0f64, |m, &v| m.max(v.abs()));
                u * depth * max_a * max_abs_brs
            })
            .collect()
    }

    fn complexity(&self) -> &'static str {
        "O(n) — max magnitudes"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fp::Precision;
    use crate::gemm::AccumModel;

    #[test]
    fn sits_between_vabft_and_higham_in_magnitude() {
        // Structural sanity on uniform data; full comparison in the
        // threshold::tests ordering test and the benches.
        let a = Matrix::from_fn(2, 32, |_, j| if j % 2 == 0 { 0.5 } else { -0.5 });
        let b = Matrix::from_fn(32, 32, |i, j| if (i + j) % 2 == 0 { 0.5 } else { -0.5 });
        let ctx = ThresholdContext::offline(AccumModel::gpu_highprec(Precision::F32));
        let sea = SeaThreshold.thresholds(&a, &b, &ctx)[0];
        let u = Precision::F32.unit_roundoff();
        // max|A| = 0.5, max abs-row-sum of B = 16 ⇒ T = u·64·8
        assert!((sea - u * 64.0 * 0.5 * 16.0).abs() < 1e-12);
    }

    #[test]
    fn scales_linearly_with_depth() {
        let mk = |n: usize| {
            (
                Matrix::from_fn(1, n, |_, _| 1.0),
                Matrix::from_fn(n, n, |_, _| 1.0),
            )
        };
        let ctx = ThresholdContext::offline(AccumModel::gpu_highprec(Precision::F64));
        let (a1, b1) = mk(100);
        let (a2, b2) = mk(200);
        let t1 = SeaThreshold.thresholds(&a1, &b1, &ctx)[0];
        let t2 = SeaThreshold.thresholds(&a2, &b2, &ctx)[0];
        // depth ×2 and row-sum ×2 ⇒ ×4
        assert!((t2 / t1 - 4.0).abs() < 1e-9);
    }
}
