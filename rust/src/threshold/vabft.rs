//! V-ABFT: the paper's variance-based adaptive threshold (§3, Algorithm 1).
//!
//! Directly models the verification difference
//! `E = |fl(Σ_n fl(Σ_k A_mk B_kn)) − fl(Σ_k A_mk fl(Σ_n B_kn))|`
//! by decomposing both operands into row mean + scaled fluctuation
//! (Eq. 15–16), expanding into four terms (Eq. 21) and bounding:
//!
//! ```text
//! T_m = e_max · ( N·|μ_Am|·Σ_k|μ_Bk|                         (deterministic)
//!        + c_σ·√( N·μ_Am²·Σ_k σ_Bk² + N²·σ_Am²·Σ_k μ_Bk² )   (terms 2+3)
//!        + c_σ·√N·σ_Am·√(Σ_k σ_Bk²) )                        (term 4)
//! ```
//!
//! with every σ² replaced by the extrema-variance bound
//! σ² ≤ (max−μ)(μ−min) (Theorem 1), so the whole computation needs only a
//! single max/min/mean pass per row: O(K) per row of A, O(KN) once for B.

use super::{Threshold, ThresholdContext};
use crate::calibrate::EmaxModel;
use crate::matrix::{Matrix, RowStats};

/// Reusable per-B summary: Σ_k |μ_Bk|, Σ_k μ_Bk², Σ_k σ_Bk² (extrema
/// bound), plus N. Serving workloads verify many A's against one weight
/// matrix B, so this is computed once and shared (see
/// [`VabftThreshold::prepare_b`]).
#[derive(Debug, Clone, Copy)]
pub struct BSummary {
    /// Columns of B (the row-sum reduction length).
    pub n: usize,
    /// Rows of B (the dot-product reduction length).
    pub k: usize,
    /// Σ_k |μ_Bk| — drives the deterministic bias term.
    pub sum_abs_mu: f64,
    /// Σ_k μ_Bk² — drives variance term 3.
    pub sum_mu_sq: f64,
    /// Σ_k σ_Bk² under the extrema bound — drives terms 2 and 4.
    pub sum_sigma_sq: f64,
}

impl BSummary {
    /// Summary over `m`'s *columns* — [`BSummary::of`] applied to the
    /// transpose. This is the "B role" of the column-checksum direction
    /// (Cᵀ = Bᵀ·Aᵀ puts Aᵀ in the B position), computed the identical
    /// way so prepared and one-shot column thresholds agree bitwise.
    pub fn of_columns(m: &Matrix) -> BSummary {
        Self::of(&m.transpose())
    }

    /// One pass over B's rows.
    pub fn of(b: &Matrix) -> BSummary {
        let (k, n) = (b.rows(), b.cols());
        let mut sum_abs_mu = 0.0;
        let mut sum_mu_sq = 0.0;
        let mut sum_sigma_sq = 0.0;
        for r in 0..k {
            let s = b.row_stats_fast(r);
            sum_abs_mu += s.mean.abs();
            sum_mu_sq += s.mean * s.mean;
            sum_sigma_sq += s.extrema_var_bound();
        }
        BSummary { n, k, sum_abs_mu, sum_mu_sq, sum_sigma_sq }
    }
}

/// The V-ABFT threshold algorithm.
#[derive(Debug, Clone)]
pub struct VabftThreshold {
    /// Confidence multiplier c_σ (paper default 2.5 ≈ 99% Gaussian
    /// coverage; raise for lower FPR tolerance).
    pub c_sigma: f64,
    /// Optional fixed e_max law (None = derive from the context).
    pub emax: Option<EmaxModel>,
}

impl Default for VabftThreshold {
    fn default() -> Self {
        VabftThreshold { c_sigma: 2.5, emax: None }
    }
}

impl VabftThreshold {
    /// Default e_max law, custom confidence multiplier.
    pub fn with_c_sigma(c_sigma: f64) -> VabftThreshold {
        VabftThreshold { c_sigma, emax: None }
    }

    /// Default c_σ, fixed e_max law (e.g. a Table 7 calibrated value).
    pub fn with_emax(emax: EmaxModel) -> VabftThreshold {
        VabftThreshold { c_sigma: 2.5, emax: Some(emax) }
    }

    /// Precompute the B-side summary (one pass over B).
    pub fn prepare_b(&self, b: &Matrix) -> BSummary {
        BSummary::of(b)
    }

    /// Algorithm 1 for a single row of A, given its stats and the B
    /// summary. `emax` must already be evaluated at the reduction length.
    #[inline]
    pub fn row_threshold(&self, a_stats: &RowStats, bsum: &BSummary, emax: f64) -> f64 {
        let n = bsum.n as f64;
        let mu_a = a_stats.mean;
        let sigma_a = a_stats.extrema_std_bound();

        // line 7: deterministic bias term
        let t_det = n * mu_a.abs() * bsum.sum_abs_mu;
        // line 8: variance of terms 2 and 3 (independent → variances add)
        let t_var23 = self.c_sigma
            * (n * mu_a * mu_a * bsum.sum_sigma_sq
                + n * n * sigma_a * sigma_a * bsum.sum_mu_sq)
                .sqrt();
        // line 9: interaction term (second-order fluctuation)
        let t_var4 = self.c_sigma * n.sqrt() * sigma_a * bsum.sum_sigma_sq.sqrt();

        emax * (t_det + t_var23 + t_var4)
    }

    /// The e_max used for a given context and reduction length.
    pub fn effective_emax(&self, ctx: &ThresholdContext, n: usize) -> f64 {
        match self.emax {
            Some(m) => m.eval(n),
            None => ctx.emax(n),
        }
    }
}

impl Threshold for VabftThreshold {
    fn name(&self) -> &'static str {
        "V-ABFT"
    }

    fn thresholds(&self, a: &Matrix, b: &Matrix, ctx: &ThresholdContext) -> Vec<f64> {
        assert_eq!(a.cols(), b.rows());
        let bsum = BSummary::of(b);
        // Reduction length governing e_max: the longer of the two
        // verification paths' accumulations (row sums over N, dot over K).
        let red_len = b.cols().max(a.cols());
        let emax = self.effective_emax(ctx, red_len);
        (0..a.rows())
            .map(|m| self.row_threshold(&a.row_stats_fast(m), &bsum, emax))
            .collect()
    }

    fn thresholds_prepared(
        &self,
        a: &Matrix,
        prepared: &super::PreparedBStats,
        ctx: &ThresholdContext,
    ) -> Vec<f64> {
        let bsum = &prepared.bsum;
        let red_len = bsum.n.max(a.cols());
        let emax = self.effective_emax(ctx, red_len);
        (0..a.rows())
            .map(|m| self.row_threshold(&a.row_stats_fast(m), bsum, emax))
            .collect()
    }

    fn thresholds_columns_prepared(
        &self,
        a: &Matrix,
        prepared: &super::PreparedColStats,
        ctx: &ThresholdContext,
    ) -> Vec<f64> {
        // Column direction via Cᵀ = Bᵀ·Aᵀ: B's cached per-column stats play
        // the "rows of A" role and A's column summary plays the "B" role.
        let asum = BSummary::of_columns(a);
        let red_len = a.rows().max(prepared.k());
        let emax = self.effective_emax(ctx, red_len);
        prepared
            .cols
            .iter()
            .map(|s| self.row_threshold(s, &asum, emax))
            .collect()
    }

    fn complexity(&self) -> &'static str {
        "O(n) — single max/min/mean pass"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fp::Precision;
    use crate::gemm::AccumModel;
    use crate::rng::{Distribution, Xoshiro256pp};

    fn ctx_fp32() -> ThresholdContext {
        ThresholdContext::offline(AccumModel::gpu_highprec(Precision::F32))
    }

    #[test]
    fn zero_matrices_give_zero_threshold() {
        let a = Matrix::zeros(4, 8);
        let b = Matrix::zeros(8, 8);
        let t = VabftThreshold::default().thresholds(&a, &b, &ctx_fp32());
        assert!(t.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn constant_matrices_have_pure_deterministic_term() {
        // Constant rows ⇒ σ = 0 everywhere ⇒ T = e_max · N·|μ_A|·Σ|μ_B|.
        let a = Matrix::from_fn(2, 16, |_, _| 2.0);
        let b = Matrix::from_fn(16, 32, |_, _| 3.0);
        let ctx = ctx_fp32();
        let th = VabftThreshold::default().thresholds(&a, &b, &ctx);
        let emax = ctx.emax(32);
        let expect = emax * (32.0 * 2.0 * (16.0 * 3.0));
        for &t in &th {
            assert!((t - expect).abs() < 1e-12 * expect);
        }
    }

    #[test]
    fn zero_mean_data_is_dominated_by_interaction_term() {
        // For zero-mean matrices Term 4 dominates (paper §3.3 "physical
        // interpretation"). Check T scales ~√N when means are ~0.
        let mut rng = Xoshiro256pp::seed_from_u64(5);
        let d = Distribution::Normal { mean: 0.0, std: 1.0 };
        let k = 64;
        let a = Matrix::sample(1, k, &d, &mut rng);
        let t = VabftThreshold::with_emax(EmaxModel::Constant(1e-6));
        let bs_small = BSummary::of(&Matrix::sample(k, 64, &d, &mut rng));
        let bs_big = BSummary::of(&Matrix::sample(k, 4096, &d, &mut rng));
        let astats = a.row_stats(0);
        let t_small = t.row_threshold(&astats, &bs_small, 1e-6);
        let t_big = t.row_threshold(&astats, &bs_big, 1e-6);
        // N grew 64× ⇒ √N-dominated growth would be 8×; the N·μ² terms are
        // tiny since sample means are O(1/√N). Allow [4, 24].
        let ratio = t_big / t_small;
        assert!((4.0..24.0).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn prepared_b_path_matches_one_shot_path() {
        let mut rng = Xoshiro256pp::seed_from_u64(6);
        let d = Distribution::normal_1_1();
        let a = Matrix::sample(5, 32, &d, &mut rng);
        let b = Matrix::sample(32, 48, &d, &mut rng);
        let algo = VabftThreshold::default();
        let ctx = ctx_fp32();
        let one_shot = algo.thresholds(&a, &b, &ctx);
        let bsum = algo.prepare_b(&b);
        let emax = algo.effective_emax(&ctx, 48);
        for i in 0..5 {
            // row_stats (two-pass) vs row_stats_fast (4-lane) sum in
            // different orders; the means agree to roundoff.
            let t = algo.row_threshold(&a.row_stats(i), &bsum, emax);
            assert!(
                (t - one_shot[i]).abs() <= 1e-12 * one_shot[i].abs(),
                "{t} vs {}",
                one_shot[i]
            );
        }
    }

    #[test]
    fn prepared_column_path_is_bitwise_the_transpose_path() {
        // The VabftThreshold override of `thresholds_columns_prepared` must
        // agree bitwise with the trait default (one-shot transpose), which
        // itself equals `thresholds_columns`: all three walk the same
        // `row_stats_fast` passes in the same order.
        let mut rng = Xoshiro256pp::seed_from_u64(9);
        let d = Distribution::normal_1_1();
        let a = Matrix::sample(7, 24, &d, &mut rng);
        let b = Matrix::sample(24, 40, &d, &mut rng);
        let algo = VabftThreshold::default();
        let ctx = ctx_fp32();
        let prepared = crate::threshold::PreparedColStats::of(&b);
        let via_prepared = algo.thresholds_columns_prepared(&a, &prepared, &ctx);
        let via_default = algo.thresholds(&prepared.bt, &a.transpose(), &ctx);
        let via_columns = algo.thresholds_columns(&a, &b, &ctx);
        assert_eq!(via_prepared.len(), b.cols());
        assert_eq!(via_prepared, via_default);
        assert_eq!(via_prepared, via_columns);
    }

    #[test]
    fn threshold_scales_linearly_with_emax() {
        let mut rng = Xoshiro256pp::seed_from_u64(7);
        let d = Distribution::uniform_pm1();
        let a = Matrix::sample(3, 16, &d, &mut rng);
        let b = Matrix::sample(16, 16, &d, &mut rng);
        let ctx = ctx_fp32();
        let t1 = VabftThreshold::with_emax(EmaxModel::Constant(1e-7))
            .thresholds(&a, &b, &ctx);
        let t2 = VabftThreshold::with_emax(EmaxModel::Constant(2e-7))
            .thresholds(&a, &b, &ctx);
        for (x, y) in t1.iter().zip(&t2) {
            assert!((y / x - 2.0).abs() < 1e-12);
        }
    }

    #[test]
    fn c_sigma_only_scales_random_terms() {
        let a = Matrix::from_fn(1, 8, |_, j| if j % 2 == 0 { 1.0 } else { -1.0 });
        let b = Matrix::from_fn(8, 8, |i, j| ((i + j) % 3) as f64 - 1.0);
        let ctx = ctx_fp32();
        let lo = VabftThreshold::with_c_sigma(1.0).thresholds(&a, &b, &ctx)[0];
        let hi = VabftThreshold::with_c_sigma(2.0).thresholds(&a, &b, &ctx)[0];
        // det term is ~0 here (zero-mean A row), so doubling c_σ ≈ doubles T.
        assert!((hi / lo - 2.0).abs() < 0.05, "{hi} vs {lo}");
    }
}
