//! Minimal command-line argument parsing (clap substitute).
//!
//! Supports `subcommand --flag --key value --key=value positional` — all
//! the binaries and examples in this crate need.

use std::collections::HashMap;
use std::str::FromStr;

/// Parsed command line.
#[derive(Debug, Clone, Default)]
pub struct Args {
    /// First non-flag token, e.g. `gemm` in `vabft gemm --threads 4`.
    pub subcommand: Option<String>,
    flags: HashMap<String, String>,
    bools: Vec<String>,
    positionals: Vec<String>,
}

impl Args {
    /// Parse from process args (skipping argv[0]).
    pub fn parse() -> Args {
        Self::parse_from(std::env::args().skip(1))
    }

    /// Parse from an iterator of argument strings.
    pub fn parse_from(args: impl IntoIterator<Item = String>) -> Args {
        let mut out = Args::default();
        let mut iter = args.into_iter().peekable();
        // first non-flag token is the subcommand
        if let Some(first) = iter.peek() {
            if !first.starts_with('-') {
                out.subcommand = iter.next();
            }
        }
        while let Some(tok) = iter.next() {
            if let Some(body) = tok.strip_prefix("--") {
                if let Some((k, v)) = body.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if iter.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = iter.next().unwrap();
                    out.flags.insert(body.to_string(), v);
                } else {
                    out.bools.push(body.to_string());
                }
            } else {
                out.positionals.push(tok);
            }
        }
        out
    }

    /// Boolean flag present? (`--foo` with no value, or `--foo=true`).
    pub fn flag(&self, name: &str) -> bool {
        self.bools.iter().any(|b| b == name)
            || self.flags.get(name).map(|v| v == "true" || v == "1").unwrap_or(false)
    }

    /// String option.
    pub fn opt(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    /// Typed option with default; exits with a message on parse failure.
    pub fn opt_or<T: FromStr>(&self, name: &str, default: T) -> T {
        match self.flags.get(name) {
            None => default,
            Some(v) => v.parse().unwrap_or_else(|_| {
                eprintln!("error: invalid value '{v}' for --{name}");
                std::process::exit(2);
            }),
        }
    }

    /// Positional (non-flag) arguments after the subcommand.
    pub fn positionals(&self) -> &[String] {
        &self.positionals
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse_from(s.split_whitespace().map(String::from))
    }

    #[test]
    fn subcommand_and_flags() {
        let a = parse("serve --workers 4 --online --size=128 extra");
        assert_eq!(a.subcommand.as_deref(), Some("serve"));
        assert_eq!(a.opt_or("workers", 0usize), 4);
        assert!(a.flag("online"));
        assert_eq!(a.opt_or("size", 0usize), 128);
        assert_eq!(a.positionals(), &["extra".to_string()]);
    }

    #[test]
    fn no_subcommand_when_flag_first() {
        let a = parse("--full");
        assert_eq!(a.subcommand, None);
        assert!(a.flag("full"));
        // A flag followed by a bare token consumes it as its value — the
        // documented `--key value` form.
        let b = parse("--mode bench");
        assert_eq!(b.opt("mode"), Some("bench"));
    }

    #[test]
    fn defaults() {
        let a = parse("run");
        assert_eq!(a.opt_or("trials", 100usize), 100);
        assert!(!a.flag("online"));
        assert_eq!(a.opt("missing"), None);
    }

    #[test]
    fn value_flags_consume_next_token() {
        let a = parse("cmd --k v --b");
        assert_eq!(a.opt("k"), Some("v"));
        assert!(a.flag("b"));
    }

    #[test]
    fn negative_number_values() {
        let a = parse("cmd --mean -1.5");
        assert_eq!(a.opt_or("mean", 0.0f64), -1.5);
    }
}
