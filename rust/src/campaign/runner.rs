//! Campaign execution: batched trials through the coordinator.
//!
//! [`run`] executes a planned grid by grouping cells per accumulation
//! model × verification point, starting one [`Coordinator`] worker pool
//! per group, registering each operand set's weights once
//! ([`crate::abft::PreparedWeights`] — checksum encoding, B-side
//! statistics, threshold vectors and the clean FPR sweep all amortized
//! across every weight-stationary trial on the set), and submitting each
//! cell's injected requests as one `submit_batch_prepared` batch.
//!
//! Determinism: cells are classified in planning order from responses
//! collected in submission order; each trial's arithmetic is
//! thread-count-independent (schedule preservation), and no wall-clock
//! value enters a result. The same `(config, seed)` therefore produces
//! identical [`CampaignOutcome`]s — and byte-identical JSON — at any
//! worker count.

use std::sync::mpsc::Receiver;
use std::sync::Arc;

use crate::abft::{EncodingMode, Verdict, VerifyPolicy};
use crate::coordinator::{
    Coordinator, CoordinatorConfig, GemmResponse, InjectSpec, PreparedGemmRequest, WeightHandle,
};
use crate::fp::Precision;
use crate::gemm::{AccumModel, GemmEngine};
use crate::inject::{BitFlip, FaultSite, FaultSpec};
use crate::matrix::Matrix;
use crate::rng::Xoshiro256pp;
use crate::threshold::{AabftThreshold, Threshold, VabftThreshold};

use super::grid::{
    plan, plan_multi_fault, plan_protection, CellSpec, GridConfig, MultiCellSpec, PlanCellSpec,
    VerifyPoint,
};

/// Stream tag separating operand-sampling RNG streams from coordinate
/// streams (both key off the master seed).
const OPERAND_TAG: u64 = 0x09E2_A4D5;

/// Aggregated statistics of one executed grid cell.
#[derive(Debug, Clone)]
pub struct CellResult {
    /// The planned cell.
    pub spec: CellSpec,
    /// Identity of the clean sweep this cell shares (index into the
    /// campaign's distinct operand-set sweeps, assigned in execution
    /// order) — what reports deduplicate the shared clean statistics by.
    pub sweep: usize,
    /// Resolved flip bit position.
    pub bit: u32,
    /// Injection trials executed.
    pub trials: usize,
    /// Trials whose fault was detected (verdict ≠ Clean).
    pub detected: usize,
    /// Trials whose expected verification-difference magnitude cleared
    /// `margin ×` the row threshold (or was non-finite) — the population
    /// the recall gate quantifies over.
    pub above: usize,
    /// Above-threshold trials detected — the recall-gate numerator.
    pub detected_above: usize,
    /// Below-threshold trials detected anyway (bonus sensitivity).
    pub detected_below: usize,
    /// Clean rows verified in the cell's FPR sweep. The sweep runs once
    /// per *operand set* and is shared by every cell using it;
    /// [`CampaignOutcome::total_clean_rows`] counts each distinct sweep
    /// once.
    pub clean_rows: usize,
    /// Clean rows of the cell's (shared) sweep that flagged — must be
    /// zero for a sound threshold.
    pub false_positives: usize,
    /// Largest expected fault magnitude injected (∞ for overflow flips).
    pub max_magnitude: f64,
    /// Largest |D1| the clean run saw — the realized rounding-noise
    /// floor ("Actual Diff" in the paper's tightness tables).
    pub clean_noise: f64,
    /// Smallest V-ABFT row threshold issued on the clean run.
    pub threshold_min: f64,
    /// Largest V-ABFT row threshold issued on the clean run.
    pub threshold_max: f64,
    /// Largest A-ABFT row threshold for the same operands (tightness
    /// baseline; not used for detection).
    pub aabft_threshold_max: f64,
    /// Trials detected under the severity-aware policy
    /// (`policy.with_severity()`), from the axis' second pass over the
    /// identical fault plan. Mirrors `detected` for offline cells (the
    /// severity axis runs on online groups, where waiving applies).
    pub severity_detected: usize,
    /// Trials whose detection the severity pass *waived* (residual below
    /// output-quantization noise; no recompute spent). Always ≤
    /// `severity_detected`; 0 for offline cells.
    pub severity_waived: usize,
}

impl CellResult {
    /// Above-threshold recall: 1.0 when the gate holds (and vacuously
    /// when no trial cleared the margin).
    pub fn recall_above(&self) -> f64 {
        if self.above == 0 {
            1.0
        } else {
            self.detected_above as f64 / self.above as f64
        }
    }

    /// Realized V-ABFT tightness on clean data: largest threshold over
    /// largest observed noise (lower is tighter; must stay ≥ 1).
    pub fn tightness(&self) -> f64 {
        self.threshold_max / self.clean_noise
    }

    /// Realized A-ABFT tightness on clean data (the baseline ratio).
    pub fn aabft_tightness(&self) -> f64 {
        self.aabft_threshold_max / self.clean_noise
    }
}

/// Aggregated statistics of one executed multi-fault cell.
#[derive(Debug, Clone)]
pub struct MultiCellResult {
    /// The planned cell.
    pub spec: MultiCellSpec,
    /// Resolved flip bit position (exponent LSB of the work grid).
    pub bit: u32,
    /// Injection trials executed.
    pub trials: usize,
    /// Trials whose faults were detected (verdict ≠ Clean).
    pub detected: usize,
    /// Trials whose planned per-row net perturbation cleared `margin ×`
    /// the row threshold (or was non-finite) on at least one row — the
    /// population the multi-fault recall gate quantifies over.
    pub above: usize,
    /// Above-margin trials detected — the recall-gate numerator.
    pub detected_above: usize,
    /// Trials whose every detection was repaired in place — no row
    /// recomputed. This is the coverage the grid-vs-baseline gate
    /// compares: on row bursts the single-checksum baseline recomputes
    /// while two-dimensional encodings correct via the column direction.
    pub corrected_no_recompute: usize,
    /// Rows corrected via the column/grid direction, summed over trials.
    pub rows_corrected_grid: usize,
    /// Row localizations that came back inconsistent, summed over trials.
    pub inconsistent_localizations: usize,
    /// Rows recomputed, summed over trials.
    pub rows_recomputed: usize,
    /// Clean rows verified in the cell's (shared) FPR sweep.
    pub clean_rows: usize,
    /// Clean rows of the cell's sweep that flagged — must be zero.
    pub false_positives: usize,
}

/// Aggregated statistics of one executed protection-plan cell.
#[derive(Debug, Clone)]
pub struct PlanCellResult {
    /// The planned cell.
    pub spec: PlanCellSpec,
    /// Resolved flip bit position (exponent MSB of the work grid).
    pub bit: u32,
    /// Injection trials executed.
    pub trials: usize,
    /// Trials whose fault was detected (verdict ≠ Clean) — must equal
    /// `trials`: every planner-selectable scheme owes recall 1.0 on the
    /// guaranteed-visible exponent-MSB upset.
    pub detected: usize,
    /// Clean rows verified in the cell's clean sweep (run under the
    /// cell's own scheme policy).
    pub clean_rows: usize,
    /// Clean rows that flagged — must be zero for every scheme.
    pub false_positives: usize,
    /// Injected trials whose recovered output was bitwise-equal to the
    /// cell's fault-free reference. Gated at 100% for the replication
    /// scheme (recovery is recomputation from clean inputs); recorded
    /// informationally for syndrome-corrected schemes.
    pub repaired_bitwise: usize,
}

/// Outcome of a full campaign run.
#[derive(Debug, Clone)]
pub struct CampaignOutcome {
    /// The grid configuration that ran.
    pub config: GridConfig,
    /// Per-cell results, in planning order.
    pub cells: Vec<CellResult>,
    /// Multi-fault axis results, in planning order (empty when the axis
    /// is disabled).
    pub multi_cells: Vec<MultiCellResult>,
    /// Clean rows verified across the multi-fault axis' distinct sweeps.
    pub multi_clean_rows: usize,
    /// Flagged rows across the multi-fault clean sweeps (must be zero —
    /// column syndromes are recovery-only, so 2D encodings cannot add
    /// false positives).
    pub multi_false_positives: usize,
    /// Protection-plan axis results, in planning order (empty when the
    /// borrowed base axes are empty).
    pub plan_cells: Vec<PlanCellResult>,
    /// Clean rows verified across the plan axis' per-scheme sweeps.
    pub plan_clean_rows: usize,
    /// Flagged rows across the plan axis' clean sweeps (must be zero —
    /// no scheme the planner can select may add false positives).
    pub plan_false_positives: usize,
    /// Clean rows verified across the *distinct* clean sweeps (one per
    /// operand set per coordinator group — cells sharing operands share
    /// a sweep, which is counted once here).
    pub clean_rows: usize,
    /// Flagged rows across the distinct clean sweeps (must be zero).
    pub false_positives: usize,
    /// Flagged rows across the severity pass's clean sweeps (must also
    /// be zero — severity only reshapes recovery, never detection).
    pub severity_false_positives: usize,
    /// One coordinator-metrics summary line per worker-pool group
    /// (campaign counters, job totals, latency) — runtime telemetry, not
    /// serialized into the JSON document (it is wall-clock-dependent).
    pub group_metrics: Vec<String>,
}

impl CampaignOutcome {
    /// Total injection trials.
    pub fn total_trials(&self) -> usize {
        self.cells.iter().map(|c| c.trials).sum()
    }

    /// Total above-threshold trials (the recall denominator).
    pub fn total_above(&self) -> usize {
        self.cells.iter().map(|c| c.above).sum()
    }

    /// Total above-threshold trials detected (the recall numerator).
    pub fn total_detected_above(&self) -> usize {
        self.cells.iter().map(|c| c.detected_above).sum()
    }

    /// Total clean rows verified, each distinct sweep counted once.
    pub fn total_clean_rows(&self) -> usize {
        self.clean_rows
    }

    /// Total clean rows that flagged (must be zero), each distinct sweep
    /// counted once.
    pub fn total_false_positives(&self) -> usize {
        self.false_positives
    }

    /// Campaign-wide above-threshold recall.
    pub fn recall_above(&self) -> f64 {
        let above = self.total_above();
        if above == 0 {
            1.0
        } else {
            self.total_detected_above() as f64 / above as f64
        }
    }

    /// The CI gate: recall 1.0 over the above-threshold population and
    /// zero false positives.
    pub fn gates_hold(&self) -> bool {
        self.total_false_positives() == 0 && self.total_detected_above() == self.total_above()
    }

    /// Total trials the severity pass waived instead of recomputing.
    pub fn total_severity_waived(&self) -> usize {
        self.cells.iter().map(|c| c.severity_waived).sum()
    }

    /// The severity-axis CI gate: the severity-aware policy detects
    /// *exactly* what the baseline policy detects, cell by cell, and its
    /// clean sweeps stay zero-FP. Waiving reshapes recovery, never
    /// detection — a single downgraded detection fails the campaign.
    pub fn severity_no_downgrade(&self) -> bool {
        self.severity_false_positives == 0
            && self.cells.iter().all(|c| c.severity_detected == c.detected)
    }

    /// Total multi-fault injection trials.
    pub fn total_multi_trials(&self) -> usize {
        self.multi_cells.iter().map(|c| c.trials).sum()
    }

    /// Sum of corrected-without-recompute trials over the multi-fault
    /// cells running `encoding`.
    pub fn multi_corrected_no_recompute(&self, encoding: EncodingMode) -> usize {
        self.multi_cells
            .iter()
            .filter(|c| c.spec.encoding == encoding)
            .map(|c| c.corrected_no_recompute)
            .sum()
    }

    /// The multi-fault detection gate: zero false positives on the axis'
    /// clean sweeps and recall 1.0 over the above-margin multi-fault
    /// trials, for *every* encoding mode — adding A-side checksums must
    /// not change what is detected. Vacuously true when the axis is
    /// empty.
    pub fn multi_fault_gates_hold(&self) -> bool {
        self.multi_false_positives == 0
            && self.multi_cells.iter().all(|c| c.detected_above == c.above)
    }

    /// Total protection-plan injection trials.
    pub fn total_plan_trials(&self) -> usize {
        self.plan_cells.iter().map(|c| c.trials).sum()
    }

    /// Total protection-plan trials detected.
    pub fn total_plan_detected(&self) -> usize {
        self.plan_cells.iter().map(|c| c.detected).sum()
    }

    /// The protection-plan gate: every scheme the per-layer planner may
    /// select detects every injected trial (recall 1.0, cell by cell —
    /// the exponent-MSB upset is guaranteed visible) and its clean
    /// sweeps stay zero-FP. Vacuously true when the axis is empty. This
    /// is what licenses the planner to choose schemes on measured cost
    /// alone: protection quality is uniform across the vocabulary.
    pub fn plan_gates_hold(&self) -> bool {
        self.plan_false_positives == 0
            && self.plan_cells.iter().all(|c| c.detected == c.trials)
    }

    /// The replication-recovery gate: every injected trial of a
    /// dual-compute (replication) cell recovered an output bitwise-equal
    /// to the fault-free reference — replication repairs by recomputing
    /// divergent rows from clean inputs, so anything short of bitwise
    /// equality is a recovery bug. Vacuously true when the axis plans no
    /// replication cells.
    pub fn replication_bitwise_equal(&self) -> bool {
        self.plan_cells
            .iter()
            .filter(|c| c.spec.scheme == crate::planner::ProtectionScheme::Replicate)
            .all(|c| c.repaired_bitwise == c.trials)
    }

    /// The grid-coverage gate: each two-dimensional encoding corrects
    /// strictly more multi-fault trials without recomputation than the
    /// single-checksum baseline across the identical fault plan (row
    /// bursts are where the baseline must recompute). Vacuously true
    /// when the axis plans no baseline or no two-dimensional cells.
    pub fn grid_exceeds_baseline(&self) -> bool {
        if !self.multi_cells.iter().any(|c| !c.spec.encoding.two_dimensional()) {
            return true;
        }
        let base = self.multi_corrected_no_recompute(EncodingMode::RowOnly);
        let mut two_d: Vec<EncodingMode> = Vec::new();
        for c in &self.multi_cells {
            if c.spec.encoding.two_dimensional() && !two_d.contains(&c.spec.encoding) {
                two_d.push(c.spec.encoding);
            }
        }
        two_d.iter().all(|&e| self.multi_corrected_no_recompute(e) > base)
    }
}

/// Expected verification-difference magnitude of a realized fault, and
/// whether it clears the recall-gate margin. `delta` is the realized
/// source-value change; `thr` the V-ABFT row thresholds the pipeline
/// itself used (same code path, bitwise-identical values).
fn expected_effect(
    fault: &FaultSpec,
    delta: f64,
    a: &Matrix,
    b: &Matrix,
    thr: &[f64],
    margin: f64,
) -> (f64, bool) {
    match fault.site {
        FaultSite::Output { row, .. } | FaultSite::ChecksumR1 { row } => {
            let mag = delta.abs();
            (mag, !mag.is_finite() || mag > margin * thr[row])
        }
        FaultSite::OperandA { row, k, col } => {
            let mag = (delta * b.get(k, col)).abs();
            (mag, !mag.is_finite() || mag > margin * thr[row])
        }
        FaultSite::OperandB { k, .. } => {
            // Persistent B fault: row i of the struck column is perturbed
            // by a_ik·δ. Detection is guaranteed as soon as any single
            // row clears the margin.
            let mut mag = 0.0f64;
            let mut above = false;
            for i in 0..a.rows() {
                let e = (a.get(i, k) * delta).abs();
                if !e.is_finite() || e > margin * thr[i] {
                    above = true;
                }
                mag = mag.max(e);
            }
            if !delta.is_finite() {
                above = true;
                mag = f64::INFINITY;
            }
            (mag, above)
        }
    }
}

/// One registered operand set within a coordinator group, with the
/// weight-side state every cell on it shares: the prepared handle, the
/// clean FPR sweep's statistics, and both threshold vectors (the V-ABFT
/// thresholds bitwise as the pipeline issues them, the A-ABFT baseline
/// for tightness reporting) — computed once, reused by each cell.
struct OperandSet {
    stream: u64,
    sweep: usize,
    a: Matrix,
    b: Matrix,
    handle: WeightHandle,
    /// The same weights registered on the severity-axis coordinator
    /// (online groups only).
    shandle: Option<WeightHandle>,
    thr: Vec<f64>,
    threshold_min: f64,
    threshold_max: f64,
    aabft_threshold_max: f64,
    clean_rows: usize,
    false_positives: usize,
    clean_noise: f64,
}

/// A cell whose trial batch is in flight: everything needed to classify
/// it once its responses are collected (in planning order).
struct PendingCell {
    ci: usize,
    oi: usize,
    faults: Vec<FaultSpec>,
    pending: Vec<(u64, Receiver<GemmResponse>)>,
    /// The identical batch in flight on the severity-axis coordinator
    /// (online groups only).
    spending: Option<Vec<(u64, Receiver<GemmResponse>)>>,
}

/// One registered operand set within a multi-fault coordinator group:
/// the prepared handle, the clean work-grid accumulator (what the
/// planned online output-site flips strike), the pipeline's row
/// thresholds for the margin gate, and the shared clean-sweep counts.
struct MultiOperandSet {
    stream: u64,
    a: Matrix,
    handle: WeightHandle,
    acc: Matrix,
    thr: Vec<f64>,
    clean_rows: usize,
    false_positives: usize,
}

/// A multi-fault cell whose trial batch is in flight.
struct PendingMultiCell {
    ci: usize,
    oi: usize,
    fault_plan: Vec<Vec<FaultSpec>>,
    pending: Vec<(u64, Receiver<GemmResponse>)>,
}

/// Margin gate for one planned multi-fault trial: price each flip from
/// the clean work-grid accumulator it strikes, sum deltas *per row*
/// (simultaneous same-row flips can partially cancel in the unweighted
/// syndrome D1 — row detection keys off the net perturbation), and gate
/// the trial when any row's net perturbation is non-finite or clears
/// `margin ×` that row's threshold. With the zero-FP noise bound
/// `noise ≤ T` and margin > 2, detection of gated trials is a theorem.
fn multi_fault_above(
    faults: &[FaultSpec],
    acc: &Matrix,
    work: Precision,
    thr: &[f64],
    margin: f64,
) -> bool {
    let mut per_row: Vec<(usize, f64)> = Vec::new();
    for f in faults {
        let (row, col) = match f.site {
            FaultSite::Output { row, col } => (row, col),
            _ => continue,
        };
        let old = acc.get(row, col);
        let (new, _) = BitFlip::new(f.bit, work).apply(old);
        let delta = new - old;
        match per_row.iter_mut().find(|(r, _)| *r == row) {
            Some((_, s)) => *s += delta,
            None => per_row.push((row, delta)),
        }
    }
    per_row.iter().any(|&(row, s)| !s.is_finite() || s.abs() > margin * thr[row])
}

/// Execute a campaign grid with `workers` coordinator worker threads per
/// group. See the module docs for the determinism contract.
pub fn run(cfg: &GridConfig, workers: usize) -> CampaignOutcome {
    run_sharded(cfg, workers, 1)
}

/// [`run`] with an explicit coordinator shard count (`workers` workers
/// *per shard*). The determinism contract extends verbatim: shard
/// routing and cross-shard scheduling never touch a trial's arithmetic
/// or the collection order, so the same `(config, seed)` produces
/// byte-identical JSON at any `(workers, shards)` —
/// `tests/campaign_engine.rs` pins both axes.
pub fn run_sharded(cfg: &GridConfig, workers: usize, shards: usize) -> CampaignOutcome {
    let cells = plan(cfg);
    let mut results: Vec<Option<CellResult>> = cells.iter().map(|_| None).collect();
    let mut clean_rows_total = 0usize;
    let mut false_positives_total = 0usize;
    let mut severity_fp_total = 0usize;
    let mut sweeps = 0usize;
    let mut group_metrics: Vec<String> = Vec::new();

    // Group cell indices by coordinator key (accumulation model +
    // verification point): one worker pool per rounding schedule.
    let mut groups: Vec<((AccumModel, VerifyPoint), Vec<usize>)> = Vec::new();
    for (i, c) in cells.iter().enumerate() {
        let key = (c.model(), c.verify);
        match groups.iter_mut().find(|(k, _)| *k == key) {
            Some((_, v)) => v.push(i),
            None => groups.push((key, vec![i])),
        }
    }

    let vab = VabftThreshold::default();
    let aab = AabftThreshold::paper_repro();
    for ((model, verify), idxs) in groups {
        // Fused cells run the real fused path: detection inside the packed
        // GEMM epilogue (clean sweeps) or the same-arithmetic post-injection
        // sweep (injected trials) — not an analytical model.
        let policy =
            if verify.online() { VerifyPolicy::fused() } else { VerifyPolicy::offline() };
        let coord = Coordinator::start(CoordinatorConfig {
            workers: workers.max(1),
            queue_depth: 256,
            model,
            policy,
            shards: shards.max(1),
            ..Default::default()
        });
        // The severity axis: online groups re-run the *identical* fault
        // plan and clean sweeps under the severity-aware variant of the
        // same policy on a second coordinator. Detection decisions must
        // match the baseline cell-for-cell (`severity_no_downgrade`);
        // the pass only measures how many escalations turn into waivers.
        let scoord = if verify.online() {
            Some(Coordinator::start(CoordinatorConfig {
                workers: workers.max(1),
                queue_depth: 256,
                model,
                policy: policy.with_severity(),
                shards: shards.max(1),
                ..Default::default()
            }))
        } else {
            None
        };

        // Submission pass. Operand sets are registered once per (input,
        // dist, shape) stream within the group and shared by its cells;
        // the clean FPR sweep and both threshold vectors run once per
        // set, not per cell — the weight-stationary amortization the
        // campaign exists to exercise. Every cell's trial batch is
        // submitted before any is collected, so the worker pool stays
        // saturated across cell boundaries (collection order below is
        // still planning order — determinism is unaffected).
        let mut operands: Vec<OperandSet> = Vec::new();
        let mut batches: Vec<PendingCell> = Vec::new();
        for &ci in &idxs {
            let cell = &cells[ci];
            let stream = cell.operand_stream();
            let oi = match operands.iter().position(|o| o.stream == stream) {
                Some(oi) => oi,
                None => {
                    let (m, k, n) = cell.shape;
                    let mut rng = Xoshiro256pp::from_stream(cfg.seed ^ OPERAND_TAG, stream);
                    let a = Matrix::sample_in(m, k, &cell.dist, model.input, &mut rng);
                    let b = Matrix::sample_in(k, n, &cell.dist, model.input, &mut rng);
                    let handle = coord.register_weights(operands.len() as u32, &b);

                    // Thresholds exactly as the pipeline computes them
                    // (same prepared statistics, same context, same
                    // implementation — bitwise-identical values).
                    let blk = &handle.blocks()[0];
                    let thr = vab.thresholds_prepared(&a, &blk.stats, handle.ctx());
                    let a_thr = aab.thresholds(&a, &b, handle.ctx());

                    // The set's one clean run: the FPR sweep and the
                    // rounding-noise floor every cell on it shares.
                    let clean = coord
                        .call_prepared(PreparedGemmRequest {
                            a: a.clone(),
                            weights: Arc::clone(&handle),
                            inject: None,
                        })
                        .result
                        .expect("clean multiply failed");
                    clean_rows_total += clean.report.rows_checked;
                    false_positives_total += clean.report.detections.len();

                    // Severity-axis clean sweep: must stay zero-FP.
                    let shandle = scoord.as_ref().map(|sc| {
                        let sh = sc.register_weights(operands.len() as u32, &b);
                        let sclean = sc
                            .call_prepared(PreparedGemmRequest {
                                a: a.clone(),
                                weights: Arc::clone(&sh),
                                inject: None,
                            })
                            .result
                            .expect("severity clean multiply failed");
                        severity_fp_total += sclean.report.detections.len();
                        sh
                    });

                    operands.push(OperandSet {
                        stream,
                        sweep: sweeps,
                        a,
                        b,
                        handle,
                        shandle,
                        threshold_min: thr.iter().cloned().fold(f64::INFINITY, f64::min),
                        threshold_max: thr.iter().cloned().fold(0.0, f64::max),
                        aabft_threshold_max: a_thr.iter().cloned().fold(0.0, f64::max),
                        thr,
                        clean_rows: clean.report.rows_checked,
                        false_positives: clean.report.detections.len(),
                        clean_noise: clean.report.max_abs_d1,
                    });
                    sweeps += 1;
                    operands.len() - 1
                }
            };
            let set = &operands[oi];

            // One batch per cell: the cell's injected trials.
            let faults = cell.faults(cfg.seed);
            let reqs: Vec<PreparedGemmRequest> = faults
                .iter()
                .map(|f| PreparedGemmRequest {
                    a: set.a.clone(),
                    weights: Arc::clone(&set.handle),
                    inject: Some(InjectSpec::single(*f)),
                })
                .collect();
            let pending = coord.submit_batch_prepared(reqs);
            coord.metrics().campaign_trials.add(faults.len() as u64);
            let spending = match (&scoord, &set.shandle) {
                (Some(sc), Some(sh)) => {
                    let sreqs: Vec<PreparedGemmRequest> = faults
                        .iter()
                        .map(|f| PreparedGemmRequest {
                            a: set.a.clone(),
                            weights: Arc::clone(sh),
                            inject: Some(InjectSpec::single(*f)),
                        })
                        .collect();
                    Some(sc.submit_batch_prepared(sreqs))
                }
                _ => None,
            };
            batches.push(PendingCell { ci, oi, faults, pending, spending });
        }

        // Collection pass, in planning order.
        for pc in batches {
            let cell = &cells[pc.ci];
            let set = &operands[pc.oi];
            let responses: Vec<GemmResponse> = pc
                .pending
                .into_iter()
                .map(|(_, rx)| rx.recv().expect("campaign worker died"))
                .collect();
            let sresponses: Option<Vec<GemmResponse>> = pc.spending.map(|sp| {
                sp.into_iter()
                    .map(|(_, rx)| rx.recv().expect("severity campaign worker died"))
                    .collect()
            });

            let mut res = CellResult {
                spec: cell.clone(),
                sweep: set.sweep,
                bit: cell.bit(),
                trials: 0,
                detected: 0,
                above: 0,
                detected_above: 0,
                detected_below: 0,
                clean_rows: set.clean_rows,
                false_positives: set.false_positives,
                max_magnitude: 0.0,
                clean_noise: set.clean_noise,
                threshold_min: set.threshold_min,
                threshold_max: set.threshold_max,
                aabft_threshold_max: set.aabft_threshold_max,
                severity_detected: 0,
                severity_waived: 0,
            };

            for (f, resp) in pc.faults.iter().zip(&responses) {
                let out = resp.result.as_ref().expect("campaign multiply failed");
                let realized = resp.injected.expect("injection outcome missing");
                let detected = out.report.verdict != Verdict::Clean;
                let (mag, above) =
                    expected_effect(f, realized.delta(), &set.a, &set.b, &set.thr, cfg.margin);
                res.trials += 1;
                if mag.is_finite() {
                    res.max_magnitude = res.max_magnitude.max(mag);
                } else {
                    res.max_magnitude = f64::INFINITY;
                }
                if above {
                    res.above += 1;
                    if detected {
                        res.detected_above += 1;
                    }
                } else if detected {
                    res.detected_below += 1;
                }
                if detected {
                    res.detected += 1;
                }
            }
            match &sresponses {
                Some(srs) => {
                    for resp in srs {
                        let out = resp.result.as_ref().expect("severity multiply failed");
                        if out.report.verdict != Verdict::Clean {
                            res.severity_detected += 1;
                        }
                        res.severity_waived += out.report.rows_waived.min(1);
                    }
                }
                // Offline groups: the axis doesn't apply; mirror the
                // baseline so the no-downgrade gate is vacuous here.
                None => res.severity_detected = res.detected,
            }
            results[pc.ci] = Some(res);
            coord.metrics().campaign_cells.inc();
        }
        group_metrics
            .push(format!("{} {}: {}", model.label(), verify.name(), coord.metrics().summary()));
        coord.shutdown();
        if let Some(sc) = scoord {
            sc.shutdown();
        }
    }

    // ---- Multi-fault axis: simultaneous flips × burst pattern ×
    // encoding mode, compared over identical operands and fault plans.
    // One coordinator per (model, encoding): prepared weights carry
    // encoding-specific state (A-side column statistics for 2D modes),
    // and the grid-vs-baseline gate needs each geometry to see the same
    // trials through its own policy.
    let multi_specs = plan_multi_fault(cfg);
    let mut multi_results: Vec<Option<MultiCellResult>> =
        multi_specs.iter().map(|_| None).collect();
    let mut multi_clean_rows = 0usize;
    let mut multi_fp = 0usize;

    let mut mgroups: Vec<((AccumModel, EncodingMode), Vec<usize>)> = Vec::new();
    for (i, c) in multi_specs.iter().enumerate() {
        let key = (c.model(), c.encoding);
        match mgroups.iter_mut().find(|(k, _)| *k == key) {
            Some((_, v)) => v.push(i),
            None => mgroups.push((key, vec![i])),
        }
    }

    for ((model, encoding), idxs) in mgroups {
        let policy = VerifyPolicy {
            encoding,
            localize_tol: cfg.localize_tol,
            ..VerifyPolicy::default()
        };
        let coord = Coordinator::start(CoordinatorConfig {
            workers: workers.max(1),
            queue_depth: 256,
            model,
            policy,
            shards: shards.max(1),
            ..Default::default()
        });
        let engine = GemmEngine::new(model);

        let mut operands: Vec<MultiOperandSet> = Vec::new();
        let mut batches: Vec<PendingMultiCell> = Vec::new();
        for &ci in &idxs {
            let cell = &multi_specs[ci];
            let stream = cell.operand_stream();
            let oi = match operands.iter().position(|o| o.stream == stream) {
                Some(oi) => oi,
                None => {
                    let (m, k, n) = cell.shape;
                    let mut rng = Xoshiro256pp::from_stream(cfg.seed ^ OPERAND_TAG, stream);
                    let a = Matrix::sample_in(m, k, &cell.dist, model.input, &mut rng);
                    let b = Matrix::sample_in(k, n, &cell.dist, model.input, &mut rng);
                    let handle = coord.register_weights(operands.len() as u32, &b);
                    let blk = &handle.blocks()[0];
                    let thr = vab.thresholds_prepared(&a, &blk.stats, handle.ctx());

                    // The clean work-grid accumulator the online
                    // output-site flips strike. Schedule preservation
                    // makes the data elements bitwise-identical to the
                    // encoded multiply at every checksum geometry, so
                    // one unencoded product prices every planned flip.
                    let acc = engine.matmul_mixed(&a, &b, 0).acc;

                    // The set's clean FPR sweep under this encoding.
                    let clean = coord
                        .call_prepared(PreparedGemmRequest {
                            a: a.clone(),
                            weights: Arc::clone(&handle),
                            inject: None,
                        })
                        .result
                        .expect("multi-fault clean multiply failed");
                    multi_clean_rows += clean.report.rows_checked;
                    multi_fp += clean.report.detections.len();

                    operands.push(MultiOperandSet {
                        stream,
                        a,
                        handle,
                        acc,
                        thr,
                        clean_rows: clean.report.rows_checked,
                        false_positives: clean.report.detections.len(),
                    });
                    operands.len() - 1
                }
            };
            let set = &operands[oi];

            let fault_plan = cell.fault_plan(cfg.seed);
            let reqs: Vec<PreparedGemmRequest> = fault_plan
                .iter()
                .map(|fs| PreparedGemmRequest {
                    a: set.a.clone(),
                    weights: Arc::clone(&set.handle),
                    inject: Some(InjectSpec::multi(fs.clone())),
                })
                .collect();
            let pending = coord.submit_batch_prepared(reqs);
            coord.metrics().campaign_trials.add(fault_plan.len() as u64);
            batches.push(PendingMultiCell { ci, oi, fault_plan, pending });
        }

        // Collection pass, in planning order.
        for pc in batches {
            let cell = &multi_specs[pc.ci];
            let set = &operands[pc.oi];
            let mut res = MultiCellResult {
                spec: cell.clone(),
                bit: cell.bit(),
                trials: 0,
                detected: 0,
                above: 0,
                detected_above: 0,
                corrected_no_recompute: 0,
                rows_corrected_grid: 0,
                inconsistent_localizations: 0,
                rows_recomputed: 0,
                clean_rows: set.clean_rows,
                false_positives: set.false_positives,
            };
            for (faults, (_, rx)) in pc.fault_plan.iter().zip(pc.pending) {
                let resp = rx.recv().expect("multi-fault campaign worker died");
                let out = resp.result.as_ref().expect("multi-fault multiply failed");
                let detected = out.report.verdict != Verdict::Clean;
                let above =
                    multi_fault_above(faults, &set.acc, model.work, &set.thr, cfg.margin);
                res.trials += 1;
                if detected {
                    res.detected += 1;
                }
                if above {
                    res.above += 1;
                    if detected {
                        res.detected_above += 1;
                    }
                }
                let all_corrected = matches!(
                    out.report.verdict,
                    Verdict::Corrected | Verdict::CorrectedGrid
                );
                if all_corrected && out.report.rows_recomputed == 0 {
                    res.corrected_no_recompute += 1;
                }
                res.rows_corrected_grid += out.report.rows_corrected_grid;
                res.inconsistent_localizations += out.report.inconsistent_localizations;
                res.rows_recomputed += out.report.rows_recomputed;
            }
            multi_results[pc.ci] = Some(res);
            coord.metrics().campaign_cells.inc();
        }
        group_metrics.push(format!(
            "{} multi/{}: {}",
            model.label(),
            encoding.name(),
            coord.metrics().summary()
        ));
        coord.shutdown();
    }

    // ---- Protection-plan axis: every scheme of the planner's
    // vocabulary validated through the production path — a `PlanEntry`
    // registered on the weight handle via `register_weights_planned`, so
    // the worker's scheme dispatch (staged / fused / grid / block-K /
    // replicated) is exactly what a planned serving run executes. Trials
    // run serially per cell in planning order; every trial's arithmetic
    // is schedule-preserved, so the axis is byte-stable at any
    // `(workers, shards)` like the rest of the campaign.
    let plan_specs = plan_protection(cfg);
    let mut plan_results: Vec<Option<PlanCellResult>> =
        plan_specs.iter().map(|_| None).collect();
    let mut plan_clean_rows = 0usize;
    let mut plan_fp = 0usize;

    let mut pgroups: Vec<(AccumModel, Vec<usize>)> = Vec::new();
    for (i, c) in plan_specs.iter().enumerate() {
        let key = c.model();
        match pgroups.iter_mut().find(|(k, _)| *k == key) {
            Some((_, v)) => v.push(i),
            None => pgroups.push((key, vec![i])),
        }
    }

    for (model, idxs) in pgroups {
        let coord = Coordinator::start(CoordinatorConfig {
            workers: workers.max(1),
            queue_depth: 256,
            model,
            shards: shards.max(1),
            ..Default::default()
        });
        for &ci in &idxs {
            let cell = &plan_specs[ci];
            let (m, k, n) = cell.shape;
            let mut rng =
                Xoshiro256pp::from_stream(cfg.seed ^ OPERAND_TAG, cell.operand_stream());
            let a = Matrix::sample_in(m, k, &cell.dist, model.input, &mut rng);
            let b = Matrix::sample_in(k, n, &cell.dist, model.input, &mut rng);
            let entry = crate::planner::PlanEntry {
                weight: ci,
                name: cell.scheme.label(),
                m,
                k,
                n,
                intensity: crate::planner::arithmetic_intensity(m, k, n),
                scheme: cell.scheme,
                predicted_ns: 0.0,
            };
            let handle = coord.register_weights_planned(ci as u32, &b, &entry);

            // Per-scheme clean sweep: the fault-free reference for the
            // bitwise-recovery gate, and the axis' zero-FP evidence.
            let clean = coord
                .call_prepared(PreparedGemmRequest {
                    a: a.clone(),
                    weights: Arc::clone(&handle),
                    inject: None,
                })
                .result
                .expect("plan-axis clean multiply failed");
            plan_clean_rows += clean.report.rows_checked;
            plan_fp += clean.report.detections.len();

            let faults = cell.faults(cfg.seed);
            coord.metrics().campaign_trials.add(faults.len() as u64);
            let mut res = PlanCellResult {
                spec: cell.clone(),
                bit: cell.bit(),
                trials: 0,
                detected: 0,
                clean_rows: clean.report.rows_checked,
                false_positives: clean.report.detections.len(),
                repaired_bitwise: 0,
            };
            for f in &faults {
                let resp = coord.call_prepared(PreparedGemmRequest {
                    a: a.clone(),
                    weights: Arc::clone(&handle),
                    inject: Some(InjectSpec::single(*f)),
                });
                let out = resp.result.expect("plan-axis multiply failed");
                res.trials += 1;
                if out.report.verdict != Verdict::Clean {
                    res.detected += 1;
                }
                let bitwise = out
                    .c
                    .data()
                    .iter()
                    .zip(clean.c.data())
                    .all(|(x, y)| x.to_bits() == y.to_bits());
                if bitwise {
                    res.repaired_bitwise += 1;
                }
            }
            plan_results[ci] = Some(res);
            coord.metrics().campaign_cells.inc();
        }
        group_metrics.push(format!("{} plan: {}", model.label(), coord.metrics().summary()));
        coord.shutdown();
    }

    let cells_out: Vec<CellResult> =
        results.into_iter().map(|r| r.expect("cell never executed")).collect();
    let multi_out: Vec<MultiCellResult> =
        multi_results.into_iter().map(|r| r.expect("multi-fault cell never executed")).collect();
    let plan_out: Vec<PlanCellResult> =
        plan_results.into_iter().map(|r| r.expect("plan cell never executed")).collect();
    CampaignOutcome {
        config: cfg.clone(),
        cells: cells_out,
        multi_cells: multi_out,
        multi_clean_rows,
        multi_false_positives: multi_fp,
        plan_cells: plan_out,
        plan_clean_rows,
        plan_false_positives: plan_fp,
        clean_rows: clean_rows_total,
        false_positives: false_positives_total,
        severity_false_positives: severity_fp_total,
        group_metrics,
    }
}
