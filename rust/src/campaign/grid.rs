//! Campaign grid planning: the deterministic trial lattice.
//!
//! A campaign is a cross product of seven axes — storage precision ×
//! reduction strategy × operand distribution × injection-site class ×
//! encoding-bit class × verification point × GEMM shape — planned into
//! [`CellSpec`]s by [`plan`]. Every random choice (operand samples, fault
//! coordinates) derives from the campaign's single master seed through
//! fixed [`crate::rng::Xoshiro256pp`] streams indexed by cell position,
//! so the full grid is reproducible bit-for-bit from `(seed, config)` —
//! at any coordinator worker count, because the engine's
//! schedule-preservation invariant makes each trial's arithmetic
//! thread-count-independent.

use crate::abft::EncodingMode;
use crate::fp::Precision;
use crate::gemm::{AccumModel, ReduceStrategy};
use crate::inject::{FaultSite, FaultSpec, SiteClass};
use crate::rng::{Distribution, Rng, Xoshiro256pp};

/// Stream tag separating fault-coordinate RNG streams from operand
/// streams (both key off the master seed).
const COORD_TAG: u64 = 0xC00D_1247;

/// Stream tag of the multi-fault axis' coordinate streams (disjoint from
/// both the single-fault coordinate and the operand streams).
const MULTI_TAG: u64 = 0x517E_BD2C;

/// Stream tag of the protection-plan axis' coordinate streams (disjoint
/// from the single-fault, multi-fault and operand streams).
const PLAN_TAG: u64 = 0x9AF7_71E3;

/// Which encoding bit a cell flips, named relative to the target
/// precision's layout so one class means the same physical event across
/// grids (paper Table 8's rows, collapsed to the four regimes).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BitClass {
    /// The sign bit: magnitude-preserving, error `2|v|`.
    Sign,
    /// Exponent MSB: the catastrophic class (overflow/underflow scale).
    ExpMsb,
    /// Exponent LSB: value doubles or halves.
    ExpLsb,
    /// Mantissa MSB: relative error up to 25%.
    MantMsb,
}

impl BitClass {
    /// All four classes, in campaign grid order.
    pub const ALL: [BitClass; 4] =
        [BitClass::Sign, BitClass::ExpMsb, BitClass::ExpLsb, BitClass::MantMsb];

    /// Short lowercase name used in reports and JSON documents.
    pub fn name(self) -> &'static str {
        match self {
            BitClass::Sign => "sign",
            BitClass::ExpMsb => "exp_msb",
            BitClass::ExpLsb => "exp_lsb",
            BitClass::MantMsb => "mant_msb",
        }
    }

    /// Resolve to a bit position of `p`'s encoding.
    pub fn bit(self, p: Precision) -> u32 {
        match self {
            BitClass::Sign => p.sign_bit(),
            BitClass::ExpMsb => p.sign_bit() - 1,
            BitClass::ExpLsb => p.exponent_lsb(),
            BitClass::MantMsb => p.exponent_lsb().saturating_sub(1),
        }
    }
}

/// Verification point of a cell (§3.6): fused verification reads the
/// pre-quantization accumulator (e_max ≈ 1e-6 for FP32 datapaths),
/// offline verification the quantized stored output (e_max ≈ 2·u_out,
/// ≈ 1e-3 for BF16) — the ~1000× detection-granularity gap the campaign
/// report quantifies.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum VerifyPoint {
    /// Fused / online: verify the accumulator before output rounding.
    Fused,
    /// Offline: verify the stored (quantized) output.
    Offline,
}

impl VerifyPoint {
    /// Short lowercase name used in reports and JSON documents.
    pub fn name(self) -> &'static str {
        match self {
            VerifyPoint::Fused => "fused",
            VerifyPoint::Offline => "offline",
        }
    }

    /// True for fused (pre-quantization) verification.
    pub fn online(self) -> bool {
        matches!(self, VerifyPoint::Fused)
    }
}

/// Spatial arrangement of one multi-fault trial's simultaneous flips —
/// the burst-pattern axis of the multi-fault grid. The patterns pick out
/// the three correction regimes of the 2D encoding:
///
/// * [`BurstPattern::RowBurst`] — every flip in one output row: the row
///   syndrome is inconsistent with a single upset, so the single-checksum
///   baseline must recompute, while column/grid encodings repair each
///   struck column from the A-side checksums.
/// * [`BurstPattern::ColBurst`] — every flip in one output column: each
///   affected row carries a single upset, so row localization corrects
///   in place under every encoding (the parity case the coverage gate
///   uses as its control).
/// * [`BurstPattern::Scatter`] — flips at distinct rows *and* distinct
///   columns: again one upset per row, correctable by the row direction
///   alone.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BurstPattern {
    /// All flips strike one output row (distinct columns).
    RowBurst,
    /// All flips strike one output column (distinct rows).
    ColBurst,
    /// Flips at pairwise-distinct rows and columns.
    Scatter,
}

impl BurstPattern {
    /// All three patterns, in campaign grid order.
    pub const ALL: [BurstPattern; 3] =
        [BurstPattern::RowBurst, BurstPattern::ColBurst, BurstPattern::Scatter];

    /// Short lowercase name used in reports and JSON documents.
    pub fn name(self) -> &'static str {
        match self {
            BurstPattern::RowBurst => "row_burst",
            BurstPattern::ColBurst => "col_burst",
            BurstPattern::Scatter => "scatter",
        }
    }
}

/// Configuration of a campaign grid. Construct via [`GridConfig::quick`],
/// [`GridConfig::full`] or [`GridConfig::smoke`] and adjust fields as
/// needed; [`plan`] expands it into cells.
#[derive(Debug, Clone)]
pub struct GridConfig {
    /// Master seed — every operand sample and fault coordinate derives
    /// from it deterministically.
    pub seed: u64,
    /// Mode label recorded in the JSON document (`"quick"`, `"full"`,
    /// `"smoke"`).
    pub mode: String,
    /// Storage precisions under test (each resolves to its platform
    /// accumulation model, see [`CellSpec::model`]).
    pub precisions: Vec<Precision>,
    /// Reduction strategies (rounding schedules) under test.
    pub strategies: Vec<ReduceStrategy>,
    /// Operand distributions.
    pub dists: Vec<Distribution>,
    /// Injection-site classes.
    pub sites: Vec<SiteClass>,
    /// Encoding-bit classes.
    pub bit_classes: Vec<BitClass>,
    /// Site classes that additionally get offline (post-quantization)
    /// cells; every site in `sites` always gets a fused cell.
    pub offline_sites: Vec<SiteClass>,
    /// GEMM shapes (M, K, N).
    pub shapes: Vec<(usize, usize, usize)>,
    /// Injection trials per cell (plus one clean trial per cell).
    pub trials_per_cell: usize,
    /// Above-threshold margin: a fault counts toward the recall gate when
    /// its expected magnitude exceeds `margin ×` the row's threshold (or
    /// is non-finite). With the zero-FP noise bound `noise ≤ T`, any
    /// margin > 2 makes detection of gated faults a theorem, not a
    /// statistic; the default of 6 additionally absorbs requantization
    /// error on coarse output grids.
    pub margin: f64,
    /// Simultaneous flip counts of the multi-fault axis (2 = the classic
    /// double upset; larger counts model wider bursts). Empty disables
    /// the axis.
    pub multi_flips: Vec<usize>,
    /// Burst patterns of the multi-fault axis.
    pub burst_patterns: Vec<BurstPattern>,
    /// Checksum encoding modes the multi-fault axis compares; must
    /// include [`EncodingMode::RowOnly`] for the grid-vs-baseline
    /// coverage gate to bind.
    pub encodings: Vec<EncodingMode>,
    /// Injection trials per multi-fault cell.
    pub multi_trials_per_cell: usize,
    /// Localization tolerance forwarded to every campaign verification
    /// policy (see [`crate::abft::VerifyPolicy::localize_tol`] for the
    /// derivation of the 0.45 default).
    pub localize_tol: f64,
}

impl GridConfig {
    fn base(seed: u64, mode: &str) -> GridConfig {
        GridConfig {
            seed,
            mode: mode.to_string(),
            precisions: vec![Precision::Bf16, Precision::F16, Precision::F32, Precision::F64],
            strategies: vec![
                ReduceStrategy::Sequential,
                ReduceStrategy::Fma,
                ReduceStrategy::Pairwise,
            ],
            dists: vec![Distribution::normal_1_1(), Distribution::uniform_01()],
            sites: SiteClass::ALL.to_vec(),
            bit_classes: BitClass::ALL.to_vec(),
            offline_sites: vec![SiteClass::Output],
            shapes: vec![(8, 64, 16)],
            trials_per_cell: 3,
            margin: 6.0,
            multi_flips: vec![2, 3],
            burst_patterns: BurstPattern::ALL.to_vec(),
            encodings: vec![EncodingMode::RowOnly, EncodingMode::RowCol, EncodingMode::Grid],
            multi_trials_per_cell: 3,
            localize_tol: 0.45,
        }
    }

    /// The CI-gated grid: all four storage precisions × three reduction
    /// strategies × two distributions × four site classes × four bit
    /// classes, fused everywhere plus offline output cells — 480 cells,
    /// small shapes, completing well under a minute.
    pub fn quick(seed: u64) -> GridConfig {
        Self::base(seed, "quick")
    }

    /// The nightly grid: adds the truncated-normal distribution, offline
    /// cells for every site class, a second paper-scale shape and more
    /// trials per cell.
    pub fn full(seed: u64) -> GridConfig {
        let mut cfg = Self::base(seed, "full");
        cfg.dists.push(Distribution::truncated_normal());
        cfg.offline_sites = SiteClass::ALL.to_vec();
        cfg.shapes = vec![(32, 256, 64), (128, 1024, 256)];
        cfg.trials_per_cell = 6;
        cfg.multi_flips = vec![2, 3, 4];
        cfg.multi_trials_per_cell = 6;
        cfg
    }

    /// A 20-cell sub-grid for determinism tests and the push-gated CI
    /// smoke step: BF16 + FP32, FMA only, exponent-MSB and mantissa-MSB
    /// bits, all four site classes.
    pub fn smoke(seed: u64) -> GridConfig {
        let mut cfg = Self::base(seed, "smoke");
        cfg.precisions = vec![Precision::Bf16, Precision::F32];
        cfg.strategies = vec![ReduceStrategy::Fma];
        cfg.dists = vec![Distribution::normal_1_1()];
        cfg.bit_classes = vec![BitClass::ExpMsb, BitClass::MantMsb];
        cfg.trials_per_cell = 4;
        // A minimal multi-fault slice: the divergent pattern (row burst)
        // plus its control (column burst), grid vs the row-only baseline
        // — 8 cells exercising the coverage gate on every smoke run.
        cfg.multi_flips = vec![2];
        cfg.burst_patterns = vec![BurstPattern::RowBurst, BurstPattern::ColBurst];
        cfg.encodings = vec![EncodingMode::RowOnly, EncodingMode::Grid];
        cfg
    }
}

/// One planned cell: a point of the campaign lattice plus its trial
/// budget. Cells own no results — [`crate::campaign::run`] pairs them
/// with [`crate::campaign::CellResult`]s in planning order.
#[derive(Debug, Clone)]
pub struct CellSpec {
    /// Position in planning order (also the fault-coordinate RNG stream).
    pub index: usize,
    /// Storage precision under test.
    pub precision: Precision,
    /// Reduction strategy (rounding schedule).
    pub strategy: ReduceStrategy,
    /// Operand distribution.
    pub dist: Distribution,
    /// Injection-site class.
    pub site: SiteClass,
    /// Encoding-bit class.
    pub bit_class: BitClass,
    /// Verification point.
    pub verify: VerifyPoint,
    /// GEMM shape (M, K, N).
    pub shape: (usize, usize, usize),
    /// Injection trials (one clean trial is always added).
    pub trials: usize,
}

/// The accumulation model a campaign runs a storage precision under:
/// wide FP32 accumulation for the sub-32-bit formats (the GPU/NPU
/// mixed-precision model), native accumulation for FP32/FP64 — with
/// `strategy` substituted in as the reduction schedule.
pub fn model_for(precision: Precision, strategy: ReduceStrategy) -> AccumModel {
    let base = match precision {
        Precision::F32 | Precision::F64 => AccumModel::gpu_highprec(precision),
        p => AccumModel::wide(p),
    };
    AccumModel { strategy, ..base }
}

impl CellSpec {
    /// The accumulation model of this cell (see [`model_for`]).
    pub fn model(&self) -> AccumModel {
        model_for(self.precision, self.strategy)
    }

    /// Precision grid the cell's flips address: the verified grid (work
    /// precision fused, output precision offline) for output and checksum
    /// sites, the operand storage grid for operand sites.
    pub fn flip_grid(&self) -> Precision {
        let m = self.model();
        match self.site {
            SiteClass::OperandA | SiteClass::OperandB => m.input,
            SiteClass::Output | SiteClass::Checksum => {
                if self.verify.online() {
                    m.work
                } else {
                    m.out
                }
            }
        }
    }

    /// The bit position this cell flips.
    pub fn bit(&self) -> u32 {
        self.bit_class.bit(self.flip_grid())
    }

    /// Stream index of the cell's operand set. Cells sharing (input
    /// precision, distribution, shape) share operands — and hence, per
    /// coordinator, prepared weights — which is what lets the engine
    /// amortize checksum encoding across the weight-stationary trials.
    pub fn operand_stream(&self) -> u64 {
        operand_stream_for(self.model().input, &self.dist, self.shape)
    }

    /// The cell's planned faults, deterministically derived from the
    /// master seed: trial t's coordinates come from substream
    /// `(seed ^ COORD_TAG, cell index)`, drawn in a fixed order.
    pub fn faults(&self, seed: u64) -> Vec<FaultSpec> {
        let (m, k, n) = self.shape;
        let mut rng = Xoshiro256pp::from_stream(seed ^ COORD_TAG, self.index as u64);
        let bit = self.bit();
        (0..self.trials)
            .map(|_| {
                let row = rng.uniform_u64(m as u64) as usize;
                let kk = rng.uniform_u64(k as u64) as usize;
                let col = rng.uniform_u64(n as u64) as usize;
                let site = match self.site {
                    SiteClass::Output => FaultSite::Output { row, col },
                    SiteClass::OperandA => FaultSite::OperandA { row, k: kk, col },
                    SiteClass::OperandB => FaultSite::OperandB { k: kk, col },
                    SiteClass::Checksum => FaultSite::ChecksumR1 { row },
                };
                FaultSpec { site, bit }
            })
            .collect()
    }

    /// Compact label for progress lines and failure messages.
    pub fn label(&self) -> String {
        let (m, k, n) = self.shape;
        format!(
            "{}x{}x{} {} {} {} {} {}",
            m,
            k,
            n,
            self.precision.name(),
            self.strategy.name(),
            self.site.name(),
            self.bit_class.name(),
            self.verify.name()
        )
    }
}

/// The shared operand-stream key: cells (single- or multi-fault) that
/// agree on (input precision, distribution, shape) sample identical
/// operands — which also makes the multi-fault axis' encodings compare
/// coverage over bitwise-identical inputs.
pub fn operand_stream_for(input: Precision, dist: &Distribution, shape: (usize, usize, usize)) -> u64 {
    let (m, k, n) = shape;
    let label = dist.label();
    let h = crate::rng::fnv1a(
        crate::rng::FNV1A_OFFSET,
        input.name().bytes().chain(label.bytes()),
    );
    h ^ ((m as u64) << 42) ^ ((k as u64) << 21) ^ n as u64
}

/// One planned multi-fault cell: a point of the (flip count × burst
/// pattern × encoding mode) lattice. Every trial injects `flips`
/// simultaneous output-site upsets arranged by `pattern`, verified
/// online under `encoding` — the axis that measures which checksum
/// geometry repairs multi-fault patterns without recomputation.
#[derive(Debug, Clone)]
pub struct MultiCellSpec {
    /// Position in planning order (also the fault-coordinate RNG stream).
    pub index: usize,
    /// Storage precision under test.
    pub precision: Precision,
    /// Reduction strategy (rounding schedule).
    pub strategy: ReduceStrategy,
    /// Operand distribution.
    pub dist: Distribution,
    /// Spatial arrangement of the simultaneous flips.
    pub pattern: BurstPattern,
    /// Simultaneous flips per trial.
    pub flips: usize,
    /// Checksum encoding mode the trial is verified under.
    pub encoding: EncodingMode,
    /// GEMM shape (M, K, N).
    pub shape: (usize, usize, usize),
    /// Injection trials.
    pub trials: usize,
}

impl MultiCellSpec {
    /// The accumulation model of this cell (see [`model_for`]).
    pub fn model(&self) -> AccumModel {
        model_for(self.precision, self.strategy)
    }

    /// The bit position every flip addresses: the exponent LSB of the
    /// verified (work) grid — the multi-fault axis runs online. An
    /// exponent-LSB flip halves or doubles the struck accumulator value,
    /// so each fault stays finite (correctable in place) while typically
    /// clearing the detection threshold by orders of magnitude.
    pub fn bit(&self) -> u32 {
        BitClass::ExpLsb.bit(self.model().work)
    }

    /// Stream index of the cell's operand set (see [`operand_stream_for`]).
    pub fn operand_stream(&self) -> u64 {
        operand_stream_for(self.model().input, &self.dist, self.shape)
    }

    /// The cell's planned trials, deterministically derived from the
    /// master seed: trial t's coordinates come from substream
    /// `(seed ^ MULTI_TAG, cell index)`, drawn in a fixed order. Each
    /// inner vector is one trial's simultaneous faults.
    pub fn fault_plan(&self, seed: u64) -> Vec<Vec<FaultSpec>> {
        let (m, _k, n) = self.shape;
        let mut rng = Xoshiro256pp::from_stream(seed ^ MULTI_TAG, self.index as u64);
        let bit = self.bit();
        (0..self.trials)
            .map(|_| match self.pattern {
                BurstPattern::RowBurst => {
                    let row = rng.uniform_u64(m as u64) as usize;
                    distinct(&mut rng, n, self.flips)
                        .into_iter()
                        .map(|col| FaultSpec::output(row, col, bit))
                        .collect()
                }
                BurstPattern::ColBurst => {
                    let col = rng.uniform_u64(n as u64) as usize;
                    distinct(&mut rng, m, self.flips)
                        .into_iter()
                        .map(|row| FaultSpec::output(row, col, bit))
                        .collect()
                }
                BurstPattern::Scatter => {
                    let rows = distinct(&mut rng, m, self.flips);
                    let cols = distinct(&mut rng, n, self.flips);
                    rows.into_iter()
                        .zip(cols)
                        .map(|(row, col)| FaultSpec::output(row, col, bit))
                        .collect()
                }
            })
            .collect()
    }

    /// Compact label for progress lines and failure messages.
    pub fn label(&self) -> String {
        let (m, k, n) = self.shape;
        format!(
            "{}x{}x{} {} {} {}x{} {}",
            m,
            k,
            n,
            self.precision.name(),
            self.strategy.name(),
            self.pattern.name(),
            self.flips,
            self.encoding.name()
        )
    }
}

/// One planned protection-plan cell: a point of the (precision ×
/// protection scheme) lattice validating that *every* scheme the
/// per-layer planner may select detects injected faults with recall 1.0
/// and zero false positives on clean sweeps — regardless of what the
/// cost model would have chosen. The axis is what licenses the planner
/// to pick any vocabulary member on measured cost alone.
#[derive(Debug, Clone)]
pub struct PlanCellSpec {
    /// Position in planning order (also the fault-coordinate RNG stream).
    pub index: usize,
    /// Storage precision under test.
    pub precision: Precision,
    /// Reduction strategy (rounding schedule).
    pub strategy: ReduceStrategy,
    /// Operand distribution.
    pub dist: Distribution,
    /// The protection scheme under test.
    pub scheme: crate::planner::ProtectionScheme,
    /// GEMM shape (M, K, N).
    pub shape: (usize, usize, usize),
    /// Injection trials (one clean trial is always added).
    pub trials: usize,
}

impl PlanCellSpec {
    /// The accumulation model of this cell (see [`model_for`]).
    pub fn model(&self) -> AccumModel {
        model_for(self.precision, self.strategy)
    }

    /// The bit position every flip addresses: the exponent MSB of the
    /// verified (work) grid. Normal accumulator magnitudes keep that bit
    /// clear, so the flip always explodes the struck value by many
    /// orders of magnitude — detection is guaranteed for every scheme
    /// (threshold-based or bitwise-compared), no margin gate needed.
    pub fn bit(&self) -> u32 {
        BitClass::ExpMsb.bit(self.model().work)
    }

    /// Stream index of the cell's operand set (see [`operand_stream_for`]).
    pub fn operand_stream(&self) -> u64 {
        operand_stream_for(self.model().input, &self.dist, self.shape)
    }

    /// The cell's planned faults, deterministically derived from the
    /// master seed: trial t's coordinates come from substream
    /// `(seed ^ PLAN_TAG, cell index)`, drawn in a fixed order. All
    /// output-site flips — the accumulator upset every scheme must catch.
    pub fn faults(&self, seed: u64) -> Vec<FaultSpec> {
        let (m, _k, n) = self.shape;
        let mut rng = Xoshiro256pp::from_stream(seed ^ PLAN_TAG, self.index as u64);
        let bit = self.bit();
        (0..self.trials)
            .map(|_| {
                let row = rng.uniform_u64(m as u64) as usize;
                let col = rng.uniform_u64(n as u64) as usize;
                FaultSpec::output(row, col, bit)
            })
            .collect()
    }

    /// Compact label for progress lines and failure messages.
    pub fn label(&self) -> String {
        let (m, k, n) = self.shape;
        format!("{}x{}x{} {} {}", m, k, n, self.precision.name(), self.scheme.label())
    }
}

/// Expand the protection-plan axis into cells, in the fixed planning
/// order (precision ⊃ scheme vocabulary). Like the multi-fault axis it
/// stays compact — shape, strategy and distribution fix to the config's
/// first entries; the dimension under test is the planner's full scheme
/// vocabulary, including the non-schedule-neutral `BlockK` member the
/// default planner only emits when explicitly enabled. Returns an empty
/// plan when the borrowed base axes are empty.
pub fn plan_protection(cfg: &GridConfig) -> Vec<PlanCellSpec> {
    let mut cells = Vec::new();
    if cfg.shapes.is_empty() || cfg.strategies.is_empty() || cfg.dists.is_empty() {
        return cells;
    }
    let shape = cfg.shapes[0];
    let strategy = cfg.strategies[0];
    let dist = cfg.dists[0].clone();
    // Split the shape's reduction into two K-blocks so the BlockK cell
    // exercises real per-block verification.
    let block_k = (shape.1 / 2).max(1);
    for &precision in &cfg.precisions {
        for scheme in crate::planner::ProtectionScheme::vocabulary(block_k) {
            cells.push(PlanCellSpec {
                index: cells.len(),
                precision,
                strategy,
                dist: dist.clone(),
                scheme,
                shape,
                trials: cfg.trials_per_cell,
            });
        }
    }
    cells
}

/// `count` pairwise-distinct draws from `0..bound` (rejection sampling —
/// deterministic given the rng state; asserts `count ≤ bound`).
fn distinct(rng: &mut Xoshiro256pp, bound: usize, count: usize) -> Vec<usize> {
    assert!(count <= bound, "cannot draw {count} distinct values from 0..{bound}");
    let mut out = Vec::with_capacity(count);
    while out.len() < count {
        let v = rng.uniform_u64(bound as u64) as usize;
        if !out.contains(&v) {
            out.push(v);
        }
    }
    out
}

/// Expand the multi-fault axis into cells, in the fixed planning order
/// (precision ⊃ pattern ⊃ flip count ⊃ encoding). The axis deliberately
/// stays compact — it fixes shape, strategy and distribution to the
/// config's first entries, varying only the dimensions the 2D-encoding
/// coverage gate quantifies over. Returns an empty plan when any of the
/// multi-fault axes (or the base axes it borrows from) is empty.
pub fn plan_multi_fault(cfg: &GridConfig) -> Vec<MultiCellSpec> {
    let mut cells = Vec::new();
    if cfg.shapes.is_empty() || cfg.strategies.is_empty() || cfg.dists.is_empty() {
        return cells;
    }
    let shape = cfg.shapes[0];
    let strategy = cfg.strategies[0];
    let dist = cfg.dists[0].clone();
    for &precision in &cfg.precisions {
        for &pattern in &cfg.burst_patterns {
            for &flips in &cfg.multi_flips {
                for &encoding in &cfg.encodings {
                    cells.push(MultiCellSpec {
                        index: cells.len(),
                        precision,
                        strategy,
                        dist: dist.clone(),
                        pattern,
                        flips,
                        encoding,
                        shape,
                        trials: cfg.multi_trials_per_cell,
                    });
                }
            }
        }
    }
    cells
}

/// Expand a grid configuration into cells, in the fixed planning order
/// (shape ⊃ precision ⊃ strategy ⊃ distribution ⊃ site ⊃ bit class ⊃
/// verify point). The order is part of the determinism contract: cell
/// indices seed the fault-coordinate streams.
pub fn plan(cfg: &GridConfig) -> Vec<CellSpec> {
    let mut cells = Vec::new();
    for &shape in &cfg.shapes {
        for &precision in &cfg.precisions {
            for &strategy in &cfg.strategies {
                for dist in &cfg.dists {
                    for &site in &cfg.sites {
                        for &bit_class in &cfg.bit_classes {
                            for verify in [VerifyPoint::Fused, VerifyPoint::Offline] {
                                if verify == VerifyPoint::Offline
                                    && !cfg.offline_sites.contains(&site)
                                {
                                    continue;
                                }
                                cells.push(CellSpec {
                                    index: cells.len(),
                                    precision,
                                    strategy,
                                    dist: dist.clone(),
                                    site,
                                    bit_class,
                                    verify,
                                    shape,
                                    trials: cfg.trials_per_cell,
                                });
                            }
                        }
                    }
                }
            }
        }
    }
    cells
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_grid_dimensions() {
        let cells = plan(&GridConfig::quick(1));
        // 1 shape × 4 precisions × 3 strategies × 2 dists × (4 sites
        // fused + 1 site offline) × 4 bit classes = 480.
        assert_eq!(cells.len(), 480);
        assert!(cells.iter().any(|c| c.precision == Precision::F16));
        assert!(cells.iter().any(|c| c.verify == VerifyPoint::Offline));
        assert!(cells
            .iter()
            .all(|c| c.verify == VerifyPoint::Fused || c.site == SiteClass::Output));
        // Indices are the planning order.
        for (i, c) in cells.iter().enumerate() {
            assert_eq!(c.index, i);
        }
    }

    #[test]
    fn bit_classes_resolve_within_encoding() {
        for p in [Precision::Bf16, Precision::F16, Precision::F32, Precision::F64] {
            for bc in BitClass::ALL {
                assert!(bc.bit(p) < p.bits(), "{bc:?} out of range for {p}");
            }
        }
        assert_eq!(BitClass::Sign.bit(Precision::Bf16), 15);
        assert_eq!(BitClass::ExpMsb.bit(Precision::Bf16), 14);
        assert_eq!(BitClass::ExpLsb.bit(Precision::Bf16), 7);
        assert_eq!(BitClass::MantMsb.bit(Precision::Bf16), 6);
    }

    #[test]
    fn faults_are_seed_deterministic_and_in_range() {
        let cells = plan(&GridConfig::smoke(7));
        // The whole grid's coordinate stream must depend on the seed
        // (per-cell coincidence is possible for low-coordinate sites).
        let all = |seed: u64| -> Vec<FaultSpec> {
            cells.iter().flat_map(|c| c.faults(seed)).collect()
        };
        assert_ne!(all(42), all(43), "fault coordinates ignore the seed");
        for c in &cells {
            let f1 = c.faults(42);
            let f2 = c.faults(42);
            assert_eq!(f1, f2, "cell {} faults not reproducible", c.index);
            let (m, k, n) = c.shape;
            for f in &f1 {
                assert!(f.bit < c.flip_grid().bits());
                match f.site {
                    FaultSite::Output { row, col } => assert!(row < m && col < n),
                    FaultSite::OperandA { row, k: kk, col } => {
                        assert!(row < m && kk < k && col < n)
                    }
                    FaultSite::OperandB { k: kk, col } => assert!(kk < k && col < n),
                    FaultSite::ChecksumR1 { row } => assert!(row < m),
                }
            }
        }
    }

    #[test]
    fn multi_fault_plan_dimensions_and_determinism() {
        let cfg = GridConfig::quick(1);
        let cells = plan_multi_fault(&cfg);
        // 4 precisions × 3 patterns × 2 flip counts × 3 encodings = 72.
        assert_eq!(cells.len(), 72);
        for (i, c) in cells.iter().enumerate() {
            assert_eq!(c.index, i);
            let plan1 = c.fault_plan(42);
            assert_eq!(plan1, c.fault_plan(42), "cell {i} plan not reproducible");
            assert_eq!(plan1.len(), c.trials);
            let (m, _, n) = c.shape;
            for trial in &plan1 {
                assert_eq!(trial.len(), c.flips);
                // Every fault is an in-range output-site flip; the
                // pattern's distinctness contract holds.
                let mut rows = Vec::new();
                let mut cols = Vec::new();
                for f in trial {
                    assert!(f.bit < c.model().work.bits());
                    match f.site {
                        FaultSite::Output { row, col } => {
                            assert!(row < m && col < n);
                            rows.push(row);
                            cols.push(col);
                        }
                        other => panic!("multi-fault plan produced {other:?}"),
                    }
                }
                let all_distinct = |v: &[usize]| {
                    v.iter().all(|x| v.iter().filter(|y| *y == x).count() == 1)
                };
                match c.pattern {
                    BurstPattern::RowBurst => {
                        assert!(rows.iter().all(|&r| r == rows[0]));
                        assert!(all_distinct(&cols));
                    }
                    BurstPattern::ColBurst => {
                        assert!(cols.iter().all(|&j| j == cols[0]));
                        assert!(all_distinct(&rows));
                    }
                    BurstPattern::Scatter => {
                        assert!(all_distinct(&rows) && all_distinct(&cols));
                    }
                }
            }
        }
        // Seed reaches the coordinates.
        let all = |seed: u64| -> Vec<Vec<FaultSpec>> {
            cells.iter().flat_map(|c| c.fault_plan(seed)).collect()
        };
        assert_ne!(all(42), all(43), "multi-fault coordinates ignore the seed");
        // The smoke slice stays minimal but keeps the divergent pattern
        // and both sides of the coverage gate.
        let smoke = plan_multi_fault(&GridConfig::smoke(1));
        assert_eq!(smoke.len(), 8);
        assert!(smoke.iter().any(|c| c.pattern == BurstPattern::RowBurst));
        assert!(smoke.iter().any(|c| c.encoding == EncodingMode::RowOnly));
        assert!(smoke.iter().any(|c| c.encoding == EncodingMode::Grid));
    }

    #[test]
    fn plan_axis_covers_the_full_scheme_vocabulary() {
        use crate::planner::ProtectionScheme;
        let cfg = GridConfig::quick(1);
        let cells = plan_protection(&cfg);
        // 4 precisions × 5 schemes = 20 cells, indexed in planning order.
        assert_eq!(cells.len(), 4 * ProtectionScheme::vocabulary(1).len());
        for (i, c) in cells.iter().enumerate() {
            assert_eq!(c.index, i);
        }
        for &p in &cfg.precisions {
            for scheme in ProtectionScheme::vocabulary((cfg.shapes[0].1 / 2).max(1)) {
                assert!(
                    cells.iter().any(|c| c.precision == p && c.scheme == scheme),
                    "missing plan cell {p} {}",
                    scheme.label()
                );
            }
        }
        // Faults are reproducible, in range, and strike the output site
        // at the work grid's exponent MSB.
        for c in &cells {
            let f1 = c.faults(42);
            assert_eq!(f1, c.faults(42), "plan cell {} not reproducible", c.index);
            assert_eq!(f1.len(), c.trials);
            let (m, _, n) = c.shape;
            for f in &f1 {
                assert_eq!(f.bit, BitClass::ExpMsb.bit(c.model().work));
                match f.site {
                    FaultSite::Output { row, col } => assert!(row < m && col < n),
                    other => panic!("plan axis produced {other:?}"),
                }
            }
        }
        // Seed reaches the coordinates.
        let all = |seed: u64| -> Vec<FaultSpec> {
            cells.iter().flat_map(|c| c.faults(seed)).collect()
        };
        assert_ne!(all(42), all(43), "plan-axis coordinates ignore the seed");
    }

    #[test]
    fn operand_streams_shared_exactly_by_input_dist_shape() {
        let cells = plan(&GridConfig::quick(1));
        for x in &cells {
            for y in &cells {
                let same_key = x.model().input == y.model().input
                    && x.dist == y.dist
                    && x.shape == y.shape;
                assert_eq!(
                    x.operand_stream() == y.operand_stream(),
                    same_key,
                    "operand stream collision/split: {} vs {}",
                    x.label(),
                    y.label()
                );
            }
        }
    }
}
