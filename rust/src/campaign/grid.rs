//! Campaign grid planning: the deterministic trial lattice.
//!
//! A campaign is a cross product of seven axes — storage precision ×
//! reduction strategy × operand distribution × injection-site class ×
//! encoding-bit class × verification point × GEMM shape — planned into
//! [`CellSpec`]s by [`plan`]. Every random choice (operand samples, fault
//! coordinates) derives from the campaign's single master seed through
//! fixed [`crate::rng::Xoshiro256pp`] streams indexed by cell position,
//! so the full grid is reproducible bit-for-bit from `(seed, config)` —
//! at any coordinator worker count, because the engine's
//! schedule-preservation invariant makes each trial's arithmetic
//! thread-count-independent.

use crate::fp::Precision;
use crate::gemm::{AccumModel, ReduceStrategy};
use crate::inject::{FaultSite, FaultSpec, SiteClass};
use crate::rng::{Distribution, Rng, Xoshiro256pp};

/// Stream tag separating fault-coordinate RNG streams from operand
/// streams (both key off the master seed).
const COORD_TAG: u64 = 0xC00D_1247;

/// Which encoding bit a cell flips, named relative to the target
/// precision's layout so one class means the same physical event across
/// grids (paper Table 8's rows, collapsed to the four regimes).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BitClass {
    /// The sign bit: magnitude-preserving, error `2|v|`.
    Sign,
    /// Exponent MSB: the catastrophic class (overflow/underflow scale).
    ExpMsb,
    /// Exponent LSB: value doubles or halves.
    ExpLsb,
    /// Mantissa MSB: relative error up to 25%.
    MantMsb,
}

impl BitClass {
    /// All four classes, in campaign grid order.
    pub const ALL: [BitClass; 4] =
        [BitClass::Sign, BitClass::ExpMsb, BitClass::ExpLsb, BitClass::MantMsb];

    /// Short lowercase name used in reports and JSON documents.
    pub fn name(self) -> &'static str {
        match self {
            BitClass::Sign => "sign",
            BitClass::ExpMsb => "exp_msb",
            BitClass::ExpLsb => "exp_lsb",
            BitClass::MantMsb => "mant_msb",
        }
    }

    /// Resolve to a bit position of `p`'s encoding.
    pub fn bit(self, p: Precision) -> u32 {
        match self {
            BitClass::Sign => p.sign_bit(),
            BitClass::ExpMsb => p.sign_bit() - 1,
            BitClass::ExpLsb => p.exponent_lsb(),
            BitClass::MantMsb => p.exponent_lsb().saturating_sub(1),
        }
    }
}

/// Verification point of a cell (§3.6): fused verification reads the
/// pre-quantization accumulator (e_max ≈ 1e-6 for FP32 datapaths),
/// offline verification the quantized stored output (e_max ≈ 2·u_out,
/// ≈ 1e-3 for BF16) — the ~1000× detection-granularity gap the campaign
/// report quantifies.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum VerifyPoint {
    /// Fused / online: verify the accumulator before output rounding.
    Fused,
    /// Offline: verify the stored (quantized) output.
    Offline,
}

impl VerifyPoint {
    /// Short lowercase name used in reports and JSON documents.
    pub fn name(self) -> &'static str {
        match self {
            VerifyPoint::Fused => "fused",
            VerifyPoint::Offline => "offline",
        }
    }

    /// True for fused (pre-quantization) verification.
    pub fn online(self) -> bool {
        matches!(self, VerifyPoint::Fused)
    }
}

/// Configuration of a campaign grid. Construct via [`GridConfig::quick`],
/// [`GridConfig::full`] or [`GridConfig::smoke`] and adjust fields as
/// needed; [`plan`] expands it into cells.
#[derive(Debug, Clone)]
pub struct GridConfig {
    /// Master seed — every operand sample and fault coordinate derives
    /// from it deterministically.
    pub seed: u64,
    /// Mode label recorded in the JSON document (`"quick"`, `"full"`,
    /// `"smoke"`).
    pub mode: String,
    /// Storage precisions under test (each resolves to its platform
    /// accumulation model, see [`CellSpec::model`]).
    pub precisions: Vec<Precision>,
    /// Reduction strategies (rounding schedules) under test.
    pub strategies: Vec<ReduceStrategy>,
    /// Operand distributions.
    pub dists: Vec<Distribution>,
    /// Injection-site classes.
    pub sites: Vec<SiteClass>,
    /// Encoding-bit classes.
    pub bit_classes: Vec<BitClass>,
    /// Site classes that additionally get offline (post-quantization)
    /// cells; every site in `sites` always gets a fused cell.
    pub offline_sites: Vec<SiteClass>,
    /// GEMM shapes (M, K, N).
    pub shapes: Vec<(usize, usize, usize)>,
    /// Injection trials per cell (plus one clean trial per cell).
    pub trials_per_cell: usize,
    /// Above-threshold margin: a fault counts toward the recall gate when
    /// its expected magnitude exceeds `margin ×` the row's threshold (or
    /// is non-finite). With the zero-FP noise bound `noise ≤ T`, any
    /// margin > 2 makes detection of gated faults a theorem, not a
    /// statistic; the default of 6 additionally absorbs requantization
    /// error on coarse output grids.
    pub margin: f64,
}

impl GridConfig {
    fn base(seed: u64, mode: &str) -> GridConfig {
        GridConfig {
            seed,
            mode: mode.to_string(),
            precisions: vec![Precision::Bf16, Precision::F16, Precision::F32, Precision::F64],
            strategies: vec![
                ReduceStrategy::Sequential,
                ReduceStrategy::Fma,
                ReduceStrategy::Pairwise,
            ],
            dists: vec![Distribution::normal_1_1(), Distribution::uniform_01()],
            sites: SiteClass::ALL.to_vec(),
            bit_classes: BitClass::ALL.to_vec(),
            offline_sites: vec![SiteClass::Output],
            shapes: vec![(8, 64, 16)],
            trials_per_cell: 3,
            margin: 6.0,
        }
    }

    /// The CI-gated grid: all four storage precisions × three reduction
    /// strategies × two distributions × four site classes × four bit
    /// classes, fused everywhere plus offline output cells — 480 cells,
    /// small shapes, completing well under a minute.
    pub fn quick(seed: u64) -> GridConfig {
        Self::base(seed, "quick")
    }

    /// The nightly grid: adds the truncated-normal distribution, offline
    /// cells for every site class, a second paper-scale shape and more
    /// trials per cell.
    pub fn full(seed: u64) -> GridConfig {
        let mut cfg = Self::base(seed, "full");
        cfg.dists.push(Distribution::truncated_normal());
        cfg.offline_sites = SiteClass::ALL.to_vec();
        cfg.shapes = vec![(32, 256, 64), (128, 1024, 256)];
        cfg.trials_per_cell = 6;
        cfg
    }

    /// A 20-cell sub-grid for determinism tests and the push-gated CI
    /// smoke step: BF16 + FP32, FMA only, exponent-MSB and mantissa-MSB
    /// bits, all four site classes.
    pub fn smoke(seed: u64) -> GridConfig {
        let mut cfg = Self::base(seed, "smoke");
        cfg.precisions = vec![Precision::Bf16, Precision::F32];
        cfg.strategies = vec![ReduceStrategy::Fma];
        cfg.dists = vec![Distribution::normal_1_1()];
        cfg.bit_classes = vec![BitClass::ExpMsb, BitClass::MantMsb];
        cfg.trials_per_cell = 4;
        cfg
    }
}

/// One planned cell: a point of the campaign lattice plus its trial
/// budget. Cells own no results — [`crate::campaign::run`] pairs them
/// with [`crate::campaign::CellResult`]s in planning order.
#[derive(Debug, Clone)]
pub struct CellSpec {
    /// Position in planning order (also the fault-coordinate RNG stream).
    pub index: usize,
    /// Storage precision under test.
    pub precision: Precision,
    /// Reduction strategy (rounding schedule).
    pub strategy: ReduceStrategy,
    /// Operand distribution.
    pub dist: Distribution,
    /// Injection-site class.
    pub site: SiteClass,
    /// Encoding-bit class.
    pub bit_class: BitClass,
    /// Verification point.
    pub verify: VerifyPoint,
    /// GEMM shape (M, K, N).
    pub shape: (usize, usize, usize),
    /// Injection trials (one clean trial is always added).
    pub trials: usize,
}

/// The accumulation model a campaign runs a storage precision under:
/// wide FP32 accumulation for the sub-32-bit formats (the GPU/NPU
/// mixed-precision model), native accumulation for FP32/FP64 — with
/// `strategy` substituted in as the reduction schedule.
pub fn model_for(precision: Precision, strategy: ReduceStrategy) -> AccumModel {
    let base = match precision {
        Precision::F32 | Precision::F64 => AccumModel::gpu_highprec(precision),
        p => AccumModel::wide(p),
    };
    AccumModel { strategy, ..base }
}

impl CellSpec {
    /// The accumulation model of this cell (see [`model_for`]).
    pub fn model(&self) -> AccumModel {
        model_for(self.precision, self.strategy)
    }

    /// Precision grid the cell's flips address: the verified grid (work
    /// precision fused, output precision offline) for output and checksum
    /// sites, the operand storage grid for operand sites.
    pub fn flip_grid(&self) -> Precision {
        let m = self.model();
        match self.site {
            SiteClass::OperandA | SiteClass::OperandB => m.input,
            SiteClass::Output | SiteClass::Checksum => {
                if self.verify.online() {
                    m.work
                } else {
                    m.out
                }
            }
        }
    }

    /// The bit position this cell flips.
    pub fn bit(&self) -> u32 {
        self.bit_class.bit(self.flip_grid())
    }

    /// Stream index of the cell's operand set. Cells sharing (input
    /// precision, distribution, shape) share operands — and hence, per
    /// coordinator, prepared weights — which is what lets the engine
    /// amortize checksum encoding across the weight-stationary trials.
    pub fn operand_stream(&self) -> u64 {
        let (m, k, n) = self.shape;
        let label = self.dist.label();
        let h = crate::rng::fnv1a(
            crate::rng::FNV1A_OFFSET,
            self.model().input.name().bytes().chain(label.bytes()),
        );
        h ^ ((m as u64) << 42) ^ ((k as u64) << 21) ^ n as u64
    }

    /// The cell's planned faults, deterministically derived from the
    /// master seed: trial t's coordinates come from substream
    /// `(seed ^ COORD_TAG, cell index)`, drawn in a fixed order.
    pub fn faults(&self, seed: u64) -> Vec<FaultSpec> {
        let (m, k, n) = self.shape;
        let mut rng = Xoshiro256pp::from_stream(seed ^ COORD_TAG, self.index as u64);
        let bit = self.bit();
        (0..self.trials)
            .map(|_| {
                let row = rng.uniform_u64(m as u64) as usize;
                let kk = rng.uniform_u64(k as u64) as usize;
                let col = rng.uniform_u64(n as u64) as usize;
                let site = match self.site {
                    SiteClass::Output => FaultSite::Output { row, col },
                    SiteClass::OperandA => FaultSite::OperandA { row, k: kk, col },
                    SiteClass::OperandB => FaultSite::OperandB { k: kk, col },
                    SiteClass::Checksum => FaultSite::ChecksumR1 { row },
                };
                FaultSpec { site, bit }
            })
            .collect()
    }

    /// Compact label for progress lines and failure messages.
    pub fn label(&self) -> String {
        let (m, k, n) = self.shape;
        format!(
            "{}x{}x{} {} {} {} {} {}",
            m,
            k,
            n,
            self.precision.name(),
            self.strategy.name(),
            self.site.name(),
            self.bit_class.name(),
            self.verify.name()
        )
    }
}

/// Expand a grid configuration into cells, in the fixed planning order
/// (shape ⊃ precision ⊃ strategy ⊃ distribution ⊃ site ⊃ bit class ⊃
/// verify point). The order is part of the determinism contract: cell
/// indices seed the fault-coordinate streams.
pub fn plan(cfg: &GridConfig) -> Vec<CellSpec> {
    let mut cells = Vec::new();
    for &shape in &cfg.shapes {
        for &precision in &cfg.precisions {
            for &strategy in &cfg.strategies {
                for dist in &cfg.dists {
                    for &site in &cfg.sites {
                        for &bit_class in &cfg.bit_classes {
                            for verify in [VerifyPoint::Fused, VerifyPoint::Offline] {
                                if verify == VerifyPoint::Offline
                                    && !cfg.offline_sites.contains(&site)
                                {
                                    continue;
                                }
                                cells.push(CellSpec {
                                    index: cells.len(),
                                    precision,
                                    strategy,
                                    dist: dist.clone(),
                                    site,
                                    bit_class,
                                    verify,
                                    shape,
                                    trials: cfg.trials_per_cell,
                                });
                            }
                        }
                    }
                }
            }
        }
    }
    cells
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_grid_dimensions() {
        let cells = plan(&GridConfig::quick(1));
        // 1 shape × 4 precisions × 3 strategies × 2 dists × (4 sites
        // fused + 1 site offline) × 4 bit classes = 480.
        assert_eq!(cells.len(), 480);
        assert!(cells.iter().any(|c| c.precision == Precision::F16));
        assert!(cells.iter().any(|c| c.verify == VerifyPoint::Offline));
        assert!(cells
            .iter()
            .all(|c| c.verify == VerifyPoint::Fused || c.site == SiteClass::Output));
        // Indices are the planning order.
        for (i, c) in cells.iter().enumerate() {
            assert_eq!(c.index, i);
        }
    }

    #[test]
    fn bit_classes_resolve_within_encoding() {
        for p in [Precision::Bf16, Precision::F16, Precision::F32, Precision::F64] {
            for bc in BitClass::ALL {
                assert!(bc.bit(p) < p.bits(), "{bc:?} out of range for {p}");
            }
        }
        assert_eq!(BitClass::Sign.bit(Precision::Bf16), 15);
        assert_eq!(BitClass::ExpMsb.bit(Precision::Bf16), 14);
        assert_eq!(BitClass::ExpLsb.bit(Precision::Bf16), 7);
        assert_eq!(BitClass::MantMsb.bit(Precision::Bf16), 6);
    }

    #[test]
    fn faults_are_seed_deterministic_and_in_range() {
        let cells = plan(&GridConfig::smoke(7));
        // The whole grid's coordinate stream must depend on the seed
        // (per-cell coincidence is possible for low-coordinate sites).
        let all = |seed: u64| -> Vec<FaultSpec> {
            cells.iter().flat_map(|c| c.faults(seed)).collect()
        };
        assert_ne!(all(42), all(43), "fault coordinates ignore the seed");
        for c in &cells {
            let f1 = c.faults(42);
            let f2 = c.faults(42);
            assert_eq!(f1, f2, "cell {} faults not reproducible", c.index);
            let (m, k, n) = c.shape;
            for f in &f1 {
                assert!(f.bit < c.flip_grid().bits());
                match f.site {
                    FaultSite::Output { row, col } => assert!(row < m && col < n),
                    FaultSite::OperandA { row, k: kk, col } => {
                        assert!(row < m && kk < k && col < n)
                    }
                    FaultSite::OperandB { k: kk, col } => assert!(kk < k && col < n),
                    FaultSite::ChecksumR1 { row } => assert!(row < m),
                }
            }
        }
    }

    #[test]
    fn operand_streams_shared_exactly_by_input_dist_shape() {
        let cells = plan(&GridConfig::quick(1));
        for x in &cells {
            for y in &cells {
                let same_key = x.model().input == y.model().input
                    && x.dist == y.dist
                    && x.shape == y.shape;
                assert_eq!(
                    x.operand_stream() == y.operand_stream(),
                    same_key,
                    "operand stream collision/split: {} vs {}",
                    x.label(),
                    y.label()
                );
            }
        }
    }
}
