//! Deterministic large-scale fault-injection campaign engine.
//!
//! The paper's headline claims — zero false positives across
//! BF16/FP16/FP32/FP64, thresholds 6–48× tighter than A-ABFT, ~1000×
//! finer detection granularity for fused verification — are statements
//! about a *space* of configurations, not a single run. This module
//! sweeps that space at scale:
//!
//! 1. [`plan`] expands a [`GridConfig`] into a lattice of [`CellSpec`]s
//!    (precision × reduction strategy × distribution × injection site ×
//!    bit class × verification point × shape), every random choice
//!    derived from one master seed;
//! 2. [`run`] executes the lattice through the [`crate::coordinator`]
//!    worker pool — each cell's trials ride one `submit_batch_prepared`
//!    batch against weights registered once, so the weight-stationary
//!    serving path ([`crate::abft::PreparedWeights`]) amortizes checksum
//!    encoding exactly as in production — and classifies each trial
//!    against the margin rule (expected magnitude > margin × threshold ⇒
//!    detection is a theorem, not a statistic);
//! 3. [`render_tables`] / [`to_doc`] aggregate per-cell recall /
//!    false-positive / magnitude / tightness statistics into the shapes
//!    of paper Tables 4–9 and the schema-versioned
//!    `BENCH_campaign.json`.
//!
//! **Reproducibility contract**: the same `(config, seed)` produces a
//! byte-identical JSON document at any coordinator worker count. This
//! holds because (a) the GEMM engine preserves every element's rounding
//! schedule regardless of threading, (b) all sampling derives from fixed
//! seed streams, (c) results are aggregated in planning order, and (d)
//! nothing wall-clock-dependent is serialized. CI pins the contract —
//! see `docs/CAMPAIGN.md` and `tests/campaign_engine.rs`.

pub mod grid;
pub mod report;
pub mod runner;

pub use grid::{
    model_for, plan, plan_multi_fault, plan_protection, BitClass, BurstPattern, CellSpec,
    GridConfig, MultiCellSpec, PlanCellSpec, VerifyPoint,
};
pub use report::{render_tables, to_doc};
pub use runner::{
    run, run_sharded, CampaignOutcome, CellResult, MultiCellResult, PlanCellResult,
};
