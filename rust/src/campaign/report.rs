//! Campaign reporting: paper-table-shaped renderings plus the
//! schema-versioned `BENCH_campaign.json` document.
//!
//! Four tables reproduce the shape of the paper's evaluation:
//!
//! * a recall / false-positive summary per precision × verification
//!   point (the headline zero-FP, recall-1.0 claim);
//! * a detection-rate ladder per site class × bit class (Tables 8/9);
//! * threshold-tightness rows projected through
//!   [`crate::experiments::tightness_row_from_campaign`] (Tables 4–6);
//! * the offline ≈ 1e-3 vs fused ≈ 1e-6 detection-granularity comparison
//!   (§3.6) — *measured* from the executed cells: the realized clean-run
//!   noise floor and the smallest issued row threshold at each
//!   verification point, both read off the real fused code path.
//!
//! The JSON document serializes one entry per grid cell through the
//! shared [`JsonDoc`] writer. It contains no timing and no worker count,
//! so a seeded campaign serializes byte-for-byte identically at any
//! thread count — the reproducibility contract CI pins.

use crate::bench_harness::{JsonDoc, JsonValue, CAMPAIGN_SCHEMA};
use crate::experiments::tightness_row_from_campaign;
use crate::report::{pct, ratio, sci, Table};

use super::grid::VerifyPoint;
use super::runner::{CampaignOutcome, CellResult, MultiCellResult, PlanCellResult};

fn fmt_shape(shape: (usize, usize, usize)) -> String {
    format!("{}x{}x{}", shape.0, shape.1, shape.2)
}

/// Sum clean-sweep statistics over a selection of cells, counting each
/// distinct sweep once: the clean FPR sweep runs per operand set ×
/// coordinator group, every cell on the set carries a copy of its
/// numbers, and `CellResult::sweep` is the runner-assigned sweep
/// identity. Returns `(clean_rows, false_positives)`.
fn distinct_clean(sel: &[&CellResult]) -> (usize, usize) {
    let mut seen: Vec<usize> = Vec::new();
    let mut rows = 0usize;
    let mut fps = 0usize;
    for c in sel {
        if !seen.contains(&c.sweep) {
            seen.push(c.sweep);
            rows += c.clean_rows;
            fps += c.false_positives;
        }
    }
    (rows, fps)
}

/// Render the campaign's paper-shaped tables, in print order.
pub fn render_tables(outcome: &CampaignOutcome) -> Vec<Table> {
    let cfg = &outcome.config;
    let verifies = [VerifyPoint::Fused, VerifyPoint::Offline];

    // 1. Recall / FP summary per precision × verification point.
    let mut summary = Table::new(
        "Campaign summary — above-threshold recall and false positives",
        &[
            "precision",
            "verify",
            "cells",
            "trials",
            "above",
            "caught",
            "recall %",
            "FP",
            "clean rows",
        ],
    );
    for &p in &cfg.precisions {
        for v in verifies {
            let sel: Vec<&CellResult> = outcome
                .cells
                .iter()
                .filter(|c| c.spec.precision == p && c.spec.verify == v)
                .collect();
            if sel.is_empty() {
                continue;
            }
            let above: usize = sel.iter().map(|c| c.above).sum();
            let caught: usize = sel.iter().map(|c| c.detected_above).sum();
            let (clean_rows, fps) = distinct_clean(&sel);
            summary.row(vec![
                p.name().to_string(),
                v.name().to_string(),
                sel.len().to_string(),
                sel.iter().map(|c| c.trials).sum::<usize>().to_string(),
                above.to_string(),
                caught.to_string(),
                if above == 0 { "-".into() } else { pct(100.0 * caught as f64 / above as f64) },
                fps.to_string(),
                clean_rows.to_string(),
            ]);
        }
    }

    // 2. Detection-rate ladder per site × bit class (Tables 8/9 shape),
    // fused cells, merged over strategies and distributions.
    let mut headers: Vec<String> = vec!["site".into(), "bit".into()];
    headers.extend(cfg.precisions.iter().map(|p| format!("{} DR %", p.name())));
    let mut ladder = Table::new(
        "Detection rate by injection site × bit class (fused; Tables 8/9 shape)",
        &headers.iter().map(|s| s.as_str()).collect::<Vec<_>>(),
    );
    for &site in &cfg.sites {
        for &bc in &cfg.bit_classes {
            let mut row = vec![site.name().to_string(), bc.name().to_string()];
            for &p in &cfg.precisions {
                let sel: Vec<&CellResult> = outcome
                    .cells
                    .iter()
                    .filter(|c| {
                        c.spec.site == site
                            && c.spec.bit_class == bc
                            && c.spec.precision == p
                            && c.spec.verify == VerifyPoint::Fused
                    })
                    .collect();
                let trials: usize = sel.iter().map(|c| c.trials).sum();
                let detected: usize = sel.iter().map(|c| c.detected).sum();
                row.push(if trials == 0 {
                    "-".into()
                } else {
                    pct(100.0 * detected as f64 / trials as f64)
                });
            }
            ladder.row(row);
        }
    }

    // 3. Threshold tightness on clean data (Tables 4–6 shape), projected
    // through the experiments-layer converter.
    let mut tight = Table::new(
        "Threshold tightness on clean data (Tables 4–6 shape)",
        &["precision", "verify", "shape", "Actual Diff", "A-ABFT", "V-ABFT", "A-Tight", "V-Tight"],
    );
    for &p in &cfg.precisions {
        for v in verifies {
            for &shape in &cfg.shapes {
                let sel: Vec<&CellResult> = outcome
                    .cells
                    .iter()
                    .filter(|c| {
                        c.spec.precision == p && c.spec.verify == v && c.spec.shape == shape
                    })
                    .collect();
                if sel.is_empty() {
                    continue;
                }
                let actual = sel.iter().map(|c| c.clean_noise).fold(0.0, f64::max);
                let a_thr = sel.iter().map(|c| c.aabft_threshold_max).fold(0.0, f64::max);
                let v_thr = sel.iter().map(|c| c.threshold_max).fold(0.0, f64::max);
                let (rows_checked, fps) = distinct_clean(&sel);
                let rows =
                    tightness_row_from_campaign(shape.2, actual, a_thr, v_thr, rows_checked, fps);
                tight.row(vec![
                    p.name().to_string(),
                    v.name().to_string(),
                    fmt_shape(shape),
                    sci(rows.actual),
                    sci(rows.aabft_threshold),
                    sci(rows.vabft_threshold),
                    ratio(rows.a_tight()),
                    ratio(rows.v_tight()),
                ]);
            }
        }
    }

    // 4. Offline vs fused detection granularity (§3.6), measured on the
    // executed cells: per precision, the realized clean-run noise floor
    // (max |D1| over shared sweeps) and the smallest row threshold the
    // pipeline actually issued at each verification point. The
    // granularity column is the offline/fused ratio of issued minimum
    // thresholds — the ~1000× gap, certified by the real fused path
    // instead of an analytical e_max model.
    let mut emax = Table::new(
        "Measured granularity: offline (stored output) vs fused (in-kernel), §3.6",
        &["precision", "offline noise", "offline T_min", "fused noise", "fused T_min", "granularity"],
    );
    for &p in &cfg.precisions {
        let side = |v: VerifyPoint| -> Option<(f64, f64)> {
            let sel: Vec<&CellResult> = outcome
                .cells
                .iter()
                .filter(|c| c.spec.precision == p && c.spec.verify == v)
                .collect();
            if sel.is_empty() {
                return None;
            }
            let noise = sel.iter().map(|c| c.clean_noise).fold(0.0, f64::max);
            let tmin = sel.iter().map(|c| c.threshold_min).fold(f64::INFINITY, f64::min);
            Some((noise, tmin))
        };
        let off = side(VerifyPoint::Offline);
        let fused = side(VerifyPoint::Fused);
        let cell = |x: Option<f64>| x.map(sci).unwrap_or_else(|| "-".into());
        let gran = match (off, fused) {
            (Some((_, ot)), Some((_, ft))) if ft > 0.0 && ot.is_finite() => ratio(ot / ft),
            _ => "-".into(),
        };
        emax.row(vec![
            p.name().to_string(),
            cell(off.map(|(n, _)| n)),
            cell(off.map(|(_, t)| t)),
            cell(fused.map(|(n, _)| n)),
            cell(fused.map(|(_, t)| t)),
            gran,
        ]);
    }

    let mut tables = vec![summary, ladder, tight, emax];

    // 5. Multi-fault correction coverage per burst pattern × encoding
    // mode: how many simultaneous-flip trials each checksum geometry
    // repaired without spending a recompute. Row bursts are the
    // divergent column — the single-checksum baseline must recompute
    // them, the 2D encodings correct via the A-side column direction.
    if !outcome.multi_cells.is_empty() {
        let mut headers: Vec<String> =
            vec!["pattern".into(), "flips".into(), "trials".into()];
        headers.extend(cfg.encodings.iter().map(|e| format!("{} corrected", e.name())));
        let mut multi = Table::new(
            "Multi-fault correction coverage (corrected without recompute) by encoding",
            &headers.iter().map(|s| s.as_str()).collect::<Vec<_>>(),
        );
        for &pattern in &cfg.burst_patterns {
            for &flips in &cfg.multi_flips {
                let sel = |e: Option<crate::abft::EncodingMode>| -> Vec<&MultiCellResult> {
                    outcome
                        .multi_cells
                        .iter()
                        .filter(|c| {
                            c.spec.pattern == pattern
                                && c.spec.flips == flips
                                && e.map(|e| c.spec.encoding == e).unwrap_or(true)
                        })
                        .collect()
                };
                let any = sel(None);
                if any.is_empty() {
                    continue;
                }
                // Trials are identical across encodings (same fault
                // plan); report one encoding's count.
                let trials: usize = sel(Some(cfg.encodings[0]))
                    .iter()
                    .map(|c| c.trials)
                    .sum();
                let mut row =
                    vec![pattern.name().to_string(), flips.to_string(), trials.to_string()];
                for &e in &cfg.encodings {
                    let corrected: usize =
                        sel(Some(e)).iter().map(|c| c.corrected_no_recompute).sum();
                    row.push(corrected.to_string());
                }
                multi.row(row);
            }
        }
        tables.push(multi);
    }

    // 6. Protection-plan scheme validation: detection and bitwise
    // recovery per planner-selectable scheme, summed over precisions.
    // Every scheme must show recall 1.0 and zero FPs — the evidence that
    // lets the arithmetic-intensity planner choose on cost alone.
    if !outcome.plan_cells.is_empty() {
        let mut plan = Table::new(
            "Protection-plan scheme validation (recall / FP / bitwise recovery)",
            &["scheme", "cells", "trials", "detected", "FP", "clean rows", "bitwise repaired"],
        );
        let mut schemes: Vec<String> = Vec::new();
        for c in &outcome.plan_cells {
            let label = c.spec.scheme.label();
            if !schemes.contains(&label) {
                schemes.push(label);
            }
        }
        for label in schemes {
            let sel: Vec<&PlanCellResult> = outcome
                .plan_cells
                .iter()
                .filter(|c| c.spec.scheme.label() == label)
                .collect();
            plan.row(vec![
                label,
                sel.len().to_string(),
                sel.iter().map(|c| c.trials).sum::<usize>().to_string(),
                sel.iter().map(|c| c.detected).sum::<usize>().to_string(),
                sel.iter().map(|c| c.false_positives).sum::<usize>().to_string(),
                sel.iter().map(|c| c.clean_rows).sum::<usize>().to_string(),
                sel.iter().map(|c| c.repaired_bitwise).sum::<usize>().to_string(),
            ]);
        }
        tables.push(plan);
    }

    tables
}

/// Serialize a campaign outcome as the schema-versioned
/// `BENCH_campaign.json` document (one entry per grid cell, no timing,
/// no thread count — byte-stable across workers).
pub fn to_doc(outcome: &CampaignOutcome) -> JsonDoc {
    let cfg = &outcome.config;
    let mut doc = JsonDoc::new(CAMPAIGN_SCHEMA);
    doc.meta("bench", JsonValue::Str("campaign".into()))
        .meta("mode", JsonValue::Str(cfg.mode.clone()))
        .meta("seed", JsonValue::Str(format!("0x{:x}", cfg.seed)))
        .meta("margin", JsonValue::Num(cfg.margin))
        .meta("cells", JsonValue::Int(outcome.cells.len() as i64))
        .meta("trials", JsonValue::Int(outcome.total_trials() as i64))
        .meta("above_threshold", JsonValue::Int(outcome.total_above() as i64))
        .meta("detected_above", JsonValue::Int(outcome.total_detected_above() as i64))
        .meta("recall_above", JsonValue::Num(outcome.recall_above()))
        .meta("clean_rows", JsonValue::Int(outcome.total_clean_rows() as i64))
        .meta("false_positives", JsonValue::Int(outcome.total_false_positives() as i64))
        .meta("gates_hold", JsonValue::Bool(outcome.gates_hold()))
        .meta("severity_waived", JsonValue::Int(outcome.total_severity_waived() as i64))
        .meta(
            "severity_no_downgrade",
            JsonValue::Bool(outcome.severity_no_downgrade()),
        )
        .meta("multi_cells", JsonValue::Int(outcome.multi_cells.len() as i64))
        .meta("multi_trials", JsonValue::Int(outcome.total_multi_trials() as i64))
        .meta("multi_clean_rows", JsonValue::Int(outcome.multi_clean_rows as i64))
        .meta(
            "multi_false_positives",
            JsonValue::Int(outcome.multi_false_positives as i64),
        )
        .meta(
            "multi_fault_gates_hold",
            JsonValue::Bool(outcome.multi_fault_gates_hold()),
        )
        .meta(
            "baseline_corrected_no_recompute",
            JsonValue::Int(
                outcome.multi_corrected_no_recompute(crate::abft::EncodingMode::RowOnly) as i64,
            ),
        )
        .meta(
            "grid_corrected_no_recompute",
            JsonValue::Int(
                outcome.multi_corrected_no_recompute(crate::abft::EncodingMode::Grid) as i64,
            ),
        )
        .meta(
            "grid_exceeds_baseline",
            JsonValue::Bool(outcome.grid_exceeds_baseline()),
        )
        .meta("plan_cells", JsonValue::Int(outcome.plan_cells.len() as i64))
        .meta("plan_trials", JsonValue::Int(outcome.total_plan_trials() as i64))
        .meta("plan_detected", JsonValue::Int(outcome.total_plan_detected() as i64))
        .meta("plan_clean_rows", JsonValue::Int(outcome.plan_clean_rows as i64))
        .meta(
            "plan_false_positives",
            JsonValue::Int(outcome.plan_false_positives as i64),
        )
        .meta("plan_gates_hold", JsonValue::Bool(outcome.plan_gates_hold()))
        .meta(
            "replication_bitwise_equal",
            JsonValue::Bool(outcome.replication_bitwise_equal()),
        );
    for c in &outcome.cells {
        let s = &c.spec;
        doc.entry(vec![
            ("cell".to_string(), JsonValue::Int(s.index as i64)),
            ("sweep".to_string(), JsonValue::Int(c.sweep as i64)),
            ("shape".to_string(), JsonValue::Str(fmt_shape(s.shape))),
            ("precision".to_string(), JsonValue::Str(s.precision.name().to_string())),
            ("strategy".to_string(), JsonValue::Str(s.strategy.name().to_string())),
            ("dist".to_string(), JsonValue::Str(s.dist.label())),
            ("site".to_string(), JsonValue::Str(s.site.name().to_string())),
            ("bit_class".to_string(), JsonValue::Str(s.bit_class.name().to_string())),
            ("bit".to_string(), JsonValue::Int(c.bit as i64)),
            ("verify".to_string(), JsonValue::Str(s.verify.name().to_string())),
            ("trials".to_string(), JsonValue::Int(c.trials as i64)),
            ("detected".to_string(), JsonValue::Int(c.detected as i64)),
            ("above".to_string(), JsonValue::Int(c.above as i64)),
            ("detected_above".to_string(), JsonValue::Int(c.detected_above as i64)),
            ("detected_below".to_string(), JsonValue::Int(c.detected_below as i64)),
            ("clean_rows".to_string(), JsonValue::Int(c.clean_rows as i64)),
            ("false_positives".to_string(), JsonValue::Int(c.false_positives as i64)),
            ("max_magnitude".to_string(), JsonValue::Sci(c.max_magnitude)),
            ("clean_noise".to_string(), JsonValue::Sci(c.clean_noise)),
            ("vabft_threshold_min".to_string(), JsonValue::Sci(c.threshold_min)),
            ("vabft_threshold_max".to_string(), JsonValue::Sci(c.threshold_max)),
            ("aabft_threshold_max".to_string(), JsonValue::Sci(c.aabft_threshold_max)),
            ("tightness".to_string(), JsonValue::Sci(c.tightness())),
            ("severity_detected".to_string(), JsonValue::Int(c.severity_detected as i64)),
            ("severity_waived".to_string(), JsonValue::Int(c.severity_waived as i64)),
        ]);
    }
    // Multi-fault axis entries ride the same document, distinguished by
    // the `multi_cell` key (single-fault entries lead with `cell`).
    for c in &outcome.multi_cells {
        let s = &c.spec;
        doc.entry(vec![
            ("multi_cell".to_string(), JsonValue::Int(s.index as i64)),
            ("shape".to_string(), JsonValue::Str(fmt_shape(s.shape))),
            ("precision".to_string(), JsonValue::Str(s.precision.name().to_string())),
            ("strategy".to_string(), JsonValue::Str(s.strategy.name().to_string())),
            ("dist".to_string(), JsonValue::Str(s.dist.label())),
            ("pattern".to_string(), JsonValue::Str(s.pattern.name().to_string())),
            ("flips".to_string(), JsonValue::Int(s.flips as i64)),
            ("encoding".to_string(), JsonValue::Str(s.encoding.name().to_string())),
            ("bit".to_string(), JsonValue::Int(c.bit as i64)),
            ("trials".to_string(), JsonValue::Int(c.trials as i64)),
            ("detected".to_string(), JsonValue::Int(c.detected as i64)),
            ("above".to_string(), JsonValue::Int(c.above as i64)),
            ("detected_above".to_string(), JsonValue::Int(c.detected_above as i64)),
            (
                "corrected_no_recompute".to_string(),
                JsonValue::Int(c.corrected_no_recompute as i64),
            ),
            ("rows_corrected_grid".to_string(), JsonValue::Int(c.rows_corrected_grid as i64)),
            (
                "inconsistent_localizations".to_string(),
                JsonValue::Int(c.inconsistent_localizations as i64),
            ),
            ("rows_recomputed".to_string(), JsonValue::Int(c.rows_recomputed as i64)),
            ("clean_rows".to_string(), JsonValue::Int(c.clean_rows as i64)),
            ("false_positives".to_string(), JsonValue::Int(c.false_positives as i64)),
        ]);
    }
    // Protection-plan axis entries, distinguished by the `plan_cell` key.
    for c in &outcome.plan_cells {
        let s = &c.spec;
        doc.entry(vec![
            ("plan_cell".to_string(), JsonValue::Int(s.index as i64)),
            ("shape".to_string(), JsonValue::Str(fmt_shape(s.shape))),
            ("precision".to_string(), JsonValue::Str(s.precision.name().to_string())),
            ("strategy".to_string(), JsonValue::Str(s.strategy.name().to_string())),
            ("dist".to_string(), JsonValue::Str(s.dist.label())),
            ("scheme".to_string(), JsonValue::Str(s.scheme.label())),
            ("bit".to_string(), JsonValue::Int(c.bit as i64)),
            ("trials".to_string(), JsonValue::Int(c.trials as i64)),
            ("detected".to_string(), JsonValue::Int(c.detected as i64)),
            ("clean_rows".to_string(), JsonValue::Int(c.clean_rows as i64)),
            ("false_positives".to_string(), JsonValue::Int(c.false_positives as i64)),
            ("repaired_bitwise".to_string(), JsonValue::Int(c.repaired_bitwise as i64)),
        ]);
    }
    doc
}
