//! # V-ABFT — variance-based adaptive thresholds for fault-tolerant GEMM
//!
//! A from-scratch reproduction of *“V-ABFT: Variance-Based Adaptive
//! Threshold for Fault-Tolerant Matrix Multiplication in Mixed-Precision
//! Deep Learning”* (Gao, Hua & Chen, 2026) as a three-layer
//! Rust + JAX + Pallas system:
//!
//! * **L1** — a fused ABFT-GEMM Pallas kernel (build-time Python, lowered
//!   to HLO text) that verifies checksums *before* output quantization.
//! * **L2** — a JAX transformer whose matmuls route through the L1 kernel;
//!   forward/loss/train-step are AOT-lowered once to `artifacts/*.hlo.txt`.
//! * **L3** — this crate: the fault-tolerant GEMM runtime. It owns the
//!   event loop, the verification pipeline (detect → localize → correct →
//!   recompute), fault-injection campaigns, threshold algorithms
//!   (V-ABFT and the A-ABFT / analytical / SEA baselines), the e_max
//!   calibration protocol, and the PJRT runtime that executes the AOT
//!   artifacts. Python is never on the request path.
//!
//! ## The execution layer: packed, register-blocked, schedule-preserving
//!
//! All GEMMs run on the packed, cache-blocked, multi-threaded engine in
//! [`gemm::tiled`] (configured by the [`gemm::EngineConfig`] builder,
//! which folds in the `vabft autotune` tuning manifest and detected CPU
//! features): operands are repacked into contiguous micro-panels
//! ([`gemm::pack`]) and driven through MR×NR register-blocked
//! microkernels ([`gemm::micro`], runtime-dispatched to explicit
//! AVX2/NEON SIMD variants by [`gemm::simd`]). The
//! load-bearing invariant: **every output element's K-reduction order is
//! bitwise-identical to the naive reference kernels** in
//! [`gemm::kernels`], for all three [`gemm::ReduceStrategy`] variants.
//! V-ABFT's variance model characterizes *where rounding happens* along
//! each element's accumulation chain, so the engine parallelizes, tiles
//! and vectorizes only across output rows and columns — never across K
//! within one element — and e_max calibrated on the naive kernels remains
//! valid at any thread count, tile shape or microkernel shape (locked in
//! by `tests/tiled_equivalence.rs` and the CI microkernel smoke bench).
//!
//! ## Quick start
//!
//! ```
//! use vabft::prelude::*;
//!
//! // Build two matrices, run a protected multiply, inject a fault, recover.
//! let mut rng = Xoshiro256pp::seed_from_u64(7);
//! let a = Matrix::sample(64, 96, &Distribution::Normal { mean: 0.0, std: 1.0 }, &mut rng);
//! let b = Matrix::sample(96, 32, &Distribution::Normal { mean: 0.0, std: 1.0 }, &mut rng);
//!
//! let engine = GemmEngine::new(AccumModel::wide(Precision::Bf16));
//! let policy = VerifyPolicy::default();
//! let ft = FtGemm::new(engine, Box::new(VabftThreshold::default()), policy);
//! let out = ft.multiply(&a, &b).unwrap();
//! assert_eq!(out.c.rows(), 64);
//! assert_eq!(out.report.verdict, Verdict::Clean);
//! ```
//!
//! ## Weight-stationary serving
//!
//! Inference serving reuses one weight matrix B across every request.
//! [`abft::PreparedWeights`] caches B's checksum encoding, the V-ABFT
//! B-side statistics and the resolved threshold context once per weight —
//! computed with the same rounding schedule as the live path, so the warm
//! path is bitwise-identical to encode-per-call in outputs and
//! verification decisions. The [`coordinator`] keeps prepared weights in
//! a shared LRU cache keyed by weight id (`register_weights`), and
//! requests can also carry the handle directly.
//!
//! At scale the coordinator runs **sharded**: N queue + worker-pool
//! units planned onto the machine's NUMA topology
//! ([`coordinator::partition`]), with optional cross-shard work stealing
//! and per-shard read-through weight caches. Sharding is pure scheduling
//! — outputs, verdicts and thresholds are bitwise-invariant across shard
//! counts, partition policies and stealing (`tests/shard_equivalence.rs`)
//! — and the [`workload`] module replays deterministic transformer-layer
//! traces through it (`vabft serve-replay`, `BENCH_serving.json`). See
//! `docs/ARCHITECTURE.md` and `docs/PERFORMANCE.md` at the repository
//! root.
//!
//! ## Detection-quality at scale
//!
//! The [`campaign`] module sweeps the full precision × bit-position ×
//! injection-site × strategy × distribution × shape space as one seeded,
//! coordinator-batched workload, emitting `BENCH_campaign.json` —
//! byte-reproducible at any thread count, so CI pins exact expected
//! detection counts (`vabft campaign --quick`; see `docs/CAMPAIGN.md`).
//!
//! See `examples/` for fault-injection campaigns, e_max calibration, a
//! serving-style coordinator and the end-to-end training supervisor.

#![warn(missing_docs)]

pub mod bench_harness;
pub mod calibrate;
pub mod campaign;
pub mod cli;
pub mod coordinator;
pub mod error;
pub mod experiments;
pub mod fp;
pub mod gemm;
pub mod inject;
pub mod matrix;
pub mod metrics;
pub mod planner;
pub mod report;
pub mod rng;
pub mod runtime;
pub mod threshold;
pub mod train;
pub mod workload;

pub mod abft {
    //! Algorithm-Based Fault Tolerance core: checksum encoding,
    //! verification, localization and online correction (paper §2.2),
    //! plus block-wise tiling (§5.2).
    //!
    //! [`FtGemm`] is the single entry point; [`VerifyGranularity`] on
    //! the policy selects monolithic (block_k = K) or per-K-block
    //! verification, both parameterizations of one shared verification
    //! pipeline (the private `pipeline` module). [`PreparedWeights`]
    //! provides the weight-stationary serving fast path at either
    //! granularity.
    pub mod encode;
    pub mod ftgemm;
    pub(crate) mod pipeline;
    pub mod prepared;
    pub mod verify;
    pub use encode::*;
    pub use ftgemm::*;
    pub use prepared::*;
    pub use verify::*;
}

/// Convenient re-exports for downstream users and the examples.
pub mod prelude {
    pub use crate::abft::{
        ChecksumEncoding, EncodingMode, FtGemm, FtGemmOutput, PreparedBlock, PreparedWeights,
        Verdict, VerifyGranularity, VerifyPolicy, VerifyReport,
    };
    pub use crate::calibrate::{CalibrationProtocol, EmaxModel, EmaxTable, Platform};
    pub use crate::campaign::{BitClass, CellSpec, GridConfig, VerifyPoint};
    pub use crate::coordinator::{PartitionPolicy, TopologyConfig};
    pub use crate::fp::{dd::Dd, Precision};
    pub use crate::gemm::{
        cpu_features, AccumModel, EngineConfig, FusedProbe, FusedRowCheck, GemmEngine,
        MicroConfig, ParallelismConfig, RowSplit, SimdLevel, TileConfig,
    };
    pub use crate::inject::{
        BitFlip, Campaign, CampaignConfig, FaultOutcome, FaultSite, FaultSpec, FlipDirection,
        InjectionSite, SiteClass,
    };
    pub use crate::matrix::{Matrix, RowStats};
    pub use crate::planner::{
        arithmetic_intensity, CostModel, CostObservation, PlanEntry, PlanMode, Planner,
        PlannerConfig, ProtectionPlan, ProtectionScheme,
    };
    pub use crate::rng::{Distribution, Rng, SplitMix64, Xoshiro256pp};
    pub use crate::runtime::{TunedShape, TuningManifest};
    pub use crate::threshold::{
        AabftThreshold, AnalyticalThreshold, SeaThreshold, Threshold, VabftThreshold,
    };
}
