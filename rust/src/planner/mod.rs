//! Arithmetic-intensity-guided per-layer protection planner.
//!
//! Uniform ABFT is the wrong call for every layer of a real serving
//! trace: large square GEMMs amortize checksum verification into noise
//! (and want it fused into the epilogue), while small or skinny layers
//! pay fixed per-request costs — per-row threshold statistics over A,
//! checksum dot products — that can rival the multiply itself, where
//! dual-compute replication is the cheaper detector. This module picks a
//! [`ProtectionScheme`] per replay-trace layer from the shape's
//! [`arithmetic_intensity`] (candidate filter) and a **measured**
//! [`CostModel`] (final call), seeded from the autotuner's
//! [`crate::runtime::TuningManifest`] and refined by a small calibration
//! pass that times each candidate scheme on the trace's own shapes.
//!
//! The emitted [`ProtectionPlan`] rides the weight handle: the
//! coordinator's `register_weights_planned` prepares each weight under
//! its entry's scheme and workers dispatch on it per request — requests
//! never re-consult the planner.
//!
//! **Invariant #9 (plan selection is pure scheduling).** Every scheme the
//! default planner emits — staged ABFT, fused-epilogue ABFT, grid
//! encodings, dual-compute replication — preserves each output element's
//! rounding schedule bit-for-bit, so a planned replay and a uniform-ABFT
//! replay produce identical outputs, verdicts and fingerprints on clean
//! traffic; the plan changes *which verifier runs*, never the data. The
//! one scheme that is **not** schedule-neutral is
//! [`ProtectionScheme::BlockK`]: per-K-block verification aggregates
//! partials with intermediate work-precision roundings (a data-path
//! choice, documented on [`crate::abft::VerifyGranularity`]), so the
//! planner only emits it when [`PlannerConfig::allow_block_k`] is
//! explicitly set — the campaign's plan axis still validates its
//! detection quality like every other scheme.

pub mod cost;
pub mod intensity;

pub use cost::{CostModel, CostObservation};
pub use intensity::arithmetic_intensity;

use crate::abft::{EncodingMode, VerifyGranularity, VerifyPolicy};
use crate::workload::LayerTrace;

/// One protection scheme the planner can assign to a layer.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ProtectionScheme {
    /// Staged (post-hoc) monolithic online ABFT — the uniform baseline:
    /// row checksums verified against the pre-quantization accumulator
    /// after the kernel returns.
    Full,
    /// Online ABFT with detection fused into the packed GEMM epilogue —
    /// same decisions, same bits, one less pass over C.
    Fused,
    /// Grid (2-D) encoding with peeling multi-fault repair
    /// ([`EncodingMode::Grid`]) — row bursts and checksum upsets are
    /// corrected without recomputation.
    Grid,
    /// Per-K-block verification at this block depth
    /// ([`VerifyGranularity::BlockK`]) — tighter thresholds and K-local
    /// fault attribution. **Not schedule-neutral**: blockwise partial
    /// aggregation legitimately changes output bits, so the default
    /// planner never emits it (see the module docs, invariant #9).
    BlockK(usize),
    /// Dual-compute replication: run the multiply twice on the identical
    /// schedule, compare accumulators bitwise, recompute divergent rows.
    /// No thresholds, no checksum verification — the detector of choice
    /// when ABFT's fixed per-request costs exceed a second (small)
    /// multiply.
    Replicate,
}

impl ProtectionScheme {
    /// Every scheme the planner can emit — the campaign's plan axis
    /// enumerates this vocabulary so each scheme's recall and
    /// false-positive behavior is validated whether or not the current
    /// cost model happens to pick it.
    pub fn vocabulary(block_k: usize) -> Vec<ProtectionScheme> {
        vec![
            ProtectionScheme::Full,
            ProtectionScheme::Fused,
            ProtectionScheme::Grid,
            ProtectionScheme::BlockK(block_k.max(1)),
            ProtectionScheme::Replicate,
        ]
    }

    /// Stable display label (used in plan summaries, bench rows and
    /// campaign cell keys).
    pub fn label(&self) -> String {
        match self {
            ProtectionScheme::Full => "full".to_string(),
            ProtectionScheme::Fused => "fused".to_string(),
            ProtectionScheme::Grid => "grid".to_string(),
            ProtectionScheme::BlockK(bk) => format!("block{bk}"),
            ProtectionScheme::Replicate => "replicate".to_string(),
        }
    }

    /// True when executing under this scheme reproduces the uniform
    /// (monolithic) path's output bits on clean data — every scheme
    /// except [`ProtectionScheme::BlockK`], whose per-block aggregation
    /// is a different rounding schedule.
    pub fn is_schedule_neutral(&self) -> bool {
        !matches!(self, ProtectionScheme::BlockK(_))
    }

    /// Derive the concrete [`VerifyPolicy`] this scheme runs under,
    /// inheriting the recovery knobs (correct / recompute / reverify /
    /// severity / localization tolerance) from `base`. Every scheme
    /// verifies online (the pre-quantization accumulator): that is both
    /// the paper's recommended verification point and what keeps plan
    /// dispatch a pure verifier swap.
    pub fn policy(&self, base: VerifyPolicy) -> VerifyPolicy {
        let mut p = base;
        p.online = true;
        p.fused = false;
        p.encoding = EncodingMode::RowOnly;
        p.granularity = VerifyGranularity::Monolithic;
        match self {
            ProtectionScheme::Full | ProtectionScheme::Replicate => {}
            ProtectionScheme::Fused => p.fused = true,
            ProtectionScheme::Grid => p.encoding = EncodingMode::Grid,
            ProtectionScheme::BlockK(bk) => {
                p.granularity = VerifyGranularity::BlockK((*bk).max(1))
            }
        }
        p
    }
}

/// How a replay chose its per-layer protection.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PlanMode {
    /// Planner-chosen mixed protection.
    Auto,
    /// Uniform staged ABFT on every layer (the baseline arm of the A/B).
    Uniform,
}

impl PlanMode {
    /// Stable label for bench rows and CLI output.
    pub fn label(&self) -> &'static str {
        match self {
            PlanMode::Auto => "auto",
            PlanMode::Uniform => "uniform",
        }
    }
}

/// The planner's decision for one distinct weight tensor.
#[derive(Debug, Clone)]
pub struct PlanEntry {
    /// Index into the trace's distinct weights.
    pub weight: usize,
    /// Layer name from the weight profile.
    pub name: String,
    /// Representative request shape (m, k, n) the decision was made for.
    pub m: usize,
    /// GEMM reduction depth.
    pub k: usize,
    /// GEMM output columns.
    pub n: usize,
    /// Arithmetic intensity of the shape (flops/byte).
    pub intensity: f64,
    /// The chosen protection scheme.
    pub scheme: ProtectionScheme,
    /// The cost model's predicted per-request cost under the chosen
    /// scheme, in nanoseconds (0.0 when no measurement or prior existed).
    pub predicted_ns: f64,
}

/// A per-layer protection plan over a replay trace's distinct weights.
#[derive(Debug, Clone)]
pub struct ProtectionPlan {
    /// How the plan was produced.
    pub mode: PlanMode,
    /// One entry per distinct weight, in weight-index order.
    pub entries: Vec<PlanEntry>,
}

impl ProtectionPlan {
    /// The entry for a weight index, if the plan covers it.
    pub fn entry_for(&self, weight: usize) -> Option<&PlanEntry> {
        self.entries.iter().find(|e| e.weight == weight)
    }

    /// Uniform staged-ABFT plan over a trace — the baseline arm of the
    /// planned-vs-uniform A/B, routed through the same planned
    /// registration path so the two arms differ only in scheme choice.
    pub fn uniform_for(trace: &LayerTrace) -> ProtectionPlan {
        let entries = distinct_weight_shapes(trace)
            .into_iter()
            .map(|(weight, name, m, k, n)| PlanEntry {
                weight,
                name,
                intensity: arithmetic_intensity(m, k, n),
                m,
                k,
                n,
                scheme: ProtectionScheme::Full,
                predicted_ns: 0.0,
            })
            .collect();
        ProtectionPlan { mode: PlanMode::Uniform, entries }
    }

    /// Count of entries per scheme label, in label order — the one-line
    /// plan summary the CLI prints.
    pub fn summary(&self) -> String {
        let mut counts: Vec<(String, usize)> = Vec::new();
        for e in &self.entries {
            let label = e.scheme.label();
            match counts.iter_mut().find(|(l, _)| *l == label) {
                Some((_, c)) => *c += 1,
                None => counts.push((label, 1)),
            }
        }
        counts.sort();
        let parts: Vec<String> =
            counts.iter().map(|(l, c)| format!("{l}={c}")).collect();
        format!("{} layers: {}", self.entries.len(), parts.join(" "))
    }
}

/// Planner knobs. The defaults emit only schedule-neutral schemes
/// (invariant #9); `allow_block_k` opts into the blockwise data path for
/// workloads that registered their weights blockwise anyway.
#[derive(Debug, Clone)]
pub struct PlannerConfig {
    /// Shapes at or below this arithmetic intensity (flops/byte) get
    /// dual-compute replication as a candidate. Above it, a second
    /// multiply can never beat checksum verification, so the candidate
    /// is not even measured.
    pub replicate_max_intensity: f64,
    /// Emit [`ProtectionScheme::BlockK`] for deep-K layers. Off by
    /// default: blockwise aggregation changes output bits (see the
    /// module docs).
    pub allow_block_k: bool,
    /// Block depth used when `allow_block_k` is set and K is at least
    /// four blocks deep.
    pub block_k: usize,
    /// Plan for multi-fault coverage: restrict candidates to the schemes
    /// that repair row-inconsistent bursts (grid encodings, replication)
    /// instead of cost-optimal single-upset protection.
    pub multi_fault: bool,
    /// Timed repetitions per (shape, scheme) in the calibration pass;
    /// the minimum over reps is recorded (classic bench hygiene).
    pub calibration_reps: usize,
}

impl Default for PlannerConfig {
    fn default() -> Self {
        PlannerConfig {
            replicate_max_intensity: 8.0,
            allow_block_k: false,
            block_k: 64,
            multi_fault: false,
            calibration_reps: 2,
        }
    }
}

/// The planner: a candidate filter (arithmetic intensity) over a measured
/// cost model.
#[derive(Debug)]
pub struct Planner {
    cfg: PlannerConfig,
    cost: CostModel,
}

impl Planner {
    /// Build a planner over a cost model (seed it from the tuning
    /// manifest and/or calibrate it first — see [`CostModel`]).
    pub fn new(cfg: PlannerConfig, cost: CostModel) -> Planner {
        Planner { cfg, cost }
    }

    /// The cost model the planner consults.
    pub fn cost_model(&self) -> &CostModel {
        &self.cost
    }

    /// Candidate schemes for a shape, in deterministic preference order
    /// (ties in predicted cost resolve to the earliest candidate — the
    /// uniform baseline first, so an uninformative cost model degrades to
    /// uniform ABFT, never to an exotic scheme).
    pub fn candidates(&self, m: usize, k: usize, n: usize) -> Vec<ProtectionScheme> {
        let intensity = arithmetic_intensity(m, k, n);
        if self.cfg.multi_fault {
            // Multi-fault coverage: only the schemes that repair
            // row-inconsistent bursts qualify; cost picks among them.
            let mut c = vec![ProtectionScheme::Grid];
            if intensity <= self.cfg.replicate_max_intensity {
                c.push(ProtectionScheme::Replicate);
            }
            return c;
        }
        let mut c = vec![ProtectionScheme::Full, ProtectionScheme::Fused];
        if self.cfg.allow_block_k && k >= 4 * self.cfg.block_k {
            c.push(ProtectionScheme::BlockK(self.cfg.block_k));
        }
        if intensity <= self.cfg.replicate_max_intensity {
            c.push(ProtectionScheme::Replicate);
        }
        c
    }

    /// Plan one shape: pick the candidate with the lowest predicted
    /// per-request cost (strictly-less comparison over the deterministic
    /// candidate order, so equal costs keep the earlier, safer scheme).
    pub fn plan_shape(
        &self,
        weight: usize,
        name: &str,
        m: usize,
        k: usize,
        n: usize,
    ) -> PlanEntry {
        let mut best = ProtectionScheme::Full;
        let mut best_ns = f64::INFINITY;
        for s in self.candidates(m, k, n) {
            let ns = self.cost.predict(s, m, k, n);
            if ns < best_ns {
                best = s;
                best_ns = ns;
            }
        }
        PlanEntry {
            weight,
            name: name.to_string(),
            m,
            k,
            n,
            intensity: arithmetic_intensity(m, k, n),
            scheme: best,
            predicted_ns: if best_ns.is_finite() { best_ns } else { 0.0 },
        }
    }

    /// Plan a whole replay trace: one entry per distinct weight, using
    /// the first trace entry referencing each weight as the
    /// representative request shape.
    pub fn plan_trace(&self, trace: &LayerTrace) -> ProtectionPlan {
        let entries = distinct_weight_shapes(trace)
            .into_iter()
            .map(|(weight, name, m, k, n)| self.plan_shape(weight, &name, m, k, n))
            .collect();
        ProtectionPlan { mode: PlanMode::Auto, entries }
    }
}

/// (weight index, layer name, m, k, n) per distinct weight of a trace, in
/// weight-index order, shaped by the first entry referencing each weight.
fn distinct_weight_shapes(trace: &LayerTrace) -> Vec<(usize, String, usize, usize, usize)> {
    let mut shapes = Vec::with_capacity(trace.weights.len());
    for (widx, (k, n, _)) in trace.weights.iter().enumerate() {
        let entry = trace.entries.iter().find(|e| e.weight == widx);
        let (m, name) = match entry {
            Some(e) => (e.m, e.name.to_string()),
            None => (1, format!("w{widx}")),
        };
        shapes.push((widx, name, m, *k, *n));
    }
    shapes
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::{build_trace, ReplayConfig};

    #[test]
    fn scheme_policies_derive_from_base() {
        let base = VerifyPolicy::default().with_severity();
        let full = ProtectionScheme::Full.policy(base);
        assert!(full.online && !full.fused && full.severity);
        assert_eq!(full.encoding, EncodingMode::RowOnly);
        let fused = ProtectionScheme::Fused.policy(base);
        assert!(fused.fused && fused.online);
        let grid = ProtectionScheme::Grid.policy(base);
        assert_eq!(grid.encoding, EncodingMode::Grid);
        let bk = ProtectionScheme::BlockK(32).policy(base);
        assert_eq!(bk.granularity, VerifyGranularity::BlockK(32));
        // Neutrality split: exactly BlockK is non-neutral.
        for s in ProtectionScheme::vocabulary(64) {
            assert_eq!(
                s.is_schedule_neutral(),
                !matches!(s, ProtectionScheme::BlockK(_)),
                "{}",
                s.label()
            );
        }
    }

    #[test]
    fn planner_is_cost_driven_and_intensity_filtered() {
        // A synthetic cost model that makes replication cheap on the
        // skinny shape and fused cheap on the big one.
        let mut cm = CostModel::new();
        cm.observe(CostObservation {
            scheme: ProtectionScheme::Replicate,
            m: 1,
            k: 256,
            n: 64,
            ns: 100.0,
        });
        cm.observe(CostObservation { scheme: ProtectionScheme::Full, m: 1, k: 256, n: 64, ns: 300.0 });
        cm.observe(CostObservation { scheme: ProtectionScheme::Fused, m: 1, k: 256, n: 64, ns: 250.0 });
        cm.observe(CostObservation {
            scheme: ProtectionScheme::Fused,
            m: 256,
            k: 256,
            n: 256,
            ns: 1000.0,
        });
        cm.observe(CostObservation {
            scheme: ProtectionScheme::Full,
            m: 256,
            k: 256,
            n: 256,
            ns: 1200.0,
        });
        let p = Planner::new(PlannerConfig::default(), cm);

        // Skinny, bandwidth-bound: replication is a candidate and wins on
        // measured cost.
        let skinny = p.plan_shape(0, "gemv", 1, 256, 64);
        assert!(skinny.intensity <= 8.0);
        assert_eq!(skinny.scheme, ProtectionScheme::Replicate);
        assert!(skinny.predicted_ns > 0.0);

        // Big square: replication is not even a candidate; fused wins.
        let big = p.plan_shape(1, "ffn", 256, 256, 256);
        assert!(!p.candidates(256, 256, 256).contains(&ProtectionScheme::Replicate));
        assert_eq!(big.scheme, ProtectionScheme::Fused);

        // BlockK never emitted by default, even for deep K.
        assert!(!p.candidates(8, 4096, 64).iter().any(|s| matches!(s, ProtectionScheme::BlockK(_))));
        let p2 = Planner::new(
            PlannerConfig { allow_block_k: true, ..PlannerConfig::default() },
            CostModel::new(),
        );
        assert!(p2.candidates(8, 4096, 64).iter().any(|s| matches!(s, ProtectionScheme::BlockK(_))));
    }

    #[test]
    fn uninformative_cost_model_degrades_to_uniform() {
        // With no observations and no priors, every candidate predicts
        // the same analytic fallback ordering — the tie-break keeps the
        // baseline for equal costs, and the analytic prior never makes
        // replication beat ABFT on a compute-rich shape.
        let p = Planner::new(PlannerConfig::default(), CostModel::new());
        let e = p.plan_shape(0, "wq", 64, 512, 512);
        assert!(e.scheme == ProtectionScheme::Full || e.scheme == ProtectionScheme::Fused);
        assert!(e.scheme.is_schedule_neutral());
    }

    #[test]
    fn trace_plan_covers_every_weight_and_uniform_is_full() {
        let cfg = ReplayConfig::smoke("gpt2", 3);
        let trace = build_trace(&cfg);
        let plan = Planner::new(PlannerConfig::default(), CostModel::new()).plan_trace(&trace);
        assert_eq!(plan.mode, PlanMode::Auto);
        assert_eq!(plan.entries.len(), trace.weights.len());
        for (i, e) in plan.entries.iter().enumerate() {
            assert_eq!(e.weight, i);
            assert!(e.scheme.is_schedule_neutral(), "default plan must be neutral");
            assert!(e.intensity > 0.0);
        }
        assert!(!plan.summary().is_empty());

        let uni = ProtectionPlan::uniform_for(&trace);
        assert_eq!(uni.mode, PlanMode::Uniform);
        assert_eq!(uni.entries.len(), trace.weights.len());
        assert!(uni.entries.iter().all(|e| e.scheme == ProtectionScheme::Full));
        assert!(uni.entry_for(0).is_some());
        assert!(uni.entry_for(usize::MAX).is_none());
    }
}
