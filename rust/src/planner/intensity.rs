//! Arithmetic intensity of a GEMM shape.
//!
//! "Arithmetic-Intensity-Guided Fault Tolerance" observes that the best
//! protection scheme flips between replication and ABFT depending on how
//! many flops a kernel performs per byte it moves: ABFT's O(n) advantage
//! is an advantage over *recomputation*, and for small or skinny layers
//! (ViT heads, GPT-2 MLPs at low batch) the fixed per-request costs of
//! checksum verification can exceed the cost of simply running the
//! multiply twice. The planner uses the intensity as a *candidate
//! filter* — which schemes are worth measuring for a shape — while the
//! measured cost model ([`crate::planner::CostModel`]) makes the final
//! call.

/// Flops per byte of an `m × k · k × n` GEMM with f64 operands:
/// `2mkn / 8(mk + kn + mn)`.
///
/// Intuition anchors: a square `s³` GEMM has intensity `s/12` (grows
/// without bound — compute-rich), while an `m=1` GEMV is pinned below
/// `1/4` flops/byte no matter how large k and n get (bandwidth-bound —
/// the regime where dual-compute replication is competitive).
pub fn arithmetic_intensity(m: usize, k: usize, n: usize) -> f64 {
    let (m, k, n) = (m.max(1) as f64, k.max(1) as f64, n.max(1) as f64);
    let flops = 2.0 * m * k * n;
    let bytes = 8.0 * (m * k + k * n + m * n);
    flops / bytes
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn intensity_orders_shapes_sensibly() {
        // Square GEMMs grow in intensity with size.
        assert!(arithmetic_intensity(256, 256, 256) > arithmetic_intensity(64, 64, 64));
        // A batch-1 GEMV is bandwidth-bound: intensity < 1/4 flops/byte
        // regardless of the weight shape.
        assert!(arithmetic_intensity(1, 4096, 4096) < 0.25);
        assert!(arithmetic_intensity(1, 1 << 20, 1 << 20) < 0.25);
        // Square s³ ≈ s/12.
        let s = 384;
        let got = arithmetic_intensity(s, s, s);
        assert!((got - s as f64 / 12.0).abs() / got < 1e-9);
        // Degenerate shapes don't divide by zero.
        assert!(arithmetic_intensity(0, 0, 0).is_finite());
    }
}
