//! Measured cost model behind the protection planner.
//!
//! Scheme choice is an economics question — "is a second multiply cheaper
//! than checksum verification *here*?" — and the honest way to answer it
//! is to measure. The model holds [`CostObservation`]s (minimum-of-reps
//! wall-clock timings of a scheme on a shape, recorded by
//! [`CostModel::calibrate_shape`]) and answers [`CostModel::predict`]
//! queries by nearest-neighbour lookup in the same smoothed log-ratio
//! shape metric the tuning manifest uses, scaled by the flop ratio
//! between the observed and queried shapes. Shapes no observation covers
//! fall back to a documented analytic prior seeded from the autotuner's
//! measured GFLOP/s ([`CostModel::seed_from_manifest`]).
//!
//! Timing noise can change which scheme the planner picks; it can never
//! change result bits. The default planner vocabulary is
//! schedule-neutral (invariant #9), so a noisy calibration at worst
//! costs wall-clock time — detection recall and output bits are
//! identical under every scheme it can emit.

use std::time::Instant;

use crate::abft::{FtGemm, VerifyPolicy};
use crate::gemm::{AccumModel, GemmEngine};
use crate::matrix::Matrix;
use crate::rng::{Distribution, Xoshiro256pp};
use crate::runtime::TuningManifest;
use crate::threshold::VabftThreshold;

use super::ProtectionScheme;

/// Seed stream tag for calibration operands (disjoint from the replay
/// weight/activation tags, so calibration never replays serving data).
const CAL_TAG: u64 = 0x5E2F_33CF;

/// Shapes further than this (summed log-ratio over m, k, n) from every
/// observation fall back to the analytic prior — same cap as
/// [`TuningManifest::lookup`].
const MAX_DIST: f64 = 3.0;

/// One timed measurement: `scheme` on an `m × k · k × n` multiply took
/// `ns` nanoseconds (minimum over calibration reps).
#[derive(Debug, Clone, PartialEq)]
pub struct CostObservation {
    /// The scheme that was timed.
    pub scheme: ProtectionScheme,
    /// Output rows of the timed shape.
    pub m: usize,
    /// Reduction depth of the timed shape.
    pub k: usize,
    /// Output columns of the timed shape.
    pub n: usize,
    /// Measured per-request cost in nanoseconds.
    pub ns: f64,
}

/// Per-scheme cost model: measured observations first, analytic prior as
/// the fallback. See the module docs.
#[derive(Debug, Clone, Default)]
pub struct CostModel {
    observations: Vec<CostObservation>,
    /// Throughput prior (GFLOP/s) used to convert the analytic model's
    /// flop-equivalent units to nanoseconds; 0.0 = unseeded (treated
    /// as 1.0, which preserves the analytic *ordering* — the only thing
    /// argmin needs).
    gflops_prior: f64,
}

impl CostModel {
    /// Empty model: every prediction uses the analytic prior.
    pub fn new() -> CostModel {
        CostModel::default()
    }

    /// Number of recorded observations.
    pub fn len(&self) -> usize {
        self.observations.len()
    }

    /// True when no observation has been recorded.
    pub fn is_empty(&self) -> bool {
        self.observations.is_empty()
    }

    /// Record a measurement (also the deterministic-test entry point: the
    /// planner's choice logic can be exercised with synthetic costs).
    pub fn observe(&mut self, obs: CostObservation) {
        self.observations.push(obs);
    }

    /// Seed the analytic prior from the autotuner's persisted manifest:
    /// the median measured GFLOP/s across tuned shape classes. Purely a
    /// unit conversion for the fallback path — measured observations
    /// always win over the prior.
    pub fn seed_from_manifest(&mut self, man: &TuningManifest) {
        let mut rates: Vec<f64> =
            man.entries.iter().map(|e| e.gflops).filter(|g| *g > 0.0).collect();
        if rates.is_empty() {
            return;
        }
        rates.sort_by(|a, b| a.partial_cmp(b).unwrap());
        self.gflops_prior = rates[rates.len() / 2];
    }

    /// The seeded throughput prior (0.0 when unseeded).
    pub fn gflops_prior(&self) -> f64 {
        self.gflops_prior
    }

    /// Time each scheme on one shape and record the minimum over `reps`
    /// repetitions. Operands are seeded from the shape (deterministic
    /// data, non-deterministic timings — see the module docs for why
    /// that is safe). Weight preparation happens outside the timed
    /// region: serving amortizes it across thousands of requests.
    pub fn calibrate_shape(
        &mut self,
        model: AccumModel,
        m: usize,
        k: usize,
        n: usize,
        schemes: &[ProtectionScheme],
        reps: usize,
    ) {
        let substream = ((m as u64) << 42) ^ ((k as u64) << 21) ^ n as u64;
        let mut rng = Xoshiro256pp::from_stream(CAL_TAG, substream);
        let d = Distribution::normal_1_1();
        let b = Matrix::sample_in(k, n, &d, model.input, &mut rng);
        let a = Matrix::sample_in(m, k, &d, model.input, &mut rng);
        for &scheme in schemes {
            let policy = scheme.policy(VerifyPolicy::default());
            let ft = FtGemm::new(
                GemmEngine::new(model),
                Box::new(VabftThreshold::default()),
                policy,
            );
            let w = ft.prepare(&b);
            let mut best = f64::INFINITY;
            for _ in 0..reps.max(1) {
                let t = Instant::now();
                let out = match scheme {
                    ProtectionScheme::Replicate => ft.multiply_replicated(&a, &w, None),
                    _ => ft.multiply_prepared(&a, &w, None),
                };
                let ns = t.elapsed().as_nanos() as f64;
                if out.is_ok() {
                    best = best.min(ns.max(1.0));
                }
            }
            if best.is_finite() {
                self.observe(CostObservation { scheme, m, k, n, ns: best });
            }
        }
    }

    /// Predicted per-request cost (nanoseconds) of `scheme` on a shape:
    /// the nearest observation of the same scheme (within [`MAX_DIST`]),
    /// scaled by the flop ratio between query and observation; otherwise
    /// the analytic prior. Equidistant observations tie-break on content
    /// (smaller `(m, k, n)`), mirroring the tuning-manifest rule.
    pub fn predict(&self, scheme: ProtectionScheme, m: usize, k: usize, n: usize) -> f64 {
        let d = |a: usize, b: usize| ((a as f64 + 1.0) / (b as f64 + 1.0)).ln().abs();
        let mut best: Option<(&CostObservation, f64)> = None;
        for o in self.observations.iter().filter(|o| o.scheme == scheme) {
            let dist = d(o.m, m) + d(o.k, k) + d(o.n, n);
            let better = match &best {
                Some((bo, bd)) => {
                    dist < *bd || (dist == *bd && (o.m, o.k, o.n) < (bo.m, bo.k, bo.n))
                }
                None => true,
            };
            if better {
                best = Some((o, dist));
            }
        }
        if let Some((o, dist)) = best {
            if dist <= MAX_DIST {
                return o.ns * (flops(m, k, n) / flops(o.m, o.k, o.n));
            }
        }
        self.analytic(scheme, m, k, n)
    }

    /// Analytic prior, in flop-equivalent units converted to ns via the
    /// manifest-seeded throughput. The structure encodes what the timed
    /// paths actually do:
    ///
    /// - every ABFT scheme pays a fixed per-request term (threshold
    ///   context, per-row statistics plumbing, verdict bookkeeping) plus
    ///   verification *traffic* — bandwidth passes over A (statistics)
    ///   and C (checksum sweep), costed at [`PASS_COST`] flop-equivalents
    ///   per element because a memory pass is not a flop;
    /// - fused ABFT saves the separate pass over C;
    /// - grid encodings double the statistics traffic (both directions);
    /// - per-K-block verification repeats the fixed work per block;
    /// - replication pays the multiply twice plus a bitwise compare, and
    ///   almost none of the fixed ABFT term.
    ///
    /// The crossover this produces — replication wins on small/skinny
    /// shapes where [`ABFT_FIXED`] dominates, ABFT wins as soon as flops
    /// do — is the arithmetic-intensity story; calibration replaces it
    /// with measurements wherever the planner has seen the shape class.
    fn analytic(&self, scheme: ProtectionScheme, m: usize, k: usize, n: usize) -> f64 {
        /// Flop-equivalents per element of a verification bandwidth pass.
        const PASS_COST: f64 = 16.0;
        /// Fixed per-request ABFT overhead, in flop-equivalents.
        const ABFT_FIXED: f64 = 8192.0;
        let (mf, kf, nf) = (m.max(1) as f64, k.max(1) as f64, n.max(1) as f64);
        let f = flops(m, k, n);
        let units = match scheme {
            ProtectionScheme::Full => {
                1.08 * f + ABFT_FIXED + PASS_COST * (mf * kf + 2.0 * mf * nf)
            }
            ProtectionScheme::Fused => {
                1.03 * f + ABFT_FIXED + PASS_COST * (mf * kf + mf * nf)
            }
            ProtectionScheme::Grid => {
                1.15 * f + ABFT_FIXED + 2.0 * PASS_COST * (mf * kf + mf * nf)
            }
            ProtectionScheme::BlockK(bk) => {
                let blocks = (kf / (*bk).max(1) as f64).ceil().max(1.0);
                1.10 * f + blocks * ABFT_FIXED + PASS_COST * (mf * kf + 2.0 * mf * nf)
            }
            ProtectionScheme::Replicate => 2.0 * f + 256.0 + 4.0 * mf * nf,
        };
        let gflops = if self.gflops_prior > 0.0 { self.gflops_prior } else { 1.0 };
        units / gflops
    }
}

/// Flop count of an `m × k · k × n` multiply (with degenerate-shape
/// guards matching [`super::arithmetic_intensity`]).
fn flops(m: usize, k: usize, n: usize) -> f64 {
    2.0 * m.max(1) as f64 * k.max(1) as f64 * n.max(1) as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fp::Precision;
    use crate::gemm::{MicroConfig, RowSplit, SimdLevel, TileConfig};
    use crate::runtime::TunedShape;

    fn obs(scheme: ProtectionScheme, m: usize, k: usize, n: usize, ns: f64) -> CostObservation {
        CostObservation { scheme, m, k, n, ns }
    }

    #[test]
    fn predict_prefers_measurements_and_scales_by_flops() {
        let mut cm = CostModel::new();
        cm.observe(obs(ProtectionScheme::Full, 64, 256, 256, 1_000.0));
        // Exact hit returns the measurement verbatim.
        assert_eq!(cm.predict(ProtectionScheme::Full, 64, 256, 256), 1_000.0);
        // A nearby shape with 2× the flops predicts 2× the cost.
        let p = cm.predict(ProtectionScheme::Full, 128, 256, 256);
        assert!((p - 2_000.0).abs() < 1e-9, "got {p}");
        // A wildly different shape ignores the observation (analytic
        // fallback — tiny shape, so far below the scaled measurement).
        let far = cm.predict(ProtectionScheme::Full, 1, 1, 1);
        assert!(far < 1_000.0);
        // Observations only inform their own scheme.
        let fused = cm.predict(ProtectionScheme::Fused, 64, 256, 256);
        assert_ne!(fused, 1_000.0);
    }

    #[test]
    fn predict_tie_breaks_on_content_not_insertion_order() {
        // (127+1)^2 = (63+1)*(255+1): both observations sit exactly ln 2
        // from the query on the m axis (the manifest test's fixture).
        let a = obs(ProtectionScheme::Full, 63, 127, 127, 500.0);
        let b = obs(ProtectionScheme::Full, 255, 127, 127, 900.0);
        let mut fwd = CostModel::new();
        fwd.observe(a.clone());
        fwd.observe(b.clone());
        let mut rev = CostModel::new();
        rev.observe(b);
        rev.observe(a);
        let q = |cm: &CostModel| cm.predict(ProtectionScheme::Full, 127, 127, 127);
        assert_eq!(q(&fwd), q(&rev));
        // Smaller (m, k, n) wins: the 63-row observation, scaled 127/63
        // in flops (k and n match).
        let expect = 500.0 * flops(127, 127, 127) / flops(63, 127, 127);
        assert!((q(&fwd) - expect).abs() < 1e-9);
    }

    #[test]
    fn analytic_prior_encodes_the_intensity_crossover() {
        let cm = CostModel::new();
        // Tiny shape: fixed ABFT cost dominates, replication is cheapest.
        let tiny = |s: ProtectionScheme| cm.predict(s, 1, 64, 64);
        assert!(tiny(ProtectionScheme::Replicate) < tiny(ProtectionScheme::Full));
        assert!(tiny(ProtectionScheme::Replicate) < tiny(ProtectionScheme::Fused));
        // Compute-rich shape: a second multiply can't win.
        let big = |s: ProtectionScheme| cm.predict(s, 512, 512, 512);
        assert!(big(ProtectionScheme::Fused) < big(ProtectionScheme::Replicate));
        assert!(big(ProtectionScheme::Full) < big(ProtectionScheme::Replicate));
        // Fused beats staged everywhere (same checks, one less pass).
        assert!(big(ProtectionScheme::Fused) < big(ProtectionScheme::Full));
        // Every scheme in the vocabulary predicts finite positive cost.
        for s in ProtectionScheme::vocabulary(64) {
            let p = cm.predict(s, 8, 256, 32);
            assert!(p.is_finite() && p > 0.0, "{}: {p}", s.label());
        }
    }

    #[test]
    fn manifest_seeding_rescales_the_prior_only() {
        let mut man = TuningManifest::new("scalar");
        man.push(TunedShape {
            label: "x".to_string(),
            m: 64,
            k: 64,
            n: 64,
            tiles: TileConfig { mc: 32, kc: 128, nc: 64 },
            micro: MicroConfig { mr: 4, nr: 16 },
            threads: 1,
            split: RowSplit::Contiguous,
            simd: SimdLevel::Scalar,
            gflops: 4.0,
            baseline_gflops: 2.0,
        });
        let mut seeded = CostModel::new();
        seeded.seed_from_manifest(&man);
        assert_eq!(seeded.gflops_prior(), 4.0);
        let unseeded = CostModel::new();
        // 4 GFLOP/s prior → analytic predictions shrink 4×; ordering is
        // unchanged, so the planner's choice is too.
        let a = unseeded.predict(ProtectionScheme::Full, 32, 128, 128);
        let b = seeded.predict(ProtectionScheme::Full, 32, 128, 128);
        assert!((a / b - 4.0).abs() < 1e-9);
        // Measurements are never rescaled.
        seeded.observe(obs(ProtectionScheme::Full, 32, 128, 128, 777.0));
        assert_eq!(seeded.predict(ProtectionScheme::Full, 32, 128, 128), 777.0);
        // An empty manifest leaves the prior unseeded.
        let mut cm = CostModel::new();
        cm.seed_from_manifest(&TuningManifest::new("scalar"));
        assert_eq!(cm.gflops_prior(), 0.0);
    }

    #[test]
    fn calibration_records_every_scheme() {
        let mut cm = CostModel::new();
        let model = AccumModel::wide(Precision::Bf16);
        let schemes = ProtectionScheme::vocabulary(16);
        cm.calibrate_shape(model, 4, 48, 8, &schemes, 1);
        assert_eq!(cm.len(), schemes.len());
        for s in schemes {
            let p = cm.predict(s, 4, 48, 8);
            assert!(p.is_finite() && p >= 1.0, "{}: {p}", s.label());
        }
    }
}
