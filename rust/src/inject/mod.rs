//! Bit-flip fault injection (paper §6.1, Tables 8/9).
//!
//! Soft errors are modelled as single-event upsets: one bit of one stored
//! element of the GEMM output flips. Injection operates on the *encoding*
//! of the element in its storage precision (e.g. the 16 bits of a BF16
//! value), so exponent/sign/mantissa semantics are exact:
//!
//! * BF16 layout: bit 15 = sign, bits 14..7 = exponent, bits 6..0 mantissa.
//!   Table 8's "bit 7 (exp LSB)" … "bit 14" rows map directly.
//! * Flips that land in the exponent scale the value by 2^(2^k)-class
//!   factors (§2.1) — the catastrophic class ABFT must catch.

use crate::fp::{Bf16, F16, Precision, F8E4M3, F8E5M2};
use crate::gemm::{AccumModel, GemmOutput};
use crate::matrix::Matrix;
use crate::rng::{Distribution, Rng, Xoshiro256pp};

/// Flip direction of the targeted bit.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum FlipDirection {
    /// The bit was 0 and became 1 (the amplifying direction for exponent
    /// bits — Table 8's "0→1" column).
    ZeroToOne,
    /// The bit was 1 and became 0.
    OneToZero,
}

/// A single bit flip at a bit position of an element's encoding.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct BitFlip {
    /// Bit position, 0 = encoding LSB.
    pub bit: u32,
    /// Storage precision whose encoding is flipped.
    pub precision: Precision,
}

impl BitFlip {
    /// A flip of `bit` in `precision`'s encoding (asserts `bit` in range).
    pub fn new(bit: u32, precision: Precision) -> BitFlip {
        assert!(bit < precision.bits(), "bit {bit} out of range for {precision}");
        BitFlip { bit, precision }
    }

    /// Apply to a value: returns (flipped value, direction taken).
    pub fn apply(&self, x: f64) -> (f64, FlipDirection) {
        match self.precision {
            Precision::Bf16 => {
                let enc = Bf16::from_f64(x);
                let dir = direction_of(enc.to_bits() as u64, self.bit);
                (enc.flip_bit(self.bit).to_f64(), dir)
            }
            Precision::F16 => {
                let enc = F16::from_f64(x);
                let dir = direction_of(enc.to_bits() as u64, self.bit);
                (enc.flip_bit(self.bit).to_f64(), dir)
            }
            Precision::F8E4M3 => {
                let enc = F8E4M3::from_f64(x);
                let dir = direction_of(enc.to_bits() as u64, self.bit);
                (enc.flip_bit(self.bit).to_f64(), dir)
            }
            Precision::F8E5M2 => {
                let enc = F8E5M2::from_f64(x);
                let dir = direction_of(enc.to_bits() as u64, self.bit);
                (enc.flip_bit(self.bit).to_f64(), dir)
            }
            Precision::F32 => {
                let enc = (x as f32).to_bits();
                let dir = direction_of(enc as u64, self.bit);
                (f32::from_bits(enc ^ (1 << self.bit)) as f64, dir)
            }
            Precision::F64 => {
                let enc = x.to_bits();
                let dir = direction_of(enc, self.bit);
                (f64::from_bits(enc ^ (1u64 << self.bit)), dir)
            }
        }
    }

    /// Whether this bit is in the exponent field.
    pub fn is_exponent_bit(&self) -> bool {
        let p = self.precision;
        self.bit >= p.exponent_lsb() && self.bit < p.sign_bit()
    }

    /// Whether this bit is the sign bit.
    pub fn is_sign_bit(&self) -> bool {
        self.bit == self.precision.sign_bit()
    }
}

fn direction_of(bits: u64, bit: u32) -> FlipDirection {
    if (bits >> bit) & 1 == 0 {
        FlipDirection::ZeroToOne
    } else {
        FlipDirection::OneToZero
    }
}

/// Location of an injection in the output matrix.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct InjectionSite {
    /// Output row.
    pub row: usize,
    /// Output column.
    pub col: usize,
}

/// Inject `flip` at `site` of `c` (which must hold values on the flip's
/// precision grid). Returns the (old, new, direction) triple.
pub fn inject(c: &mut Matrix, site: InjectionSite, flip: BitFlip) -> (f64, f64, FlipDirection) {
    let old = c.get(site.row, site.col);
    let (new, dir) = flip.apply(old);
    c.set(site.row, site.col, new);
    (old, new, dir)
}

/// Class of injection site in the campaign grid taxonomy — *where* the
/// single-event upset strikes, without coordinates.
///
/// The classes have different detection semantics:
///
/// * [`SiteClass::Output`] — a stored element of the (partial) product;
///   the classic ABFT target, one row perturbed by the flip magnitude.
/// * [`SiteClass::OperandA`] — a transient upset of an A register as it
///   feeds one FMA: one output element is perturbed by `δ_a · b_kj`.
/// * [`SiteClass::OperandB`] — a persistent upset of a stored B element
///   *after* checksum encoding: every output row i of column j is
///   perturbed by `a_ik · δ_b` (the Table 8 memory-fault configuration).
/// * [`SiteClass::Checksum`] — the already-verified checksum row itself:
///   the data columns stay clean, but verification sees `|D1|` shifted by
///   the full flip magnitude. Campaigns must report this as its own class
///   — a flagged checksum row is a *checksum* fault, not a data miss.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum SiteClass {
    /// Stored output / accumulator element.
    Output,
    /// A-operand register feeding one FMA (transient).
    OperandA,
    /// Stored B element after encoding (persistent).
    OperandB,
    /// First checksum entry (`c^{r1}`) of one row.
    Checksum,
}

impl SiteClass {
    /// All four classes, in campaign grid order.
    pub const ALL: [SiteClass; 4] =
        [SiteClass::Output, SiteClass::OperandA, SiteClass::OperandB, SiteClass::Checksum];

    /// Short lowercase name used in reports and JSON documents.
    pub fn name(self) -> &'static str {
        match self {
            SiteClass::Output => "output",
            SiteClass::OperandA => "operand_a",
            SiteClass::OperandB => "operand_b",
            SiteClass::Checksum => "checksum",
        }
    }
}

/// A fully-located injection site (class + coordinates).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultSite {
    /// Stored output / accumulator element at (`row`, `col`).
    Output {
        /// Output row.
        row: usize,
        /// Output column.
        col: usize,
    },
    /// Transient upset of A's element (`row`, `k`) as consumed by the FMA
    /// producing output element (`row`, `col`).
    OperandA {
        /// Output (and A) row.
        row: usize,
        /// K index of the corrupted A element.
        k: usize,
        /// Output column whose accumulation consumed the bad register.
        col: usize,
    },
    /// Persistent upset of stored B element (`k`, `col`): perturbs every
    /// output row of column `col` by `a_ik · δ_b`.
    OperandB {
        /// K index (row of B).
        k: usize,
        /// Output column (column of B).
        col: usize,
    },
    /// The first checksum entry (`c^{r1}`) of output row `row`.
    ChecksumR1 {
        /// Output row whose checksum entry is struck.
        row: usize,
    },
}

impl FaultSite {
    /// The site's class (coordinates dropped).
    pub fn class(self) -> SiteClass {
        match self {
            FaultSite::Output { .. } => SiteClass::Output,
            FaultSite::OperandA { .. } => SiteClass::OperandA,
            FaultSite::OperandB { .. } => SiteClass::OperandB,
            FaultSite::ChecksumR1 { .. } => SiteClass::Checksum,
        }
    }
}

/// A located fault plus the encoding bit to flip — the unit of work of a
/// campaign trial, and the coordinator's injection request payload.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FaultSpec {
    /// Where the upset strikes.
    pub site: FaultSite,
    /// Bit position flipped, addressing the site's storage encoding
    /// (verified grid for output/checksum sites, operand grid otherwise).
    pub bit: u32,
}

impl FaultSpec {
    /// Stored-output-element flip at (`row`, `col`) — the classic
    /// single-event-upset configuration.
    pub fn output(row: usize, col: usize, bit: u32) -> FaultSpec {
        FaultSpec { site: FaultSite::Output { row, col }, bit }
    }

    /// Transient A-register flip feeding output element (`row`, `col`)
    /// through K index `k`.
    pub fn operand_a(row: usize, k: usize, col: usize, bit: u32) -> FaultSpec {
        FaultSpec { site: FaultSite::OperandA { row, k, col }, bit }
    }

    /// Persistent stored-B-element flip at (`k`, `col`).
    pub fn operand_b(k: usize, col: usize, bit: u32) -> FaultSpec {
        FaultSpec { site: FaultSite::OperandB { k, col }, bit }
    }

    /// Checksum-row flip: the `c^{r1}` entry of output row `row`.
    pub fn checksum(row: usize, bit: u32) -> FaultSpec {
        FaultSpec { site: FaultSite::ChecksumR1 { row }, bit }
    }
}

/// The realized flip at a fault's *source* value: the element that was
/// actually struck (an output/checksum entry, or an operand element).
/// Campaign drivers combine `new - old` with the clean operands to compute
/// each trial's expected verification-difference magnitude.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultOutcome {
    /// Value before the flip.
    pub old: f64,
    /// Value after the flip.
    pub new: f64,
}

impl FaultOutcome {
    /// The signed source-value change `new - old`.
    pub fn delta(&self) -> f64 {
        self.new - self.old
    }
}

/// Apply a located fault to an encoded (partial) product, mutating the
/// matrix the verification policy reads (`out.acc` online, `out.c`
/// offline). One implementation shared by the coordinator's injection
/// path and the campaign engine, so every site class has exactly one
/// semantics.
///
/// * `online` — which of `out`'s matrices is verified (and thus struck);
/// * `input` — operand storage precision (operand-site flips address it);
/// * `grid` — the verified grid (output/checksum-site flips address it);
/// * `a` / `b` — the *clean* operand (block) matrices, `a` M×K, `b` K×N;
///   operand-site faults propagate through them exactly as the existing
///   Table 8 campaign does: perturb the accumulator, then re-round onto
///   the verified grid.
///
/// Out-of-range coordinates are clamped to the operand/product bounds
/// (operand K indices against the *block* depth `b.rows()`, which for a
/// blockwise-prepared weight is shallower than A), so a malformed request
/// degrades to a nearby site instead of panicking a worker thread; when
/// an addressed dimension is empty the fault is a no-op (`old == new`).
/// Returns the realized source-value flip.
pub fn apply_fault(
    spec: &FaultSpec,
    online: bool,
    input: Precision,
    grid: Precision,
    a: &Matrix,
    b: &Matrix,
    out: &mut GemmOutput,
) -> FaultOutcome {
    let n = b.cols();
    let tgt = if online { &mut out.acc } else { &mut out.c };
    let rows = tgt.rows();
    let depth = a.cols().min(b.rows());
    let empty = match spec.site {
        FaultSite::Output { .. } => rows == 0 || n == 0,
        FaultSite::ChecksumR1 { .. } => rows == 0,
        FaultSite::OperandA { .. } | FaultSite::OperandB { .. } => {
            rows == 0 || n == 0 || depth == 0
        }
    };
    if empty {
        return FaultOutcome { old: 0.0, new: 0.0 };
    }
    match spec.site {
        FaultSite::Output { row, col } => {
            let (row, col) = (row.min(rows - 1), col.min(n - 1));
            let flip = BitFlip::new(spec.bit, grid);
            let old = tgt.get(row, col);
            let (new, _) = flip.apply(old);
            tgt.set(row, col, new);
            FaultOutcome { old, new }
        }
        FaultSite::ChecksumR1 { row } => {
            // Checksum entries live in the encoded columns beyond the N
            // data columns: c^{r1} at column N (c^{r2} at N+1).
            let row = row.min(rows - 1);
            let flip = BitFlip::new(spec.bit, grid);
            let old = tgt.get(row, n);
            let (new, _) = flip.apply(old);
            tgt.set(row, n, new);
            FaultOutcome { old, new }
        }
        FaultSite::OperandA { row, k, col } => {
            let (row, k, col) = (row.min(rows - 1), k.min(depth - 1), col.min(n - 1));
            let flip = BitFlip::new(spec.bit, input);
            let old = a.get(row, k);
            let (new, _) = flip.apply(old);
            let v = tgt.get(row, col);
            tgt.set(row, col, grid.quantize(v + (new - old) * b.get(k, col)));
            FaultOutcome { old, new }
        }
        FaultSite::OperandB { k, col } => {
            let (k, col) = (k.min(depth - 1), col.min(n - 1));
            let flip = BitFlip::new(spec.bit, input);
            let old = b.get(k, col);
            let (new, _) = flip.apply(old);
            let delta = new - old;
            for i in 0..rows {
                let v = tgt.get(i, col);
                tgt.set(i, col, grid.quantize(v + a.get(i, k) * delta));
            }
            FaultOutcome { old, new }
        }
    }
}

/// Where the upset strikes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum InjectTarget {
    /// A stored element of the operand matrix B (weights/activations in
    /// memory) — the SEU corrupts the multiply's *input* after checksum
    /// encoding; the error propagates to one output column. This is the
    /// configuration that reproduces Table 8's detection-rate ladder
    /// (bit-7 flips change B elements by ~|b|, far below row thresholds;
    /// bit-14 flips overflow unit-scale operands to Inf — 100% caught).
    InputB,
    /// A stored element of the output C (compute/output-register upset).
    OutputC,
}

/// Configuration of a detection-rate campaign (Tables 8/9).
#[derive(Debug, Clone)]
pub struct CampaignConfig {
    /// GEMM shape (M, K, N) — Table 8 uses (128, 1024, 256).
    pub shape: (usize, usize, usize),
    /// Operand distribution.
    pub dist: Distribution,
    /// Accumulation model (precision under test).
    pub model: AccumModel,
    /// Bit positions to test.
    pub bits: Vec<u32>,
    /// Injections per bit position.
    pub trials_per_bit: usize,
    /// Fresh operand matrices every this many trials (amortizes the GEMM
    /// across injections; each injection targets a fresh random site).
    pub trials_per_matrix: usize,
    /// Verify online (accumulator) or offline (stored output).
    pub online: bool,
    /// Injection target (see [`InjectTarget`]).
    pub target: InjectTarget,
    /// Override the threshold e_max (None = context default). Table 8
    /// uses the FTAN-GEMM deployment value 1e-3 (FP16-level, §3.6
    /// practical recommendations), which reproduces the paper's
    /// per-distribution detection rates.
    pub emax_override: Option<crate::calibrate::EmaxModel>,
    /// Base RNG seed; trials use deterministic substreams.
    pub seed: u64,
}

impl CampaignConfig {
    /// Table 8 configuration for one distribution: BF16 operands, fused
    /// (FP32-accumulator) verification with the FTAN-GEMM deployment
    /// e_max of 1e-3 (FP16-level — §3.6's practical recommendation),
    /// upsets striking stored B elements. This is the configuration whose
    /// per-distribution detection ladder matches the paper's Table 8.
    pub fn table8(dist: Distribution, trials_per_bit: usize) -> CampaignConfig {
        CampaignConfig {
            shape: (128, 1024, 256),
            dist,
            model: AccumModel::wide(Precision::Bf16),
            bits: (7..=14).collect(),
            trials_per_bit,
            trials_per_matrix: 64,
            online: true,
            target: InjectTarget::InputB,
            emax_override: Some(crate::calibrate::EmaxModel::Constant(1e-3)),
            seed: 0x7AB1E8,
        }
    }
}

/// Per-bit campaign outcome.
#[derive(Debug, Clone, Copy)]
pub struct BitResult {
    /// Bit position tested.
    pub bit: u32,
    /// Injection trials performed.
    pub trials: usize,
    /// Trials where the fault was detected.
    pub detected: usize,
    /// Detected trials whose column was correctly localized.
    pub localized: usize,
    /// Trials where the flip produced a value identical after requantize
    /// (impossible for true bit flips, kept as a sanity counter).
    pub no_effect: usize,
    /// Detected trials among the 0→1 (amplifying) flips.
    pub detected_0to1: usize,
    /// Trials whose flip direction was 0→1.
    pub trials_0to1: usize,
}

impl BitResult {
    /// Detection rate in percent (Table 8's DR column).
    pub fn detection_rate(&self) -> f64 {
        if self.trials == 0 {
            0.0
        } else {
            100.0 * self.detected as f64 / self.trials as f64
        }
    }
}

/// A detection-rate campaign over bit positions.
pub struct Campaign {
    /// The campaign's configuration.
    pub config: CampaignConfig,
}

/// Outcome of one injection trial.
struct Trial {
    dir: FlipDirection,
    no_effect: bool,
    detected: bool,
    localized: bool,
}

/// Outcome of a whole campaign plus the clean-run false positive count.
#[derive(Debug, Clone)]
pub struct CampaignResult {
    /// Per-bit results in the configured bit order.
    pub bits: Vec<BitResult>,
    /// Clean (uninjected) rows verified for the FPR sweep.
    pub clean_rows_checked: usize,
    /// Clean rows that flagged — must be zero for a sound threshold.
    pub false_positives: usize,
}

impl Campaign {
    /// Build a campaign from its configuration.
    pub fn new(config: CampaignConfig) -> Campaign {
        Campaign { config }
    }

    /// Run with the given threshold algorithm.
    pub fn run(&self, threshold: &dyn crate::threshold::Threshold) -> CampaignResult {
        use crate::abft::encode::ChecksumEncoding;
        use crate::abft::verify::{check_row, localize, weight_vector, Localization};
        use crate::gemm::GemmEngine;
        use crate::threshold::ThresholdContext;

        let cfg = &self.config;
        let (m, k, n) = cfg.shape;
        let engine = GemmEngine::new(cfg.model);
        let mut ctx = if cfg.online {
            ThresholdContext::online(cfg.model)
        } else {
            ThresholdContext::offline(cfg.model)
        };
        if let Some(emax) = cfg.emax_override {
            ctx = ctx.with_emax(emax);
        }
        let grid = if cfg.online { cfg.model.work } else { cfg.model.out };
        let weights = weight_vector(n);

        let mut results: Vec<BitResult> = cfg
            .bits
            .iter()
            .map(|&bit| BitResult {
                bit,
                trials: 0,
                detected: 0,
                localized: 0,
                no_effect: 0,
                detected_0to1: 0,
                trials_0to1: 0,
            })
            .collect();
        let mut clean_rows_checked = 0usize;
        let mut false_positives = 0usize;

        let matrices_needed =
            (cfg.trials_per_bit + cfg.trials_per_matrix - 1) / cfg.trials_per_matrix;
        for mat_idx in 0..matrices_needed {
            let mut rng = Xoshiro256pp::from_stream(cfg.seed, mat_idx as u64);
            let a = Matrix::sample_in(m, k, &cfg.dist, cfg.model.input, &mut rng);
            let b = Matrix::sample_in(k, n, &cfg.dist, cfg.model.input, &mut rng);
            let enc = if cfg.online {
                ChecksumEncoding::encode_b_wide(&b, &engine)
            } else {
                ChecksumEncoding::encode_b(&b, &engine)
            };
            let out = engine.matmul_mixed(&a, &enc.b_encoded, enc.wide_cols());
            let src = if cfg.online { &out.acc } else { &out.c };
            let (c, cr1, cr2) = enc.split_product(src);
            let (acc, _, _) = enc.split_product(&out.acc);
            let thresholds = threshold.thresholds(&a, &b, &ctx);

            // FPR sweep on the clean product (every row, once per matrix).
            for i in 0..m {
                let rc = check_row(c.row(i), cr1[i], cr2[i], thresholds[i], &engine, &weights);
                clean_rows_checked += 1;
                if rc.flagged {
                    false_positives += 1;
                }
            }

            // Injection trials for each bit.
            let trials_this_matrix = cfg
                .trials_per_matrix
                .min(cfg.trials_per_bit - mat_idx * cfg.trials_per_matrix);
            for (bi, &bit) in cfg.bits.iter().enumerate() {
                for t in 0..trials_this_matrix {
                    let mut trng = Xoshiro256pp::from_stream(
                        cfg.seed ^ 0xB17F11F,
                        ((mat_idx * cfg.bits.len() + bi) * cfg.trials_per_matrix + t) as u64,
                    );
                    let r = match cfg.target {
                        InjectTarget::OutputC => {
                            // SEU in the stored output: one row affected.
                            let flip = BitFlip::new(bit, grid);
                            let site = InjectionSite {
                                row: trng.uniform_u64(m as u64) as usize,
                                col: trng.uniform_u64(n as u64) as usize,
                            };
                            let mut row_data = c.row(site.row).to_vec();
                            let old = row_data[site.col];
                            let (new, dir) = flip.apply(old);
                            row_data[site.col] = new;
                            let rc = check_row(
                                &row_data,
                                cr1[site.row],
                                cr2[site.row],
                                thresholds[site.row],
                                &engine,
                                &weights,
                            );
                            let localized = rc.flagged
                                && matches!(
                                    localize(rc.d1, rc.d2, n, 0.45),
                                    Localization::Column(j) if j == site.col
                                );
                            Trial { dir, no_effect: new == old, detected: rc.flagged, localized }
                        }
                        InjectTarget::InputB => {
                            // SEU in a stored B element (memory upset in a
                            // weight/activation): the checksums were encoded
                            // from the clean B, so the corrupted column j of
                            // C disagrees with them. Every row is perturbed
                            // by a_ik·δ; detection = any row flags.
                            let flip = BitFlip::new(bit, cfg.model.input);
                            let bk = trng.uniform_u64(k as u64) as usize;
                            let bj = trng.uniform_u64(n as u64) as usize;
                            let old_b = b.get(bk, bj);
                            let (new_b, dir) = flip.apply(old_b);
                            let delta = new_b - old_b;
                            let mut detected = false;
                            let mut localized = false;
                            let mut row_buf = vec![0.0; n];
                            for i in 0..m {
                                row_buf.copy_from_slice(c.row(i));
                                // perturb via the FP32 accumulator, then
                                // re-round to the verified grid
                                let perturbed = acc.get(i, bj) + a.get(i, bk) * delta;
                                row_buf[bj] = grid.quantize(perturbed);
                                let rc = check_row(
                                    &row_buf,
                                    cr1[i],
                                    cr2[i],
                                    thresholds[i],
                                    &engine,
                                    &weights,
                                );
                                if rc.flagged {
                                    detected = true;
                                    if matches!(
                                        localize(rc.d1, rc.d2, n, 0.45),
                                        Localization::Column(j) if j == bj
                                    ) {
                                        localized = true;
                                    }
                                    break;
                                }
                            }
                            Trial { dir, no_effect: delta == 0.0, detected, localized }
                        }
                    };
                    let br = &mut results[bi];
                    br.trials += 1;
                    if r.dir == FlipDirection::ZeroToOne {
                        br.trials_0to1 += 1;
                    }
                    if r.no_effect {
                        br.no_effect += 1;
                    }
                    if r.detected {
                        br.detected += 1;
                        if r.dir == FlipDirection::ZeroToOne {
                            br.detected_0to1 += 1;
                        }
                        if r.localized {
                            br.localized += 1;
                        }
                    }
                }
            }
        }
        CampaignResult { bits: results, clean_rows_checked, false_positives }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::threshold::VabftThreshold;

    #[test]
    fn flip_directions_and_fields() {
        let f = BitFlip::new(14, Precision::Bf16);
        assert!(f.is_exponent_bit());
        assert!(!f.is_sign_bit());
        let s = BitFlip::new(15, Precision::Bf16);
        assert!(s.is_sign_bit());
        let (v, dir) = s.apply(2.0);
        assert_eq!(v, -2.0);
        assert_eq!(dir, FlipDirection::ZeroToOne);
        let m = BitFlip::new(0, Precision::Bf16);
        assert!(!m.is_exponent_bit());
    }

    #[test]
    fn exponent_flip_is_large() {
        // Bit 13 (second-highest exponent bit) flip on 1.5: exponent 127 =
        // 0111_1111 → flipping bit 6 of the field (value 64) gives 2^-64 scale.
        let f = BitFlip::new(13, Precision::Bf16);
        let (v, _) = f.apply(1.5);
        assert!(v == 1.5 * 2.0f64.powi(-64), "{v}");
    }

    #[test]
    fn inject_mutates_matrix() {
        let mut c = Matrix::from_fn(4, 4, |_, _| 1.0);
        let (old, new, _) =
            inject(&mut c, InjectionSite { row: 1, col: 2 }, BitFlip::new(7, Precision::Bf16));
        assert_eq!(old, 1.0);
        assert_eq!(new, 0.5); // exp LSB of 1.0 is 1 → flips to 0 → 0.5
        assert_eq!(c.get(1, 2), 0.5);
    }

    #[test]
    fn small_campaign_detects_high_exponent_bits() {
        let mut cfg = CampaignConfig::table8(Distribution::normal_1_1(), 16);
        cfg.shape = (16, 128, 32); // shrink for test speed
        cfg.trials_per_matrix = 16;
        let res = Campaign::new(cfg.clone()).run(&VabftThreshold::default());
        assert_eq!(res.false_positives, 0, "FPR must be zero");
        // Amplifying (0→1) flips in the top exponent bits must always be
        // caught; the exponent MSB (bit 14) overflows unit-scale operands
        // to Inf in either direction.
        for br in res.bits.iter().filter(|b| b.bit >= 10) {
            if br.trials_0to1 > 0 {
                assert_eq!(
                    br.detected_0to1, br.trials_0to1,
                    "bit {}: 0→1 DR {}/{}",
                    br.bit, br.detected_0to1, br.trials_0to1
                );
            }
        }
        // Bit 14 (exp MSB): 0→1 overflows to Inf (always caught); the few
        // 1→0 flips on N(1,1)'s |b| ≥ 2 tail produce ~|b|-sized errors
        // that can sit near the threshold at this tiny test shape.
        let b14 = res.bits.iter().find(|b| b.bit == 14).unwrap();
        assert!(
            b14.detection_rate() >= 80.0,
            "bit 14 (exp MSB): DR {}%",
            b14.detection_rate()
        );

        // Output-register injection variant (offline: the BF16 bit
        // positions address the stored C encoding): top bits flag too.
        cfg.target = InjectTarget::OutputC;
        cfg.online = false;
        cfg.emax_override = None;
        let res2 = Campaign::new(cfg).run(&VabftThreshold::default());
        assert_eq!(res2.false_positives, 0);
        for br in res2.bits.iter().filter(|b| b.bit >= 11) {
            if br.trials_0to1 > 0 {
                assert_eq!(br.detected_0to1, br.trials_0to1, "bit {} (OutputC)", br.bit);
            }
        }
    }

    #[test]
    fn apply_fault_site_semantics() {
        // 2×3 product of ones-operands, encoded width 3 data + 2 checksum
        // columns; acc == c (identity grids) keeps the arithmetic obvious.
        let a = Matrix::from_fn(2, 4, |_, _| 1.0);
        let b = Matrix::from_fn(4, 3, |_, _| 1.0);
        let enc = Matrix::from_fn(2, 5, |_, j| if j < 3 { 4.0 } else { 12.0 });
        let mut out = GemmOutput { c: enc.clone(), acc: enc.clone() };

        // Output flip: exactly one acc element changes (sign bit: 4 → −4).
        let o = apply_fault(
            &FaultSpec::output(1, 2, 63),
            true,
            Precision::F64,
            Precision::F64,
            &a,
            &b,
            &mut out,
        );
        assert_eq!((o.old, o.new), (4.0, -4.0));
        assert_eq!(out.acc.get(1, 2), -4.0);
        assert_eq!(out.acc.get(0, 2), 4.0);
        assert_eq!(out.c.get(1, 2), 4.0, "offline matrix untouched by online flip");

        // Checksum flip lands in column N, not the data columns.
        let mut out = GemmOutput { c: enc.clone(), acc: enc.clone() };
        let o = apply_fault(
            &FaultSpec::checksum(0, 63),
            true,
            Precision::F64,
            Precision::F64,
            &a,
            &b,
            &mut out,
        );
        assert_eq!((o.old, o.new), (12.0, -12.0));
        assert_eq!(out.acc.get(0, 3), -12.0);
        assert!((0..3).all(|j| out.acc.get(0, j) == 4.0));

        // OperandA (transient): one element perturbed by δ_a · b_kj = −2·1.
        let mut out = GemmOutput { c: enc.clone(), acc: enc.clone() };
        let o = apply_fault(
            &FaultSpec::operand_a(0, 1, 1, 63),
            true,
            Precision::F64,
            Precision::F64,
            &a,
            &b,
            &mut out,
        );
        assert_eq!(o.delta(), -2.0);
        assert_eq!(out.acc.get(0, 1), 2.0);
        assert_eq!(out.acc.get(0, 0), 4.0);

        // OperandB (persistent): every row of the struck column perturbed
        // by a_ik · δ_b = 1·(−2).
        let mut out = GemmOutput { c: enc.clone(), acc: enc };
        let o = apply_fault(
            &FaultSpec::operand_b(2, 1, 63),
            true,
            Precision::F64,
            Precision::F64,
            &a,
            &b,
            &mut out,
        );
        assert_eq!(o.delta(), -2.0);
        assert_eq!(out.acc.get(0, 1), 2.0);
        assert_eq!(out.acc.get(1, 1), 2.0);
        assert_eq!(out.acc.get(0, 0), 4.0);
    }

    #[test]
    fn fault_site_classes() {
        assert_eq!(FaultSpec::output(0, 0, 1).site.class(), SiteClass::Output);
        assert_eq!(FaultSpec::operand_a(0, 0, 0, 1).site.class(), SiteClass::OperandA);
        assert_eq!(FaultSpec::operand_b(0, 0, 1).site.class(), SiteClass::OperandB);
        assert_eq!(FaultSpec::checksum(0, 1).site.class(), SiteClass::Checksum);
        assert_eq!(SiteClass::ALL.len(), 4);
        assert_eq!(SiteClass::Checksum.name(), "checksum");
    }

    #[test]
    fn campaign_is_deterministic() {
        let mut cfg = CampaignConfig::table8(Distribution::uniform_01(), 8);
        cfg.shape = (8, 64, 16);
        cfg.trials_per_matrix = 8;
        let r1 = Campaign::new(cfg.clone()).run(&VabftThreshold::default());
        let r2 = Campaign::new(cfg).run(&VabftThreshold::default());
        for (a, b) in r1.bits.iter().zip(&r2.bits) {
            assert_eq!(a.detected, b.detected);
            assert_eq!(a.trials, b.trials);
        }
    }
}
