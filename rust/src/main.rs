//! `vabft` — command-line front end for the V-ABFT fault-tolerant GEMM
//! library.
//!
//! ```text
//! vabft calibrate  [--platform cpu|gpu|npu] [--precision fp32] [--trials N] [--online]
//! vabft campaign   [--quick|--full|--smoke] [--seed S] [--workers W] [--shards N]
//!                  [--json FILE] [--localize-tol T]
//!                  # deterministic campaign grid: precision x strategy x dist x
//!                  # site x bit x verify point, plus the multi-fault axis
//!                  # (simultaneous flips x burst pattern x encoding mode) and
//!                  # the protection-plan axis (every planner scheme x
//!                  # precision, recall / FP / bitwise-recovery gated);
//!                  # writes BENCH_campaign.json and exits non-zero if a
//!                  # detection-quality gate fails, grid-mode corrected-
//!                  # without-recompute coverage does not beat the single-
//!                  # checksum baseline, or a plan-axis gate breaks
//! vabft serve-replay
//!                  [--family llama-7b|gpt2|vit-b32|mixed] [--scale S] [--layers L]
//!                  [--batch M] [--passes P] [--concurrency C] [--seed S]
//!                  [--shards 1,2,4] [--workers W] [--partition contiguous|interleaved]
//!                  [--steal] [--fused] [--plan auto|uniform] [--smoke]
//!                  [--json FILE] [--precision bf16]
//!                  # replay deterministic transformer-layer traces through the
//!                  # sharded coordinator; --fused selects the in-kernel (GEMM
//!                  # epilogue) verify point for every request; --plan auto adds
//!                  # a planner-driven arm per shard count (cost-model scheme
//!                  # per layer) that must reproduce the uniform fingerprint
//!                  # bit-for-bit; exits non-zero if any arm's output
//!                  # fingerprint diverges from the baseline
//! vabft serve-replay --open-loop
//!                  [--families llama-7b,gpt2,vit-b32] [--requests N] [--rate R]
//!                  [--arrival poisson|bursty|diurnal] [--slo MS] [--fault-every N]
//!                  [--shards 1,2,4] [--workers W] [--partition P] [--steal]
//!                  [--fused] [--smoke] [--json FILE] [--precision bf16] [--seed S]
//!                  # open-loop traffic: seeded arrivals over a mixed-family
//!                  # trace, bounded-queue admission (load-shed, never block),
//!                  # p50/p99/p999 + SLO attainment; exits non-zero if the
//!                  # deep-queue fingerprint ladder diverges across shard
//!                  # counts or severity-aware recovery downgrades a detection
//! vabft campaign --table8
//!                  [--precision bf16] [--dist n11|nz|u|u01|trunc] [--trials N] [--offline]
//!                  # legacy single-configuration Table 8 bit ladder
//! vabft tightness  [--precision fp32] [--sizes 128,256,512] [--trials N]
//! vabft gemm       [--m 512 --k 512 --n 512] [--strategy seq|fma|pairwise] [--reps R]
//!                  [--threads T] [--mc M --kc K --nc N] [--mr R --nr C]
//!                  [--split contiguous|interleaved] [--simd auto|scalar|avx2|avx512|neon]
//!                  [--manifest FILE]
//!                  # packed/unpacked engines vs naive kernel (bitwise-checked);
//!                  # engine flags not given explicitly come from the tuning
//!                  # manifest (if one exists) via EngineConfig::from_args
//! vabft autotune   [--smoke|--quick|--full] [--seed S] [--manifest FILE] [--gate]
//!                  # search (mc,kc,nc) x (mr,nr) x threads x split x simd per
//!                  # shape class (transformer-layer traces + campaign grid
//!                  # shapes), bitwise-check every candidate against the scalar
//!                  # serial engine, persist winners to the tuning manifest
//!                  # that gemm / serve-replay / the coordinator load at
//!                  # startup; --gate re-measures tuned vs untuned default and
//!                  # exits non-zero if the tuned schedule loses
//! vabft gemm --prepared
//!                  [--m 8 --k 512 --n 512] [--precision bf16] [--reps R]
//!                  [--block-k B] [--offline] [--threads T]
//!                  [--encoding row|rowcol|grid] [--localize-tol T]
//!                  # weight-stationary FT-GEMM: cold encode-per-call vs
//!                  # PreparedWeights warm path (bitwise-checked);
//!                  # --encoding adds A-side column checksums (rowcol) or
//!                  # grid peeling decode (grid)
//! vabft artifacts  [--dir artifacts]     # list AOT artifacts
//! vabft info                             # e_max table, subcommands
//! ```

use vabft::calibrate::{CalibrationProtocol, EmaxTable, Platform};
use vabft::cli::Args;
use vabft::fp::Precision;
use vabft::inject::{Campaign, CampaignConfig};
use vabft::report::{pct, ratio, sci, Table};
use vabft::rng::Distribution;
use vabft::threshold::{AabftThreshold, Threshold, ThresholdContext, VabftThreshold};

fn main() {
    let args = Args::parse();
    match args.subcommand.as_deref() {
        Some("calibrate") => cmd_calibrate(&args),
        Some("campaign") => cmd_campaign(&args),
        Some("serve-replay") => cmd_serve_replay(&args),
        Some("tightness") => cmd_tightness(&args),
        Some("gemm") => cmd_gemm(&args),
        Some("autotune") => cmd_autotune(&args),
        Some("artifacts") => cmd_artifacts(&args),
        Some("info") | None => cmd_info(),
        Some(other) => {
            eprintln!("unknown subcommand '{other}'");
            eprintln!(
                "usage: vabft [calibrate|campaign|serve-replay|tightness|gemm|autotune|\
                 artifacts|info] [--flags]"
            );
            std::process::exit(2);
        }
    }
}

fn parse_precision(args: &Args, default: Precision) -> Precision {
    match args.opt("precision") {
        None => default,
        Some(s) => Precision::parse(s).unwrap_or_else(|| {
            eprintln!("unknown precision '{s}'");
            std::process::exit(2);
        }),
    }
}

fn parse_platform(args: &Args) -> Platform {
    match args.opt("platform").unwrap_or("gpu") {
        "cpu" => Platform::Cpu,
        "gpu" => Platform::Gpu,
        "npu" => Platform::Npu,
        other => {
            eprintln!("unknown platform '{other}'");
            std::process::exit(2);
        }
    }
}

fn parse_dist(args: &Args) -> Distribution {
    match args.opt("dist").unwrap_or("n11") {
        "n11" => Distribution::normal_1_1(),
        "nz" => Distribution::near_zero_normal(),
        "u" => Distribution::uniform_pm1(),
        "u01" => Distribution::uniform_01(),
        "trunc" => Distribution::truncated_normal(),
        other => {
            eprintln!("unknown distribution '{other}' (n11|nz|u|u01|trunc)");
            std::process::exit(2);
        }
    }
}

fn cmd_calibrate(args: &Args) {
    let platform = parse_platform(args);
    let precision = parse_precision(args, Precision::F32);
    let online = args.flag("online");
    let model = platform.model_for(precision);
    let proto = CalibrationProtocol {
        trials_per_size: args.opt_or("trials", 10),
        ..Default::default()
    };
    println!(
        "calibrating {} / {} (strategy {:?}, online={online})…",
        platform.name(),
        precision,
        model.strategy
    );
    let res = proto.run(model, online);
    let mut t = Table::new(
        &format!("e_max calibration — {} {}", platform.name(), precision),
        &["N", "e_max", "e_max/u", "mean rel", "trials"],
    );
    let u = if online { model.work } else { model.out }.unit_roundoff();
    for p in &res.points {
        t.row(vec![
            p.n.to_string(),
            sci(p.emax),
            format!("{:.1}", p.emax / u),
            sci(p.mean_rel),
            p.trials.to_string(),
        ]);
    }
    t.print();
    println!(
        "fitted law: {}   CV {:.1}%   R²(√N) {:.2}",
        res.fitted.label(),
        res.cv * 100.0,
        res.r2_sqrt_n
    );
    println!(
        "Table 7 recommended: {}",
        EmaxTable::recommended(platform, precision).label()
    );
}

/// The deterministic campaign grid engine (default), or the legacy
/// Table 8 bit-ladder with `--table8`.
///
/// Grid mode sweeps precision × strategy × distribution × injection site
/// × bit class × verification point from one seed, executes every trial
/// through the coordinator, prints the paper-shaped tables and writes
/// `BENCH_campaign.json`. Exits non-zero when a detection-quality gate
/// fails (above-threshold recall < 1.0 or any clean false positive) —
/// the CI contract.
fn cmd_campaign(args: &Args) {
    if args.flag("table8") {
        return cmd_campaign_table8(args);
    }
    use vabft::campaign::{self, GridConfig};

    let seed = args.opt_or("seed", 0xCA4Au64);
    let mut cfg = if args.flag("full") {
        GridConfig::full(seed)
    } else if args.flag("smoke") {
        GridConfig::smoke(seed)
    } else {
        GridConfig::quick(seed)
    };
    // Localization acceptance tolerance for the multi-fault axis (see
    // `VerifyPolicy::localize_tol` for the derivation of the 0.45
    // default).
    cfg.localize_tol = args.opt_or("localize-tol", cfg.localize_tol);
    if !(0.0 < cfg.localize_tol && cfg.localize_tol < 0.5) {
        eprintln!(
            "error: --localize-tol {} out of range (0, 0.5): at 0.5 two adjacent \
             columns become indistinguishable",
            cfg.localize_tol
        );
        std::process::exit(2);
    }
    let workers = args.opt_or("workers", 4usize);
    let shards = args.opt_or("shards", 1usize);
    println!(
        "campaign grid: mode={} seed=0x{seed:x} workers={workers} shards={shards} \
         ({} precisions x {} strategies x {} dists x {} sites x {} bits)",
        cfg.mode,
        cfg.precisions.len(),
        cfg.strategies.len(),
        cfg.dists.len(),
        cfg.sites.len(),
        cfg.bit_classes.len(),
    );
    let t0 = std::time::Instant::now();
    let outcome = campaign::run_sharded(&cfg, workers, shards);
    let elapsed = t0.elapsed();
    for t in campaign::render_tables(&outcome) {
        t.print();
    }
    println!("coordinator groups:");
    for line in &outcome.group_metrics {
        println!("  {line}");
    }
    println!();
    let doc = campaign::to_doc(&outcome);
    // An explicit --json FILE wins over the env fallback; without it the
    // document lands at the repo root (or $VABFT_CAMPAIGN_JSON).
    let (filename, written) = match args.opt("json") {
        Some(f) => (f, doc.write_to(f)),
        None => ("BENCH_campaign.json", doc.write("BENCH_campaign.json", "VABFT_CAMPAIGN_JSON")),
    };
    match written {
        Ok(path) => println!(
            "wrote {} ({} cells, {} trials) in {:.1}s",
            path.display(),
            outcome.cells.len(),
            outcome.total_trials(),
            elapsed.as_secs_f64()
        ),
        Err(e) => {
            eprintln!("failed to write {filename}: {e}");
            std::process::exit(1);
        }
    }
    if !outcome.gates_hold() {
        eprintln!(
            "campaign gate FAILED: recall {}/{} above-threshold, {} false positives \
             over {} clean rows",
            outcome.total_detected_above(),
            outcome.total_above(),
            outcome.total_false_positives(),
            outcome.total_clean_rows(),
        );
        std::process::exit(1);
    }
    println!(
        "gates OK: {}/{} above-threshold faults detected (recall 1.0), \
         0/{} clean rows false-positive",
        outcome.total_detected_above(),
        outcome.total_above(),
        outcome.total_clean_rows(),
    );
    if !outcome.severity_no_downgrade() {
        eprintln!(
            "campaign gate FAILED: severity-aware recovery downgraded detection \
             ({} severity false positives; waiving must change repair, never recall)",
            outcome.severity_false_positives,
        );
        std::process::exit(1);
    }
    println!(
        "severity gate OK: per-cell detection identical under waiving \
         ({} trials waived sub-noise residuals, 0 downgrades, 0 false positives)",
        outcome.total_severity_waived(),
    );
    if !outcome.multi_fault_gates_hold() {
        eprintln!(
            "campaign gate FAILED: multi-fault axis broke a detection gate \
             ({} false positives over {} clean rows; recall must stay 1.0)",
            outcome.multi_false_positives, outcome.multi_clean_rows,
        );
        std::process::exit(1);
    }
    if !outcome.grid_exceeds_baseline() {
        eprintln!(
            "campaign gate FAILED: grid-mode corrected-without-recompute coverage \
             ({} grid vs {} row-only over {} multi-fault trials) does not strictly \
             exceed the single-checksum baseline",
            outcome.multi_corrected_no_recompute(vabft::abft::EncodingMode::Grid),
            outcome.multi_corrected_no_recompute(vabft::abft::EncodingMode::RowOnly),
            outcome.total_multi_trials(),
        );
        std::process::exit(1);
    }
    println!(
        "multi-fault gate OK: {} trials, grid corrected-without-recompute {} > \
         row-only baseline {} (0 false positives)",
        outcome.total_multi_trials(),
        outcome.multi_corrected_no_recompute(vabft::abft::EncodingMode::Grid),
        outcome.multi_corrected_no_recompute(vabft::abft::EncodingMode::RowOnly),
    );
    if !outcome.plan_gates_hold() {
        eprintln!(
            "campaign gate FAILED: protection-plan axis broke a detection gate \
             ({}/{} injected faults detected, {} false positives over {} clean rows; \
             every planner scheme must hold recall 1.0 with zero FP)",
            outcome.total_plan_detected(),
            outcome.total_plan_trials(),
            outcome.plan_false_positives,
            outcome.plan_clean_rows,
        );
        std::process::exit(1);
    }
    if !outcome.replication_bitwise_equal() {
        eprintln!(
            "campaign gate FAILED: replication recovery produced an output that is \
             not bitwise-equal to the fault-free reference (recomputation from clean \
             inputs admits no tolerance)"
        );
        std::process::exit(1);
    }
    println!(
        "plan gate OK: {} scheme cells, {}/{} injected faults detected through \
         planned dispatch, 0/{} clean rows false-positive, replication recovery \
         bitwise-equal",
        outcome.plan_cells.len(),
        outcome.total_plan_detected(),
        outcome.total_plan_trials(),
        outcome.plan_clean_rows,
    );
}

/// Legacy single-configuration detection-rate ladder (paper Table 8).
fn cmd_campaign_table8(args: &Args) {
    let precision = parse_precision(args, Precision::Bf16);
    let dist = parse_dist(args);
    let trials = args.opt_or("trials", 512usize);
    let mut cfg = CampaignConfig::table8(dist.clone(), trials);
    cfg.model = Platform::Npu.model_for(precision);
    // table8 defaults to fused (online) verification with the deployment
    // e_max; --offline switches to post-hoc verification, whose threshold
    // must revert to the output-precision default.
    if args.flag("offline") {
        cfg.online = false;
        cfg.emax_override = None;
    }
    if let Some(shape) = args.opt("shape") {
        let d: Vec<usize> = shape.split(',').map(|s| s.parse().unwrap()).collect();
        assert_eq!(d.len(), 3, "--shape M,K,N");
        cfg.shape = (d[0], d[1], d[2]);
    }
    println!(
        "campaign: {} {} shape {:?} trials/bit {} online={}",
        precision,
        dist.label(),
        cfg.shape,
        trials,
        cfg.online
    );
    let res = Campaign::new(cfg).run(&VabftThreshold::default());
    let mut t = Table::new(
        &format!("Detection rate — {} {}", precision, dist.label()),
        &["bit", "DR %", "localized %", "trials", "0→1 DR %"],
    );
    for b in &res.bits {
        t.row(vec![
            b.bit.to_string(),
            pct(b.detection_rate()),
            pct(100.0 * b.localized as f64 / b.trials.max(1) as f64),
            b.trials.to_string(),
            if b.trials_0to1 > 0 {
                pct(100.0 * b.detected_0to1 as f64 / b.trials_0to1 as f64)
            } else {
                "-".into()
            },
        ]);
    }
    t.print();
    println!(
        "clean rows checked: {}   false positives: {}",
        res.clean_rows_checked, res.false_positives
    );
}

/// Replay deterministic transformer-layer traces through the sharded
/// coordinator at each requested shard count, assert the output
/// fingerprint is shard-invariant (the differential gate — exits
/// non-zero on divergence), print the throughput ladder, and optionally
/// write the `vabft-serving/v3` document. `--fused` selects the
/// fused-epilogue verify point (detection inside the packed GEMM kernel,
/// [`vabft::abft::VerifyPolicy::fused`]) for every request — outputs and
/// verdicts are bitwise-unchanged, so the fingerprint gate doubles as an
/// end-to-end check of the fused path. `--plan auto` adds the planned
/// arm of the A/B: the arithmetic-intensity planner (cost model seeded
/// from the tuning manifest, calibrated on the trace's own shapes)
/// assigns a protection scheme per layer, and every planned run must
/// reproduce the uniform run's fingerprint bit-for-bit — the default
/// plan vocabulary is schedule-neutral (invariant #9), so divergence is
/// a dispatch bug, not noise.
fn cmd_serve_replay(args: &Args) {
    if args.flag("open-loop") {
        return cmd_serve_replay_open_loop(args);
    }
    use vabft::abft::VerifyPolicy;
    use vabft::coordinator::{CoordinatorConfig, PartitionPolicy};
    use vabft::gemm::{AccumModel, EngineConfig};
    use vabft::planner::{CostModel, Planner, PlannerConfig, ProtectionPlan};
    use vabft::runtime::TuningManifest;
    use vabft::workload::{
        build_trace, replay_doc, run_replay, run_replay_planned, ReplayConfig, ReplayRow,
    };

    let smoke = args.flag("smoke");
    let family =
        args.opt("family").unwrap_or(if smoke { "gpt2" } else { "llama-7b" }).to_string();
    let seed = args.opt_or("seed", 0x5E12u64);
    let mut cfg =
        if smoke { ReplayConfig::smoke(&family, seed) } else { ReplayConfig::quick(&family, seed) };
    cfg.scale = args.opt_or("scale", cfg.scale).max(1);
    cfg.layers = args.opt_or("layers", cfg.layers).max(1);
    cfg.batch = args.opt_or("batch", cfg.batch).max(1);
    cfg.passes = args.opt_or("passes", cfg.passes).max(1);
    cfg.concurrency = args.opt_or("concurrency", cfg.concurrency).max(1);

    let precision = parse_precision(args, Precision::Bf16);
    let model = if precision == Precision::F32 || precision == Precision::F64 {
        AccumModel::gpu_highprec(precision)
    } else {
        AccumModel::wide(precision)
    };
    let workers = args.opt_or("workers", 2usize).max(1);
    let partition = PartitionPolicy::parse(args.opt("partition").unwrap_or("contiguous"))
        .unwrap_or_else(|| {
            eprintln!("unknown partition policy (contiguous|interleaved)");
            std::process::exit(2);
        });
    let steal = args.flag("steal");
    let fused = args.flag("fused");
    let plan_mode = args.opt("plan").unwrap_or("uniform");
    if plan_mode != "auto" && plan_mode != "uniform" {
        eprintln!("unknown --plan '{plan_mode}' (auto|uniform)");
        std::process::exit(2);
    }
    let shard_counts: Vec<usize> = args
        .opt("shards")
        .unwrap_or(if smoke { "1,2" } else { "1,2,4" })
        .split(',')
        .map(|s| {
            s.trim().parse().unwrap_or_else(|_| {
                eprintln!("invalid --shards list '{s}'");
                std::process::exit(2);
            })
        })
        .collect();
    println!(
        "serve-replay: family={family} scale={} layers={} batch={} passes={} \
         concurrency={} seed=0x{seed:x} model={} partition={} steal={steal} fused={fused} \
         plan={plan_mode} workers/shard={workers}",
        cfg.scale,
        cfg.layers,
        cfg.batch,
        cfg.passes,
        cfg.concurrency,
        model.label(),
        partition.name(),
    );

    // One engine configuration for every shard count: CLI overrides plus
    // the tuning manifest (loaded once, here, at startup).
    let engine_cfg = EngineConfig::from_args(args);

    // `--plan auto`: build the protection plan once, before the ladder.
    // The cost model's analytic prior is seeded from the tuning
    // manifest's measured GFLOP/s, then overridden by a calibration pass
    // that times every neutral scheme on each distinct trace shape —
    // scheme choice is measured economics, never a hard-coded rule.
    let plan: Option<ProtectionPlan> = if plan_mode == "auto" {
        use vabft::planner::ProtectionScheme;
        let trace = build_trace(&cfg);
        let mut cost = CostModel::new();
        if let Ok(Some(man)) = TuningManifest::load_default() {
            cost.seed_from_manifest(&man);
        }
        let pcfg = PlannerConfig::default();
        let schemes: Vec<ProtectionScheme> = ProtectionScheme::vocabulary(pcfg.block_k)
            .into_iter()
            .filter(|s| s.is_schedule_neutral())
            .collect();
        let mut shapes: Vec<(usize, usize, usize)> = Vec::new();
        for e in &ProtectionPlan::uniform_for(&trace).entries {
            if !shapes.contains(&(e.m, e.k, e.n)) {
                shapes.push((e.m, e.k, e.n));
            }
        }
        for &(m, k, n) in &shapes {
            cost.calibrate_shape(model, m, k, n, &schemes, pcfg.calibration_reps);
        }
        let p = Planner::new(pcfg, cost).plan_trace(&trace);
        println!("protection plan (auto): {}", p.summary());
        Some(p)
    } else {
        None
    };

    let mut rows: Vec<ReplayRow> = Vec::new();
    let mut t = Table::new(
        "Sharded serving replay",
        &["shards", "plan", "requests", "elapsed", "req/s", "GFLOP/s", "stolen", "speedup", "fp=="],
    );
    let mk_ccfg = |shards: usize| CoordinatorConfig {
        workers,
        queue_depth: (2 * cfg.concurrency).max(16),
        model,
        engine: Some(engine_cfg.clone()),
        shards: shards.max(1),
        partition,
        steal,
        policy: if fused { VerifyPolicy::fused() } else { VerifyPolicy::default() },
        ..Default::default()
    };
    for &shards in &shard_counts {
        // The uniform arm always runs: it is the fingerprint baseline
        // every planned run must match bit-for-bit.
        let report = run_replay(&cfg, mk_ccfg(shards));
        let row = ReplayRow::ladder(
            report,
            rows.first(),
            partition.name(),
            steal,
            workers,
            cfg.concurrency,
        );
        push_replay_row(&mut t, &mut rows, shards, row);
        if let Some(p) = &plan {
            let report = run_replay_planned(&cfg, mk_ccfg(shards), Some(p));
            let row = ReplayRow::ladder(
                report,
                rows.first(),
                partition.name(),
                steal,
                workers,
                cfg.concurrency,
            )
            .with_plan(p.mode.label());
            push_replay_row(&mut t, &mut rows, shards, row);
        }
    }
    t.print();
    if let Some(f) = args.opt("json") {
        let mode = if smoke { "smoke" } else { "custom" };
        match replay_doc(&rows, mode).write_to(f) {
            Ok(p) => println!("wrote {}", p.display()),
            Err(e) => {
                eprintln!("failed to write {f}: {e}");
                std::process::exit(1);
            }
        }
    }
    if rows.iter().any(|r| !r.fingerprint_equal) {
        eprintln!(
            "serve-replay gate FAILED: output fingerprint diverged across shard counts \
             or plan arms (sharding and neutral plan selection must be pure scheduling \
             — invariant #9)"
        );
        std::process::exit(1);
    }
    let faulty: usize = rows.iter().map(|r| r.report.faulty).sum();
    if faulty > 0 {
        eprintln!("serve-replay gate FAILED: {faulty} non-clean verdicts on a clean replay");
        std::process::exit(1);
    }
    println!(
        "gate OK: fingerprint identical across shards {:?} (plan={plan_mode}); \
         all {} responses clean",
        shard_counts,
        rows.iter().map(|r| r.report.requests).sum::<usize>()
    );
}

/// Append one replay-ladder row to the printed table and the collected
/// row set (shared by the uniform and planned arms of `serve-replay`).
fn push_replay_row(
    t: &mut Table,
    rows: &mut Vec<vabft::workload::ReplayRow>,
    shards: usize,
    row: vabft::workload::ReplayRow,
) {
    t.row(vec![
        shards.to_string(),
        row.plan.clone(),
        row.report.requests.to_string(),
        format!("{:?}", row.report.elapsed),
        format!("{:.1}", row.report.rps()),
        format!("{:.2}", row.report.gflops()),
        row.report.stolen.to_string(),
        format!("{:.2}x", row.speedup_vs_baseline),
        if row.fingerprint_equal { "yes".into() } else { "DIVERGED".into() },
    ]);
    rows.push(row);
}

/// Open-loop variant of `serve-replay` (`--open-loop`): seeded arrival
/// processes release a mixed-family trace against the wall clock,
/// admission goes through the bounded non-blocking queue (explicit
/// load-shed verdicts, never a stalled arrival loop), and the report
/// carries p50/p99/p999, shed rate and SLO attainment. Two CI gates:
///
/// * **determinism ladder** — the same `(config, seed)` schedule re-runs
///   at every requested shard count with queues deep enough that nothing
///   sheds (shedding is the one timing-dependent outcome), and the run
///   exits non-zero if any trace or output fingerprint diverges from the
///   baseline shard count;
/// * **severity gate** — a fault-injected schedule replays under
///   always-recompute and severity-aware recovery
///   ([`vabft::abft::VerifyPolicy::with_severity`]); the run exits
///   non-zero if the severity policy downgrades a detection or alters
///   any computed output's bits.
fn cmd_serve_replay_open_loop(args: &Args) {
    use std::time::Duration;
    use vabft::abft::VerifyPolicy;
    use vabft::coordinator::{CoordinatorConfig, PartitionPolicy};
    use vabft::gemm::{AccumModel, EngineConfig};
    use vabft::workload::{replay_doc, run_open_loop, ArrivalModel, OpenLoopConfig, ReplayRow};

    let smoke = args.flag("smoke");
    let seed = args.opt_or("seed", 0x01E2u64);
    let mut cfg = OpenLoopConfig::smoke(seed);
    if let Some(f) = args.opt("families").or_else(|| args.opt("family")) {
        cfg.families = f.split(',').map(|s| s.trim().to_string()).collect();
    }
    cfg.scale = args.opt_or("scale", cfg.scale).max(1);
    cfg.layers = args.opt_or("layers", cfg.layers).max(1);
    cfg.batch = args.opt_or("batch", cfg.batch).max(1);
    cfg.requests = args.opt_or("requests", if smoke { 48 } else { 120 }).max(1);
    cfg.rate = args.opt_or("rate", cfg.rate);
    if !(cfg.rate > 0.0 && cfg.rate.is_finite()) {
        eprintln!("--rate must be a positive requests/second figure");
        std::process::exit(2);
    }
    cfg.arrival = match args.opt("arrival") {
        None => cfg.arrival,
        Some(s) => ArrivalModel::parse(s).unwrap_or_else(|| {
            eprintln!("unknown arrival model '{s}' (poisson|bursty|diurnal)");
            std::process::exit(2);
        }),
    };
    cfg.slo = match args.opt_or("slo", 250u64) {
        0 => None,
        ms => Some(Duration::from_millis(ms)),
    };
    cfg.fault_every = args.opt_or("fault-every", 0usize);

    let precision = parse_precision(args, Precision::Bf16);
    let model = if precision == Precision::F32 || precision == Precision::F64 {
        AccumModel::gpu_highprec(precision)
    } else {
        AccumModel::wide(precision)
    };
    let workers = args.opt_or("workers", 2usize).max(1);
    let partition = PartitionPolicy::parse(args.opt("partition").unwrap_or("contiguous"))
        .unwrap_or_else(|| {
            eprintln!("unknown partition policy (contiguous|interleaved)");
            std::process::exit(2);
        });
    let steal = args.flag("steal");
    let fused = args.flag("fused");
    let base_policy = if fused { VerifyPolicy::fused() } else { VerifyPolicy::default() };
    let shard_counts: Vec<usize> = args
        .opt("shards")
        .unwrap_or(if smoke { "1,2" } else { "1,2,4" })
        .split(',')
        .map(|s| {
            s.trim().parse().unwrap_or_else(|_| {
                eprintln!("invalid --shards list '{s}'");
                std::process::exit(2);
            })
        })
        .collect();
    println!(
        "serve-replay (open loop): families={} requests={} rate={}/s arrival={} \
         slo={:?} fault_every={} seed=0x{seed:x} model={} partition={} steal={steal} \
         fused={fused} workers/shard={workers}",
        cfg.families.join("+"),
        cfg.requests,
        cfg.rate,
        cfg.arrival.name(),
        cfg.slo,
        cfg.fault_every,
        model.label(),
        partition.name(),
    );

    // One engine configuration for every gate run: CLI overrides plus the
    // tuning manifest (loaded once, here, at startup).
    let engine_cfg = EngineConfig::from_args(args);
    let ccfg_for = |shards: usize, policy: VerifyPolicy| CoordinatorConfig {
        workers,
        // The gates run with queues at least as deep as the offered count
        // so nothing sheds: which requests complete is then a pure
        // function of the seed, and the fingerprints are exact.
        queue_depth: cfg.requests,
        model,
        engine: Some(engine_cfg.clone()),
        shards: shards.max(1),
        partition,
        steal,
        policy,
        ..Default::default()
    };

    let mut rows: Vec<ReplayRow> = Vec::new();
    let mut base_fps: Option<(u64, u64)> = None;
    let mut schedule_equal = true;
    let mut output_equal = true;
    let mut t = Table::new(
        "Open-loop serving replay (deep-queue determinism ladder)",
        &["shards", "offered", "admitted", "shed%", "p50", "p99", "p999", "SLO %", "req/s", "fp=="],
    );
    for &shards in &shard_counts {
        let r = run_open_loop(&cfg, ccfg_for(shards, base_policy));
        let (btrace, bout) = *base_fps.get_or_insert((r.trace_fingerprint, r.output_fingerprint));
        schedule_equal &= r.trace_fingerprint == btrace;
        output_equal &= r.output_fingerprint == bout;
        let slo_pct = 100.0 * r.slo_attainment();
        let offered = r.offered;
        let row = ReplayRow::ladder(
            r.replay,
            rows.first(),
            partition.name(),
            steal,
            workers,
            cfg.requests,
        );
        t.row(vec![
            shards.to_string(),
            offered.to_string(),
            row.report.requests.to_string(),
            format!("{:.1}", 100.0 * row.report.shed_rate()),
            format!("{:?}", row.report.p50),
            format!("{:?}", row.report.p99),
            format!("{:?}", row.report.p999),
            format!("{slo_pct:.1}"),
            format!("{:.1}", row.report.rps()),
            if row.fingerprint_equal { "yes".into() } else { "DIVERGED".into() },
        ]);
        rows.push(row);
    }
    t.print();
    if let Some(f) = args.opt("json") {
        let mode = if smoke { "open-loop-smoke" } else { "open-loop" };
        match replay_doc(&rows, mode).write_to(f) {
            Ok(p) => println!("wrote {}", p.display()),
            Err(e) => {
                eprintln!("failed to write {f}: {e}");
                std::process::exit(1);
            }
        }
    }
    if !schedule_equal
        || !output_equal
        || rows.iter().any(|r| !r.fingerprint_equal || r.report.shed > 0)
    {
        eprintln!(
            "serve-replay gate FAILED: open-loop fingerprint diverged across shard \
             counts {shard_counts:?} (schedule_equal={schedule_equal} \
             output_equal={output_equal}; deep queues must never shed)"
        );
        std::process::exit(1);
    }
    println!(
        "gate OK: schedule + output fingerprints identical across shards {:?}; \
         0 of {} offered requests shed",
        shard_counts,
        cfg.requests * shard_counts.len(),
    );

    // Severity gate: the same faulted schedule under always-recompute vs
    // severity-aware recovery. Detection counts and output bits must be
    // identical — waiving may only change *how* a detection is repaired.
    let mut gate_cfg = cfg.clone();
    gate_cfg.fault_every = if cfg.fault_every > 0 { cfg.fault_every } else { 5 };
    let strict = run_open_loop(&gate_cfg, ccfg_for(shard_counts[0], base_policy));
    let lenient = run_open_loop(&gate_cfg, ccfg_for(shard_counts[0], base_policy.with_severity()));
    if strict.faults_detected == 0 {
        eprintln!(
            "serve-replay gate FAILED: fault plan (every {}th request) produced no \
             detections — severity gate is vacuous",
            gate_cfg.fault_every
        );
        std::process::exit(1);
    }
    if lenient.faults_detected != strict.faults_detected
        || lenient.output_fingerprint != strict.output_fingerprint
        || strict.faults_waived != 0
        || lenient.faults_waived + lenient.rows_recomputed != strict.rows_recomputed
    {
        eprintln!(
            "serve-replay gate FAILED: severity-aware recovery downgraded the faulted \
             replay (detections {} vs {}, waived {} vs {}, recomputed {} vs {}, \
             output bits {})",
            strict.faults_detected,
            lenient.faults_detected,
            strict.faults_waived,
            lenient.faults_waived,
            strict.rows_recomputed,
            lenient.rows_recomputed,
            if lenient.output_fingerprint == strict.output_fingerprint {
                "identical"
            } else {
                "DIVERGED"
            },
        );
        std::process::exit(1);
    }
    println!(
        "severity gate OK: {} detections preserved; severity waived {} of {} strict \
         recomputes; output bits identical",
        strict.faults_detected, lenient.faults_waived, strict.rows_recomputed,
    );
}

fn cmd_tightness(args: &Args) {
    use vabft::abft::encode::ChecksumEncoding;
    use vabft::gemm::GemmEngine;
    use vabft::matrix::Matrix;
    use vabft::rng::Xoshiro256pp;

    let precision = parse_precision(args, Precision::F32);
    let trials = args.opt_or("trials", 5usize);
    let sizes: Vec<usize> = args
        .opt("sizes")
        .unwrap_or("128,256,512")
        .split(',')
        .map(|s| s.parse().unwrap())
        .collect();
    let model = Platform::Gpu.model_for(precision);
    let engine = GemmEngine::new(model);
    let ctx = ThresholdContext::offline(model);
    let vab = VabftThreshold::default();
    let aab = AabftThreshold::paper_repro();
    let dist = Distribution::uniform_pm1();

    let mut t = Table::new(
        &format!("Threshold tightness — {} U(-1,1)", precision),
        &["Size", "Actual Diff", "A-ABFT", "V-ABFT", "A-Tight", "V-Tight"],
    );
    for &n in &sizes {
        let mut worst_e = 0.0f64;
        let mut a_th = 0.0;
        let mut v_th = 0.0;
        for trial in 0..trials {
            let mut rng = Xoshiro256pp::from_stream(n as u64, trial as u64);
            let m = n.min(32);
            let a = Matrix::sample_in(m, n, &dist, model.input, &mut rng);
            let b = Matrix::sample_in(n, n, &dist, model.input, &mut rng);
            let enc = ChecksumEncoding::encode_b(&b, &engine);
            let out = engine.matmul_mixed(&a, &enc.b_encoded, enc.wide_cols());
            let (c, cr1, _) = enc.split_product(&out.c);
            for i in 0..m {
                let e = (cr1[i] - engine.reduce(c.row(i))).abs();
                worst_e = worst_e.max(e);
            }
            a_th = aab.thresholds(&a, &b, &ctx)[0];
            v_th = vab.thresholds(&a, &b, &ctx).iter().cloned().fold(0.0, f64::max);
        }
        t.row(vec![
            format!("{n}x{n}"),
            sci(worst_e),
            sci(a_th),
            sci(v_th),
            ratio(a_th / worst_e),
            ratio(v_th / worst_e),
        ]);
    }
    t.print();
}

/// Tiled parallel engine vs the naive reference kernel: wall-clock
/// comparison plus a bitwise equality check (the schedule-preservation
/// invariant, end to end). `ParallelismConfig` comes straight from the
/// CLI flags (`--threads/--mc/--kc/--nc`). With `--prepared`, runs the
/// weight-stationary FT-GEMM comparison instead (see `cmd_gemm_prepared`).
fn cmd_gemm(args: &Args) {
    if args.flag("prepared") {
        return cmd_gemm_prepared(args);
    }
    use vabft::bench_harness::time_once;
    use vabft::gemm::{kernels, tiled, EngineConfig, ReduceStrategy};
    use vabft::rng::Rng;
    use vabft::rng::Xoshiro256pp;

    let m = args.opt_or("m", 512usize);
    let k = args.opt_or("k", 512usize);
    let n = args.opt_or("n", 512usize);
    let reps = args.opt_or("reps", 3usize);
    let strategy = match args.opt("strategy").unwrap_or("seq") {
        "seq" | "sequential" => ReduceStrategy::Sequential,
        "fma" => ReduceStrategy::Fma,
        "pair" | "pairwise" => ReduceStrategy::Pairwise,
        other => {
            eprintln!("unknown strategy '{other}' (seq|fma|pairwise)");
            std::process::exit(2);
        }
    };
    // Flags not given explicitly are filled from the tuning manifest (if
    // one exists) for this exact shape, then from the defaults.
    let par = EngineConfig::from_args(args).resolve_for(m, k, n);
    println!(
        "fp32 GEMM {m}x{k}x{n}, strategy {}, threads {}, tiles (mc {}, kc {}, nc {}), \
         micro (mr {}, nr {}), split {}, simd {}",
        strategy.name(),
        par.threads,
        par.tiles.mc,
        par.tiles.kc,
        par.tiles.nc,
        par.micro.mr,
        par.micro.nr,
        par.split.name(),
        par.simd.resolve().name()
    );

    let mut rng = Xoshiro256pp::seed_from_u64(0xBE);
    let a: Vec<f32> = (0..m * k).map(|_| (rng.next_f64() * 2.0 - 1.0) as f32).collect();
    let b: Vec<f32> = (0..k * n).map(|_| (rng.next_f64() * 2.0 - 1.0) as f32).collect();

    let naive = |a: &[f32], b: &[f32]| kernels::reference_gemm_f32(a, b, m, k, n, strategy);

    let mut t = Table::new(
        "Packed / unpacked engines vs naive kernel",
        &["engine", "best", "speedup"],
    );
    let mut t_naive = std::time::Duration::MAX;
    let mut t_unpacked = std::time::Duration::MAX;
    let mut t_packed = std::time::Duration::MAX;
    let mut c_naive = Vec::new();
    let mut c_unpacked = Vec::new();
    let mut c_packed = Vec::new();
    for _ in 0..reps.max(1) {
        let mut out = Vec::new();
        let d = time_once(|| out = naive(&a, &b));
        t_naive = t_naive.min(d);
        c_naive = out;
        let mut out2 = Vec::new();
        let d2 = time_once(|| out2 = tiled::gemm_unpacked_f32(&a, &b, m, k, n, strategy, &par));
        t_unpacked = t_unpacked.min(d2);
        c_unpacked = out2;
        let mut out3 = Vec::new();
        let d3 = time_once(|| out3 = tiled::gemm_f32(&a, &b, m, k, n, strategy, &par));
        t_packed = t_packed.min(d3);
        c_packed = out3;
    }
    assert_eq!(c_naive, c_unpacked, "schedule invariant violated: unpacked differs");
    assert_eq!(c_naive, c_packed, "schedule invariant violated: packed differs");
    t.row(vec!["naive ikj".into(), format!("{t_naive:?}"), "1.00x".into()]);
    t.row(vec![
        format!("unpacked x{}", par.threads),
        format!("{t_unpacked:?}"),
        format!("{:.2}x", t_naive.as_secs_f64() / t_unpacked.as_secs_f64()),
    ]);
    t.row(vec![
        format!("packed x{}", par.threads),
        format!("{t_packed:?}"),
        format!("{:.2}x", t_naive.as_secs_f64() / t_packed.as_secs_f64()),
    ]);
    t.print();
    println!("bitwise equality: OK ({} elements)", c_naive.len());
}

/// Weight-stationary FT-GEMM comparison: the cold path (checksum encode +
/// B statistics per call) vs the warm path (`PreparedWeights` computed
/// once). Serving-shaped by default: a small activation batch against a
/// large weight matrix. Asserts bitwise-identical outputs and identical
/// verdicts — the prepared path is a pure amortization, never a numerical
/// change.
fn cmd_gemm_prepared(args: &Args) {
    use vabft::abft::{EncodingMode, FtGemm, VerifyGranularity, VerifyPolicy};
    use vabft::bench_harness::time_once;
    use vabft::gemm::{AccumModel, EngineConfig, GemmEngine};
    use vabft::matrix::Matrix;
    use vabft::rng::Xoshiro256pp;

    let m = args.opt_or("m", 8usize);
    let k = args.opt_or("k", 512usize);
    let n = args.opt_or("n", 512usize);
    let reps = args.opt_or("reps", 5usize).max(1);
    let block_k = args.opt_or("block-k", 0usize); // 0 = monolithic
    let precision = parse_precision(args, Precision::Bf16);
    let online = !args.flag("offline");
    let encoding = match args.opt("encoding") {
        None => EncodingMode::RowOnly,
        Some(s) => EncodingMode::parse(s).unwrap_or_else(|| {
            eprintln!("unknown encoding '{s}' (row|rowcol|grid)");
            std::process::exit(2);
        }),
    };
    let model = if precision == Precision::F32 || precision == Precision::F64 {
        AccumModel::gpu_highprec(precision)
    } else {
        AccumModel::wide(precision)
    };
    let mut policy = if online { VerifyPolicy::default() } else { VerifyPolicy::offline() };
    policy.encoding = encoding;
    // Localization acceptance tolerance (see `VerifyPolicy::localize_tol`
    // for the derivation of the 0.45 default).
    policy.localize_tol = args.opt_or("localize-tol", policy.localize_tol);
    if !(0.0 < policy.localize_tol && policy.localize_tol < 0.5) {
        eprintln!(
            "error: --localize-tol {} out of range (0, 0.5): at 0.5 two adjacent \
             columns become indistinguishable",
            policy.localize_tol
        );
        std::process::exit(2);
    }
    let ecfg = EngineConfig::from_args(args);
    // Cold and warm legs must share one accumulation grouping to compare
    // bitwise; block_k = K is exactly the monolithic parameterization.
    let bk = if block_k == 0 { k.max(1) } else { block_k };
    policy = policy.with_granularity(VerifyGranularity::BlockK(bk));
    let bw = FtGemm::new(
        GemmEngine::with_config(model, ecfg),
        Box::new(VabftThreshold::default()),
        policy,
    );
    println!(
        "weight-stationary FT-GEMM {m}x{k}x{n}, model {}, online={online}, encoding={}, \
         block_k={}",
        model.label(),
        encoding.name(),
        if block_k == 0 { "K (monolithic)".to_string() } else { block_k.to_string() }
    );

    let mut rng = Xoshiro256pp::seed_from_u64(0xFEED);
    let d = vabft::rng::Distribution::normal_1_1();
    let a = Matrix::sample_in(m, k, &d, model.input, &mut rng);
    let b = Matrix::sample_in(k, n, &d, model.input, &mut rng);

    // Prepare once (timed separately — the registration cost).
    let mut prepared = None;
    let t_prepare = time_once(|| prepared = Some(bw.prepare(&b)));
    let prepared = prepared.unwrap();

    let mut t_cold = std::time::Duration::MAX;
    let mut t_warm = std::time::Duration::MAX;
    let mut cold = None;
    let mut warm = None;
    for _ in 0..reps {
        let mut out = None;
        let dur = time_once(|| out = Some(bw.multiply(&a, &b).unwrap()));
        t_cold = t_cold.min(dur);
        cold = out;
        let mut out2 = None;
        let dur2 = time_once(|| out2 = Some(bw.multiply_prepared(&a, &prepared, None).unwrap()));
        t_warm = t_warm.min(dur2);
        warm = out2;
    }
    let (cold, warm) = (cold.unwrap(), warm.unwrap());
    assert_eq!(cold.c.data(), warm.c.data(), "warm path must be bitwise-identical");
    assert_eq!(cold.report.verdict, warm.report.verdict, "verdicts must match");

    let mut t = Table::new(
        "Cold (encode per call) vs warm (PreparedWeights)",
        &["path", "best", "speedup"],
    );
    t.row(vec!["cold".into(), format!("{t_cold:?}"), "1.00x".into()]);
    t.row(vec![
        "warm".into(),
        format!("{t_warm:?}"),
        format!("{:.2}x", t_cold.as_secs_f64() / t_warm.as_secs_f64()),
    ]);
    t.print();
    println!("prepare (once): {t_prepare:?}  —  amortized across every request");
    println!("bitwise equality + identical verdicts: OK");
}

/// `vabft autotune`: search the tiled engine's scheduling space per shape
/// class and persist the winners into the tuning manifest that
/// [`vabft::gemm::EngineConfig`] (and hence `gemm`, `serve-replay` and
/// the coordinator) folds into every engine built without explicit
/// overrides. See [`vabft::gemm::autotune`].
fn cmd_autotune(args: &Args) {
    use vabft::gemm::{autotune, AutotuneConfig, AutotuneMode};
    use vabft::runtime::TuningManifest;

    let mode = if args.flag("smoke") {
        AutotuneMode::Smoke
    } else if args.flag("full") {
        AutotuneMode::Full
    } else {
        AutotuneMode::Quick
    };
    let seed = args.opt_or("seed", 0xA070u64);
    let path = match args.opt("manifest") {
        Some(p) => std::path::PathBuf::from(p),
        None => TuningManifest::default_path(),
    };
    let cfg = AutotuneConfig { mode, seed, path };
    let manifest = match autotune::run(&cfg) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("autotune failed: {e:#}");
            std::process::exit(1);
        }
    };
    if args.flag("gate") {
        match autotune::gate(&manifest, seed) {
            Ok(n) => println!("autotune gate OK: {n} transformer shape(s) checked"),
            Err(e) => {
                eprintln!("{e:#}");
                std::process::exit(1);
            }
        }
    }
}

fn cmd_artifacts(args: &Args) {
    let dir = std::path::PathBuf::from(args.opt("dir").unwrap_or("artifacts"));
    match vabft::runtime::PjrtRuntime::from_artifacts(&dir) {
        Err(e) => {
            eprintln!("failed to load artifacts from {}: {e:#}", dir.display());
            eprintln!("run `make artifacts` first");
            std::process::exit(1);
        }
        Ok(rt) => {
            println!("platform: {}", rt.platform());
            let mut t = Table::new("AOT artifacts", &["name", "file", "meta"]);
            for e in &rt.manifest().entries {
                let mut meta: Vec<String> =
                    e.meta.iter().map(|(k, v)| format!("{k}={v}")).collect();
                meta.sort();
                t.row(vec![e.name.clone(), e.file.clone(), meta.join(" ")]);
            }
            t.print();
        }
    }
}

fn cmd_info() {
    println!("V-ABFT: variance-based adaptive thresholds for fault-tolerant GEMM\n");
    let mut t = Table::new(
        "Recommended e_max (paper Table 7)",
        &["Platform", "Precision", "e_max", "N-dependence"],
    );
    for platform in [Platform::Cpu, Platform::Gpu, Platform::Npu] {
        for p in [Precision::F64, Precision::F32, Precision::Bf16, Precision::F16] {
            let m = EmaxTable::recommended(platform, p);
            t.row(vec![
                platform.name().to_string(),
                p.name().to_string(),
                m.label(),
                match m {
                    vabft::calibrate::EmaxModel::Constant(_) => "constant".into(),
                    vabft::calibrate::EmaxModel::SqrtN { .. } => "∝ √N".into(),
                },
            ]);
        }
    }
    t.print();
    println!(
        "subcommands: calibrate | campaign | serve-replay | tightness | gemm | autotune | \
         artifacts | info"
    );
}
