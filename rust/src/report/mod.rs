//! Paper-style table rendering for benches and examples.
//!
//! Every bench regenerates one of the paper's tables; this module prints
//! them in aligned ASCII (and Markdown) with the same row/column structure
//! so EXPERIMENTS.md can record paper-vs-measured side by side.

/// A simple table with aligned columns.
#[derive(Debug, Clone)]
pub struct Table {
    /// Table caption.
    pub title: String,
    /// Column headers.
    pub headers: Vec<String>,
    /// Data rows (each the same width as `headers`).
    pub rows: Vec<Vec<String>>,
}

impl Table {
    /// Empty table with a caption and headers.
    pub fn new(title: &str, headers: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Append a row (must match the header count).
    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(cells.len(), self.headers.len(), "column count mismatch");
        self.rows.push(cells);
        self
    }

    fn widths(&self) -> Vec<usize> {
        let mut w: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                w[i] = w[i].max(c.len());
            }
        }
        w
    }

    /// Render as aligned ASCII.
    pub fn render(&self) -> String {
        let w = self.widths();
        let mut out = String::new();
        out.push_str(&format!("== {} ==\n", self.title));
        let fmt_row = |cells: &[String]| -> String {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:>width$}", c, width = w[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        out.push_str(&fmt_row(&self.headers));
        out.push('\n');
        out.push_str(&"-".repeat(w.iter().sum::<usize>() + 2 * (w.len() - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }

    /// Render as GitHub Markdown.
    pub fn render_markdown(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!("### {}\n\n", self.title));
        out.push_str(&format!("| {} |\n", self.headers.join(" | ")));
        out.push_str(&format!(
            "|{}|\n",
            self.headers.iter().map(|_| "---").collect::<Vec<_>>().join("|")
        ));
        for row in &self.rows {
            out.push_str(&format!("| {} |\n", row.join(" | ")));
        }
        out
    }

    /// Print the ASCII rendering to stdout.
    pub fn print(&self) {
        print!("{}", self.render());
        println!();
    }
}

/// Scientific notation like the paper's tables (e.g. "1.27e-14").
pub fn sci(x: f64) -> String {
    if x == 0.0 {
        return "0".to_string();
    }
    format!("{x:.2e}")
}

/// Tightness ratio like the paper ("164×"); "-" for non-finite.
pub fn ratio(x: f64) -> String {
    if !x.is_finite() {
        return "-".to_string();
    }
    if x >= 100.0 {
        format!("{x:.0}x")
    } else if x >= 10.0 {
        format!("{x:.0}x")
    } else {
        format!("{x:.1}x")
    }
}

/// Percentage with two decimals ("99.96").
pub fn pct(x: f64) -> String {
    format!("{x:.2}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new("Demo", &["Size", "Value"]);
        t.row(vec!["128x128".into(), "1.27e-14".into()]);
        t.row(vec!["2048x2048".into(), "8.22e-13".into()]);
        let r = t.render();
        assert!(r.contains("Demo"));
        assert!(r.contains("128x128"));
        let lines: Vec<&str> = r.lines().collect();
        // all data lines equal width
        assert_eq!(lines[3].len(), lines[4].len());
    }

    #[test]
    fn markdown_shape() {
        let mut t = Table::new("T", &["a", "b"]);
        t.row(vec!["1".into(), "2".into()]);
        let md = t.render_markdown();
        assert!(md.contains("| a | b |"));
        assert!(md.contains("|---|---|"));
    }

    #[test]
    fn formatting_helpers() {
        assert_eq!(sci(0.0), "0");
        assert_eq!(sci(1.27e-14), "1.27e-14");
        assert_eq!(ratio(163.7), "164x");
        assert_eq!(ratio(7.4), "7.4x");
        assert_eq!(ratio(f64::INFINITY), "-");
        assert_eq!(pct(99.957), "99.96");
    }

    #[test]
    #[should_panic]
    fn row_width_mismatch_panics() {
        let mut t = Table::new("T", &["a", "b"]);
        t.row(vec!["1".into()]);
    }
}
