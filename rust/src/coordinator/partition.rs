//! NUMA-aware shard planning: topology detection, partition policies and
//! the shard → topology-group assignment feeding the sharded
//! [`crate::coordinator::Coordinator`].
//!
//! The serving tier splits its worker pool into shards, each pinned to
//! one *topology group* (a NUMA node's CPU set, detected from
//! `/sys/devices/system/node`, or a deterministic single-group fallback
//! when the hierarchy is absent — containers, non-Linux, tests). A
//! [`PartitionPolicy`] decides two things at once:
//!
//! * **which group each shard lands on** — `Contiguous` fills groups in
//!   order (shard-local traffic stays on one memory node), `Interleaved`
//!   deals shards round-robin across groups (balances bandwidth for
//!   skewed shape mixes);
//! * **how each shard's engine splits GEMM rows across its intra-op
//!   threads** — the policy maps onto [`RowSplit`] and is threaded into
//!   every worker's [`ParallelismConfig`], so the row-parallel split in
//!   [`crate::gemm::tiled`] matches the page-placement story above it.
//!
//! None of this can change results: the engine's schedule-preservation
//! invariant covers every `ParallelismConfig`, and shard assignment only
//! decides *where* a request executes. `tests/shard_equivalence.rs` pins
//! bitwise equality across shard counts × policies × stealing.
//!
//! The crate is dependency-free, so "pinning" is capacity-shaped rather
//! than `sched_setaffinity`-enforced: a shard sized to its group's CPU
//! count never oversubscribes the node, and the OS scheduler keeps
//! cache-warm threads where they ran. True affinity syscalls would need
//! libc and are deliberately out of scope.

use std::path::Path;

use crate::gemm::{ParallelismConfig, RowSplit};

/// How shards map onto topology groups and how each shard's engine deals
/// rows to its intra-op threads. Schedule-neutral by construction.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum PartitionPolicy {
    /// Fill topology groups in order; engines use contiguous row panels.
    #[default]
    Contiguous,
    /// Deal shards round-robin across groups; engines use interleaved
    /// row blocks.
    Interleaved,
}

impl PartitionPolicy {
    /// Short lowercase name used in CLIs and reports.
    pub fn name(self) -> &'static str {
        match self {
            PartitionPolicy::Contiguous => "contiguous",
            PartitionPolicy::Interleaved => "interleaved",
        }
    }

    /// Parse a CLI value (`contiguous` | `interleaved`).
    pub fn parse(s: &str) -> Option<PartitionPolicy> {
        match s {
            "contiguous" => Some(PartitionPolicy::Contiguous),
            "interleaved" => Some(PartitionPolicy::Interleaved),
            _ => None,
        }
    }

    /// The engine row-split this policy implies for shard workers.
    pub fn row_split(self) -> RowSplit {
        match self {
            PartitionPolicy::Contiguous => RowSplit::Contiguous,
            PartitionPolicy::Interleaved => RowSplit::Interleaved,
        }
    }
}

/// One topology group: a NUMA node id and the CPUs it owns.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TopologyGroup {
    /// Node id (the `N` of `/sys/devices/system/node/nodeN`).
    pub id: usize,
    /// CPU ids local to the node, ascending.
    pub cpus: Vec<usize>,
}

/// The machine's memory topology as the coordinator sees it: one or more
/// CPU groups, each a NUMA node (or the whole machine in the fallback).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TopologyConfig {
    /// Topology groups, ascending by node id; never empty.
    pub groups: Vec<TopologyGroup>,
}

impl TopologyConfig {
    /// Detect from `/sys/devices/system/node`, falling back to
    /// [`TopologyConfig::fallback`] when the hierarchy is missing or
    /// unparsable (containers without sysfs, non-Linux hosts).
    pub fn detect() -> TopologyConfig {
        Self::from_sys(Path::new("/sys/devices/system/node")).unwrap_or_else(Self::fallback)
    }

    /// Parse `nodeN/cpulist` files under `root` (testable detection
    /// core). Returns `None` when no node directory with a readable,
    /// non-empty cpulist exists.
    pub fn from_sys(root: &Path) -> Option<TopologyConfig> {
        let entries = std::fs::read_dir(root).ok()?;
        let mut groups = Vec::new();
        for e in entries.flatten() {
            let name = e.file_name();
            let name = name.to_string_lossy();
            let Some(idx) = name.strip_prefix("node") else { continue };
            let Ok(id) = idx.parse::<usize>() else { continue };
            let Ok(list) = std::fs::read_to_string(e.path().join("cpulist")) else { continue };
            let cpus = parse_cpulist(list.trim());
            if !cpus.is_empty() {
                groups.push(TopologyGroup { id, cpus });
            }
        }
        if groups.is_empty() {
            return None;
        }
        groups.sort_by_key(|g| g.id);
        Some(TopologyConfig { groups })
    }

    /// Deterministic single-group fallback: one group holding every
    /// hardware thread the runtime reports (at least one).
    pub fn fallback() -> TopologyConfig {
        let n = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        Self::uniform(1, n)
    }

    /// Synthetic uniform topology (tests, reproducible planning):
    /// `groups` groups of `cpus_per_group` consecutively numbered CPUs.
    pub fn uniform(groups: usize, cpus_per_group: usize) -> TopologyConfig {
        let (groups, per) = (groups.max(1), cpus_per_group.max(1));
        TopologyConfig {
            groups: (0..groups)
                .map(|id| TopologyGroup {
                    id,
                    cpus: (id * per..(id + 1) * per).collect(),
                })
                .collect(),
        }
    }

    /// Total CPUs across all groups.
    pub fn total_cpus(&self) -> usize {
        self.groups.iter().map(|g| g.cpus.len()).sum()
    }
}

/// Parse a kernel cpulist string (`"0-3,8,10-11"`) into ascending CPU
/// ids. Malformed fragments are skipped (detection falls back rather
/// than panicking on exotic sysfs content).
pub fn parse_cpulist(s: &str) -> Vec<usize> {
    let mut out = Vec::new();
    for part in s.split(',') {
        let part = part.trim();
        if part.is_empty() {
            continue;
        }
        match part.split_once('-') {
            None => {
                if let Ok(v) = part.parse::<usize>() {
                    out.push(v);
                }
            }
            Some((lo, hi)) => {
                if let (Ok(lo), Ok(hi)) = (lo.trim().parse::<usize>(), hi.trim().parse::<usize>())
                {
                    if lo <= hi && hi - lo < 4096 {
                        out.extend(lo..=hi);
                    }
                }
            }
        }
    }
    out.sort_unstable();
    out.dedup();
    out
}

/// One planned shard: its topology group and the engine configuration
/// its workers run with.
#[derive(Debug, Clone)]
pub struct ShardSpec {
    /// Shard index (0-based).
    pub shard: usize,
    /// Index into [`TopologyConfig::groups`] this shard is pinned to.
    pub group: usize,
    /// Worker threads this shard runs.
    pub workers: usize,
    /// Engine config for the shard's workers: the base config with the
    /// policy's row split applied and intra-op threads clamped to the
    /// group's CPU count (a shard never oversubscribes its node).
    pub parallelism: ParallelismConfig,
}

/// The full shard layout for one coordinator.
#[derive(Debug, Clone)]
pub struct ShardPlan {
    /// Per-shard assignments, ascending by shard index.
    pub shards: Vec<ShardSpec>,
    /// The topology the plan was computed against.
    pub topology: TopologyConfig,
}

impl ShardPlan {
    /// Plan `shards` shards of `workers_per_shard` workers over
    /// `topology` under `policy`. `base` is the per-worker engine
    /// config; each shard gets it with `threads` clamped to its group's
    /// CPU count (only when the caller asked for intra-op parallelism —
    /// `threads == 1` stays serial). The policy's
    /// [`PartitionPolicy::row_split`] is applied only when `base` left
    /// the split at its default ([`RowSplit::Contiguous`]): an explicit
    /// `Interleaved` request (e.g. a `--split` flag) is preserved.
    pub fn plan(
        shards: usize,
        workers_per_shard: usize,
        base: ParallelismConfig,
        policy: PartitionPolicy,
        topology: TopologyConfig,
    ) -> ShardPlan {
        let shards = shards.max(1);
        // A groupless topology (hand-built) degrades to the fallback
        // rather than panicking the planner.
        let topology =
            if topology.groups.is_empty() { TopologyConfig::fallback() } else { topology };
        let ngroups = topology.groups.len();
        let specs = (0..shards)
            .map(|s| {
                let group = match policy {
                    // Evenly fill groups in order: shard s of S covers the
                    // same group band contiguous row splits cover.
                    PartitionPolicy::Contiguous => s * ngroups / shards,
                    PartitionPolicy::Interleaved => s % ngroups,
                };
                let cpus = topology.groups[group].cpus.len().max(1);
                let mut parallelism = base;
                if parallelism.split == RowSplit::Contiguous {
                    parallelism = parallelism.split(policy.row_split());
                }
                if parallelism.threads > 1 {
                    parallelism.threads = parallelism.threads.min(cpus);
                }
                ShardSpec { shard: s, group, workers: workers_per_shard.max(1), parallelism }
            })
            .collect();
        ShardPlan { shards: specs, topology }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cpulist_parsing() {
        assert_eq!(parse_cpulist("0-3,8,10-11"), vec![0, 1, 2, 3, 8, 10, 11]);
        assert_eq!(parse_cpulist("5"), vec![5]);
        assert_eq!(parse_cpulist(""), Vec::<usize>::new());
        assert_eq!(parse_cpulist("2-2"), vec![2]);
        // malformed fragments are skipped, not fatal
        assert_eq!(parse_cpulist("x,3-1,4"), vec![4]);
        // duplicates collapse
        assert_eq!(parse_cpulist("1,1,0-2"), vec![0, 1, 2]);
    }

    #[test]
    fn fallback_is_deterministic_and_nonempty() {
        let a = TopologyConfig::fallback();
        let b = TopologyConfig::fallback();
        assert_eq!(a, b);
        assert_eq!(a.groups.len(), 1);
        assert!(a.total_cpus() >= 1);
    }

    #[test]
    fn detect_never_panics_and_never_returns_empty() {
        let t = TopologyConfig::detect();
        assert!(!t.groups.is_empty());
        assert!(t.total_cpus() >= 1);
        for g in &t.groups {
            assert!(!g.cpus.is_empty());
        }
    }

    #[test]
    fn from_sys_reads_synthetic_tree() {
        let root = std::env::temp_dir().join(format!("vabft-topo-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&root);
        for (node, list) in [(0usize, "0-3"), (1, "4-7")] {
            let d = root.join(format!("node{node}"));
            std::fs::create_dir_all(&d).unwrap();
            std::fs::write(d.join("cpulist"), list).unwrap();
        }
        // A non-node entry must be ignored.
        std::fs::create_dir_all(root.join("possible")).unwrap();
        let t = TopologyConfig::from_sys(&root).expect("synthetic tree parses");
        assert_eq!(t.groups.len(), 2);
        assert_eq!(t.groups[0].cpus, vec![0, 1, 2, 3]);
        assert_eq!(t.groups[1].cpus, vec![4, 5, 6, 7]);
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn plan_policies_assign_groups_and_splits() {
        let topo = TopologyConfig::uniform(2, 4);
        let base = ParallelismConfig::with_threads(16);
        let contig = ShardPlan::plan(4, 2, base, PartitionPolicy::Contiguous, topo.clone());
        assert_eq!(
            contig.shards.iter().map(|s| s.group).collect::<Vec<_>>(),
            vec![0, 0, 1, 1]
        );
        let inter = ShardPlan::plan(4, 2, base, PartitionPolicy::Interleaved, topo);
        assert_eq!(
            inter.shards.iter().map(|s| s.group).collect::<Vec<_>>(),
            vec![0, 1, 0, 1]
        );
        for s in contig.shards.iter().chain(&inter.shards) {
            // intra-op threads clamped to the 4-CPU group
            assert_eq!(s.parallelism.threads, 4);
            assert_eq!(s.workers, 2);
        }
        assert_eq!(contig.shards[0].parallelism.split, RowSplit::Contiguous);
        assert_eq!(inter.shards[0].parallelism.split, RowSplit::Interleaved);
    }

    #[test]
    fn plan_preserves_an_explicit_row_split() {
        // A caller-chosen Interleaved split must survive a Contiguous
        // partition policy (the --split flag is not silently discarded).
        let topo = TopologyConfig::uniform(1, 8);
        let base = ParallelismConfig::with_threads(4).split(RowSplit::Interleaved);
        let plan = ShardPlan::plan(2, 1, base, PartitionPolicy::Contiguous, topo);
        for s in &plan.shards {
            assert_eq!(s.parallelism.split, RowSplit::Interleaved);
        }
    }

    #[test]
    fn plan_keeps_serial_engines_serial() {
        let topo = TopologyConfig::uniform(2, 8);
        let plan = ShardPlan::plan(
            2,
            1,
            ParallelismConfig::serial(),
            PartitionPolicy::Interleaved,
            topo,
        );
        for s in &plan.shards {
            assert_eq!(s.parallelism.threads, 1, "serial stays serial");
        }
    }
}
