//! L3 coordinator: a fault-tolerant GEMM service.
//!
//! This is the production harness the paper's §6.8 integration implies
//! (FTAN-GEMM on Ascend): a request router + worker pool that
//!
//! 1. registers weight matrices once (`register_weights`: checksum
//!    encoding + V-ABFT statistics + threshold context precomputed into a
//!    [`crate::abft::PreparedWeights`] handle, kept in an LRU cache keyed
//!    by [`WeightId`] — the weight-stationary serving fast path;
//!    re-registering an id replaces the cached entry),
//! 2. accepts activation×weight multiply requests, singly (`submit`),
//!    batched (`submit_batch`, one tagged receiver per request), or
//!    handle-based (`submit_prepared`, bypassing the id lookup),
//! 3. executes them on the tiled parallel GEMM engine under the
//!    configured accumulation model (`CoordinatorConfig::parallelism`
//!    sets each worker's intra-op threads/tiles; results are bitwise
//!    independent of that setting),
//! 4. verifies / corrects / recomputes per policy, and
//! 5. exposes counters + latency histograms.
//!
//! Built on std threads + channels (the offline registry has no tokio; a
//! CPU-bound verification pipeline wants a thread pool, not an async
//! reactor). Backpressure comes from the bounded per-shard submission
//! channels.
//!
//! ## Sharding
//!
//! The service runs as `CoordinatorConfig::shards` independent
//! queue + worker-pool units, planned onto the machine's NUMA topology
//! by [`partition::ShardPlan`] (groups detected from `/sys`, with a
//! deterministic fallback). Requests route round-robin by submission id;
//! `CoordinatorConfig::steal` lets idle shards drain backlogged
//! neighbours (whole requests only). Sharding, the partition policy and
//! stealing are pure scheduling — outputs, verdicts and thresholds are
//! bitwise-invariant across all of them (`tests/shard_equivalence.rs`).

pub mod partition;
mod service;
pub use partition::{PartitionPolicy, ShardPlan, ShardSpec, TopologyConfig, TopologyGroup};
pub use service::{
    Admission, Coordinator, CoordinatorConfig, GemmRequest, GemmResponse, InjectSpec,
    PreparedGemmRequest, WeightHandle, WeightId,
};
