//! The coordinator service implementation.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, Sender, SyncSender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

use crate::abft::{FtGemm, FtGemmOutput, PreparedWeights, Verdict, VerifyPolicy};
use crate::fp::Precision;
use crate::gemm::{AccumModel, GemmEngine, GemmOutput, ParallelismConfig};
use crate::inject::{apply_fault, FaultOutcome, FaultSpec};
use crate::matrix::Matrix;
use crate::metrics::ServiceMetrics;
use crate::threshold::{Threshold, VabftThreshold};

/// Identifier of a registered weight matrix.
pub type WeightId = u32;

/// A shared handle to a prepared weight matrix, as returned by
/// [`Coordinator::register_weights`]. Requests carrying a handle
/// ([`PreparedGemmRequest`]) bypass the id → weights cache lookup entirely
/// and stay valid even after the id is evicted or re-registered.
pub type WeightHandle = Arc<PreparedWeights>;

/// Optional fault injection attached to a request (campaigns and demos):
/// a located fault + bit, applied to the first K-block's encoded partial
/// before verification (a single-event upset strikes once). Output and
/// checksum flips address the verified grid (FP32 online, the output
/// precision offline); operand flips address the operand storage grid.
/// See [`crate::inject::FaultSpec`] — `InjectSpec::output(row, col, bit)`
/// is the classic stored-output-element configuration.
pub type InjectSpec = FaultSpec;

/// A protected-multiply request against a registered weight id.
#[derive(Debug)]
pub struct GemmRequest {
    /// Activation matrix (M × K).
    pub a: Matrix,
    /// Which registered weight matrix to multiply against.
    pub weight: WeightId,
    /// Optional fault injection (campaigns/demos).
    pub inject: Option<InjectSpec>,
}

/// The handle-based variant of [`GemmRequest`]: carries the prepared
/// weights directly instead of a [`WeightId`], so no cache lookup happens
/// on the hot path and eviction/re-registration cannot affect the request.
#[derive(Debug)]
pub struct PreparedGemmRequest {
    /// Activation matrix (M × K).
    pub a: Matrix,
    /// The prepared weights to multiply against.
    pub weights: WeightHandle,
    /// Optional fault injection (campaigns/demos).
    pub inject: Option<InjectSpec>,
}

/// The response: the (possibly repaired) product and its verdict.
#[derive(Debug)]
pub struct GemmResponse {
    /// The id assigned at submission (see [`Coordinator::submit_tagged`]).
    pub id: u64,
    /// The protected multiply's output, or an error string.
    pub result: Result<FtGemmOutput, String>,
    /// The realized source-value flip of the request's injection, if the
    /// request carried one (campaign telemetry: drivers combine
    /// `new - old` with the clean operands to classify each trial).
    pub injected: Option<FaultOutcome>,
    /// Queue + execution time, submission to completion.
    pub latency: std::time::Duration,
}

/// Coordinator configuration.
pub struct CoordinatorConfig {
    /// Worker threads executing protected multiplies.
    pub workers: usize,
    /// Bounded queue depth (backpressure: submit blocks when full).
    pub queue_depth: usize,
    /// Accumulation model every worker's engine runs.
    pub model: AccumModel,
    /// Verification policy applied to every request.
    pub policy: VerifyPolicy,
    /// Threshold algorithm factory (each worker gets one instance).
    pub threshold: Arc<dyn Fn() -> Box<dyn Threshold> + Send + Sync>,
    /// Per-worker GEMM engine execution config (tiles + intra-op threads).
    /// Results are identical for any value (schedule preservation); this
    /// only trades per-request latency against worker-level throughput —
    /// keep `workers × parallelism.threads` ≤ the core count.
    pub parallelism: ParallelismConfig,
    /// Capacity of the LRU cache of prepared weights, in entries.
    /// Registering beyond it evicts the least-recently-used weight; id
    /// requests against an evicted weight error (handles stay valid).
    pub weight_capacity: usize,
    /// K-block granularity weights are prepared at (None = monolithic,
    /// `block_k = K`). Blockwise preparation gives per-block thresholds
    /// (tighter, paper §5.2) at the cost of one encoding per block.
    pub block_k: Option<usize>,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            workers: 2,
            queue_depth: 64,
            model: AccumModel::wide(Precision::Bf16),
            policy: VerifyPolicy::default(),
            threshold: Arc::new(|| Box::new(VabftThreshold::default())),
            parallelism: ParallelismConfig::serial(),
            weight_capacity: 1024,
            block_k: None,
        }
    }
}

/// LRU map of prepared weights keyed by [`WeightId`]. Insertions replace
/// (invalidate) existing entries; lookups refresh recency; overflow evicts
/// the least-recently-used entry.
///
/// Guarded by a `Mutex` (recency refresh mutates on lookup). The critical
/// section is a map probe + `Arc` clone — nanoseconds against the
/// µs-to-ms GEMM each request then runs; shard the cache or move to
/// per-entry atomic ticks if worker counts ever make this contend.
struct WeightCache {
    cap: usize,
    tick: u64,
    map: HashMap<WeightId, (u64, WeightHandle)>,
}

impl WeightCache {
    fn new(cap: usize) -> WeightCache {
        WeightCache { cap: cap.max(1), tick: 0, map: HashMap::new() }
    }

    fn get(&mut self, id: WeightId) -> Option<WeightHandle> {
        self.tick += 1;
        let tick = self.tick;
        self.map.get_mut(&id).map(|e| {
            e.0 = tick;
            Arc::clone(&e.1)
        })
    }

    fn insert(&mut self, id: WeightId, w: WeightHandle) {
        self.tick += 1;
        // Replacement = invalidation: the old Arc is dropped here; jobs
        // dequeued after this point resolve to the new weights.
        self.map.insert(id, (self.tick, w));
        if self.map.len() > self.cap {
            let lru = self.map.iter().min_by_key(|&(_, &(t, _))| t).map(|(&k, _)| k);
            if let Some(lru) = lru {
                self.map.remove(&lru);
            }
        }
    }

    fn contains(&self, id: WeightId) -> bool {
        self.map.contains_key(&id)
    }

    fn len(&self) -> usize {
        self.map.len()
    }
}

enum Payload {
    ById(GemmRequest),
    Handle(PreparedGemmRequest),
}

struct Job {
    id: u64,
    payload: Payload,
    reply: Sender<GemmResponse>,
    submitted: Instant,
}

/// The fault-tolerant GEMM service.
///
/// ```
/// use std::sync::Arc;
/// use vabft::coordinator::{Coordinator, CoordinatorConfig, GemmRequest, PreparedGemmRequest};
/// use vabft::prelude::*;
///
/// let coord = Coordinator::start(CoordinatorConfig::default());
/// let mut rng = Xoshiro256pp::seed_from_u64(1);
/// let d = Distribution::normal_1_1();
/// let b = Matrix::sample_in(64, 32, &d, Precision::Bf16, &mut rng);
///
/// // Register once: checksum encoding + V-ABFT statistics cached (LRU).
/// let handle = coord.register_weights(7, &b);
///
/// // Request by id…
/// let a = Matrix::sample_in(8, 64, &d, Precision::Bf16, &mut rng);
/// let resp = coord.call(GemmRequest { a: a.clone(), weight: 7, inject: None });
/// let by_id = resp.result.unwrap();
/// assert_eq!(by_id.report.verdict, Verdict::Clean);
///
/// // …or by handle (no cache lookup, immune to eviction/re-registration).
/// let resp = coord.call_prepared(PreparedGemmRequest {
///     a,
///     weights: Arc::clone(&handle),
///     inject: None,
/// });
/// let by_handle = resp.result.unwrap();
/// assert_eq!(by_handle.c.data(), by_id.c.data()); // bitwise-identical
/// coord.shutdown();
/// ```
pub struct Coordinator {
    tx: Option<SyncSender<Job>>,
    handles: Vec<JoinHandle<()>>,
    weights: Arc<Mutex<WeightCache>>,
    metrics: Arc<ServiceMetrics>,
    next_id: AtomicU64,
    ft_template: Arc<FtGemm>,
    block_k: Option<usize>,
}

impl Coordinator {
    /// Start the worker pool.
    pub fn start(cfg: CoordinatorConfig) -> Coordinator {
        let (tx, rx) = sync_channel::<Job>(cfg.queue_depth);
        let rx = Arc::new(Mutex::new(rx));
        let weights = Arc::new(Mutex::new(WeightCache::new(cfg.weight_capacity)));
        let metrics = Arc::new(ServiceMetrics::new());

        let mut handles = Vec::new();
        for wid in 0..cfg.workers.max(1) {
            let rx = Arc::clone(&rx);
            let weights = Arc::clone(&weights);
            let metrics = Arc::clone(&metrics);
            let ft = FtGemm::new(
                GemmEngine::with_parallelism(cfg.model, cfg.parallelism),
                (cfg.threshold)(),
                cfg.policy,
            );
            let model = cfg.model;
            let policy = cfg.policy;
            handles.push(
                std::thread::Builder::new()
                    .name(format!("ftgemm-worker-{wid}"))
                    .spawn(move || worker_loop(rx, weights, metrics, ft, model, policy))
                    .expect("spawn worker"),
            );
        }
        let ft_template = Arc::new(FtGemm::new(
            GemmEngine::with_parallelism(cfg.model, cfg.parallelism),
            (cfg.threshold)(),
            cfg.policy,
        ));
        Coordinator {
            tx: Some(tx),
            handles,
            weights,
            metrics,
            next_id: AtomicU64::new(0),
            ft_template,
            block_k: cfg.block_k,
        }
    }

    /// Register (or replace) a weight matrix: encodes checksums and
    /// precomputes the per-block threshold statistics once, inserts the
    /// result into the LRU cache under `id`, and returns the shared handle
    /// for direct (id-free) submission. Re-registering an id **replaces**
    /// the cached entry — later requests for the id never see state from
    /// the previous matrix.
    pub fn register_weights(&self, id: WeightId, b: &Matrix) -> WeightHandle {
        let prepared = Arc::new(match self.block_k {
            None => self.ft_template.prepare(b),
            Some(bk) => self.ft_template.prepare_blockwise(b, bk),
        });
        self.weights.lock().unwrap().insert(id, Arc::clone(&prepared));
        prepared
    }

    /// Back-compat alias of [`Coordinator::register_weights`] (discarding
    /// the handle).
    pub fn register_weight(&self, id: WeightId, b: &Matrix) {
        let _ = self.register_weights(id, b);
    }

    /// Whether `id` is currently resident in the weight cache (it may have
    /// been evicted by LRU pressure or never registered).
    pub fn weight_resident(&self, id: WeightId) -> bool {
        self.weights.lock().unwrap().contains(id)
    }

    /// Number of weight matrices currently resident in the cache.
    pub fn weights_resident(&self) -> usize {
        self.weights.lock().unwrap().len()
    }

    /// Submit a request; returns a receiver for the response. Blocks when
    /// the queue is full (backpressure).
    pub fn submit(&self, req: GemmRequest) -> Receiver<GemmResponse> {
        self.submit_tagged(req).1
    }

    /// Submit a request and also return the id its response will carry
    /// (`GemmResponse::id`) — the building block of [`Self::submit_batch`].
    pub fn submit_tagged(&self, req: GemmRequest) -> (u64, Receiver<GemmResponse>) {
        self.enqueue(Payload::ById(req))
    }

    /// Submit a handle-based request (see [`PreparedGemmRequest`]).
    pub fn submit_prepared(&self, req: PreparedGemmRequest) -> Receiver<GemmResponse> {
        self.submit_prepared_tagged(req).1
    }

    /// Handle-based variant of [`Self::submit_tagged`].
    pub fn submit_prepared_tagged(
        &self,
        req: PreparedGemmRequest,
    ) -> (u64, Receiver<GemmResponse>) {
        self.enqueue(Payload::Handle(req))
    }

    fn enqueue(&self, payload: Payload) -> (u64, Receiver<GemmResponse>) {
        let (reply_tx, reply_rx) = std::sync::mpsc::channel();
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        self.metrics.jobs_submitted.inc();
        self.tx
            .as_ref()
            .expect("coordinator already shut down")
            .send(Job { id, payload, reply: reply_tx, submitted: Instant::now() })
            .expect("worker pool hung up");
        (id, reply_rx)
    }

    /// Batched submit: enqueue every request (in order, sharing the
    /// backpressure of the bounded queue) and return one `(id, receiver)`
    /// pair per request, in the same order. Requests of one batch fan out
    /// across the worker pool and complete independently; the ids tie the
    /// responses back to their requests.
    pub fn submit_batch(
        &self,
        reqs: Vec<GemmRequest>,
    ) -> Vec<(u64, Receiver<GemmResponse>)> {
        self.metrics.batches_submitted.inc();
        reqs.into_iter().map(|r| self.submit_tagged(r)).collect()
    }

    /// Handle-based variant of [`Self::submit_batch`]: enqueue every
    /// prepared request in order and return one `(id, receiver)` pair per
    /// request. The campaign engine's hot path — each cell's trials ride
    /// one batch against weights prepared once.
    pub fn submit_batch_prepared(
        &self,
        reqs: Vec<PreparedGemmRequest>,
    ) -> Vec<(u64, Receiver<GemmResponse>)> {
        self.metrics.batches_submitted.inc();
        reqs.into_iter().map(|r| self.submit_prepared_tagged(r)).collect()
    }

    /// Convenience: submit and wait.
    pub fn call(&self, req: GemmRequest) -> GemmResponse {
        self.submit(req).recv().expect("worker dropped reply")
    }

    /// Convenience: submit a handle-based request and wait.
    pub fn call_prepared(&self, req: PreparedGemmRequest) -> GemmResponse {
        self.submit_prepared(req).recv().expect("worker dropped reply")
    }

    /// Service counters and latency histograms.
    pub fn metrics(&self) -> &ServiceMetrics {
        &self.metrics
    }

    /// Drain the queue and join all workers.
    pub fn shutdown(mut self) {
        drop(self.tx.take());
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        drop(self.tx.take());
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

fn worker_loop(
    rx: Arc<Mutex<Receiver<Job>>>,
    weights: Arc<Mutex<WeightCache>>,
    metrics: Arc<ServiceMetrics>,
    ft: FtGemm,
    model: AccumModel,
    policy: VerifyPolicy,
) {
    loop {
        // Hold the lock only while receiving.
        let job = match rx.lock().unwrap().recv() {
            Ok(j) => j,
            Err(_) => return, // all senders gone: shutdown
        };
        // Resolve the request to (activation, prepared weights, injection).
        let resolved: Result<(Matrix, WeightHandle, Option<InjectSpec>), String> =
            match job.payload {
                Payload::ById(req) => match weights.lock().unwrap().get(req.weight) {
                    None => Err(format!("unknown or evicted weight id {}", req.weight)),
                    Some(w) => Ok((req.a, w, req.inject)),
                },
                Payload::Handle(req) => Ok((req.a, req.weights, req.inject)),
            };
        let mut injected = None;
        let result = match resolved {
            Err(e) => Err(e),
            Ok((a, w, inject)) => {
                let run = match inject {
                    None => ft.multiply_prepared(&a, &w, None),
                    Some(spec) => {
                        let grid = if policy.online { model.work } else { model.out };
                        // A single-event upset strikes once: inject into
                        // the first K-block's partial only, even when the
                        // weights are prepared blockwise. The realized
                        // flip is recorded through a Cell because the
                        // injection hook is a shared (&dyn Fn) closure.
                        let outcome = std::cell::Cell::new(None);
                        let f = |bi: usize, out: &mut GemmOutput| {
                            if bi != 0 {
                                return;
                            }
                            if let Some(blk) = w.blocks().first() {
                                outcome.set(Some(apply_fault(
                                    &spec,
                                    policy.online,
                                    model.input,
                                    grid,
                                    &a,
                                    &blk.stats.b,
                                    out,
                                )));
                            }
                        };
                        let r = ft.multiply_prepared(&a, &w, Some(&f));
                        injected = outcome.get();
                        r
                    }
                };
                run.map_err(|e| e.to_string())
            }
        };
        if let Ok(out) = &result {
            match out.report.verdict {
                Verdict::Clean => {}
                Verdict::Corrected => {
                    metrics.faults_detected.add(out.report.detections.len() as u64);
                    metrics
                        .faults_corrected
                        .add(out.report.detections.iter().filter(|d| d.corrected).count() as u64);
                }
                Verdict::Recomputed | Verdict::Flagged => {
                    metrics.faults_detected.add(out.report.detections.len() as u64);
                    metrics.rows_recomputed.add(out.report.rows_recomputed as u64);
                }
            }
        }
        metrics.jobs_completed.inc();
        metrics.latency.record(job.submitted.elapsed());
        let _ = job.reply.send(GemmResponse {
            id: job.id,
            result,
            injected,
            latency: job.submitted.elapsed(),
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::{Distribution, Xoshiro256pp};

    fn coordinator(workers: usize) -> (Coordinator, Matrix) {
        let cfg = CoordinatorConfig {
            workers,
            queue_depth: 16,
            model: AccumModel::wide(Precision::Bf16),
            ..Default::default()
        };
        let c = Coordinator::start(cfg);
        let mut rng = Xoshiro256pp::seed_from_u64(1);
        let b = Matrix::sample_in(
            64,
            32,
            &Distribution::normal_1_1(),
            Precision::Bf16,
            &mut rng,
        );
        c.register_weight(7, &b);
        (c, b)
    }

    fn activation(seed: u64) -> Matrix {
        let mut rng = Xoshiro256pp::seed_from_u64(seed);
        Matrix::sample_in(8, 64, &Distribution::normal_1_1(), Precision::Bf16, &mut rng)
    }

    #[test]
    fn clean_requests_round_trip() {
        let (c, _b) = coordinator(2);
        let resp = c.call(GemmRequest { a: activation(2), weight: 7, inject: None });
        let out = resp.result.expect("ok");
        assert_eq!(out.report.verdict, Verdict::Clean);
        assert_eq!(out.c.rows(), 8);
        assert_eq!(out.c.cols(), 32);
        assert_eq!(c.metrics().jobs_completed.get(), 1);
        c.shutdown();
    }

    #[test]
    fn unknown_weight_errors() {
        let (c, _b) = coordinator(1);
        let resp = c.call(GemmRequest { a: activation(3), weight: 99, inject: None });
        assert!(resp.result.is_err());
        c.shutdown();
    }

    #[test]
    fn injected_fault_is_detected_and_repaired() {
        let (c, _b) = coordinator(1);
        let resp = c.call(GemmRequest {
            a: activation(4),
            weight: 7,
            inject: Some(InjectSpec::output(2, 5, 13)),
        });
        let realized = resp.injected.expect("injection outcome reported");
        assert_ne!(realized.delta(), 0.0);
        let out = resp.result.expect("ok");
        assert_ne!(out.report.verdict, Verdict::Clean);
        assert!(c.metrics().faults_detected.get() >= 1);
        // online policy + bit 13 flip on fp32 accumulator → huge D1 →
        // localize + correct (or recompute); output must verify clean:
        let clean = c.call(GemmRequest { a: activation(4), weight: 7, inject: None });
        let cm = clean.result.unwrap().c;
        assert!(out.c.max_abs_diff(&cm) < 1e-2, "diff {}", out.c.max_abs_diff(&cm));
        c.shutdown();
    }

    #[test]
    fn many_concurrent_requests() {
        let (c, _b) = coordinator(4);
        let receivers: Vec<_> = (0..32)
            .map(|i| c.submit(GemmRequest { a: activation(10 + i), weight: 7, inject: None }))
            .collect();
        for r in receivers {
            let resp = r.recv().unwrap();
            assert!(resp.result.is_ok());
        }
        assert_eq!(c.metrics().jobs_completed.get(), 32);
        c.shutdown();
    }

    #[test]
    fn submit_batch_ids_match_responses() {
        let (c, _b) = coordinator(3);
        let reqs: Vec<GemmRequest> = (0..12)
            .map(|i| GemmRequest { a: activation(40 + i), weight: 7, inject: None })
            .collect();
        let pending = c.submit_batch(reqs);
        assert_eq!(pending.len(), 12);
        for (id, rx) in pending {
            let resp = rx.recv().unwrap();
            assert_eq!(resp.id, id, "response routed to the wrong receiver");
            assert!(resp.result.is_ok());
        }
        assert_eq!(c.metrics().batches_submitted.get(), 1);
        assert_eq!(c.metrics().jobs_completed.get(), 12);
        c.shutdown();
    }

    #[test]
    fn worker_parallelism_config_is_applied() {
        let cfg = CoordinatorConfig {
            workers: 1,
            parallelism: crate::gemm::ParallelismConfig::with_threads(2),
            ..Default::default()
        };
        let c = Coordinator::start(cfg);
        let mut rng = Xoshiro256pp::seed_from_u64(9);
        let b = Matrix::sample_in(64, 32, &Distribution::normal_1_1(), Precision::Bf16, &mut rng);
        c.register_weight(1, &b);
        // Same request through a serial coordinator must give bitwise the
        // same product (schedule preservation end to end).
        let (cs, _) = coordinator(1);
        cs.register_weight(1, &b);
        let a = activation(41);
        let x = c.call(GemmRequest { a: a.clone(), weight: 1, inject: None });
        let y = cs.call(GemmRequest { a, weight: 1, inject: None });
        let (x, y) = (x.result.unwrap().c, y.result.unwrap().c);
        assert_eq!(x.data(), y.data());
        c.shutdown();
        cs.shutdown();
    }

    #[test]
    fn weight_replacement_takes_effect() {
        let (c, b) = coordinator(1);
        // replace weight 7 with its negation; outputs should flip sign
        let mut neg = b.clone();
        for v in neg.data_mut() {
            *v = -*v;
        }
        let a = activation(5);
        let before = c.call(GemmRequest { a: a.clone(), weight: 7, inject: None });
        c.register_weight(7, &neg);
        let after = c.call(GemmRequest { a, weight: 7, inject: None });
        let x = before.result.unwrap().c;
        let y = after.result.unwrap().c;
        let mut maxsum = 0.0f64;
        for (p, q) in x.data().iter().zip(y.data()) {
            maxsum = maxsum.max((p + q).abs());
        }
        assert!(maxsum < 1e-6, "outputs should negate: {maxsum}");
        c.shutdown();
    }

    #[test]
    fn handle_requests_bypass_the_cache() {
        let (c, b) = coordinator(1);
        let handle = c.register_weights(8, &b);
        let a = activation(6);
        let by_id = c.call(GemmRequest { a: a.clone(), weight: 8, inject: None });
        let by_handle = c.call_prepared(PreparedGemmRequest {
            a: a.clone(),
            weights: Arc::clone(&handle),
            inject: None,
        });
        let (x, y) = (by_id.result.unwrap().c, by_handle.result.unwrap().c);
        assert_eq!(x.data(), y.data(), "id and handle paths must be bitwise-identical");
        // A handle outlives re-registration of its id.
        let mut other = b.clone();
        for v in other.data_mut() {
            *v = -*v;
        }
        c.register_weights(8, &other);
        let still = c.call_prepared(PreparedGemmRequest { a, weights: handle, inject: None });
        assert_eq!(still.result.unwrap().c.data(), x.data());
        c.shutdown();
    }

    // LRU eviction semantics (capacity, recency refresh, evicted-id
    // errors, handle survival) are pinned by the richer integration test
    // `tests/weight_cache.rs::lru_eviction_errors_by_id_but_handles_survive`.
}
