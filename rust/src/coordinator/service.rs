//! The sharded coordinator service implementation.
//!
//! The service is split into *shards*: each shard owns a bounded job
//! queue and a worker pool planned onto one topology group by
//! [`ShardPlan`] (see [`crate::coordinator::partition`]). Requests are
//! dealt round-robin across shards by submission id — deterministic
//! routing, no load feedback — and an optional cross-shard work-stealing
//! pass lets idle shards drain backlogged neighbours when the shape mix
//! is skewed. Idle workers **park on condvars** (their shard queue's
//! `not_empty`, or the pool-wide steal signal when stealing is on): an
//! idle pool burns zero CPU, an enqueue wakes the workers that can
//! serve it, and there is no polling interval anywhere.
//! Stealing moves **whole requests** (never rows of one
//! GEMM), and every worker executes the same schedule-preserving
//! pipeline, so the shard count, partition policy and steal setting are
//! pure scheduling: outputs, verdicts and thresholds are bitwise
//! invariant (`tests/shard_equivalence.rs`).
//!
//! Prepared weights live in one shared LRU (`WeightCache`) with a
//! per-shard read-through cache in front: id lookups hit the shard-local
//! map (one uncontended mutex per shard) and only fall through to the
//! shared LRU on a miss or after any (re-)registration, which bumps a
//! global generation and invalidates every shard cache at once.

use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

use crate::abft::{FtGemm, FtGemmOutput, PreparedWeights, Verdict, VerifyPolicy};
use crate::coordinator::partition::{PartitionPolicy, ShardPlan, TopologyConfig};
use crate::fp::Precision;
use crate::gemm::{AccumModel, EngineConfig, GemmEngine, GemmOutput, ParallelismConfig};
use crate::inject::{apply_fault, FaultOutcome, FaultSpec};
use crate::matrix::Matrix;
use crate::metrics::ServiceMetrics;
use crate::threshold::{Threshold, VabftThreshold};

/// Identifier of a registered weight matrix.
pub type WeightId = u32;

/// A shared handle to a prepared weight matrix, as returned by
/// [`Coordinator::register_weights`]. Requests carrying a handle
/// ([`PreparedGemmRequest`]) bypass the id → weights cache lookup entirely
/// and stay valid even after the id is evicted or re-registered.
pub type WeightHandle = Arc<PreparedWeights>;

/// Optional fault injection attached to a request (campaigns and demos):
/// one or more located faults + bits, applied in order to the first
/// K-block's encoded partial before verification. A single-entry spec is
/// the classic single-event upset; multi-entry specs model multi-bit
/// upsets and row/column bursts for the 2D-encoding campaign axis.
/// Output and checksum flips address the verified grid (FP32 online, the
/// output precision offline); operand flips address the operand storage
/// grid. See [`crate::inject::FaultSpec`] —
/// `InjectSpec::output(row, col, bit)` is the classic
/// stored-output-element configuration.
#[derive(Debug, Clone, PartialEq)]
pub struct InjectSpec {
    /// The faults to apply, in order, to the first K-block's partial.
    pub faults: Vec<FaultSpec>,
}

impl InjectSpec {
    /// A single-fault spec (the classic single-event upset).
    pub fn single(fault: FaultSpec) -> InjectSpec {
        InjectSpec { faults: vec![fault] }
    }

    /// A multi-fault spec: every fault strikes the same partial before
    /// verification runs (simultaneous upsets / burst patterns).
    pub fn multi(faults: Vec<FaultSpec>) -> InjectSpec {
        InjectSpec { faults }
    }

    /// Single stored-output-element flip at (`row`, `col`).
    pub fn output(row: usize, col: usize, bit: u32) -> InjectSpec {
        Self::single(FaultSpec::output(row, col, bit))
    }

    /// Single transient A-register flip feeding output (`row`, `col`)
    /// through K index `k`.
    pub fn operand_a(row: usize, k: usize, col: usize, bit: u32) -> InjectSpec {
        Self::single(FaultSpec::operand_a(row, k, col, bit))
    }

    /// Single persistent stored-B-element flip at (`k`, `col`).
    pub fn operand_b(k: usize, col: usize, bit: u32) -> InjectSpec {
        Self::single(FaultSpec::operand_b(k, col, bit))
    }

    /// Single checksum-row flip: the `c^{r1}` entry of output row `row`.
    pub fn checksum(row: usize, bit: u32) -> InjectSpec {
        Self::single(FaultSpec::checksum(row, bit))
    }
}

impl From<FaultSpec> for InjectSpec {
    fn from(fault: FaultSpec) -> InjectSpec {
        InjectSpec::single(fault)
    }
}

/// A protected-multiply request against a registered weight id.
#[derive(Debug)]
pub struct GemmRequest {
    /// Activation matrix (M × K).
    pub a: Matrix,
    /// Which registered weight matrix to multiply against.
    pub weight: WeightId,
    /// Optional fault injection (campaigns/demos).
    pub inject: Option<InjectSpec>,
}

/// The handle-based variant of [`GemmRequest`]: carries the prepared
/// weights directly instead of a [`WeightId`], so no cache lookup happens
/// on the hot path and eviction/re-registration cannot affect the request.
#[derive(Debug)]
pub struct PreparedGemmRequest {
    /// Activation matrix (M × K).
    pub a: Matrix,
    /// The prepared weights to multiply against.
    pub weights: WeightHandle,
    /// Optional fault injection (campaigns/demos).
    pub inject: Option<InjectSpec>,
}

/// Explicit admission verdict of the non-blocking submit path
/// ([`Coordinator::try_submit_prepared`]): the open-loop traffic engine
/// must never block its arrival loop on a full queue, so instead of
/// backpressure it receives either an acceptance or a load-shed verdict.
#[derive(Debug)]
pub enum Admission {
    /// The request was enqueued; its response will carry this id.
    Accepted(u64, Receiver<GemmResponse>),
    /// The target shard's queue was full: the request was refused
    /// *before* any compute, `jobs_shed` was incremented, and the
    /// request is handed back untouched. Shedding never alters any
    /// computed output's bits — a shed request simply never executes.
    Shed(PreparedGemmRequest),
}

/// The response: the (possibly repaired) product and its verdict.
#[derive(Debug)]
pub struct GemmResponse {
    /// The id assigned at submission (see [`Coordinator::submit_tagged`]).
    pub id: u64,
    /// The protected multiply's output, or an error string.
    pub result: Result<FtGemmOutput, String>,
    /// The realized source-value flip of the request's injection, if the
    /// request carried one (campaign telemetry: drivers combine
    /// `new - old` with the clean operands to classify each trial).
    pub injected: Option<FaultOutcome>,
    /// Queue + execution time, submission to completion.
    pub latency: std::time::Duration,
}

/// Coordinator configuration.
pub struct CoordinatorConfig {
    /// Worker threads executing protected multiplies, **per shard**.
    pub workers: usize,
    /// Bounded queue depth per shard (backpressure: submit blocks when
    /// the target shard's queue is full).
    pub queue_depth: usize,
    /// Accumulation model every worker's engine runs.
    pub model: AccumModel,
    /// Verification policy applied to every request.
    pub policy: VerifyPolicy,
    /// Threshold algorithm factory (each worker gets one instance).
    pub threshold: Arc<dyn Fn() -> Box<dyn Threshold> + Send + Sync>,
    /// Per-worker GEMM engine execution config (tiles + intra-op threads).
    /// Results are identical for any value (schedule preservation); this
    /// only trades per-request latency against worker-level throughput —
    /// keep `shards × workers × parallelism.threads` ≤ the core count.
    /// The shard plan applies the partition policy's row split and clamps
    /// intra-op threads to each shard's topology group.
    pub parallelism: ParallelismConfig,
    /// Unified engine configuration (tiles + microkernel + row split +
    /// SIMD level + tuning manifest). When set it takes precedence over
    /// [`CoordinatorConfig::parallelism`]: every worker engine is built
    /// from it, so each request's GEMM shape gets a tuning-manifest
    /// lookup, while the shard plan's intra-op thread clamp and row
    /// split still apply on top. `None` falls back to `parallelism`.
    pub engine: Option<EngineConfig>,
    /// Capacity of the shared LRU cache of prepared weights, in entries.
    /// Registering beyond it evicts the least-recently-used weight; id
    /// requests against an evicted weight error (handles stay valid).
    pub weight_capacity: usize,
    /// K-block granularity weights are prepared at (None = monolithic,
    /// `block_k = K`). Blockwise preparation gives per-block thresholds
    /// (tighter, paper §5.2) at the cost of one encoding per block.
    pub block_k: Option<usize>,
    /// Number of shards (independent queue + worker-pool units). 1 =
    /// the classic single-queue coordinator.
    pub shards: usize,
    /// How shards map onto topology groups and how each shard's engine
    /// splits rows (see [`PartitionPolicy`]). Schedule-neutral.
    pub partition: PartitionPolicy,
    /// Enable cross-shard work stealing: idle workers opportunistically
    /// drain other shards' queues (whole requests only).
    pub steal: bool,
    /// Topology to plan shards against; `None` detects from `/sys` with
    /// a deterministic single-group fallback.
    pub topology: Option<TopologyConfig>,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            workers: 2,
            queue_depth: 64,
            model: AccumModel::wide(Precision::Bf16),
            policy: VerifyPolicy::default(),
            threshold: Arc::new(|| Box::new(VabftThreshold::default())),
            parallelism: ParallelismConfig::serial(),
            engine: None,
            weight_capacity: 1024,
            block_k: None,
            shards: 1,
            partition: PartitionPolicy::Contiguous,
            steal: false,
            topology: None,
        }
    }
}

/// An entry's recency stamp: an atomic tick shared between the LRU map
/// and every shard cache holding the entry, so shard-local hits can
/// refresh recency **lock-free** (two relaxed atomics) and eviction
/// still tracks real use exactly.
type Recency = Arc<AtomicU64>;

/// LRU map of prepared weights keyed by [`WeightId`]. Insertions replace
/// (invalidate) existing entries; lookups refresh recency; overflow
/// evicts the entry with the oldest recency stamp — including stamps
/// refreshed by shard-cache hits that never took this lock.
struct WeightCache {
    cap: usize,
    map: HashMap<WeightId, (Recency, WeightHandle)>,
}

impl WeightCache {
    fn new(cap: usize) -> WeightCache {
        WeightCache { cap: cap.max(1), map: HashMap::new() }
    }

    fn get(&mut self, id: WeightId, tick: u64) -> Option<(Recency, WeightHandle)> {
        self.map.get(&id).map(|(r, h)| {
            r.store(tick, Ordering::Relaxed);
            (Arc::clone(r), Arc::clone(h))
        })
    }

    fn insert(&mut self, id: WeightId, w: WeightHandle, tick: u64) {
        // Replacement = invalidation: the old Arc is dropped here; jobs
        // dequeued after this point resolve to the new weights.
        self.map.insert(id, (Arc::new(AtomicU64::new(tick)), w));
        if self.map.len() > self.cap {
            // The just-inserted key is exempt from the overflow scan:
            // its tick was taken before this lock, so a concurrent
            // lock-free shard-cache hit could have stamped an older
            // entry with a newer tick — without the exemption the scan
            // could evict the registration it is serving.
            let lru = self
                .map
                .iter()
                .filter(|(&k, _)| k != id)
                .min_by_key(|(_, (r, _))| r.load(Ordering::Relaxed))
                .map(|(&k, _)| k);
            if let Some(lru) = lru {
                self.map.remove(&lru);
            }
        }
    }

    fn contains(&self, id: WeightId) -> bool {
        self.map.contains_key(&id)
    }

    fn len(&self) -> usize {
        self.map.len()
    }
}

/// The shared weight store: one LRU behind a generation counter. Every
/// insert bumps the generation (inside the cache lock), which invalidates
/// every shard's read-through cache at once — registration is rare in
/// serving, so coarse invalidation buys an uncontended steady-state.
struct SharedWeights {
    cache: Mutex<WeightCache>,
    generation: AtomicU64,
    /// Global recency clock; advanced lock-free by both read-throughs
    /// and shard-cache hits.
    tick: AtomicU64,
}

impl SharedWeights {
    fn new(cap: usize) -> SharedWeights {
        SharedWeights {
            cache: Mutex::new(WeightCache::new(cap)),
            generation: AtomicU64::new(0),
            tick: AtomicU64::new(0),
        }
    }

    fn next_tick(&self) -> u64 {
        self.tick.fetch_add(1, Ordering::Relaxed) + 1
    }

    fn insert(&self, id: WeightId, w: WeightHandle) {
        let tick = self.next_tick();
        let mut c = self.cache.lock().unwrap();
        c.insert(id, w, tick);
        // Bump inside the cache lock: a reader that loads the new
        // generation is guaranteed to read-through to the new entry.
        self.generation.fetch_add(1, Ordering::Release);
    }
}

/// One shard's read-through cache in front of [`SharedWeights`]:
/// generation-stamped handles served without touching the shared LRU
/// lock. A hit refreshes the entry's shared recency stamp through its
/// [`Recency`] atomic — the hottest weight stays the most recently used
/// even when every request is a shard-local hit. A stale generation
/// stamp (any registration since fill) clears the map and falls through.
#[derive(Default)]
struct ShardWeightCache {
    map: Mutex<HashMap<WeightId, (u64, Recency, WeightHandle)>>,
}

impl ShardWeightCache {
    /// Resolve `id`, preferring the shard-local entry when no
    /// registration happened since it was cached.
    fn resolve(&self, shared: &SharedWeights, id: WeightId) -> Option<WeightHandle> {
        // Load the generation *before* any cache read: if a registration
        // interleaves, the stamp we store is older than the bump and the
        // next lookup revalidates — never the reverse. (Named to stay
        // clear of the 2024-edition `gen` keyword.)
        let generation = shared.generation.load(Ordering::Acquire);
        {
            let mut local = self.map.lock().unwrap();
            match local.get(&id) {
                Some((g, recency, h)) if *g == generation => {
                    recency.store(shared.next_tick(), Ordering::Relaxed);
                    return Some(Arc::clone(h));
                }
                Some(_) => {
                    // Some registration invalidated everything we hold.
                    local.clear();
                }
                None => {}
            }
        }
        // Read-through: the shared LRU lookup refreshes recency too.
        let (recency, h) = shared.cache.lock().unwrap().get(id, shared.next_tick())?;
        self.map.lock().unwrap().insert(id, (generation, recency, Arc::clone(&h)));
        Some(h)
    }
}

enum Payload {
    ById(GemmRequest),
    Handle(PreparedGemmRequest),
}

struct Job {
    id: u64,
    payload: Payload,
    reply: Sender<GemmResponse>,
    submitted: Instant,
}

/// State behind one shard queue's mutex: the buffered jobs plus the
/// closed flag set at shutdown.
struct QueueState {
    deque: VecDeque<Job>,
    closed: bool,
}

/// One shard's bounded job queue: a mutex-guarded deque with two
/// condvars. `not_empty` parks the shard's own workers when idle (an
/// enqueue wakes exactly one — no polling), `not_full` parks producers
/// at capacity (the submit-side backpressure the old `sync_channel`
/// provided). Jobs buffered at close remain poppable until drained, so
/// shutdown never drops accepted work.
struct ShardQueue {
    state: Mutex<QueueState>,
    not_empty: Condvar,
    not_full: Condvar,
    cap: usize,
}

impl ShardQueue {
    fn new(cap: usize) -> ShardQueue {
        ShardQueue {
            state: Mutex::new(QueueState { deque: VecDeque::new(), closed: false }),
            not_empty: Condvar::new(),
            not_full: Condvar::new(),
            cap: cap.max(1),
        }
    }

    /// Bounded blocking push. Panics if the queue closed, matching the
    /// old `SyncSender::send(..).expect("worker pool hung up")` surface.
    fn push(&self, job: Job) {
        let mut s = self.state.lock().unwrap();
        while s.deque.len() >= self.cap && !s.closed {
            s = self.not_full.wait(s).unwrap();
        }
        assert!(!s.closed, "worker pool hung up");
        s.deque.push_back(job);
        drop(s);
        self.not_empty.notify_one();
    }

    /// Non-blocking bounded push — the open-loop admission-control path:
    /// when the queue is at capacity the job is handed back (`Err`) so
    /// the caller can emit an explicit load-shed verdict instead of
    /// blocking the arrival loop. Panics if the queue closed, matching
    /// [`ShardQueue::push`].
    fn try_push(&self, job: Job) -> Result<(), Job> {
        let mut s = self.state.lock().unwrap();
        assert!(!s.closed, "worker pool hung up");
        if s.deque.len() >= self.cap {
            return Err(job);
        }
        s.deque.push_back(job);
        drop(s);
        self.not_empty.notify_one();
        Ok(())
    }

    /// Non-blocking pop — also the drain path after close: buffered jobs
    /// keep coming out until the deque is empty.
    fn try_pop(&self) -> Option<Job> {
        let mut s = self.state.lock().unwrap();
        let job = s.deque.pop_front();
        if job.is_some() {
            drop(s);
            self.not_full.notify_one();
        }
        job
    }

    /// Blocking pop for the shard's own workers (the no-steal
    /// configuration): parks on `not_empty` until a job arrives or the
    /// queue closes empty (→ `None`, the shutdown return).
    fn pop_wait(&self) -> Option<Job> {
        let mut s = self.state.lock().unwrap();
        loop {
            if let Some(job) = s.deque.pop_front() {
                drop(s);
                self.not_full.notify_one();
                return Some(job);
            }
            if s.closed {
                return None;
            }
            s = self.not_empty.wait(s).unwrap();
        }
    }

    fn is_closed(&self) -> bool {
        self.state.lock().unwrap().closed
    }

    /// Close at shutdown: future pushes panic, parked workers and
    /// producers all wake; buffered jobs stay poppable.
    fn close(&self) {
        self.state.lock().unwrap().closed = true;
        self.not_empty.notify_all();
        self.not_full.notify_all();
    }
}

/// Pool-wide epoch-counted wakeup for steal-enabled workers. A worker
/// snapshots the epoch *before* its scan (own queue, then every
/// neighbour); any enqueue or shutdown during the scan bumps past the
/// snapshot, so `wait_past` returns immediately instead of sleeping
/// through the event — lost-wakeup-free parking with no timeout and no
/// poll interval.
struct StealSignal {
    epoch: Mutex<u64>,
    cv: Condvar,
}

impl StealSignal {
    fn new() -> StealSignal {
        StealSignal { epoch: Mutex::new(0), cv: Condvar::new() }
    }

    fn epoch(&self) -> u64 {
        *self.epoch.lock().unwrap()
    }

    fn bump(&self) {
        *self.epoch.lock().unwrap() += 1;
        self.cv.notify_all();
    }

    fn wait_past(&self, seen: u64) {
        let mut e = self.epoch.lock().unwrap();
        while *e == seen {
            e = self.cv.wait(e).unwrap();
        }
    }
}

/// The fault-tolerant GEMM service.
///
/// ```
/// use std::sync::Arc;
/// use vabft::coordinator::{Coordinator, CoordinatorConfig, GemmRequest, PreparedGemmRequest};
/// use vabft::prelude::*;
///
/// let coord = Coordinator::start(CoordinatorConfig::default());
/// let mut rng = Xoshiro256pp::seed_from_u64(1);
/// let d = Distribution::normal_1_1();
/// let b = Matrix::sample_in(64, 32, &d, Precision::Bf16, &mut rng);
///
/// // Register once: checksum encoding + V-ABFT statistics cached (LRU).
/// let handle = coord.register_weights(7, &b);
///
/// // Request by id…
/// let a = Matrix::sample_in(8, 64, &d, Precision::Bf16, &mut rng);
/// let resp = coord.call(GemmRequest { a: a.clone(), weight: 7, inject: None });
/// let by_id = resp.result.unwrap();
/// assert_eq!(by_id.report.verdict, Verdict::Clean);
///
/// // …or by handle (no cache lookup, immune to eviction/re-registration).
/// let resp = coord.call_prepared(PreparedGemmRequest {
///     a,
///     weights: Arc::clone(&handle),
///     inject: None,
/// });
/// let by_handle = resp.result.unwrap();
/// assert_eq!(by_handle.c.data(), by_id.c.data()); // bitwise-identical
/// coord.shutdown();
/// ```
pub struct Coordinator {
    queues: Option<Vec<Arc<ShardQueue>>>,
    steal_signal: Arc<StealSignal>,
    steal: bool,
    handles: Vec<JoinHandle<()>>,
    shared: Arc<SharedWeights>,
    /// Kept so registration can clear every shard's read-through cache
    /// eagerly (see [`Coordinator::register_weights`]).
    shard_caches: Vec<Arc<ShardWeightCache>>,
    metrics: Arc<ServiceMetrics>,
    next_id: AtomicU64,
    ft_template: Arc<FtGemm>,
    block_k: Option<usize>,
    plan: ShardPlan,
}

/// Everything one worker thread needs (see [`worker_loop`]).
struct WorkerCtx {
    shard: usize,
    queues: Vec<Arc<ShardQueue>>,
    signal: Arc<StealSignal>,
    local: Arc<ShardWeightCache>,
    shared: Arc<SharedWeights>,
    metrics: Arc<ServiceMetrics>,
    ft: FtGemm,
    model: AccumModel,
    policy: VerifyPolicy,
    steal: bool,
}

impl Coordinator {
    /// Start the sharded worker pool per the config's [`ShardPlan`].
    pub fn start(cfg: CoordinatorConfig) -> Coordinator {
        let topology = cfg.topology.clone().unwrap_or_else(TopologyConfig::detect);
        // The plan clamps intra-op threads and assigns row splits from a
        // concrete ParallelismConfig; a unified engine config resolves to
        // one here (defaults for whatever it leaves unset).
        let base_par = match &cfg.engine {
            Some(e) => e.resolve(),
            None => cfg.parallelism,
        };
        let plan = ShardPlan::plan(cfg.shards, cfg.workers, base_par, cfg.partition, topology);
        let nshards = plan.shards.len();
        let shared = Arc::new(SharedWeights::new(cfg.weight_capacity));
        let metrics = Arc::new(ServiceMetrics::new());

        let queues: Vec<Arc<ShardQueue>> =
            (0..nshards).map(|_| Arc::new(ShardQueue::new(cfg.queue_depth.max(1)))).collect();
        let signal = Arc::new(StealSignal::new());
        let locals: Vec<Arc<ShardWeightCache>> =
            (0..nshards).map(|_| Arc::new(ShardWeightCache::default())).collect();

        let mut handles = Vec::new();
        for spec in &plan.shards {
            for wid in 0..spec.workers {
                let ctx = WorkerCtx {
                    shard: spec.shard,
                    queues: queues.clone(),
                    signal: Arc::clone(&signal),
                    local: Arc::clone(&locals[spec.shard]),
                    shared: Arc::clone(&shared),
                    metrics: Arc::clone(&metrics),
                    // With a unified engine config, keep it unresolved so
                    // each request's shape gets a manifest lookup — but pin
                    // the plan's thread clamp and row split, which the
                    // manifest must not override.
                    ft: FtGemm::new(
                        match &cfg.engine {
                            Some(e) => GemmEngine::with_config(
                                cfg.model,
                                e.clone()
                                    .threads(spec.parallelism.threads)
                                    .split(spec.parallelism.split),
                            ),
                            None => GemmEngine::with_parallelism(cfg.model, spec.parallelism),
                        },
                        (cfg.threshold)(),
                        cfg.policy,
                    ),
                    model: cfg.model,
                    policy: cfg.policy,
                    steal: cfg.steal && nshards > 1,
                };
                handles.push(
                    std::thread::Builder::new()
                        .name(format!("ftgemm-s{}-w{wid}", spec.shard))
                        .spawn(move || worker_loop(ctx))
                        .expect("spawn worker"),
                );
            }
        }
        let ft_template = Arc::new(FtGemm::new(
            match &cfg.engine {
                Some(e) => GemmEngine::with_config(cfg.model, e.clone()),
                None => GemmEngine::with_parallelism(cfg.model, cfg.parallelism),
            },
            (cfg.threshold)(),
            cfg.policy,
        ));
        Coordinator {
            queues: Some(queues),
            steal_signal: signal,
            steal: cfg.steal && nshards > 1,
            handles,
            shared,
            shard_caches: locals,
            metrics,
            next_id: AtomicU64::new(0),
            ft_template,
            block_k: cfg.block_k,
            plan,
        }
    }

    /// The shard layout this coordinator runs (topology groups, worker
    /// counts, per-shard engine configs).
    pub fn plan(&self) -> &ShardPlan {
        &self.plan
    }

    /// Number of shards.
    pub fn shards(&self) -> usize {
        self.plan.shards.len()
    }

    /// Register (or replace) a weight matrix: encodes checksums and
    /// precomputes the per-block threshold statistics once, inserts the
    /// result into the shared LRU cache under `id` (invalidating every
    /// shard's read-through cache), and returns the shared handle for
    /// direct (id-free) submission. Re-registering an id **replaces** the
    /// cached entry — later requests for the id never see state from the
    /// previous matrix.
    pub fn register_weights(&self, id: WeightId, b: &Matrix) -> WeightHandle {
        let prepared = Arc::new(match self.block_k {
            None => self.ft_template.prepare(b),
            Some(bk) => self.ft_template.prepare_blockwise(b, bk),
        });
        self.shared.insert(id, Arc::clone(&prepared));
        // Eagerly drop every shard's read-through entries (the generation
        // bump already invalidates them *logically*; clearing here also
        // releases the Arcs, so replaced/evicted PreparedWeights don't
        // stay pinned in shards whose later traffic is handle-only and
        // would never revalidate).
        for c in &self.shard_caches {
            c.map.lock().unwrap().clear();
        }
        prepared
    }

    /// Back-compat alias of [`Coordinator::register_weights`] (discarding
    /// the handle).
    pub fn register_weight(&self, id: WeightId, b: &Matrix) {
        let _ = self.register_weights(id, b);
    }

    /// Register a weight matrix under a protection-plan entry: the weight
    /// is prepared under the entry's scheme-derived policy (encoding,
    /// verification point, granularity) and the entry rides the handle,
    /// so workers dispatch each request to the planned verifier without
    /// re-consulting the planner. Inherits the coordinator policy's
    /// recovery knobs (correct / recompute / severity / …); the scheme
    /// only chooses *which verifier runs* (invariant #9).
    pub fn register_weights_planned(
        &self,
        id: WeightId,
        b: &Matrix,
        entry: &crate::planner::PlanEntry,
    ) -> WeightHandle {
        let policy = entry.scheme.policy(self.ft_template.policy());
        let engine = self.ft_template.engine();
        let prepared = match entry.scheme {
            crate::planner::ProtectionScheme::BlockK(bk) => {
                PreparedWeights::prepare_blockwise(b, engine, &policy, bk.max(1))
            }
            _ => PreparedWeights::prepare(b, engine, &policy),
        };
        let prepared = Arc::new(prepared.with_protection(entry.clone()));
        self.shared.insert(id, Arc::clone(&prepared));
        for c in &self.shard_caches {
            c.map.lock().unwrap().clear();
        }
        prepared
    }

    /// Whether `id` is currently resident in the shared weight cache (it
    /// may have been evicted by LRU pressure or never registered).
    pub fn weight_resident(&self, id: WeightId) -> bool {
        self.shared.cache.lock().unwrap().contains(id)
    }

    /// Number of weight matrices currently resident in the shared cache.
    pub fn weights_resident(&self) -> usize {
        self.shared.cache.lock().unwrap().len()
    }

    /// Submit a request; returns a receiver for the response. Blocks when
    /// the target shard's queue is full (backpressure).
    pub fn submit(&self, req: GemmRequest) -> Receiver<GemmResponse> {
        self.submit_tagged(req).1
    }

    /// Submit a request and also return the id its response will carry
    /// (`GemmResponse::id`) — the building block of [`Self::submit_batch`].
    pub fn submit_tagged(&self, req: GemmRequest) -> (u64, Receiver<GemmResponse>) {
        self.enqueue(Payload::ById(req))
    }

    /// Submit a handle-based request (see [`PreparedGemmRequest`]).
    pub fn submit_prepared(&self, req: PreparedGemmRequest) -> Receiver<GemmResponse> {
        self.submit_prepared_tagged(req).1
    }

    /// Handle-based variant of [`Self::submit_tagged`].
    pub fn submit_prepared_tagged(
        &self,
        req: PreparedGemmRequest,
    ) -> (u64, Receiver<GemmResponse>) {
        self.enqueue(Payload::Handle(req))
    }

    fn enqueue(&self, payload: Payload) -> (u64, Receiver<GemmResponse>) {
        let (reply_tx, reply_rx) = std::sync::mpsc::channel();
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        self.metrics.jobs_submitted.inc();
        let queues = self.queues.as_ref().expect("coordinator already shut down");
        // Deterministic round-robin routing: shard = id mod shards.
        let shard = (id % queues.len() as u64) as usize;
        queues[shard].push(Job { id, payload, reply: reply_tx, submitted: Instant::now() });
        if self.steal {
            // Wake parked steal-enabled workers on every enqueue: any of
            // them may legitimately serve this job.
            self.steal_signal.bump();
        }
        (id, reply_rx)
    }

    /// Non-blocking handle-based submit — the admission-control path of
    /// the open-loop traffic engine. Routes exactly like
    /// [`Self::submit_prepared_tagged`] (deterministic round-robin by
    /// submission id), but when the target shard's queue is full the
    /// request is *shed*: handed back in [`Admission::Shed`] with
    /// `jobs_shed` incremented and nothing computed. Note the submission
    /// id is consumed either way, so under shedding the id sequence has
    /// gaps (ids stay unique and monotone).
    pub fn try_submit_prepared(&self, req: PreparedGemmRequest) -> Admission {
        let (reply_tx, reply_rx) = std::sync::mpsc::channel();
        let queues = self.queues.as_ref().expect("coordinator already shut down");
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let shard = (id % queues.len() as u64) as usize;
        let job =
            Job { id, payload: Payload::Handle(req), reply: reply_tx, submitted: Instant::now() };
        match queues[shard].try_push(job) {
            Ok(()) => {
                self.metrics.jobs_submitted.inc();
                if self.steal {
                    self.steal_signal.bump();
                }
                Admission::Accepted(id, reply_rx)
            }
            Err(job) => {
                self.metrics.jobs_shed.inc();
                match job.payload {
                    Payload::Handle(req) => Admission::Shed(req),
                    Payload::ById(_) => unreachable!("try_submit_prepared enqueues handles only"),
                }
            }
        }
    }

    /// Batched submit: enqueue every request (in order, sharing the
    /// backpressure of the bounded per-shard queues) and return one
    /// `(id, receiver)` pair per request, in the same order. Requests of
    /// one batch fan out round-robin across the shards and complete
    /// independently; the ids tie the responses back to their requests.
    pub fn submit_batch(
        &self,
        reqs: Vec<GemmRequest>,
    ) -> Vec<(u64, Receiver<GemmResponse>)> {
        self.metrics.batches_submitted.inc();
        reqs.into_iter().map(|r| self.submit_tagged(r)).collect()
    }

    /// Handle-based variant of [`Self::submit_batch`]: enqueue every
    /// prepared request in order and return one `(id, receiver)` pair per
    /// request. The campaign engine's and replay workload's hot path —
    /// each batch rides against weights prepared once.
    pub fn submit_batch_prepared(
        &self,
        reqs: Vec<PreparedGemmRequest>,
    ) -> Vec<(u64, Receiver<GemmResponse>)> {
        self.metrics.batches_submitted.inc();
        reqs.into_iter().map(|r| self.submit_prepared_tagged(r)).collect()
    }

    /// Convenience: submit and wait.
    pub fn call(&self, req: GemmRequest) -> GemmResponse {
        self.submit(req).recv().expect("worker dropped reply")
    }

    /// Convenience: submit a handle-based request and wait.
    pub fn call_prepared(&self, req: PreparedGemmRequest) -> GemmResponse {
        self.submit_prepared(req).recv().expect("worker dropped reply")
    }

    /// Service counters and latency histograms.
    pub fn metrics(&self) -> &ServiceMetrics {
        &self.metrics
    }

    /// Drain every shard's queue and join all workers.
    pub fn shutdown(mut self) {
        self.halt();
    }

    fn halt(&mut self) {
        if let Some(queues) = self.queues.take() {
            for q in &queues {
                q.close();
            }
            // Wake parked steal-enabled workers so they observe closure.
            self.steal_signal.bump();
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.halt();
    }
}

/// Steal one queued job from any other shard, scanning neighbours in a
/// fixed rotation from this worker's shard. Each probe takes the target
/// queue's mutex only for the deque pop — never across a GEMM.
fn try_steal(ctx: &WorkerCtx) -> Option<Job> {
    let n = ctx.queues.len();
    for off in 1..n {
        if let Some(job) = ctx.queues[(ctx.shard + off) % n].try_pop() {
            return Some(job);
        }
    }
    None
}

fn worker_loop(ctx: WorkerCtx) {
    loop {
        match next_job(&ctx) {
            Some((job, stolen)) => process(&ctx, job, stolen),
            None => return,
        }
    }
}

/// Acquire this worker's next job: own queue first, then steal targets,
/// then **park** until something changes. Without stealing the worker
/// parks directly on its queue's `not_empty` condvar. With stealing it
/// parks on the pool-wide steal signal, whose epoch it snapshotted
/// *before* the scan — an enqueue (on any shard) or shutdown during the
/// scan bumps past the snapshot and the wait returns immediately, so no
/// wakeup can be lost and no polling interval exists. Returns `None` at
/// shutdown, after the own queue is fully drained (`try_pop` yields
/// every buffered job before the closed check) and a final steal sweep
/// found nothing; jobs still queued on other shards are drained by their
/// own workers.
///
/// Every queue lock is internal to one `ShardQueue` call, so a worker
/// never holds a queue lock while executing a GEMM.
fn next_job(ctx: &WorkerCtx) -> Option<(Job, bool)> {
    if !ctx.steal {
        return ctx.queues[ctx.shard].pop_wait().map(|j| (j, false));
    }
    let own = &ctx.queues[ctx.shard];
    loop {
        let seen = ctx.signal.epoch();
        if let Some(job) = own.try_pop() {
            return Some((job, false));
        }
        if let Some(job) = try_steal(ctx) {
            return Some((job, true));
        }
        if own.is_closed() {
            return None;
        }
        ctx.signal.wait_past(seen);
    }
}

/// Execute one job end to end: resolve weights, run the protected
/// multiply (with the request's injection, if any), record metrics, send
/// the reply.
fn process(ctx: &WorkerCtx, job: Job, stolen: bool) {
    // Resolve the request to (activation, prepared weights, injection).
    let resolved: Result<(Matrix, WeightHandle, Option<InjectSpec>), String> = match job.payload {
        Payload::ById(req) => match ctx.local.resolve(&ctx.shared, req.weight) {
            None => Err(format!("unknown or evicted weight id {}", req.weight)),
            Some(w) => Ok((req.a, w, req.inject)),
        },
        Payload::Handle(req) => Ok((req.a, req.weights, req.inject)),
    };
    let mut injected = None;
    let result = match resolved {
        Err(e) => Err(e),
        Ok((a, w, inject)) => {
            // Planned dispatch: a protection-plan entry riding the handle
            // swaps the verifier per request (invariant #9). The effective
            // policy derives from the entry's scheme, inheriting the
            // coordinator policy's recovery knobs; un-planned handles run
            // the coordinator policy untouched.
            let scheme = w.protection().map(|p| p.scheme);
            let eff = match scheme {
                Some(s) => s.policy(ctx.policy),
                None => ctx.policy,
            };
            let run = match inject {
                None => match scheme {
                    Some(crate::planner::ProtectionScheme::Replicate) => {
                        ctx.ft.multiply_replicated_with_policy(&a, &w, &eff, None)
                    }
                    Some(_) => ctx.ft.multiply_prepared_with_policy(&a, &w, &eff, None),
                    None => ctx.ft.multiply_prepared(&a, &w, None),
                },
                Some(spec) => {
                    let grid = if eff.online { ctx.model.work } else { ctx.model.out };
                    // Upsets strike the first K-block's partial only, even
                    // when the weights are prepared blockwise; a spec may
                    // carry several simultaneous faults (burst patterns).
                    // The first realized flip is recorded through a Cell
                    // because the injection hook is a shared (&dyn Fn)
                    // closure.
                    let outcome = std::cell::Cell::new(None);
                    let f = |bi: usize, out: &mut GemmOutput| {
                        if bi != 0 {
                            return;
                        }
                        if let Some(blk) = w.blocks().first() {
                            for fault in &spec.faults {
                                let o = apply_fault(
                                    fault,
                                    eff.online,
                                    ctx.model.input,
                                    grid,
                                    &a,
                                    &blk.stats.b,
                                    out,
                                );
                                if outcome.get().is_none() {
                                    outcome.set(Some(o));
                                }
                            }
                        }
                    };
                    let r = match scheme {
                        Some(crate::planner::ProtectionScheme::Replicate) => {
                            ctx.ft.multiply_replicated_with_policy(&a, &w, &eff, Some(&f))
                        }
                        Some(_) => ctx.ft.multiply_prepared_with_policy(&a, &w, &eff, Some(&f)),
                        None => ctx.ft.multiply_prepared(&a, &w, Some(&f)),
                    };
                    injected = outcome.get();
                    r
                }
            };
            run.map_err(|e| e.to_string())
        }
    };
    if let Ok(out) = &result {
        // Grid-direction telemetry is verdict-independent: partial grid
        // corrections can precede a recompute, and inconsistent
        // localizations occur on any multi-fault row. Both are zero on
        // clean runs.
        ctx.metrics.faults_corrected_grid.add(out.report.rows_corrected_grid as u64);
        ctx.metrics
            .inconsistent_localizations
            .add(out.report.inconsistent_localizations as u64);
        match out.report.verdict {
            Verdict::Clean => {}
            Verdict::Corrected | Verdict::CorrectedGrid => {
                ctx.metrics.faults_detected.add(out.report.detections.len() as u64);
                ctx.metrics
                    .faults_corrected
                    .add(out.report.detections.iter().filter(|d| d.corrected).count() as u64);
            }
            Verdict::Recomputed | Verdict::Flagged => {
                ctx.metrics.faults_detected.add(out.report.detections.len() as u64);
                ctx.metrics.rows_recomputed.add(out.report.rows_recomputed as u64);
                ctx.metrics.faults_waived.add(out.report.rows_waived as u64);
            }
            Verdict::Waived => {
                ctx.metrics.faults_detected.add(out.report.detections.len() as u64);
                ctx.metrics
                    .faults_corrected
                    .add(out.report.detections.iter().filter(|d| d.corrected).count() as u64);
                ctx.metrics.faults_waived.add(out.report.rows_waived as u64);
            }
        }
    }
    if stolen {
        ctx.metrics.jobs_stolen.inc();
    }
    ctx.metrics.jobs_completed.inc();
    let latency = job.submitted.elapsed();
    ctx.metrics.latency.record(latency);
    ctx.metrics.tail.record(latency);
    let _ = job.reply.send(GemmResponse { id: job.id, result, injected, latency });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::{Distribution, Xoshiro256pp};

    fn coordinator(workers: usize) -> (Coordinator, Matrix) {
        let cfg = CoordinatorConfig {
            workers,
            queue_depth: 16,
            model: AccumModel::wide(Precision::Bf16),
            ..Default::default()
        };
        let c = Coordinator::start(cfg);
        let mut rng = Xoshiro256pp::seed_from_u64(1);
        let b = Matrix::sample_in(
            64,
            32,
            &Distribution::normal_1_1(),
            Precision::Bf16,
            &mut rng,
        );
        c.register_weight(7, &b);
        (c, b)
    }

    fn activation(seed: u64) -> Matrix {
        let mut rng = Xoshiro256pp::seed_from_u64(seed);
        Matrix::sample_in(8, 64, &Distribution::normal_1_1(), Precision::Bf16, &mut rng)
    }

    #[test]
    fn clean_requests_round_trip() {
        let (c, _b) = coordinator(2);
        let resp = c.call(GemmRequest { a: activation(2), weight: 7, inject: None });
        let out = resp.result.expect("ok");
        assert_eq!(out.report.verdict, Verdict::Clean);
        assert_eq!(out.c.rows(), 8);
        assert_eq!(out.c.cols(), 32);
        assert_eq!(c.metrics().jobs_completed.get(), 1);
        c.shutdown();
    }

    #[test]
    fn unknown_weight_errors() {
        let (c, _b) = coordinator(1);
        let resp = c.call(GemmRequest { a: activation(3), weight: 99, inject: None });
        assert!(resp.result.is_err());
        c.shutdown();
    }

    #[test]
    fn injected_fault_is_detected_and_repaired() {
        let (c, _b) = coordinator(1);
        let resp = c.call(GemmRequest {
            a: activation(4),
            weight: 7,
            inject: Some(InjectSpec::output(2, 5, 13)),
        });
        let realized = resp.injected.expect("injection outcome reported");
        assert_ne!(realized.delta(), 0.0);
        let out = resp.result.expect("ok");
        assert_ne!(out.report.verdict, Verdict::Clean);
        assert!(c.metrics().faults_detected.get() >= 1);
        // online policy + bit 13 flip on fp32 accumulator → huge D1 →
        // localize + correct (or recompute); output must verify clean:
        let clean = c.call(GemmRequest { a: activation(4), weight: 7, inject: None });
        let cm = clean.result.unwrap().c;
        assert!(out.c.max_abs_diff(&cm) < 1e-2, "diff {}", out.c.max_abs_diff(&cm));
        c.shutdown();
    }

    #[test]
    fn many_concurrent_requests() {
        let (c, _b) = coordinator(4);
        let receivers: Vec<_> = (0..32)
            .map(|i| c.submit(GemmRequest { a: activation(10 + i), weight: 7, inject: None }))
            .collect();
        for r in receivers {
            let resp = r.recv().unwrap();
            assert!(resp.result.is_ok());
        }
        assert_eq!(c.metrics().jobs_completed.get(), 32);
        c.shutdown();
    }

    #[test]
    fn submit_batch_ids_match_responses() {
        let (c, _b) = coordinator(3);
        let reqs: Vec<GemmRequest> = (0..12)
            .map(|i| GemmRequest { a: activation(40 + i), weight: 7, inject: None })
            .collect();
        let pending = c.submit_batch(reqs);
        assert_eq!(pending.len(), 12);
        for (id, rx) in pending {
            let resp = rx.recv().unwrap();
            assert_eq!(resp.id, id, "response routed to the wrong receiver");
            assert!(resp.result.is_ok());
        }
        assert_eq!(c.metrics().batches_submitted.get(), 1);
        assert_eq!(c.metrics().jobs_completed.get(), 12);
        c.shutdown();
    }

    #[test]
    fn worker_parallelism_config_is_applied() {
        let cfg = CoordinatorConfig {
            workers: 1,
            parallelism: crate::gemm::ParallelismConfig::with_threads(2),
            ..Default::default()
        };
        let c = Coordinator::start(cfg);
        let mut rng = Xoshiro256pp::seed_from_u64(9);
        let b = Matrix::sample_in(64, 32, &Distribution::normal_1_1(), Precision::Bf16, &mut rng);
        c.register_weight(1, &b);
        // Same request through a serial coordinator must give bitwise the
        // same product (schedule preservation end to end).
        let (cs, _) = coordinator(1);
        cs.register_weight(1, &b);
        let a = activation(41);
        let x = c.call(GemmRequest { a: a.clone(), weight: 1, inject: None });
        let y = cs.call(GemmRequest { a, weight: 1, inject: None });
        let (x, y) = (x.result.unwrap().c, y.result.unwrap().c);
        assert_eq!(x.data(), y.data());
        c.shutdown();
        cs.shutdown();
    }

    #[test]
    fn worker_engine_config_is_applied() {
        // A unified engine config with a tuned entry for the request's
        // exact shape: workers must pick it up (shape-aware resolve) and
        // the output must stay bitwise-identical to the serial default —
        // manifest-driven tuning is pure scheduling.
        let mut manifest = crate::runtime::TuningManifest::new("test");
        manifest.push(crate::runtime::TunedShape {
            label: "test/shape".into(),
            m: 8,
            k: 64,
            n: 32,
            tiles: crate::gemm::TileConfig { mc: 32, kc: 32, nc: 16 },
            micro: crate::gemm::MicroConfig { mr: 4, nr: 8 },
            threads: 2,
            split: crate::gemm::RowSplit::Interleaved,
            simd: crate::gemm::SimdLevel::Auto,
            gflops: 1.0,
            baseline_gflops: 1.0,
        });
        let cfg = CoordinatorConfig {
            workers: 1,
            engine: Some(EngineConfig::new().manifest(manifest)),
            ..Default::default()
        };
        let c = Coordinator::start(cfg);
        let mut rng = Xoshiro256pp::seed_from_u64(9);
        let b = Matrix::sample_in(64, 32, &Distribution::normal_1_1(), Precision::Bf16, &mut rng);
        c.register_weight(1, &b);
        let (cs, _) = coordinator(1);
        cs.register_weight(1, &b);
        let a = activation(41);
        let x = c.call(GemmRequest { a: a.clone(), weight: 1, inject: None });
        let y = cs.call(GemmRequest { a, weight: 1, inject: None });
        let (x, y) = (x.result.unwrap().c, y.result.unwrap().c);
        assert_eq!(x.data(), y.data());
        c.shutdown();
        cs.shutdown();
    }

    #[test]
    fn weight_replacement_takes_effect() {
        let (c, b) = coordinator(1);
        // replace weight 7 with its negation; outputs should flip sign
        let mut neg = b.clone();
        for v in neg.data_mut() {
            *v = -*v;
        }
        let a = activation(5);
        let before = c.call(GemmRequest { a: a.clone(), weight: 7, inject: None });
        c.register_weight(7, &neg);
        let after = c.call(GemmRequest { a, weight: 7, inject: None });
        let x = before.result.unwrap().c;
        let y = after.result.unwrap().c;
        let mut maxsum = 0.0f64;
        for (p, q) in x.data().iter().zip(y.data()) {
            maxsum = maxsum.max((p + q).abs());
        }
        assert!(maxsum < 1e-6, "outputs should negate: {maxsum}");
        c.shutdown();
    }

    #[test]
    fn handle_requests_bypass_the_cache() {
        let (c, b) = coordinator(1);
        let handle = c.register_weights(8, &b);
        let a = activation(6);
        let by_id = c.call(GemmRequest { a: a.clone(), weight: 8, inject: None });
        let by_handle = c.call_prepared(PreparedGemmRequest {
            a: a.clone(),
            weights: Arc::clone(&handle),
            inject: None,
        });
        let (x, y) = (by_id.result.unwrap().c, by_handle.result.unwrap().c);
        assert_eq!(x.data(), y.data(), "id and handle paths must be bitwise-identical");
        // A handle outlives re-registration of its id.
        let mut other = b.clone();
        for v in other.data_mut() {
            *v = -*v;
        }
        c.register_weights(8, &other);
        let still = c.call_prepared(PreparedGemmRequest { a, weights: handle, inject: None });
        assert_eq!(still.result.unwrap().c.data(), x.data());
        c.shutdown();
    }

    #[test]
    fn sharded_coordinator_routes_round_robin_and_completes() {
        let cfg = CoordinatorConfig {
            workers: 1,
            shards: 3,
            topology: Some(TopologyConfig::uniform(1, 4)),
            ..Default::default()
        };
        let c = Coordinator::start(cfg);
        assert_eq!(c.shards(), 3);
        let mut rng = Xoshiro256pp::seed_from_u64(1);
        let b = Matrix::sample_in(64, 32, &Distribution::normal_1_1(), Precision::Bf16, &mut rng);
        c.register_weight(7, &b);
        let reqs: Vec<GemmRequest> = (0..9)
            .map(|i| GemmRequest { a: activation(70 + i), weight: 7, inject: None })
            .collect();
        let pending = c.submit_batch(reqs);
        for (id, rx) in pending {
            let resp = rx.recv().unwrap();
            assert_eq!(resp.id, id);
            assert!(resp.result.is_ok());
        }
        assert_eq!(c.metrics().jobs_completed.get(), 9);
        c.shutdown();
    }

    #[test]
    fn per_shard_cache_sees_reregistration() {
        // The generation bump must invalidate shard-local read-through
        // entries: a re-register between two id requests on the same
        // shard must flip the served weights.
        let cfg = CoordinatorConfig {
            workers: 1,
            shards: 2,
            topology: Some(TopologyConfig::uniform(1, 2)),
            ..Default::default()
        };
        let c = Coordinator::start(cfg);
        let mut rng = Xoshiro256pp::seed_from_u64(2);
        let b = Matrix::sample_in(64, 32, &Distribution::normal_1_1(), Precision::Bf16, &mut rng);
        c.register_weight(3, &b);
        let a = activation(80);
        // Warm both shards' read-through caches and keep a reference
        // product.
        let before = c
            .call(GemmRequest { a: a.clone(), weight: 3, inject: None })
            .result
            .unwrap()
            .c;
        assert!(c.call(GemmRequest { a: a.clone(), weight: 3, inject: None }).result.is_ok());
        let mut neg = b.clone();
        for v in neg.data_mut() {
            *v = -*v;
        }
        c.register_weight(3, &neg);
        // Both shards must now serve the negated weights: a stale
        // shard-local entry would reproduce `before` (its own checksums
        // are self-consistent, so only the product exposes staleness).
        for _ in 0..2 {
            let out = c.call(GemmRequest { a: a.clone(), weight: 3, inject: None }).result.unwrap();
            assert_eq!(out.report.verdict, Verdict::Clean);
            let mut maxsum = 0.0f64;
            for (p, q) in before.data().iter().zip(out.c.data()) {
                maxsum = maxsum.max((p + q).abs());
            }
            assert!(maxsum < 1e-6, "stale shard cache served old B: {maxsum}");
        }
        c.shutdown();
    }

    #[test]
    fn steal_enabled_pool_parks_idle_and_wakes_on_enqueue() {
        // Steal-enabled workers park on the pool-wide signal when idle; a
        // lost wakeup would hang the first recv below forever. Letting the
        // pool go fully idle between submissions exercises the
        // park-then-wake edge on every iteration.
        let cfg = CoordinatorConfig {
            workers: 1,
            shards: 2,
            steal: true,
            topology: Some(TopologyConfig::uniform(1, 2)),
            ..Default::default()
        };
        let c = Coordinator::start(cfg);
        let mut rng = Xoshiro256pp::seed_from_u64(3);
        let b = Matrix::sample_in(64, 32, &Distribution::normal_1_1(), Precision::Bf16, &mut rng);
        c.register_weight(5, &b);
        std::thread::sleep(std::time::Duration::from_millis(10));
        for i in 0..8 {
            let resp = c.call(GemmRequest { a: activation(60 + i), weight: 5, inject: None });
            assert!(resp.result.is_ok());
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
        assert_eq!(c.metrics().jobs_completed.get(), 8);
        c.shutdown();
    }

    #[test]
    fn try_push_hands_the_job_back_at_capacity() {
        let q = ShardQueue::new(1);
        let (tx, _rx) = std::sync::mpsc::channel();
        let job = |id| Job {
            id,
            payload: Payload::ById(GemmRequest { a: Matrix::zeros(1, 1), weight: 0, inject: None }),
            reply: tx.clone(),
            submitted: Instant::now(),
        };
        assert!(q.try_push(job(0)).is_ok());
        let back = q.try_push(job(1)).expect_err("depth-1 queue must refuse a second job");
        assert_eq!(back.id, 1, "the refused job must come back intact");
        // Draining frees the capacity again.
        assert_eq!(q.try_pop().expect("buffered job").id, 0);
        assert!(q.try_push(job(2)).is_ok());
    }

    #[test]
    fn open_loop_admission_sheds_instead_of_blocking() {
        // A depth-1 queue with one worker and a burst of 24 back-to-back
        // non-blocking submissions: most must shed (the worker cannot
        // drain multi-millisecond GEMMs at submission speed), none may
        // block, and the metrics must account for every request exactly
        // once as accepted or shed.
        let cfg = CoordinatorConfig { workers: 1, queue_depth: 1, ..Default::default() };
        let c = Coordinator::start(cfg);
        let mut rng = Xoshiro256pp::seed_from_u64(11);
        let d = Distribution::normal_1_1();
        let b = Matrix::sample_in(96, 96, &d, Precision::Bf16, &mut rng);
        let h = c.register_weights(1, &b);
        let a = Matrix::sample_in(96, 96, &d, Precision::Bf16, &mut rng);
        let mut pending = Vec::new();
        let mut shed = 0u64;
        for _ in 0..24 {
            let req =
                PreparedGemmRequest { a: a.clone(), weights: Arc::clone(&h), inject: None };
            match c.try_submit_prepared(req) {
                Admission::Accepted(id, rx) => pending.push((id, rx)),
                Admission::Shed(back) => {
                    // The shed request comes back untouched.
                    assert_eq!(back.a.data(), a.data());
                    shed += 1;
                }
            }
        }
        assert!(shed >= 1, "a depth-1 queue must shed under a 24-deep burst");
        assert_eq!(c.metrics().jobs_shed.get(), shed);
        assert_eq!(c.metrics().jobs_submitted.get(), pending.len() as u64);
        for (id, rx) in pending {
            let resp = rx.recv().expect("accepted requests must complete");
            assert_eq!(resp.id, id);
            assert!(resp.result.is_ok());
        }
        let snap = c.metrics().snapshot();
        assert_eq!(snap.jobs_completed + snap.jobs_shed, 24);
        c.shutdown();
    }

    // LRU eviction semantics (capacity, recency refresh, evicted-id
    // errors, handle survival) are pinned by the richer integration test
    // `tests/weight_cache.rs::lru_eviction_errors_by_id_but_handles_survive`.
}
