//! Training supervisor: the end-to-end validation driver.
//!
//! Runs the AOT-compiled L2 train step (a GPT-style transformer whose
//! matmuls route through the L1 fused ABFT-GEMM Pallas kernel) from Rust,
//! supervising every step's verification signal:
//!
//! * the artifact returns, besides the updated parameters and the loss,
//!   the maximum verification ratio `max_i |E_i| / T_i` across every
//!   protected GEMM in the model — fused-kernel (online) ABFT, computed on
//!   the FP32 accumulator before any quantization (paper §3.6);
//! * a ratio > 1 means some row tripped its V-ABFT threshold: the
//!   supervisor discards the step's updates and re-executes (a transient
//!   SEU does not repeat), keeping the loss curve clean;
//! * faults are injected through a dedicated kernel input (layer/row/col/
//!   delta), emulating a compute SEU inside a designated GEMM.
//!
//! The artifact contract (see `python/compile/aot.py`):
//! inputs  `[p_0 … p_{P-1}, tokens i32[B,S+1], lr f32[], fault f32[4]]`,
//! outputs `[p'_0 … p'_{P-1}, loss f32[], ratio f32[]]`,
//! manifest metadata `n_params=P`, `param<i>=<dims>`, `batch=B,S+1`.

mod data;
pub use data::SyntheticCorpus;

use crate::anyhow;
use crate::error::{Context, Result};

use crate::rng::{Rng, Xoshiro256pp};
use crate::runtime::{literal_f32, literal_i32, ArtifactEntry, PjrtRuntime};

/// Supervisor configuration.
#[derive(Debug, Clone)]
pub struct TrainerConfig {
    /// Manifest name of the train-step artifact.
    pub artifact: String,
    /// Learning rate passed to the step.
    pub lr: f32,
    /// Parameter-initialization seed.
    pub seed: u64,
    /// Discard + re-execute steps whose verification ratio exceeds 1.
    pub rollback_on_detection: bool,
}

impl Default for TrainerConfig {
    fn default() -> Self {
        TrainerConfig {
            artifact: "train_step".to_string(),
            lr: 3e-2,
            seed: 42,
            rollback_on_detection: true,
        }
    }
}

/// A fault to inject into one protected GEMM of the step.
#[derive(Debug, Clone, Copy)]
pub struct StepFault {
    /// Which protected GEMM (kernel call index) to corrupt.
    pub gemm_index: usize,
    /// Accumulator row to corrupt.
    pub row: usize,
    /// Accumulator column to corrupt.
    pub col: usize,
    /// Additive corruption of the FP32 accumulator element.
    pub delta: f32,
}

/// Outcome of one supervised step.
#[derive(Debug, Clone, Copy)]
pub struct StepOutcome {
    /// The step's loss.
    pub loss: f32,
    /// max over protected GEMMs and rows of |E| / T.
    pub ratio: f32,
    /// Whether the parameter update was applied.
    pub applied: bool,
    /// Whether the step was re-executed after a detection.
    pub retried: bool,
}

/// The training supervisor.
pub struct Trainer<'rt> {
    rt: &'rt PjrtRuntime,
    cfg: TrainerConfig,
    entry: ArtifactEntry,
    params: Vec<Vec<f32>>,
    shapes: Vec<Vec<i64>>,
    /// tokens shape [B, S+1]
    batch_shape: Vec<i64>,
    /// Steps executed (including re-executions).
    pub steps_run: usize,
    /// Steps whose verification ratio tripped.
    pub detections: usize,
}

impl<'rt> Trainer<'rt> {
    /// Set up from the runtime's manifest and initialize parameters.
    pub fn new(rt: &'rt PjrtRuntime, cfg: TrainerConfig) -> Result<Trainer<'rt>> {
        let entry = rt
            .manifest()
            .get(&cfg.artifact)
            .ok_or_else(|| anyhow!("artifact '{}' not in manifest", cfg.artifact))?
            .clone();
        crate::ensure!(rt.has(&cfg.artifact), "artifact '{}' not compiled", cfg.artifact);
        let n_params: usize = entry
            .meta_parse("n_params")
            .ok_or_else(|| anyhow!("manifest missing n_params"))?;
        let mut shapes = Vec::with_capacity(n_params);
        for i in 0..n_params {
            let dims = entry
                .meta_dims(&format!("param{i}"))
                .ok_or_else(|| anyhow!("manifest missing param{i}"))?;
            shapes.push(dims.into_iter().map(|d| d as i64).collect::<Vec<i64>>());
        }
        let batch_shape: Vec<i64> = entry
            .meta_dims("batch")
            .ok_or_else(|| anyhow!("manifest missing batch"))?
            .into_iter()
            .map(|d| d as i64)
            .collect();

        let mut rng = Xoshiro256pp::seed_from_u64(cfg.seed);
        let params = shapes
            .iter()
            .map(|dims| init_tensor(dims, &mut rng))
            .collect();
        Ok(Trainer {
            rt,
            cfg,
            entry,
            params,
            shapes,
            batch_shape,
            steps_run: 0,
            detections: 0,
        })
    }

    /// Batch size and sequence length expected by the artifact
    /// (tokens shape is [B, S+1]: inputs plus next-token targets).
    pub fn batch_dims(&self) -> (usize, usize) {
        (self.batch_shape[0] as usize, self.batch_shape[1] as usize - 1)
    }

    /// The train-step artifact's manifest entry.
    pub fn entry(&self) -> &ArtifactEntry {
        &self.entry
    }

    /// Current parameter tensors (flat, one per shape).
    pub fn params(&self) -> &[Vec<f32>] {
        &self.params
    }

    /// Shapes of the parameter tensors.
    pub fn param_shapes(&self) -> &[Vec<i64>] {
        &self.shapes
    }

    /// Corrupt one stored parameter element (memory-SEU experiment hook).
    pub fn flip_param_bit(&mut self, tensor: usize, index: usize, bit: u32) {
        let v = self.params[tensor][index];
        self.params[tensor][index] = f32::from_bits(v.to_bits() ^ (1 << bit));
    }

    /// Run one supervised step on a token batch (`tokens.len()` must be
    /// B·(S+1)).
    pub fn step(&mut self, tokens: &[i32], fault: Option<StepFault>) -> Result<StepOutcome> {
        let (outs, loss, ratio) = self.execute(tokens, fault)?;
        self.steps_run += 1;
        let detected = ratio > 1.0 || !ratio.is_finite();
        if !detected {
            self.apply_updates(outs);
            return Ok(StepOutcome { loss, ratio, applied: true, retried: false });
        }
        self.detections += 1;
        if !self.cfg.rollback_on_detection {
            // Unprotected mode: apply the corrupted update anyway (the
            // "what would have happened" baseline for the experiments).
            self.apply_updates(outs);
            return Ok(StepOutcome { loss, ratio, applied: true, retried: false });
        }
        // Detection: discard, re-execute without the transient fault.
        let (outs2, loss2, ratio2) = self.execute(tokens, None)?;
        crate::ensure!(
            ratio2 <= 1.0,
            "verification still failing after re-execution (ratio {ratio2})"
        );
        self.apply_updates(outs2);
        Ok(StepOutcome { loss: loss2, ratio, applied: true, retried: true })
    }

    fn execute(
        &self,
        tokens: &[i32],
        fault: Option<StepFault>,
    ) -> Result<(Vec<Vec<f32>>, f32, f32)> {
        let mut inputs: Vec<(&[f32], &[i64])> = Vec::with_capacity(self.params.len() + 3);
        for (p, s) in self.params.iter().zip(&self.shapes) {
            inputs.push((p.as_slice(), s.as_slice()));
        }
        let fault_vec: [f32; 4] = match fault {
            None => [-1.0, 0.0, 0.0, 0.0],
            Some(f) => [f.gemm_index as f32, f.row as f32, f.col as f32, f.delta],
        };
        let lr = [self.cfg.lr];

        // Mixed dtypes: build literals directly.
        let mut literals = Vec::with_capacity(inputs.len() + 3);
        for (data, dims) in &inputs {
            literals.push(literal_f32(data, dims)?);
        }
        literals.push(literal_i32(tokens, &self.batch_shape)?);
        literals.push(literal_f32(&lr, &[])?);
        literals.push(literal_f32(&fault_vec, &[4])?);

        let outs = self
            .rt
            .execute(&self.cfg.artifact, &literals)
            .context("train step execution")?;
        crate::ensure!(
            outs.len() == self.params.len() + 2,
            "expected {} outputs, got {}",
            self.params.len() + 2,
            outs.len()
        );
        let mut new_params = Vec::with_capacity(self.params.len());
        for lit in outs.iter().take(self.params.len()) {
            new_params.push(lit.to_vec::<f32>().map_err(|e| anyhow!("param out: {e:?}"))?);
        }
        let loss: f32 = outs[self.params.len()]
            .to_vec::<f32>()
            .map_err(|e| anyhow!("loss out: {e:?}"))?[0];
        let ratio: f32 = outs[self.params.len() + 1]
            .to_vec::<f32>()
            .map_err(|e| anyhow!("ratio out: {e:?}"))?[0];
        Ok((new_params, loss, ratio))
    }

    fn apply_updates(&mut self, new_params: Vec<Vec<f32>>) {
        self.params = new_params;
    }
}

/// Scaled-normal initialization: N(0, 1/√fan_in) for matrices, N(0, 0.02)
/// for embeddings/vectors.
fn init_tensor(dims: &[i64], rng: &mut impl Rng) -> Vec<f32> {
    let n: i64 = dims.iter().product();
    let std = if dims.len() >= 2 {
        1.0 / (dims[0] as f64).sqrt()
    } else {
        0.02
    };
    (0..n).map(|_| (rng.standard_normal() * std) as f32).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn init_tensor_scales_with_fan_in() {
        let mut rng = Xoshiro256pp::seed_from_u64(1);
        let t = init_tensor(&[400, 100], &mut rng);
        assert_eq!(t.len(), 40_000);
        let var: f64 =
            t.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>() / t.len() as f64;
        assert!((var - 1.0 / 400.0).abs() < 2e-4, "var {var}");
    }

    #[test]
    fn fault_encoding() {
        let f = StepFault { gemm_index: 2, row: 3, col: 5, delta: 8.0 };
        // mirrors the encoding in execute()
        let v = [f.gemm_index as f32, f.row as f32, f.col as f32, f.delta];
        assert_eq!(v, [2.0, 3.0, 5.0, 8.0]);
    }
}
