//! Synthetic training corpus for the end-to-end driver.
//!
//! Byte-level sequences drawn from a randomized affine-recurrence language:
//! within a sequence, `x_{t+1} = (a·x_t + b) mod V` with per-sequence
//! (a, b) drawn from a small dictionary, plus occasional uniform noise
//! tokens. A transformer learns this quickly, giving a visibly decreasing
//! loss in a few hundred steps — exactly what the training-supervisor
//! experiment needs to show fault-induced loss spikes vs. protected runs.

use crate::rng::{Rng, Xoshiro256pp};

/// Deterministic synthetic corpus generator.
pub struct SyntheticCorpus {
    vocab: usize,
    rng: Xoshiro256pp,
    /// Dictionary of (a, b) recurrence parameters.
    rules: Vec<(u64, u64)>,
    noise: f64,
}

impl SyntheticCorpus {
    /// Corpus over `vocab` tokens with a seeded rule dictionary.
    pub fn new(vocab: usize, seed: u64) -> SyntheticCorpus {
        let mut rng = Xoshiro256pp::seed_from_u64(seed);
        let rules = (0..4)
            .map(|_| {
                // odd multipliers are invertible mod 2^k vocab sizes
                (rng.uniform_u64(vocab as u64 / 2) * 2 + 1, rng.uniform_u64(vocab as u64))
            })
            .collect();
        SyntheticCorpus { vocab, rng, rules, noise: 0.02 }
    }

    /// One batch of token sequences, shape B×(S+1) flattened row-major
    /// (the +1 column provides next-token targets).
    pub fn batch(&mut self, b: usize, s_plus_1: usize) -> Vec<i32> {
        let mut out = Vec::with_capacity(b * s_plus_1);
        for _ in 0..b {
            let (a, c) = self.rules[self.rng.uniform_u64(self.rules.len() as u64) as usize];
            let mut x = self.rng.uniform_u64(self.vocab as u64);
            for _ in 0..s_plus_1 {
                out.push(x as i32);
                x = if self.rng.next_f64() < self.noise {
                    self.rng.uniform_u64(self.vocab as u64)
                } else {
                    (a.wrapping_mul(x).wrapping_add(c)) % self.vocab as u64
                };
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batch_shape_and_range() {
        let mut c = SyntheticCorpus::new(256, 7);
        let b = c.batch(4, 65);
        assert_eq!(b.len(), 4 * 65);
        assert!(b.iter().all(|&t| (0..256).contains(&t)));
    }

    #[test]
    fn sequences_are_mostly_predictable() {
        // Each sequence follows one affine rule except noise positions:
        // verify ≥90% of transitions match one of the dictionary rules.
        let mut c = SyntheticCorpus::new(256, 8);
        let rules = c.rules.clone();
        let s = 65;
        let batch = c.batch(8, s);
        let mut hits = 0;
        let mut total = 0;
        for seq in batch.chunks(s) {
            for w in seq.windows(2) {
                total += 1;
                if rules
                    .iter()
                    .any(|&(a, b)| (a.wrapping_mul(w[0] as u64).wrapping_add(b)) % 256 == w[1] as u64)
                {
                    hits += 1;
                }
            }
        }
        assert!(hits as f64 / total as f64 > 0.9, "{hits}/{total}");
    }

    #[test]
    fn deterministic_given_seed() {
        let mut a = SyntheticCorpus::new(128, 1);
        let mut b = SyntheticCorpus::new(128, 1);
        assert_eq!(a.batch(2, 17), b.batch(2, 17));
    }
}
