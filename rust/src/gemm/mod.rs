//! GEMM engines with pluggable accumulation models.
//!
//! The paper's central empirical claim about e_max (§3.6) is that the
//! verification error of a GEMM is determined by *where rounding happens*:
//!
//! | Paper platform              | Rounding schedule                     | e_max behaviour        |
//! |-----------------------------|---------------------------------------|------------------------|
//! | CPU (Xeon, FMA/SIMD)        | tree-shaped reduction, depth log K    | ≈ constant (4–6u)      |
//! | GPU H100 FP32/FP64          | per-step rounding along K             | ∝ √N                   |
//! | GPU/NPU BF16/FP16/FP8       | FP32 accumulate, round once at output | ≈ 2·u_output, constant |
//! | NPU 910B FP32               | per-step rounding in FP32             | ∝ √N                   |
//!
//! [`AccumModel`] encodes a schedule as (input precision, work precision,
//! reduction strategy, output precision); [`GemmEngine`] executes it. The
//! engine returns both the output-rounded matrix and the pre-quantization
//! accumulator (`GemmOutput::acc`) so the ABFT layer can implement both
//! *offline* verification (on the stored low-precision C) and *online /
//! fused-kernel* verification (on the FP32 accumulator, §3.6) — the 1000×
//! detection-granularity result.
//!
//! ## Execution: the packed, register-blocked parallel engine
//!
//! Engine execution is delegated to [`tiled`]: an (MC, KC, NC)
//! cache-blocked, [`std::thread::scope`]-parallel engine whose inner
//! loops run on *packed* operand panels ([`pack`]) through MR×NR
//! register-blocked microkernels ([`micro`], runtime-dispatched to
//! explicit SIMD variants by [`simd`]), configured by the
//! [`EngineConfig`] builder (`GemmEngine::with_config`). Its contract
//! is **schedule preservation**: results are bitwise-identical to the
//! naive reference kernels in [`kernels`] for every strategy, tile
//! shape, microkernel shape and thread count, because parallelism,
//! blocking and register tiling are applied only across output
//! rows/columns — never across K inside one element's reduction chain.
//! The rounding-schedule table above (and every calibrated e_max)
//! therefore holds unchanged on the parallel engine; "make it faster"
//! means tuning [`TileConfig`]/[`MicroConfig`] and thread counts, not
//! re-deriving thresholds. The invariant is locked in by
//! `tests/tiled_equivalence.rs` and the CI microkernel smoke bench.

pub mod autotune;
pub mod config;
pub mod exact;
pub mod kernels;
pub mod micro;
pub mod pack;
pub mod simd;
pub mod tiled;

pub use autotune::{AutotuneConfig, AutotuneMode};
pub use config::EngineConfig;
pub use simd::{cpu_features, SimdLevel};
pub use tiled::{MicroConfig, ParallelismConfig, RowSplit, TileConfig};

use crate::fp::Precision;
use crate::matrix::Matrix;

/// How a sum over K (or N) is reduced.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ReduceStrategy {
    /// Strict left-to-right accumulation; one rounding for the product and
    /// one for the add per step. Error ∝ √K. (GPU FP32/FP64, NPU FP32.)
    Sequential,
    /// Fused multiply-add: one rounding per step. Error ∝ √K, smaller
    /// constant. (Ablation of the CPU model.)
    Fma,
    /// Adjacent-pair tree reduction; depth ⌈log₂K⌉, near-constant error.
    /// (CPU SIMD/blocked model.)
    Pairwise,
}

impl ReduceStrategy {
    /// Short lowercase name used in CLIs and reports.
    pub fn name(self) -> &'static str {
        match self {
            ReduceStrategy::Sequential => "sequential",
            ReduceStrategy::Fma => "fma",
            ReduceStrategy::Pairwise => "pairwise",
        }
    }
}

/// A complete accumulation model: the rounding schedule of one platform.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct AccumModel {
    /// Precision the *operands* are stored in. Operands are quantized onto
    /// this grid before the multiply (a no-op if they are already on it).
    pub input: Precision,
    /// Precision of the multiply-accumulate datapath.
    pub work: Precision,
    /// Reduction order within the datapath.
    pub strategy: ReduceStrategy,
    /// Precision the result is rounded to when written back.
    pub out: Precision,
}

impl AccumModel {
    /// CPU (Xeon) model: tree reduction in the operand precision.
    /// Reproduces Table 2's "≈ constant" e_max rows.
    pub fn cpu(p: Precision) -> AccumModel {
        AccumModel { input: p, work: p, strategy: ReduceStrategy::Pairwise, out: p }
    }

    /// GPU high-precision model (H100 FP32/FP64): per-step rounding.
    /// Reproduces Table 2's "∝ √N" rows.
    pub fn gpu_highprec(p: Precision) -> AccumModel {
        AccumModel { input: p, work: p, strategy: ReduceStrategy::Sequential, out: p }
    }

    /// NPU (Ascend 910B) FP32 model: per-step FP32 rounding (Table 1 row 3).
    pub fn npu_fp32() -> AccumModel {
        Self::gpu_highprec(Precision::F32)
    }

    /// Mixed-precision ("wide") accumulation: low-precision inputs, FP32
    /// accumulate, one output rounding — the GPU/NPU BF16/FP16 model with
    /// e_max ≈ 2·u_out (Tables 1, 2 and 7).
    pub fn wide(low: Precision) -> AccumModel {
        AccumModel {
            input: low,
            work: Precision::F32,
            strategy: ReduceStrategy::Sequential,
            out: low,
        }
    }

    /// FP8 model: FP8 inputs, FP32 accumulate, **FP16 output** — §3.6's
    /// observation that FP8 GEMM inherits FP16's e_max.
    pub fn fp8(input: Precision) -> AccumModel {
        assert!(
            matches!(input, Precision::F8E4M3 | Precision::F8E5M2),
            "fp8 model needs an FP8 input format"
        );
        AccumModel {
            input,
            work: Precision::F32,
            strategy: ReduceStrategy::Sequential,
            out: Precision::F16,
        }
    }

    /// True if the output rounding step actually loses information
    /// (out coarser than work) — the regime where online (pre-quantization)
    /// verification beats offline by ~1000× (§3.6).
    pub fn quantizes_output(&self) -> bool {
        self.out.mantissa_bits() < self.work.mantissa_bits()
    }

    /// Human-readable label for reports.
    pub fn label(&self) -> String {
        if self.input == self.work && self.work == self.out {
            format!("{}[{}]", self.work.name(), self.strategy.name())
        } else {
            format!(
                "{}->{}[{}]->{}",
                self.input.name(),
                self.work.name(),
                self.strategy.name(),
                self.out.name()
            )
        }
    }
}

/// What the fused verification epilogue needs to check one encoded
/// product row while it is still the raw work-precision accumulator:
/// the number of data columns (the encoded row carries the r1/r2
/// checksums at positions `n` and `n + 1`), the position-weight vector
/// `[1, …, n]`, and one detection threshold per output row.
///
/// Borrowed, not owned — the ABFT pipeline resolves thresholds per
/// K-block *before* the multiply and lends them to the engine for the
/// duration of the fused call.
#[derive(Debug, Clone, Copy)]
pub struct FusedProbe<'a> {
    /// Number of data columns (checksums live at `n` and `n + 1`).
    pub n: usize,
    /// Position weights `[1, …, n]` (length `n`).
    pub weights: &'a [f64],
    /// Per-row detection thresholds (length = output rows).
    pub thresholds: &'a [f64],
}

/// One row's fused verification measurements, produced in the packed
/// microkernel epilogue (pre-quantization). Field semantics match
/// [`crate::abft::verify::RowCheck`] — same reductions, same schedule,
/// same comparison — plus the row index, because epilogue rows complete
/// in worker-dependent order.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FusedRowCheck {
    /// Output row this check belongs to.
    pub row: usize,
    /// D1 = recomputed row sum − checksum ≈ fault magnitude.
    pub d1: f64,
    /// D2 = recomputed weighted row sum − weighted checksum.
    pub d2: f64,
    /// The detection threshold |D1| was compared against.
    pub threshold: f64,
    /// |D1| > threshold (or D1 non-finite).
    pub flagged: bool,
}

/// Check one completed accumulator row against the probe — the exact
/// arithmetic of `abft::verify::check_row` (same `reduce_in`/`dot_in`
/// schedule, same subtraction, same comparison), applied in the fused
/// epilogue instead of after the product is materialized.
fn fused_check_row(
    row: &[f64],
    probe: &FusedProbe<'_>,
    work: Precision,
    strategy: ReduceStrategy,
    i: usize,
) -> FusedRowCheck {
    debug_assert!(row.len() >= probe.n + 2);
    let data = &row[..probe.n];
    let rowsum = reduce_in(data, work, strategy);
    let wsum = dot_in(data, probe.weights, work, strategy);
    let d1 = rowsum - row[probe.n];
    let d2 = wsum - row[probe.n + 1];
    let threshold = probe.thresholds[i];
    let flagged = !d1.is_finite() || d1.abs() > threshold;
    FusedRowCheck { row: i, d1, d2, threshold, flagged }
}

/// Result of a modelled GEMM.
#[derive(Debug, Clone)]
pub struct GemmOutput {
    /// The result as written back: rounded to `model.out`.
    pub c: Matrix,
    /// The pre-output-rounding accumulator (in `model.work` precision).
    /// Equal to `c` when the model does not quantize its output.
    pub acc: Matrix,
}

/// Executes GEMMs and reductions under an [`AccumModel`], on the tiled
/// parallel engine ([`tiled`]).
///
/// Execution is configured by an [`EngineConfig`]: each GEMM call
/// resolves it *for that call's shape* ([`EngineConfig::resolve_for`]),
/// so an engine built with `EngineConfig::auto()` picks tuned blocking
/// per layer shape from the tuning manifest. Resolution is pure
/// scheduling — results are bitwise-identical whatever it returns.
#[derive(Debug, Clone)]
pub struct GemmEngine {
    model: AccumModel,
    config: EngineConfig,
}

impl GemmEngine {
    /// Serial engine (1 worker, default tiles). Numerically identical to
    /// every other [`EngineConfig`] by the schedule-preservation
    /// invariant.
    pub fn new(model: AccumModel) -> GemmEngine {
        GemmEngine { model, config: EngineConfig::new() }
    }

    /// Engine with an execution configuration builder.
    pub fn with_config(model: AccumModel, config: EngineConfig) -> GemmEngine {
        GemmEngine { model, config }
    }

    /// Engine with a fully-pinned execution configuration (every field of
    /// `par` is explicit; no manifest lookups happen).
    pub fn with_parallelism(model: AccumModel, par: ParallelismConfig) -> GemmEngine {
        Self::with_config(model, par.into())
    }

    /// The accumulation model this engine executes.
    pub fn model(&self) -> AccumModel {
        self.model
    }

    /// The execution configuration builder.
    pub fn config(&self) -> &EngineConfig {
        &self.config
    }

    /// The execution configuration, resolved shape-blind
    /// ([`EngineConfig::resolve`]). Per-call resolution may differ when a
    /// tuning manifest is attached.
    pub fn parallelism(&self) -> ParallelismConfig {
        self.config.resolve()
    }

    /// Swap the execution configuration (does not change results).
    pub fn set_config(&mut self, config: EngineConfig) {
        self.config = config;
    }

    /// Swap in a fully-pinned execution configuration (does not change
    /// results).
    pub fn set_parallelism(&mut self, par: ParallelismConfig) {
        self.config = par.into();
    }

    /// C = A·B under the engine's accumulation model.
    pub fn matmul(&self, a: &Matrix, b: &Matrix) -> GemmOutput {
        self.matmul_mixed(a, b, 0)
    }

    /// C = A·B where the last `b_wide_cols` columns of B are kept in the
    /// *work* precision instead of being quantized to the input grid —
    /// the fused-kernel ABFT configuration in which checksum encodings
    /// never leave the FP32 datapath (§3.6). `b_wide_cols = 0` is a plain
    /// modelled GEMM.
    pub fn matmul_mixed(&self, a: &Matrix, b: &Matrix, b_wide_cols: usize) -> GemmOutput {
        assert_eq!(a.cols(), b.rows(), "GEMM shape mismatch {}x{} · {}x{}",
            a.rows(), a.cols(), b.rows(), b.cols());
        assert!(b_wide_cols <= b.cols());
        let m = self.model;
        let (rows, k, cols) = (a.rows(), a.cols(), b.cols());

        // 1. Quantize operands to the input grid (no-op when already
        //    there); wide B columns quantize to the work grid instead.
        let aq = quantize_data(a.data(), m.input);
        let bq = if b_wide_cols == 0 {
            quantize_data(b.data(), m.input)
        } else {
            let split = cols - b_wide_cols;
            let mut out = Vec::with_capacity(b.data().len());
            for r in 0..k {
                let row = b.row(r);
                out.extend(row[..split].iter().map(|&x| m.input.quantize(x)));
                out.extend(row[split..].iter().map(|&x| m.work.quantize(x)));
            }
            out
        };

        // 2. Multiply-accumulate in the work precision, on the tiled
        //    parallel engine (bitwise-equal to the reference kernels).
        //    The execution config resolves per shape (pure scheduling).
        let par = self.config.resolve_for(rows, k, cols);
        let acc_data: Vec<f64> = match m.work {
            Precision::F64 => tiled::gemm_f64(&aq, &bq, rows, k, cols, m.strategy, &par),
            Precision::F32 => {
                let a32 = kernels::to_f32_vec(&aq);
                let b32 = kernels::to_f32_vec(&bq);
                let c = tiled::gemm_f32(&a32, &b32, rows, k, cols, m.strategy, &par);
                c.into_iter().map(|x| x as f64).collect()
            }
            other => tiled::gemm_generic(&aq, &bq, rows, k, cols, other, m.strategy, &par),
        };
        let acc = Matrix::from_vec(rows, cols, acc_data);

        // 3. Round the write-back to the output precision.
        let c = if m.quantizes_output() || m.out != m.work {
            acc.quantized(m.out)
        } else {
            acc.clone()
        };
        GemmOutput { c, acc }
    }

    /// [`GemmEngine::matmul_mixed`] for 2D-encoded operands: the last
    /// `a_wide_rows` rows of A (the A-side column-checksum rows) are kept
    /// in the *work* precision instead of being quantized to the input
    /// grid, exactly as the wide B columns are. Every data row of A and
    /// every column of B follows the same quantization and reduction
    /// schedule as [`GemmEngine::matmul_mixed`], so the leading
    /// `rows − a_wide_rows` output rows are bitwise-identical to the
    /// row-only encoding's product — the checksum rows ride along without
    /// touching any data element's rounding schedule.
    pub fn matmul_mixed_2d(
        &self,
        a: &Matrix,
        b: &Matrix,
        b_wide_cols: usize,
        a_wide_rows: usize,
    ) -> GemmOutput {
        assert_eq!(a.cols(), b.rows(), "GEMM shape mismatch {}x{} · {}x{}",
            a.rows(), a.cols(), b.rows(), b.cols());
        assert!(b_wide_cols <= b.cols());
        assert!(a_wide_rows <= a.rows());
        let m = self.model;
        let (rows, k, cols) = (a.rows(), a.cols(), b.cols());

        // Operand quantization mirrors matmul_mixed: data elements go to
        // the input grid, checksum rows/columns to the work grid. A is
        // row-major, so the wide A rows are one trailing slice.
        let aq = if a_wide_rows == 0 {
            quantize_data(a.data(), m.input)
        } else {
            let split = (rows - a_wide_rows) * k;
            let mut out = Vec::with_capacity(a.data().len());
            out.extend(a.data()[..split].iter().map(|&x| m.input.quantize(x)));
            out.extend(a.data()[split..].iter().map(|&x| m.work.quantize(x)));
            out
        };
        let bq = if b_wide_cols == 0 {
            quantize_data(b.data(), m.input)
        } else {
            let split = cols - b_wide_cols;
            let mut out = Vec::with_capacity(b.data().len());
            for r in 0..k {
                let row = b.row(r);
                out.extend(row[..split].iter().map(|&x| m.input.quantize(x)));
                out.extend(row[split..].iter().map(|&x| m.work.quantize(x)));
            }
            out
        };

        let par = self.config.resolve_for(rows, k, cols);
        let acc_data: Vec<f64> = match m.work {
            Precision::F64 => tiled::gemm_f64(&aq, &bq, rows, k, cols, m.strategy, &par),
            Precision::F32 => {
                let a32 = kernels::to_f32_vec(&aq);
                let b32 = kernels::to_f32_vec(&bq);
                let c = tiled::gemm_f32(&a32, &b32, rows, k, cols, m.strategy, &par);
                c.into_iter().map(|x| x as f64).collect()
            }
            other => tiled::gemm_generic(&aq, &bq, rows, k, cols, other, m.strategy, &par),
        };
        let acc = Matrix::from_vec(rows, cols, acc_data);
        let c = if m.quantizes_output() || m.out != m.work {
            acc.quantized(m.out)
        } else {
            acc.clone()
        };
        GemmOutput { c, acc }
    }

    /// [`GemmEngine::matmul_mixed`] with the checksum verification fused
    /// into the packed microkernel epilogue: as each output row's
    /// accumulators leave the registers (final K-block, final column
    /// tile), the row's r1/r2 reductions and the d1-vs-threshold
    /// comparison run on the spot — per row, pre-quantization, while the
    /// row is cache-hot. Returns the product plus one [`FusedRowCheck`]
    /// per row, sorted by row index.
    ///
    /// The product is bitwise-identical to [`GemmEngine::matmul_mixed`]
    /// (the epilogue only reads completed rows), and the checks are
    /// bitwise-identical to running `abft::verify::check_row` on the
    /// accumulator afterwards: the epilogue uses the same
    /// [`reduce_in`]/[`dot_in`] schedule on the same bits. For the F32
    /// work precision the epilogue sees `f32` rows and widens them —
    /// exact, so `dot_in`'s internal narrowing round-trips to the
    /// identical values the post-hoc path reads from the accumulator
    /// matrix. Work precisions without a native kernel (the generic
    /// ablation path) fall back to a post-GEMM sweep over the
    /// accumulator — same arithmetic, same results, no epilogue.
    pub fn matmul_mixed_fused(
        &self,
        a: &Matrix,
        b: &Matrix,
        b_wide_cols: usize,
        probe: &FusedProbe<'_>,
    ) -> (GemmOutput, Vec<FusedRowCheck>) {
        assert_eq!(a.cols(), b.rows(), "GEMM shape mismatch {}x{} · {}x{}",
            a.rows(), a.cols(), b.rows(), b.cols());
        assert!(b_wide_cols <= b.cols());
        let m = self.model;
        let (rows, k, cols) = (a.rows(), a.cols(), b.cols());
        assert!(cols >= probe.n + 2, "fused probe needs the two checksum columns");
        assert_eq!(probe.weights.len(), probe.n, "fused probe weight length");
        assert_eq!(probe.thresholds.len(), rows, "fused probe threshold length");

        let aq = quantize_data(a.data(), m.input);
        let bq = if b_wide_cols == 0 {
            quantize_data(b.data(), m.input)
        } else {
            let split = cols - b_wide_cols;
            let mut out = Vec::with_capacity(b.data().len());
            for r in 0..k {
                let row = b.row(r);
                out.extend(row[..split].iter().map(|&x| m.input.quantize(x)));
                out.extend(row[split..].iter().map(|&x| m.work.quantize(x)));
            }
            out
        };

        let sink: std::sync::Mutex<Vec<FusedRowCheck>> =
            std::sync::Mutex::new(Vec::with_capacity(rows));
        let mut via_epilogue = true;
        let par = self.config.resolve_for(rows, k, cols);
        let acc_data: Vec<f64> = match m.work {
            Precision::F64 => {
                let ep = |i: usize, row: &[f64]| {
                    let rc = fused_check_row(row, probe, m.work, m.strategy, i);
                    sink.lock().unwrap().push(rc);
                };
                tiled::gemm_f64_fused(&aq, &bq, rows, k, cols, m.strategy, &par, &ep)
            }
            Precision::F32 => {
                let a32 = kernels::to_f32_vec(&aq);
                let b32 = kernels::to_f32_vec(&bq);
                let ep = |i: usize, row: &[f32]| {
                    // f32 → f64 widening is exact; dot_in/reduce_in narrow
                    // back to the identical f32 values internally.
                    let wide: Vec<f64> = row.iter().map(|&x| x as f64).collect();
                    let rc = fused_check_row(&wide, probe, m.work, m.strategy, i);
                    sink.lock().unwrap().push(rc);
                };
                let c = tiled::gemm_f32_fused(&a32, &b32, rows, k, cols, m.strategy, &par, &ep);
                c.into_iter().map(|x| x as f64).collect()
            }
            other => {
                via_epilogue = false;
                tiled::gemm_generic(&aq, &bq, rows, k, cols, other, m.strategy, &par)
            }
        };
        let acc = Matrix::from_vec(rows, cols, acc_data);
        let checks = if via_epilogue {
            let mut v = sink.into_inner().unwrap();
            v.sort_unstable_by_key(|c| c.row);
            debug_assert_eq!(v.len(), rows);
            v
        } else {
            self.fused_sweep(&acc, probe)
        };
        let c = if m.quantizes_output() || m.out != m.work {
            acc.quantized(m.out)
        } else {
            acc.clone()
        };
        (GemmOutput { c, acc }, checks)
    }

    /// Run the fused per-row checks over an already-materialized
    /// accumulator — the arithmetic of the fused epilogue without the
    /// fusion. Used when something (a fault-injection hook, a work
    /// precision with no native kernel) must touch the accumulator after
    /// the GEMM: the checks are bitwise-identical to the epilogue's
    /// because both run `reduce_in`/`dot_in` on the same row bits.
    pub fn fused_sweep(&self, acc: &Matrix, probe: &FusedProbe<'_>) -> Vec<FusedRowCheck> {
        debug_assert!(acc.cols() >= probe.n + 2);
        (0..acc.rows())
            .map(|i| fused_check_row(acc.row(i), probe, self.model.work, self.model.strategy, i))
            .collect()
    }

    /// Raw work-precision GEMM on the packed parallel engine: multiply
    /// `a` (m×k) by `b` (k×n) in the engine's work precision and
    /// reduction strategy **without quantizing the operands to the input
    /// grid first**.
    ///
    /// This is the batched form of [`GemmEngine::reduce`] /
    /// [`GemmEngine::dot`]: column j of the result is the engine-schedule
    /// dot product of each row of `a` with column j of `b` (for the F32
    /// work precision the operands are first rounded to f32, exactly as
    /// `dot_in` does). The ABFT checksum encodings ride this path so
    /// verification arithmetic runs on the same optimized engine as the
    /// GEMM it protects.
    pub fn matmul_work(&self, a: &[f64], b: &[f64], m: usize, k: usize, n: usize) -> Vec<f64> {
        assert_eq!(a.len(), m * k, "matmul_work: A shape mismatch");
        assert_eq!(b.len(), k * n, "matmul_work: B shape mismatch");
        let model = self.model;
        let par = self.config.resolve_for(m, k, n);
        match model.work {
            Precision::F64 => tiled::gemm_f64(a, b, m, k, n, model.strategy, &par),
            Precision::F32 => {
                let a32 = kernels::to_f32_vec(a);
                let b32 = kernels::to_f32_vec(b);
                tiled::gemm_f32(&a32, &b32, m, k, n, model.strategy, &par)
                    .into_iter()
                    .map(|x| x as f64)
                    .collect()
            }
            other => tiled::gemm_generic(a, b, m, k, n, other, model.strategy, &par),
        }
    }

    /// fl-sum of a slice under the engine's work precision and strategy —
    /// the primitive both ABFT verification paths are built from, so that
    /// the checksum arithmetic matches the hardware being modelled.
    pub fn reduce(&self, xs: &[f64]) -> f64 {
        reduce_in(xs, self.model.work, self.model.strategy)
    }

    /// fl-dot-product under the engine's work precision and strategy.
    pub fn dot(&self, a: &[f64], b: &[f64]) -> f64 {
        dot_in(a, b, self.model.work, self.model.strategy)
    }
}

/// fl-sum in an arbitrary precision/strategy.
pub fn reduce_in(xs: &[f64], p: Precision, strategy: ReduceStrategy) -> f64 {
    match p {
        Precision::F64 => match strategy {
            ReduceStrategy::Sequential | ReduceStrategy::Fma => kernels::seq_reduce_f64(xs),
            ReduceStrategy::Pairwise => kernels::pairwise_reduce_f64(xs),
        },
        Precision::F32 => {
            let v = kernels::to_f32_vec(xs);
            (match strategy {
                ReduceStrategy::Sequential | ReduceStrategy::Fma => kernels::seq_reduce_f32(&v),
                ReduceStrategy::Pairwise => kernels::pairwise_reduce_f32(&v),
            }) as f64
        }
        other => generic_reduce(xs, other, strategy),
    }
}

/// fl-dot in an arbitrary precision/strategy.
pub fn dot_in(a: &[f64], b: &[f64], p: Precision, strategy: ReduceStrategy) -> f64 {
    match p {
        Precision::F64 => match strategy {
            ReduceStrategy::Sequential => kernels::seq_dot_f64(a, b),
            ReduceStrategy::Fma => kernels::fma_dot_f64(a, b),
            ReduceStrategy::Pairwise => {
                let prods: Vec<f64> = a.iter().zip(b).map(|(&x, &y)| x * y).collect();
                kernels::pairwise_reduce_f64(&prods)
            }
        },
        Precision::F32 => {
            let a32 = kernels::to_f32_vec(a);
            let b32 = kernels::to_f32_vec(b);
            (match strategy {
                ReduceStrategy::Sequential => kernels::seq_dot_f32(&a32, &b32),
                ReduceStrategy::Fma => kernels::fma_dot_f32(&a32, &b32),
                ReduceStrategy::Pairwise => {
                    let prods: Vec<f32> =
                        a32.iter().zip(&b32).map(|(&x, &y)| x * y).collect();
                    kernels::pairwise_reduce_f32(&prods)
                }
            }) as f64
        }
        other => {
            let prods: Vec<f64> =
                a.iter().zip(b).map(|(&x, &y)| other.quantize(x * y)).collect();
            generic_reduce(&prods, other, strategy)
        }
    }
}

fn quantize_data(xs: &[f64], p: Precision) -> Vec<f64> {
    let mut v = xs.to_vec();
    p.quantize_slice(&mut v);
    v
}

/// Slow generic reference path: every multiply and add individually
/// quantized to an arbitrary precision. Used for ablations (e.g. true
/// per-step BF16 accumulation, the "offline low-precision" regime) and as
/// the naive reference the tiled generic path must match bitwise.
pub fn generic_gemm(
    a: &[f64],
    b: &[f64],
    m: usize,
    k: usize,
    n: usize,
    p: Precision,
    s: ReduceStrategy,
) -> Vec<f64> {
    let mut c = vec![0.0; m * n];
    let mut prods = vec![0.0; k];
    for i in 0..m {
        for j in 0..n {
            for kk in 0..k {
                prods[kk] = p.quantize(a[i * k + kk] * b[kk * n + j]);
            }
            c[i * n + j] = generic_reduce(&prods, p, s);
        }
    }
    c
}

pub(crate) fn generic_reduce(xs: &[f64], p: Precision, s: ReduceStrategy) -> f64 {
    match s {
        ReduceStrategy::Sequential | ReduceStrategy::Fma => {
            let mut acc = 0.0;
            for &x in xs {
                acc = p.quantize(acc + x);
            }
            acc
        }
        ReduceStrategy::Pairwise => {
            if xs.is_empty() {
                return 0.0;
            }
            let mut buf = xs.to_vec();
            let mut len = buf.len();
            while len > 1 {
                let half = len / 2;
                for i in 0..half {
                    buf[i] = p.quantize(buf[2 * i] + buf[2 * i + 1]);
                }
                if len % 2 == 1 {
                    buf[half] = buf[len - 1];
                    len = half + 1;
                } else {
                    len = half;
                }
            }
            buf[0]
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::{Distribution, Xoshiro256pp};

    fn pair(m: usize, k: usize, n: usize, seed: u64) -> (Matrix, Matrix) {
        let mut rng = Xoshiro256pp::seed_from_u64(seed);
        let d = Distribution::uniform_pm1();
        (Matrix::sample(m, k, &d, &mut rng), Matrix::sample(k, n, &d, &mut rng))
    }

    #[test]
    fn all_models_approximate_exact() {
        let (a, b) = pair(16, 32, 12, 1);
        let exact = exact::matmul_dd(&a, &b);
        let models = [
            AccumModel::cpu(Precision::F64),
            AccumModel::cpu(Precision::F32),
            AccumModel::gpu_highprec(Precision::F64),
            AccumModel::gpu_highprec(Precision::F32),
            AccumModel::npu_fp32(),
            AccumModel::wide(Precision::Bf16),
            AccumModel::wide(Precision::F16),
            AccumModel::fp8(Precision::F8E4M3),
        ];
        for model in models {
            let out = GemmEngine::new(model).matmul(&a, &b);
            // Error budget: K·u_input·max|ab| with K=32 — generous bound.
            let u = model.input.unit_roundoff();
            let budget = 64.0 * 32.0 * u;
            let diff = out.c.max_abs_diff(&exact);
            assert!(diff <= budget, "{}: diff {diff} > {budget}", model.label());
        }
    }

    #[test]
    fn wide_model_output_is_on_low_grid_but_acc_is_not() {
        let (a, b) = pair(8, 64, 8, 2);
        let out = GemmEngine::new(AccumModel::wide(Precision::Bf16)).matmul(&a, &b);
        for &v in out.c.data() {
            assert_eq!(Precision::Bf16.quantize(v), v, "c not on bf16 grid");
        }
        // The accumulator must retain sub-BF16 information for some element
        // (probability of all 64 accumulations landing on the bf16 grid is nil).
        assert!(out.acc.data().iter().any(|&v| Precision::Bf16.quantize(v) != v));
        // And acc rounds to c.
        for (cv, av) in out.c.data().iter().zip(out.acc.data()) {
            assert_eq!(*cv, Precision::Bf16.quantize(*av));
        }
    }

    #[test]
    fn fp8_model_outputs_fp16() {
        let (a, b) = pair(4, 16, 4, 3);
        let out = GemmEngine::new(AccumModel::fp8(Precision::F8E4M3)).matmul(&a, &b);
        for &v in out.c.data() {
            assert_eq!(Precision::F16.quantize(v), v);
        }
    }

    #[test]
    fn engine_reduce_matches_gemm_rowsum_schedule() {
        // Verification relies on reduce() applying the same schedule the
        // GEMM kernel used. For the sequential model, summing the products
        // of a 1xK · Kx1 GEMM must equal dot().
        let (a, b) = pair(1, 100, 1, 4);
        let eng = GemmEngine::new(AccumModel::gpu_highprec(Precision::F32));
        let out = eng.matmul(&a, &b);
        let d = eng.dot(a.row(0), &b.transpose().row(0).to_vec());
        assert_eq!(out.acc.get(0, 0), d);
    }

    #[test]
    fn seq_f32_error_grows_with_k_but_wide_output_error_does_not() {
        // Structural check of the two e_max regimes (full experiment in
        // benches): per-step FP32 error grows with K; BF16-output error is
        // dominated by the final rounding at every K.
        let mut worst_seq = vec![];
        let mut worst_wide = vec![];
        for &k in &[64usize, 1024] {
            let (a, b) = pair(4, k, 4, 5 + k as u64);
            let exact = exact::matmul_dd(&a, &b);
            let seq = GemmEngine::new(AccumModel::npu_fp32()).matmul(&a, &b);
            let wide = GemmEngine::new(AccumModel::wide(Precision::Bf16)).matmul(&a, &b);
            let scale = exact.max_abs();
            worst_seq.push(seq.c.max_abs_diff(&exact) / scale);
            worst_wide.push(wide.c.max_abs_diff(&exact) / scale / Precision::Bf16.unit_roundoff());
        }
        assert!(worst_seq[1] > worst_seq[0], "fp32 per-step error should grow: {worst_seq:?}");
        // Wide-model relative error stays within a few u_bf16 at both sizes.
        for w in &worst_wide {
            assert!(*w < 8.0, "wide model error should be O(u_bf16): {worst_wide:?}");
        }
    }

    #[test]
    fn generic_path_matches_native_for_f32() {
        // The generic per-op quantization path must agree exactly with the
        // native f32 kernels (they implement the same schedule).
        let (a, b) = pair(3, 17, 5, 6);
        let aq = quantize_data(a.data(), Precision::F32);
        let bq = quantize_data(b.data(), Precision::F32);
        let a32 = kernels::to_f32_vec(&aq);
        let b32 = kernels::to_f32_vec(&bq);
        for s in [ReduceStrategy::Sequential, ReduceStrategy::Pairwise] {
            let gen = generic_gemm(&aq, &bq, 3, 17, 5, Precision::F32, s);
            let nat: Vec<f64> = kernels::reference_gemm_f32(&a32, &b32, 3, 17, 5, s)
                .into_iter()
                .map(|x| x as f64)
                .collect();
            assert_eq!(gen, nat, "strategy {s:?}");
        }
    }

    #[test]
    fn fused_matmul_is_bitwise_equal_and_checks_match_the_sweep() {
        // The fused epilogue must change nothing about the product and
        // must produce exactly the checks a post-hoc sweep over the
        // accumulator produces — for every kernel dispatch path (f64,
        // f32, generic) and thread count. B's last two columns stand in
        // for the checksum columns; their values are irrelevant to the
        // bitwise contract.
        let (a, b) = pair(11, 37, 23, 11);
        let n = b.cols() - 2;
        let weights: Vec<f64> = (1..=n).map(|j| j as f64).collect();
        let thresholds = vec![1e-3; a.rows()];
        let probe = FusedProbe { n, weights: &weights, thresholds: &thresholds };
        for model in [
            AccumModel::cpu(Precision::F64),
            AccumModel::gpu_highprec(Precision::F32),
            AccumModel::wide(Precision::Bf16),
            AccumModel::cpu(Precision::Bf16), // generic path → sweep fallback
        ] {
            for threads in [1usize, 4] {
                let par = ParallelismConfig::with_threads(threads)
                    .tiles(TileConfig::new(4, 16, 8));
                let eng = GemmEngine::with_parallelism(model, par);
                let want = eng.matmul_mixed(&a, &b, 2);
                let (got, checks) = eng.matmul_mixed_fused(&a, &b, 2, &probe);
                assert_eq!(got.acc.data(), want.acc.data(), "{model:?} t={threads}");
                assert_eq!(got.c.data(), want.c.data(), "{model:?} t={threads}");
                let sweep = eng.fused_sweep(&want.acc, &probe);
                assert_eq!(checks.len(), a.rows());
                for (i, (rc, sw)) in checks.iter().zip(&sweep).enumerate() {
                    assert_eq!(rc.row, i);
                    assert_eq!(rc.d1.to_bits(), sw.d1.to_bits(), "{model:?} row {i}");
                    assert_eq!(rc.d2.to_bits(), sw.d2.to_bits(), "{model:?} row {i}");
                    assert_eq!(rc.flagged, sw.flagged);
                }
            }
        }
    }

    #[test]
    fn matmul_mixed_2d_preserves_data_row_schedules() {
        // Appending wide A checksum rows must leave every data row's
        // product bitwise-identical to the row-only call, and the
        // zero-wide call must be exactly matmul_mixed.
        let (a, b) = pair(9, 24, 10, 12);
        let mut ext = a.data().to_vec();
        for w in 0..2u32 {
            for j in 0..a.cols() {
                let mut s = 0.0;
                for i in 0..a.rows() {
                    s += a.get(i, j) * if w == 0 { 1.0 } else { (i + 1) as f64 };
                }
                ext.push(s);
            }
        }
        let a2 = Matrix::from_vec(a.rows() + 2, a.cols(), ext);
        for model in [
            AccumModel::cpu(Precision::F64),
            AccumModel::gpu_highprec(Precision::F32),
            AccumModel::wide(Precision::Bf16),
            AccumModel::cpu(Precision::Bf16), // generic work-precision path
        ] {
            let eng = GemmEngine::new(model);
            let base = eng.matmul_mixed(&a, &b, 0);
            let zero = eng.matmul_mixed_2d(&a, &b, 0, 0);
            assert_eq!(zero.acc.data(), base.acc.data(), "{model:?} zero-wide acc");
            assert_eq!(zero.c.data(), base.c.data(), "{model:?} zero-wide c");
            let got = eng.matmul_mixed_2d(&a2, &b, 0, 2);
            for i in 0..a.rows() {
                assert_eq!(got.acc.row(i), base.acc.row(i), "{model:?} acc row {i}");
                assert_eq!(got.c.row(i), base.c.row(i), "{model:?} c row {i}");
            }
        }
    }

    #[test]
    fn engine_results_independent_of_parallelism() {
        // GemmEngine-level schedule preservation: same model, different
        // ParallelismConfig, bitwise-identical c and acc.
        let (a, b) = pair(13, 37, 21, 7);
        for model in [
            AccumModel::cpu(Precision::F64),
            AccumModel::gpu_highprec(Precision::F32),
            AccumModel::wide(Precision::Bf16),
            AccumModel::cpu(Precision::Bf16), // generic work-precision path
        ] {
            let base = GemmEngine::new(model).matmul(&a, &b);
            for threads in [2usize, 4] {
                let par = ParallelismConfig::with_threads(threads)
                    .tiles(TileConfig::new(4, 16, 8));
                let out = GemmEngine::with_parallelism(model, par).matmul(&a, &b);
                assert_eq!(out.acc.data(), base.acc.data(), "{model:?} t={threads}");
                assert_eq!(out.c.data(), base.c.data(), "{model:?} t={threads}");
            }
        }
    }
}
