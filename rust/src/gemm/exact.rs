//! Exact (double-double) reference GEMM and reductions.
//!
//! Substitute for the paper's mpmath 100-dp baseline (see DESIGN.md §6):
//! used to measure *true* rounding errors of the verification paths in the
//! FP64 tightness experiment (Table 4) and as the correctness oracle in
//! tests.

use crate::fp::dd::Dd;
use crate::matrix::Matrix;

/// Exact product C = A·B, each element accumulated in double-double and
/// rounded once to f64 at the end.
pub fn matmul_dd(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.cols(), b.rows(), "shape mismatch");
    let (m, n) = (a.rows(), b.cols());
    let mut c = Matrix::zeros(m, n);
    // ikj order with a dd accumulator panel per output row.
    let mut accs = vec![Dd::ZERO; n];
    for i in 0..m {
        for acc in accs.iter_mut() {
            *acc = Dd::ZERO;
        }
        let arow = a.row(i);
        for (kk, &av) in arow.iter().enumerate() {
            let brow = b.row(kk);
            for (acc, &bv) in accs.iter_mut().zip(brow) {
                *acc = acc.mul_acc(av, bv);
            }
        }
        for (j, acc) in accs.iter().enumerate() {
            c.set(i, j, acc.to_f64());
        }
    }
    c
}

/// Exact row sums of M in double-double (kept as `Dd` so callers can
/// subtract f64 path results without losing the small difference).
pub fn row_sums_dd(m: &Matrix) -> Vec<Dd> {
    (0..m.rows()).map(|i| Dd::sum(m.row(i))).collect()
}

/// Exact dot in double-double.
pub fn dot_dd(a: &[f64], b: &[f64]) -> Dd {
    Dd::dot(a, b)
}

/// Exact verification reference for row `i` of C = A·B: the true value of
/// Σ_n Σ_k A[i][k]·B[k][n], computed as Σ_k A[i][k]·rowsum_dd(B)[k] in
/// double-double. O(MK + KN) for all rows, not O(MKN).
pub fn exact_row_checksums(a: &Matrix, b: &Matrix) -> Vec<Dd> {
    let brs = row_sums_dd(b);
    (0..a.rows())
        .map(|i| {
            let arow = a.row(i);
            let mut acc = Dd::ZERO;
            for (k, &av) in arow.iter().enumerate() {
                acc = acc.add(brs[k].mul_f64(av));
            }
            acc
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::{Distribution, Xoshiro256pp};

    #[test]
    fn dd_gemm_matches_integer_arithmetic() {
        // Integer-valued matrices multiply exactly in f64 too; dd must agree
        // bit-for-bit.
        let a = Matrix::from_fn(5, 7, |i, j| ((i * 7 + j) % 13) as f64 - 6.0);
        let b = Matrix::from_fn(7, 4, |i, j| ((i * 4 + j) % 11) as f64 - 5.0);
        let c = matmul_dd(&a, &b);
        for i in 0..5 {
            for j in 0..4 {
                let mut s = 0.0;
                for k in 0..7 {
                    s += a.get(i, k) * b.get(k, j);
                }
                assert_eq!(c.get(i, j), s);
            }
        }
    }

    #[test]
    fn exact_checksums_equal_brute_force() {
        let mut rng = Xoshiro256pp::seed_from_u64(21);
        let a = Matrix::sample(6, 9, &Distribution::uniform_pm1(), &mut rng);
        let b = Matrix::sample(9, 8, &Distribution::uniform_pm1(), &mut rng);
        let fast = exact_row_checksums(&a, &b);
        // brute force: dd GEMM then dd row sums
        let c = matmul_dd(&a, &b);
        for i in 0..6 {
            let mut acc = Dd::ZERO;
            // re-accumulate in dd over the exact products
            for k in 0..9 {
                for j in 0..8 {
                    acc = acc.mul_acc(a.get(i, k), b.get(k, j));
                }
            }
            let _ = &c;
            assert!(
                (fast[i].sub(acc)).to_f64().abs() < 1e-25,
                "row {i}: {} vs {}",
                fast[i].to_f64(),
                acc.to_f64()
            );
        }
    }
}
