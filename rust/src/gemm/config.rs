//! [`EngineConfig`]: the one construction surface for engine execution.
//!
//! Before this module, callers assembled execution state from four
//! free-standing pieces — [`ParallelismConfig`], [`TileConfig`],
//! [`MicroConfig`], [`RowSplit`] — and a manifest lookup they had to
//! remember to do themselves. `EngineConfig` collapses that into one
//! builder:
//!
//! ```
//! use vabft::prelude::*;
//!
//! // Auto: detected CPU features + tuning manifest (when present).
//! let auto = EngineConfig::auto();
//! // Explicit overrides always win over the manifest.
//! let cfg = EngineConfig::new().threads(4).tile(32, 128, 64).micro(4, 16);
//! let engine = GemmEngine::with_config(AccumModel::wide(Precision::Bf16), cfg);
//! assert_eq!(engine.parallelism().threads, 4);
//! # let _ = auto;
//! ```
//!
//! Unset fields resolve per shape: [`EngineConfig::resolve_for`] consults
//! the loaded [`TuningManifest`] for the nearest tuned shape class and
//! fills only the fields the caller left open, so `--mr 4` on the CLI
//! still pins MR even when the manifest disagrees. Everything this type
//! chooses is *scheduling* — by the schedule-preservation invariant the
//! results are bitwise-identical for every resolution.

use super::simd::{cpu_features, SimdLevel};
use super::tiled::{MicroConfig, ParallelismConfig, RowSplit, TileConfig};
use crate::runtime::TuningManifest;

/// Builder for engine execution configuration: threads, cache tiles,
/// microkernel shape, row split, SIMD level, and an optional tuning
/// manifest that fills whatever the caller leaves unset.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct EngineConfig {
    /// `None` = 1 worker; `Some(0)` = one worker per hardware thread.
    threads: Option<usize>,
    tiles: Option<TileConfig>,
    micro: Option<MicroConfig>,
    split: Option<RowSplit>,
    simd: Option<SimdLevel>,
    manifest: Option<TuningManifest>,
}

impl EngineConfig {
    /// Empty configuration: every field unset, no manifest. Resolves to
    /// [`ParallelismConfig::serial`] — the deterministic library default.
    pub fn new() -> EngineConfig {
        EngineConfig::default()
    }

    /// Hands-off configuration: one worker per hardware thread, SIMD
    /// level from CPU detection, and the tuning manifest at
    /// [`TuningManifest::default_path`] when one is present and valid
    /// (quietly skipped otherwise — auto must never fail).
    pub fn auto() -> EngineConfig {
        EngineConfig {
            threads: Some(0),
            manifest: TuningManifest::load_default().ok().flatten(),
            ..EngineConfig::default()
        }
    }

    /// Set the worker-thread count (`0` = one per hardware thread).
    pub fn threads(mut self, threads: usize) -> EngineConfig {
        self.threads = Some(threads);
        self
    }

    /// Set the cache-blocking tile sizes (all must be positive).
    pub fn tile(self, mc: usize, kc: usize, nc: usize) -> EngineConfig {
        self.tiles(TileConfig::new(mc, kc, nc))
    }

    /// Set the cache-blocking tile configuration.
    pub fn tiles(mut self, tiles: TileConfig) -> EngineConfig {
        self.tiles = Some(tiles);
        self
    }

    /// Set the microkernel (register-block) shape.
    pub fn micro(self, mr: usize, nr: usize) -> EngineConfig {
        self.micro_config(MicroConfig::new(mr, nr))
    }

    /// Set the microkernel shape from a [`MicroConfig`].
    pub fn micro_config(mut self, micro: MicroConfig) -> EngineConfig {
        self.micro = Some(micro);
        self
    }

    /// Set the row-split policy.
    pub fn split(mut self, split: RowSplit) -> EngineConfig {
        self.split = Some(split);
        self
    }

    /// Force a SIMD dispatch level (for A/B testing; `Auto` re-enables
    /// detection).
    pub fn simd(mut self, simd: SimdLevel) -> EngineConfig {
        self.simd = Some(simd);
        self
    }

    /// Attach a tuning manifest; its per-shape winners fill whatever
    /// fields are still unset at [`EngineConfig::resolve_for`] time.
    pub fn manifest(mut self, manifest: TuningManifest) -> EngineConfig {
        self.manifest = Some(manifest);
        self
    }

    /// The attached tuning manifest, if any.
    pub fn manifest_ref(&self) -> Option<&TuningManifest> {
        self.manifest.as_ref()
    }

    /// Resolve shape-blind: unset fields take the library defaults
    /// (1 worker, [`TileConfig::DEFAULT`], [`MicroConfig::DEFAULT`],
    /// contiguous split, auto SIMD). The manifest is ignored here — it is
    /// keyed by shape; use [`EngineConfig::resolve_for`] when one is
    /// known.
    pub fn resolve(&self) -> ParallelismConfig {
        let threads = match self.threads {
            None => 1,
            Some(0) => std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
            Some(t) => t,
        };
        ParallelismConfig {
            threads,
            tiles: self.tiles.unwrap_or(TileConfig::DEFAULT),
            micro: self.micro.unwrap_or(MicroConfig::DEFAULT),
            split: self.split.unwrap_or_default(),
            simd: self.simd.unwrap_or_default(),
        }
    }

    /// Resolve for one GEMM shape: explicit fields always win; fields
    /// left unset take the nearest tuned shape class from the manifest
    /// (when attached and within [`TuningManifest::lookup`]'s distance
    /// cap), then the library defaults. Pure scheduling — the returned
    /// configuration never changes a result bit.
    pub fn resolve_for(&self, m: usize, k: usize, n: usize) -> ParallelismConfig {
        let mut base = self.resolve();
        if let Some(entry) = self.manifest.as_ref().and_then(|man| man.lookup(m, k, n)) {
            if self.tiles.is_none() {
                base.tiles = entry.tiles;
            }
            if self.micro.is_none() {
                base.micro = entry.micro;
            }
            // `Some(0)` asked for auto threads; tuned counts refine both
            // that and the unset default.
            if !matches!(self.threads, Some(t) if t > 0) {
                base.threads = entry.threads.max(1);
            }
            if self.split.is_none() {
                base.split = entry.split;
            }
            if self.simd.is_none() {
                base.simd = entry.simd;
            }
        }
        base
    }

    /// The shared CLI flag helper (`gemm`, `campaign`, `serve-replay`,
    /// `autotune` and the benches all call exactly this): reads
    /// `--threads N` (0 = auto), `--mc/--kc/--nc`, `--mr/--nr`,
    /// `--split contiguous|interleaved`,
    /// `--simd auto|scalar|avx2|avx512|neon` and `--manifest PATH`.
    ///
    /// Flags that are absent stay *unset* (so the manifest may fill
    /// them); present-but-invalid values exit with a usage error, and a
    /// forced `--simd` level the CPU cannot run is rejected up front
    /// rather than silently demoted. Without `--manifest`, the default
    /// manifest path is tried and quietly skipped when absent; an
    /// explicit `--manifest` that fails to load is fatal. Successful
    /// loads print one `tuning manifest: …` line (CI greps for it).
    pub fn from_args(args: &crate::cli::Args) -> EngineConfig {
        let mut cfg = EngineConfig::new();
        if args.opt("threads").is_some() {
            cfg.threads = Some(args.opt_or("threads", 1usize));
        }
        if args.opt("mc").is_some() || args.opt("kc").is_some() || args.opt("nc").is_some() {
            let d = TileConfig::DEFAULT;
            cfg.tiles = Some(TileConfig::new(
                args.opt_or("mc", d.mc),
                args.opt_or("kc", d.kc),
                args.opt_or("nc", d.nc),
            ));
        }
        if args.opt("mr").is_some() || args.opt("nr").is_some() {
            let d = MicroConfig::DEFAULT;
            cfg.micro = Some(MicroConfig::new(args.opt_or("mr", d.mr), args.opt_or("nr", d.nr)));
        }
        if let Some(s) = args.opt("split") {
            cfg.split = Some(RowSplit::parse(s).unwrap_or_else(|| {
                eprintln!("error: invalid value '{s}' for --split (contiguous|interleaved)");
                std::process::exit(2);
            }));
        }
        if let Some(s) = args.opt("simd") {
            let level = SimdLevel::parse(s).unwrap_or_else(|| {
                eprintln!("error: invalid value '{s}' for --simd (auto|scalar|avx2|avx512|neon)");
                std::process::exit(2);
            });
            if !level.is_available() {
                eprintln!("error: --simd {level} is unavailable on this CPU ({})", cpu_features());
                std::process::exit(2);
            }
            cfg.simd = Some(level);
        }
        match args.opt("manifest") {
            Some(path) => {
                let p = std::path::Path::new(path);
                match TuningManifest::load(p) {
                    Ok(man) => {
                        println!(
                            "tuning manifest: loaded {} shapes from {} (cpu {})",
                            man.entries.len(),
                            p.display(),
                            man.cpu
                        );
                        cfg.manifest = Some(man);
                    }
                    Err(e) => {
                        eprintln!("error: --manifest {path}: {e}");
                        std::process::exit(1);
                    }
                }
            }
            None => match TuningManifest::load_default() {
                Ok(Some(man)) => {
                    println!(
                        "tuning manifest: loaded {} shapes from {} (cpu {})",
                        man.entries.len(),
                        TuningManifest::default_path().display(),
                        man.cpu
                    );
                    cfg.manifest = Some(man);
                }
                Ok(None) => {}
                Err(e) => eprintln!("warning: ignoring default tuning manifest: {e}"),
            },
        }
        cfg
    }
}

/// A fully-specified [`ParallelismConfig`] is an [`EngineConfig`] with
/// every field pinned (and no manifest) — the migration shim for call
/// sites built before the builder existed.
impl From<ParallelismConfig> for EngineConfig {
    fn from(par: ParallelismConfig) -> EngineConfig {
        let ParallelismConfig { threads, tiles, micro, split, simd } = par;
        EngineConfig {
            threads: Some(threads),
            tiles: Some(tiles),
            micro: Some(micro),
            split: Some(split),
            simd: Some(simd),
            manifest: None,
        }
    }
}

/// Shape-blind resolution ([`EngineConfig::resolve`]).
impl From<EngineConfig> for ParallelismConfig {
    fn from(cfg: EngineConfig) -> ParallelismConfig {
        cfg.resolve()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::TunedShape;

    fn tuned_entry(m: usize, k: usize, n: usize) -> TunedShape {
        TunedShape {
            label: "test".to_string(),
            m,
            k,
            n,
            tiles: TileConfig { mc: 16, kc: 32, nc: 48 },
            micro: MicroConfig { mr: 4, nr: 16 },
            threads: 3,
            split: RowSplit::Interleaved,
            simd: SimdLevel::Scalar,
            gflops: 2.0,
            baseline_gflops: 1.0,
        }
    }

    #[test]
    fn empty_config_resolves_to_serial() {
        assert_eq!(EngineConfig::new().resolve(), ParallelismConfig::serial());
        assert_eq!(EngineConfig::new().resolve_for(64, 64, 64), ParallelismConfig::serial());
    }

    #[test]
    fn auto_resolves_to_hardware_threads() {
        let par = EngineConfig::auto().resolve();
        assert!(par.threads >= 1);
        assert_eq!(par.tiles, TileConfig::DEFAULT);
    }

    #[test]
    fn manifest_fills_only_unset_fields() {
        let mut man = TuningManifest::new("test");
        man.push(tuned_entry(64, 64, 64));
        let cfg = EngineConfig::new().manifest(man).tile(8, 8, 8).threads(2);
        let par = cfg.resolve_for(64, 64, 64);
        // Explicit wins.
        assert_eq!(par.tiles, TileConfig { mc: 8, kc: 8, nc: 8 });
        assert_eq!(par.threads, 2);
        // Unset fields come from the tuned entry.
        assert_eq!(par.micro, MicroConfig { mr: 4, nr: 16 });
        assert_eq!(par.split, RowSplit::Interleaved);
        assert_eq!(par.simd, SimdLevel::Scalar);
        // A shape far from every tuned class falls back to defaults.
        let far = cfg.resolve_for(1, 1_000_000, 1);
        assert_eq!(far.micro, MicroConfig::DEFAULT);
    }

    #[test]
    fn parallelism_round_trips_through_engine_config() {
        let par = ParallelismConfig::with_threads(5)
            .tiles(TileConfig::new(4, 16, 8))
            .micro(MicroConfig::new(2, 4))
            .split(RowSplit::Interleaved)
            .simd(SimdLevel::Scalar);
        let cfg: EngineConfig = par.into();
        assert_eq!(ParallelismConfig::from(cfg.clone()), par);
        // And the manifest cannot override pinned fields.
        let mut man = TuningManifest::new("test");
        man.push(tuned_entry(8, 8, 8));
        assert_eq!(cfg.manifest(man).resolve_for(8, 8, 8), par);
    }

    #[test]
    fn from_args_distinguishes_absent_from_default() {
        let args = crate::cli::Args::parse_from(
            ["gemm", "--mr", "4", "--nr", "16"].map(String::from),
        );
        let cfg = EngineConfig::from_args(&args);
        let mut man = TuningManifest::new("test");
        man.push(tuned_entry(64, 64, 64));
        let par = cfg.manifest(man).resolve_for(64, 64, 64);
        // --mr/--nr were given: pinned.
        assert_eq!(par.micro, MicroConfig { mr: 4, nr: 16 });
        // --mc/--kc/--nc were not: the manifest fills them.
        assert_eq!(par.tiles, TileConfig { mc: 16, kc: 32, nc: 48 });
    }
}
