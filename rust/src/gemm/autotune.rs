//! `vabft autotune` — searches the tiled engine's *scheduling* space
//! (cache tiles × microkernel shape × worker count × row split × SIMD
//! level) per GEMM shape class and persists the winners into the
//! [`TuningManifest`] that [`super::EngineConfig`] folds into every
//! engine built without explicit overrides.
//!
//! Shape classes come from two sources: the transformer-layer traces of
//! [`crate::workload::build_trace`] (one class per distinct (M, K, N)
//! per model family) and the fault-campaign grid shapes of
//! [`crate::campaign::GridConfig`]. Every candidate is measured on the
//! FMA reduction schedule and **bitwise-checked against the serial
//! scalar engine** before it may win — tuning can never trade bits for
//! speed, because every point in the search space is pure scheduling
//! (see invariant #8 in `docs/ARCHITECTURE.md`).
//!
//! The `--gate` pass re-measures each persisted transformer-shape winner
//! against the untuned default configuration and fails if the tuned
//! schedule loses (beyond a 10% measurement-noise allowance) — the
//! nightly guard that a stale manifest cannot regress serving.

use std::path::PathBuf;
use std::time::Instant;

use crate::error::{Context, Result};
use crate::gemm::simd::{cpu_features, SimdLevel};
use crate::gemm::tiled::{self, MicroConfig, ParallelismConfig, RowSplit, TileConfig};
use crate::gemm::ReduceStrategy;
use crate::rng::{Rng, Xoshiro256pp};
use crate::runtime::{TunedShape, TuningManifest};
use crate::workload::{build_trace, ReplayConfig};

/// Search depth of an autotune run.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum AutotuneMode {
    /// CI smoke: one family, a handful of candidates, sub-second shapes.
    Smoke,
    /// Nightly default: all families at bench-quick scale, a pruned grid.
    #[default]
    Quick,
    /// Exhaustive-ish: all families, larger shapes, the full grid.
    Full,
}

impl AutotuneMode {
    /// Lowercase mode name used in CLI output.
    pub fn name(self) -> &'static str {
        match self {
            AutotuneMode::Smoke => "smoke",
            AutotuneMode::Quick => "quick",
            AutotuneMode::Full => "full",
        }
    }

    /// Timed repetitions per candidate (best-of; first rep is warmup).
    fn reps(self) -> usize {
        match self {
            AutotuneMode::Smoke => 2,
            AutotuneMode::Quick => 3,
            AutotuneMode::Full => 5,
        }
    }
}

/// One shape class to tune: a labelled (M, K, N).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ShapeClass {
    /// `family/layer` for transformer shapes, `grid/MxKxN` for campaign
    /// grid shapes.
    pub label: String,
    /// GEMM rows.
    pub m: usize,
    /// GEMM reduction depth.
    pub k: usize,
    /// GEMM output columns.
    pub n: usize,
}

/// Autotune run configuration.
#[derive(Debug, Clone)]
pub struct AutotuneConfig {
    /// Search depth.
    pub mode: AutotuneMode,
    /// Seed for the deterministic operand samples.
    pub seed: u64,
    /// Manifest destination.
    pub path: PathBuf,
}

/// The shape classes a mode tunes: deduplicated transformer-layer GEMM
/// shapes per family, then the campaign grid shapes.
pub fn shape_classes(mode: AutotuneMode) -> Vec<ShapeClass> {
    let families: &[&str] = match mode {
        AutotuneMode::Smoke => &["gpt2"],
        _ => &["llama-7b", "gpt2", "vit-b32"],
    };
    let mut out: Vec<ShapeClass> = Vec::new();
    let mut push = |label: String, m: usize, k: usize, n: usize| {
        if !out.iter().any(|s| (s.m, s.k, s.n) == (m, k, n)) {
            out.push(ShapeClass { label, m, k, n });
        }
    };
    for family in families {
        let cfg = match mode {
            AutotuneMode::Smoke => ReplayConfig::smoke(family, 0),
            AutotuneMode::Quick => ReplayConfig::quick(family, 0),
            AutotuneMode::Full => {
                let mut c = ReplayConfig::quick(family, 0);
                c.scale = 8;
                c.batch = 16;
                c
            }
        };
        for e in build_trace(&cfg).entries {
            push(format!("{family}/{}", e.name), e.m, e.k, e.n);
        }
    }
    // Campaign grid shapes (GridConfig::quick / ::nightly).
    let grid: &[(usize, usize, usize)] = match mode {
        AutotuneMode::Smoke => &[(8, 64, 16)],
        AutotuneMode::Quick => &[(8, 64, 16), (32, 256, 64)],
        AutotuneMode::Full => &[(8, 64, 16), (32, 256, 64), (128, 1024, 256)],
    };
    for &(m, k, n) in grid {
        push(format!("grid/{m}x{k}x{n}"), m, k, n);
    }
    out
}

/// One point of the search space.
type Candidate = (TileConfig, MicroConfig, usize, RowSplit, SimdLevel);

/// The candidate grid for a mode. The untuned default schedule is always
/// candidate 0, so the winner can never lose to it on the measurements
/// that picked it.
fn candidates(mode: AutotuneMode) -> Vec<Candidate> {
    let hw = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let tiles: Vec<TileConfig> = match mode {
        AutotuneMode::Smoke => vec![TileConfig::DEFAULT, TileConfig { mc: 32, kc: 128, nc: 64 }],
        AutotuneMode::Quick => vec![
            TileConfig::DEFAULT,
            TileConfig { mc: 32, kc: 128, nc: 64 },
            TileConfig { mc: 96, kc: 256, nc: 192 },
        ],
        AutotuneMode::Full => vec![
            TileConfig::DEFAULT,
            TileConfig { mc: 32, kc: 128, nc: 64 },
            TileConfig { mc: 96, kc: 256, nc: 192 },
            TileConfig { mc: 128, kc: 512, nc: 256 },
        ],
    };
    let micros: Vec<MicroConfig> = match mode {
        AutotuneMode::Smoke => vec![MicroConfig::DEFAULT],
        _ => vec![
            MicroConfig::DEFAULT,
            MicroConfig { mr: 4, nr: 16 },
            MicroConfig { mr: 8, nr: 16 },
        ],
    };
    let mut threads = vec![1usize];
    if hw > 1 {
        if matches!(mode, AutotuneMode::Full) && hw > 3 {
            threads.push(hw / 2);
        }
        threads.push(hw);
    }
    let splits: Vec<RowSplit> = match mode {
        AutotuneMode::Smoke => vec![RowSplit::Contiguous],
        _ => vec![RowSplit::Contiguous, RowSplit::Interleaved],
    };
    let simds = SimdLevel::available_levels();

    let mut out = vec![(
        TileConfig::DEFAULT,
        MicroConfig::DEFAULT,
        1,
        RowSplit::Contiguous,
        SimdLevel::Auto,
    )];
    for &t in &tiles {
        for &u in &micros {
            for &th in &threads {
                for &sp in &splits {
                    for &sl in &simds {
                        let c = (t, u, th, sp, sl);
                        if !out.contains(&c) {
                            out.push(c);
                        }
                    }
                }
            }
        }
    }
    out
}

/// Deterministic operands in [-1, 1) for a shape.
fn operands(m: usize, k: usize, n: usize, seed: u64) -> (Vec<f32>, Vec<f32>) {
    let mut rng = Xoshiro256pp::from_stream(0xA070_73E5, seed);
    let mut fill = |len: usize| -> Vec<f32> {
        (0..len).map(|_| rng.uniform(-1.0, 1.0) as f32).collect()
    };
    (fill(m * k), fill(k * n))
}

/// Best-of-`reps` throughput of one candidate on the FMA schedule,
/// plus its output for the bitwise check. The first rep doubles as
/// warmup (packing buffers, thread spawn) since best-of discards it
/// unless it was genuinely fastest.
fn measure(
    m: usize,
    k: usize,
    n: usize,
    a: &[f32],
    b: &[f32],
    par: &ParallelismConfig,
    reps: usize,
) -> (f64, Vec<f32>) {
    let mut best = f64::INFINITY;
    let mut out = Vec::new();
    for _ in 0..reps.max(1) {
        let t0 = Instant::now();
        let c = tiled::gemm_f32(a, b, m, k, n, ReduceStrategy::Fma, par);
        best = best.min(t0.elapsed().as_secs_f64().max(1e-9));
        out = c;
    }
    (2.0 * m as f64 * k as f64 * n as f64 / best / 1e9, out)
}

fn par_of(c: &Candidate) -> ParallelismConfig {
    ParallelismConfig {
        threads: c.2,
        tiles: c.0,
        micro: c.1,
        split: c.3,
        simd: c.4,
    }
}

/// Run the search and persist the manifest. Returns the manifest after
/// verifying it reloads byte-identically from `cfg.path`.
pub fn run(cfg: &AutotuneConfig) -> Result<TuningManifest> {
    let shapes = shape_classes(cfg.mode);
    let cands = candidates(cfg.mode);
    let reps = cfg.mode.reps();
    println!(
        "autotune[{}]: {} shape classes x {} candidates (cpu {})",
        cfg.mode.name(),
        shapes.len(),
        cands.len(),
        cpu_features()
    );

    let mut manifest = TuningManifest::new(cpu_features());
    for (si, s) in shapes.iter().enumerate() {
        let (a, b) = operands(s.m, s.k, s.n, cfg.seed ^ si as u64);
        // Scalar serial reference: the bitwise ground truth every
        // candidate must reproduce.
        let reference = tiled::gemm_f32(
            &a,
            &b,
            s.m,
            s.k,
            s.n,
            ReduceStrategy::Fma,
            &ParallelismConfig { simd: SimdLevel::Scalar, ..ParallelismConfig::serial() },
        );

        let mut baseline = 0.0f64;
        let mut best: Option<(f64, &Candidate)> = None;
        for (ci, c) in cands.iter().enumerate() {
            let (gflops, out) = measure(s.m, s.k, s.n, &a, &b, &par_of(c), reps);
            crate::ensure!(
                out.iter().zip(&reference).all(|(x, y)| x.to_bits() == y.to_bits()),
                "autotune: candidate {:?} is not bitwise-equal to the scalar \
                 serial engine at {} ({}x{}x{})",
                c,
                s.label,
                s.m,
                s.k,
                s.n
            );
            if ci == 0 {
                baseline = gflops;
            }
            let better = match best {
                None => true,
                Some((g, _)) => gflops > g,
            };
            if better {
                best = Some((gflops, c));
            }
        }
        let (gflops, c) = best.expect("candidate grid is never empty");
        println!(
            "autotune[{}]: {:<24} {}x{}x{} -> mc={} kc={} nc={} mr={} nr={} \
             threads={} split={} simd={} ({:.2} gflops, baseline {:.2})",
            cfg.mode.name(),
            s.label,
            s.m,
            s.k,
            s.n,
            c.0.mc,
            c.0.kc,
            c.0.nc,
            c.1.mr,
            c.1.nr,
            c.2,
            c.3.name(),
            c.4.resolve().name(),
            gflops,
            baseline
        );
        manifest.push(TunedShape {
            label: s.label.clone(),
            m: s.m,
            k: s.k,
            n: s.n,
            tiles: c.0,
            micro: c.1,
            threads: c.2,
            split: c.3,
            simd: c.4.resolve(),
            gflops,
            baseline_gflops: baseline,
        });
    }

    manifest
        .save(&cfg.path)
        .with_context(|| format!("autotune: writing manifest to {}", cfg.path.display()))?;
    let reloaded = TuningManifest::load(&cfg.path)
        .with_context(|| format!("autotune: re-reading {}", cfg.path.display()))?;
    crate::ensure!(
        reloaded == manifest,
        "autotune: manifest did not round-trip through {}",
        cfg.path.display()
    );
    println!(
        "autotune[{}]: wrote {} shapes to {}",
        cfg.mode.name(),
        manifest.entries.len(),
        cfg.path.display()
    );
    Ok(manifest)
}

/// Gate pass: re-measure each persisted *transformer* shape (labels not
/// under `grid/`) with its tuned schedule vs the untuned default, and
/// fail if any tuned schedule is more than 10% slower — the allowance
/// covers run-to-run measurement noise, nothing else.
pub fn gate(manifest: &TuningManifest, seed: u64) -> Result<usize> {
    let mut checked = 0usize;
    let mut losses: Vec<String> = Vec::new();
    for (i, e) in manifest.entries.iter().enumerate() {
        if e.label.starts_with("grid/") {
            continue;
        }
        checked += 1;
        let (a, b) = operands(e.m, e.k, e.n, seed ^ i as u64);
        let tuned_par = ParallelismConfig {
            threads: e.threads.max(1),
            tiles: e.tiles,
            micro: e.micro,
            split: e.split,
            simd: e.simd,
        };
        let (tuned, _) = measure(e.m, e.k, e.n, &a, &b, &tuned_par, 3);
        let (default, _) = measure(e.m, e.k, e.n, &a, &b, &ParallelismConfig::serial(), 3);
        let verdict = if tuned >= 0.9 * default { "ok" } else { "LOSS" };
        println!(
            "autotune gate: {:<24} {}x{}x{} tuned {:.2} vs default {:.2} gflops [{}]",
            e.label, e.m, e.k, e.n, tuned, default, verdict
        );
        if tuned < 0.9 * default {
            losses.push(format!(
                "{} ({}x{}x{}): tuned {:.2} < default {:.2} gflops",
                e.label, e.m, e.k, e.n, tuned, default
            ));
        }
    }
    crate::ensure!(
        losses.is_empty(),
        "autotune gate: tuned schedule loses to the untuned default at {} \
         transformer shape(s):\n  {}",
        losses.len(),
        losses.join("\n  ")
    );
    Ok(checked)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn smoke_shape_classes_are_small_and_deduped() {
        let shapes = shape_classes(AutotuneMode::Smoke);
        assert!(!shapes.is_empty());
        assert!(shapes.len() <= 8, "smoke must stay tiny, got {}", shapes.len());
        for (i, s) in shapes.iter().enumerate() {
            assert!(s.m > 0 && s.k > 0 && s.n > 0);
            for t in &shapes[i + 1..] {
                assert_ne!((s.m, s.k, s.n), (t.m, t.k, t.n), "duplicate shape {}", s.label);
            }
        }
        // Both sources are represented.
        assert!(shapes.iter().any(|s| s.label.starts_with("gpt2/")));
        assert!(shapes.iter().any(|s| s.label.starts_with("grid/")));
    }

    #[test]
    fn candidate_grid_leads_with_the_untuned_default() {
        for mode in [AutotuneMode::Smoke, AutotuneMode::Quick, AutotuneMode::Full] {
            let cands = candidates(mode);
            assert_eq!(
                cands[0],
                (
                    TileConfig::DEFAULT,
                    MicroConfig::DEFAULT,
                    1,
                    RowSplit::Contiguous,
                    SimdLevel::Auto
                )
            );
            // No duplicate points — the search never measures twice.
            for (i, c) in cands.iter().enumerate() {
                assert!(!cands[i + 1..].contains(c), "duplicate candidate {c:?}");
            }
        }
    }

    #[test]
    fn smoke_run_round_trips_and_gates() {
        let path = std::env::temp_dir()
            .join(format!("vabft-autotune-test-{}.tsv", std::process::id()));
        let cfg = AutotuneConfig { mode: AutotuneMode::Smoke, seed: 7, path: path.clone() };
        let manifest = run(&cfg).unwrap();
        assert!(!manifest.entries.is_empty());
        assert_eq!(TuningManifest::load(&path).unwrap(), manifest);
        // Every persisted level is concrete and executable here.
        for e in &manifest.entries {
            assert_ne!(e.simd, SimdLevel::Auto);
            assert!(e.simd.is_available());
            assert!(e.gflops > 0.0 && e.baseline_gflops > 0.0);
        }
        let checked = gate(&manifest, 7).unwrap();
        assert!(checked > 0);
        std::fs::remove_file(&path).ok();
    }
}
