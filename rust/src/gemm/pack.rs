//! Operand packing for the register-blocked GEMM engine.
//!
//! The microkernels in [`crate::gemm::micro`] consume *packed panels*:
//! contiguous buffers laid out so that each K step reads MR consecutive A
//! values and NR consecutive B values. Packing happens once per
//! (K-block, row-panel) for A and once per (K-block, N-block) for B, and
//! is amortized over the whole M loop / N loop respectively — an O(K·N)
//! copy against O(M·K·N) multiply-accumulates.
//!
//! Packing is a pure data *relayout*: values are copied bit-for-bit (no
//! arithmetic), and ragged edges are padded with zeros that only ever
//! reach scratch accumulator lanes (see `micro.rs`). It therefore cannot
//! affect the rounding schedule.

/// Pack a worker's A rows for one K-block into MR-tall micro-panels.
///
/// Source: rows `i0 .. i0 + rows` of row-major `a` (row stride `k`),
/// columns `k0 .. k0 + kb`. Destination layout: `ceil(rows/mr)` panels,
/// each `kb × mr`, K-major — element `(panel p, step kk, lane r)` at
/// `p·kb·mr + kk·mr + r` holding `A[i0 + p·mr + r][k0 + kk]`, zero for
/// lanes past the last row.
pub fn pack_a<T: Copy + Default>(
    a: &[T],
    k: usize,
    i0: usize,
    rows: usize,
    k0: usize,
    kb: usize,
    mr: usize,
    out: &mut Vec<T>,
) {
    let panels = (rows + mr - 1) / mr;
    out.clear();
    out.resize(panels * kb * mr, T::default());
    for p in 0..panels {
        let ip = p * mr;
        let h = mr.min(rows - ip);
        let base = p * kb * mr;
        for r in 0..h {
            let row0 = (i0 + ip + r) * k + k0;
            let arow = &a[row0..row0 + kb];
            for (kk, &v) in arow.iter().enumerate() {
                out[base + kk * mr + r] = v;
            }
        }
    }
}

/// Pack one (K-block, N-block) of B into NR-wide micro-panels.
///
/// Source: rows `k0 .. k0 + kb` of row-major `b` (row stride `n`),
/// columns `j0 .. j0 + jw`. Destination layout: `ceil(jw/nr)` panels,
/// each `kb × nr`, K-major — element `(panel q, step kk, lane c)` at
/// `q·kb·nr + kk·nr + c` holding `B[k0 + kk][j0 + q·nr + c]`, zero for
/// lanes past the last column.
pub fn pack_b<T: Copy + Default>(
    b: &[T],
    n: usize,
    k0: usize,
    kb: usize,
    j0: usize,
    jw: usize,
    nr: usize,
    out: &mut Vec<T>,
) {
    let panels = (jw + nr - 1) / nr;
    out.clear();
    out.resize(panels * kb * nr, T::default());
    for q in 0..panels {
        let jp = j0 + q * nr;
        let w = nr.min(j0 + jw - jp);
        let base = q * kb * nr;
        for kk in 0..kb {
            let row0 = (k0 + kk) * n + jp;
            out[base + kk * nr..base + kk * nr + w].copy_from_slice(&b[row0..row0 + w]);
        }
    }
}

/// Pack a full-K column strip of B contiguously: `out[kk·jw + c] =
/// B[kk][j0 + c]`. Used by the pairwise strategy, whose reduction tree
/// spans the whole K extent — the product buffer is then filled from
/// contiguous memory instead of striding by `n` every K step.
pub fn pack_b_cols<T: Copy>(b: &[T], n: usize, k: usize, j0: usize, jw: usize, out: &mut Vec<T>) {
    out.clear();
    out.reserve(k * jw);
    for kk in 0..k {
        out.extend_from_slice(&b[kk * n + j0..kk * n + j0 + jw]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pack_a_layout_and_padding() {
        // 3 rows of a 5×4 A, K-block [1, 4), mr = 2 → 2 panels of 3×2.
        let k = 4;
        let a: Vec<f64> = (0..20).map(|x| x as f64).collect();
        let mut out = Vec::new();
        pack_a(&a, k, 1, 3, 1, 3, 2, &mut out);
        assert_eq!(out.len(), 2 * 3 * 2);
        // Panel 0, kk = 0 holds A[1][1], A[2][1] = 5, 9.
        assert_eq!(&out[0..2], &[5.0, 9.0]);
        // Panel 0, kk = 2 holds A[1][3], A[2][3] = 7, 11.
        assert_eq!(&out[4..6], &[7.0, 11.0]);
        // Panel 1 holds A[3][1..4] in lane 0 and zero padding in lane 1.
        assert_eq!(&out[6..12], &[13.0, 0.0, 14.0, 0.0, 15.0, 0.0]);
    }

    #[test]
    fn pack_b_layout_and_padding() {
        // B 3×5, K-block [1, 3), columns [1, 4), nr = 2 → 2 panels of 2×2.
        let n = 5;
        let b: Vec<f64> = (0..15).map(|x| x as f64).collect();
        let mut out = Vec::new();
        pack_b(&b, n, 1, 2, 1, 3, 2, &mut out);
        assert_eq!(out.len(), 2 * 2 * 2);
        // Panel 0: kk=0 → B[1][1..3] = 6,7; kk=1 → B[2][1..3] = 11,12.
        assert_eq!(&out[0..4], &[6.0, 7.0, 11.0, 12.0]);
        // Panel 1: column 3 with zero padding.
        assert_eq!(&out[4..8], &[8.0, 0.0, 13.0, 0.0]);
    }

    #[test]
    fn pack_b_cols_is_contiguous_strip() {
        let n = 4;
        let b: Vec<f32> = (0..12).map(|x| x as f32).collect();
        let mut out = Vec::new();
        pack_b_cols(&b, n, 3, 1, 2, &mut out);
        assert_eq!(out, vec![1.0, 2.0, 5.0, 6.0, 9.0, 10.0]);
    }

    #[test]
    fn buffers_are_reusable_across_blocks() {
        // clear + resize must fully re-fill (stale data from a previous,
        // larger block must not leak into padding).
        let a: Vec<f64> = (0..16).map(|x| 1.0 + x as f64).collect();
        let mut out = Vec::new();
        pack_a(&a, 4, 0, 4, 0, 4, 4, &mut out); // full 4×4, no padding
        pack_a(&a, 4, 0, 3, 0, 2, 2, &mut out); // smaller block with padding
        assert_eq!(out.len(), 2 * 2 * 2);
        // Panel 1 lane 1 (row 3 of 3) must be zero padding, not stale data.
        assert_eq!(out[4 + 1], 0.0);
        assert_eq!(out[4 + 3], 0.0);
    }
}
