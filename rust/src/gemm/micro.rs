//! Register-blocked MR×NR microkernels for the packed GEMM engine.
//!
//! A microkernel owns an MR×NR tile of C: the accumulators live in local
//! variables (registers, for the monomorphized sizes) for the whole
//! K-block, so C memory is touched exactly twice per (tile, K-block)
//! instead of twice per K step. The operands arrive *packed*
//! ([`crate::gemm::pack`]): per K step the kernel reads MR contiguous A
//! values and NR contiguous B values and performs MR·NR independent
//! multiply-accumulates.
//!
//! **Why this is schedule-preserving.** Every accumulator belongs to a
//! distinct output element, and for each element the kernel performs
//! exactly the reference schedule — one `round(mul)`+`round(add)`
//! (sequential) or one fused `mul_add` (FMA) per K step, K ascending.
//! The only axis being vectorized is *across independent output
//! elements*, which is the one transformation that cannot reorder any
//! element's K-chain (see `docs/ARCHITECTURE.md`). Ragged edges are
//! handled by zero-padded packing: padded lanes accumulate into
//! scratch accumulators that are never stored, so real elements see
//! only real operands, in reference order.
//!
//! The pairwise strategy has no microkernel here: its reduction tree
//! depends on the full K extent, so it is staged on packed B panels in
//! [`crate::gemm::tiled`] instead.
//!
//! For `f32`/`f64` the scalar kernels below are fronted by the explicit
//! `std::arch` SIMD kernels in [`crate::gemm::simd`], selected per call
//! by a resolved [`SimdLevel`] — bitwise-identical by construction (the
//! SIMD kernels vectorize the same across-outputs axis) and enforced by
//! `tests/simd_dispatch.rs`.

use super::simd::{self, SimdLevel};

/// Arithmetic surface the packed engine needs from an element type.
///
/// Implemented for `f32` and `f64`. Each method is a single IEEE-754
/// operation (one rounding), so a generic kernel built from them executes
/// the exact reference rounding schedule for either type.
pub trait Element: Copy + Default + PartialEq + Send + Sync + std::fmt::Debug + 'static {
    /// IEEE multiply (one rounding).
    fn mul(self, rhs: Self) -> Self;
    /// IEEE add (one rounding).
    fn add(self, rhs: Self) -> Self;
    /// Fused multiply-add `self * b + c` (one rounding).
    fn madd(self, b: Self, c: Self) -> Self;

    /// Attempt the micro-tile update with an explicit SIMD kernel
    /// ([`crate::gemm::simd`]) at the given (already resolved) level.
    /// Returns `false` when no kernel covers this type / ISA / (mr, nr)
    /// combination, in which case the caller must run the scalar kernel
    /// — which produces the same bits, since SIMD kernels vectorize only
    /// across independent output columns. The default declines.
    fn run_simd(
        _level: SimdLevel,
        _fma: bool,
        _apanel: &[Self],
        _bpanel: &[Self],
        _kb: usize,
        _c: &mut [Self],
        _ldc: usize,
        _h: usize,
        _w: usize,
        _mr: usize,
        _nr: usize,
    ) -> bool {
        false
    }
}

impl Element for f32 {
    #[inline(always)]
    fn mul(self, rhs: Self) -> Self {
        self * rhs
    }
    #[inline(always)]
    fn add(self, rhs: Self) -> Self {
        self + rhs
    }
    #[inline(always)]
    fn madd(self, b: Self, c: Self) -> Self {
        self.mul_add(b, c)
    }
    #[inline]
    fn run_simd(
        level: SimdLevel,
        fma: bool,
        apanel: &[f32],
        bpanel: &[f32],
        kb: usize,
        c: &mut [f32],
        ldc: usize,
        h: usize,
        w: usize,
        mr: usize,
        nr: usize,
    ) -> bool {
        simd::run_f32(level, fma, apanel, bpanel, kb, c, ldc, h, w, mr, nr)
    }
}

impl Element for f64 {
    #[inline(always)]
    fn mul(self, rhs: Self) -> Self {
        self * rhs
    }
    #[inline(always)]
    fn add(self, rhs: Self) -> Self {
        self + rhs
    }
    #[inline(always)]
    fn madd(self, b: Self, c: Self) -> Self {
        self.mul_add(b, c)
    }
    #[inline]
    fn run_simd(
        level: SimdLevel,
        fma: bool,
        apanel: &[f64],
        bpanel: &[f64],
        kb: usize,
        c: &mut [f64],
        ldc: usize,
        h: usize,
        w: usize,
        mr: usize,
        nr: usize,
    ) -> bool {
        simd::run_f64(level, fma, apanel, bpanel, kb, c, ldc, h, w, mr, nr)
    }
}

/// Upper bound on `mr`/`nr` (the dynamic-fallback kernel keeps its
/// accumulator tile on the stack: `MAX_MICRO² · 8 B = 8 KiB` for f64).
pub const MAX_MICRO: usize = 32;

/// One MR×NR micro-tile update: `C[0..h, 0..w] (+)= Apanel · Bpanel` over
/// `kb` K steps, accumulators held in registers.
///
/// * `apanel` — packed A micro-panel, `kb × MR`, K-major (`kk*MR + r`).
/// * `bpanel` — packed B micro-panel, `kb × NR`, K-major (`kk*NR + c`).
/// * `c` — the C tile's top-left element; row stride `ldc`.
/// * `h`, `w` — live tile extent (`h ≤ MR`, `w ≤ NR`); padded lanes
///   accumulate into scratch and are not stored.
/// * `fma` — `true` runs the FMA schedule (`madd`), `false` the
///   sequential schedule (`mul` then `add`).
/// * `simd` — a **resolved** [`SimdLevel`] (never `Auto`; the engine
///   resolves once per GEMM call). Non-`Scalar` levels first offer the
///   tile to [`Element::run_simd`]; a declined tile (or `Scalar`) runs
///   the scalar kernels below. Either way the bits are identical —
///   dispatch is pure scheduling.
///
/// Dispatches to a monomorphized kernel for the supported (mr, nr)
/// sizes and to a dynamic-size fallback otherwise (bitwise-identical,
/// just slower).
#[inline]
pub fn run_micro<T: Element>(
    simd: SimdLevel,
    fma: bool,
    apanel: &[T],
    bpanel: &[T],
    kb: usize,
    c: &mut [T],
    ldc: usize,
    h: usize,
    w: usize,
    mr: usize,
    nr: usize,
) {
    if simd != SimdLevel::Scalar
        && T::run_simd(simd, fma, apanel, bpanel, kb, c, ldc, h, w, mr, nr)
    {
        return;
    }
    match (fma, mr, nr) {
        (false, 2, 4) => ukr::<T, 2, 4, false>(apanel, bpanel, kb, c, ldc, h, w),
        (false, 2, 8) => ukr::<T, 2, 8, false>(apanel, bpanel, kb, c, ldc, h, w),
        (false, 4, 4) => ukr::<T, 4, 4, false>(apanel, bpanel, kb, c, ldc, h, w),
        (false, 4, 8) => ukr::<T, 4, 8, false>(apanel, bpanel, kb, c, ldc, h, w),
        (false, 4, 16) => ukr::<T, 4, 16, false>(apanel, bpanel, kb, c, ldc, h, w),
        (false, 8, 4) => ukr::<T, 8, 4, false>(apanel, bpanel, kb, c, ldc, h, w),
        (false, 8, 8) => ukr::<T, 8, 8, false>(apanel, bpanel, kb, c, ldc, h, w),
        (false, 8, 16) => ukr::<T, 8, 16, false>(apanel, bpanel, kb, c, ldc, h, w),
        (false, 16, 4) => ukr::<T, 16, 4, false>(apanel, bpanel, kb, c, ldc, h, w),
        (true, 2, 4) => ukr::<T, 2, 4, true>(apanel, bpanel, kb, c, ldc, h, w),
        (true, 2, 8) => ukr::<T, 2, 8, true>(apanel, bpanel, kb, c, ldc, h, w),
        (true, 4, 4) => ukr::<T, 4, 4, true>(apanel, bpanel, kb, c, ldc, h, w),
        (true, 4, 8) => ukr::<T, 4, 8, true>(apanel, bpanel, kb, c, ldc, h, w),
        (true, 4, 16) => ukr::<T, 4, 16, true>(apanel, bpanel, kb, c, ldc, h, w),
        (true, 8, 4) => ukr::<T, 8, 4, true>(apanel, bpanel, kb, c, ldc, h, w),
        (true, 8, 8) => ukr::<T, 8, 8, true>(apanel, bpanel, kb, c, ldc, h, w),
        (true, 8, 16) => ukr::<T, 8, 16, true>(apanel, bpanel, kb, c, ldc, h, w),
        (true, 16, 4) => ukr::<T, 16, 4, true>(apanel, bpanel, kb, c, ldc, h, w),
        _ => ukr_dyn(fma, apanel, bpanel, kb, c, ldc, h, w, mr, nr),
    }
}

/// [`run_micro`] plus a row-completion hook — the fused-verification
/// epilogue attachment point. The kernel itself is unchanged (identical
/// arithmetic, identical schedule); after the tile is stored, `on_row`
/// is invoked once per live tile row with the row's panel-local index
/// (`row0 + r`). The packed engine calls this only for the micro-tile
/// that completes a row (final K-block, final column tile), so the hook
/// fires exactly once per output row, at the moment the row's
/// accumulators leave the registers — before any output quantization.
#[allow(clippy::too_many_arguments)]
#[inline]
pub fn run_micro_fused<T: Element>(
    simd: SimdLevel,
    fma: bool,
    apanel: &[T],
    bpanel: &[T],
    kb: usize,
    c: &mut [T],
    ldc: usize,
    h: usize,
    w: usize,
    mr: usize,
    nr: usize,
    row0: usize,
    on_row: &mut dyn FnMut(usize),
) {
    run_micro(simd, fma, apanel, bpanel, kb, c, ldc, h, w, mr, nr);
    for r in 0..h {
        on_row(row0 + r);
    }
}

/// The monomorphized microkernel: MR, NR and the schedule are const, so
/// the accumulator tile is a fixed-size array the optimizer keeps in
/// vector registers, with the NR loop vectorized across output columns.
fn ukr<T: Element, const MR: usize, const NR: usize, const FMA: bool>(
    apanel: &[T],
    bpanel: &[T],
    kb: usize,
    c: &mut [T],
    ldc: usize,
    h: usize,
    w: usize,
) {
    debug_assert!(apanel.len() >= kb * MR && bpanel.len() >= kb * NR);
    debug_assert!(h <= MR && w <= NR && h >= 1);
    let mut acc = [[T::default(); NR]; MR];
    for (r, arow) in acc.iter_mut().enumerate().take(h) {
        for (cc, av) in arow.iter_mut().enumerate().take(w) {
            *av = c[r * ldc + cc];
        }
    }
    for kk in 0..kb {
        let av = &apanel[kk * MR..kk * MR + MR];
        let bv = &bpanel[kk * NR..kk * NR + NR];
        for (r, arow) in acc.iter_mut().enumerate() {
            let ar = av[r];
            if FMA {
                for (cc, a) in arow.iter_mut().enumerate() {
                    *a = ar.madd(bv[cc], *a);
                }
            } else {
                for (cc, a) in arow.iter_mut().enumerate() {
                    *a = a.add(ar.mul(bv[cc]));
                }
            }
        }
    }
    for (r, arow) in acc.iter().enumerate().take(h) {
        for (cc, av) in arow.iter().enumerate().take(w) {
            c[r * ldc + cc] = *av;
        }
    }
}

/// Dynamic-size fallback for (mr, nr) pairs without a monomorphized
/// kernel. Same algorithm and therefore bitwise-identical results; the
/// accumulator tile lives on the stack but indices are runtime values,
/// so it will not be held in registers. Used only for exotic `--mr/--nr`
/// experiments.
#[allow(clippy::too_many_arguments)]
fn ukr_dyn<T: Element>(
    fma: bool,
    apanel: &[T],
    bpanel: &[T],
    kb: usize,
    c: &mut [T],
    ldc: usize,
    h: usize,
    w: usize,
    mr: usize,
    nr: usize,
) {
    debug_assert!(mr <= MAX_MICRO && nr <= MAX_MICRO);
    debug_assert!(apanel.len() >= kb * mr && bpanel.len() >= kb * nr);
    debug_assert!(h <= mr && w <= nr);
    let mut acc = [T::default(); MAX_MICRO * MAX_MICRO];
    for r in 0..h {
        for cc in 0..w {
            acc[r * nr + cc] = c[r * ldc + cc];
        }
    }
    for kk in 0..kb {
        let av = &apanel[kk * mr..kk * mr + mr];
        let bv = &bpanel[kk * nr..kk * nr + nr];
        for (r, &ar) in av.iter().enumerate() {
            let arow = &mut acc[r * nr..r * nr + nr];
            if fma {
                for (a, &bb) in arow.iter_mut().zip(bv) {
                    *a = ar.madd(bb, *a);
                }
            } else {
                for (a, &bb) in arow.iter_mut().zip(bv) {
                    *a = a.add(ar.mul(bb));
                }
            }
        }
    }
    for r in 0..h {
        for cc in 0..w {
            c[r * ldc + cc] = acc[r * nr + cc];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Reference: the naive sequential/FMA schedule on unpacked operands.
    fn reference(fma: bool, a: &[f64], b: &[f64], m: usize, k: usize, n: usize) -> Vec<f64> {
        let mut c = vec![0.0; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut acc = 0.0f64;
                for kk in 0..k {
                    let p = a[i * k + kk];
                    let q = b[kk * n + j];
                    if fma {
                        acc = p.mul_add(q, acc);
                    } else {
                        acc += p * q;
                    }
                }
                c[i * n + j] = acc;
            }
        }
        c
    }

    fn pack_for_tile(a: &[f64], b: &[f64], m: usize, k: usize, n: usize, mr: usize, nr: usize) -> (Vec<f64>, Vec<f64>) {
        // Single micro-panel each, zero-padded to (mr, nr).
        let mut ap = vec![0.0; k * mr];
        for r in 0..m {
            for kk in 0..k {
                ap[kk * mr + r] = a[r * k + kk];
            }
        }
        let mut bp = vec![0.0; k * nr];
        for kk in 0..k {
            for cc in 0..n {
                bp[kk * nr + cc] = b[kk * n + cc];
            }
        }
        (ap, bp)
    }

    #[test]
    fn micro_tile_matches_reference_all_sizes() {
        // One zero-padded tile per (mr, nr), monomorphized and dynamic.
        let (m, k, n) = (5, 23, 7);
        let a: Vec<f64> = (0..m * k).map(|i| ((i * 37 + 11) % 97) as f64 * 0.0625 - 2.0).collect();
        let b: Vec<f64> = (0..k * n).map(|i| ((i * 53 + 29) % 89) as f64 * 0.03125 - 1.0).collect();
        for (mr, nr) in [(8usize, 8usize), (8, 16), (16, 4), (5, 7), (3, 9)] {
            if mr < m || nr < n {
                continue;
            }
            let (ap, bp) = pack_for_tile(&a, &b, m, k, n, mr, nr);
            for fma in [false, true] {
                let want = reference(fma, &a, &b, m, k, n);
                let mut c = vec![0.0; m * n];
                run_micro(SimdLevel::Scalar, fma, &ap, &bp, k, &mut c, n, m, n, mr, nr);
                assert_eq!(c, want, "mr={mr} nr={nr} fma={fma}");
                // Every available explicit level must produce the same
                // bits through the public entry point.
                for level in SimdLevel::available_levels() {
                    let mut c = vec![0.0; m * n];
                    run_micro(level, fma, &ap, &bp, k, &mut c, n, m, n, mr, nr);
                    assert_eq!(c, want, "mr={mr} nr={nr} fma={fma} {level}");
                }
            }
        }
    }

    #[test]
    fn accumulates_over_split_k() {
        // Running the kernel over two K-blocks with C carried in memory
        // must equal one full-K run (the carried accumulator round-trips
        // through memory exactly).
        let (m, k, n) = (4, 31, 4);
        let a: Vec<f64> = (0..m * k).map(|i| (i as f64).sin()).collect();
        let b: Vec<f64> = (0..k * n).map(|i| (i as f64).cos()).collect();
        let (mr, nr) = (4, 4);
        let (ap, bp) = pack_for_tile(&a, &b, m, k, n, mr, nr);
        let want = reference(false, &a, &b, m, k, n);
        let split = 17;
        for level in SimdLevel::available_levels() {
            let mut c = vec![0.0; m * n];
            let (ap1, bp1) = (&ap[..split * mr], &bp[..split * nr]);
            run_micro(level, false, ap1, bp1, split, &mut c, n, m, n, mr, nr);
            let (ap2, bp2) = (&ap[split * mr..], &bp[split * nr..]);
            run_micro(level, false, ap2, bp2, k - split, &mut c, n, m, n, mr, nr);
            assert_eq!(c, want, "{level}");
        }
    }
}
