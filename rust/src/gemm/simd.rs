//! Explicit `std::arch` SIMD microkernels behind runtime CPU-feature
//! dispatch.
//!
//! The scalar microkernels in [`crate::gemm::micro`] rely on the
//! autovectorizer finding the one legal vector axis. This module makes
//! that axis explicit: hand-written AVX2+FMA kernels on x86-64 and NEON
//! kernels on aarch64, selected at runtime by [`SimdLevel`] and CPU
//! feature detection.
//!
//! **Why these kernels are schedule-preserving.** Each vector register
//! holds accumulators for *independent output columns* of one output
//! row. Per K step the kernel broadcasts one A value, loads NR packed B
//! values, and performs one vector op per accumulator register — so lane
//! `j` executes exactly the scalar schedule for element `(i, j)`:
//! `acc = acc + round(a·b)` (sequential — `add(mul)` with the same
//! operand order as [`crate::gemm::micro::Element::add`]) or one fused
//! `acc = fma(a, b, acc)` per step, K ascending. Vector IEEE-754 ops are
//! lane-wise exact copies of their scalar counterparts, so the output is
//! **bitwise-identical** to the scalar microkernel — there is no
//! within-K vectorization, no horizontal reduction, no re-association
//! anywhere. Ragged tiles reuse the packing contract: padded A rows are
//! simply skipped (they are never stored) and partial-width C columns go
//! through a zero-padded stack buffer, with the padded B lanes being the
//! zeros the packer wrote.
//!
//! Dispatch never changes results, only speed — [`SimdLevel`] is part of
//! [`crate::gemm::ParallelismConfig`] and is covered by the same bitwise
//! equivalence suites as threads/tiles/micro shapes
//! (`tests/simd_dispatch.rs`, `tests/tiled_equivalence.rs`).
//!
//! **AVX-512 note.** 512-bit `_mm512_*` intrinsics are not stable on
//! this crate's MSRV (1.74), so [`SimdLevel::Avx512`] — selected only
//! when `avx512f` is actually detected, and recorded as such in tuning
//! manifests — dispatches the widest kernels stable `std::arch` can
//! express: the 256-bit AVX2+FMA set, double-pumped for NR = 16. True
//! 512-bit kernels can slot in behind the same level without touching
//! any interface once the intrinsics stabilize.

/// Instruction-set level for the explicit GEMM microkernels.
///
/// A pure scheduling knob: every level produces bitwise-identical
/// outputs (see the module docs); forcing a level that the host cannot
/// execute silently falls back to [`SimdLevel::Scalar`] at
/// [`SimdLevel::resolve`] time (CLIs reject it loudly instead).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum SimdLevel {
    /// Detect at runtime and use the widest available level.
    #[default]
    Auto,
    /// The portable scalar microkernels (autovectorized at best).
    Scalar,
    /// 256-bit AVX2 + FMA kernels (x86-64).
    Avx2,
    /// AVX-512-capable hosts (requires `avx512f`): dispatches the widest
    /// kernels stable `std::arch` offers at this crate's MSRV — the
    /// 256-bit AVX2+FMA set, double-pumped for NR = 16 (see the module
    /// docs). Kept as a distinct level so manifests and bench rows
    /// record the detected ISA truthfully.
    Avx512,
    /// 128-bit NEON kernels (aarch64).
    Neon,
}

impl SimdLevel {
    /// Every level, detection order (widest first within each arch).
    pub const ALL: [SimdLevel; 5] =
        [SimdLevel::Auto, SimdLevel::Scalar, SimdLevel::Avx2, SimdLevel::Avx512, SimdLevel::Neon];

    /// Short lowercase name used in CLIs, manifests and bench rows.
    pub fn name(self) -> &'static str {
        match self {
            SimdLevel::Auto => "auto",
            SimdLevel::Scalar => "scalar",
            SimdLevel::Avx2 => "avx2",
            SimdLevel::Avx512 => "avx512",
            SimdLevel::Neon => "neon",
        }
    }

    /// Parse a [`SimdLevel::name`] string (`auto|scalar|avx2|avx512|neon`).
    pub fn parse(s: &str) -> Option<SimdLevel> {
        SimdLevel::ALL.iter().copied().find(|l| l.name() == s)
    }

    /// Whether this host can execute the level's kernels right now.
    /// `Auto` and `Scalar` are always available; explicit levels require
    /// both the right target arch and runtime CPU-feature detection.
    pub fn is_available(self) -> bool {
        match self {
            SimdLevel::Auto | SimdLevel::Scalar => true,
            #[cfg(target_arch = "x86_64")]
            SimdLevel::Avx2 => {
                std::arch::is_x86_feature_detected!("avx2")
                    && std::arch::is_x86_feature_detected!("fma")
            }
            #[cfg(target_arch = "x86_64")]
            SimdLevel::Avx512 => {
                SimdLevel::Avx2.is_available() && std::arch::is_x86_feature_detected!("avx512f")
            }
            #[cfg(target_arch = "aarch64")]
            SimdLevel::Neon => std::arch::is_aarch64_feature_detected!("neon"),
            #[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
            _ => false,
            #[cfg(target_arch = "x86_64")]
            SimdLevel::Neon => false,
            #[cfg(target_arch = "aarch64")]
            SimdLevel::Avx2 | SimdLevel::Avx512 => false,
        }
    }

    /// The widest level this host can execute (never `Auto`; `Scalar`
    /// when no explicit kernels apply). Detection is cached.
    pub fn detect() -> SimdLevel {
        static DETECTED: std::sync::OnceLock<SimdLevel> = std::sync::OnceLock::new();
        *DETECTED.get_or_init(|| {
            for level in [SimdLevel::Avx512, SimdLevel::Avx2, SimdLevel::Neon] {
                if level.is_available() {
                    return level;
                }
            }
            SimdLevel::Scalar
        })
    }

    /// Resolve to a concrete executable level: `Auto` becomes
    /// [`SimdLevel::detect`], an unavailable forced level degrades to
    /// `Scalar` (bitwise-identical — dispatch is pure scheduling).
    pub fn resolve(self) -> SimdLevel {
        match self {
            SimdLevel::Auto => SimdLevel::detect(),
            level if level.is_available() => level,
            _ => SimdLevel::Scalar,
        }
    }

    /// The distinct concrete levels this host can execute, `Scalar`
    /// first — the sweep axis for equivalence tests and A/B benches.
    pub fn available_levels() -> Vec<SimdLevel> {
        let mut out = vec![SimdLevel::Scalar];
        for level in [SimdLevel::Avx2, SimdLevel::Avx512, SimdLevel::Neon] {
            if level.is_available() {
                out.push(level);
            }
        }
        out
    }
}

impl std::fmt::Display for SimdLevel {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Detected CPU-feature label recorded in tuning manifests and bench
/// rows (e.g. `avx2+fma`, `avx2+fma+avx512f`, `neon`, `scalar`).
pub fn cpu_features() -> String {
    #[cfg(target_arch = "x86_64")]
    {
        let mut feats: Vec<&str> = Vec::new();
        if std::arch::is_x86_feature_detected!("avx2") {
            feats.push("avx2");
        }
        if std::arch::is_x86_feature_detected!("fma") {
            feats.push("fma");
        }
        if std::arch::is_x86_feature_detected!("avx512f") {
            feats.push("avx512f");
        }
        if feats.is_empty() {
            "x86-64-baseline".to_string()
        } else {
            feats.join("+")
        }
    }
    #[cfg(target_arch = "aarch64")]
    {
        if std::arch::is_aarch64_feature_detected!("neon") {
            "neon".to_string()
        } else {
            "aarch64-baseline".to_string()
        }
    }
    #[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
    {
        "scalar".to_string()
    }
}

/// Explicit-SIMD f32 micro-tile update, bitwise-identical to the scalar
/// [`crate::gemm::micro::run_micro`] path. Returns `false` when no
/// kernel covers this (level, mr, nr) — the caller then runs the scalar
/// kernel, which produces the same bits.
pub(crate) fn run_f32(
    level: SimdLevel,
    fma: bool,
    apanel: &[f32],
    bpanel: &[f32],
    kb: usize,
    c: &mut [f32],
    ldc: usize,
    h: usize,
    w: usize,
    mr: usize,
    nr: usize,
) -> bool {
    #[cfg(target_arch = "x86_64")]
    if matches!(level, SimdLevel::Avx2 | SimdLevel::Avx512) && SimdLevel::Avx2.is_available() {
        // SAFETY: avx2+fma verified available on this CPU just above.
        return unsafe { x86::run_f32(fma, apanel, bpanel, kb, c, ldc, h, w, mr, nr) };
    }
    #[cfg(target_arch = "aarch64")]
    if level == SimdLevel::Neon && SimdLevel::Neon.is_available() {
        // SAFETY: neon verified available on this CPU just above.
        return unsafe { neon::run_f32(fma, apanel, bpanel, kb, c, ldc, h, w, mr, nr) };
    }
    let _ = (level, fma, apanel, bpanel, kb, c, ldc, h, w, mr, nr);
    false
}

/// Explicit-SIMD f64 micro-tile update (see [`run_f32`]).
pub(crate) fn run_f64(
    level: SimdLevel,
    fma: bool,
    apanel: &[f64],
    bpanel: &[f64],
    kb: usize,
    c: &mut [f64],
    ldc: usize,
    h: usize,
    w: usize,
    mr: usize,
    nr: usize,
) -> bool {
    #[cfg(target_arch = "x86_64")]
    if matches!(level, SimdLevel::Avx2 | SimdLevel::Avx512) && SimdLevel::Avx2.is_available() {
        // SAFETY: avx2+fma verified available on this CPU just above.
        return unsafe { x86::run_f64(fma, apanel, bpanel, kb, c, ldc, h, w, mr, nr) };
    }
    #[cfg(target_arch = "aarch64")]
    if level == SimdLevel::Neon && SimdLevel::Neon.is_available() {
        // SAFETY: neon verified available on this CPU just above.
        return unsafe { neon::run_f64(fma, apanel, bpanel, kb, c, ldc, h, w, mr, nr) };
    }
    let _ = (level, fma, apanel, bpanel, kb, c, ldc, h, w, mr, nr);
    false
}

/// Generates one monomorphic SIMD microkernel: MR accumulator rows × NV
/// vector registers of LANES columns each (NR = NV·LANES). The loop body
/// mirrors the scalar `ukr` exactly — same operand order, one vector op
/// per element per K step, K ascending — so every lane is bitwise-equal
/// to the scalar schedule. Written as a macro over (MR, NV) literals
/// because `#[target_feature]` cannot be combined with const generics on
/// the MSRV toolchain.
macro_rules! simd_ukr {
    ($name:ident, $ty:ty, $vty:ty, $lanes:expr, $mr:expr, $nv:expr,
     $feature:literal, $setzero:ident, $loadu:ident, $storeu:ident,
     $set1:ident, $fmadd:ident, $add:ident, $mul:ident) => {
        #[target_feature(enable = $feature)]
        unsafe fn $name(
            fma: bool,
            apanel: &[$ty],
            bpanel: &[$ty],
            kb: usize,
            c: &mut [$ty],
            ldc: usize,
            h: usize,
            w: usize,
        ) {
            const MR: usize = $mr;
            const NV: usize = $nv;
            const LANES: usize = $lanes;
            const NR: usize = NV * LANES;
            debug_assert!(apanel.len() >= kb * MR && bpanel.len() >= kb * NR);
            debug_assert!(h >= 1 && h <= MR && w <= NR);
            let mut acc = [[$setzero(); NV]; MR];
            let mut buf = [0 as $ty; LANES];
            // Load the live C tile: full vectors directly, the ragged
            // tail through a zero-padded stack buffer. Padded lanes are
            // scratch that is never stored — exactly the scalar
            // kernel's padded-accumulator contract.
            for r in 0..h {
                for v in 0..NV {
                    let lo = v * LANES;
                    if lo >= w {
                        break;
                    }
                    let take = (w - lo).min(LANES);
                    acc[r][v] = if take == LANES {
                        $loadu(c.as_ptr().add(r * ldc + lo))
                    } else {
                        buf = [0 as $ty; LANES];
                        buf[..take].copy_from_slice(&c[r * ldc + lo..r * ldc + lo + take]);
                        $loadu(buf.as_ptr())
                    };
                }
            }
            // K ascending; per step: broadcast one A value per row, one
            // vector op per accumulator register. Lane j of register
            // (r, v) is element (r, v·LANES + j)'s scalar schedule.
            if fma {
                for kk in 0..kb {
                    let bp = bpanel.as_ptr().add(kk * NR);
                    let mut bv = [$setzero(); NV];
                    for v in 0..NV {
                        bv[v] = $loadu(bp.add(v * LANES));
                    }
                    let ap = apanel.as_ptr().add(kk * MR);
                    for r in 0..h {
                        let av = $set1(*ap.add(r));
                        for v in 0..NV {
                            // acc = fma(a, b, acc): one rounding, the
                            // scalar `madd` per lane.
                            acc[r][v] = $fmadd(av, bv[v], acc[r][v]);
                        }
                    }
                }
            } else {
                for kk in 0..kb {
                    let bp = bpanel.as_ptr().add(kk * NR);
                    let mut bv = [$setzero(); NV];
                    for v in 0..NV {
                        bv[v] = $loadu(bp.add(v * LANES));
                    }
                    let ap = apanel.as_ptr().add(kk * MR);
                    for r in 0..h {
                        let av = $set1(*ap.add(r));
                        for v in 0..NV {
                            // acc = acc + round(a·b): two roundings in
                            // the scalar `add(mul)` operand order.
                            acc[r][v] = $add(acc[r][v], $mul(av, bv[v]));
                        }
                    }
                }
            }
            for r in 0..h {
                for v in 0..NV {
                    let lo = v * LANES;
                    if lo >= w {
                        break;
                    }
                    let take = (w - lo).min(LANES);
                    if take == LANES {
                        $storeu(c.as_mut_ptr().add(r * ldc + lo), acc[r][v]);
                    } else {
                        $storeu(buf.as_mut_ptr(), acc[r][v]);
                        c[r * ldc + lo..r * ldc + lo + take].copy_from_slice(&buf[..take]);
                    }
                }
            }
        }
    };
}

#[cfg(target_arch = "x86_64")]
mod x86 {
    //! AVX2+FMA kernels: 8-lane f32 / 4-lane f64 256-bit registers,
    //! NR = 16 shapes double-pumped over two registers.
    use std::arch::x86_64::*;

    macro_rules! x86_f32 {
        ($name:ident, $mr:expr, $nv:expr) => {
            simd_ukr!(
                $name, f32, __m256, 8, $mr, $nv, "avx2,fma", _mm256_setzero_ps,
                _mm256_loadu_ps, _mm256_storeu_ps, _mm256_set1_ps, _mm256_fmadd_ps,
                _mm256_add_ps, _mm256_mul_ps
            );
        };
    }
    macro_rules! x86_f64 {
        ($name:ident, $mr:expr, $nv:expr) => {
            simd_ukr!(
                $name, f64, __m256d, 4, $mr, $nv, "avx2,fma", _mm256_setzero_pd,
                _mm256_loadu_pd, _mm256_storeu_pd, _mm256_set1_pd, _mm256_fmadd_pd,
                _mm256_add_pd, _mm256_mul_pd
            );
        };
    }

    x86_f32!(ukr_f32_2x8, 2, 1);
    x86_f32!(ukr_f32_4x8, 4, 1);
    x86_f32!(ukr_f32_8x8, 8, 1);
    x86_f32!(ukr_f32_4x16, 4, 2);
    x86_f32!(ukr_f32_8x16, 8, 2);

    x86_f64!(ukr_f64_2x4, 2, 1);
    x86_f64!(ukr_f64_4x4, 4, 1);
    x86_f64!(ukr_f64_8x4, 8, 1);
    x86_f64!(ukr_f64_16x4, 16, 1);
    x86_f64!(ukr_f64_2x8, 2, 2);
    x86_f64!(ukr_f64_4x8, 4, 2);
    x86_f64!(ukr_f64_8x8, 8, 2);
    x86_f64!(ukr_f64_4x16, 4, 4);
    x86_f64!(ukr_f64_8x16, 8, 4);

    /// # Safety
    /// Caller must have verified avx2+fma via CPU-feature detection.
    pub(super) unsafe fn run_f32(
        fma: bool,
        apanel: &[f32],
        bpanel: &[f32],
        kb: usize,
        c: &mut [f32],
        ldc: usize,
        h: usize,
        w: usize,
        mr: usize,
        nr: usize,
    ) -> bool {
        match (mr, nr) {
            (2, 8) => ukr_f32_2x8(fma, apanel, bpanel, kb, c, ldc, h, w),
            (4, 8) => ukr_f32_4x8(fma, apanel, bpanel, kb, c, ldc, h, w),
            (8, 8) => ukr_f32_8x8(fma, apanel, bpanel, kb, c, ldc, h, w),
            (4, 16) => ukr_f32_4x16(fma, apanel, bpanel, kb, c, ldc, h, w),
            (8, 16) => ukr_f32_8x16(fma, apanel, bpanel, kb, c, ldc, h, w),
            _ => return false,
        }
        true
    }

    /// # Safety
    /// Caller must have verified avx2+fma via CPU-feature detection.
    pub(super) unsafe fn run_f64(
        fma: bool,
        apanel: &[f64],
        bpanel: &[f64],
        kb: usize,
        c: &mut [f64],
        ldc: usize,
        h: usize,
        w: usize,
        mr: usize,
        nr: usize,
    ) -> bool {
        match (mr, nr) {
            (2, 4) => ukr_f64_2x4(fma, apanel, bpanel, kb, c, ldc, h, w),
            (4, 4) => ukr_f64_4x4(fma, apanel, bpanel, kb, c, ldc, h, w),
            (8, 4) => ukr_f64_8x4(fma, apanel, bpanel, kb, c, ldc, h, w),
            (16, 4) => ukr_f64_16x4(fma, apanel, bpanel, kb, c, ldc, h, w),
            (2, 8) => ukr_f64_2x8(fma, apanel, bpanel, kb, c, ldc, h, w),
            (4, 8) => ukr_f64_4x8(fma, apanel, bpanel, kb, c, ldc, h, w),
            (8, 8) => ukr_f64_8x8(fma, apanel, bpanel, kb, c, ldc, h, w),
            (4, 16) => ukr_f64_4x16(fma, apanel, bpanel, kb, c, ldc, h, w),
            (8, 16) => ukr_f64_8x16(fma, apanel, bpanel, kb, c, ldc, h, w),
            _ => return false,
        }
        true
    }
}

#[cfg(target_arch = "aarch64")]
mod neon {
    //! NEON kernels: 4-lane f32 / 2-lane f64 128-bit registers, wider NR
    //! shapes multi-pumped across registers.
    use std::arch::aarch64::*;

    /// `vfmaq` takes the addend first (`acc + a·b`); adapt to the
    /// `fma(a, b, acc)` argument order the shared macro expects.
    #[inline(always)]
    unsafe fn fma_f32(a: float32x4_t, b: float32x4_t, acc: float32x4_t) -> float32x4_t {
        vfmaq_f32(acc, a, b)
    }
    /// See [`fma_f32`].
    #[inline(always)]
    unsafe fn fma_f64(a: float64x2_t, b: float64x2_t, acc: float64x2_t) -> float64x2_t {
        vfmaq_f64(acc, a, b)
    }
    /// Zero register (macro expects a no-arg constructor).
    #[inline(always)]
    unsafe fn zero_f32() -> float32x4_t {
        vdupq_n_f32(0.0)
    }
    /// See [`zero_f32`].
    #[inline(always)]
    unsafe fn zero_f64() -> float64x2_t {
        vdupq_n_f64(0.0)
    }

    macro_rules! neon_f32 {
        ($name:ident, $mr:expr, $nv:expr) => {
            simd_ukr!(
                $name, f32, float32x4_t, 4, $mr, $nv, "neon", zero_f32, vld1q_f32,
                vst1q_f32, vdupq_n_f32, fma_f32, vaddq_f32, vmulq_f32
            );
        };
    }
    macro_rules! neon_f64 {
        ($name:ident, $mr:expr, $nv:expr) => {
            simd_ukr!(
                $name, f64, float64x2_t, 2, $mr, $nv, "neon", zero_f64, vld1q_f64,
                vst1q_f64, vdupq_n_f64, fma_f64, vaddq_f64, vmulq_f64
            );
        };
    }

    neon_f32!(ukr_f32_2x4, 2, 1);
    neon_f32!(ukr_f32_4x4, 4, 1);
    neon_f32!(ukr_f32_8x4, 8, 1);
    neon_f32!(ukr_f32_16x4, 16, 1);
    neon_f32!(ukr_f32_2x8, 2, 2);
    neon_f32!(ukr_f32_4x8, 4, 2);
    neon_f32!(ukr_f32_8x8, 8, 2);
    neon_f32!(ukr_f32_4x16, 4, 4);
    neon_f32!(ukr_f32_8x16, 8, 4);

    neon_f64!(ukr_f64_2x4, 2, 2);
    neon_f64!(ukr_f64_4x4, 4, 2);
    neon_f64!(ukr_f64_8x4, 8, 2);
    neon_f64!(ukr_f64_16x4, 16, 2);
    neon_f64!(ukr_f64_4x8, 4, 4);
    neon_f64!(ukr_f64_8x8, 8, 4);

    /// # Safety
    /// Caller must have verified neon via CPU-feature detection.
    pub(super) unsafe fn run_f32(
        fma: bool,
        apanel: &[f32],
        bpanel: &[f32],
        kb: usize,
        c: &mut [f32],
        ldc: usize,
        h: usize,
        w: usize,
        mr: usize,
        nr: usize,
    ) -> bool {
        match (mr, nr) {
            (2, 4) => ukr_f32_2x4(fma, apanel, bpanel, kb, c, ldc, h, w),
            (4, 4) => ukr_f32_4x4(fma, apanel, bpanel, kb, c, ldc, h, w),
            (8, 4) => ukr_f32_8x4(fma, apanel, bpanel, kb, c, ldc, h, w),
            (16, 4) => ukr_f32_16x4(fma, apanel, bpanel, kb, c, ldc, h, w),
            (2, 8) => ukr_f32_2x8(fma, apanel, bpanel, kb, c, ldc, h, w),
            (4, 8) => ukr_f32_4x8(fma, apanel, bpanel, kb, c, ldc, h, w),
            (8, 8) => ukr_f32_8x8(fma, apanel, bpanel, kb, c, ldc, h, w),
            (4, 16) => ukr_f32_4x16(fma, apanel, bpanel, kb, c, ldc, h, w),
            (8, 16) => ukr_f32_8x16(fma, apanel, bpanel, kb, c, ldc, h, w),
            _ => return false,
        }
        true
    }

    /// # Safety
    /// Caller must have verified neon via CPU-feature detection.
    pub(super) unsafe fn run_f64(
        fma: bool,
        apanel: &[f64],
        bpanel: &[f64],
        kb: usize,
        c: &mut [f64],
        ldc: usize,
        h: usize,
        w: usize,
        mr: usize,
        nr: usize,
    ) -> bool {
        match (mr, nr) {
            (2, 4) => ukr_f64_2x4(fma, apanel, bpanel, kb, c, ldc, h, w),
            (4, 4) => ukr_f64_4x4(fma, apanel, bpanel, kb, c, ldc, h, w),
            (8, 4) => ukr_f64_8x4(fma, apanel, bpanel, kb, c, ldc, h, w),
            (16, 4) => ukr_f64_16x4(fma, apanel, bpanel, kb, c, ldc, h, w),
            (4, 8) => ukr_f64_4x8(fma, apanel, bpanel, kb, c, ldc, h, w),
            (8, 8) => ukr_f64_8x8(fma, apanel, bpanel, kb, c, ldc, h, w),
            _ => return false,
        }
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gemm::micro;

    /// Candidate micro-shapes across both element widths and ISAs; the
    /// dispatchers return `false` for uncovered pairs, which the test
    /// treats as "scalar fallback, nothing to compare".
    const SHAPES: [(usize, usize); 10] =
        [(2, 4), (2, 8), (4, 4), (4, 8), (4, 16), (8, 4), (8, 8), (8, 16), (16, 4), (3, 5)];

    fn fill_f64(len: usize, seed: u64) -> Vec<f64> {
        let mut rng = crate::rng::Xoshiro256pp::seed_from_u64(seed);
        let d = crate::rng::Distribution::uniform_pm1();
        (0..len).map(|_| d.sample(&mut rng)).collect()
    }

    /// One packed tile per (mr, nr): SIMD output (when a kernel claims
    /// the shape) must be bitwise-equal to the scalar microkernel, at
    /// full and at ragged (h, w) extents.
    #[test]
    fn simd_tiles_bitwise_equal_scalar() {
        for level in SimdLevel::available_levels() {
            if level == SimdLevel::Scalar {
                continue;
            }
            for &(mr, nr) in &SHAPES {
                for fma in [false, true] {
                    for (h, w) in [(mr, nr), (mr - 1, nr - 1), (1, 1)] {
                        let kb = 29;
                        let ap64 = fill_f64(kb * mr, 0xA0 + mr as u64);
                        let bp64 = fill_f64(kb * nr, 0xB0 + nr as u64);
                        let c064 = fill_f64(h * nr, 0xC0);
                        // f64 lane check
                        let mut want = c064.clone();
                        micro::run_micro(
                            SimdLevel::Scalar,
                            fma,
                            &ap64,
                            &bp64,
                            kb,
                            &mut want,
                            nr,
                            h,
                            w,
                            mr,
                            nr,
                        );
                        let mut got = c064.clone();
                        if run_f64(level, fma, &ap64, &bp64, kb, &mut got, nr, h, w, mr, nr) {
                            assert_eq!(got, want, "f64 {level} {mr}x{nr} fma={fma} h={h} w={w}");
                        } else {
                            assert_eq!(got, c064, "claimed-false kernel wrote: f64 {level}");
                        }
                        // f32 lane check
                        let ap32: Vec<f32> = ap64.iter().map(|&x| x as f32).collect();
                        let bp32: Vec<f32> = bp64.iter().map(|&x| x as f32).collect();
                        let c032: Vec<f32> = c064.iter().map(|&x| x as f32).collect();
                        let mut want = c032.clone();
                        micro::run_micro(
                            SimdLevel::Scalar,
                            fma,
                            &ap32,
                            &bp32,
                            kb,
                            &mut want,
                            nr,
                            h,
                            w,
                            mr,
                            nr,
                        );
                        let mut got = c032.clone();
                        if run_f32(level, fma, &ap32, &bp32, kb, &mut got, nr, h, w, mr, nr) {
                            assert_eq!(got, want, "f32 {level} {mr}x{nr} fma={fma} h={h} w={w}");
                        } else {
                            assert_eq!(got, c032, "claimed-false kernel wrote: f32 {level}");
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn level_parse_name_round_trip() {
        for level in SimdLevel::ALL {
            assert_eq!(SimdLevel::parse(level.name()), Some(level));
        }
        assert_eq!(SimdLevel::parse("sse9"), None);
        assert_eq!(SimdLevel::default(), SimdLevel::Auto);
    }

    #[test]
    fn resolve_is_concrete_and_executable() {
        for level in SimdLevel::ALL {
            let resolved = level.resolve();
            assert_ne!(resolved, SimdLevel::Auto, "{level} resolved to Auto");
            assert!(resolved.is_available(), "{level} resolved to unavailable {resolved}");
        }
        assert_eq!(SimdLevel::Auto.resolve(), SimdLevel::detect());
        // Forcing a level the host lacks degrades to Scalar, never traps.
        for level in [SimdLevel::Avx2, SimdLevel::Avx512, SimdLevel::Neon] {
            if !level.is_available() {
                assert_eq!(level.resolve(), SimdLevel::Scalar);
            }
        }
    }

    #[test]
    fn scalar_and_unknown_shapes_decline() {
        let (ap, bp) = (vec![1.0f32; 8], vec![1.0f32; 8]);
        let mut c = vec![0.0f32; 4];
        // Scalar never claims a tile; exotic shapes fall through too.
        assert!(!run_f32(SimdLevel::Scalar, true, &ap, &bp, 1, &mut c, 2, 2, 2, 8, 8));
        for level in SimdLevel::available_levels() {
            assert!(!run_f32(level, true, &ap, &bp, 1, &mut c, 2, 2, 2, 3, 5));
        }
        assert_eq!(c, vec![0.0; 4]);
        assert!(!cpu_features().is_empty());
    }
}
