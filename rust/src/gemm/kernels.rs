//! Native inner loops for the accumulation models.
//!
//! Each kernel reproduces one *rounding schedule*, which §3.6 of the paper
//! shows is the variable that determines e_max:
//!
//! * `seq_*` — one rounding per multiply and per add, strictly in order
//!   (dependency chain along K). Verification error grows ∝ √K. This is
//!   the paper's "per-step rounding" regime: NPU FP32 and (empirically)
//!   H100 FP32/FP64.
//! * `fma_*` — one rounding per fused multiply-add step; same √K law with
//!   a smaller constant. Provided for ablations.
//! * `pairwise_*` — tree reduction; error depth is log₂K, so e_max is
//!   near-constant in K. This is the paper's CPU (Xeon/FMA/SIMD) regime.
//!
//! The loops are written ikj (products broadcast across the output row) so
//! the compiler can vectorize across N — the accumulators for different
//! output columns are independent, so vectorization does not alter the
//! per-element rounding schedule.
//!
//! These kernels are the **schedule reference**: production GEMMs run on
//! the tiled parallel engine in [`crate::gemm::tiled`], whose contract is
//! bitwise equality with the functions here for every strategy, tile
//! shape and thread count (`tests/tiled_equivalence.rs`). Change a
//! schedule here and the engine, the e_max calibrations and the
//! equivalence tests all move together — or not at all.

use super::ReduceStrategy;

/// f64 → f32 conversion of a slice (one rounding per element).
pub fn to_f32_vec(xs: &[f64]) -> Vec<f32> {
    xs.iter().map(|&x| x as f32).collect()
}

/// Dispatch to the f32 reference kernel of a strategy — the single place
/// callers (CLI, benches, equivalence tests) get the naive baseline from.
pub fn reference_gemm_f32(
    a: &[f32],
    b: &[f32],
    m: usize,
    k: usize,
    n: usize,
    strategy: ReduceStrategy,
) -> Vec<f32> {
    match strategy {
        ReduceStrategy::Sequential => seq_gemm_f32(a, b, m, k, n),
        ReduceStrategy::Fma => fma_gemm_f32(a, b, m, k, n),
        ReduceStrategy::Pairwise => pairwise_gemm_f32(a, b, m, k, n),
    }
}

/// Dispatch to the f64 reference kernel of a strategy.
pub fn reference_gemm_f64(
    a: &[f64],
    b: &[f64],
    m: usize,
    k: usize,
    n: usize,
    strategy: ReduceStrategy,
) -> Vec<f64> {
    match strategy {
        ReduceStrategy::Sequential => seq_gemm_f64(a, b, m, k, n),
        ReduceStrategy::Fma => fma_gemm_f64(a, b, m, k, n),
        ReduceStrategy::Pairwise => pairwise_gemm_f64(a, b, m, k, n),
    }
}

macro_rules! kernels_for {
    ($seq:ident, $fma:ident, $pair:ident, $seq_reduce:ident, $pair_reduce:ident,
     $seq_dot:ident, $fma_dot:ident, $ty:ty) => {
        /// Sequential-rounding GEMM: C[i][j] = fl(... fl(fl(c + fl(a·b))))
        /// with one product rounding and one add rounding per K step.
        pub fn $seq(a: &[$ty], b: &[$ty], m: usize, k: usize, n: usize) -> Vec<$ty> {
            debug_assert_eq!(a.len(), m * k);
            debug_assert_eq!(b.len(), k * n);
            let mut c = vec![0 as $ty; m * n];
            for i in 0..m {
                let crow = &mut c[i * n..(i + 1) * n];
                for kk in 0..k {
                    let av = a[i * k + kk];
                    let brow = &b[kk * n..(kk + 1) * n];
                    for (cv, &bv) in crow.iter_mut().zip(brow) {
                        *cv += av * bv; // round(mul) then round(add)
                    }
                }
            }
            c
        }

        /// FMA GEMM: one rounding per step via fused multiply-add.
        pub fn $fma(a: &[$ty], b: &[$ty], m: usize, k: usize, n: usize) -> Vec<$ty> {
            debug_assert_eq!(a.len(), m * k);
            debug_assert_eq!(b.len(), k * n);
            let mut c = vec![0 as $ty; m * n];
            for i in 0..m {
                let crow = &mut c[i * n..(i + 1) * n];
                for kk in 0..k {
                    let av = a[i * k + kk];
                    let brow = &b[kk * n..(kk + 1) * n];
                    for (cv, &bv) in crow.iter_mut().zip(brow) {
                        *cv = av.mul_add(bv, *cv);
                    }
                }
            }
            c
        }

        /// Pairwise (tree) GEMM: products rounded once, then summed by
        /// adjacent-pair combination — reduction depth ⌈log₂K⌉.
        ///
        /// Processes output columns in blocks so the K×NB product buffer
        /// stays cache-resident and every tree level vectorizes across the
        /// block.
        pub fn $pair(a: &[$ty], b: &[$ty], m: usize, k: usize, n: usize) -> Vec<$ty> {
            debug_assert_eq!(a.len(), m * k);
            debug_assert_eq!(b.len(), k * n);
            const NB: usize = 64;
            let mut c = vec![0 as $ty; m * n];
            let mut buf = vec![0 as $ty; k.max(1) * NB];
            for i in 0..m {
                let arow = &a[i * k..(i + 1) * k];
                let mut j0 = 0;
                while j0 < n {
                    let jw = NB.min(n - j0);
                    // products
                    for kk in 0..k {
                        let av = arow[kk];
                        let brow = &b[kk * n + j0..kk * n + j0 + jw];
                        let dst = &mut buf[kk * NB..kk * NB + jw];
                        for (d, &bv) in dst.iter_mut().zip(brow) {
                            *d = av * bv;
                        }
                    }
                    // pairwise tree along k, vectorized across the block
                    let mut len = k;
                    while len > 1 {
                        let half = len / 2;
                        for p in 0..half {
                            let (lo, hi) = buf.split_at_mut((2 * p + 1) * NB);
                            let dst = &mut lo[2 * p * NB..2 * p * NB + jw];
                            let src = &hi[..jw];
                            for (d, &s) in dst.iter_mut().zip(src) {
                                *d += s;
                            }
                        }
                        // compact: move pair sums (at even slots) down
                        for p in 0..half {
                            if p != 2 * p {
                                buf.copy_within(2 * p * NB..2 * p * NB + jw, p * NB);
                            }
                        }
                        if len % 2 == 1 {
                            buf.copy_within((len - 1) * NB..(len - 1) * NB + jw, half * NB);
                            len = half + 1;
                        } else {
                            len = half;
                        }
                    }
                    let dst = &mut c[i * n + j0..i * n + j0 + jw];
                    dst.copy_from_slice(&buf[..jw]);
                    j0 += jw;
                }
            }
            c
        }

        /// Sequential-rounding sum.
        pub fn $seq_reduce(xs: &[$ty]) -> $ty {
            let mut acc = 0 as $ty;
            for &x in xs {
                acc += x;
            }
            acc
        }

        /// Pairwise (tree) sum, matching the tree shape of the pairwise
        /// GEMM kernel (adjacent pairs, odd element carried).
        pub fn $pair_reduce(xs: &[$ty]) -> $ty {
            if xs.is_empty() {
                return 0 as $ty;
            }
            let mut buf: Vec<$ty> = xs.to_vec();
            let mut len = buf.len();
            while len > 1 {
                let half = len / 2;
                for p in 0..half {
                    buf[p] = buf[2 * p] + buf[2 * p + 1];
                }
                if len % 2 == 1 {
                    buf[half] = buf[len - 1];
                    len = half + 1;
                } else {
                    len = half;
                }
            }
            buf[0]
        }

        /// Sequential-rounding dot product.
        pub fn $seq_dot(a: &[$ty], b: &[$ty]) -> $ty {
            debug_assert_eq!(a.len(), b.len());
            let mut acc = 0 as $ty;
            for (&x, &y) in a.iter().zip(b) {
                acc += x * y;
            }
            acc
        }

        /// FMA dot product.
        pub fn $fma_dot(a: &[$ty], b: &[$ty]) -> $ty {
            debug_assert_eq!(a.len(), b.len());
            let mut acc = 0 as $ty;
            for (&x, &y) in a.iter().zip(b) {
                acc = x.mul_add(y, acc);
            }
            acc
        }
    };
}

kernels_for!(
    seq_gemm_f32,
    fma_gemm_f32,
    pairwise_gemm_f32,
    seq_reduce_f32,
    pairwise_reduce_f32,
    seq_dot_f32,
    fma_dot_f32,
    f32
);
kernels_for!(
    seq_gemm_f64,
    fma_gemm_f64,
    pairwise_gemm_f64,
    seq_reduce_f64,
    pairwise_reduce_f64,
    seq_dot_f64,
    fma_dot_f64,
    f64
);

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fp::dd::Dd;
    use crate::rng::{Distribution, Xoshiro256pp};

    fn rand_vec(n: usize, seed: u64) -> Vec<f64> {
        let mut rng = Xoshiro256pp::seed_from_u64(seed);
        let d = Distribution::uniform_pm1();
        (0..n).map(|_| d.sample(&mut rng)).collect()
    }

    #[test]
    fn small_gemm_agrees_across_kernels() {
        let (m, k, n) = (4, 6, 5);
        let a = rand_vec(m * k, 1);
        let b = rand_vec(k * n, 2);
        let s = seq_gemm_f64(&a, &b, m, k, n);
        let f = fma_gemm_f64(&a, &b, m, k, n);
        let p = pairwise_gemm_f64(&a, &b, m, k, n);
        // Exact reference via double-double.
        for i in 0..m {
            for j in 0..n {
                let mut acc = Dd::ZERO;
                for kk in 0..k {
                    acc = acc.mul_acc(a[i * k + kk], b[kk * n + j]);
                }
                let exact = acc.to_f64();
                for (name, c) in [("seq", &s), ("fma", &f), ("pair", &p)] {
                    let got = c[i * n + j];
                    assert!(
                        (got - exact).abs() <= 1e-13 * exact.abs().max(1.0),
                        "{name} [{i}][{j}]: {got} vs {exact}"
                    );
                }
            }
        }
    }

    #[test]
    fn pairwise_gemm_matches_pairwise_reduce() {
        // The GEMM kernel's tree must equal the standalone reduction on the
        // same products — otherwise verification paths would diverge.
        let (m, k, n) = (3, 13, 70); // k odd and n > NB exercise edges
        let a = rand_vec(m * k, 3);
        let b = rand_vec(k * n, 4);
        let c = pairwise_gemm_f64(&a, &b, m, k, n);
        for i in 0..m {
            for j in 0..n {
                let prods: Vec<f64> = (0..k).map(|kk| a[i * k + kk] * b[kk * n + j]).collect();
                let want = pairwise_reduce_f64(&prods);
                assert_eq!(c[i * n + j], want, "[{i}][{j}]");
            }
        }
    }

    #[test]
    fn seq_gemm_matches_seq_dot() {
        let (m, k, n) = (2, 37, 9);
        let a = rand_vec(m * k, 5);
        let b = rand_vec(k * n, 6);
        let c = seq_gemm_f64(&a, &b, m, k, n);
        let bt: Vec<f64> = {
            let mut t = vec![0.0; n * k];
            for kk in 0..k {
                for j in 0..n {
                    t[j * k + kk] = b[kk * n + j];
                }
            }
            t
        };
        for i in 0..m {
            for j in 0..n {
                let want = seq_dot_f64(&a[i * k..(i + 1) * k], &bt[j * k..(j + 1) * k]);
                assert_eq!(c[i * n + j], want);
            }
        }
    }

    #[test]
    fn f32_kernels_round_like_f32() {
        // A value that cancels differently in f32 vs f64 must show the f32
        // schedule: big + small - big loses the small term sequentially.
        let a = vec![1.0f32, 1.0, 1.0];
        let b = vec![1e8f32, 1.0, -1e8];
        assert_eq!(seq_dot_f32(&a, &b), 0.0); // 1e8 + 1 → 1e8 in f32
        // pairwise: (1e8 + 1) + (-1e8) = 1e8 + -1e8... pairs are
        // (p0+p1) + p2 = 1e8 + (-1e8) = 0 as well for len 3.
        // Use len 4 to get ((p0+p1)+(p2+p3)):
        let xs = [1e8f32, -1e8, 1.0, 1.0];
        assert_eq!(pairwise_reduce_f32(&xs), 2.0); // (0) + (2)
        assert_eq!(seq_reduce_f32(&xs), 2.0);
        let xs2 = [1e8f32, 1.0, 1.0, -1e8];
        assert_eq!(pairwise_reduce_f32(&xs2), 0.0); // (1e8) + (1-1e8) = 1e8-99999999=?
        assert_eq!(seq_reduce_f32(&xs2), 0.0);
    }

    #[test]
    fn pairwise_error_grows_slower_than_sequential() {
        // The structural property behind the CPU-vs-GPU e_max shapes.
        let n = 1 << 16;
        let xs = rand_vec(n, 7);
        let xs32 = to_f32_vec(&xs);
        let exact = Dd::sum(&xs32.iter().map(|&x| x as f64).collect::<Vec<_>>()).to_f64();
        let seq_err = (seq_reduce_f32(&xs32) as f64 - exact).abs();
        let pair_err = (pairwise_reduce_f32(&xs32) as f64 - exact).abs();
        assert!(
            pair_err <= seq_err.max(1e-6),
            "pairwise {pair_err} should not exceed sequential {seq_err}"
        );
    }

    #[test]
    fn empty_and_single() {
        assert_eq!(pairwise_reduce_f64(&[]), 0.0);
        assert_eq!(pairwise_reduce_f64(&[3.5]), 3.5);
        assert_eq!(seq_reduce_f64(&[]), 0.0);
    }
}
