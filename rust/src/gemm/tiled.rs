//! Tiled, packed, multi-threaded GEMM execution with a **schedule-
//! preservation guarantee**.
//!
//! The naive kernels in [`crate::gemm::kernels`] define, per output
//! element, a *rounding schedule*: the exact order in which the K products
//! are rounded and combined. V-ABFT's whole threshold model (and every
//! calibrated e_max in [`crate::calibrate`]) is a statement about that
//! schedule — so a faster engine is only admissible if it provably does
//! not change it.
//!
//! This engine gets its speed from the three transformations that are
//! schedule-neutral, and only those:
//!
//! * **Parallelism across output rows.** Each worker owns a disjoint
//!   panel of C rows. Different output elements never share an
//!   accumulator, so assigning rows to threads cannot reorder any
//!   element's K-chain. Workers are scoped [`std::thread::scope`] threads
//!   writing through disjoint `chunks_mut` panels — no locks, no atomics,
//!   no cross-worker communication.
//! * **Cache blocking over (K, N) — never *within* one element's
//!   reduction.** For the sequential / FMA schedules, K-blocks are
//!   visited in ascending order with the accumulator carried in place, so
//!   element (i, j) still sees products k = 0, 1, …, K−1 in exactly the
//!   reference order. For the pairwise schedule the reduction tree shape
//!   depends on the *full* K, so products are staged for the whole K
//!   extent (per column block) and the tree is identical to
//!   [`crate::gemm::kernels`]'s — column-block width only changes which
//!   *elements* share a buffer, not any element's tree.
//! * **Packing + register blocking across independent output elements.**
//!   Operands are repacked ([`crate::gemm::pack`]) into contiguous
//!   micro-panels — a bit-for-bit relayout, no arithmetic — and an MR×NR
//!   microkernel ([`crate::gemm::micro`]) keeps a tile of C accumulators
//!   in registers for a whole K-block. The kernel vectorizes **across
//!   output columns/rows only**: each element still receives exactly one
//!   reference-order operation per K step on its own accumulator, which
//!   is the single legal vectorization axis (vectorizing *along* K would
//!   re-associate the reduction and move roundings).
//!
//! The resulting invariant — packed/tiled/parallel output bitwise-equal
//! to the naive reference for every strategy, element type, tile shape,
//! microkernel shape and thread count — is enforced by
//! `tests/tiled_equivalence.rs` and by unit tests below. The pre-packing
//! engine from PR 1 is retained as [`gemm_unpacked_f32`] /
//! [`gemm_unpacked_f64`], both as the middle rung of the bench trajectory
//! (naive → unpacked → packed) and as an independent cross-check.

use super::micro::{self, Element};
use super::pack;
use super::simd::SimdLevel;
use super::ReduceStrategy;
use crate::fp::Precision;

/// Cache-blocking tile sizes (elements, not bytes).
///
/// `mc` bounds the row-panel a worker iterates at a time, `kc` the K-block
/// kept hot while streaming B, `nc` the column-block width (also the
/// product-buffer width of the pairwise schedule). Any positive values are
/// valid; the defaults target ~L2-resident B panels for f32.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TileConfig {
    /// Row-panel height a worker iterates at a time.
    pub mc: usize,
    /// K-block depth kept hot while streaming B.
    pub kc: usize,
    /// Column-block width (also the pairwise product-buffer width).
    pub nc: usize,
}

impl TileConfig {
    /// The measured defaults: ~L2-resident B panels for f32 (see
    /// `docs/PERFORMANCE.md` for the tuning rationale).
    pub const DEFAULT: TileConfig = TileConfig { mc: 64, kc: 256, nc: 128 };

    /// Construct from explicit tile sizes (all must be positive).
    pub fn new(mc: usize, kc: usize, nc: usize) -> TileConfig {
        assert!(mc > 0 && kc > 0 && nc > 0, "tile sizes must be positive");
        TileConfig { mc, kc, nc }
    }
}

impl Default for TileConfig {
    fn default() -> Self {
        TileConfig::DEFAULT
    }
}

/// Microkernel (register-blocking) shape: the MR×NR tile of C
/// accumulators held in registers by the packed engine.
///
/// Results are bitwise-identical for every shape (register blocking only
/// groups *independent* output elements); the shape trades register
/// pressure against operand reuse. Shapes with a monomorphized kernel
/// (see [`crate::gemm::micro::run_micro`]) are fastest; anything else
/// falls back to a dynamic-size kernel.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MicroConfig {
    /// Micro-tile height (rows of C per register block).
    pub mr: usize,
    /// Micro-tile width (columns of C per register block).
    pub nr: usize,
}

impl MicroConfig {
    /// Default 8×8 tile: 8 vector accumulators for AVX2-class f32, still
    /// reasonable for f64 (see `docs/PERFORMANCE.md` for the MR/NR
    /// tuning recipe).
    pub const DEFAULT: MicroConfig = MicroConfig { mr: 8, nr: 8 };

    /// Construct from explicit sizes (each in `1 ..= MAX_MICRO`).
    pub fn new(mr: usize, nr: usize) -> MicroConfig {
        assert!(
            (1..=micro::MAX_MICRO).contains(&mr) && (1..=micro::MAX_MICRO).contains(&nr),
            "micro-tile sizes must be in 1..={}",
            micro::MAX_MICRO
        );
        MicroConfig { mr, nr }
    }
}

impl Default for MicroConfig {
    fn default() -> Self {
        MicroConfig::DEFAULT
    }
}

/// How the row-parallel split assigns output rows to worker threads.
///
/// Both policies are schedule-neutral by construction: they only decide
/// *which worker* owns a row, never the order of any element's
/// K-reduction, so results are bitwise-identical under either (pinned by
/// `tests/shard_equivalence.rs` and the unit tests below). The choice is
/// purely a locality/load-balance trade — see
/// [`crate::coordinator::partition`] for the NUMA rationale.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum RowSplit {
    /// One contiguous row panel per worker (the classic split): each
    /// worker streams a dense panel of C, best when C's pages are local
    /// to the worker's memory node (first-touch / contiguous NUMA
    /// placement).
    #[default]
    Contiguous,
    /// Row blocks of at most [`TileConfig::mc`] rows (shrunk for small
    /// M so every worker gets work) dealt round-robin across workers:
    /// block `i` goes to worker `i mod threads`. Matches interleaved
    /// NUMA page placement and evens out row-cost skew at the cost of
    /// panel locality.
    Interleaved,
}

impl RowSplit {
    /// Short lowercase name used in CLIs and reports.
    pub fn name(self) -> &'static str {
        match self {
            RowSplit::Contiguous => "contiguous",
            RowSplit::Interleaved => "interleaved",
        }
    }

    /// Parse a [`RowSplit::name`] string (`contiguous|interleaved`).
    pub fn parse(s: &str) -> Option<RowSplit> {
        match s {
            "contiguous" => Some(RowSplit::Contiguous),
            "interleaved" => Some(RowSplit::Interleaved),
            _ => None,
        }
    }
}

/// Execution configuration of the tiled engine: worker count + tiles +
/// microkernel shape + row-split policy.
///
/// Results are **bitwise identical for every value of this struct** (the
/// schedule-preservation invariant); it only trades wall-clock time.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ParallelismConfig {
    /// Worker threads. 1 = run on the caller's thread (no spawns).
    pub threads: usize,
    /// Cache-blocking tile sizes.
    pub tiles: TileConfig,
    /// Register-blocking (microkernel) shape for the packed engine.
    pub micro: MicroConfig,
    /// How output rows are dealt to the worker threads.
    pub split: RowSplit,
    /// SIMD dispatch level for the f32/f64 microkernels
    /// ([`crate::gemm::simd`]); resolved once per GEMM call. Like every
    /// other field, pure scheduling — outputs are bitwise-identical at
    /// any level.
    pub simd: SimdLevel,
}

impl ParallelismConfig {
    /// Single-threaded, default tiles — the library default, so plain
    /// `GemmEngine::new` behaves like a deterministic serial engine.
    pub fn serial() -> ParallelismConfig {
        ParallelismConfig {
            threads: 1,
            tiles: TileConfig::DEFAULT,
            micro: MicroConfig::DEFAULT,
            split: RowSplit::Contiguous,
            simd: SimdLevel::Auto,
        }
    }

    /// `threads` workers, default tiles.
    pub fn with_threads(threads: usize) -> ParallelismConfig {
        ParallelismConfig { threads: threads.max(1), ..Self::serial() }
    }

    /// One worker per available hardware thread.
    pub fn auto() -> ParallelismConfig {
        let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        Self::with_threads(threads)
    }

    /// Replace the tile configuration.
    pub fn tiles(mut self, tiles: TileConfig) -> ParallelismConfig {
        self.tiles = tiles;
        self
    }

    /// Replace the microkernel shape.
    pub fn micro(mut self, micro: MicroConfig) -> ParallelismConfig {
        self.micro = micro;
        self
    }

    /// Replace the row-split policy.
    pub fn split(mut self, split: RowSplit) -> ParallelismConfig {
        self.split = split;
        self
    }

    /// Replace the SIMD dispatch level.
    pub fn simd(mut self, simd: SimdLevel) -> ParallelismConfig {
        self.simd = simd;
        self
    }
}

impl Default for ParallelismConfig {
    fn default() -> Self {
        ParallelismConfig::serial()
    }
}

/// Split C into disjoint per-worker row sets per [`RowSplit`] and run
/// `panel_fn` on each panel (on the caller's thread when `threads == 1`).
/// The only form of parallelism in this module: workers never share an
/// accumulator, so the assignment policy cannot change any element's
/// K-reduction — both splits are bitwise-identical to serial execution.
fn parallel_over_rows<T, F>(c: &mut [T], m: usize, n: usize, par: &ParallelismConfig, panel_fn: F)
where
    T: Send,
    F: Fn(&mut [T], usize, usize) + Sync,
{
    let threads = par.threads.max(1).min(m);
    if threads == 1 {
        panel_fn(c, 0, m);
        return;
    }
    match par.split {
        RowSplit::Contiguous => {
            let rows_per = (m + threads - 1) / threads;
            std::thread::scope(|s| {
                for (ci, chunk) in c.chunks_mut(rows_per * n).enumerate() {
                    let i0 = ci * rows_per;
                    let f = &panel_fn;
                    s.spawn(move || {
                        let rows = chunk.len() / n;
                        f(chunk, i0, rows);
                    });
                }
            });
        }
        RowSplit::Interleaved => {
            // Deal row blocks round-robin: block i → worker i % threads.
            // Each block is still a contiguous panel (packing efficiency
            // is per-block), only ownership is strided. Block height is
            // mc, shrunk when m is small so every worker still gets work
            // (mc-sized blocks alone would serialize any m ≤ mc GEMM).
            let block = par.tiles.mc.min((m + threads - 1) / threads).max(1);
            let nblocks = (m + block - 1) / block;
            let threads = threads.min(nblocks);
            let mut per_worker: Vec<Vec<(usize, &mut [T])>> =
                (0..threads).map(|_| Vec::new()).collect();
            for (bi, chunk) in c.chunks_mut(block * n).enumerate() {
                per_worker[bi % threads].push((bi * block, chunk));
            }
            std::thread::scope(|s| {
                for blocks in per_worker {
                    let f = &panel_fn;
                    s.spawn(move || {
                        for (i0, chunk) in blocks {
                            let rows = chunk.len() / n;
                            f(chunk, i0, rows);
                        }
                    });
                }
            });
        }
    }
}

/// Packed, register-blocked, multi-threaded f32 GEMM — bitwise-equal to
/// the naive kernel of the same strategy in [`crate::gemm::kernels`].
pub fn gemm_f32(
    a: &[f32],
    b: &[f32],
    m: usize,
    k: usize,
    n: usize,
    strategy: ReduceStrategy,
    par: &ParallelismConfig,
) -> Vec<f32> {
    gemm_packed(a, b, m, k, n, strategy, par)
}

/// Packed, register-blocked, multi-threaded f64 GEMM — bitwise-equal to
/// the naive kernel of the same strategy in [`crate::gemm::kernels`].
pub fn gemm_f64(
    a: &[f64],
    b: &[f64],
    m: usize,
    k: usize,
    n: usize,
    strategy: ReduceStrategy,
    par: &ParallelismConfig,
) -> Vec<f64> {
    gemm_packed(a, b, m, k, n, strategy, par)
}

/// The shared packed implementation behind [`gemm_f32`] / [`gemm_f64`].
fn gemm_packed<T: Element>(
    a: &[T],
    b: &[T],
    m: usize,
    k: usize,
    n: usize,
    strategy: ReduceStrategy,
    par: &ParallelismConfig,
) -> Vec<T> {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    let mut c = vec![T::default(); m * n];
    if m == 0 || n == 0 {
        return c;
    }
    let (tiles, u) = (par.tiles, par.micro);
    // Resolve SIMD dispatch once per GEMM call (pure scheduling — every
    // level is bitwise-identical), not per micro-tile.
    let s = par.simd.resolve();
    parallel_over_rows(&mut c, m, n, par, |chunk, i0, rows| match strategy {
        ReduceStrategy::Sequential => {
            packed_seq_fma(a, b, chunk, i0, rows, k, n, false, tiles, u, s)
        }
        ReduceStrategy::Fma => packed_seq_fma(a, b, chunk, i0, rows, k, n, true, tiles, u, s),
        ReduceStrategy::Pairwise => packed_pairwise(a, b, chunk, i0, rows, k, n, tiles),
    });
    c
}

/// [`gemm_f32`] with a fused per-row epilogue (see [`gemm_f64_fused`]).
#[allow(clippy::too_many_arguments)]
pub fn gemm_f32_fused(
    a: &[f32],
    b: &[f32],
    m: usize,
    k: usize,
    n: usize,
    strategy: ReduceStrategy,
    par: &ParallelismConfig,
    epilogue: &(dyn Fn(usize, &[f32]) + Sync),
) -> Vec<f32> {
    gemm_packed_fused(a, b, m, k, n, strategy, par, epilogue)
}

/// [`gemm_f64`] with a fused per-row epilogue: `epilogue(i, row)` is
/// invoked exactly once per output row, from the worker thread that owns
/// the row, at the moment the row's final values leave the microkernel
/// registers (final K-block, final column tile) — i.e. on the
/// pre-quantization accumulator, before the caller ever stores or rounds
/// it. The GEMM arithmetic is byte-for-byte the non-fused engine's
/// (the epilogue only *reads* completed rows), so schedule preservation
/// holds by construction; the fused ABFT verify point rides here.
///
/// Rows arrive in worker-dependent order; callers needing a
/// deterministic order must sort by row index.
#[allow(clippy::too_many_arguments)]
pub fn gemm_f64_fused(
    a: &[f64],
    b: &[f64],
    m: usize,
    k: usize,
    n: usize,
    strategy: ReduceStrategy,
    par: &ParallelismConfig,
    epilogue: &(dyn Fn(usize, &[f64]) + Sync),
) -> Vec<f64> {
    gemm_packed_fused(a, b, m, k, n, strategy, par, epilogue)
}

/// The shared packed implementation behind [`gemm_f32_fused`] /
/// [`gemm_f64_fused`]: identical loop structure (and therefore identical
/// bits) to [`gemm_packed`], plus the row-completion epilogue.
#[allow(clippy::too_many_arguments)]
fn gemm_packed_fused<T: Element>(
    a: &[T],
    b: &[T],
    m: usize,
    k: usize,
    n: usize,
    strategy: ReduceStrategy,
    par: &ParallelismConfig,
    epilogue: &(dyn Fn(usize, &[T]) + Sync),
) -> Vec<T> {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    let mut c = vec![T::default(); m * n];
    if m == 0 {
        return c;
    }
    if n == 0 || k == 0 {
        // Degenerate shapes never reach the microkernel: every row is
        // already final (all zeros), so honour the exactly-once epilogue
        // contract serially.
        for i in 0..m {
            epilogue(i, &c[i * n..(i + 1) * n]);
        }
        return c;
    }
    let (tiles, u) = (par.tiles, par.micro);
    let s = par.simd.resolve();
    parallel_over_rows(&mut c, m, n, par, |chunk, i0, rows| match strategy {
        ReduceStrategy::Sequential => {
            packed_seq_fma_fused(a, b, chunk, i0, rows, k, n, false, tiles, u, s, epilogue)
        }
        ReduceStrategy::Fma => {
            packed_seq_fma_fused(a, b, chunk, i0, rows, k, n, true, tiles, u, s, epilogue)
        }
        ReduceStrategy::Pairwise => {
            // The pairwise tree finishes a row only after its last column
            // strip (the tree is per column block), so the epilogue fires
            // per panel row once the worker's whole panel is done.
            packed_pairwise(a, b, chunk, i0, rows, k, n, tiles);
            for r in 0..rows {
                epilogue(i0 + r, &chunk[r * n..(r + 1) * n]);
            }
        }
    });
    c
}

/// One worker's packed sequential/FMA row panel.
///
/// Loop nest (outer → inner): K-blocks ascending (accumulator carried in
/// C, so each element's K-chain stays in reference order) → pack A
/// micro-panels once per K-block, amortized over the N loop → N-blocks,
/// packing B once per (K, N)-block, amortized over the M loop → MC row
/// groups → MR×NR microkernel tiles.
#[allow(clippy::too_many_arguments)]
fn packed_seq_fma<T: Element>(
    a: &[T],
    b: &[T],
    c: &mut [T],
    i0: usize,
    rows: usize,
    k: usize,
    n: usize,
    fma: bool,
    t: TileConfig,
    u: MicroConfig,
    s: SimdLevel,
) {
    debug_assert_eq!(c.len(), rows * n);
    let (mr, nr) = (u.mr, u.nr);
    // Round the row step up to whole micro-panels so a panel never spans
    // an mc boundary (each element is visited exactly once per K-block).
    let mc = ((t.mc + mr - 1) / mr) * mr;
    let mut apack: Vec<T> = Vec::new();
    let mut bpack: Vec<T> = Vec::new();
    let mut k0 = 0;
    while k0 < k {
        let k1 = (k0 + t.kc).min(k);
        let kb = k1 - k0;
        pack::pack_a(a, k, i0, rows, k0, kb, mr, &mut apack);
        let mut j0 = 0;
        while j0 < n {
            let j1 = (j0 + t.nc).min(n);
            let jw = j1 - j0;
            pack::pack_b(b, n, k0, kb, j0, jw, nr, &mut bpack);
            let mut r0 = 0;
            while r0 < rows {
                let r1 = (r0 + mc).min(rows);
                let mut ip = r0;
                while ip < r1 {
                    let h = mr.min(rows - ip);
                    let apanel = &apack[(ip / mr) * kb * mr..][..kb * mr];
                    let mut jp = 0;
                    while jp < jw {
                        let w = nr.min(jw - jp);
                        let bpanel = &bpack[(jp / nr) * kb * nr..][..kb * nr];
                        micro::run_micro(
                            s,
                            fma,
                            apanel,
                            bpanel,
                            kb,
                            &mut c[ip * n + j0 + jp..],
                            n,
                            h,
                            w,
                            mr,
                            nr,
                        );
                        jp += nr;
                    }
                    ip += mr;
                }
                r0 = r1;
            }
            j0 = j1;
        }
        k0 = k1;
    }
}

/// [`packed_seq_fma`] with the fused row-completion epilogue. The loop
/// nest, packing and microkernel calls are identical (same bits); the
/// only addition is that the micro-tile which completes a row — final
/// K-block, final column block, last NR tile of the row group — runs
/// through [`micro::run_micro_fused`], whose hook records the finished
/// rows, and the epilogue then reads each completed row directly from C
/// while it is still the raw work-precision accumulator.
#[allow(clippy::too_many_arguments)]
fn packed_seq_fma_fused<T: Element>(
    a: &[T],
    b: &[T],
    c: &mut [T],
    i0: usize,
    rows: usize,
    k: usize,
    n: usize,
    fma: bool,
    t: TileConfig,
    u: MicroConfig,
    s: SimdLevel,
    epilogue: &(dyn Fn(usize, &[T]) + Sync),
) {
    debug_assert_eq!(c.len(), rows * n);
    let (mr, nr) = (u.mr, u.nr);
    let mc = ((t.mc + mr - 1) / mr) * mr;
    let mut apack: Vec<T> = Vec::new();
    let mut bpack: Vec<T> = Vec::new();
    let mut completed: Vec<usize> = Vec::new();
    let mut k0 = 0;
    while k0 < k {
        let k1 = (k0 + t.kc).min(k);
        let kb = k1 - k0;
        pack::pack_a(a, k, i0, rows, k0, kb, mr, &mut apack);
        let mut j0 = 0;
        while j0 < n {
            let j1 = (j0 + t.nc).min(n);
            let jw = j1 - j0;
            // Rows become final only in the last K-block's last N-block.
            let final_pass = k1 == k && j1 == n;
            pack::pack_b(b, n, k0, kb, j0, jw, nr, &mut bpack);
            let mut r0 = 0;
            while r0 < rows {
                let r1 = (r0 + mc).min(rows);
                let mut ip = r0;
                while ip < r1 {
                    let h = mr.min(rows - ip);
                    let apanel = &apack[(ip / mr) * kb * mr..][..kb * mr];
                    let mut jp = 0;
                    while jp < jw {
                        let w = nr.min(jw - jp);
                        let bpanel = &bpack[(jp / nr) * kb * nr..][..kb * nr];
                        if final_pass && jp + nr >= jw {
                            micro::run_micro_fused(
                                s,
                                fma,
                                apanel,
                                bpanel,
                                kb,
                                &mut c[ip * n + j0 + jp..],
                                n,
                                h,
                                w,
                                mr,
                                nr,
                                ip,
                                &mut |r| completed.push(r),
                            );
                        } else {
                            micro::run_micro(
                                s,
                                fma,
                                apanel,
                                bpanel,
                                kb,
                                &mut c[ip * n + j0 + jp..],
                                n,
                                h,
                                w,
                                mr,
                                nr,
                            );
                        }
                        jp += nr;
                    }
                    // Fire while the rows are hot: their final values were
                    // just stored from the microkernel registers.
                    for r in completed.drain(..) {
                        epilogue(i0 + r, &c[r * n..(r + 1) * n]);
                    }
                    ip += mr;
                }
                r0 = r1;
            }
            j0 = j1;
        }
        k0 = k1;
    }
}

/// One worker's packed pairwise row panel: the tree shape depends on the
/// full K, so products of one (row, column-block) are staged for the
/// whole K extent — from a B strip packed contiguously once per
/// (column-block, worker) — and reduced by the exact adjacent-pair /
/// odd-carry tree of the reference kernel.
fn packed_pairwise<T: Element>(
    a: &[T],
    b: &[T],
    c: &mut [T],
    i0: usize,
    rows: usize,
    k: usize,
    n: usize,
    t: TileConfig,
) {
    debug_assert_eq!(c.len(), rows * n);
    let bw = t.nc.min(n).max(1);
    let mut bpack: Vec<T> = Vec::new();
    let mut buf = vec![T::default(); k.max(1) * bw];
    let mut j0 = 0;
    while j0 < n {
        let jw = bw.min(n - j0);
        pack::pack_b_cols(b, n, k, j0, jw, &mut bpack);
        for r in 0..rows {
            let arow = &a[(i0 + r) * k..(i0 + r + 1) * k];
            // products (one rounding each), from contiguous packed B
            for kk in 0..k {
                let av = arow[kk];
                let src = &bpack[kk * jw..kk * jw + jw];
                let dst = &mut buf[kk * jw..kk * jw + jw];
                for (d, &bv) in dst.iter_mut().zip(src) {
                    *d = av.mul(bv);
                }
            }
            // adjacent-pair tree along k, odd element carried
            let mut len = k;
            while len > 1 {
                let half = len / 2;
                for p in 0..half {
                    let (lo, hi) = buf.split_at_mut((2 * p + 1) * jw);
                    let dst = &mut lo[2 * p * jw..2 * p * jw + jw];
                    let src = &hi[..jw];
                    for (d, &s) in dst.iter_mut().zip(src) {
                        *d = d.add(s);
                    }
                }
                for p in 1..half {
                    buf.copy_within(2 * p * jw..2 * p * jw + jw, p * jw);
                }
                if len % 2 == 1 {
                    buf.copy_within((len - 1) * jw..(len - 1) * jw + jw, half * jw);
                    len = half + 1;
                } else {
                    len = half;
                }
            }
            c[r * n + j0..r * n + j0 + jw].copy_from_slice(&buf[..jw]);
        }
        j0 += jw;
    }
}

macro_rules! unpacked_kernels {
    ($gemm:ident, $panel:ident, $ty:ty) => {
        /// The PR-1 tiled engine: cache-blocked and multi-threaded but
        /// streaming *unpacked* row-major operands with the accumulator
        /// in C memory. Bitwise-equal to the naive kernel of the same
        /// strategy (and to the packed engine) — kept as the middle rung
        /// of the bench trajectory and as an independent cross-check of
        /// the packed path.
        pub fn $gemm(
            a: &[$ty],
            b: &[$ty],
            m: usize,
            k: usize,
            n: usize,
            strategy: ReduceStrategy,
            par: &ParallelismConfig,
        ) -> Vec<$ty> {
            debug_assert_eq!(a.len(), m * k);
            debug_assert_eq!(b.len(), k * n);
            let mut c = vec![0 as $ty; m * n];
            if m == 0 || n == 0 {
                return c;
            }
            let tiles = par.tiles;
            parallel_over_rows(&mut c, m, n, par, |chunk, i0, rows| {
                $panel(a, b, chunk, i0, rows, k, n, strategy, tiles);
            });
            c
        }

        /// One worker's row panel: rows `i0 .. i0 + rows` of C, written to
        /// `c` (a `rows × n` slice).
        #[allow(clippy::too_many_arguments)]
        fn $panel(
            a: &[$ty],
            b: &[$ty],
            c: &mut [$ty],
            i0: usize,
            rows: usize,
            k: usize,
            n: usize,
            strategy: ReduceStrategy,
            t: TileConfig,
        ) {
            debug_assert_eq!(c.len(), rows * n);
            match strategy {
                // Sequential / FMA: K-blocks ascending with the accumulator
                // carried in C — element (i, j) sees k = 0..K in reference
                // order; (kc, nc, mc) blocking only improves locality.
                ReduceStrategy::Sequential => {
                    let mut k0 = 0;
                    while k0 < k {
                        let k1 = (k0 + t.kc).min(k);
                        let mut j0 = 0;
                        while j0 < n {
                            let j1 = (j0 + t.nc).min(n);
                            let mut r0 = 0;
                            while r0 < rows {
                                let r1 = (r0 + t.mc).min(rows);
                                for r in r0..r1 {
                                    let arow = &a[(i0 + r) * k..(i0 + r + 1) * k];
                                    let (cs, ce) = (r * n + j0, r * n + j1);
                                    for kk in k0..k1 {
                                        let av = arow[kk];
                                        let brow = &b[kk * n + j0..kk * n + j1];
                                        let crow = &mut c[cs..ce];
                                        for (cv, &bv) in crow.iter_mut().zip(brow) {
                                            *cv += av * bv; // round(mul), round(add)
                                        }
                                    }
                                }
                                r0 = r1;
                            }
                            j0 = j1;
                        }
                        k0 = k1;
                    }
                }
                ReduceStrategy::Fma => {
                    let mut k0 = 0;
                    while k0 < k {
                        let k1 = (k0 + t.kc).min(k);
                        let mut j0 = 0;
                        while j0 < n {
                            let j1 = (j0 + t.nc).min(n);
                            let mut r0 = 0;
                            while r0 < rows {
                                let r1 = (r0 + t.mc).min(rows);
                                for r in r0..r1 {
                                    let arow = &a[(i0 + r) * k..(i0 + r + 1) * k];
                                    let (cs, ce) = (r * n + j0, r * n + j1);
                                    for kk in k0..k1 {
                                        let av = arow[kk];
                                        let brow = &b[kk * n + j0..kk * n + j1];
                                        let crow = &mut c[cs..ce];
                                        for (cv, &bv) in crow.iter_mut().zip(brow) {
                                            *cv = av.mul_add(bv, *cv); // one rounding
                                        }
                                    }
                                }
                                r0 = r1;
                            }
                            j0 = j1;
                        }
                        k0 = k1;
                    }
                }
                // Pairwise: staged for the whole K extent per column
                // block, identical tree to kernels.rs.
                ReduceStrategy::Pairwise => {
                    let bw = t.nc.min(n).max(1);
                    let mut buf = vec![0 as $ty; k.max(1) * bw];
                    for r in 0..rows {
                        let arow = &a[(i0 + r) * k..(i0 + r + 1) * k];
                        let mut j0 = 0;
                        while j0 < n {
                            let jw = bw.min(n - j0);
                            // products (one rounding each)
                            for kk in 0..k {
                                let av = arow[kk];
                                let brow = &b[kk * n + j0..kk * n + j0 + jw];
                                let dst = &mut buf[kk * jw..kk * jw + jw];
                                for (d, &bv) in dst.iter_mut().zip(brow) {
                                    *d = av * bv;
                                }
                            }
                            // adjacent-pair tree along k, odd element carried
                            let mut len = k;
                            while len > 1 {
                                let half = len / 2;
                                for p in 0..half {
                                    let (lo, hi) = buf.split_at_mut((2 * p + 1) * jw);
                                    let dst = &mut lo[2 * p * jw..2 * p * jw + jw];
                                    let src = &hi[..jw];
                                    for (d, &s) in dst.iter_mut().zip(src) {
                                        *d += s;
                                    }
                                }
                                for p in 1..half {
                                    buf.copy_within(2 * p * jw..2 * p * jw + jw, p * jw);
                                }
                                if len % 2 == 1 {
                                    buf.copy_within((len - 1) * jw..(len - 1) * jw + jw, half * jw);
                                    len = half + 1;
                                } else {
                                    len = half;
                                }
                            }
                            c[r * n + j0..r * n + j0 + jw].copy_from_slice(&buf[..jw]);
                            j0 += jw;
                        }
                    }
                }
            }
        }
    };
}

unpacked_kernels!(gemm_unpacked_f32, unpacked_panel_f32, f32);
unpacked_kernels!(gemm_unpacked_f64, unpacked_panel_f64, f64);

/// Tiled multi-threaded GEMM in an arbitrary (software-rounded) work
/// precision — the generic ablation path, parallelized over rows and
/// blocked by [`TileConfig`] (K-blocks for the carried sequential/FMA
/// accumulator, column blocks with packed B strips for both schedules).
/// Every element is computed exactly as in [`crate::gemm::generic_gemm`]:
/// one work-precision rounding per product and one per reduction step,
/// in the reference order.
pub fn gemm_generic(
    a: &[f64],
    b: &[f64],
    m: usize,
    k: usize,
    n: usize,
    p: Precision,
    strategy: ReduceStrategy,
    par: &ParallelismConfig,
) -> Vec<f64> {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    let mut c = vec![0.0f64; m * n];
    if m == 0 || n == 0 {
        return c;
    }
    let tiles = par.tiles;
    parallel_over_rows(&mut c, m, n, par, |chunk, i0, rows| {
        generic_panel(a, b, chunk, i0, rows, k, n, p, strategy, tiles);
    });
    c
}

/// One worker's generic-precision row panel (see [`gemm_generic`]).
#[allow(clippy::too_many_arguments)]
fn generic_panel(
    a: &[f64],
    b: &[f64],
    c: &mut [f64],
    i0: usize,
    rows: usize,
    k: usize,
    n: usize,
    p: Precision,
    strategy: ReduceStrategy,
    t: TileConfig,
) {
    debug_assert_eq!(c.len(), rows * n);
    let bw = t.nc.min(n).max(1);
    match strategy {
        // generic_reduce treats Sequential and Fma identically (products
        // quantized separately, one quantized add per step), so one
        // blocked path serves both — mirroring crate::gemm::generic_gemm.
        // K-blocks ascend with the accumulator carried in C; each step is
        // c = q(c + q(a·b)), batched across the column block with
        // quantize_slice (bitwise-equal to per-element quantize).
        ReduceStrategy::Sequential | ReduceStrategy::Fma => {
            let mut prods = vec![0.0f64; bw];
            let mut k0 = 0;
            while k0 < k {
                let k1 = (k0 + t.kc).min(k);
                let mut j0 = 0;
                while j0 < n {
                    let j1 = (j0 + t.nc).min(n);
                    let jw = j1 - j0;
                    let mut r0 = 0;
                    while r0 < rows {
                        let r1 = (r0 + t.mc).min(rows);
                        for r in r0..r1 {
                            let arow = &a[(i0 + r) * k..(i0 + r + 1) * k];
                            for kk in k0..k1 {
                                let av = arow[kk];
                                let brow = &b[kk * n + j0..kk * n + j1];
                                let pr = &mut prods[..jw];
                                for (d, &bv) in pr.iter_mut().zip(brow) {
                                    *d = av * bv;
                                }
                                p.quantize_slice(pr);
                                let crow = &mut c[r * n + j0..r * n + j1];
                                for (cv, &d) in crow.iter_mut().zip(pr.iter()) {
                                    *cv += d;
                                }
                                p.quantize_slice(crow);
                            }
                        }
                        r0 = r1;
                    }
                    j0 = j1;
                }
                k0 = k1;
            }
        }
        // Pairwise: full-K staging per (row, column-block) on a packed B
        // strip, then the reference adjacent-pair tree with one
        // work-precision rounding per node (batched across the block).
        ReduceStrategy::Pairwise => {
            let mut bpack: Vec<f64> = Vec::new();
            let mut buf = vec![0.0f64; k.max(1) * bw];
            let mut j0 = 0;
            while j0 < n {
                let jw = bw.min(n - j0);
                pack::pack_b_cols(b, n, k, j0, jw, &mut bpack);
                for r in 0..rows {
                    let arow = &a[(i0 + r) * k..(i0 + r + 1) * k];
                    for kk in 0..k {
                        let av = arow[kk];
                        let src = &bpack[kk * jw..kk * jw + jw];
                        let dst = &mut buf[kk * jw..kk * jw + jw];
                        for (d, &bv) in dst.iter_mut().zip(src) {
                            *d = av * bv;
                        }
                        p.quantize_slice(dst);
                    }
                    let mut len = k;
                    while len > 1 {
                        let half = len / 2;
                        for pp in 0..half {
                            let (lo, hi) = buf.split_at_mut((2 * pp + 1) * jw);
                            let dst = &mut lo[2 * pp * jw..2 * pp * jw + jw];
                            let src = &hi[..jw];
                            for (d, &s) in dst.iter_mut().zip(src) {
                                *d += s;
                            }
                            p.quantize_slice(dst);
                        }
                        for pp in 1..half {
                            buf.copy_within(2 * pp * jw..2 * pp * jw + jw, pp * jw);
                        }
                        if len % 2 == 1 {
                            buf.copy_within((len - 1) * jw..(len - 1) * jw + jw, half * jw);
                            len = half + 1;
                        } else {
                            len = half;
                        }
                    }
                    c[r * n + j0..r * n + j0 + jw].copy_from_slice(&buf[..jw]);
                }
                j0 += jw;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gemm::kernels;
    use crate::rng::{Distribution, Xoshiro256pp};

    fn rand_vec(n: usize, seed: u64) -> Vec<f64> {
        let mut rng = Xoshiro256pp::seed_from_u64(seed);
        let d = Distribution::uniform_pm1();
        (0..n).map(|_| d.sample(&mut rng)).collect()
    }

    fn configs() -> Vec<ParallelismConfig> {
        let mut out = Vec::new();
        for threads in [1usize, 2, 4] {
            for tiles in [
                TileConfig::DEFAULT,
                TileConfig::new(1, 3, 5),   // degenerate tiny tiles
                TileConfig::new(2, 7, 64),  // odd K blocks
                TileConfig::new(8, 512, 16),
            ] {
                for micro in [
                    MicroConfig::DEFAULT,
                    MicroConfig::new(4, 8),
                    MicroConfig::new(1, 4),
                    MicroConfig::new(3, 5), // dynamic-fallback kernel
                ] {
                    for split in [RowSplit::Contiguous, RowSplit::Interleaved] {
                        // Auto exercises the host's widest explicit
                        // kernels, Scalar pins the reference path — both
                        // must be bitwise-identical.
                        for simd in [SimdLevel::Scalar, SimdLevel::Auto] {
                            out.push(ParallelismConfig { threads, tiles, micro, split, simd });
                        }
                    }
                }
            }
        }
        out
    }

    #[test]
    fn tiled_f64_bitwise_equals_reference_all_strategies() {
        // Ragged sizes on purpose: odd K (pairwise carry), n > nc, m not a
        // multiple of the thread count.
        let (m, k, n) = (7, 29, 83);
        let a = rand_vec(m * k, 1);
        let b = rand_vec(k * n, 2);
        let refs = [
            (ReduceStrategy::Sequential, kernels::seq_gemm_f64(&a, &b, m, k, n)),
            (ReduceStrategy::Fma, kernels::fma_gemm_f64(&a, &b, m, k, n)),
            (ReduceStrategy::Pairwise, kernels::pairwise_gemm_f64(&a, &b, m, k, n)),
        ];
        for par in configs() {
            for (strategy, want) in &refs {
                let got = gemm_f64(&a, &b, m, k, n, *strategy, &par);
                assert_eq!(&got, want, "packed {strategy:?} diverged under {par:?}");
                let got_unpacked = gemm_unpacked_f64(&a, &b, m, k, n, *strategy, &par);
                assert_eq!(&got_unpacked, want, "unpacked {strategy:?} diverged under {par:?}");
            }
        }
    }

    #[test]
    fn tiled_f32_bitwise_equals_reference_all_strategies() {
        let (m, k, n) = (9, 64, 33);
        let a: Vec<f32> = rand_vec(m * k, 3).iter().map(|&x| x as f32).collect();
        let b: Vec<f32> = rand_vec(k * n, 4).iter().map(|&x| x as f32).collect();
        let refs = [
            (ReduceStrategy::Sequential, kernels::seq_gemm_f32(&a, &b, m, k, n)),
            (ReduceStrategy::Fma, kernels::fma_gemm_f32(&a, &b, m, k, n)),
            (ReduceStrategy::Pairwise, kernels::pairwise_gemm_f32(&a, &b, m, k, n)),
        ];
        for par in configs() {
            for (strategy, want) in &refs {
                let got = gemm_f32(&a, &b, m, k, n, *strategy, &par);
                assert_eq!(&got, want, "packed {strategy:?} diverged under {par:?}");
                let got_unpacked = gemm_unpacked_f32(&a, &b, m, k, n, *strategy, &par);
                assert_eq!(&got_unpacked, want, "unpacked {strategy:?} diverged under {par:?}");
            }
        }
    }

    #[test]
    fn generic_tiled_matches_generic_reference() {
        let (m, k, n) = (5, 21, 18);
        let p = Precision::Bf16;
        let a: Vec<f64> = rand_vec(m * k, 5).iter().map(|&x| p.quantize(x)).collect();
        let b: Vec<f64> = rand_vec(k * n, 6).iter().map(|&x| p.quantize(x)).collect();
        for strategy in
            [ReduceStrategy::Sequential, ReduceStrategy::Fma, ReduceStrategy::Pairwise]
        {
            let want = crate::gemm::generic_gemm(&a, &b, m, k, n, p, strategy);
            for par in configs() {
                let got = gemm_generic(&a, &b, m, k, n, p, strategy, &par);
                assert_eq!(got, want, "{strategy:?} diverged under {par:?}");
            }
        }
    }

    #[test]
    fn generic_blocked_is_bitwise_stable_across_tile_configs() {
        // The satellite contract for the once tile-blind generic path:
        // every TileConfig must produce output bitwise-equal to
        // crate::gemm::generic_gemm.
        let (m, k, n) = (6, 40, 23);
        let p = Precision::F16;
        let a: Vec<f64> = rand_vec(m * k, 7).iter().map(|&x| p.quantize(x)).collect();
        let b: Vec<f64> = rand_vec(k * n, 8).iter().map(|&x| p.quantize(x)).collect();
        for strategy in
            [ReduceStrategy::Sequential, ReduceStrategy::Fma, ReduceStrategy::Pairwise]
        {
            let want = crate::gemm::generic_gemm(&a, &b, m, k, n, p, strategy);
            for kc in [1usize, 3, 7, 40, 256] {
                for nc in [1usize, 5, 23, 128] {
                    let par = ParallelismConfig::serial().tiles(TileConfig::new(4, kc, nc));
                    let got = gemm_generic(&a, &b, m, k, n, p, strategy, &par);
                    assert_eq!(got, want, "{strategy:?} kc={kc} nc={nc}");
                }
            }
        }
    }

    #[test]
    fn generic_wall_time_responds_to_kc() {
        // gemm_generic used to ignore TileConfig entirely; now kc decides
        // the K-block structure. With nc = 1 the per-(block, row) sweep
        // setup costs about as much as a single K step, so kc = 1 (one K
        // step per sweep — 8·1024·32 sweeps) must be measurably slower
        // than kc = K (256 sweeps): the contrast is structural, not a few
        // percent. Timing is min-of-5 to de-noise.
        let (m, k, n) = (8, 1024, 32);
        let p = Precision::Bf16;
        let a: Vec<f64> = rand_vec(m * k, 9).iter().map(|&x| p.quantize(x)).collect();
        let b: Vec<f64> = rand_vec(k * n, 10).iter().map(|&x| p.quantize(x)).collect();
        let time = |kc: usize| {
            let par = ParallelismConfig::serial().tiles(TileConfig::new(64, kc, 1));
            (0..5)
                .map(|_| {
                    let t0 = std::time::Instant::now();
                    std::hint::black_box(gemm_generic(
                        &a,
                        &b,
                        m,
                        k,
                        n,
                        p,
                        ReduceStrategy::Sequential,
                        &par,
                    ));
                    t0.elapsed()
                })
                .min()
                .unwrap()
        };
        let coarse = time(k);
        let fine = time(1);
        assert!(
            fine > coarse,
            "generic path ignores kc: kc=1 took {fine:?}, kc={k} took {coarse:?}"
        );
    }

    #[test]
    fn degenerate_shapes() {
        let par = ParallelismConfig::with_threads(4);
        assert!(gemm_f64(&[], &[], 0, 0, 0, ReduceStrategy::Sequential, &par).is_empty());
        // k = 0: all zeros, any shape
        let c = gemm_f64(&[], &[], 3, 0, 2, ReduceStrategy::Pairwise, &par);
        assert_eq!(c, vec![0.0; 6]);
        let c = gemm_f64(&[], &[], 3, 0, 2, ReduceStrategy::Sequential, &par);
        assert_eq!(c, vec![0.0; 6]);
        // single row, more threads than rows
        let c1 = gemm_f64(&[2.0, 3.0], &[10.0, 100.0], 1, 2, 1, ReduceStrategy::Sequential, &par);
        assert_eq!(c1, vec![2.0 * 10.0 + 3.0 * 100.0]);
    }

    #[test]
    fn engine_config_flags_resolve_to_parallelism() {
        // The one shared flag helper ([`crate::gemm::EngineConfig`])
        // resolves CLI flags into a ParallelismConfig; pin the mapping.
        let args = crate::cli::Args::parse_from(
            "x --threads 4 --mc 32 --kc 128 --nc 64 --mr 4 --nr 16 --split interleaved \
             --simd scalar"
                .split_whitespace()
                .map(String::from),
        );
        let par = crate::gemm::EngineConfig::from_args(&args).resolve();
        assert_eq!(par.threads, 4);
        assert_eq!(par.tiles, TileConfig::new(32, 128, 64));
        assert_eq!(par.micro, MicroConfig::new(4, 16));
        assert_eq!(par.split, RowSplit::Interleaved);
        assert_eq!(par.simd, SimdLevel::Scalar);
        let auto = crate::cli::Args::parse_from(
            "x --threads 0".split_whitespace().map(String::from),
        );
        let par = crate::gemm::EngineConfig::from_args(&auto).resolve();
        assert!(par.threads >= 1);
        assert_eq!(par.micro, MicroConfig::DEFAULT);
        assert_eq!(par.split, RowSplit::Contiguous);
        assert_eq!(par.simd, SimdLevel::Auto);
    }

    #[test]
    fn fused_epilogue_is_bitwise_neutral_and_fires_once_per_row() {
        use std::sync::Mutex;
        let (m, k, n) = (7, 29, 33);
        let a = rand_vec(m * k, 21);
        let b = rand_vec(k * n, 22);
        for strategy in
            [ReduceStrategy::Sequential, ReduceStrategy::Fma, ReduceStrategy::Pairwise]
        {
            let want = gemm_f64(&a, &b, m, k, n, strategy, &ParallelismConfig::serial());
            for par in configs() {
                let seen: Mutex<Vec<(usize, Vec<f64>)>> = Mutex::new(Vec::new());
                let ep = |i: usize, row: &[f64]| {
                    seen.lock().unwrap().push((i, row.to_vec()));
                };
                let got = gemm_f64_fused(&a, &b, m, k, n, strategy, &par, &ep);
                assert_eq!(got, want, "fused C diverged: {strategy:?} {par:?}");
                let mut rows = seen.into_inner().unwrap();
                rows.sort_unstable_by_key(|(i, _)| *i);
                assert_eq!(rows.len(), m, "epilogue count: {strategy:?} {par:?}");
                for (i, (row, vals)) in rows.iter().enumerate() {
                    assert_eq!(*row, i, "row skipped or fired twice: {strategy:?} {par:?}");
                    assert_eq!(
                        vals.as_slice(),
                        &want[i * n..(i + 1) * n],
                        "epilogue saw a non-final row {i}: {strategy:?} {par:?}"
                    );
                }
            }
        }
    }

    #[test]
    fn fused_epilogue_degenerate_shapes() {
        use std::sync::Mutex;
        let par = ParallelismConfig::with_threads(4);
        // k = 0: all-zero rows, epilogue still fires once per row.
        for strategy in [ReduceStrategy::Sequential, ReduceStrategy::Pairwise] {
            let seen: Mutex<Vec<usize>> = Mutex::new(Vec::new());
            let ep = |i: usize, row: &[f64]| {
                assert!(row.iter().all(|&v| v == 0.0));
                seen.lock().unwrap().push(i);
            };
            let c = gemm_f64_fused(&[], &[], 3, 0, 2, strategy, &par, &ep);
            assert_eq!(c, vec![0.0; 6]);
            let mut rows = seen.into_inner().unwrap();
            rows.sort_unstable();
            assert_eq!(rows, vec![0, 1, 2], "{strategy:?}");
        }
        // Single row, more threads than rows.
        let seen: Mutex<Vec<(usize, Vec<f64>)>> = Mutex::new(Vec::new());
        let ep = |i: usize, row: &[f64]| {
            seen.lock().unwrap().push((i, row.to_vec()));
        };
        let c = gemm_f64_fused(
            &[2.0, 3.0],
            &[10.0, 100.0],
            1,
            2,
            1,
            ReduceStrategy::Sequential,
            &par,
            &ep,
        );
        assert_eq!(c, vec![2.0 * 10.0 + 3.0 * 100.0]);
        assert_eq!(seen.into_inner().unwrap(), vec![(0, c)]);
        // m = 0: nothing to verify, no epilogue calls.
        let ep = |_: usize, _: &[f64]| panic!("epilogue fired for m = 0");
        assert!(gemm_f64_fused(&[], &[], 0, 0, 0, ReduceStrategy::Fma, &par, &ep).is_empty());
    }

    #[test]
    fn fused_f32_matches_non_fused() {
        use std::sync::Mutex;
        let (m, k, n) = (9, 64, 33);
        let a: Vec<f32> = rand_vec(m * k, 3).iter().map(|&x| x as f32).collect();
        let b: Vec<f32> = rand_vec(k * n, 4).iter().map(|&x| x as f32).collect();
        for strategy in
            [ReduceStrategy::Sequential, ReduceStrategy::Fma, ReduceStrategy::Pairwise]
        {
            let want = gemm_f32(&a, &b, m, k, n, strategy, &ParallelismConfig::serial());
            for threads in [1usize, 3] {
                let par = ParallelismConfig::with_threads(threads)
                    .tiles(TileConfig::new(2, 7, 16))
                    .micro(MicroConfig::new(4, 8));
                let count = Mutex::new(0usize);
                let ep = |i: usize, row: &[f32]| {
                    assert_eq!(row, &want[i * n..(i + 1) * n]);
                    *count.lock().unwrap() += 1;
                };
                let got = gemm_f32_fused(&a, &b, m, k, n, strategy, &par, &ep);
                assert_eq!(got, want, "{strategy:?} t={threads}");
                assert_eq!(*count.lock().unwrap(), m);
            }
        }
    }

    #[test]
    fn interleaved_split_is_bitwise_equal_to_contiguous() {
        // Dedicated pin of the RowSplit invariant on ragged shapes where
        // the interleave actually strides blocks (mc smaller than m).
        let (m, k, n) = (23, 31, 17);
        let a = rand_vec(m * k, 11);
        let b = rand_vec(k * n, 12);
        for strategy in
            [ReduceStrategy::Sequential, ReduceStrategy::Fma, ReduceStrategy::Pairwise]
        {
            let base = gemm_f64(&a, &b, m, k, n, strategy, &ParallelismConfig::serial());
            for threads in [2usize, 3, 8] {
                for mc in [1usize, 4, 64] {
                    let par = ParallelismConfig::with_threads(threads)
                        .tiles(TileConfig::new(mc, 7, 5))
                        .split(RowSplit::Interleaved);
                    let got = gemm_f64(&a, &b, m, k, n, strategy, &par);
                    assert_eq!(got, base, "{strategy:?} t={threads} mc={mc}");
                }
            }
        }
    }
}
