//! Tiled, multi-threaded GEMM execution with a **schedule-preservation
//! guarantee**.
//!
//! The naive kernels in [`crate::gemm::kernels`] define, per output
//! element, a *rounding schedule*: the exact order in which the K products
//! are rounded and combined. V-ABFT's whole threshold model (and every
//! calibrated e_max in [`crate::calibrate`]) is a statement about that
//! schedule — so a faster engine is only admissible if it provably does
//! not change it.
//!
//! This engine gets its speed from the two transformations that are
//! schedule-neutral, and only those:
//!
//! * **Parallelism across output rows.** Each worker owns a disjoint
//!   panel of C rows. Different output elements never share an
//!   accumulator, so assigning rows to threads cannot reorder any
//!   element's K-chain. Workers are scoped [`std::thread::scope`] threads
//!   writing through disjoint `chunks_mut` panels — no locks, no atomics,
//!   no cross-worker communication.
//! * **Cache blocking over (K, N) — never *within* one element's
//!   reduction.** For the sequential / FMA schedules, K-blocks are
//!   visited in ascending order with the accumulator carried in place, so
//!   element (i, j) still sees products k = 0, 1, …, K−1 in exactly the
//!   reference order. For the pairwise schedule the reduction tree shape
//!   depends on the *full* K, so products are staged for the whole K
//!   extent (per column block) and the tree is identical to
//!   [`crate::gemm::kernels`]'s — column-block width only changes which
//!   *elements* share a buffer, not any element's tree.
//!
//! The resulting invariant — tiled/parallel output bitwise-equal to the
//! naive reference for every strategy, tile shape and thread count — is
//! enforced by `tests/tiled_equivalence.rs` and by unit tests below.

use super::ReduceStrategy;
use crate::fp::Precision;

/// Cache-blocking tile sizes (elements, not bytes).
///
/// `mc` bounds the row-panel a worker iterates at a time, `kc` the K-block
/// kept hot while streaming B, `nc` the column-block width (also the
/// product-buffer width of the pairwise schedule). Any positive values are
/// valid; the defaults target ~L2-resident B panels for f32.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TileConfig {
    /// Row-panel height a worker iterates at a time.
    pub mc: usize,
    /// K-block depth kept hot while streaming B.
    pub kc: usize,
    /// Column-block width (also the pairwise product-buffer width).
    pub nc: usize,
}

impl TileConfig {
    /// The measured defaults: ~L2-resident B panels for f32 (see
    /// `docs/PERFORMANCE.md` for the tuning rationale).
    pub const DEFAULT: TileConfig = TileConfig { mc: 64, kc: 256, nc: 128 };

    /// Construct from explicit tile sizes (all must be positive).
    pub fn new(mc: usize, kc: usize, nc: usize) -> TileConfig {
        assert!(mc > 0 && kc > 0 && nc > 0, "tile sizes must be positive");
        TileConfig { mc, kc, nc }
    }
}

impl Default for TileConfig {
    fn default() -> Self {
        TileConfig::DEFAULT
    }
}

/// Execution configuration of the tiled engine: worker count + tiles.
///
/// Results are **bitwise identical for every value of this struct** (the
/// schedule-preservation invariant); it only trades wall-clock time.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ParallelismConfig {
    /// Worker threads. 1 = run on the caller's thread (no spawns).
    pub threads: usize,
    /// Cache-blocking tile sizes.
    pub tiles: TileConfig,
}

impl ParallelismConfig {
    /// Single-threaded, default tiles — the library default, so plain
    /// `GemmEngine::new` behaves like a deterministic serial engine.
    pub fn serial() -> ParallelismConfig {
        ParallelismConfig { threads: 1, tiles: TileConfig::DEFAULT }
    }

    /// `threads` workers, default tiles.
    pub fn with_threads(threads: usize) -> ParallelismConfig {
        ParallelismConfig { threads: threads.max(1), tiles: TileConfig::DEFAULT }
    }

    /// One worker per available hardware thread.
    pub fn auto() -> ParallelismConfig {
        let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        ParallelismConfig { threads, tiles: TileConfig::DEFAULT }
    }

    /// Replace the tile configuration.
    pub fn tiles(mut self, tiles: TileConfig) -> ParallelismConfig {
        self.tiles = tiles;
        self
    }

    /// Parse from CLI flags: `--threads N --mc M --kc K --nc N`
    /// (`--threads 0` means auto). Shared by the `vabft` binary and the
    /// bench harness mains.
    pub fn from_args(args: &crate::cli::Args) -> ParallelismConfig {
        let mut par = match args.opt_or("threads", 1usize) {
            0 => ParallelismConfig::auto(),
            t => ParallelismConfig::with_threads(t),
        };
        let d = TileConfig::DEFAULT;
        par.tiles = TileConfig::new(
            args.opt_or("mc", d.mc),
            args.opt_or("kc", d.kc),
            args.opt_or("nc", d.nc),
        );
        par
    }
}

impl Default for ParallelismConfig {
    fn default() -> Self {
        ParallelismConfig::serial()
    }
}

macro_rules! tiled_kernels {
    ($gemm:ident, $panel:ident, $ty:ty) => {
        /// Tiled multi-threaded GEMM, bitwise-equal to the naive kernel of
        /// the same strategy in [`crate::gemm::kernels`].
        pub fn $gemm(
            a: &[$ty],
            b: &[$ty],
            m: usize,
            k: usize,
            n: usize,
            strategy: ReduceStrategy,
            par: &ParallelismConfig,
        ) -> Vec<$ty> {
            debug_assert_eq!(a.len(), m * k);
            debug_assert_eq!(b.len(), k * n);
            let mut c = vec![0 as $ty; m * n];
            if m == 0 || n == 0 {
                return c;
            }
            let threads = par.threads.max(1).min(m);
            if threads == 1 {
                $panel(a, b, &mut c, 0, m, k, n, strategy, par.tiles);
                return c;
            }
            // Disjoint contiguous row panels per worker; no worker ever
            // touches another's accumulators, so the per-element schedule
            // is untouched by construction.
            let rows_per = (m + threads - 1) / threads;
            let tiles = par.tiles;
            std::thread::scope(|s| {
                for (ci, chunk) in c.chunks_mut(rows_per * n).enumerate() {
                    let i0 = ci * rows_per;
                    s.spawn(move || {
                        let rows = chunk.len() / n;
                        $panel(a, b, chunk, i0, rows, k, n, strategy, tiles);
                    });
                }
            });
            c
        }

        /// One worker's row panel: rows `i0 .. i0 + rows` of C, written to
        /// `c` (a `rows × n` slice).
        fn $panel(
            a: &[$ty],
            b: &[$ty],
            c: &mut [$ty],
            i0: usize,
            rows: usize,
            k: usize,
            n: usize,
            strategy: ReduceStrategy,
            t: TileConfig,
        ) {
            debug_assert_eq!(c.len(), rows * n);
            match strategy {
                // Sequential / FMA: K-blocks ascending with the accumulator
                // carried in C — element (i, j) sees k = 0..K in reference
                // order; (kc, nc, mc) blocking only improves locality.
                ReduceStrategy::Sequential => {
                    let mut k0 = 0;
                    while k0 < k {
                        let k1 = (k0 + t.kc).min(k);
                        let mut j0 = 0;
                        while j0 < n {
                            let j1 = (j0 + t.nc).min(n);
                            let mut r0 = 0;
                            while r0 < rows {
                                let r1 = (r0 + t.mc).min(rows);
                                for r in r0..r1 {
                                    let arow = &a[(i0 + r) * k..(i0 + r + 1) * k];
                                    let (cs, ce) = (r * n + j0, r * n + j1);
                                    for kk in k0..k1 {
                                        let av = arow[kk];
                                        let brow = &b[kk * n + j0..kk * n + j1];
                                        let crow = &mut c[cs..ce];
                                        for (cv, &bv) in crow.iter_mut().zip(brow) {
                                            *cv += av * bv; // round(mul), round(add)
                                        }
                                    }
                                }
                                r0 = r1;
                            }
                            j0 = j1;
                        }
                        k0 = k1;
                    }
                }
                ReduceStrategy::Fma => {
                    let mut k0 = 0;
                    while k0 < k {
                        let k1 = (k0 + t.kc).min(k);
                        let mut j0 = 0;
                        while j0 < n {
                            let j1 = (j0 + t.nc).min(n);
                            let mut r0 = 0;
                            while r0 < rows {
                                let r1 = (r0 + t.mc).min(rows);
                                for r in r0..r1 {
                                    let arow = &a[(i0 + r) * k..(i0 + r + 1) * k];
                                    let (cs, ce) = (r * n + j0, r * n + j1);
                                    for kk in k0..k1 {
                                        let av = arow[kk];
                                        let brow = &b[kk * n + j0..kk * n + j1];
                                        let crow = &mut c[cs..ce];
                                        for (cv, &bv) in crow.iter_mut().zip(brow) {
                                            *cv = av.mul_add(bv, *cv); // one rounding
                                        }
                                    }
                                }
                                r0 = r1;
                            }
                            j0 = j1;
                        }
                        k0 = k1;
                    }
                }
                // Pairwise: the tree shape depends on the full K, so the
                // products of one (row, column-block) are staged for the
                // whole K extent and reduced by the exact adjacent-pair /
                // odd-carry tree of the reference kernel. The column-block
                // width (nc) decides buffer residency only.
                ReduceStrategy::Pairwise => {
                    let bw = t.nc.min(n).max(1);
                    let mut buf = vec![0 as $ty; k.max(1) * bw];
                    for r in 0..rows {
                        let arow = &a[(i0 + r) * k..(i0 + r + 1) * k];
                        let mut j0 = 0;
                        while j0 < n {
                            let jw = bw.min(n - j0);
                            // products (one rounding each)
                            for kk in 0..k {
                                let av = arow[kk];
                                let brow = &b[kk * n + j0..kk * n + j0 + jw];
                                let dst = &mut buf[kk * jw..kk * jw + jw];
                                for (d, &bv) in dst.iter_mut().zip(brow) {
                                    *d = av * bv;
                                }
                            }
                            // adjacent-pair tree along k, odd element carried
                            let mut len = k;
                            while len > 1 {
                                let half = len / 2;
                                for p in 0..half {
                                    let (lo, hi) = buf.split_at_mut((2 * p + 1) * jw);
                                    let dst = &mut lo[2 * p * jw..2 * p * jw + jw];
                                    let src = &hi[..jw];
                                    for (d, &s) in dst.iter_mut().zip(src) {
                                        *d += s;
                                    }
                                }
                                for p in 1..half {
                                    buf.copy_within(2 * p * jw..2 * p * jw + jw, p * jw);
                                }
                                if len % 2 == 1 {
                                    buf.copy_within((len - 1) * jw..(len - 1) * jw + jw, half * jw);
                                    len = half + 1;
                                } else {
                                    len = half;
                                }
                            }
                            c[r * n + j0..r * n + j0 + jw].copy_from_slice(&buf[..jw]);
                            j0 += jw;
                        }
                    }
                }
            }
        }
    };
}

tiled_kernels!(gemm_f32, panel_f32, f32);
tiled_kernels!(gemm_f64, panel_f64, f64);

/// Tiled multi-threaded GEMM in an arbitrary (software-rounded) work
/// precision — the generic ablation path, parallelized over rows. Every
/// element is computed exactly as in [`crate::gemm::generic_gemm`].
pub fn gemm_generic(
    a: &[f64],
    b: &[f64],
    m: usize,
    k: usize,
    n: usize,
    p: Precision,
    strategy: ReduceStrategy,
    par: &ParallelismConfig,
) -> Vec<f64> {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    let mut c = vec![0.0f64; m * n];
    if m == 0 || n == 0 {
        return c;
    }
    let threads = par.threads.max(1).min(m);
    let panel = |a: &[f64], b: &[f64], c: &mut [f64], i0: usize, rows: usize| {
        let mut prods = vec![0.0f64; k];
        for r in 0..rows {
            let arow = &a[(i0 + r) * k..(i0 + r + 1) * k];
            for j in 0..n {
                for (kk, pr) in prods.iter_mut().enumerate() {
                    *pr = p.quantize(arow[kk] * b[kk * n + j]);
                }
                c[r * n + j] = super::generic_reduce(&prods, p, strategy);
            }
        }
    };
    if threads == 1 {
        panel(a, b, &mut c, 0, m);
        return c;
    }
    let rows_per = (m + threads - 1) / threads;
    std::thread::scope(|s| {
        for (ci, chunk) in c.chunks_mut(rows_per * n).enumerate() {
            let i0 = ci * rows_per;
            let panel = &panel;
            s.spawn(move || {
                let rows = chunk.len() / n;
                panel(a, b, chunk, i0, rows);
            });
        }
    });
    c
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gemm::kernels;
    use crate::rng::{Distribution, Xoshiro256pp};

    fn rand_vec(n: usize, seed: u64) -> Vec<f64> {
        let mut rng = Xoshiro256pp::seed_from_u64(seed);
        let d = Distribution::uniform_pm1();
        (0..n).map(|_| d.sample(&mut rng)).collect()
    }

    fn configs() -> Vec<ParallelismConfig> {
        let mut out = Vec::new();
        for threads in [1usize, 2, 4] {
            for tiles in [
                TileConfig::DEFAULT,
                TileConfig::new(1, 3, 5),   // degenerate tiny tiles
                TileConfig::new(2, 7, 64),  // odd K blocks
                TileConfig::new(8, 512, 16),
            ] {
                out.push(ParallelismConfig { threads, tiles });
            }
        }
        out
    }

    #[test]
    fn tiled_f64_bitwise_equals_reference_all_strategies() {
        // Ragged sizes on purpose: odd K (pairwise carry), n > nc, m not a
        // multiple of the thread count.
        let (m, k, n) = (7, 29, 83);
        let a = rand_vec(m * k, 1);
        let b = rand_vec(k * n, 2);
        let refs = [
            (ReduceStrategy::Sequential, kernels::seq_gemm_f64(&a, &b, m, k, n)),
            (ReduceStrategy::Fma, kernels::fma_gemm_f64(&a, &b, m, k, n)),
            (ReduceStrategy::Pairwise, kernels::pairwise_gemm_f64(&a, &b, m, k, n)),
        ];
        for par in configs() {
            for (strategy, want) in &refs {
                let got = gemm_f64(&a, &b, m, k, n, *strategy, &par);
                assert_eq!(&got, want, "{strategy:?} diverged under {par:?}");
            }
        }
    }

    #[test]
    fn tiled_f32_bitwise_equals_reference_all_strategies() {
        let (m, k, n) = (9, 64, 33);
        let a: Vec<f32> = rand_vec(m * k, 3).iter().map(|&x| x as f32).collect();
        let b: Vec<f32> = rand_vec(k * n, 4).iter().map(|&x| x as f32).collect();
        let refs = [
            (ReduceStrategy::Sequential, kernels::seq_gemm_f32(&a, &b, m, k, n)),
            (ReduceStrategy::Fma, kernels::fma_gemm_f32(&a, &b, m, k, n)),
            (ReduceStrategy::Pairwise, kernels::pairwise_gemm_f32(&a, &b, m, k, n)),
        ];
        for par in configs() {
            for (strategy, want) in &refs {
                let got = gemm_f32(&a, &b, m, k, n, *strategy, &par);
                assert_eq!(&got, want, "{strategy:?} diverged under {par:?}");
            }
        }
    }

    #[test]
    fn generic_tiled_matches_generic_reference() {
        let (m, k, n) = (5, 21, 18);
        let p = Precision::Bf16;
        let a: Vec<f64> = rand_vec(m * k, 5).iter().map(|&x| p.quantize(x)).collect();
        let b: Vec<f64> = rand_vec(k * n, 6).iter().map(|&x| p.quantize(x)).collect();
        for strategy in
            [ReduceStrategy::Sequential, ReduceStrategy::Fma, ReduceStrategy::Pairwise]
        {
            let want = crate::gemm::generic_gemm(&a, &b, m, k, n, p, strategy);
            for par in configs() {
                let got = gemm_generic(&a, &b, m, k, n, p, strategy, &par);
                assert_eq!(got, want, "{strategy:?} diverged under {par:?}");
            }
        }
    }

    #[test]
    fn degenerate_shapes() {
        let par = ParallelismConfig::with_threads(4);
        assert!(gemm_f64(&[], &[], 0, 0, 0, ReduceStrategy::Sequential, &par).is_empty());
        // k = 0: all zeros, any shape
        let c = gemm_f64(&[], &[], 3, 0, 2, ReduceStrategy::Pairwise, &par);
        assert_eq!(c, vec![0.0; 6]);
        // single row, more threads than rows
        let c1 = gemm_f64(&[2.0, 3.0], &[10.0, 100.0], 1, 2, 1, ReduceStrategy::Sequential, &par);
        assert_eq!(c1, vec![2.0 * 10.0 + 3.0 * 100.0]);
    }

    #[test]
    fn from_args_parses_flags() {
        let args = crate::cli::Args::parse_from(
            "x --threads 4 --mc 32 --kc 128 --nc 64".split_whitespace().map(String::from),
        );
        let par = ParallelismConfig::from_args(&args);
        assert_eq!(par.threads, 4);
        assert_eq!(par.tiles, TileConfig::new(32, 128, 64));
        let auto = crate::cli::Args::parse_from(
            "x --threads 0".split_whitespace().map(String::from),
        );
        assert!(ParallelismConfig::from_args(&auto).threads >= 1);
    }
}
