//! Benchmark harness (criterion substitute — criterion is not in the
//! offline registry).
//!
//! Provides warmup + timed iterations with median/p95 statistics, and the
//! quick/full mode switch the table benches use: `cargo bench` runs quick
//! (reduced sizes/trials, minutes); `cargo bench -- --full` or
//! `VABFT_BENCH_FULL=1` reproduces the paper's exact sizes and trial
//! counts.

pub mod json;
pub use json::{
    validate_schema, BenchRecord, BenchRecords, JsonDoc, JsonValue, BENCH_SCHEMA, CAMPAIGN_SCHEMA,
    SERVING_SCHEMA,
};

use std::time::{Duration, Instant};

/// Timing statistics over repeated runs.
#[derive(Debug, Clone, Copy)]
pub struct Stats {
    /// Timed iterations.
    pub iters: usize,
    /// Mean duration.
    pub mean: Duration,
    /// Median duration.
    pub median: Duration,
    /// Fastest iteration.
    pub min: Duration,
    /// Slowest iteration.
    pub max: Duration,
    /// 95th-percentile duration.
    pub p95: Duration,
}

impl Stats {
    /// One-line human-readable summary.
    pub fn summary(&self) -> String {
        format!(
            "median {:?}  mean {:?}  min {:?}  p95 {:?}  (n={})",
            self.median, self.mean, self.min, self.p95, self.iters
        )
    }

    /// Throughput given an amount of work per iteration.
    pub fn per_second(&self, work_per_iter: f64) -> f64 {
        work_per_iter / self.median.as_secs_f64()
    }
}

/// Time `f` with warmup. Runs at least `min_iters` and until `min_time`
/// elapses (whichever is later).
pub fn bench(mut f: impl FnMut(), min_iters: usize, min_time: Duration) -> Stats {
    // warmup
    f();
    let mut samples: Vec<Duration> = Vec::new();
    let start = Instant::now();
    while samples.len() < min_iters || start.elapsed() < min_time {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed());
        if samples.len() >= 10_000 {
            break;
        }
    }
    stats_of(&mut samples)
}

/// Quick one-shot measurement (no warmup) for expensive workloads.
pub fn time_once(f: impl FnOnce()) -> Duration {
    let t0 = Instant::now();
    f();
    t0.elapsed()
}

fn stats_of(samples: &mut [Duration]) -> Stats {
    samples.sort();
    let n = samples.len();
    let sum: Duration = samples.iter().sum();
    Stats {
        iters: n,
        mean: sum / n as u32,
        median: samples[n / 2],
        min: samples[0],
        max: samples[n - 1],
        p95: samples[((n as f64 * 0.95) as usize).min(n - 1)],
    }
}

/// Bench execution mode: quick (default) or full paper-scale.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BenchMode {
    /// Reduced sizes/trials (default; minutes).
    Quick,
    /// The paper's exact sizes and trial counts.
    Full,
}

impl BenchMode {
    /// Parse from process args (`--full`) or env (`VABFT_BENCH_FULL=1`).
    pub fn from_env() -> BenchMode {
        let args: Vec<String> = std::env::args().collect();
        if args.iter().any(|a| a == "--full")
            || std::env::var("VABFT_BENCH_FULL").map(|v| v == "1").unwrap_or(false)
        {
            BenchMode::Full
        } else {
            BenchMode::Quick
        }
    }

    /// True in full (paper-scale) mode.
    pub fn is_full(self) -> bool {
        self == BenchMode::Full
    }

    /// Pick quick/full variant.
    pub fn pick<T>(self, quick: T, full: T) -> T {
        match self {
            BenchMode::Quick => quick,
            BenchMode::Full => full,
        }
    }

    /// Print the standard mode banner benches lead with.
    pub fn banner(self, bench_name: &str) {
        println!(
            "[{}] mode = {} (pass --full or set VABFT_BENCH_FULL=1 for paper-scale runs)\n",
            bench_name,
            match self {
                BenchMode::Quick => "QUICK",
                BenchMode::Full => "FULL",
            }
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_produces_ordered_stats() {
        let s = bench(
            || {
                std::hint::black_box((0..1000).sum::<u64>());
            },
            50,
            Duration::from_millis(5),
        );
        assert!(s.iters >= 50);
        assert!(s.min <= s.median);
        assert!(s.median <= s.max);
        assert!(s.p95 <= s.max);
    }

    #[test]
    fn mode_pick() {
        assert_eq!(BenchMode::Quick.pick(1, 2), 1);
        assert_eq!(BenchMode::Full.pick(1, 2), 2);
    }

    #[test]
    fn throughput() {
        let s = Stats {
            iters: 1,
            mean: Duration::from_secs(2),
            median: Duration::from_secs(2),
            min: Duration::from_secs(2),
            max: Duration::from_secs(2),
            p95: Duration::from_secs(2),
        };
        assert_eq!(s.per_second(10.0), 5.0);
    }
}
