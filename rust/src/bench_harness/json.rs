//! Machine-readable trajectory output: the `BENCH_*.json` documents.
//!
//! Perf work is only credible against a recorded baseline, so the perf
//! benches (`parallel_engine`, `microkernel`) emit their measurements as
//! a small JSON document in addition to the human tables — and the
//! campaign engine emits its detection-quality grid the same way
//! (`BENCH_campaign.json`). The files are committed at the repository
//! root; their git history *is* the trajectory future PRs regress
//! against.
//!
//! All documents share one schema-versioned writer, [`JsonDoc`]: a
//! top-level object carrying a `schema` tag, flat metadata, and a uniform
//! `entries` array. No serde in the offline registry — the schema is flat
//! enough to write by hand, and writing it in exactly one place is what
//! lets [`validate_schema`] reject drift for every document at once.
//!
//! Determinism contract: a [`JsonDoc`] serializes byte-for-byte
//! identically for identical content — fixed field order, fixed float
//! formatting, no timestamps. The campaign's cross-thread-count
//! reproducibility test relies on this.

use std::path::PathBuf;

/// Schema tag of the perf-bench trajectory documents
/// (`BENCH_gemm.json`, `BENCH_gemm_micro.json`).
pub const BENCH_SCHEMA: &str = "vabft-bench/v1";

/// Schema tag of the campaign detection-quality documents
/// (`BENCH_campaign.json`). v2 added the multi-fault correction axis
/// (`multi_cell` entries with `pattern`/`flips`/`encoding` columns and
/// the `grid_exceeds_baseline` coverage gate in the metadata); v3 adds
/// the protection-plan axis (`plan_cell` entries validating every
/// planner-selectable scheme plus the `plan_gates_hold` /
/// `replication_bitwise_equal` metadata gates). Older documents no
/// longer validate — consumers must regenerate, not mix column sets in
/// one trajectory file.
pub const CAMPAIGN_SCHEMA: &str = "vabft-campaign/v3";

/// Schema tag of the serving-replay throughput documents
/// (`BENCH_serving.json`). v2 added the open-loop columns (`arrival`,
/// `p50_ms`/`p99_ms`/`p999_ms` tail latencies, `shed_rate`); v3 adds
/// the `plan` column (`"uniform"` / `"auto"`) for the planned-vs-uniform
/// A/B pair. Older documents no longer validate — consumers must
/// regenerate, not mix column sets in one trajectory file.
pub const SERVING_SCHEMA: &str = "vabft-serving/v3";

fn esc(s: &str) -> String {
    s.replace('\\', "\\\\").replace('"', "\\\"")
}

/// One scalar value in a schema-versioned document.
#[derive(Debug, Clone)]
pub enum JsonValue {
    /// String (escaped on write).
    Str(String),
    /// Integer.
    Int(i64),
    /// Float, fixed three decimal places (throughputs, ratios).
    Num(f64),
    /// Float, scientific notation with six significant decimals
    /// (magnitudes, thresholds). Non-finite values are stringified —
    /// JSON has no Inf/NaN literals.
    Sci(f64),
    /// Boolean.
    Bool(bool),
}

impl JsonValue {
    fn render(&self) -> String {
        match *self {
            JsonValue::Str(ref s) => format!("\"{}\"", esc(s)),
            JsonValue::Int(i) => i.to_string(),
            JsonValue::Num(x) if !x.is_finite() => format!("\"{x}\""),
            JsonValue::Num(x) => format!("{x:.3}"),
            JsonValue::Sci(x) if !x.is_finite() => format!("\"{x}\""),
            JsonValue::Sci(x) => format!("{x:.6e}"),
            JsonValue::Bool(b) => b.to_string(),
        }
    }
}

/// A schema-versioned JSON document: `schema` tag, flat metadata, and a
/// uniform `entries` array. The single writer behind every committed
/// `BENCH_*.json` file.
#[derive(Debug, Clone)]
pub struct JsonDoc {
    schema: String,
    meta: Vec<(String, JsonValue)>,
    entries: Vec<Vec<(String, JsonValue)>>,
}

impl JsonDoc {
    /// Empty document declaring `schema`.
    pub fn new(schema: &str) -> JsonDoc {
        JsonDoc { schema: schema.to_string(), meta: Vec::new(), entries: Vec::new() }
    }

    /// Append one top-level metadata field (serialized in insertion
    /// order, before `entries`).
    pub fn meta(&mut self, key: &str, value: JsonValue) -> &mut Self {
        self.meta.push((key.to_string(), value));
        self
    }

    /// Append one entry (an ordered list of `key: value` fields).
    pub fn entry(&mut self, fields: Vec<(String, JsonValue)>) -> &mut Self {
        self.entries.push(fields);
        self
    }

    /// Number of entries.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// True when no entries have been recorded.
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    fn render_entry(fields: &[(String, JsonValue)]) -> String {
        let body: Vec<String> =
            fields.iter().map(|(k, v)| format!("\"{}\": {}", esc(k), v.render())).collect();
        format!("{{{}}}", body.join(", "))
    }

    /// Serialize deterministically (fixed order, fixed float formats).
    pub fn to_json(&self) -> String {
        let mut out = String::new();
        out.push_str("{\n");
        out.push_str(&format!("  \"schema\": \"{}\",\n", esc(&self.schema)));
        for (k, v) in &self.meta {
            out.push_str(&format!("  \"{}\": {},\n", esc(k), v.render()));
        }
        out.push_str("  \"entries\": [\n");
        for (i, fields) in self.entries.iter().enumerate() {
            out.push_str(&format!(
                "    {}{}\n",
                Self::render_entry(fields),
                if i + 1 == self.entries.len() { "" } else { "," }
            ));
        }
        out.push_str("  ]\n}\n");
        out
    }

    /// Splice this document's entries onto an existing serialized
    /// document of the *same schema*, preserving the existing metadata
    /// and entries verbatim. This is how several benches share one
    /// trajectory file (`parallel_engine` writes `BENCH_gemm.json`, the
    /// overhead bench appends its §6.8 ladder to it) without any of them
    /// clobbering the others' measurements. Errors when `existing` fails
    /// [`validate_schema`] for this document's schema.
    pub fn splice_into(&self, existing: &str) -> Result<String, String> {
        validate_schema(existing, &self.schema)?;
        let open = existing
            .find("\"entries\": [")
            .ok_or_else(|| "document has no `entries` array".to_string())?;
        let close = existing
            .rfind(']')
            .ok_or_else(|| "unterminated `entries` array".to_string())?;
        if close < open {
            return Err("malformed `entries` array".to_string());
        }
        let has_entries = existing[open..close].contains('{');
        let mut out = existing[..close].trim_end().to_string();
        for (i, fields) in self.entries.iter().enumerate() {
            if has_entries || i > 0 {
                out.push(',');
            }
            out.push_str("\n    ");
            out.push_str(&Self::render_entry(fields));
        }
        out.push_str("\n  ]\n}\n");
        Ok(out)
    }

    /// Append this document's entries to `filename` at the repository
    /// root (or `$<env_override>` verbatim when set and non-empty). When
    /// the file already holds a same-schema document its metadata and
    /// entries are preserved and the new entries are spliced on; a
    /// missing, unreadable or foreign-schema file is overwritten fresh.
    pub fn append(&self, filename: &str, env_override: &str) -> std::io::Result<PathBuf> {
        let path = Self::resolve(filename, env_override);
        match std::fs::read_to_string(&path) {
            Ok(existing) => match self.splice_into(&existing) {
                Ok(json) => {
                    std::fs::write(&path, json)?;
                    Ok(path)
                }
                Err(_) => self.write_to(path),
            },
            Err(_) => self.write_to(path),
        }
    }

    /// Write the document to `path` verbatim (an explicitly requested
    /// destination, e.g. a CLI `--json FILE` flag — takes precedence over
    /// any env fallback), returning the path.
    pub fn write_to(&self, path: impl Into<PathBuf>) -> std::io::Result<PathBuf> {
        let path = path.into();
        std::fs::write(&path, self.to_json())?;
        Ok(path)
    }

    /// Write the document to `filename` at the repository root (or to
    /// `$<env_override>` verbatim when that variable is set and
    /// non-empty), returning the path.
    pub fn write(&self, filename: &str, env_override: &str) -> std::io::Result<PathBuf> {
        self.write_to(Self::resolve(filename, env_override))
    }

    /// Resolve a trajectory destination: `$<env_override>` verbatim when
    /// set and non-empty, else `filename` at the repository root.
    fn resolve(filename: &str, env_override: &str) -> PathBuf {
        match std::env::var(env_override) {
            Ok(p) if !p.is_empty() => PathBuf::from(p),
            _ => {
                // CARGO_MANIFEST_DIR is rust/; the trajectory lives at
                // the workspace root next to README.md.
                let manifest = PathBuf::from(env!("CARGO_MANIFEST_DIR"));
                let root = manifest.parent().map(|p| p.to_path_buf()).unwrap_or(manifest);
                root.join(filename)
            }
        }
    }
}

/// Validate that a serialized document declares exactly `schema` and has
/// the writer's document shape. Consumers (CI gates, trend tooling) call
/// this before trusting a committed file; the unit tests pin it so any
/// writer change that drifts the schema without bumping the version tag
/// fails the build.
pub fn validate_schema(json: &str, schema: &str) -> Result<(), String> {
    let tag = format!("\"schema\": \"{}\"", esc(schema));
    let first = json.lines().nth(1).unwrap_or("");
    if first.trim().trim_end_matches(',') != tag {
        return Err(format!(
            "schema mismatch: expected `{tag}` as the first field, found `{}`",
            first.trim()
        ));
    }
    if !json.contains("\"entries\": [") {
        return Err("document has no `entries` array".to_string());
    }
    Ok(())
}

/// One measurement: a row of a perf bench's `entries` array.
#[derive(Debug, Clone)]
pub struct BenchRecord {
    /// What was measured, e.g. `"1024x1024x1024"` or `"quantize 65536"`.
    pub case: String,
    /// Element/precision label (`"fp32"`, `"fp64"`, `"bf16(generic)"`).
    pub precision: String,
    /// Reduction strategy name, or `"-"` when not applicable.
    pub strategy: String,
    /// Engine/variant label (`"naive"`, `"unpacked"`, `"packed"`,
    /// `"quantize"`, `"quantize_slice"`, `"mr8nr8"` …).
    pub engine: String,
    /// Worker threads used (1 for single-threaded cases).
    pub threads: usize,
    /// Unit of `value` (`"GFLOP/s"`, `"Melem/s"`).
    pub unit: String,
    /// The measured throughput in `unit`s.
    pub value: f64,
    /// Speedup vs the case's baseline variant (1.0 for the baseline
    /// itself).
    pub speedup_vs_baseline: f64,
    /// Whether the variant's output was verified bitwise-equal to the
    /// reference (the schedule-preservation gate; always checked, never
    /// a timing assertion).
    pub bitwise_equal: bool,
}

/// Collects [`BenchRecord`]s for one bench binary and serializes them
/// through the shared [`JsonDoc`] writer under [`BENCH_SCHEMA`].
#[derive(Debug, Clone)]
pub struct BenchRecords {
    bench: String,
    records: Vec<BenchRecord>,
}

impl BenchRecords {
    /// Start a record set for the named bench.
    pub fn new(bench: &str) -> BenchRecords {
        BenchRecords { bench: bench.to_string(), records: Vec::new() }
    }

    /// Append one measurement.
    pub fn push(&mut self, r: BenchRecord) {
        self.records.push(r);
    }

    /// Number of collected records.
    pub fn len(&self) -> usize {
        self.records.len()
    }

    /// True when nothing has been recorded.
    pub fn is_empty(&self) -> bool {
        self.records.is_empty()
    }

    /// Assemble the schema-versioned document.
    pub fn to_doc(&self) -> JsonDoc {
        let mut doc = JsonDoc::new(BENCH_SCHEMA);
        doc.meta("bench", JsonValue::Str(self.bench.clone()));
        doc.meta(
            "mode",
            JsonValue::Str(
                if super::BenchMode::from_env().is_full() { "full" } else { "quick" }.to_string(),
            ),
        );
        for r in &self.records {
            doc.entry(vec![
                ("case".to_string(), JsonValue::Str(r.case.clone())),
                ("precision".to_string(), JsonValue::Str(r.precision.clone())),
                ("strategy".to_string(), JsonValue::Str(r.strategy.clone())),
                ("engine".to_string(), JsonValue::Str(r.engine.clone())),
                ("threads".to_string(), JsonValue::Int(r.threads as i64)),
                ("unit".to_string(), JsonValue::Str(r.unit.clone())),
                ("value".to_string(), JsonValue::Num(r.value)),
                ("speedup_vs_baseline".to_string(), JsonValue::Num(r.speedup_vs_baseline)),
                ("bitwise_equal".to_string(), JsonValue::Bool(r.bitwise_equal)),
            ]);
        }
        doc
    }

    /// Serialize to the trajectory JSON document.
    pub fn to_json(&self) -> String {
        self.to_doc().to_json()
    }

    /// Write the document to `filename` at the repository root (or to
    /// `$VABFT_BENCH_JSON` verbatim when set), returning the path.
    pub fn write(&self, filename: &str) -> std::io::Result<PathBuf> {
        self.to_doc().write(filename, "VABFT_BENCH_JSON")
    }

    /// Append this record set's entries to `filename` at the repository
    /// root (or `$VABFT_BENCH_JSON`), preserving entries another bench
    /// already recorded in the same trajectory file. See
    /// [`JsonDoc::splice_into`].
    pub fn append(&self, filename: &str) -> std::io::Result<PathBuf> {
        self.to_doc().append(filename, "VABFT_BENCH_JSON")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn record() -> BenchRecord {
        BenchRecord {
            case: "64x64x64".into(),
            precision: "fp32".into(),
            strategy: "fma".into(),
            engine: "packed".into(),
            threads: 2,
            unit: "GFLOP/s".into(),
            value: 12.3456,
            speedup_vs_baseline: 2.5,
            bitwise_equal: true,
        }
    }

    #[test]
    fn json_shape() {
        let mut rs = BenchRecords::new("unit_test");
        assert!(rs.is_empty());
        rs.push(record());
        rs.push(BenchRecord { engine: "naive".into(), speedup_vs_baseline: 1.0, ..record() });
        assert_eq!(rs.len(), 2);
        let j = rs.to_json();
        assert!(j.contains("\"schema\": \"vabft-bench/v1\""));
        assert!(j.contains("\"bench\": \"unit_test\""));
        assert!(j.contains("\"value\": 12.346"));
        assert!(j.contains("\"bitwise_equal\": true"));
        // exactly one comma-separated entry (the last has no comma)
        assert_eq!(j.matches("},\n").count(), 1);
        assert!(j.trim_end().ends_with('}'));
    }

    #[test]
    fn escaping() {
        let mut rs = BenchRecords::new("a\"b");
        rs.push(BenchRecord { case: "x\\y".into(), ..record() });
        let j = rs.to_json();
        assert!(j.contains("a\\\"b"));
        assert!(j.contains("x\\\\y"));
    }

    #[test]
    fn schema_validation_rejects_drift() {
        let mut rs = BenchRecords::new("drift");
        rs.push(record());
        let j = rs.to_json();
        assert!(validate_schema(&j, BENCH_SCHEMA).is_ok());
        // A different document family must not validate …
        assert!(validate_schema(&j, CAMPAIGN_SCHEMA).is_err());
        // … nor a bumped version …
        assert!(validate_schema(&j, "vabft-bench/v2").is_err());
        // … nor a schema-less or shape-less document.
        assert!(validate_schema("{}", BENCH_SCHEMA).is_err());
        let headless = j.replacen("\"schema\": \"vabft-bench/v1\",\n", "", 1);
        assert!(validate_schema(&headless, BENCH_SCHEMA).is_err());
        let mut doc = JsonDoc::new(CAMPAIGN_SCHEMA);
        doc.meta("bench", JsonValue::Str("campaign".into()));
        assert!(validate_schema(&doc.to_json(), CAMPAIGN_SCHEMA).is_ok());
    }

    #[test]
    fn splice_appends_entries_preserving_existing() {
        let mut first = BenchRecords::new("first");
        first.push(record());
        let base = first.to_json();
        let mut second = BenchRecords::new("second");
        second.push(BenchRecord { engine: "other".into(), ..record() });
        let merged = second.to_doc().splice_into(&base).unwrap();
        assert!(validate_schema(&merged, BENCH_SCHEMA).is_ok());
        // Existing metadata and entries survive; the new entry is added.
        assert!(merged.contains("\"bench\": \"first\""));
        assert!(merged.contains("\"engine\": \"packed\""));
        assert!(merged.contains("\"engine\": \"other\""));
        // Two entries → exactly one separating comma, last entry bare.
        assert_eq!(merged.matches("},\n").count(), 1);
        // Splicing onto the merged document again keeps growing it.
        let grown = second.to_doc().splice_into(&merged).unwrap();
        assert_eq!(grown.matches("},\n").count(), 2);
        // The committed placeholder form (`"entries": []`) also accepts
        // a first splice.
        let placeholder = "{\n  \"schema\": \"vabft-bench/v1\",\n  \"bench\": \"x\",\n  \
                           \"entries\": []\n}\n";
        let seeded = second.to_doc().splice_into(placeholder).unwrap();
        assert!(validate_schema(&seeded, BENCH_SCHEMA).is_ok());
        assert!(seeded.contains("\"engine\": \"other\""));
        assert_eq!(seeded.matches("},\n").count(), 0);
        // Foreign schemas and shapeless documents are refused.
        assert!(second.to_doc().splice_into("{}").is_err());
        let mut campaign = JsonDoc::new(CAMPAIGN_SCHEMA);
        campaign.entry(vec![("cell".to_string(), JsonValue::Int(0))]);
        assert!(campaign.splice_into(&base).is_err());
    }

    #[test]
    fn serving_schema_v3_rejects_older_documents() {
        // The v2 → v3 migration: v3 rows carry the `plan` column
        // (planned-vs-uniform A/B) that v1/v2 rows lack, so committed
        // older trajectories must be rejected outright (regenerated,
        // never spliced into).
        assert_eq!(SERVING_SCHEMA, "vabft-serving/v3");
        for old in ["vabft-serving/v1", "vabft-serving/v2"] {
            let doc = format!(
                "{{\n  \"schema\": \"{old}\",\n  \"bench\": \"serving_replay\",\n  \
                 \"entries\": []\n}}\n"
            );
            assert!(validate_schema(&doc, SERVING_SCHEMA).is_err());
            // A v3 doc refuses to splice onto an older file (forcing the
            // fresh-overwrite path in `JsonDoc::append`).
            let mut patch = JsonDoc::new(SERVING_SCHEMA);
            patch.entry(vec![("rps".to_string(), JsonValue::Num(1.0))]);
            assert!(patch.splice_into(&doc).is_err());
        }
        // A same-tag v3 document still validates.
        let v3 = JsonDoc::new(SERVING_SCHEMA);
        assert!(validate_schema(&v3.to_json(), SERVING_SCHEMA).is_ok());
    }

    #[test]
    fn campaign_schema_v3_rejects_older_documents() {
        // The v2 → v3 migration: v3 documents carry the protection-plan
        // axis (`plan_cell` entries, `plan_gates_hold` metadata) that
        // v1/v2 documents lack, so committed older trajectories must be
        // rejected outright (regenerated, never spliced into).
        assert_eq!(CAMPAIGN_SCHEMA, "vabft-campaign/v3");
        for old in ["vabft-campaign/v1", "vabft-campaign/v2"] {
            let doc = format!(
                "{{\n  \"schema\": \"{old}\",\n  \"bench\": \"campaign\",\n  \
                 \"entries\": []\n}}\n"
            );
            assert!(validate_schema(&doc, CAMPAIGN_SCHEMA).is_err());
            // A v3 doc refuses to splice onto an older file (forcing the
            // fresh-overwrite path in `JsonDoc::append`).
            let mut patch = JsonDoc::new(CAMPAIGN_SCHEMA);
            patch.entry(vec![("cell".to_string(), JsonValue::Int(0))]);
            assert!(patch.splice_into(&doc).is_err());
        }
        // A same-tag v3 document still validates.
        let v3 = JsonDoc::new(CAMPAIGN_SCHEMA);
        assert!(validate_schema(&v3.to_json(), CAMPAIGN_SCHEMA).is_ok());
    }

    #[test]
    fn value_rendering_is_deterministic() {
        assert_eq!(JsonValue::Num(12.3456).render(), "12.346");
        assert_eq!(JsonValue::Sci(0.0012345678).render(), "1.234568e-3");
        assert_eq!(JsonValue::Sci(f64::INFINITY).render(), "\"inf\"");
        assert_eq!(JsonValue::Int(-3).render(), "-3");
        assert_eq!(JsonValue::Bool(false).render(), "false");
        assert_eq!(JsonValue::Str("a\"b".into()).render(), "\"a\\\"b\"");
    }
}
