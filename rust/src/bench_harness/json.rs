//! Machine-readable bench output: the `BENCH_*.json` trajectory files.
//!
//! Perf work is only credible against a recorded baseline, so the perf
//! benches (`parallel_engine`, `microkernel`) emit their measurements as
//! a small JSON document in addition to the human tables. The files are
//! committed at the repository root; their git history *is* the
//! throughput trajectory future PRs regress against.
//!
//! No serde in the offline registry — the schema is flat enough to write
//! by hand: a top-level object with bench metadata and an `entries`
//! array of uniform records.

use std::path::PathBuf;

/// One measurement: a row of the `entries` array.
#[derive(Debug, Clone)]
pub struct BenchRecord {
    /// What was measured, e.g. `"1024x1024x1024"` or `"quantize 65536"`.
    pub case: String,
    /// Element/precision label (`"fp32"`, `"fp64"`, `"bf16(generic)"`).
    pub precision: String,
    /// Reduction strategy name, or `"-"` when not applicable.
    pub strategy: String,
    /// Engine/variant label (`"naive"`, `"unpacked"`, `"packed"`,
    /// `"quantize"`, `"quantize_slice"`, `"mr8nr8"` …).
    pub engine: String,
    /// Worker threads used (1 for single-threaded cases).
    pub threads: usize,
    /// Unit of `value` (`"GFLOP/s"`, `"Melem/s"`).
    pub unit: String,
    /// The measured throughput in `unit`s.
    pub value: f64,
    /// Speedup vs the case's baseline variant (1.0 for the baseline
    /// itself).
    pub speedup_vs_baseline: f64,
    /// Whether the variant's output was verified bitwise-equal to the
    /// reference (the schedule-preservation gate; always checked, never
    /// a timing assertion).
    pub bitwise_equal: bool,
}

/// Collects [`BenchRecord`]s for one bench binary and serializes them.
#[derive(Debug, Clone)]
pub struct BenchRecords {
    bench: String,
    records: Vec<BenchRecord>,
}

fn esc(s: &str) -> String {
    s.replace('\\', "\\\\").replace('"', "\\\"")
}

impl BenchRecords {
    /// Start a record set for the named bench.
    pub fn new(bench: &str) -> BenchRecords {
        BenchRecords { bench: bench.to_string(), records: Vec::new() }
    }

    /// Append one measurement.
    pub fn push(&mut self, r: BenchRecord) {
        self.records.push(r);
    }

    /// Number of collected records.
    pub fn len(&self) -> usize {
        self.records.len()
    }

    /// True when nothing has been recorded.
    pub fn is_empty(&self) -> bool {
        self.records.is_empty()
    }

    /// Serialize to the trajectory JSON document.
    pub fn to_json(&self) -> String {
        let mut out = String::new();
        out.push_str("{\n");
        out.push_str(&format!("  \"bench\": \"{}\",\n", esc(&self.bench)));
        out.push_str(&format!(
            "  \"mode\": \"{}\",\n",
            if super::BenchMode::from_env().is_full() { "full" } else { "quick" }
        ));
        out.push_str("  \"entries\": [\n");
        for (i, r) in self.records.iter().enumerate() {
            out.push_str(&format!(
                "    {{\"case\": \"{}\", \"precision\": \"{}\", \"strategy\": \"{}\", \
                 \"engine\": \"{}\", \"threads\": {}, \"unit\": \"{}\", \"value\": {:.3}, \
                 \"speedup_vs_baseline\": {:.3}, \"bitwise_equal\": {}}}{}\n",
                esc(&r.case),
                esc(&r.precision),
                esc(&r.strategy),
                esc(&r.engine),
                r.threads,
                esc(&r.unit),
                r.value,
                r.speedup_vs_baseline,
                r.bitwise_equal,
                if i + 1 == self.records.len() { "" } else { "," }
            ));
        }
        out.push_str("  ]\n}\n");
        out
    }

    /// Write the document to `filename` at the repository root (or to
    /// `$VABFT_BENCH_JSON` verbatim when set), returning the path.
    pub fn write(&self, filename: &str) -> std::io::Result<PathBuf> {
        let path = match std::env::var("VABFT_BENCH_JSON") {
            Ok(p) if !p.is_empty() => PathBuf::from(p),
            _ => {
                // CARGO_MANIFEST_DIR is rust/; the trajectory lives at
                // the workspace root next to README.md.
                let manifest = PathBuf::from(env!("CARGO_MANIFEST_DIR"));
                manifest.parent().map(|p| p.to_path_buf()).unwrap_or(manifest).join(filename)
            }
        };
        std::fs::write(&path, self.to_json())?;
        Ok(path)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn record() -> BenchRecord {
        BenchRecord {
            case: "64x64x64".into(),
            precision: "fp32".into(),
            strategy: "fma".into(),
            engine: "packed".into(),
            threads: 2,
            unit: "GFLOP/s".into(),
            value: 12.3456,
            speedup_vs_baseline: 2.5,
            bitwise_equal: true,
        }
    }

    #[test]
    fn json_shape() {
        let mut rs = BenchRecords::new("unit_test");
        assert!(rs.is_empty());
        rs.push(record());
        rs.push(BenchRecord { engine: "naive".into(), speedup_vs_baseline: 1.0, ..record() });
        assert_eq!(rs.len(), 2);
        let j = rs.to_json();
        assert!(j.contains("\"bench\": \"unit_test\""));
        assert!(j.contains("\"value\": 12.346"));
        assert!(j.contains("\"bitwise_equal\": true"));
        // exactly one comma-separated entry (the last has no comma)
        assert_eq!(j.matches("},\n").count(), 1);
        assert!(j.trim_end().ends_with('}'));
    }

    #[test]
    fn escaping() {
        let mut rs = BenchRecords::new("a\"b");
        rs.push(BenchRecord { case: "x\\y".into(), ..record() });
        let j = rs.to_json();
        assert!(j.contains("a\\\"b"));
        assert!(j.contains("x\\\\y"));
    }
}
