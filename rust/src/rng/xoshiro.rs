//! SplitMix64 and xoshiro256++ generators.

use super::Rng;

/// SplitMix64 (Steele, Lea & Flood 2014). Used to expand seeds for
/// xoshiro and as a tiny standalone generator.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    /// Construct from a raw 64-bit seed.
    pub fn new(seed: u64) -> SplitMix64 {
        SplitMix64 { state: seed }
    }
}

impl Rng for SplitMix64 {
    #[inline]
    fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256++ 1.0 (Blackman & Vigna 2019): fast, 256-bit state,
/// passes BigCrush. The crate-wide default generator.
#[derive(Debug, Clone)]
pub struct Xoshiro256pp {
    s: [u64; 4],
}

impl Xoshiro256pp {
    /// Seed via SplitMix64 expansion (the reference seeding procedure).
    pub fn seed_from_u64(seed: u64) -> Xoshiro256pp {
        let mut sm = SplitMix64::new(seed);
        let s = [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()];
        Xoshiro256pp { s }
    }

    /// Deterministic per-(stream, substream) generator, so experiments can
    /// key a generator by (table id, matrix size, trial index) and get the
    /// same matrices on every run regardless of thread scheduling.
    pub fn from_stream(stream: u64, substream: u64) -> Xoshiro256pp {
        // Mix the two words through SplitMix64 twice for independence.
        let mut sm = SplitMix64::new(stream.wrapping_mul(0xA24BAED4963EE407) ^ substream);
        let _ = sm.next_u64();
        let s = [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()];
        Xoshiro256pp { s }
    }

    /// The jump function: advances the stream by 2^128 draws. Used to
    /// partition one seed into parallel non-overlapping streams.
    pub fn jump(&mut self) {
        const JUMP: [u64; 4] =
            [0x180EC6D33CFD0ABA, 0xD5A61266F0C9392C, 0xA9582618E03FC9AA, 0x39ABDC4529B1661C];
        let mut s0 = 0u64;
        let mut s1 = 0u64;
        let mut s2 = 0u64;
        let mut s3 = 0u64;
        for jump in JUMP {
            for b in 0..64 {
                if (jump & (1u64 << b)) != 0 {
                    s0 ^= self.s[0];
                    s1 ^= self.s[1];
                    s2 ^= self.s[2];
                    s3 ^= self.s[3];
                }
                let _ = self.next_u64();
            }
        }
        self.s = [s0, s1, s2, s3];
    }
}

impl Rng for Xoshiro256pp {
    #[inline]
    fn next_u64(&mut self) -> u64 {
        let result = (self.s[0].wrapping_add(self.s[3]))
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_reference_vector() {
        // Reference values for seed 1234567 from the public SplitMix64
        // reference implementation.
        let mut sm = SplitMix64::new(1234567);
        let v = [sm.next_u64(), sm.next_u64(), sm.next_u64()];
        assert_eq!(v[0], 6457827717110365317);
        assert_eq!(v[1], 3203168211198807973);
        assert_eq!(v[2], 9817491932198370423);
    }

    #[test]
    fn xoshiro_deterministic() {
        let mut a = Xoshiro256pp::seed_from_u64(42);
        let mut b = Xoshiro256pp::seed_from_u64(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Xoshiro256pp::seed_from_u64(1);
        let mut b = Xoshiro256pp::seed_from_u64(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn stream_keying_is_stable() {
        let mut a = Xoshiro256pp::from_stream(8, 100);
        let mut b = Xoshiro256pp::from_stream(8, 100);
        let mut c = Xoshiro256pp::from_stream(8, 101);
        assert_eq!(a.next_u64(), b.next_u64());
        let mut a2 = Xoshiro256pp::from_stream(8, 100);
        let _ = a2.next_u64();
        assert_ne!(a2.next_u64(), c.next_u64());
    }

    #[test]
    fn jump_produces_disjoint_prefix() {
        let mut a = Xoshiro256pp::seed_from_u64(7);
        let mut b = a.clone();
        b.jump();
        let xs: Vec<u64> = (0..32).map(|_| a.next_u64()).collect();
        let ys: Vec<u64> = (0..32).map(|_| b.next_u64()).collect();
        for y in &ys {
            assert!(!xs.contains(y));
        }
    }

    #[test]
    fn equidistribution_rough() {
        let mut rng = Xoshiro256pp::seed_from_u64(99);
        let mut buckets = [0usize; 16];
        let n = 160_000;
        for _ in 0..n {
            buckets[(rng.next_u64() >> 60) as usize] += 1;
        }
        let expect = n / 16;
        for (i, &b) in buckets.iter().enumerate() {
            assert!(
                (b as f64 - expect as f64).abs() < expect as f64 * 0.05,
                "bucket {i}: {b} vs {expect}"
            );
        }
    }
}
