//! Sampling distributions used across the paper's experiments (§6.1).

use super::Rng;

/// A sampling distribution for matrix elements.
///
/// The paper's experimental section (§6.1) tests four distributions;
/// §3.6's calibration protocol adds |N(1,1)|. All are provided here in
/// parametric form, plus `Constant` (useful in tests) and `Scaled`
/// composition for building weight-like tensors.
#[derive(Debug, Clone, PartialEq)]
pub enum Distribution {
    /// Normal N(mean, std²).
    Normal { mean: f64, std: f64 },
    /// Uniform U(lo, hi).
    Uniform { lo: f64, hi: f64 },
    /// N(mean, std²) truncated (by rejection) to [lo, hi].
    TruncatedNormal { mean: f64, std: f64, lo: f64, hi: f64 },
    /// |N(mean, std²)| — the paper's calibration distribution (§3.6 step 1,
    /// "positive matrices with |N(1,1)| elements").
    AbsNormal { mean: f64, std: f64 },
    /// Every element equal to `value` (degenerate; exercises the
    /// extrema-variance bound's zero-variance edge).
    Constant { value: f64 },
}

impl Distribution {
    /// §6.1 "N(1e-6, 1)": near-zero mean, normalized-activation-like.
    pub fn near_zero_normal() -> Distribution {
        Distribution::Normal { mean: 1e-6, std: 1.0 }
    }

    /// §6.1 "N(1,1)": non-zero mean, the A-ABFT stress test.
    pub fn normal_1_1() -> Distribution {
        Distribution::Normal { mean: 1.0, std: 1.0 }
    }

    /// §6.1 "U(-1,1)".
    pub fn uniform_pm1() -> Distribution {
        Distribution::Uniform { lo: -1.0, hi: 1.0 }
    }

    /// Table 6's BF16 setup uses U(0,1).
    pub fn uniform_01() -> Distribution {
        Distribution::Uniform { lo: 0.0, hi: 1.0 }
    }

    /// §6.1 "Truncated N(0,1) in [-1,1]".
    pub fn truncated_normal() -> Distribution {
        Distribution::TruncatedNormal { mean: 0.0, std: 1.0, lo: -1.0, hi: 1.0 }
    }

    /// §3.6 calibration distribution |N(1,1)|.
    pub fn calibration() -> Distribution {
        Distribution::AbsNormal { mean: 1.0, std: 1.0 }
    }

    /// The paper's four evaluation distributions in Table 8 column order.
    pub fn paper_suite() -> [(&'static str, Distribution); 4] {
        [
            ("N(1e-6,1)", Self::near_zero_normal()),
            ("N(1,1)", Self::normal_1_1()),
            ("U(-1,1)", Self::uniform_pm1()),
            ("TruncN", Self::truncated_normal()),
        ]
    }

    /// Draw one sample.
    pub fn sample<R: Rng + ?Sized>(&self, rng: &mut R) -> f64 {
        match *self {
            Distribution::Normal { mean, std } => mean + std * rng.standard_normal(),
            Distribution::Uniform { lo, hi } => rng.uniform(lo, hi),
            Distribution::TruncatedNormal { mean, std, lo, hi } => {
                assert!(lo < hi, "empty truncation interval");
                loop {
                    let x = mean + std * rng.standard_normal();
                    if x >= lo && x <= hi {
                        return x;
                    }
                }
            }
            Distribution::AbsNormal { mean, std } => (mean + std * rng.standard_normal()).abs(),
            Distribution::Constant { value } => value,
        }
    }

    /// Fill a slice with samples.
    pub fn sample_into<R: Rng + ?Sized>(&self, out: &mut [f64], rng: &mut R) {
        for v in out.iter_mut() {
            *v = self.sample(rng);
        }
    }

    /// Short display name for reports.
    pub fn label(&self) -> String {
        match *self {
            Distribution::Normal { mean, std } => format!("N({mean},{std})"),
            Distribution::Uniform { lo, hi } => format!("U({lo},{hi})"),
            Distribution::TruncatedNormal { lo, hi, .. } => format!("TruncN[{lo},{hi}]"),
            Distribution::AbsNormal { mean, std } => format!("|N({mean},{std})|"),
            Distribution::Constant { value } => format!("Const({value})"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Xoshiro256pp;

    fn moments(d: &Distribution, n: usize) -> (f64, f64) {
        let mut rng = Xoshiro256pp::seed_from_u64(1234);
        let mut sum = 0.0;
        let mut sumsq = 0.0;
        for _ in 0..n {
            let x = d.sample(&mut rng);
            sum += x;
            sumsq += x * x;
        }
        let mean = sum / n as f64;
        (mean, sumsq / n as f64 - mean * mean)
    }

    #[test]
    fn normal_moments() {
        let (m, v) = moments(&Distribution::Normal { mean: 2.0, std: 3.0 }, 100_000);
        assert!((m - 2.0).abs() < 0.05);
        assert!((v - 9.0).abs() < 0.2);
    }

    #[test]
    fn uniform_moments_and_range() {
        let d = Distribution::uniform_pm1();
        let mut rng = Xoshiro256pp::seed_from_u64(5);
        for _ in 0..10_000 {
            let x = d.sample(&mut rng);
            assert!((-1.0..1.0).contains(&x));
        }
        let (m, v) = moments(&d, 100_000);
        assert!(m.abs() < 0.01);
        assert!((v - 1.0 / 3.0).abs() < 0.01); // Var U(-1,1) = 1/3
    }

    #[test]
    fn truncated_normal_respects_bounds() {
        let d = Distribution::truncated_normal();
        let mut rng = Xoshiro256pp::seed_from_u64(6);
        for _ in 0..10_000 {
            let x = d.sample(&mut rng);
            assert!((-1.0..=1.0).contains(&x));
        }
        // Truncating N(0,1) to ±1σ gives variance ≈ 0.2912
        let (m, v) = moments(&d, 200_000);
        assert!(m.abs() < 0.01);
        assert!((v - 0.2912).abs() < 0.01, "var {v}");
    }

    #[test]
    fn abs_normal_is_positive() {
        let d = Distribution::calibration();
        let mut rng = Xoshiro256pp::seed_from_u64(7);
        for _ in 0..10_000 {
            assert!(d.sample(&mut rng) >= 0.0);
        }
    }

    #[test]
    fn constant_distribution() {
        let d = Distribution::Constant { value: 4.25 };
        let mut rng = Xoshiro256pp::seed_from_u64(8);
        for _ in 0..10 {
            assert_eq!(d.sample(&mut rng), 4.25);
        }
    }
}
