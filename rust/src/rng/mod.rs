//! Pseudo-random number generation and sampling distributions.
//!
//! The offline registry has no `rand`, and the experiments need exactly
//! reproducible streams keyed by (experiment, size, trial), so this module
//! implements:
//!
//! * [`SplitMix64`] — seed expander (Steele et al. 2014), also a fine
//!   general-purpose generator for non-critical uses.
//! * [`Xoshiro256pp`] — xoshiro256++ (Blackman & Vigna 2019), the default
//!   generator everywhere in the crate.
//! * [`Distribution`] — the four distributions from the paper's §6.1
//!   (near-zero normal, N(1,1), U(-1,1), truncated normal) plus the
//!   calibration distribution |N(1,1)| from §3.6 and general parametric
//!   forms.

mod distributions;
mod xoshiro;

pub use distributions::Distribution;
pub use xoshiro::{SplitMix64, Xoshiro256pp};

/// FNV-1a 64-bit offset basis — the canonical initial state for
/// [`fnv1a`].
pub const FNV1A_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;

/// Fold `bytes` into an FNV-1a hash state (64-bit). The crate's one
/// stable, dependency-free byte hash: the campaign grid derives operand
/// stream ids with it and the replay workload builds its output
/// fingerprint from it — one implementation, so the two can never drift.
pub fn fnv1a(h: u64, bytes: impl IntoIterator<Item = u8>) -> u64 {
    let mut h = h;
    for b in bytes {
        h = (h ^ b as u64).wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Minimal RNG interface (the `rand_core` API surface we actually need).
pub trait Rng {
    /// Next 64 uniformly random bits.
    fn next_u64(&mut self) -> u64;

    /// Uniform f64 in [0, 1) with 53 random bits.
    #[inline]
    fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in [lo, hi).
    #[inline]
    fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Uniform integer in [0, n) (Lemire-style rejection-free for our
    /// purposes: modulo bias is < 2^-32 for n ≪ 2^32, but we do proper
    /// rejection sampling to keep streams exactly unbiased).
    fn uniform_u64(&mut self, n: u64) -> u64 {
        assert!(n > 0);
        if n.is_power_of_two() {
            return self.next_u64() & (n - 1);
        }
        let zone = u64::MAX - (u64::MAX % n);
        loop {
            let v = self.next_u64();
            if v < zone {
                return v % n;
            }
        }
    }

    /// Standard normal via Box–Muller (polar form avoided to keep the
    /// stream consumption deterministic: exactly two u64 per pair).
    fn standard_normal(&mut self) -> f64 {
        // Use the cached second variate when available is NOT done here to
        // keep the trait object-safe and stateless; callers drawing many
        // normals should use `Distribution::Normal` + `sample_into`.
        let u1 = loop {
            let u = self.next_f64();
            if u > 0.0 {
                break u;
            }
        };
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Fork a statistically independent generator (for worker threads).
    fn fork(&mut self) -> Xoshiro256pp {
        Xoshiro256pp::seed_from_u64(self.next_u64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_u64_bounds_and_coverage() {
        let mut rng = Xoshiro256pp::seed_from_u64(1);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = rng.uniform_u64(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s), "all residues should appear");
    }

    #[test]
    fn next_f64_in_unit_interval() {
        let mut rng = Xoshiro256pp::seed_from_u64(2);
        for _ in 0..10_000 {
            let x = rng.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn standard_normal_moments() {
        let mut rng = Xoshiro256pp::seed_from_u64(3);
        let n = 200_000;
        let mut sum = 0.0;
        let mut sumsq = 0.0;
        for _ in 0..n {
            let x = rng.standard_normal();
            sum += x;
            sumsq += x * x;
        }
        let mean = sum / n as f64;
        let var = sumsq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.01, "mean {mean}");
        assert!((var - 1.0).abs() < 0.02, "var {var}");
    }

    #[test]
    fn fork_diverges_from_parent() {
        let mut a = Xoshiro256pp::seed_from_u64(4);
        let mut b = a.fork();
        let xs: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let ys: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        assert_ne!(xs, ys);
    }

    #[test]
    fn fnv1a_matches_reference_vectors() {
        // Canonical FNV-1a test vectors (64-bit).
        assert_eq!(fnv1a(FNV1A_OFFSET, std::iter::empty::<u8>()), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a(FNV1A_OFFSET, *b"a"), 0xaf63_dc4c_8601_ec8c);
        assert_eq!(fnv1a(FNV1A_OFFSET, *b"foobar"), 0x85944171f73967e8);
        // Chaining two folds equals one fold over the concatenation.
        let once = fnv1a(FNV1A_OFFSET, *b"foobar");
        let twice = fnv1a(fnv1a(FNV1A_OFFSET, *b"foo"), *b"bar");
        assert_eq!(once, twice);
    }
}
