//! Verification, localization and online correction (paper §2.2,
//! Eq. 4–11).
//!
//! Given the encoded product row `[C[i][0..N] | C^{r1}[i] | C^{r2}[i]]`,
//! recompute the actual row sums with the same reduction schedule and form
//! the verification differences (Eq. 7–8: δ_k = C[i][k] − C_ref[i][k], so
//! the recomputed sums carry the error and the checksums are the
//! reference):
//!
//! ```text
//! D1 = Σ_j C[i][j] − C^{r1}[i]           (≈ δ_j, the fault magnitude)
//! D2 = Σ_j w(j)·C[i][j] − C^{r2}[i]      (≈ w(j)·δ_j)
//! ```
//!
//! A row is flagged when |D1| exceeds its threshold; the fault column is
//! recovered as `j = D2/D1 − 1` and corrected in place by subtracting D1
//! (Eq. 10) — online correction without recomputation.

use crate::abft::encode::position_weight;
use crate::gemm::GemmEngine;
use crate::matrix::Matrix;

/// Per-row verification measurements.
#[derive(Debug, Clone, Copy)]
pub struct RowCheck {
    /// D1 = recomputed row sum − checksum ≈ fault magnitude δ_j.
    pub d1: f64,
    /// D2 = recomputed weighted row sum − weighted checksum ≈ w(j)·δ_j.
    pub d2: f64,
    /// The detection threshold applied to |D1|.
    pub threshold: f64,
    /// |D1| > threshold.
    pub flagged: bool,
}

/// Result of localizing a flagged row.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Localization {
    /// Single fault at this column; D2/D1 was close to an integer weight.
    Column(usize),
    /// The D2/D1 ratio fell outside [1, N] or far from any integer —
    /// inconsistent with a single-column upset (multi-fault, checksum-column
    /// fault, or a fault smaller than rounding noise).
    Inconsistent,
}

/// Verify one encoded product row. `data` is C[i][0..n], `cr1`/`cr2` the
/// checksum entries.
pub fn check_row(
    data: &[f64],
    cr1: f64,
    cr2: f64,
    threshold: f64,
    engine: &GemmEngine,
    weights: &[f64],
) -> RowCheck {
    debug_assert_eq!(data.len(), weights.len());
    let rowsum = engine.reduce(data);
    let wsum = engine.dot(data, weights);
    let d1 = rowsum - cr1;
    let d2 = wsum - cr2;
    // NaN/Inf in the row (e.g. an exponent flip overflowing BF16) can make
    // d1 NaN; treat any non-finite difference as flagged.
    let flagged = !d1.is_finite() || d1.abs() > threshold;
    RowCheck { d1, d2, threshold, flagged }
}

/// Localize a single-column fault from (D1, D2) (Eq. 9).
///
/// `tol` is the acceptable distance of D2/D1 from the nearest integer
/// weight, in weight units (0.5 accepts anything that rounds inside the
/// row; smaller values reject noisier ratios as inconsistent).
pub fn localize(d1: f64, d2: f64, n: usize, tol: f64) -> Localization {
    if !d1.is_finite() || !d2.is_finite() || d1 == 0.0 {
        return Localization::Inconsistent;
    }
    let ratio = d2 / d1; // ≈ w(j) = j+1
    if !ratio.is_finite() {
        return Localization::Inconsistent;
    }
    let w = ratio.round();
    if (ratio - w).abs() > tol {
        return Localization::Inconsistent;
    }
    if w < 1.0 || w > n as f64 {
        return Localization::Inconsistent;
    }
    Localization::Column(w as usize - 1)
}

/// Correct a localized fault in place (Eq. 10): C[i][j] ← C[i][j] − D1,
/// requantizing onto the output grid the row is stored in.
pub fn correct_in_place(
    c: &mut Matrix,
    row: usize,
    col: usize,
    d1: f64,
    out_precision: crate::fp::Precision,
) {
    let fixed = c.get(row, col) - d1;
    c.set(row, col, out_precision.quantize(fixed));
}

/// Position weights [w(0), …, w(n−1)] = [1, …, n].
pub fn weight_vector(n: usize) -> Vec<f64> {
    (0..n).map(position_weight).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::abft::encode::ChecksumEncoding;
    use crate::fp::Precision;
    use crate::gemm::AccumModel;
    use crate::rng::{Distribution, Xoshiro256pp};

    fn setup(
        seed: u64,
    ) -> (Matrix, Vec<f64>, Vec<f64>, GemmEngine, ChecksumEncoding) {
        let mut rng = Xoshiro256pp::seed_from_u64(seed);
        let d = Distribution::uniform_pm1();
        let a = Matrix::sample(6, 24, &d, &mut rng);
        let b = Matrix::sample(24, 16, &d, &mut rng);
        let engine = GemmEngine::new(AccumModel::cpu(Precision::F64));
        let enc = ChecksumEncoding::encode_b(&b, &engine);
        let cf = engine.matmul_mixed(&a, &enc.b_encoded, enc.wide_cols()).c;
        let (c, cr1, cr2) = enc.split_product(&cf);
        (c, cr1, cr2, engine, enc)
    }

    #[test]
    fn clean_rows_pass() {
        let (c, cr1, cr2, engine, _) = setup(1);
        let w = weight_vector(16);
        for i in 0..c.rows() {
            let rc = check_row(c.row(i), cr1[i], cr2[i], 1e-10, &engine, &w);
            assert!(!rc.flagged, "row {i}: d1 = {}", rc.d1);
        }
    }

    #[test]
    fn injected_fault_is_detected_localized_corrected() {
        let (mut c, cr1, cr2, engine, _) = setup(2);
        let (fi, fj, delta) = (3usize, 7usize, 0.125f64);
        let clean = c.get(fi, fj);
        c.set(fi, fj, clean + delta);

        let w = weight_vector(16);
        let rc = check_row(c.row(fi), cr1[fi], cr2[fi], 1e-6, &engine, &w);
        assert!(rc.flagged);
        // D1 = (rowsum + delta) − checksum ≈ +delta
        assert!((rc.d1 - delta).abs() < 1e-9, "d1 = {}", rc.d1);

        match localize(rc.d1, rc.d2, 16, 0.45) {
            Localization::Column(j) => assert_eq!(j, fj),
            other => panic!("localization failed: {other:?}"),
        }
        correct_in_place(&mut c, fi, fj, rc.d1, Precision::F64);
        assert!((c.get(fi, fj) - clean).abs() < 1e-9);

        // Row verifies clean after correction.
        let rc2 = check_row(c.row(fi), cr1[fi], cr2[fi], 1e-6, &engine, &w);
        assert!(!rc2.flagged, "post-correction d1 = {}", rc2.d1);
    }

    #[test]
    fn nan_poisoned_row_is_flagged() {
        let (mut c, cr1, cr2, engine, _) = setup(3);
        c.set(0, 5, f64::NAN);
        let w = weight_vector(16);
        let rc = check_row(c.row(0), cr1[0], cr2[0], 1e9, &engine, &w);
        assert!(rc.flagged, "NaN must always flag regardless of threshold");
        assert_eq!(localize(rc.d1, rc.d2, 16, 0.45), Localization::Inconsistent);
    }

    #[test]
    fn infinity_overflow_is_flagged() {
        let (mut c, cr1, cr2, engine, _) = setup(4);
        c.set(1, 0, f64::INFINITY);
        let w = weight_vector(16);
        let rc = check_row(c.row(1), cr1[1], cr2[1], 1e9, &engine, &w);
        assert!(rc.flagged);
    }

    #[test]
    fn localize_rejects_out_of_range_ratio() {
        assert_eq!(localize(1.0, 40.0, 16, 0.45), Localization::Inconsistent);
        assert_eq!(localize(1.0, 0.2, 16, 0.45), Localization::Inconsistent);
        assert_eq!(localize(0.0, 1.0, 16, 0.45), Localization::Inconsistent);
        assert_eq!(localize(1.0, 3.3, 16, 0.2), Localization::Inconsistent);
        assert_eq!(localize(1.0, 3.1, 16, 0.2), Localization::Column(2));
    }

    #[test]
    fn localize_tolerance_boundary() {
        // The 0.45 default sits strictly below 0.5 — the point where two
        // adjacent columns become indistinguishable. A ratio exactly
        // halfway between integer weights (distance 0.5, the worst case,
        // produced by e.g. same-row deltas δ at w=4 and δ at w=5:
        // D2/D1 = 4.5) must be rejected at tol 0.45 …
        assert_eq!(localize(2.0, 9.0, 16, 0.45), Localization::Inconsistent);
        // … and only an explicit tol ≥ 0.5 would accept it.
        assert_eq!(localize(2.0, 9.0, 16, 0.5), Localization::Column(4));
        // Distances inside the tolerance are accepted (exact binary
        // fractions, so no representation slack in the comparison):
        // 3.4375 is 0.4375 from 3 …
        assert_eq!(localize(1.0, 3.4375, 16, 0.45), Localization::Column(2));
        // … while 3.46875 (0.46875 away) is rejected, from either side.
        assert_eq!(localize(1.0, 3.46875, 16, 0.45), Localization::Inconsistent);
        assert_eq!(localize(1.0, 2.5625, 16, 0.45), Localization::Column(2));
    }

    #[test]
    fn two_faults_in_one_row_localize_inconsistently_most_of_the_time() {
        // Under the SEU model two upsets per row are out of scope; the
        // ratio check should usually notice. Deterministic instance:
        let (mut c, cr1, cr2, engine, _) = setup(5);
        c.set(2, 3, c.get(2, 3) + 1.0);
        c.set(2, 11, c.get(2, 11) + std::f64::consts::E); // irrational offset
        let w = weight_vector(16);
        let rc = check_row(c.row(2), cr1[2], cr2[2], 1e-6, &engine, &w);
        assert!(rc.flagged);
        // ratio = (4·1 + 12·e)/(1 + e) ≈ 9.85 → 0.15 from integer; with a
        // tight tolerance this is rejected.
        assert_eq!(localize(rc.d1, rc.d2, 16, 0.1), Localization::Inconsistent);
    }
}
