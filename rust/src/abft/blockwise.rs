//! Block-wise ABFT (paper §5.2): partition the K dimension into tiles,
//! checksum + verify each partial product independently, then accumulate.
//!
//! Rounding error grows with accumulation depth, so per-block verification
//! (depth `bk` instead of `K`) gets *tighter thresholds* — the paper's
//! Ascend integration uses (M, K, N) tiles of (128, 1024, 256) to "achieve
//! reliable detection while keeping overhead within the GEMM pipeline's
//! slack". Per-block verification also localizes the fault in K (which
//! block) in addition to the output column.

use crate::abft::encode::ChecksumEncoding;
use crate::abft::verify::{check_row, localize, weight_vector, Localization};
use crate::abft::{Detection, Verdict, VerifyPolicy, VerifyReport};
use crate::gemm::GemmEngine;
use crate::matrix::Matrix;
use crate::threshold::{Threshold, ThresholdContext, VabftThreshold};

/// Output of a block-wise protected multiply.
#[derive(Debug, Clone)]
pub struct BlockwiseOutput {
    pub c: Matrix,
    pub report: VerifyReport,
    /// Which K-block each detection occurred in (parallel to
    /// `report.detections`).
    pub detection_blocks: Vec<usize>,
    pub blocks: usize,
}

/// Block-wise fault-tolerant GEMM over K tiles.
pub struct BlockwiseFtGemm {
    engine: GemmEngine,
    threshold: VabftThreshold,
    policy: VerifyPolicy,
    /// K tile depth (paper's NPU configuration uses 1024).
    pub block_k: usize,
}

impl BlockwiseFtGemm {
    pub fn new(engine: GemmEngine, block_k: usize, policy: VerifyPolicy) -> BlockwiseFtGemm {
        assert!(block_k > 0);
        BlockwiseFtGemm { engine, threshold: VabftThreshold::default(), policy, block_k }
    }

    pub fn with_threshold(mut self, t: VabftThreshold) -> Self {
        self.threshold = t;
        self
    }

    /// Protected multiply with optional per-block fault injection
    /// (`inject(block_index, partial)` mutates the partial accumulator).
    pub fn multiply_with_injection(
        &self,
        a: &Matrix,
        b: &Matrix,
        mut inject: impl FnMut(usize, &mut Matrix),
    ) -> anyhow::Result<BlockwiseOutput> {
        assert_eq!(a.cols(), b.rows());
        let (m, k, n) = (a.rows(), a.cols(), b.cols());
        let model = self.engine.model();
        let ctx = if self.policy.online {
            ThresholdContext::online(model)
        } else {
            ThresholdContext::offline(model)
        };
        let grid = if self.policy.online { model.work } else { model.out };
        let weights = weight_vector(n);
        let blocks = (k + self.block_k - 1) / self.block_k;

        let mut acc = Matrix::zeros(m, n);
        let mut detections = Vec::new();
        let mut detection_blocks = Vec::new();
        let mut rows_recomputed = 0usize;

        for bi in 0..blocks {
            let k0 = bi * self.block_k;
            let k1 = (k0 + self.block_k).min(k);
            // Slice the K block (copying; block reuse patterns would cache
            // these in a real pipeline).
            let a_blk = Matrix::from_fn(m, k1 - k0, |i, j| a.get(i, k0 + j));
            let b_blk = Matrix::from_fn(k1 - k0, n, |i, j| b.get(k0 + i, j));

            let enc = if self.policy.online {
                ChecksumEncoding::encode_b_wide(&b_blk, &self.engine)
            } else {
                ChecksumEncoding::encode_b(&b_blk, &self.engine)
            };
            let mut out = self.engine.matmul_mixed(&a_blk, &enc.b_encoded, enc.wide_cols());
            inject(bi, &mut out.acc);
            let src = if self.policy.online { &out.acc } else { &out.c };
            let (mut part, cr1, cr2) = enc.split_product(src);

            // Per-block thresholds: reduction depth is the BLOCK depth, so
            // e_max (and hence T) is evaluated at max(n, bk), not K.
            let th = self.threshold.thresholds(&a_blk, &b_blk, &ctx);

            for i in 0..m {
                let rc = check_row(part.row(i), cr1[i], cr2[i], th[i], &self.engine, &weights);
                if !rc.flagged {
                    continue;
                }
                let mut det = Detection {
                    row: i,
                    col: None,
                    d1: rc.d1,
                    d2: rc.d2,
                    threshold: rc.threshold,
                    corrected: false,
                };
                if self.policy.correct {
                    if let Localization::Column(j) =
                        localize(rc.d1, rc.d2, n, self.policy.localize_tol)
                    {
                        det.col = Some(j);
                        let fixed = part.get(i, j) - rc.d1;
                        part.set(i, j, grid.quantize(fixed));
                        det.corrected = true;
                    }
                }
                if !det.corrected && self.policy.recompute {
                    let a_row = Matrix::from_vec(1, k1 - k0, a_blk.row(i).to_vec());
                    let rec = self.engine.matmul(&a_row, &b_blk);
                    let src_row =
                        if self.policy.online { rec.acc } else { rec.c };
                    part.row_mut(i).copy_from_slice(src_row.row(0));
                    rows_recomputed += 1;
                }
                detections.push(det);
                detection_blocks.push(bi);
            }

            // Aggregate the verified partial into the running sum (work
            // precision; the final output rounding happens once below).
            for i in 0..m {
                let dst = acc.row_mut(i);
                for (d, &s) in dst.iter_mut().zip(part.row(i)) {
                    *d = model.work.quantize(*d + s);
                }
            }
        }

        let verdict = if detections.is_empty() {
            Verdict::Clean
        } else if rows_recomputed > 0 {
            Verdict::Recomputed
        } else if detections.iter().all(|d| d.corrected) {
            Verdict::Corrected
        } else {
            Verdict::Flagged
        };
        let c = acc.quantized(model.out);
        Ok(BlockwiseOutput {
            c,
            report: VerifyReport {
                verdict,
                detections,
                rows_checked: m * blocks,
                rows_recomputed,
            },
            detection_blocks,
            blocks,
        })
    }

    /// Protected multiply without injection.
    pub fn multiply(&self, a: &Matrix, b: &Matrix) -> anyhow::Result<BlockwiseOutput> {
        self.multiply_with_injection(a, b, |_, _| {})
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fp::Precision;
    use crate::gemm::AccumModel;
    use crate::rng::{Distribution, Xoshiro256pp};

    fn operands(seed: u64, m: usize, k: usize, n: usize) -> (Matrix, Matrix) {
        let mut rng = Xoshiro256pp::seed_from_u64(seed);
        let d = Distribution::normal_1_1();
        (Matrix::sample(m, k, &d, &mut rng), Matrix::sample(k, n, &d, &mut rng))
    }

    #[test]
    fn blockwise_matches_monolithic_product() {
        let (a, b) = operands(1, 8, 96, 16);
        let model = AccumModel::wide(Precision::Bf16);
        let bw = BlockwiseFtGemm::new(GemmEngine::new(model), 32, VerifyPolicy::default());
        let out = bw.multiply(&a, &b).unwrap();
        assert_eq!(out.report.verdict, Verdict::Clean);
        assert_eq!(out.blocks, 3);
        // numerically close to the monolithic engine result (different
        // accumulation grouping → small fp differences)
        let mono = GemmEngine::new(model).matmul(&a, &b);
        assert!(out.c.max_abs_diff(&mono.c) < 0.1, "{}", out.c.max_abs_diff(&mono.c));
    }

    #[test]
    fn ragged_last_block() {
        let (a, b) = operands(2, 4, 50, 8); // 50 = 32 + 18
        let model = AccumModel::cpu(Precision::F64);
        let bw = BlockwiseFtGemm::new(GemmEngine::new(model), 32, VerifyPolicy::default());
        let out = bw.multiply(&a, &b).unwrap();
        assert_eq!(out.blocks, 2);
        assert_eq!(out.report.verdict, Verdict::Clean);
        let mono = GemmEngine::new(model).matmul(&a, &b);
        assert!(out.c.max_abs_diff(&mono.c) < 1e-10);
    }

    #[test]
    fn fault_is_attributed_to_its_block_and_corrected() {
        let (a, b) = operands(3, 8, 128, 16);
        let model = AccumModel::wide(Precision::Bf16);
        let bw = BlockwiseFtGemm::new(GemmEngine::new(model), 64, VerifyPolicy::default());
        let clean = bw.multiply(&a, &b).unwrap();
        let out = bw
            .multiply_with_injection(&a, &b, |bi, acc| {
                if bi == 1 {
                    let v = acc.get(5, 3);
                    acc.set(5, 3, v + 8.0);
                }
            })
            .unwrap();
        assert_eq!(out.report.verdict, Verdict::Corrected);
        assert_eq!(out.detection_blocks, vec![1], "fault must localize to block 1");
        assert_eq!(out.report.detections[0].row, 5);
        assert_eq!(out.report.detections[0].col, Some(3));
        assert!(out.c.max_abs_diff(&clean.c) < 1e-2);
    }

    #[test]
    fn per_block_thresholds_are_tighter_than_monolithic() {
        // The point of §5.2: depth-bk verification beats depth-K. Compare
        // the V-ABFT threshold of one block against the full-K threshold.
        let (a, b) = operands(4, 4, 1024, 64);
        let model = AccumModel::npu_fp32();
        let ctx = ThresholdContext::offline(model);
        let vab = VabftThreshold::default();
        let t_full = vab.thresholds(&a, &b, &ctx)[0];
        let a_blk = Matrix::from_fn(4, 128, |i, j| a.get(i, j));
        let b_blk = Matrix::from_fn(128, 64, |i, j| b.get(i, j));
        let t_blk = vab.thresholds(&a_blk, &b_blk, &ctx)[0];
        assert!(
            t_blk < t_full / 2.0,
            "block threshold {t_blk} should be ≪ full {t_full}"
        );
    }
}
